//! Bench: throughput of the Rust-side dot algorithms — the performance half
//! of the accuracy/throughput trade-off the paper motivates. Reports GUP/s
//! (updates per second) for each scheme at n = 64k (L2-resident on the
//! host): expect kahan ~2-4x slower than naive in *scalar* Rust (the gap
//! SIMD closes on the paper's machines) and dot2 slower still; the exact
//! expansion accumulator is orders of magnitude off — the "arbitrary
//! precision" end of the spectrum.

use kahan_ecm::accuracy::{dots, exact::exact_dot, sums};
use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::util::rng::Rng;

fn main() {
    let n = 65_536usize;
    let mut rng = Rng::new(3);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let xs: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a * b).collect();

    let mut r = Runner::new();
    let w = n as f64;
    r.bench("naive_dot", w, || {
        black_box(dots::naive_dot(&x, &y));
    });
    r.bench("kahan_dot (Fig. 2b)", w, || {
        black_box(dots::kahan_dot(&x, &y));
    });
    r.bench("kahan_dot_lanes x128 (Pallas semantics)", w, || {
        black_box(dots::kahan_dot_lanes(&x, &y, 128));
    });
    r.bench("dot2 (Ogita-Rump-Oishi)", w, || {
        black_box(dots::dot2(&x, &y));
    });
    r.bench("neumaier_sum of products", w, || {
        black_box(sums::neumaier_sum(&xs));
    });
    r.bench("pairwise_sum of products", w, || {
        black_box(sums::pairwise_sum(&xs));
    });
    // Exact accumulation is very slow; bench a slice to keep wallclock sane.
    let m = 2048usize;
    r.bench("exact_dot (Shewchuk expansions, n=2048)", m as f64, || {
        black_box(exact_dot(&x[..m], &y[..m]));
    });
    r.footer("UP");
}
