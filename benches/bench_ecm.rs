//! Bench: the ECM engine itself — Table I derivations and the `ecm-inputs`
//! table (every kernel x machine x precision). The model must be cheap
//! enough to run interactively and inside sweeps.

use kahan_ecm::arch::all_machines;
use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::harness::{self, Ctx};
use kahan_ecm::isa::Variant;
use kahan_ecm::util::units::Precision;

fn main() {
    let mut r = Runner::new();
    let machines = all_machines();

    r.bench("derive+predict: HSW kahan-fma5", 1.0, || {
        let m = &machines[0];
        let i = ecm::derive::paper_row(m, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem);
        black_box(i.predict().mem_cycles());
    });

    r.bench("derive+predict: all machines x 5 variants x 2 prec", 1.0, || {
        for m in &machines {
            for v in [
                Variant::NaiveSimd,
                Variant::KahanSimd,
                Variant::KahanSimdFma,
                Variant::KahanSimdFma5,
                Variant::KahanScalar,
            ] {
                for p in [Precision::Sp, Precision::Dp] {
                    let i = ecm::derive::paper_row(m, v, p, MemLevel::Mem);
                    black_box(i.predict().mem_cycles());
                }
            }
        }
    });

    r.bench("saturation + scaling curve: HSW naive", 1.0, || {
        let m = &machines[0];
        let i = ecm::derive::paper_row(m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        black_box(ecm::scaling::scaling_curve(m, &i));
    });

    r.bench("experiment table1 (end-to-end)", 1.0, || {
        black_box(harness::tables::table1(&Ctx::quick()).unwrap());
    });

    r.bench("experiment ecm-inputs (end-to-end)", 1.0, || {
        black_box(harness::tables::ecm_inputs(&Ctx::quick()).unwrap());
    });
}
