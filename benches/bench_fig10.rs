//! Bench: Fig. 10 regeneration (cross-architecture comparison).

use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::harness::{fig10, Ctx};

fn main() {
    let mut r = Runner::new();
    r.bench("fig10a end-to-end", 1.0, || {
        black_box(fig10::fig10a(&Ctx::quick()).unwrap());
    });
    r.bench("fig10b end-to-end", 1.0, || {
        black_box(fig10::fig10b(&Ctx::quick()).unwrap());
    });
}
