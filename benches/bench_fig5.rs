//! Bench: Fig. 5 regeneration (HSW/BDW single-core sweeps) end-to-end, plus
//! the per-point primitive (one sweep point = core sim memoized + cache
//! engine + compose).

use kahan_ecm::arch::haswell;
use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::harness::{fig5, Ctx};
use kahan_ecm::isa::Variant;
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::units::{Precision, GIB, MIB};

fn main() {
    let mut r = Runner::new();
    let m = haswell();
    let k = ecm::derive::kernel_for(&m, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem);
    let sizes = sim::default_sweep_sizes(GIB);

    r.bench("one sweep point (4 MiB)", 1.0, || {
        black_box(sim::sweep(&m, &k, &[4 * MIB], &MeasureOpts::default()));
    });
    r.bench(&format!("full sweep ({} points)", sizes.len()), sizes.len() as f64, || {
        black_box(sim::sweep(&m, &k, &sizes, &MeasureOpts::default()));
    });
    r.bench("fig5a end-to-end (quick grid)", 1.0, || {
        black_box(fig5::fig5a(&Ctx::quick()).unwrap());
    });
    r.bench("fig5b end-to-end (quick grid)", 1.0, || {
        black_box(fig5::fig5b(&Ctx::quick()).unwrap());
    });
}
