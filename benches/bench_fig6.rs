//! Bench: Fig. 6 regeneration (KNC per-level kernels sweep).

use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::harness::{fig6, Ctx};

fn main() {
    let mut r = Runner::new();
    r.bench("fig6 end-to-end (quick grid)", 1.0, || {
        black_box(fig6::fig6(&Ctx::quick()).unwrap());
    });
}
