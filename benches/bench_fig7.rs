//! Bench: Fig. 7 regeneration (PWR8 SMT sweeps — the heaviest single-core
//! experiments: 4 SMT settings x full sweep, 112-op bodies, SMT-8 sim).

use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::harness::{fig7, Ctx};

fn main() {
    let mut r = Runner::new();
    r.bench("fig7a end-to-end (quick grid)", 1.0, || {
        black_box(fig7::fig7a(&Ctx::quick()).unwrap());
    });
    r.bench("fig7b end-to-end (quick grid)", 1.0, || {
        black_box(fig7::fig7b(&Ctx::quick()).unwrap());
    });
}
