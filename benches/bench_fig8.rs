//! Bench: Fig. 8 regeneration (in-memory core scans on all four machines)
//! plus the corescan primitive.

use kahan_ecm::arch::haswell;
use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::harness::{fig8, Ctx};
use kahan_ecm::isa::Variant;
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::units::{Precision, GIB};

fn main() {
    let mut r = Runner::new();
    let m = haswell();
    let k = ecm::derive::kernel_for(&m, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem);
    r.bench("corescan primitive (HSW, 14 cores)", 14.0, || {
        black_box(sim::corescan(&m, &k, 10 * GIB, &MeasureOpts::default()));
    });
    for (name, f) in [
        ("fig8a", fig8::fig8a as fn(&Ctx) -> anyhow::Result<kahan_ecm::harness::ExperimentOutput>),
        ("fig8b", fig8::fig8b as fn(&Ctx) -> _),
        ("fig8c", fig8::fig8c as fn(&Ctx) -> _),
        ("fig8d", fig8::fig8d as fn(&Ctx) -> _),
    ] {
        r.bench(&format!("{name} end-to-end"), 1.0, || {
            black_box(f(&Ctx::quick()).unwrap());
        });
    }
}
