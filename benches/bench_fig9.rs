//! Bench: Fig. 9 regeneration (compiler Kahan ddot scaling, all machines).

use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::harness::{fig9, Ctx};

fn main() {
    let mut r = Runner::new();
    r.bench("fig9 end-to-end", 1.0, || {
        black_box(fig9::fig9(&Ctx::quick()).unwrap());
    });
}
