//! Bench: the real-machine path — PJRT execution of the AOT artifacts
//! (feature `pjrt`; requires `make artifacts` and a real xla crate; exits
//! cleanly when either is absent). Includes dispatch overhead (tiny
//! artifact) vs streaming throughput (large artifact).

use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::runtime::{Executor, Manifest};
use kahan_ecm::util::rng::Rng;

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("artifacts/ not built; skipping host benches (run `make artifacts`)");
        return;
    };
    let Ok(mut ex) = Executor::new(manifest) else {
        eprintln!("no PJRT runtime available (stub xla crate); skipping host benches");
        return;
    };
    let mut rng = Rng::new(5);

    let mut r = Runner::new();
    for name in ["naive_opt_f32_n4096", "naive_f32_n4096", "kahan_f32_n4096"] {
        let art = ex.manifest().get(name).unwrap().clone();
        let data: Vec<Vec<f64>> = art
            .input_shapes
            .iter()
            .map(|s| {
                let n: u64 = s.iter().product();
                (0..n).map(|_| rng.normal()).collect()
            })
            .collect();
        let refs: Vec<&[f64]> = data.iter().map(|d| d.as_slice()).collect();
        let lits = ex.literals(&art, &refs).unwrap();
        // warm compile outside the timed region
        let _ = ex.run_prepared(name, &lits).unwrap();
        r.bench(&format!("pjrt exec {name}"), art.updates() as f64, || {
            black_box(ex.run_prepared(name, &lits).unwrap());
        });
    }
    r.footer("UP");
}
