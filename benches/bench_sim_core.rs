//! Bench: the scoreboard core simulator — the hot inner loop of every sweep
//! point. Perf target (EXPERIMENTS.md §Perf): single kernel steady-state
//! < 10 ms.

use kahan_ecm::arch::{haswell, knights_corner, power8};
use kahan_ecm::bench_kit::{black_box, Runner};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::isa::Variant;
use kahan_ecm::sim::simulate_core;
use kahan_ecm::util::units::Precision;

fn main() {
    let mut r = Runner::new();
    let hsw = haswell();
    let knc = knights_corner();
    let p8 = power8();

    let k_naive = ecm::derive::kernel_for(&hsw, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
    let k_kahan =
        ecm::derive::kernel_for(&hsw, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem);
    let k_knc = ecm::derive::kernel_for(&knc, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem);
    let k_p8 = ecm::derive::kernel_for(&p8, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem);

    r.bench("scoreboard: HSW naive (30 ops/body)", 1.0, || {
        black_box(simulate_core(&hsw, &k_naive, 1).cycles_per_cl);
    });
    r.bench("scoreboard: HSW kahan-fma5", 1.0, || {
        black_box(simulate_core(&hsw, &k_kahan, 1).cycles_per_cl);
    });
    r.bench("scoreboard: KNC kahan (in-order, SMT-2)", 1.0, || {
        black_box(simulate_core(&knc, &k_knc, 2).cycles_per_cl);
    });
    r.bench("scoreboard: PWR8 kahan (112 ops/body, SMT-8)", 1.0, || {
        black_box(simulate_core(&p8, &k_p8, 8).cycles_per_cl);
    });
}
