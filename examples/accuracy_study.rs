//! Accuracy deep-dive: sweep the condition number and watch each summation
//! scheme fail in its own way — the quantitative version of the paper's
//! Sect. 1 motivation ("balancing performance vs. accuracy").
//!
//! Run: `cargo run --release --example accuracy_study [-- <n>]`

use kahan_ecm::accuracy::{
    dots::{dot2, kahan_dot, kahan_dot_lanes, naive_dot},
    generator::{condition_number, ill_conditioned_dot},
};
use kahan_ecm::util::rng::Rng;
use kahan_ecm::util::table::Table;

fn rel(got: f64, exact: f64) -> String {
    let e = if exact == 0.0 {
        got.abs()
    } else {
        ((got - exact) / exact).abs()
    };
    if e == 0.0 {
        "exact".to_string()
    } else {
        format!("{e:.1e}")
    }
}

fn main() {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4096);
    let mut rng = Rng::new(7);
    let mut t = Table::new([
        "cond (measured)", "naive", "kahan", "kahan 128 lanes (Pallas semantics)", "dot2 (ORO)",
    ]);
    for ce in (4..=120).step_by(8) {
        let (x, y, exact) = ill_conditioned_dot(n, 2f64.powi(ce), &mut rng);
        let cond = condition_number(&x, &y, exact);
        t.row([
            format!("2^{:.0}", cond.log2()),
            rel(naive_dot(&x, &y), exact),
            rel(kahan_dot(&x, &y), exact),
            rel(kahan_dot_lanes(&x, &y, 128), exact),
            rel(dot2(&x, &y), exact),
        ]);
    }
    println!("relative error vs condition number (n = {n}, f64)\n");
    print!("{}", t.to_text());
    println!("\nreading guide: naive degrades ~ eps*cond immediately; Kahan (scalar and");
    println!("lane-parallel — the Pallas kernel's semantics) holds ~eps until cond ~ 1/eps;");
    println!("dot2 computes in doubled precision and holds until cond ~ 1/eps^2 ~ 2^104.");
}
