//! The Sect. 6 "blueprint" claim: point the ECM machinery at a machine the
//! paper never covered. Loads `configs/example_machine.toml` (a Zen-like
//! core), derives the model for every kernel variant, and compares the
//! simulated testbed against the analytic predictions.
//!
//! Run: `cargo run --release --example custom_arch [-- path/to/machine.toml]`

use kahan_ecm::arch::loader::{machine_from_config, EXAMPLE_CONFIG};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::isa::Variant;
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::table::{fnum, Table};
use kahan_ecm::util::units::{Precision, GIB};

fn main() -> anyhow::Result<()> {
    let text = match std::env::args().nth(1) {
        Some(path) => std::fs::read_to_string(path)?,
        None => EXAMPLE_CONFIG.to_string(),
    };
    let m = machine_from_config(&text)?;
    println!("machine: {} ({} cores @ {} GHz)\n", m.name, m.cores, m.freq_ghz);

    let mut t = Table::new([
        "kernel", "ECM input", "prediction (cy/CL)", "sim in-mem (cy/CL)", "n_s chip",
        "P_sat GUP/s",
    ]);
    for v in [
        Variant::NaiveSimd,
        Variant::KahanSimd,
        Variant::KahanSimdFma,
        Variant::KahanSimdFma5,
        Variant::KahanScalar,
    ] {
        let inputs = ecm::derive::paper_row(&m, v, Precision::Sp, MemLevel::Mem);
        let pred = inputs.predict();
        let sat = ecm::scaling::saturation(&m, &inputs);
        let k = ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::Mem);
        let sim_pt = &sim::sweep(&m, &k, &[GIB], &MeasureOpts::default())[0];
        t.row([
            v.label().to_string(),
            inputs.shorthand(),
            pred.shorthand(),
            fnum(sim_pt.cy_per_cl, 2),
            sat.n_s_chip.to_string(),
            fnum(sat.p_sat_chip, 2),
        ]);
    }
    print!("{}", t.to_text());
    println!("\nThe same analysis runs on any machine you can describe in the config");
    println!("format — see configs/example_machine.toml for the schema.");
    Ok(())
}
