//! Mini-likwid on the host: sweep the native kernel ladder over vector
//! lengths on this machine's CPU, exactly like the paper sweeps its testbed
//! machines with likwid-bench. Works on any host — no artifacts needed.
//! (With `--features pjrt` and `make artifacts`, the AOT-compiled Pallas
//! kernels are swept as well.)
//!
//! Run: `cargo run --release --example host_sweep [-- --quick]`

use kahan_ecm::runtime::backend::{Backend, NativeBackend};
use kahan_ecm::runtime::hostbench::{bench_kernel, detect_freq_ghz};
use kahan_ecm::util::table::{fnum, Table};
use kahan_ecm::util::units::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let backend = NativeBackend::new();
    let freq = detect_freq_ghz();
    println!(
        "native backend: avx2 = {}, clock = {}\n",
        backend.has_avx2(),
        freq.map(|f| format!("{f:.2} GHz"))
            .unwrap_or_else(|| "unknown".to_string())
    );

    let (warm, reps) = if quick { (1, 3) } else { (3, 11) };
    let sizes: &[usize] = if quick {
        &[1 << 10, 1 << 14, 1 << 18]
    } else {
        &[1 << 10, 1 << 13, 1 << 16, 1 << 19, 1 << 22, 1 << 24]
    };
    let mut t = Table::new(["kernel", "n", "ws", "ns/exec (min)", "MFlop/s", "GUP/s", "GB/s"]);
    for spec in backend.kernels() {
        for &n in sizes {
            let r = bench_kernel(&backend, spec, n, warm, reps, freq)?;
            t.row([
                r.kernel.clone(),
                r.n.to_string(),
                fmt_bytes(r.ws_bytes),
                fnum(r.ns.min, 0),
                fnum(r.mflops_best, 0),
                fnum(r.gups_best, 3),
                fnum(r.gbs_best, 2),
            ]);
            eprint!(".");
        }
    }
    eprintln!();
    print!("{}", t.to_text());
    println!("\nIn cache the Kahan rungs cost up to ~4x the naive dot; in memory the");
    println!("unrolled+SIMD Kahan variants converge to naive — 'Kahan for free'.");

    #[cfg(feature = "pjrt")]
    pjrt_sweep(quick)?;

    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_sweep(quick: bool) -> anyhow::Result<()> {
    use kahan_ecm::runtime::{bench_artifact, Executor, Manifest};

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            println!("\nPJRT sweep skipped: {e} (run `make artifacts`).");
            return Ok(());
        }
    };
    let mut ex = match Executor::new(manifest) {
        Ok(ex) => ex,
        Err(e) => {
            println!("\nPJRT sweep skipped: {e:#}.");
            return Ok(());
        }
    };
    println!("\nPJRT platform: {}\n", ex.platform());

    let (warm, reps) = if quick { (1, 3) } else { (3, 11) };
    let mut t = Table::new(["artifact", "ws", "ns/exec (min)", "GUP/s", "GB/s"]);
    let names: Vec<String> = ex
        .manifest()
        .artifacts
        .iter()
        .filter(|a| {
            // The sequential-scan variant is O(n)-slow by design; keep its
            // large sizes out of the default sweep.
            !(a.variant == "kahan_scalar" && a.n > 262_144) && !(quick && a.n > 262_144)
        })
        .map(|a| a.name.clone())
        .collect();
    for name in names {
        let r = bench_artifact(&mut ex, &name, warm, reps)?;
        t.row([
            r.name.clone(),
            fmt_bytes(r.ws_bytes),
            fnum(r.ns.min, 0),
            fnum(r.gups_best, 3),
            fnum(r.gbs_best, 2),
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", t.to_text());
    println!("\nnaive_opt = XLA's native dot (compiler-optimal baseline);");
    println!("naive/kahan = lane-parallel Pallas kernels (interpret-mode lowering);");
    println!("kahan_scalar = the loop-carried 'compiler variant' — slow by design.");
    Ok(())
}
