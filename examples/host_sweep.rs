//! Mini-likwid on the host: sweep the AOT-compiled kernels over working-set
//! sizes on this machine's CPU via PJRT, exactly like the paper sweeps its
//! testbed machines with likwid-bench. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example host_sweep [-- --quick]`

use kahan_ecm::runtime::{bench_artifact, Executor, Manifest};
use kahan_ecm::util::table::{fnum, Table};
use kahan_ecm::util::units::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let manifest = Manifest::load("artifacts")?;
    let mut ex = Executor::new(manifest)?;
    println!("PJRT platform: {}\n", ex.platform());

    let (warm, reps) = if quick { (1, 3) } else { (3, 11) };
    let mut t = Table::new(["artifact", "ws", "ns/exec (min)", "GUP/s", "GB/s"]);
    let names: Vec<String> = ex
        .manifest()
        .artifacts
        .iter()
        .filter(|a| {
            // The sequential-scan variant is O(n)-slow by design; keep its
            // large sizes out of the default sweep.
            !(a.variant == "kahan_scalar" && a.n > 262_144)
                && !(quick && a.n > 262_144)
        })
        .map(|a| a.name.clone())
        .collect();
    for name in names {
        let r = bench_artifact(&mut ex, &name, warm, reps)?;
        t.row([
            r.name.clone(),
            fmt_bytes(r.ws_bytes),
            fnum(r.ns.min, 0),
            fnum(r.gups_best, 3),
            fnum(r.gbs_best, 2),
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", t.to_text());
    println!("\nnaive_opt = XLA's native dot (compiler-optimal baseline);");
    println!("naive/kahan = lane-parallel Pallas kernels (interpret-mode lowering);");
    println!("kahan_scalar = the loop-carried 'compiler variant' — slow by design.");
    Ok(())
}
