//! Quickstart: the paper's claim in three acts.
//!
//! 1. *Numerics*: run the native backend's naive and Kahan SIMD dot kernels
//!    on an ill-conditioned input and compare both against the exact value
//!    (with the `pjrt` feature + `make artifacts`, the AOT Pallas kernels
//!    run the same comparison in the `acc` experiment).
//! 2. *Analysis*: derive the ECM model for both kernels on Haswell-EP and
//!    show that Kahan's extra arithmetic is hidden behind the memory
//!    bottleneck ("Kahan for free").
//! 3. *Virtual measurement*: confirm with the simulator testbed.
//!
//! Run: `cargo run --release --example quickstart`

use kahan_ecm::accuracy::{exact::exact_dot, generator::ill_conditioned_dot};
use kahan_ecm::arch::haswell;
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::isa::Variant;
use kahan_ecm::runtime::backend::{
    Backend, ImplStyle, KernelClass, KernelInput, KernelSpec, NativeBackend,
};
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::rng::Rng;
use kahan_ecm::util::units::{Precision, GIB};

fn main() -> anyhow::Result<()> {
    println!("=== 1. Numerics (native backend kernels) =============================");
    let backend = NativeBackend::new();
    let mut rng = Rng::new(42);
    let (x, y, _) = ill_conditioned_dot(4096, 2f64.powi(24), &mut rng);
    let exact = exact_dot(&x, &y);
    let input = KernelInput::Dot(&x, &y);
    println!(
        "condition ~ 2^24, n = 4096, f64 kernels (native backend, avx2 = {}):",
        backend.has_avx2()
    );
    println!("  exact  = {exact:+.9e}");
    for class in [KernelClass::NaiveDot, KernelClass::KahanDot] {
        let spec = KernelSpec::new(class, ImplStyle::SimdLanes);
        let got = backend.run(spec, &input)?;
        println!(
            "  {:<16} = {got:+.9e}   (rel err {:.2e})",
            spec.id(),
            ((got - exact) / exact).abs()
        );
    }

    println!("\n=== 2. ECM analysis on Haswell-EP ====================================");
    let m = haswell();
    for v in [Variant::NaiveSimd, Variant::KahanSimdFma5, Variant::KahanScalar] {
        let inputs = ecm::derive::paper_row(&m, v, Precision::Sp, MemLevel::Mem);
        let pred = inputs.predict();
        let sat = ecm::scaling::saturation(&m, &inputs);
        println!(
            "  {:<14} input {:<36} -> {:<26} n_s/chip = {}",
            inputs.kernel,
            inputs.shorthand(),
            pred.shorthand(),
            sat.n_s_chip
        );
    }
    println!("  => naive and SIMD-Kahan share the same memory-level 19.2 cy/CL:");
    println!("     the compensated dot costs NOTHING for memory-resident data.");

    println!("\n=== 3. Virtual testbed confirms ======================================");
    for v in [Variant::NaiveSimd, Variant::KahanSimdFma5] {
        let k = ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::Mem);
        let pt = &sim::sweep(&m, &k, &[GIB], &MeasureOpts::default())[0];
        println!(
            "  {:<16} simulated in-memory: {:>6.2} cy/CL = {:.2} GUP/s",
            k.name, pt.cy_per_cl, pt.gups
        );
    }
    println!("\nNext: `kahan-ecm run all` regenerates every paper figure into out/,");
    println!("      `kahan-ecm bench-native` measures the ladder on this machine.");
    Ok(())
}
