//! Quickstart: the paper's claim in three acts.
//!
//! 1. *Numerics*: run the AOT-compiled naive and Kahan dot kernels (same
//!    bits, one PJRT dispatch) on an ill-conditioned input and compare both
//!    against the exact value.
//! 2. *Analysis*: derive the ECM model for both kernels on Haswell-EP and
//!    show that Kahan's extra arithmetic is hidden behind the memory
//!    bottleneck ("Kahan for free").
//! 3. *Virtual measurement*: confirm with the simulator testbed.
//!
//! Run: `cargo run --release --example quickstart` (needs `make artifacts`
//! for act 1; acts 2-3 always work).

use kahan_ecm::accuracy::{exact::exact_dot_f32, generator::ill_conditioned_dot};
use kahan_ecm::arch::haswell;
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::isa::Variant;
use kahan_ecm::runtime::{Executor, Manifest};
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::rng::Rng;
use kahan_ecm::util::units::{Precision, GIB};

fn main() -> anyhow::Result<()> {
    println!("=== 1. Numerics (real kernels via PJRT) ===============================");
    match Manifest::load("artifacts") {
        Ok(manifest) => {
            let mut ex = Executor::new(manifest)?;
            let mut rng = Rng::new(42);
            let (x, y, _) = ill_conditioned_dot(4096, 2f64.powi(12), &mut rng);
            let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
            let exact = exact_dot_f32(&xf, &yf);
            let xd: Vec<f64> = xf.iter().map(|&v| v as f64).collect();
            let yd: Vec<f64> = yf.iter().map(|&v| v as f64).collect();
            let out = ex.run("pair_f32_n4096", &[&xd, &yd])?;
            let (naive, kahan) = (out.outputs[0][0], out.outputs[1][0]);
            println!("condition ~ 2^12, n = 4096, f32 kernels (Pallas, AOT via PJRT):");
            println!("  exact  = {exact:+.9e}");
            println!(
                "  naive  = {naive:+.9e}   (rel err {:.2e})",
                ((naive - exact) / exact).abs()
            );
            println!(
                "  kahan  = {kahan:+.9e}   (rel err {:.2e})",
                ((kahan - exact) / exact).abs()
            );
        }
        Err(e) => println!("  [skipped: {e}; run `make artifacts`]"),
    }

    println!("\n=== 2. ECM analysis on Haswell-EP ====================================");
    let m = haswell();
    for v in [Variant::NaiveSimd, Variant::KahanSimdFma5, Variant::KahanScalar] {
        let inputs = ecm::derive::paper_row(&m, v, Precision::Sp, MemLevel::Mem);
        let pred = inputs.predict();
        let sat = ecm::scaling::saturation(&m, &inputs);
        println!(
            "  {:<14} input {:<36} -> {:<26} n_s/chip = {}",
            inputs.kernel,
            inputs.shorthand(),
            pred.shorthand(),
            sat.n_s_chip
        );
    }
    println!("  => naive and SIMD-Kahan share the same memory-level 19.2 cy/CL:");
    println!("     the compensated dot costs NOTHING for memory-resident data.");

    println!("\n=== 3. Virtual testbed confirms ======================================");
    for v in [Variant::NaiveSimd, Variant::KahanSimdFma5] {
        let k = ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::Mem);
        let pt = &sim::sweep(&m, &k, &[GIB], &MeasureOpts::default())[0];
        println!(
            "  {:<16} simulated in-memory: {:>6.2} cy/CL = {:.2} GUP/s",
            k.name, pt.cy_per_cl, pt.gups
        );
    }
    println!("\nNext: `kahan-ecm run all` regenerates every paper figure into out/.");
    Ok(())
}
