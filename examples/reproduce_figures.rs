//! End-to-end driver: regenerate EVERY table and figure of the paper into
//! `out/`, exercising the full stack — ECM engine, simulator testbed, and
//! the PJRT runtime over the AOT-compiled Pallas kernels (acc + host
//! experiments). This is the run recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example reproduce_figures [-- --quick]`

use kahan_ecm::coordinator::{all_experiments, assemble_report, run_parallel};
use kahan_ecm::harness::Ctx;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = Ctx {
        quick,
        ..Ctx::default()
    };
    let defs = all_experiments();
    let jobs = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    eprintln!(
        "reproducing {} paper artifacts ({} mode, {jobs} workers) ...",
        defs.len(),
        if quick { "quick" } else { "full" }
    );
    let outcomes = run_parallel(&defs, &ctx, jobs);
    let mut failed = 0;
    for o in &outcomes {
        match &o.result {
            Ok(out) => {
                out.write("out")?;
                println!("[{:<10}] ok   {:6.1}s  out/{}/", o.id, o.seconds, o.id);
            }
            Err(e) => {
                println!("[{:<10}] FAIL {:6.1}s  {e:#}", o.id, o.seconds);
                failed += 1;
            }
        }
    }
    std::fs::write("out/REPORT.md", assemble_report(&defs, &outcomes))?;
    println!("\nreport: out/REPORT.md");
    if failed > 0 {
        anyhow::bail!("{failed} experiment(s) failed");
    }
    Ok(())
}
