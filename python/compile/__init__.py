"""Build-time compile path (L1 Pallas kernels + L2 JAX model + AOT lowering).

Python in this package runs exactly once, at ``make artifacts`` time. Nothing
here is imported on the Rust request path; the interchange format is HLO text
(see ``aot.py``).

f64 ("ddot") variants require 64-bit mode, so it is enabled unconditionally
at package import — before any tracing can happen.
"""

import jax

jax.config.update("jax_enable_x64", True)
