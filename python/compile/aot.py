"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and NOT
a serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md and gen_hlo.py.

Every artifact is one statically-shaped executable ``artifacts/<name>.hlo.txt``
plus one entry in ``artifacts/manifest.json``. The Rust side
(``rust/src/runtime``) consumes only the manifest and the text files.

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--only SUBSTR] [--list]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Working-set sweep sizes for the host benchmark: with 2 streams x 4 B (f32)
# these span ~32 KiB (L1/L2) to ~256 MiB (memory) on typical hosts.
SWEEP_N = [4096, 262144, 4194304, 33554432]
SCALAR_N = [4096, 262144]  # the sequential variant executes in O(n) steps
DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def artifact_specs():
    """Yield (name, fn, arg_specs, meta) for every artifact to build."""
    for dt_name, dt in DTYPES.items():
        for n in SWEEP_N:
            v = jax.ShapeDtypeStruct((n,), dt)
            for variant in ("naive_opt", "naive", "kahan"):
                fn, _ = model.VARIANTS[variant]
                yield (
                    f"{variant}_{dt_name}_n{n}",
                    fn,
                    (v, v),
                    {"variant": variant, "dtype": dt_name, "n": n, "outputs": 1},
                )
        for n in SCALAR_N:
            v = jax.ShapeDtypeStruct((n,), dt)
            fn, _ = model.VARIANTS["kahan_scalar"]
            yield (
                f"kahan_scalar_{dt_name}_n{n}",
                fn,
                (v, v),
                {"variant": "kahan_scalar", "dtype": dt_name, "n": n, "outputs": 1},
            )
    # Compensated summation (accuracy study).
    for n in (262144,):
        v = jax.ShapeDtypeStruct((n,), jnp.float32)
        yield (
            f"kahan_sum_f32_n{n}",
            model.sum_kahan,
            (v,),
            {"variant": "kahan_sum", "dtype": "f32", "n": n, "outputs": 1},
        )
    # Paired naive+kahan on identical bits (accuracy study).
    for n in (4096, 1048576):
        v = jax.ShapeDtypeStruct((n,), jnp.float32)
        yield (
            f"pair_f32_n{n}",
            model.dot_pair,
            (v, v),
            {"variant": "pair", "dtype": "f32", "n": n, "outputs": 2},
        )
    # Batched compensated dots: one PJRT dispatch, B independent rows.
    b, n = 64, 16384
    vb = jax.ShapeDtypeStruct((b, n), jnp.float32)
    yield (
        f"kahan_batched_f32_b{b}_n{n}",
        model.dot_kahan_batched,
        (vb, vb),
        {"variant": "kahan_batched", "dtype": "f32", "n": n, "batch": b, "outputs": 1},
    )


def to_hlo_text(lowered):
    """stablehlo MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir, only=None, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name, fn, arg_specs, meta in artifact_specs():
        if only and only not in name:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": meta["dtype"]} for s in arg_specs
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            **meta,
        }
        entries.append(entry)
        if verbose:
            print(f"  {name}: {len(text)} chars", file=sys.stderr)
    manifest = {
        "format": 1,
        "interchange": "hlo-text",
        "jax": jax.__version__,
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return entries


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    p.add_argument("--only", default=None, help="build only artifacts whose name contains SUBSTR")
    p.add_argument("--list", action="store_true", help="list artifact names and exit")
    args = p.parse_args()
    if args.list:
        for name, _, _, _ in artifact_specs():
            print(name)
        return
    entries = build(args.out_dir, only=args.only)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
