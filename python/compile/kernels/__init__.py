"""L1: Pallas kernels for the paper's compute hot-spot (the dot product).

Kernel inventory
----------------
- ``naive_dot``  — the baseline "plain sdot/ddot": lane-parallel
  multiply-accumulate, one partial sum per lane, plain lane reduction.
  This is the Pallas analog of the compiler-optimal unrolled SIMD loop of
  Fig. 2a.
- ``kahan_dot``  — the Kahan-compensated dot product of Fig. 2b: the
  compensation term ``c`` lives lane-resident in fast storage for the whole
  sweep, exactly like the register-resident ``c`` of the paper's AVX/IMCI/VSX
  kernels, and the final lane reduction is itself compensated so the lane
  fold does not destroy what the compensation bought.
- ``kahan_sum``  — compensated summation of a single stream (the primitive
  the Kahan trick is usually stated for; used by the accuracy study).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness target and
real-TPU behavior is estimated analytically (DESIGN.md §9).
"""

from .naive_dot import naive_dot
from .kahan_dot import kahan_dot, kahan_dot_state
from .kahan_sum import kahan_sum
from . import ref

__all__ = ["naive_dot", "kahan_dot", "kahan_dot_state", "kahan_sum", "ref"]
