"""Shared plumbing for the 1-D streaming Pallas kernels.

All three kernels (naive_dot, kahan_dot, kahan_sum) are *streaming* kernels:
a 1-D grid walks the input in ``block``-sized slabs (the BlockSpec expresses
the HBM→VMEM schedule that the paper's CPUs expressed with hardware/software
prefetching), and per-lane accumulator state is carried across grid steps in
an output block that every step maps to the same location.

``LANES`` defaults to 128 — the TPU vector-lane count — mirroring the SIMD
width the paper's kernels expressed with AVX/IMCI/VSX registers (see
DESIGN.md §7 Hardware-Adaptation).
"""

import jax.numpy as jnp

# Interpret-mode grid steps carry the *full* input buffers through the XLA
# while-loop state (a copy per step on CPU), so large streams want few,
# large blocks: cap at 1 Mi elements (32 steps for the largest artifact).
# On real TPU hardware the copy artifact does not exist and a 64-Ki block
# (~1 MiB VMEM tile incl. accumulators) would be the natural choice — see
# DESIGN.md §9 and EXPERIMENTS.md §Perf L1.
MAX_DEFAULT_BLOCK = 1 << 20
MIN_DEFAULT_BLOCK = 1024


def _next_pow2(v):
    p = 1
    while p < v:
        p *= 2
    return p


def choose_layout(n, block=None, lanes=None):
    """Pick (block, lanes, padded_n) for an n-element stream.

    ``block`` must be a multiple of ``lanes``; inputs are zero-padded up to a
    multiple of ``block``. Zero padding is harmless for a dot product (the
    products contribute exact zeros; pushing a zero through the Kahan
    recurrence merely applies the pending compensation early, which is a
    *compensated* operation and does not lose accuracy).

    Performance note (EXPERIMENTS.md §Perf, L1): ``lanes`` defaults to the
    full block (one Kahan row per grid step). Fewer, wider rows avoid the
    per-row ``while``/dynamic-slice loop in the interpret-mode lowering;
    more lane-parallel partial sums also improve accuracy slightly. The
    default ``block`` adapts to n (power of two, 1 Ki .. 64 Ki elements):
    interpret-mode grid steps cost ~0.3 ms each on CPU, so fewer/larger
    slabs win; 64 Ki f32 keeps the per-step tile (inputs + accumulators
    ~1 MiB) comfortably VMEM-sized for the real-TPU mapping (DESIGN.md §9).
    """
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    if block is None:
        block = max(MIN_DEFAULT_BLOCK, min(MAX_DEFAULT_BLOCK, _next_pow2(n)))
        if lanes is not None and lanes > block:
            block = lanes
        if lanes is not None and block % lanes:
            block = ((block + lanes - 1) // lanes) * lanes
    if lanes is None:
        lanes = block
    if block % lanes != 0:
        raise ValueError(f"block ({block}) must be a multiple of lanes ({lanes})")
    padded = ((n + block - 1) // block) * block
    return block, lanes, padded


def pad_to(x, padded):
    n = x.shape[0]
    if n == padded:
        return x
    return jnp.pad(x, (0, padded - n))
