"""Kahan-compensated dot product as a Pallas kernel (the paper's Fig. 2b).

Mapping from the paper's SIMD kernels (DESIGN.md §7):

- One Kahan recurrence runs *per vector lane* (``lanes`` of them), exactly as
  the AVX version of the paper runs eight f32 recurrences per register. The
  per-lane state ``(sum, c)`` stays resident in the accumulator blocks for
  the entire stream — the analog of keeping ``ymm`` registers live across
  the unrolled loop.
- The 1-D grid streams ``block``-element slabs of ``x`` and ``y``; the
  BlockSpec index maps are the declarative form of the paper's
  prefetch/unroll schedule (Mosaic double-buffers the HBM→VMEM copies).
- The final grid step folds the per-lane states with a *compensated* lane
  reduction (two_sum based, accumulating both the fold's own rounding errors
  and the pending per-lane compensations) so the reduction does not
  reintroduce O(lanes)·eps error. The paper's asm kernels do the same with
  a horizontal-add epilogue; a plain ``jnp.sum`` here would forfeit roughly
  half the accuracy gain.

Outputs: ``(dot, s_lanes, c_lanes)``. The per-lane state is exposed because
(a) the L2 model reuses it for chunked/distributed dot products and (b) tests
assert invariants on it. The scalar ``dot`` is the headline result.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import choose_layout, pad_to


def _kernel(lanes):
    def kernel(x_ref, y_ref, o_ref, s_ref, c_ref):
        i = pl.program_id(0)
        nsteps = pl.num_programs(0)

        @pl.when(i == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)
            c_ref[...] = jnp.zeros_like(c_ref)
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...].reshape(-1, lanes)
        y = y_ref[...].reshape(-1, lanes)
        rows = x.shape[0]

        # Lane-parallel Kahan recurrence over the rows of this slab. The
        # row loop is the sequential dependency the paper hides with
        # unrolling; lanes are the parallel dimension that hides it here.
        # `rows` is static: small row counts are unrolled in Python (no
        # XLA `while` + dynamic-slice per row — EXPERIMENTS.md §Perf L1);
        # the default layout has rows == 1.
        def step(r, carry):
            s, c = carry
            prod = x[r] * y[r]
            yv = prod - c
            t = s + yv
            c_new = (t - s) - yv
            return t, c_new

        carry = (s_ref[...], c_ref[...])
        if rows <= 8:
            for r in range(rows):
                carry = step(r, carry)
            s, c = carry
        else:
            s, c = lax.fori_loop(0, rows, lambda r, sc: step(r, sc), carry)
        s_ref[...] = s
        c_ref[...] = c

        @pl.when(i == nsteps - 1)
        def _finalize():
            o_ref[0] = _compensated_fold(s_ref[...], c_ref[...])

    return kernel


def _compensated_fold(s, c):
    """Fold per-lane Kahan states into a scalar without losing compensation.

    Power-of-two lane counts use a fully vectorized two_sum *tree* (log2
    depth, no sequential loop); other counts fall back to a sequential
    compensated fold. Both accumulate the fold's own rounding errors plus
    the pending per-lane compensations (which subtract in Fig. 2b's
    convention). Mirrored exactly by ``ref.compensated_lane_reduce``.
    """
    lanes = s.shape[0]
    if lanes & (lanes - 1) == 0:
        err = -c
        while s.shape[0] > 1:
            half = s.shape[0] // 2
            a, b = s[:half], s[half:]
            t = a + b
            ap = t - b
            bp = t - ap
            e = (a - ap) + (b - bp)  # exact two_sum residual, vectorized
            s = t
            err = err[:half] + err[half:] + e
        return s[0] + err[0]

    def fold(l, carry):
        acc, err = carry
        acc2 = acc + s[l]
        ap = acc2 - s[l]
        bp = acc2 - ap
        t = (acc - ap) + (s[l] - bp)
        return acc2, err + (t - c[l])

    zero = jnp.zeros((), s.dtype)
    acc, err = lax.fori_loop(0, lanes, fold, (zero, zero))
    return acc + err


def kahan_dot_state(x, y, block=None, lanes=None):
    """Kahan dot returning ``(dot, s_lanes, c_lanes)``; see module docstring."""
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"expected equal 1-D shapes, got {x.shape} vs {y.shape}")
    n = x.shape[0]
    block, lanes, padded = choose_layout(n, block, lanes)
    x = pad_to(x, padded)
    y = pad_to(y, padded)
    grid = padded // block
    return pl.pallas_call(
        _kernel(lanes),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((lanes,), x.dtype),
            jax.ShapeDtypeStruct((lanes,), x.dtype),
        ],
        interpret=True,
    )(x, y)


def kahan_dot(x, y, block=None, lanes=None):
    """Kahan-compensated dot product of two 1-D vectors (scalar result)."""
    out, _, _ = kahan_dot_state(x, y, block=block, lanes=lanes)
    return out[0]
