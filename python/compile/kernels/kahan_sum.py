"""Kahan-compensated summation of a single stream as a Pallas kernel.

The summation primitive underlying the dot product (the paper's Sect. 1
frames Kahan as a summation algorithm; the dot product is summation of
elementwise products). Used by the accuracy study and as a second,
independent exercise of the lane-resident-compensation pattern.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import choose_layout, pad_to
from .kahan_dot import _compensated_fold


def _kernel(lanes):
    def kernel(x_ref, o_ref, s_ref, c_ref):
        i = pl.program_id(0)
        nsteps = pl.num_programs(0)

        @pl.when(i == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)
            c_ref[...] = jnp.zeros_like(c_ref)
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...].reshape(-1, lanes)
        rows = x.shape[0]

        def step(r, carry):
            s, c = carry
            yv = x[r] - c
            t = s + yv
            return t, (t - s) - yv

        # Static small row counts are unrolled (see kahan_dot.py; the
        # default layout has rows == 1).
        carry = (s_ref[...], c_ref[...])
        if rows <= 8:
            for r in range(rows):
                carry = step(r, carry)
            s, c = carry
        else:
            s, c = lax.fori_loop(0, rows, lambda r, sc: step(r, sc), carry)
        s_ref[...] = s
        c_ref[...] = c

        @pl.when(i == nsteps - 1)
        def _finalize():
            o_ref[0] = _compensated_fold(s_ref[...], c_ref[...])

    return kernel


def kahan_sum(x, block=None, lanes=None):
    """Kahan-compensated sum of a 1-D vector (scalar result)."""
    if x.ndim != 1:
        raise ValueError(f"expected 1-D input, got shape {x.shape}")
    n = x.shape[0]
    block, lanes, padded = choose_layout(n, block, lanes)
    x = pad_to(x, padded)
    grid = padded // block
    out, _, _ = pl.pallas_call(
        _kernel(lanes),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((lanes,), x.dtype),
            jax.ShapeDtypeStruct((lanes,), x.dtype),
        ],
        interpret=True,
    )(x)
    return out[0]
