"""Naive (uncompensated) dot product as a Pallas kernel — the paper's Fig. 2a
baseline ("plain sdot/ddot").

Identical streaming structure to ``kahan_dot`` (same BlockSpec schedule, same
per-lane partial sums) so that the *only* difference between the two kernels
is the compensation arithmetic — mirroring the paper's setup where naive and
Kahan kernels share the load schedule and differ in the arithmetic mix
(2 flops/update vs 5 flops/update).
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .common import choose_layout, pad_to


def _kernel(lanes):
    def kernel(x_ref, y_ref, o_ref, s_ref):
        i = pl.program_id(0)
        nsteps = pl.num_programs(0)

        @pl.when(i == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)
            o_ref[...] = jnp.zeros_like(o_ref)

        x = x_ref[...].reshape(-1, lanes)
        y = y_ref[...].reshape(-1, lanes)

        # Lane-parallel multiply-accumulate: one partial sum per lane, the
        # direct analog of the unrolled-SIMD naive loop (FMA per row).
        s_ref[...] = s_ref[...] + jnp.sum(x * y, axis=0)

        @pl.when(i == nsteps - 1)
        def _finalize():
            o_ref[0] = jnp.sum(s_ref[...])

    return kernel


def naive_dot(x, y, block=None, lanes=None):
    """Naive lane-parallel dot product of two 1-D vectors (scalar result)."""
    if x.shape != y.shape or x.ndim != 1:
        raise ValueError(f"expected equal 1-D shapes, got {x.shape} vs {y.shape}")
    n = x.shape[0]
    block, lanes, padded = choose_layout(n, block, lanes)
    x = pad_to(x, padded)
    y = pad_to(y, padded)
    grid = padded // block
    out, _ = pl.pallas_call(
        _kernel(lanes),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), x.dtype),
            jax.ShapeDtypeStruct((lanes,), x.dtype),
        ],
        interpret=True,
    )(x, y)
    return out[0]
