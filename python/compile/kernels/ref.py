"""Pure-jnp correctness oracles for the Pallas kernels.

Three tiers of reference:

1. ``naive_dot_ref`` / ``naive_sum_ref`` — what the baseline kernel computes,
   up to reassociation (``jnp.dot`` / ``jnp.sum``).
2. ``kahan_dot_ref`` / ``kahan_sum_ref`` — a sequential scalar Kahan
   recurrence (``lax.scan``) in the *working* dtype. This is the literal
   algorithm of Fig. 2b of the paper and is the semantic model for what the
   lane-parallel kernel must approximate (the kernel runs one Kahan
   recurrence per lane plus a compensated lane fold, so it does not match
   the scalar recurrence bit-for-bit; it matches to a few ulps).
3. ``highprec_dot`` — the same dot evaluated in f64 (for f32 inputs); used as
   the "ground truth" both kernels are compared against for error measures.

``two_sum`` / ``fast_two_sum`` are the error-free transformations used by the
compensated lane reduction; they are exposed here so tests can check their
exactness property directly.
"""

import jax
import jax.numpy as jnp
from jax import lax


def two_sum(a, b):
    """Knuth's error-free transformation: a + b = s + t exactly.

    Returns ``(s, t)`` with ``s = fl(a + b)`` and ``t`` the exact rounding
    error. Branch-free; valid for any ordering of |a|, |b|.
    """
    s = a + b
    ap = s - b
    bp = s - ap
    da = a - ap
    db = b - bp
    return s, da + db


def fast_two_sum(a, b):
    """Dekker's error-free transformation; requires |a| >= |b|."""
    s = a + b
    t = b - (s - a)
    return s, t


def kahan_step(carry, xy):
    """One iteration of the Fig. 2b loop: (sum, c), (a_i, b_i) -> (sum', c')."""
    s, c = carry
    a, b = xy
    prod = a * b
    y = prod - c
    t = s + y
    c_new = (t - s) - y
    return (t, c_new), None


def kahan_dot_ref(x, y):
    """Sequential scalar Kahan dot product (lax.scan), working dtype."""
    zero = jnp.zeros((), x.dtype)
    (s, c), _ = lax.scan(kahan_step, (zero, zero), (x, y))
    return s


def kahan_sum_ref(x):
    """Sequential scalar Kahan summation (lax.scan), working dtype."""

    def step(carry, a):
        s, c = carry
        yv = a - c
        t = s + yv
        return (t, (t - s) - yv), None

    zero = jnp.zeros((), x.dtype)
    (s, c), _ = lax.scan(step, (zero, zero), x)
    return s


def naive_dot_ref(x, y):
    """Baseline oracle: XLA's own reduction order for the dot product."""
    return jnp.dot(x, y)


def naive_sum_ref(x):
    return jnp.sum(x)


def highprec_dot(x, y):
    """f64 ground truth (only meaningful for f32 inputs)."""
    return jnp.dot(x.astype(jnp.float64), y.astype(jnp.float64))


def highprec_sum(x):
    return jnp.sum(x.astype(jnp.float64))


def compensated_lane_reduce(s, c):
    """Fold per-lane Kahan states (s_i, c_i) into one scalar, compensated —
    the exact algorithm of the Pallas kernels' final grid step.

    Each lane carries a partial sum ``s_i`` and its pending compensation
    ``c_i`` (which *subtracts* in the Fig. 2b formulation). Power-of-two
    lane counts use the vectorized two_sum tree (mirrors
    ``kahan_dot._compensated_fold`` bit-for-bit); other counts fold
    sequentially. Both accumulate every rounding error plus the pending
    compensations into an error term applied once at the end.
    """
    lanes = s.shape[0]
    if lanes & (lanes - 1) == 0 and lanes > 1:
        err = -c
        while s.shape[0] > 1:
            half = s.shape[0] // 2
            a, b = s[:half], s[half:]
            t = a + b
            ap = t - b
            bp = t - ap
            e = (a - ap) + (b - bp)
            s = t
            err = err[:half] + err[half:] + e
        return s[0] + err[0]

    def step(carry, sc):
        acc, err = carry
        si, ci = sc
        acc, t = two_sum(acc, si)
        return (acc, err + (t - ci)), None

    zero = jnp.zeros((), s.dtype)
    (acc, err), _ = lax.scan(step, (zero, zero), (s, c))
    return acc + err
