"""L2: the JAX compute graphs that get AOT-lowered for the Rust runtime.

Variants mirror the paper's kernel zoo (Sect. 4):

=====================  =======================================================
paper kernel           L2 variant
=====================  =======================================================
naive sdot/ddot,       ``dot_naive_opt``  — ``jnp.dot`` (XLA's own optimal
compiler -O3           reduction; the "compiler generates optimal code" case)
naive, manual SIMD     ``dot_naive``      — the Pallas lane-parallel kernel
Kahan, compiler        ``dot_kahan_scalar`` — sequential ``lax.scan`` Kahan,
(-O1, vectorization    the loop-carried-dependency form a compiler must emit
inhibited)             when it may not reassociate (slow on purpose)
Kahan, manual SIMD     ``dot_kahan``      — the Pallas lane-resident kernel
(AVX/IMCI/VSX)
=====================  =======================================================

plus ``sum_kahan`` (compensated summation) and ``dot_kahan_batched`` (a
B-row batch of compensated dots — the shape the paper's motivating numerics
workloads, e.g. residual norms across many RHS, actually use).

Every public function here is a pure JAX function of arrays; ``aot.py``
lowers each (variant × dtype × size) to an HLO-text artifact.
"""

import jax
import jax.numpy as jnp

from .kernels import kahan_dot, kahan_dot_state, kahan_sum, naive_dot
from .kernels import ref


def dot_naive_opt(x, y):
    """Compiler-optimal naive dot: XLA chooses the reduction strategy."""
    return (jnp.dot(x, y),)


def dot_naive(x, y):
    """Manual lane-parallel naive dot (Pallas kernel)."""
    return (naive_dot(x, y),)


def dot_kahan(x, y):
    """Manual lane-resident Kahan dot (Pallas kernel)."""
    return (kahan_dot(x, y),)


def dot_kahan_state(x, y):
    """Kahan dot exposing per-lane (sum, c) state; used for chunked dots."""
    out, s, c = kahan_dot_state(x, y)
    return (out[0], s, c)


def dot_kahan_scalar(x, y):
    """Sequential scalar Kahan dot — the 'compiler-generated' variant.

    The loop-carried dependency on the compensation term is explicit
    (``lax.scan``), so XLA cannot vectorize across iterations, exactly like
    the compiler variant the paper benchmarks (Sect. 4.2: "the compiler
    detects (correctly) a loop-carried dependency on c, which prohibits SIMD
    vectorization").
    """
    return (ref.kahan_dot_ref(x, y),)


def sum_kahan(x):
    """Compensated summation (Pallas kernel)."""
    return (kahan_sum(x),)


def dot_kahan_batched(xs, ys):
    """Batch of compensated dots: (B, N) x (B, N) -> (B,).

    Rows are independent, so the batch dimension is mapped sequentially with
    ``lax.map`` over the Pallas kernel — batching is the L3 coordinator's
    job (it fans rows out across worker threads); the artifact exists so a
    single PJRT dispatch can amortize executor overhead for small batches.
    """
    return (jax.lax.map(lambda xy: kahan_dot(xy[0], xy[1]), (xs, ys)),)


def dot_pair(x, y):
    """Naive and Kahan dot of the same data in one dispatch.

    Used by the accuracy study: evaluating both on identical inputs in one
    executable guarantees the comparison sees the same bits.
    """
    return (naive_dot(x, y), kahan_dot(x, y))


VARIANTS = {
    "naive_opt": (dot_naive_opt, 2),
    "naive": (dot_naive, 2),
    "kahan": (dot_kahan, 2),
    "kahan_scalar": (dot_kahan_scalar, 2),
    "kahan_sum": (sum_kahan, 1),
    "pair": (dot_pair, 2),
}
