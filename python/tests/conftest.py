"""Test config: put python/ on sys.path and tame hypothesis for slow
interpret-mode Pallas execution."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings, HealthCheck

settings.register_profile(
    "pallas",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("pallas")
