"""Input generators for the numeric tests.

``ill_conditioned_dot`` is a simplified Ogita–Rump–Oishi generator: it builds
a dot product with a prescribed condition number ``cond ~ 2^e_spread`` by
mixing large-magnitude terms that cancel almost exactly with small-magnitude
noise. The exact value is computed with ``math.fsum`` over per-element
products evaluated in f64 (exact for f32 inputs, and accurate to 1 ulp for
f64 inputs since fsum is exactly rounded over the f64 products).
"""

import math

import numpy as np


def ill_conditioned_dot(n, cond_exp, dtype=np.float32, seed=0):
    """Return (x, y, exact) with condition number roughly 2**cond_exp.

    Construction: first half draws factors with exponents spread uniformly in
    [0, cond_exp/2] on both x and y (so products span 2**cond_exp); second
    half inserts near-cancelling terms: y_i chosen so x_i*y_i ~ -(current
    partial sum scale). This mirrors Algorithm 6.1 of Ogita, Rump & Oishi
    (SIAM J. Sci. Comput. 2005) in structure, without requiring exact
    rational arithmetic.
    """
    assert n >= 4 and n % 2 == 0
    rng = np.random.default_rng(seed)
    half = n // 2
    e = rng.uniform(0.0, cond_exp / 2.0, size=half)
    # Ensure the extremes of the exponent range are present.
    e[0] = cond_exp / 2.0
    e[-1] = 0.0
    x1 = ((2.0 * rng.random(half) - 1.0) * np.exp2(e)).astype(dtype)
    y1 = ((2.0 * rng.random(half) - 1.0) * np.exp2(e)).astype(dtype)

    x2 = np.empty(half, dtype=dtype)
    y2 = np.empty(half, dtype=dtype)
    # Exact running sum of what we have so far (f64 products of f32/f64 bits).
    prods = [float(a) * float(b) for a, b in zip(x1.astype(np.float64), y1.astype(np.float64))]
    for i in range(half):
        # Exponent ramps back down so later terms probe every magnitude.
        target_e = cond_exp / 2.0 * (1.0 - i / max(1, half - 1))
        xv = dtype((2.0 * rng.random() - 1.0) * math.exp(target_e * math.log(2.0)))
        if xv == 0.0:
            xv = dtype(1.0)
        s = math.fsum(prods)
        yv = dtype(-s / float(xv) * rng.random())
        x2[i] = xv
        y2[i] = yv
        prods.append(float(np.float64(xv)) * float(np.float64(yv)))
    x = np.concatenate([x1, x2])
    y = np.concatenate([y1, y2])
    exact = math.fsum(
        float(a) * float(b)
        for a, b in zip(x.astype(np.float64), y.astype(np.float64))
    )
    return x, y, exact


def exact_dot(x, y):
    """Exact (f64-product fsum) value of the dot product of f32/f64 arrays."""
    return math.fsum(
        float(a) * float(b)
        for a, b in zip(np.asarray(x, np.float64), np.asarray(y, np.float64))
    )


def exact_sum(x):
    return math.fsum(float(a) for a in np.asarray(x, np.float64))
