"""AOT path: lowering to HLO text, manifest integrity, re-load sanity.

Full-size artifact builds are exercised by ``make artifacts``; here we lower
a small representative subset and validate structure + executability via the
CPU PJRT client (the same backend class the Rust side drives through FFI).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_artifact_specs_well_formed():
    names = set()
    for name, fn, arg_specs, meta in aot.artifact_specs():
        assert name not in names, f"duplicate artifact {name}"
        names.add(name)
        assert callable(fn)
        assert meta["variant"] in (
            "naive_opt", "naive", "kahan", "kahan_scalar", "kahan_sum",
            "pair", "kahan_batched",
        )
        assert meta["outputs"] >= 1
        for s in arg_specs:
            assert all(d > 0 for d in s.shape)
    # the sweep must cover both dtypes and all sweep sizes for core variants
    for dt in ("f32", "f64"):
        for n in aot.SWEEP_N:
            for v in ("naive_opt", "naive", "kahan"):
                assert f"{v}_{dt}_n{n}" in names


def test_to_hlo_text_smoke():
    spec = jax.ShapeDtypeStruct((256,), jnp.float32)
    lowered = jax.jit(model.dot_kahan).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_text_roundtrips_through_pjrt(tmp_path):
    """Lower → text → parse → compile → execute on CPU PJRT, compare
    numerics with direct eager evaluation. This is exactly the Rust path."""
    from jax._src.lib import xla_client as xc

    n = 512
    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(model.dot_pair).lower(spec, spec)
    text = aot.to_hlo_text(lowered)

    x = np.linspace(-1, 1, n).astype(np.float32)
    y = (np.sin(np.arange(n)) * 100).astype(np.float32)
    want_naive, want_kahan = model.dot_pair(jnp.asarray(x), jnp.asarray(y))

    client = xc.Client  # noqa: F841  (presence check)
    backend = jax.devices("cpu")[0].client
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
    )
    # Execute via jax itself (the text form is validated structurally; the
    # binary-level load is the Rust integration test's job).
    assert comp.as_hlo_text().startswith("HloModule")
    got_naive, got_kahan = jax.jit(model.dot_pair)(jnp.asarray(x), jnp.asarray(y))
    assert float(got_naive) == pytest.approx(float(want_naive), rel=1e-6)
    assert float(got_kahan) == pytest.approx(float(want_kahan), rel=1e-6)


def test_build_subset_and_manifest(tmp_path):
    entries = aot.build(str(tmp_path), only="pair_f32_n4096", verbose=False)
    assert len(entries) == 1
    e = entries[0]
    assert e["variant"] == "pair"
    assert e["outputs"] == 2
    hlo = (tmp_path / e["file"]).read_text()
    assert hlo.startswith("HloModule")
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == 1
    assert manifest["interchange"] == "hlo-text"
    assert manifest["artifacts"][0]["name"] == "pair_f32_n4096"
    import hashlib

    assert manifest["artifacts"][0]["sha256"] == hashlib.sha256(hlo.encode()).hexdigest()


def test_build_writes_into_fresh_dir(tmp_path):
    out = os.path.join(str(tmp_path), "nested", "artifacts")
    entries = aot.build(out, only="kahan_sum_f32", verbose=False)
    assert entries
    assert os.path.exists(os.path.join(out, "manifest.json"))
