"""L1 correctness: Pallas kernels vs the pure-jnp oracles (the CORE signal).

hypothesis sweeps shapes, dtypes, block/lane layouts; fixed-seed tests pin
edge cases (n=1, n<lanes, non-divisible n, negative zeros, huge/tiny values).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kahan_dot, kahan_dot_state, kahan_sum, naive_dot, ref
from compile.kernels.common import choose_layout
from tests.gen import exact_dot, exact_sum, ill_conditioned_dot


def rnd(n, seed, dtype=jnp.float32, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return (jax.random.normal(k, (n,)) * scale).astype(dtype)


# ---------------------------------------------------------------- two_sum EFT


@given(
    st.floats(-1e30, 1e30, allow_nan=False, allow_subnormal=False),
    st.floats(-1e30, 1e30, allow_nan=False, allow_subnormal=False),
)
def test_two_sum_exact(a, b):
    """two_sum is an error-free transformation: s + t == a + b exactly
    (verified in higher precision via fsum)."""
    s, t = ref.two_sum(jnp.float64(a), jnp.float64(b))
    # s must be the correctly rounded sum, and t the exact residual.
    assert float(s) == a + b
    # s + t == a + b exactly, checked by exact cancellation:
    assert math.fsum([float(s), float(t), -a, -b]) == 0.0


@given(
    st.floats(-1e15, 1e15, allow_nan=False, allow_subnormal=False),
    st.floats(-1.0, 1.0, allow_subnormal=False),
)
def test_fast_two_sum_exact_when_ordered(a, b):
    # XLA CPU flushes subnormals (FTZ), so the EFT property is only claimed
    # on normal floats.
    if abs(a) < abs(b):
        a, b = b, a
    s, t = ref.fast_two_sum(jnp.float64(a), jnp.float64(b))
    assert math.fsum([float(s), float(t), -a, -b]) == 0.0


# ----------------------------------------------------------- layout plumbing


def test_choose_layout_defaults():
    block, lanes, padded = choose_layout(10_000)
    assert block % lanes == 0
    assert padded % block == 0
    assert padded >= 10_000


def test_choose_layout_small_n():
    # Small n: one padded block, lanes = block (rows == 1 fast path).
    block, lanes, padded = choose_layout(3)
    assert lanes == block
    assert padded == block
    assert padded >= 3


def test_choose_layout_rejects_bad_block():
    with pytest.raises(ValueError):
        choose_layout(100, block=100, lanes=64)


def test_choose_layout_rejects_nonpositive():
    with pytest.raises(ValueError):
        choose_layout(0)


# --------------------------------------------------------------- naive_dot


@given(
    n=st.integers(1, 3000),
    seed=st.integers(0, 2**31),
    dt=st.sampled_from(["f32", "f64"]),
)
def test_naive_dot_matches_jnp(n, seed, dt):
    dtype = jnp.float32 if dt == "f32" else jnp.float64
    x, y = rnd(n, seed, dtype), rnd(n, seed + 1, dtype)
    got = naive_dot(x, y)
    want = ref.naive_dot_ref(x, y)
    # Different (but both valid) reduction orders: compare against the
    # standard naive-summation error bound n*eps*sum|x_i*y_i|, not the
    # (possibly cancelled) result magnitude.
    eps = np.finfo(np.float32 if dt == "f32" else np.float64).eps
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-300
    assert abs(float(got) - float(want)) <= 2 * n * eps * scale


@pytest.mark.parametrize("n", [1, 2, 127, 128, 129, 255, 2048, 2049, 4096 + 17])
def test_naive_dot_sizes(n):
    x, y = rnd(n, 7), rnd(n, 8)
    got = naive_dot(x, y)
    want = exact_dot(np.asarray(x), np.asarray(y))
    assert math.isclose(float(got), want, rel_tol=1e-4, abs_tol=1e-6)


@pytest.mark.parametrize("block,lanes", [(128, 128), (256, 64), (1024, 128), (64, 8)])
def test_naive_dot_layout_invariance(block, lanes):
    """The result must not depend materially on the block/lane layout."""
    x, y = rnd(5000, 3), rnd(5000, 4)
    got = naive_dot(x, y, block=block, lanes=lanes)
    want = exact_dot(np.asarray(x), np.asarray(y))
    assert math.isclose(float(got), want, rel_tol=1e-4, abs_tol=1e-6)


# --------------------------------------------------------------- kahan_dot


@given(
    n=st.integers(1, 3000),
    seed=st.integers(0, 2**31),
    dt=st.sampled_from(["f32", "f64"]),
)
def test_kahan_dot_close_to_scalar_kahan(n, seed, dt):
    """Lane-parallel Kahan vs the sequential Fig. 2b recurrence: both are
    compensated schemes; they agree to a few ulps of the result magnitude."""
    dtype = jnp.float32 if dt == "f32" else jnp.float64
    x, y = rnd(n, seed, dtype), rnd(n, seed + 1, dtype)
    got = float(kahan_dot(x, y))
    want = float(ref.kahan_dot_ref(x, y))
    eps = 1e-6 if dt == "f32" else 1e-15
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(got - want) <= 8 * eps * scale


@given(n=st.integers(1, 2000), seed=st.integers(0, 2**31))
def test_kahan_dot_close_to_exact(n, seed):
    x, y = rnd(n, seed), rnd(n, seed + 1)
    got = float(kahan_dot(x, y))
    want = exact_dot(np.asarray(x), np.asarray(y))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    # Compensated f32 result should be within a few f32 ulps of exact.
    assert abs(got - want) <= 8 * np.finfo(np.float32).eps * scale


@pytest.mark.parametrize("n", [1, 2, 127, 128, 129, 2048, 2049, 10_000])
def test_kahan_dot_sizes(n):
    x, y = rnd(n, 11), rnd(n, 12)
    got = float(kahan_dot(x, y))
    want = exact_dot(np.asarray(x), np.asarray(y))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(got - want) <= 8 * np.finfo(np.float32).eps * scale


@pytest.mark.parametrize("block,lanes", [(128, 128), (256, 64), (2048, 128), (64, 8)])
def test_kahan_dot_layout_invariance(block, lanes):
    x, y = rnd(5000, 13), rnd(5000, 14)
    got = float(kahan_dot(x, y, block=block, lanes=lanes))
    want = exact_dot(np.asarray(x), np.asarray(y))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(got - want) <= 8 * np.finfo(np.float32).eps * scale


def test_kahan_dot_state_consistent():
    """Scalar output equals the compensated fold of the exposed lane state."""
    x, y = rnd(4096, 21), rnd(4096, 22)
    out, s, c = kahan_dot_state(x, y)
    folded = ref.compensated_lane_reduce(s, c)
    np.testing.assert_allclose(float(out[0]), float(folded), rtol=0, atol=0)


def test_kahan_dot_zero_padding_harmless():
    """Padding to the block boundary must not change the compensated result
    beyond a couple of ulps (zeros only flush pending compensation)."""
    n = 2048 - 3  # forces 3 zero pads at default block
    x, y = rnd(n, 31), rnd(n, 32)
    a = float(kahan_dot(x, y))
    b = float(kahan_dot(jnp.pad(x, (0, 3)), jnp.pad(y, (0, 3))))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(a - b) <= 4 * np.finfo(np.float32).eps * scale


def test_kahan_beats_naive_on_ill_conditioned():
    """The paper's premise: compensation wins when cancellation is severe."""
    wins = 0
    for seed in range(5):
        x, y, exact = ill_conditioned_dot(512, cond_exp=30, seed=seed)
        xj, yj = jnp.asarray(x), jnp.asarray(y)
        err_naive = abs(float(naive_dot(xj, yj)) - exact)
        err_kahan = abs(float(kahan_dot(xj, yj)) - exact)
        if err_kahan <= err_naive:
            wins += 1
    assert wins >= 4  # allow one tie/fluke


def test_kahan_dot_shape_mismatch_raises():
    with pytest.raises(ValueError):
        kahan_dot(jnp.ones((4,)), jnp.ones((5,)))
    with pytest.raises(ValueError):
        kahan_dot(jnp.ones((4, 2)), jnp.ones((4, 2)))


# --------------------------------------------------------------- kahan_sum


@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31))
def test_kahan_sum_close_to_exact(n, seed):
    x = rnd(n, seed)
    got = float(kahan_sum(x))
    want = exact_sum(np.asarray(x))
    scale = float(jnp.sum(jnp.abs(x))) + 1e-30
    assert abs(got - want) <= 8 * np.finfo(np.float32).eps * scale


def test_kahan_sum_cancellation():
    """1e8 + many small values - 1e8: naive f32 drops the smalls entirely."""
    small = np.full(10_000, 0.1, np.float32)
    x = jnp.asarray(np.concatenate([[1e8], small, [-1e8]]).astype(np.float32))
    got = float(kahan_sum(x))
    want = exact_sum(np.asarray(x))
    naive = float(jnp.sum(x))
    assert abs(got - want) < abs(naive - want)
    # Kahan bound: |err| <= 2*eps*sum(|x_i|) — relative to the *condition*
    # of the sum (sum|x| ~ 2e8), not to the small result (1e3).
    bound = 2 * np.finfo(np.float32).eps * float(jnp.sum(jnp.abs(x)))
    assert abs(got - want) <= bound


def test_kahan_sum_rejects_2d():
    with pytest.raises(ValueError):
        kahan_sum(jnp.ones((4, 4)))


# --------------------------------------------------- jit/lowering stability


def test_kernels_jit_stable():
    """Kernels must trace and execute consistently under jit (AOT relies on
    this: artifacts are jit-lowered). XLA may contract mul+add into FMAs
    differently between the eager and fully-jitted graphs, so we require
    ulp-level agreement rather than bit equality."""
    x, y = rnd(1024, 41), rnd(1024, 42)
    eager = float(kahan_dot(x, y))
    jitted = float(jax.jit(kahan_dot)(x, y))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(eager - jitted) <= 4 * np.finfo(np.float32).eps * scale


@settings(max_examples=10)
@given(n=st.integers(4, 500))
def test_naive_vs_kahan_same_data_similar(n):
    """On well-conditioned data, both kernels agree to f32 tolerance
    (the paper's 'Kahan costs nothing *numerically* on benign data')."""
    x, y = rnd(n, n), rnd(n, n + 1)
    a = float(naive_dot(x, y))
    b = float(kahan_dot(x, y))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(a - b) <= 64 * np.finfo(np.float32).eps * scale
