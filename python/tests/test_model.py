"""L2 model: variant semantics, batching, shape stability."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from tests.gen import exact_dot, ill_conditioned_dot


def rnd(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


def test_variants_registry_complete():
    assert set(model.VARIANTS) == {
        "naive_opt", "naive", "kahan", "kahan_scalar", "kahan_sum", "pair",
    }
    for name, (fn, ninputs) in model.VARIANTS.items():
        assert callable(fn), name
        assert ninputs in (1, 2), name


@pytest.mark.parametrize("variant", ["naive_opt", "naive", "kahan", "kahan_scalar"])
def test_dot_variants_agree(variant):
    x, y = rnd((2048,), 1), rnd((2048,), 2)
    fn, _ = model.VARIANTS[variant]
    (got,) = fn(x, y)
    want = exact_dot(np.asarray(x), np.asarray(y))
    assert math.isclose(float(got), want, rel_tol=1e-4, abs_tol=1e-6)


def test_kahan_scalar_is_literal_fig2b():
    """The 'compiler' variant must match a literal Python transcription of
    Fig. 2b bit-for-bit (same order, same operations)."""
    x, y = rnd((513,), 3), rnd((513,), 4)
    (got,) = model.dot_kahan_scalar(x, y)
    s = np.float32(0.0)
    c = np.float32(0.0)
    xs, ys = np.asarray(x), np.asarray(y)
    for a, b in zip(xs, ys):
        prod = np.float32(a * b)
        yv = np.float32(prod - c)
        t = np.float32(s + yv)
        c = np.float32(np.float32(t - s) - yv)
        s = t
    # XLA CPU may contract mul+sub into an FMA inside the scan body, which
    # perturbs individual steps by <= 1 ulp; allow a few ulps of the
    # accumulated magnitude rather than demanding bit equality.
    tol = 4 * np.finfo(np.float32).eps * float(np.sum(np.abs(xs * ys)))
    assert abs(float(got) - float(s)) <= tol


def test_dot_pair_same_bits():
    x, y = rnd((4096,), 5), rnd((4096,), 6)
    naive, kahan = model.dot_pair(x, y)
    # Both outputs evaluate the same inputs; kahan must be at least as close
    # to exact on ill-conditioned data (checked elsewhere); here: both finite
    # and close on benign data.
    assert np.isfinite(float(naive)) and np.isfinite(float(kahan))
    scale = float(jnp.sum(jnp.abs(x * y))) + 1e-30
    assert abs(float(naive) - float(kahan)) <= 64 * np.finfo(np.float32).eps * scale


def test_batched_matches_rowwise():
    b, n = 8, 1024
    xs, ys = rnd((b, n), 7), rnd((b, n), 8)
    (got,) = model.dot_kahan_batched(xs, ys)
    assert got.shape == (b,)
    for i in range(b):
        (row,) = model.dot_kahan(xs[i], ys[i])
        assert float(got[i]) == float(row)


def test_batched_improves_on_ill_conditioned_rows():
    rows = []
    exacts = []
    for seed in range(4):
        x, y, e = ill_conditioned_dot(256, cond_exp=24, seed=seed)
        rows.append((x, y))
        exacts.append(e)
    xs = jnp.asarray(np.stack([r[0] for r in rows]))
    ys = jnp.asarray(np.stack([r[1] for r in rows]))
    (got,) = model.dot_kahan_batched(xs, ys)
    naive = jnp.sum(xs * ys, axis=1)
    kahan_worse = sum(
        1
        for i, e in enumerate(exacts)
        if abs(float(got[i]) - e) > abs(float(naive[i]) - e)
    )
    assert kahan_worse <= 1


def test_dot_kahan_state_shapes():
    x, y = rnd((4096,), 9), rnd((4096,), 10)
    out, s, c = model.dot_kahan_state(x, y)
    assert out.shape == ()
    assert s.shape == c.shape
    assert s.ndim == 1


@settings(max_examples=8)
@given(n=st.integers(2, 600), dt=st.sampled_from(["f32", "f64"]))
def test_variants_dtype_preserved(n, dt):
    dtype = jnp.float32 if dt == "f32" else jnp.float64
    x, y = rnd((n,), n, dtype), rnd((n,), n + 1, dtype)
    for variant in ("naive", "kahan"):
        fn, _ = model.VARIANTS[variant]
        (got,) = fn(x, y)
        assert got.dtype == dtype, variant
