//! Dot-product algorithm zoo: naive, Kahan (the paper's Fig. 2b), and dot2
//! (Ogita–Rump–Oishi compensated dot with exact products — doubled working
//! precision; included as the "stronger than Kahan" reference point the
//! related-work section cites [5]).

use super::eft::{two_prod, two_sum};

/// Naive dot product (the paper's Fig. 2a).
pub fn naive_dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Kahan-compensated dot product — a literal transcription of Fig. 2b:
///
/// ```c
/// for (i = 0; i < N; i++) {
///     double y = a[i] * b[i] - c;
///     double t = sum + y;
///     c = (t - sum) - y;
///     sum = t;
/// }
/// ```
pub fn kahan_dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut sum = 0.0;
    let mut c = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        let yv = a * b - c;
        let t = sum + yv;
        c = (t - sum) - yv;
        sum = t;
    }
    sum
}

/// Compensated fold of per-lane (sum, compensation) pairs — the `_finalize`
/// step of the Pallas kernel (kernels/kahan_dot.py), shared by
/// [`kahan_dot_lanes`] and every unrolled/SIMD Kahan kernel of the native
/// backend (`runtime::backend::native`), so the lane-combination semantics
/// cannot drift between the reference and the deployed implementations.
pub fn fold_kahan_lanes(s: &[f64], c: &[f64]) -> f64 {
    let mut acc = 0.0;
    let mut err = 0.0;
    for (sv, cv) in s.iter().zip(c) {
        let (a2, t) = two_sum(acc, *sv);
        acc = a2;
        err += t - cv;
    }
    acc + err
}

/// Lane-structured Kahan dot: `lanes` independent Fig. 2b recurrences plus a
/// compensated fold — the exact algorithm the Pallas kernel implements
/// (DESIGN.md §7), provided here so Rust-side tests can pin the kernel's
/// semantics without invoking PJRT.
pub fn kahan_dot_lanes(x: &[f64], y: &[f64], lanes: usize) -> f64 {
    assert_eq!(x.len(), y.len());
    assert!(lanes > 0);
    let mut s = vec![0.0; lanes];
    let mut c = vec![0.0; lanes];
    for (i, (&a, &b)) in x.iter().zip(y).enumerate() {
        let l = i % lanes;
        let yv = a * b - c[l];
        let t = s[l] + yv;
        c[l] = (t - s[l]) - yv;
        s[l] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Ogita–Rump–Oishi `Dot2`: compensated dot with exact products; result is
/// as if computed in twice the working precision.
pub fn dot2(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut p = 0.0;
    let mut s = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        let (h, r) = two_prod(a, b);
        let (q, t) = two_sum(p, h);
        p = q;
        s += t + r;
    }
    p + s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot;
    use crate::accuracy::generator::ill_conditioned_dot;
    use crate::ptest::property;
    use crate::util::rng::Rng;

    #[test]
    fn agree_on_benign_data() {
        let x: Vec<f64> = (0..64).map(|i| (i as f64) * 0.5).collect();
        let y: Vec<f64> = (0..64).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let want = exact_dot(&x, &y);
        for f in [naive_dot, kahan_dot, dot2] {
            let got = f(&x, &y);
            assert!((got - want).abs() <= 1e-12 * want.abs().max(1.0));
        }
    }

    #[test]
    fn error_ordering_on_ill_conditioned() {
        // dot2 <= kahan <= naive (statistically; per-seed asserted loosely).
        let mut rng = Rng::new(2016);
        let mut kahan_wins = 0;
        let mut dot2_wins = 0;
        let mut ratios = Vec::new();
        const TRIALS: usize = 20;
        for _ in 0..TRIALS {
            let (x, y, exact) = ill_conditioned_dot(400, 2f64.powi(40), &mut rng);
            let e_naive = (naive_dot(&x, &y) - exact).abs();
            let e_kahan = (kahan_dot(&x, &y) - exact).abs();
            let e_dot2 = (dot2(&x, &y) - exact).abs();
            if e_kahan <= e_naive {
                kahan_wins += 1;
            }
            if e_dot2 <= e_kahan {
                dot2_wins += 1;
            }
            ratios.push((e_naive + 1e-300) / (e_kahan + 1e-300));
        }
        // Per-case ties can happen; the *aggregate* advantage must be clear.
        assert!(kahan_wins >= TRIALS / 2 + 2, "kahan won only {kahan_wins}/{TRIALS}");
        assert!(dot2_wins >= TRIALS - 2, "dot2 won only {dot2_wins}/{TRIALS}");
        let g = crate::util::stats::geomean(&ratios);
        assert!(g >= 4.0, "naive/kahan error geomean ratio only {g}");
    }

    #[test]
    fn dot2_is_doubled_precision() {
        property("dot2 ~ exact", 50, |g| {
            let n = g.usize(10, 500);
            let x = g.vec_f64_log(n, -15, 15);
            let y = g.vec_f64_log(n, -15, 15);
            let want = exact_dot(&x, &y);
            let got = dot2(&x, &y);
            let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
            assert!(
                (got - want).abs() <= 4.0 * f64::EPSILON * cond.max(want.abs()),
                "err {} vs cond {}",
                (got - want).abs(),
                cond
            );
        });
    }

    #[test]
    fn lanes_matches_scalar_for_one_lane() {
        property("kahan_dot_lanes(1) == kahan_dot", 50, |g| {
            let n = g.usize(1, 300);
            let x = g.vec_f64_log(n, -10, 10);
            let y = g.vec_f64_log(n, -10, 10);
            assert_eq!(kahan_dot_lanes(&x, &y, 1), kahan_dot(&x, &y));
        });
    }

    #[test]
    fn lanes_accuracy_comparable() {
        property("lane Kahan within Kahan-class error", 40, |g| {
            let n = g.usize(16, 600);
            let lanes = *g.choose(&[2usize, 4, 8, 16, 128]);
            let x = g.vec_f64_log(n, -20, 20);
            let y = g.vec_f64_log(n, -20, 20);
            let want = exact_dot(&x, &y);
            let got = kahan_dot_lanes(&x, &y, lanes);
            let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
            assert!((got - want).abs() <= 16.0 * f64::EPSILON * cond);
        });
    }

    #[test]
    fn kahan_matches_fig2b_stepwise() {
        // Fine-grained pin: run 4 steps by hand and demand bit equality.
        let x = [1e16, 1.0, -1e16, 1.0];
        let y = [1.0, 1.0, 1.0, 1.0];
        let mut sum = 0.0;
        let mut c = 0.0;
        for i in 0..4 {
            let yv = x[i] * y[i] - c;
            let t = sum + yv;
            c = (t - sum) - yv;
            sum = t;
        }
        assert_eq!(kahan_dot(&x, &y), sum);
        // Note: plain Kahan *loses* the +1 here (c = -1 is absorbed into the
        // rounded -1e16 + 1 step) — the documented weakness Neumaier fixes.
        assert_eq!(sum, 1.0);
        assert_eq!(crate::accuracy::sums::neumaier_sum(&[1e16, 1.0, -1e16, 1.0]), 2.0);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        naive_dot(&[1.0], &[1.0, 2.0]);
    }
}
