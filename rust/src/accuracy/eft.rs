//! Error-free transformations (EFTs): the exact building blocks of
//! compensated algorithms.
//!
//! * `two_sum(a, b)`  -> (s, e) with s = fl(a+b) and s + e = a + b exactly
//!   (Knuth / Møller; 6 flops, no branch).
//! * `fast_two_sum(a, b)` -> same, 3 flops, requires |a| >= |b| (Dekker).
//! * `two_prod(a, b)` -> (p, e) with p = fl(a*b) and p + e = a * b exactly
//!   (via FMA: e = fma(a, b, -p)).

/// Knuth's branch-free exact addition.
#[inline]
pub fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let ap = s - b;
    let bp = s - ap;
    let da = a - ap;
    let db = b - bp;
    (s, da + db)
}

/// Dekker's exact addition; caller guarantees |a| >= |b|.
#[inline]
pub fn fast_two_sum(a: f64, b: f64) -> (f64, f64) {
    debug_assert!(a == 0.0 || b == 0.0 || a.abs() >= b.abs() || a.is_nan() || b.is_nan());
    let s = a + b;
    let e = b - (s - a);
    (s, e)
}

/// Exact multiplication via FMA.
#[inline]
pub fn two_prod(a: f64, b: f64) -> (f64, f64) {
    let p = a * b;
    let e = a.mul_add(b, -p);
    (p, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::property;

    /// Check s + e == a + b exactly by comparing in extended precision via
    /// an independent route: the identity holds iff (s - a - b) + e == 0 in
    /// exact arithmetic; we verify with two_sum itself on shuffled operands
    /// plus a high-precision split check using integer-representable parts.
    fn assert_eft_sum(a: f64, b: f64) {
        let (s, e) = two_sum(a, b);
        assert_eq!(s, a + b, "s must be the rounded sum");
        // Exactness check via the algebraic identity in f64: the residual of
        // (a + b) - s is representable, and two_sum of (e, s) must rebuild
        // identical parts.
        let (s2, e2) = two_sum(b, a);
        assert_eq!(s, s2, "commutativity of the rounded sum");
        assert_eq!(e, e2, "commutativity of the residual");
        // The residual must be no larger than half an ulp of s.
        if s.is_finite() && s != 0.0 {
            let ulp = s.abs() * f64::EPSILON;
            assert!(e.abs() <= ulp, "|e| = {e} exceeds ulp bound {ulp} (s={s})");
        }
    }

    #[test]
    fn two_sum_known_cases() {
        // 1 + 2^-60: the residual is exactly 2^-60.
        let tiny = 2f64.powi(-60);
        let (s, e) = two_sum(1.0, tiny);
        assert_eq!(s, 1.0);
        assert_eq!(e, tiny);
        // Residual captures what rounding discarded: 1e16 + 1 rounds to
        // 1e16 (ulp at 1e16 is 2), and e recovers the lost 1.0 exactly.
        let (s, e) = two_sum(1e16, 1.0);
        assert_eq!(s, 1e16);
        assert_eq!(e, 1.0);
        // Exact cancellation at the 2^53 integer boundary.
        let a = 9007199254740992.0; // 2^53
        let b = -9007199254740991.0; // -(2^53 - 1), representable
        let (s, e) = two_sum(a, b);
        assert_eq!(s, 1.0);
        assert_eq!(e, 0.0);
    }

    #[test]
    fn two_sum_properties() {
        property("two_sum exactness", 500, |g| {
            let a = g.f64_log(-300, 300);
            let b = g.f64_log(-300, 300);
            assert_eft_sum(a, b);
        });
    }

    #[test]
    fn fast_two_sum_matches_two_sum_when_ordered() {
        property("fast_two_sum == two_sum (ordered)", 500, |g| {
            let mut a = g.f64_log(-100, 100);
            let mut b = g.f64_log(-100, 100);
            if a.abs() < b.abs() {
                std::mem::swap(&mut a, &mut b);
            }
            let (s1, e1) = two_sum(a, b);
            let (s2, e2) = fast_two_sum(a, b);
            assert_eq!(s1, s2);
            assert_eq!(e1, e2);
        });
    }

    #[test]
    fn two_prod_exactness() {
        property("two_prod exactness", 500, |g| {
            let a = g.f64_log(-150, 150);
            let b = g.f64_log(-150, 150);
            let (p, e) = two_prod(a, b);
            assert_eq!(p, a * b);
            // Verify p + e == a*b by recomputing the residual with integer
            // splitting (Dekker's split is exact for these ranges).
            let e2 = a.mul_add(b, -p);
            assert_eq!(e, e2);
            if p.is_finite() && p != 0.0 {
                assert!(e.abs() <= p.abs() * f64::EPSILON);
            }
        });
    }

    #[test]
    fn two_prod_known_case() {
        // (1 + 2^-30)^2 = 1 + 2^-29 + 2^-60; the 2^-60 term is the residual.
        let x = 1.0 + 2f64.powi(-30);
        let (p, e) = two_prod(x, x);
        assert_eq!(p, 1.0 + 2f64.powi(-29));
        assert_eq!(e, 2f64.powi(-60));
    }
}
