//! Exact floating-point accumulation via Shewchuk-style expansions.
//!
//! An *expansion* is a sum of non-overlapping f64 components; adding a value
//! with `grow_expansion` (a chain of two_sums) keeps the representation
//! exact. This provides the arbitrary-precision ground truth the paper's
//! accuracy discussion presumes, without an external bignum dependency —
//! every f64 (and every product of two f32s, which is exact in f64) can be
//! accumulated with zero error.

use super::eft::{two_prod, two_sum};

/// Exact accumulator: maintains the running sum as an expansion.
#[derive(Clone, Debug, Default)]
pub struct ExactAcc {
    /// Non-overlapping components, increasing magnitude order.
    comps: Vec<f64>,
}

impl ExactAcc {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one f64 exactly (Shewchuk's GROW-EXPANSION).
    pub fn add(&mut self, x: f64) {
        let mut q = x;
        let mut out = Vec::with_capacity(self.comps.len() + 1);
        for &c in &self.comps {
            let (s, e) = two_sum(q, c);
            if e != 0.0 {
                out.push(e);
            }
            q = s;
        }
        if q != 0.0 || out.is_empty() {
            out.push(q);
        }
        self.comps = out;
    }

    /// Add the exact product a * b (both f64) via two_prod.
    pub fn add_prod(&mut self, a: f64, b: f64) {
        let (p, e) = two_prod(a, b);
        self.add(e);
        self.add(p);
    }

    /// The correctly rounded value of the exact sum.
    pub fn value(&self) -> f64 {
        // Components are non-overlapping; summing from smallest to largest
        // magnitude yields the correctly rounded result for non-pathological
        // expansions; we do a final compensated pass for safety.
        let mut s = 0.0;
        let mut c = 0.0;
        for &x in &self.comps {
            let (t, e) = two_sum(s, x);
            s = t;
            c += e;
        }
        s + c
    }

    /// Number of expansion components (diagnostic).
    pub fn len(&self) -> usize {
        self.comps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.comps.iter().all(|&c| c == 0.0)
    }
}

/// Exact dot product of f64 slices (every product tracked exactly).
pub fn exact_dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = ExactAcc::new();
    for (&a, &b) in x.iter().zip(y) {
        acc.add_prod(a, b);
    }
    acc.value()
}

/// Exact dot product of f32 data: f32*f32 is exact in f64, so promoting and
/// exact-summing gives the true value.
pub fn exact_dot_f32(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = ExactAcc::new();
    for (&a, &b) in x.iter().zip(y) {
        acc.add((a as f64) * (b as f64));
    }
    acc.value()
}

/// Exact sum of f64 values.
pub fn exact_sum(x: &[f64]) -> f64 {
    let mut acc = ExactAcc::new();
    for &v in x {
        acc.add(v);
    }
    acc.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::property;

    #[test]
    fn simple_sums() {
        let mut a = ExactAcc::new();
        for _ in 0..10 {
            a.add(0.1);
        }
        // 10 * 0.1 != 1.0 in naive f64; the exact accumulator still rounds
        // the *true* sum of ten f64(0.1) values, which is NOT 1.0 exactly.
        let direct: f64 = (0..10).fold(0.0, |s, _| s + 0.1);
        assert_ne!(direct, 1.0);
        // The exact value: 10 * (0.1 + eps_repr). Compare against fsum-like
        // reference computed with integer arithmetic on the bit pattern:
        let v = a.value();
        assert!((v - 1.0).abs() < 1e-15);
        assert!(v != direct || v == direct); // value is well-defined
    }

    #[test]
    fn cancellation_exact() {
        let mut a = ExactAcc::new();
        a.add(1e300);
        a.add(1.0);
        a.add(-1e300);
        assert_eq!(a.value(), 1.0);
    }

    #[test]
    fn many_scales_exact() {
        // Sum 2^-1022 .. 2^60 in shuffled order; exact result is computable
        // as a geometric series in exact arithmetic; we verify the
        // accumulator is order-independent instead (a strictly stronger
        // check than any tolerance).
        let mut xs: Vec<f64> = (-500..=60).map(|e| 2f64.powi(e)).collect();
        let mut fwd = ExactAcc::new();
        for &x in &xs {
            fwd.add(x);
        }
        xs.reverse();
        let mut rev = ExactAcc::new();
        for &x in &xs {
            rev.add(x);
        }
        assert_eq!(fwd.value(), rev.value());
    }

    #[test]
    fn order_independence_property() {
        property("ExactAcc is order independent", 100, |g| {
            let n = g.usize(2, 60);
            let xs = g.vec_f64_log(n, -60, 60);
            let mut fwd = ExactAcc::new();
            let mut rev = ExactAcc::new();
            for &x in &xs {
                fwd.add(x);
            }
            for &x in xs.iter().rev() {
                rev.add(x);
            }
            assert_eq!(fwd.value(), rev.value(), "xs = {xs:?}");
        });
    }

    #[test]
    fn add_prod_matches_promoted_f32() {
        property("exact_dot_f32 == exact_dot of promoted", 50, |g| {
            let n = g.usize(1, 40);
            let x: Vec<f32> = (0..n).map(|_| g.f64_range(-1e6, 1e6) as f32).collect();
            let y: Vec<f32> = (0..n).map(|_| g.f64_range(-1e6, 1e6) as f32).collect();
            let xp: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let yp: Vec<f64> = y.iter().map(|&v| v as f64).collect();
            assert_eq!(exact_dot_f32(&x, &y), exact_dot(&xp, &yp));
        });
    }

    #[test]
    fn value_of_empty_is_zero() {
        assert_eq!(ExactAcc::new().value(), 0.0);
        assert_eq!(exact_sum(&[]), 0.0);
    }

    #[test]
    fn expansion_stays_compact_for_similar_magnitudes() {
        let mut a = ExactAcc::new();
        for i in 0..10_000 {
            a.add(1.0 + (i as f64) * 1e-10);
        }
        // Non-overlapping invariant keeps the expansion short.
        assert!(a.len() <= 64, "expansion blew up: {} comps", a.len());
    }
}
