//! Ill-conditioned dot-product generator (Ogita–Rump–Oishi Algorithm 6.1
//! in structure): produces (x, y, exact) where the condition number
//! `cond = 2 Σ|x_i y_i| / |Σ x_i y_i|` is approximately a requested target,
//! so accuracy studies can sweep difficulty.

use super::exact::ExactAcc;
use crate::util::rng::Rng;

/// Generate an ill-conditioned dot product of length `n` (n >= 4, even)
/// with condition number ~ `cond`. Returns (x, y, exact_value).
pub fn ill_conditioned_dot(n: usize, cond: f64, rng: &mut Rng) -> (Vec<f64>, Vec<f64>, f64) {
    assert!(n >= 4, "need n >= 4");
    assert!(cond >= 1.0);
    let half = n / 2;
    let b = cond.log2() / 2.0; // exponent half-range
    let mut x = vec![0.0; n];
    let mut y = vec![0.0; n];

    // First half: exponents spread over [0, b]; extremes anchored.
    for i in 0..half {
        let e = if i == 0 {
            b
        } else if i == half - 1 {
            0.0
        } else {
            rng.range_f64(0.0, b)
        };
        x[i] = (2.0 * rng.f64() - 1.0) * 2f64.powf(e);
        y[i] = (2.0 * rng.f64() - 1.0) * 2f64.powf(e);
    }

    // Second half (ORO Algorithm 6.1 structure): choose y_i so the running
    // sum is *steered to* a fresh random value of magnitude 2^e, with e
    // ramping back down to 0. This cancels the large first-half terms while
    // pinning the final sum near magnitude 1 — which is what controls the
    // condition number (cond ~ Σ|x·y| / |Σ x·y| ~ 2^b · n / 1).
    let mut acc = ExactAcc::new();
    for i in 0..half {
        acc.add_prod(x[i], y[i]);
    }
    for i in 0..(n - half) {
        let e = b * (1.0 - i as f64 / (n - half - 1).max(1) as f64);
        let mut xv = (2.0 * rng.f64() - 1.0) * 2f64.powf(e);
        if xv == 0.0 {
            xv = 1.0;
        }
        let target = (2.0 * rng.f64() - 1.0) * 2f64.powf(e);
        let s = acc.value();
        let yv = (target - s) / xv;
        x[half + i] = xv;
        y[half + i] = yv;
        acc.add_prod(xv, yv);
    }
    let exact = acc.value();
    (x, y, exact)
}

/// Measured condition number of a dot product: 2 Σ|x_i y_i| / |Σ x_i y_i|.
pub fn condition_number(x: &[f64], y: &[f64], exact: f64) -> f64 {
    let abs_sum: f64 = x.iter().zip(y).map(|(a, b)| (a * b).abs()).sum();
    if exact == 0.0 {
        f64::INFINITY
    } else {
        2.0 * abs_sum / exact.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::dots::{kahan_dot, naive_dot};
    use crate::accuracy::exact::exact_dot;
    use crate::ptest::property;

    #[test]
    fn exact_value_is_exact() {
        let mut rng = Rng::new(1);
        let (x, y, exact) = ill_conditioned_dot(64, 2f64.powi(30), &mut rng);
        assert_eq!(exact, exact_dot(&x, &y));
    }

    #[test]
    fn condition_scales_with_request() {
        let mut rng = Rng::new(7);
        let mut last = 0.0;
        for &ce in &[10.0, 30.0, 60.0] {
            let (x, y, exact) = ill_conditioned_dot(256, 2f64.powf(ce), &mut rng);
            let c = condition_number(&x, &y, exact);
            // Within a few orders of magnitude of target, and increasing.
            assert!(c > last, "cond {c} not increasing (prev {last})");
            assert!(
                c.log2() > ce * 0.4 && c.log2() < ce * 2.5 + 16.0,
                "cond 2^{} for target 2^{}",
                c.log2(),
                ce
            );
            last = c;
        }
    }

    #[test]
    fn naive_degrades_kahan_survives() {
        // At cond ~ 2^40, naive f64 keeps ~eps*cond ~ 2^-12 relative error;
        // kahan stays near eps.
        let mut rng = Rng::new(99);
        let mut kahan_better = 0;
        for _ in 0..10 {
            let (x, y, exact) = ill_conditioned_dot(512, 2f64.powi(44), &mut rng);
            if exact == 0.0 {
                continue;
            }
            let rel = |v: f64| ((v - exact) / exact).abs();
            if rel(kahan_dot(&x, &y)) <= rel(naive_dot(&x, &y)) {
                kahan_better += 1;
            }
        }
        assert!(kahan_better >= 8, "{kahan_better}/10");
    }

    #[test]
    fn generator_properties() {
        property("generator invariants", 30, |g| {
            let n = g.usize(2, 100) * 2 + 2; // even, >= 6
            let cond = 2f64.powf(g.f64_range(4.0, 50.0));
            let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
            let (x, y, exact) = ill_conditioned_dot(n, cond, &mut rng);
            assert_eq!(x.len(), n);
            assert_eq!(y.len(), n);
            assert!(exact.is_finite());
            assert!(x.iter().all(|v| v.is_finite()));
            assert!(y.iter().all(|v| v.is_finite()));
        });
    }
}
