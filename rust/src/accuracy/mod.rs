//! Floating-point accuracy substrate: error-free transformations, the
//! summation/dot algorithm zoo, an exact (expansion-based) accumulator, and
//! the ill-conditioned input generator.
//!
//! This module backs the paper's *motivation* (Sect. 1: naive summation
//! loses accuracy; Kahan compensates at some cost) with measurable numbers,
//! and provides the ground truth the PJRT-executed kernels are validated
//! against in the accuracy study (`kahan-ecm run acc`).

pub mod dots;
pub mod eft;
pub mod exact;
pub mod generator;
pub mod sums;

pub use dots::{dot2, kahan_dot, naive_dot};
pub use eft::{fast_two_sum, two_prod, two_sum};
pub use exact::ExactAcc;
pub use generator::ill_conditioned_dot;
pub use sums::{kahan_sum, naive_sum, neumaier_sum, pairwise_sum};
