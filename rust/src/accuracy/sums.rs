//! The summation algorithm zoo: naive, Kahan (Fig. 2b's recurrence),
//! Neumaier's improvement, and pairwise summation — the accuracy/throughput
//! spectrum the paper's introduction surveys [2, 3, 4, 8].

/// Naive left-to-right summation: error grows O(n · eps · Σ|x|).
pub fn naive_sum(x: &[f64]) -> f64 {
    x.iter().sum()
}

/// Kahan's compensated summation (Kahan 1965, the paper's Fig. 2b without
/// the product): error O(eps · Σ|x|), independent of n.
pub fn kahan_sum(x: &[f64]) -> f64 {
    let mut s = 0.0;
    let mut c = 0.0;
    for &v in x {
        let y = v - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
    }
    s
}

/// Neumaier's variant: also catches the case |v| > |s| that plain Kahan
/// mishandles (e.g. [1, 1e100, 1, -1e100]).
pub fn neumaier_sum(x: &[f64]) -> f64 {
    let mut s = 0.0;
    let mut c = 0.0;
    for &v in x {
        let t = s + v;
        if s.abs() >= v.abs() {
            c += (s - t) + v;
        } else {
            c += (v - t) + s;
        }
        s = t;
    }
    s + c
}

/// Pairwise (cascade) summation: error O(log n · eps · Σ|x|); what
/// high-level `sum()` implementations (incl. XLA reductions) approximate.
pub fn pairwise_sum(x: &[f64]) -> f64 {
    const BASE: usize = 32;
    fn rec(x: &[f64]) -> f64 {
        if x.len() <= BASE {
            x.iter().sum()
        } else {
            let mid = x.len() / 2;
            rec(&x[..mid]) + rec(&x[mid..])
        }
    }
    rec(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_sum;
    use crate::ptest::property;

    #[test]
    fn all_agree_on_benign_data() {
        let x: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let want = 5050.0;
        assert_eq!(naive_sum(&x), want);
        assert_eq!(kahan_sum(&x), want);
        assert_eq!(neumaier_sum(&x), want);
        assert_eq!(pairwise_sum(&x), want);
    }

    #[test]
    fn kahan_classic_demo() {
        // 1e8 + 10_000 * 0.1 - 1e8 in f64 is benign; use the f32-style
        // stress in f64: 1.0 + n*eps-scale values.
        let mut x = vec![1e16];
        x.extend(std::iter::repeat(1.0).take(10_000));
        x.push(-1e16);
        let want = exact_sum(&x);
        let e_naive = (naive_sum(&x) - want).abs();
        let e_kahan = (kahan_sum(&x) - want).abs();
        assert!(e_kahan <= e_naive);
        assert_eq!(kahan_sum(&x), 10_000.0);
    }

    #[test]
    fn neumaier_beats_kahan_on_swapped_magnitudes() {
        let x = [1.0, 1e100, 1.0, -1e100];
        assert_eq!(neumaier_sum(&x), 2.0);
        // Plain Kahan loses it entirely (documented limitation).
        assert_eq!(kahan_sum(&x), 0.0);
    }

    #[test]
    fn error_bounds_property() {
        property("kahan within bound, naive within bound", 100, |g| {
            let n = g.usize(10, 2000);
            let x = g.vec_f64_log(n, -20, 20);
            let want = exact_sum(&x);
            let abs_sum: f64 = x.iter().map(|v| v.abs()).sum();
            let e_naive = (naive_sum(&x) - want).abs();
            let e_kahan = (kahan_sum(&x) - want).abs();
            let e_pair = (pairwise_sum(&x) - want).abs();
            let eps = f64::EPSILON;
            assert!(
                e_kahan <= 4.0 * eps * abs_sum,
                "kahan err {e_kahan} vs bound {}",
                4.0 * eps * abs_sum
            );
            assert!(e_naive <= 2.0 * n as f64 * eps * abs_sum);
            let logn = (n as f64).log2().ceil() + 8.0;
            assert!(e_pair <= 2.0 * logn * eps * abs_sum);
        });
    }

    #[test]
    fn kahan_never_worse_than_naive_statistically() {
        property("kahan <= naive error (usually)", 60, |g| {
            let n = g.usize(100, 1500);
            let x = g.vec_f64_log(n, -30, 30);
            let want = exact_sum(&x);
            let e_naive = (naive_sum(&x) - want).abs();
            let e_kahan = (kahan_sum(&x) - want).abs();
            // Not a per-case theorem (ties happen), but Kahan must never be
            // *significantly* worse.
            let abs_sum: f64 = x.iter().map(|v| v.abs()).sum();
            assert!(e_kahan <= e_naive.max(4.0 * f64::EPSILON * abs_sum));
        });
    }

    #[test]
    fn empty_and_single() {
        for f in [naive_sum, kahan_sum, neumaier_sum, pairwise_sum] {
            assert_eq!(f(&[]), 0.0);
            assert_eq!(f(&[42.5]), 42.5);
        }
    }
}
