//! Load a user-defined `Machine` from the `key = value` config format —
//! the Sect. 6 "blueprint" extension: point the ECM engine and simulator at
//! a machine we never encoded (see `examples/custom_arch.rs` and
//! `configs/example_machine.toml`).

use crate::arch::machine::*;
use crate::isa::OpClass;
use crate::util::config::{Config, ConfigError};

#[derive(Debug)]
pub enum LoadError {
    Config(ConfigError),
    BadCap(String),
    BadOverlap(String),
    Invalid(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Config(e) => write!(f, "{e}"),
            LoadError::BadCap(cap) => write!(
                f,
                "bad port capability '{cap}' (expected load/store/add/mul/fma/mov/prefetch/scalar)"
            ),
            LoadError::BadOverlap(p) => {
                write!(f, "bad overlap policy '{p}' (expected intel/full/knc)")
            }
            LoadError::Invalid(msg) => write!(f, "machine failed validation: {msg}"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Config(e) => std::error::Error::source(e),
            _ => None,
        }
    }
}

impl From<ConfigError> for LoadError {
    fn from(e: ConfigError) -> Self {
        LoadError::Config(e)
    }
}

fn parse_caps(items: &[String]) -> Result<Vec<OpClass>, LoadError> {
    items
        .iter()
        .map(|s| match s.to_lowercase().as_str() {
            "load" => Ok(OpClass::Load),
            "store" => Ok(OpClass::Store),
            "add" => Ok(OpClass::Add),
            "mul" => Ok(OpClass::Mul),
            "fma" => Ok(OpClass::Fma),
            "mov" => Ok(OpClass::Mov),
            "prefetch" | "prefetch1" => Ok(OpClass::Prefetch(1)),
            "prefetch2" => Ok(OpClass::Prefetch(2)),
            "scalar" => Ok(OpClass::Scalar),
            other => Err(LoadError::BadCap(other.to_string())),
        })
        .collect()
}

/// Parse a machine description. See `configs/example_machine.toml` for the
/// schema; sections: `[machine]`, `[port.*]`, `[cache.*]` (sorted by name,
/// so use l1/l2/l3 naming), `[memory]`, optional `[calibration]`.
pub fn machine_from_config(text: &str) -> Result<Machine, LoadError> {
    let cfg = Config::parse(text)?;

    let mut ports = Vec::new();
    for (name, _) in cfg.sections_with_prefix("port") {
        let caps = parse_caps(&cfg.get_list(name, "caps")?)?;
        // Machine uses &'static str labels; a one-shot leak for a
        // user-loaded config is fine (CLI lifetime == process lifetime).
        let label: &'static str = Box::leak(
            name.trim_start_matches("port.").to_string().into_boxed_str(),
        );
        ports.push(Port { name: label, caps });
    }

    let mut caches = Vec::new();
    for (name, _) in cfg.sections_with_prefix("cache") {
        let label: &'static str = Box::leak(
            name.trim_start_matches("cache.").to_uppercase().into_boxed_str(),
        );
        caches.push(CacheLevel {
            name: label,
            capacity: cfg.get(name, "capacity")?,
            bw_bytes_per_cy: cfg.get_or(name, "bw_bytes_per_cy", 0.0)?,
            latency_penalty: cfg.get_or(name, "latency_penalty", 0.0)?,
            shared: cfg.get_or(name, "shared", false)?,
        });
    }

    let overlap = match cfg
        .get_or::<String>("machine", "overlap", "intel".into())?
        .to_lowercase()
        .as_str()
    {
        "intel" => OverlapPolicy::IntelNonOverlapping,
        "full" => OverlapPolicy::FullOverlap,
        "knc" => OverlapPolicy::KncPaired,
        other => return Err(LoadError::BadOverlap(other.to_string())),
    };

    let m = Machine {
        name: Box::leak(cfg.get::<String>("machine", "name")?.into_boxed_str()),
        shorthand: Box::leak(
            cfg.get_or::<String>("machine", "shorthand", "CUSTOM".into())?
                .into_boxed_str(),
        ),
        freq_ghz: cfg.get("machine", "freq_ghz")?,
        cores: cfg.get("machine", "cores")?,
        smt_ways: cfg.get_or("machine", "smt_ways", 1)?,
        cacheline: cfg.get_or("machine", "cacheline", 64)?,
        simd_bytes: cfg.get("machine", "simd_bytes")?,
        simd_regs: cfg.get_or("machine", "simd_regs", 16)?,
        issue_width: cfg.get_or("machine", "issue_width", 4)?,
        in_order: cfg.get_or("machine", "in_order", false)?,
        ports,
        lat: InstrLatency {
            load: cfg.get_or("latency", "load", 4)?,
            add: cfg.get_or("latency", "add", 3)?,
            mul: cfg.get_or("latency", "mul", 5)?,
            fma: cfg.get_or("latency", "fma", 5)?,
        },
        caches,
        mem: MemorySystem {
            sustained_bw_gbs: cfg.get("memory", "sustained_bw_gbs")?,
            domains: cfg.get_or("memory", "domains", 1)?,
            latency_penalty: cfg.get_or("memory", "latency_penalty", 0.0)?,
        },
        overlap,
        victim_llc: cfg.get_or("machine", "victim_llc", false)?,
        calib: Calibration {
            l2_friction_cy_per_cl: cfg.get_or("calibration", "l2_friction_cy_per_cl", 0.0)?,
            mem_friction_cy_per_cl: cfg.get_or("calibration", "mem_friction_cy_per_cl", 0.0)?,
            core_efficiency: cfg.get_or("calibration", "core_efficiency", 1.0)?,
            effective_llc_capacity: match cfg
                .get_or("calibration", "effective_llc_capacity", 0u64)?
            {
                0 => None,
                v => Some(v),
            },
            erratic_window: None,
            noise_rel: cfg.get_or("calibration", "noise_rel", 0.0)?,
        },
    };
    m.validate().map_err(LoadError::Invalid)?;
    Ok(m)
}

pub const EXAMPLE_CONFIG: &str = r#"# Example user-defined machine for kahan-ecm (schema reference).
[machine]
name = Example Zen-like core
shorthand = ZEN
freq_ghz = 3.5
cores = 8
smt_ways = 2
cacheline = 64
simd_bytes = 32
simd_regs = 16
issue_width = 6
overlap = intel

[latency]
load = 4
add = 3
mul = 3
fma = 5

[port.p0]
caps = fma, mul
[port.p1]
caps = fma, mul, add
[port.p2]
caps = add
[port.p3]
caps = load
[port.p4]
caps = load
[port.p5]
caps = store

[cache.l1]
capacity = 32768
[cache.l2]
capacity = 524288
bw_bytes_per_cy = 64
[cache.l3]
capacity = 33554432
bw_bytes_per_cy = 32
latency_penalty = 2
shared = true

[memory]
sustained_bw_gbs = 40
domains = 1
latency_penalty = 2
"#;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_config_loads() {
        let m = machine_from_config(EXAMPLE_CONFIG).unwrap();
        assert_eq!(m.shorthand, "ZEN");
        assert_eq!(m.cores, 8);
        assert_eq!(m.ports.len(), 6);
        assert_eq!(m.caches.len(), 3);
        // Two ADD-capable ports on this machine.
        assert_eq!(m.throughput(&OpClass::Add), 2.0);
        assert_eq!(m.caches[2].latency_penalty, 2.0);
    }

    #[test]
    fn missing_required_key_rejected() {
        let bad = EXAMPLE_CONFIG.replace("freq_ghz = 3.5", "");
        assert!(machine_from_config(&bad).is_err());
    }

    #[test]
    fn bad_cap_rejected() {
        let bad = EXAMPLE_CONFIG.replace("caps = fma, mul", "caps = warp");
        assert!(matches!(machine_from_config(&bad), Err(LoadError::BadCap(_))));
    }

    #[test]
    fn bad_overlap_rejected() {
        let bad = EXAMPLE_CONFIG.replace("overlap = intel", "overlap = gpu");
        assert!(matches!(
            machine_from_config(&bad),
            Err(LoadError::BadOverlap(_))
        ));
    }

    #[test]
    fn validation_runs() {
        // Remove all load ports -> validate() must fail.
        let bad = EXAMPLE_CONFIG
            .replace("caps = load", "caps = mov");
        assert!(matches!(machine_from_config(&bad), Err(LoadError::Invalid(_))));
    }
}
