//! The `Machine` description consumed by the ECM engine and the simulator.
//!
//! Everything here is either a vendor-documented quantity (port counts,
//! cache bandwidths, latencies — Table I of the paper) or an empirically
//! calibrated one (sustained memory bandwidth, latency penalties T_p,
//! measured frictions), mirroring exactly which inputs the paper treats as
//! specs vs. measurements (Sect. 2).

use crate::isa::OpClass;

/// One execution port and the instruction classes it can execute.
#[derive(Clone, Debug)]
pub struct Port {
    pub name: &'static str,
    pub caps: Vec<OpClass>,
}

impl Port {
    pub fn can(&self, op: &OpClass) -> bool {
        // Prefetches are modeled as consuming an issue slot, not a port;
        // Movs are handled by renaming on OoO machines (see scheduler).
        self.caps.iter().any(|c| c == op)
    }
}

/// Instruction latencies in cycles (vendor optimization manuals).
#[derive(Clone, Copy, Debug)]
pub struct InstrLatency {
    pub load: u32,
    pub add: u32,
    pub mul: u32,
    pub fma: u32,
}

impl InstrLatency {
    pub fn of(&self, op: &OpClass) -> u32 {
        match op {
            OpClass::Load => self.load,
            OpClass::Add => self.add,
            OpClass::Mul => self.mul,
            OpClass::Fma => self.fma,
            OpClass::Mov => 0,
            _ => 1,
        }
    }
}

/// One cache level. Bandwidth is toward the core (refill bandwidth of the
/// next-closer level); `latency_penalty` is the ECM T_p applied when a
/// transfer crosses this level's interconnect (Sect. 2: Uncore levels on
/// Intel, the ring on KNC; zero on POWER8).
#[derive(Clone, Debug)]
pub struct CacheLevel {
    pub name: &'static str,
    pub capacity: u64,
    /// Bytes per cycle this level can deliver to the next-closer level.
    pub bw_bytes_per_cy: f64,
    /// ECM latency penalty T_p in cycles for transfers sourced here.
    pub latency_penalty: f64,
    /// Shared among all cores (affects multicore scaling of cache-resident
    /// working sets; only memory is a bottleneck for the dot kernels).
    pub shared: bool,
}

/// Main memory as seen by one chip.
#[derive(Clone, Debug)]
pub struct MemorySystem {
    /// Measured sustained load-only bandwidth per memory domain, GB/s
    /// (Table I "Meas. load BW"; per CoD domain on HSW/BDW).
    pub sustained_bw_gbs: f64,
    /// ccNUMA memory domains per chip (2 under cluster-on-die, else 1).
    pub domains: u32,
    /// ECM latency penalty T_p for memory transfers, cycles.
    pub latency_penalty: f64,
}

/// How in-core cycles and data-transfer cycles combine (Sect. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverlapPolicy {
    /// Intel Xeon: cycles with L1<->register traffic (T_nOL) overlap with
    /// nothing; all other in-core cycles (T_OL) overlap with all transfers.
    /// T_ECM = max(T_OL, T_nOL + sum(T_data)).
    IntelNonOverlapping,
    /// IBM POWER8: the multi-ported L1 makes all in-core work overlapping;
    /// T_nOL = 0 and T_ECM = max(T_OL, sum(T_data)).
    FullOverlap,
    /// KNC: in-order dual-issue; loads/prefetches pair onto the V-pipe but
    /// still contribute non-overlapping cycles like Intel Xeon.
    KncPaired,
}

/// Empirical calibration: measured-vs-model frictions the paper reports but
/// cannot derive (Sect. 5). These feed ONLY the simulator ("measurements"),
/// never the ECM predictions — keeping model-vs-measurement honest.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    /// Extra cy/CL on L2-resident streams (HSW/BDW hardware-prefetcher
    /// shortfall: "naive ... falls short of the L2 model prediction").
    pub l2_friction_cy_per_cl: f64,
    /// Extra cy/CL on memory-resident streams (the unexplained HSW
    /// AVX/FMA-Kahan in-memory anomaly of Sect. 5.1).
    pub mem_friction_cy_per_cl: f64,
    /// Fraction of nominal instruction throughput actually achieved
    /// (PWR8 misses "by 20-30%" -> 0.75; Intel/KNC 1.0).
    pub core_efficiency: f64,
    /// Effective last-level-cache capacity if worse than nominal (PWR8's
    /// 8 MB L3 "only effective up to 2 MB").
    pub effective_llc_capacity: Option<u64>,
    /// Erratic-performance window (lo, hi, relative amplitude): PWR8's
    /// fluctuating 2 MB .. 64 MB region (Sect. 5.3).
    pub erratic_window: Option<(u64, u64, f64)>,
    /// Relative measurement jitter applied to all simulated points.
    pub noise_rel: f64,
}

/// A complete machine model.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: &'static str,
    pub shorthand: &'static str,
    pub freq_ghz: f64,
    pub cores: u32,
    pub smt_ways: u32,
    pub cacheline: u64,
    pub simd_bytes: u64,
    pub simd_regs: u32,
    /// Instructions issued/retired per cycle (4 µops Intel, 8 PWR8, 2 KNC).
    pub issue_width: u32,
    pub in_order: bool,
    pub ports: Vec<Port>,
    pub lat: InstrLatency,
    /// Cache levels, closest (L1) first.
    pub caches: Vec<CacheLevel>,
    pub mem: MemorySystem,
    pub overlap: OverlapPolicy,
    /// POWER8-style victim LLC: memory refills go directly to L2; the LLC
    /// holds L2 evictions (changes the data path, Sect. 3).
    pub victim_llc: bool,
    pub calib: Calibration,
}

impl Machine {
    /// SIMD lanes per vector instruction at a given element size.
    pub fn simd_lanes(&self, elem_bytes: u64) -> u32 {
        (self.simd_bytes / elem_bytes) as u32
    }

    /// Ports able to execute `op`.
    pub fn ports_for(&self, op: &OpClass) -> Vec<usize> {
        self.ports
            .iter()
            .enumerate()
            .filter(|(_, p)| p.can(op))
            .map(|(i, _)| i)
            .collect()
    }

    /// Peak throughput (instructions/cy) for an op class = #capable ports.
    pub fn throughput(&self, op: &OpClass) -> f64 {
        self.ports_for(op).len() as f64
    }

    /// Cycles for one cache line from memory (per domain, sustained BW).
    pub fn mem_cycles_per_cl(&self) -> f64 {
        let bw = self.mem.sustained_bw_gbs;
        crate::util::units::bw_to_cycles_per_cl(bw, self.freq_ghz, self.cacheline)
    }

    /// Cycles for one cache line from cache level `idx+1` into level `idx`'s
    /// side (i.e. the refill bandwidth of `caches[idx+1]`).
    pub fn cache_cycles_per_cl(&self, level: usize) -> f64 {
        crate::util::units::bpc_to_cycles_per_cl(self.caches[level].bw_bytes_per_cy, self.cacheline)
    }

    /// Sanity checks on the model.
    pub fn validate(&self) -> Result<(), String> {
        if self.ports.is_empty() {
            return Err(format!("{}: no ports", self.shorthand));
        }
        if self.caches.is_empty() {
            return Err(format!("{}: no caches", self.shorthand));
        }
        for w in self.caches.windows(2) {
            if w[0].capacity >= w[1].capacity {
                return Err(format!(
                    "{}: cache capacities not increasing ({} >= {})",
                    self.shorthand, w[0].capacity, w[1].capacity
                ));
            }
        }
        if self.throughput(&OpClass::Load) == 0.0 {
            return Err(format!("{}: no load port", self.shorthand));
        }
        if self.throughput(&OpClass::Add) == 0.0 && self.throughput(&OpClass::Fma) == 0.0 {
            return Err(format!("{}: no FP port", self.shorthand));
        }
        if !(0.1..=1.0).contains(&self.calib.core_efficiency) {
            return Err(format!("{}: implausible core efficiency", self.shorthand));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;

    #[test]
    fn all_presets_validate() {
        for m in all_machines() {
            m.validate().unwrap_or_else(|e| panic!("{e}"));
        }
    }

    #[test]
    fn port_lookup() {
        let m = haswell();
        assert_eq!(m.throughput(&OpClass::Load), 2.0);
        assert_eq!(m.throughput(&OpClass::Fma), 2.0);
        assert_eq!(m.throughput(&OpClass::Add), 1.0);
        assert_eq!(m.throughput(&OpClass::Mul), 2.0);
    }

    #[test]
    fn lanes() {
        assert_eq!(haswell().simd_lanes(4), 8); // AVX2 SP
        assert_eq!(haswell().simd_lanes(8), 4); // AVX2 DP
        assert_eq!(knights_corner().simd_lanes(4), 16);
        assert_eq!(power8().simd_lanes(4), 4); // VSX SP
    }
}
