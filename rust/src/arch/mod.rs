//! Machine models: Table I of the paper encoded as data, plus a loader for
//! user-defined architectures (the "blueprint for other kernels/machines"
//! extension of Sect. 6).

pub mod loader;
pub mod machine;
pub mod presets;

pub use machine::{
    CacheLevel, Calibration, InstrLatency, Machine, MemorySystem, OverlapPolicy, Port,
};
pub use presets::{all_machines, broadwell, haswell, host, knights_corner, power8};
