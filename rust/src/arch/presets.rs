//! Table I encoded: the four machines of the paper plus a generic host.
//!
//! Sources: Table I of the paper; Intel optimization manual (port maps,
//! latencies); Sinharoy et al. (POWER8 core, [19]); Intel KNC docs [18].
//! All "measured" quantities (sustained bandwidth, latency penalties T_p,
//! calibration frictions) are the paper's own values — the point of the
//! reproduction is that, given these inputs, the ECM machinery and the
//! simulator regenerate the paper's predictions and curves.

use super::machine::*;
use crate::isa::OpClass::*;
use crate::util::units::{KIB, MIB};

/// Intel Haswell-EP (Xeon E5-2695 v3): 14 cores @ 2.3 GHz, AVX2, CoD mode.
pub fn haswell() -> Machine {
    Machine {
        name: "Intel Haswell-EP (E5-2695 v3)",
        shorthand: "HSW",
        freq_ghz: 2.3,
        cores: 14,
        smt_ways: 2,
        cacheline: 64,
        simd_bytes: 32,
        simd_regs: 16,
        issue_width: 4,
        in_order: false,
        ports: vec![
            Port { name: "P0", caps: vec![Fma, Mul] },
            Port { name: "P1", caps: vec![Fma, Mul, Add] },
            Port { name: "P2", caps: vec![Load] },
            Port { name: "P3", caps: vec![Load] },
            Port { name: "P4", caps: vec![Store] },
        ],
        lat: InstrLatency { load: 4, add: 3, mul: 5, fma: 5 },
        caches: vec![
            CacheLevel {
                name: "L1",
                capacity: 32 * KIB,
                bw_bytes_per_cy: 0.0,
                latency_penalty: 0.0,
                shared: false,
            },
            CacheLevel {
                name: "L2",
                capacity: 256 * KIB,
                bw_bytes_per_cy: 64.0,
                latency_penalty: 0.0,
                shared: false,
            },
            // 35 MB chip-wide; CoD halves what one core can use.
            CacheLevel {
                name: "L3",
                capacity: 35 * MIB / 2,
                bw_bytes_per_cy: 32.0,
                latency_penalty: 1.0,
                shared: true,
            },
        ],
        mem: MemorySystem { sustained_bw_gbs: 32.0, domains: 2, latency_penalty: 1.0 },
        overlap: OverlapPolicy::IntelNonOverlapping,
        victim_llc: false,
        calib: Calibration {
            // Sect. 5.1: naive & FMA-Kahan "fall short of the L2 model
            // prediction" by ~1 cy/CL.
            l2_friction_cy_per_cl: 0.5,
            // Sect. 5.1: unexplained worse in-memory behavior on HSW.
            mem_friction_cy_per_cl: 0.5,
            core_efficiency: 1.0,
            effective_llc_capacity: None,
            erratic_window: None,
            noise_rel: 0.015,
        },
    }
}

/// Intel Broadwell-EP (pre-release, 22 cores @ 2.1 GHz): a 14-nm shrink of
/// HSW; more cores -> more Uncore hops -> T_p = 5 cy.
pub fn broadwell() -> Machine {
    let mut m = haswell();
    m.name = "Intel Broadwell-EP (pre-release)";
    m.shorthand = "BDW";
    m.freq_ghz = 2.1;
    m.cores = 22;
    m.caches[2].capacity = 55 * MIB / 2;
    m.caches[2].latency_penalty = 5.0;
    m.mem = MemorySystem { sustained_bw_gbs: 32.3, domains: 2, latency_penalty: 5.0 };
    m.calib.l2_friction_cy_per_cl = 0.5;
    m.calib.mem_friction_cy_per_cl = 0.0;
    m
}

/// Intel Xeon Phi 5110P "Knights Corner": 60 in-order cores @ 1.05 GHz,
/// 512-bit IMCI SIMD, no shared LLC, ring interconnect to GDDR5.
pub fn knights_corner() -> Machine {
    Machine {
        name: "Intel Xeon Phi 5110P (Knights Corner)",
        shorthand: "KNC",
        freq_ghz: 1.05,
        cores: 60,
        smt_ways: 4,
        cacheline: 64,
        simd_bytes: 64,
        simd_regs: 32,
        issue_width: 2,
        in_order: true,
        ports: vec![
            // U-pipe: the 512-b VPU. V-pipe: loads/prefetches/scalar ops —
            // loads can be *issued* from either pipe but there is a single
            // L1 read port (Table I: LOAD throughput 1/cy), so Load lives
            // on V only; pairing an arith (U) with a load (V) still models
            // the paper's "overlap the FMA with one of the loads".
            Port { name: "U", caps: vec![Fma, Mul, Add, Mov] },
            Port { name: "V", caps: vec![Load, Store, Prefetch(1), Prefetch(2), Scalar, Mov] },
        ],
        lat: InstrLatency { load: 3, add: 4, mul: 4, fma: 4 },
        caches: vec![
            CacheLevel {
                name: "L1",
                capacity: 32 * KIB,
                bw_bytes_per_cy: 0.0,
                latency_penalty: 0.0,
                shared: false,
            },
            CacheLevel {
                name: "L2",
                capacity: 512 * KIB,
                bw_bytes_per_cy: 32.0,
                latency_penalty: 0.0,
                shared: false,
            },
        ],
        mem: MemorySystem { sustained_bw_gbs: 175.0, domains: 1, latency_penalty: 20.0 },
        overlap: OverlapPolicy::KncPaired,
        victim_llc: false,
        calib: Calibration {
            l2_friction_cy_per_cl: 0.0,
            mem_friction_cy_per_cl: 0.0,
            core_efficiency: 1.0,
            effective_llc_capacity: None,
            erratic_window: None,
            noise_rel: 0.02,
        },
    }
}

/// IBM POWER8 (S822LC): 10 cores @ 2.926 GHz, VSX (16 B), 128-B lines,
/// per-core victim L3, Centaur memory buffers.
pub fn power8() -> Machine {
    Machine {
        name: "IBM POWER8 (S822LC)",
        shorthand: "PWR8",
        freq_ghz: 2.926,
        cores: 10,
        smt_ways: 8,
        cacheline: 128,
        simd_bytes: 16,
        simd_regs: 64,
        issue_width: 8,
        in_order: false,
        ports: vec![
            Port { name: "VSX0", caps: vec![Fma, Mul, Add] },
            Port { name: "VSX1", caps: vec![Fma, Mul, Add] },
            Port { name: "LSU0", caps: vec![Load, Store] },
            Port { name: "LSU1", caps: vec![Load, Store] },
        ],
        // POWER8 FPU pipeline latency ~6 cy (Sinharoy et al. [19]).
        lat: InstrLatency { load: 4, add: 6, mul: 6, fma: 6 },
        caches: vec![
            CacheLevel {
                name: "L1",
                capacity: 64 * KIB,
                bw_bytes_per_cy: 0.0,
                latency_penalty: 0.0,
                shared: false,
            },
            CacheLevel {
                name: "L2",
                capacity: 512 * KIB,
                bw_bytes_per_cy: 64.0,
                latency_penalty: 0.0,
                shared: false,
            },
            // Per-core 8 MB victim L3: no Uncore crossing -> T_p = 0.
            CacheLevel {
                name: "L3",
                capacity: 8 * MIB,
                bw_bytes_per_cy: 32.0,
                latency_penalty: 0.0,
                shared: false,
            },
        ],
        mem: MemorySystem { sustained_bw_gbs: 73.6, domains: 1, latency_penalty: 0.0 },
        overlap: OverlapPolicy::FullOverlap,
        victim_llc: true,
        calib: Calibration {
            l2_friction_cy_per_cl: 0.0,
            mem_friction_cy_per_cl: 0.0,
            // Sect. 5.3: "we failed to reach the predicted instruction
            // throughput of the processor by 20-30%".
            core_efficiency: 0.75,
            // Sect. 5.3: "The 8 MB L3 cache is only effective up to 2 MB".
            effective_llc_capacity: Some(2 * MIB),
            // Sect. 5.3: erratic behavior between 2 MB and 64 MB.
            erratic_window: Some((2 * MIB, 64 * MIB, 0.25)),
            noise_rel: 0.02,
        },
    }
}

/// Generic host description for the real-machine PJRT path. Core counts and
/// frequency are detected at runtime where it matters (hostbench); this
/// static model exists so the ECM/simulator tooling can also be pointed at
/// "a current laptop/server class core" (used by the custom-arch example).
pub fn host() -> Machine {
    let mut m = haswell();
    m.name = "Generic x86-64 host (AVX2 class)";
    m.shorthand = "HOST";
    m.freq_ghz = 3.0;
    m.cores = std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(1);
    m.mem = MemorySystem { sustained_bw_gbs: 25.0, domains: 1, latency_penalty: 2.0 };
    m.calib.noise_rel = 0.0;
    m
}

/// The four paper machines, in Table I order.
pub fn all_machines() -> Vec<Machine> {
    vec![haswell(), broadwell(), knights_corner(), power8()]
}

/// Look up a machine by shorthand (case-insensitive); includes HOST.
pub fn by_shorthand(s: &str) -> Option<Machine> {
    let up = s.to_uppercase();
    all_machines()
        .into_iter()
        .chain(std::iter::once(host()))
        .find(|m| m.shorthand == up)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let hsw = haswell();
        assert_eq!(hsw.cores, 14);
        assert_eq!(hsw.simd_bytes, 32);
        assert_eq!(hsw.cacheline, 64);
        let bdw = broadwell();
        assert_eq!(bdw.cores, 22);
        assert_eq!(bdw.mem.latency_penalty, 5.0);
        let knc = knights_corner();
        assert_eq!(knc.cores, 60);
        assert!(knc.in_order);
        assert_eq!(knc.simd_bytes, 64);
        let p8 = power8();
        assert_eq!(p8.cacheline, 128);
        assert_eq!(p8.smt_ways, 8);
        assert!(p8.victim_llc);
    }

    #[test]
    fn data_transfer_cycles_match_sect4() {
        let hsw = haswell();
        // T_L1L2: 64 B/cy -> 1 cy/CL; T_L2L3: 32 B/cy -> 2 cy/CL.
        assert_eq!(hsw.cache_cycles_per_cl(1), 1.0);
        assert_eq!(hsw.cache_cycles_per_cl(2), 2.0);
        // Memory: 4.6 cy/CL (Sect. 4.1.1).
        assert!((hsw.mem_cycles_per_cl() - 4.6).abs() < 1e-9);
        let p8 = power8();
        // L2->L1 64 B/cy on 128-B lines: 2 cy/CL; L3->L2: 4 cy/CL.
        assert_eq!(p8.cache_cycles_per_cl(1), 2.0);
        assert_eq!(p8.cache_cycles_per_cl(2), 4.0);
        // Memory ~5.0 cy/CL (Sect. 4.1.3 rounds 5.09 to 5.0).
        assert!((p8.mem_cycles_per_cl() - 5.09).abs() < 0.02);
        let knc = knights_corner();
        assert!((knc.mem_cycles_per_cl() - 0.384).abs() < 1e-3);
    }

    #[test]
    fn by_shorthand_lookup() {
        assert!(by_shorthand("hsw").is_some());
        assert!(by_shorthand("PWR8").is_some());
        assert!(by_shorthand("HOST").is_some());
        assert!(by_shorthand("ZEN5").is_none());
    }
}
