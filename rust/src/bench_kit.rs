//! Mini-criterion: the measurement harness used by `benches/*.rs`
//! (criterion is not in the offline crate cache; DESIGN.md §2).
//!
//! Methodology mirrors likwid-bench/criterion: warmup until timing
//! stabilizes, then `samples` timed batches, each batch sized so one batch
//! takes ≥ `min_batch_time`; report the robust summary. The *minimum* is
//! the headline statistic for cycle-deterministic workloads (as in the
//! paper's likwid-bench measurements); mean/median/stddev are also kept.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub min_batch_time: Duration,
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            min_batch_time: Duration::from_millis(20),
            samples: 15,
        }
    }
}

impl BenchConfig {
    /// Faster settings for CI / smoke runs.
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(20),
            min_batch_time: Duration::from_millis(2),
            samples: 5,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in nanoseconds.
    pub ns_per_iter: Summary,
    /// Iterations per timed batch (diagnostic).
    pub batch_iters: u64,
    /// Optional throughput denominator: "work units" per iteration.
    pub work_per_iter: f64,
}

impl BenchResult {
    /// Work units per second based on the *minimum* (best) sample.
    pub fn throughput_best(&self) -> f64 {
        self.work_per_iter / (self.ns_per_iter.min * 1e-9)
    }

    pub fn throughput_median(&self) -> f64 {
        self.work_per_iter / (self.ns_per_iter.median * 1e-9)
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12.1} ns/iter (min {:>12.1}, sd {:>5.1}%)",
            self.name,
            self.ns_per_iter.median,
            self.ns_per_iter.min,
            self.ns_per_iter.rel_stddev() * 100.0
        )
    }
}

/// Measure `f`, which performs *one* iteration of work per call.
/// `work_per_iter` is the number of "work units" (e.g. updates) one call
/// performs, used for throughput reporting.
pub fn bench<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    work_per_iter: f64,
    mut f: F,
) -> BenchResult {
    // Warmup + batch sizing: run until warmup budget is spent, measuring
    // a rough per-iter time.
    let warm_start = Instant::now();
    let mut iters: u64 = 0;
    while warm_start.elapsed() < cfg.warmup || iters == 0 {
        f();
        iters += 1;
        if iters > 1_000_000_000 {
            break;
        }
    }
    let rough = warm_start.elapsed().as_nanos() as f64 / iters as f64;
    let batch = cfg.min_batch_time.as_nanos() as f64 / rough.max(0.1);
    let batch_iters = (batch.ceil() as u64).max(1);

    let mut samples = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..batch_iters {
            f();
        }
        let dt = t0.elapsed().as_nanos() as f64;
        samples.push(dt / batch_iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        ns_per_iter: Summary::of(&samples),
        batch_iters,
        work_per_iter,
    }
}

/// A named group of benchmarks with uniform config, printing as it goes —
/// the `main()` body of each `benches/*.rs` file.
pub struct Runner {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Runner {
    pub fn new() -> Self {
        // `CARGO_BENCH_QUICK=1 cargo bench` for smoke runs.
        let cfg = if std::env::var("CARGO_BENCH_QUICK").is_ok() {
            BenchConfig::quick()
        } else {
            BenchConfig::default()
        };
        Self {
            cfg,
            results: Vec::new(),
        }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, work_per_iter: f64, f: F) -> &BenchResult {
        let r = bench(name, &self.cfg, work_per_iter, f);
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print a throughput table footer (units/s with a unit label).
    pub fn footer(&self, unit: &str) {
        println!("--");
        for r in &self.results {
            if r.work_per_iter > 0.0 {
                println!(
                    "{:<44} {:>10.3} G{unit}/s (best)",
                    r.name,
                    r.throughput_best() / 1e9
                );
            }
        }
    }
}

impl Default for Runner {
    fn default() -> Self {
        Self::new()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let cfg = BenchConfig::quick();
        let mut acc = 0u64;
        let r = bench("noop-ish", &cfg, 1.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.ns_per_iter.min > 0.0);
        assert!(r.ns_per_iter.min < 1e6, "{}", r.ns_per_iter.min);
        assert!(r.batch_iters >= 1);
    }

    #[test]
    fn throughput_consistent() {
        let cfg = BenchConfig::quick();
        let r = bench("sleepless", &cfg, 100.0, || {
            black_box((0..100).sum::<u64>());
        });
        let t = r.throughput_best();
        assert!(t > 0.0);
        assert_eq!(t, 100.0 / (r.ns_per_iter.min * 1e-9));
    }
}
