//! L3 coordination: the experiment registry, the parallel runner and the
//! report assembler behind the `kahan-ecm` CLI.

pub mod pool;
pub mod registry;
pub mod report;

pub use pool::run_parallel;
pub use registry::{all_experiments, find, ExperimentDef};
pub use report::assemble_report;
