//! Parallel experiment runner (std scoped threads; the offline crate cache
//! has no tokio, and the workload is CPU-bound batch jobs anyway —
//! DESIGN.md §2).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::registry::ExperimentDef;
use crate::harness::{Ctx, ExperimentOutput};

/// Outcome of one experiment run.
pub struct RunOutcome {
    pub id: &'static str,
    pub result: Result<ExperimentOutput>,
    pub seconds: f64,
}

/// Run experiments on up to `jobs` worker threads, preserving input order
/// in the returned outcomes.
pub fn run_parallel(defs: &[ExperimentDef], ctx: &Ctx, jobs: usize) -> Vec<RunOutcome> {
    let jobs = jobs.max(1).min(defs.len().max(1));
    let next = AtomicUsize::new(0);
    let outcomes: Mutex<Vec<Option<RunOutcome>>> =
        Mutex::new((0..defs.len()).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= defs.len() {
                    break;
                }
                let def = &defs[i];
                let t0 = std::time::Instant::now();
                let result = (def.run)(ctx);
                let outcome = RunOutcome {
                    id: def.id,
                    result,
                    seconds: t0.elapsed().as_secs_f64(),
                };
                outcomes.lock().unwrap()[i] = Some(outcome);
            });
        }
    });

    outcomes
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("missing outcome"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::find;

    #[test]
    fn runs_fast_experiments_in_parallel() {
        let defs: Vec<ExperimentDef> = find("table1")
            .into_iter()
            .chain(find("fig1"))
            .chain(find("ecm-inputs"))
            .collect();
        let out = run_parallel(&defs, &Ctx::quick(), 3);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].id, "table1");
        assert_eq!(out[2].id, "ecm-inputs");
        for o in &out {
            assert!(o.result.is_ok(), "{} failed", o.id);
        }
    }

    #[test]
    fn jobs_one_works() {
        let defs = find("fig1");
        let out = run_parallel(&defs, &Ctx::quick(), 1);
        assert!(out[0].result.is_ok());
    }
}
