//! Experiment registry: every paper table/figure mapped to its generator
//! (DESIGN.md §5).

use anyhow::Result;

use crate::harness::{self, Ctx, ExperimentOutput};

type RunFn = fn(&Ctx) -> Result<ExperimentOutput>;

/// One registered experiment.
#[derive(Clone)]
pub struct ExperimentDef {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub title: &'static str,
    /// Needs the AOT artifacts / PJRT runtime.
    pub needs_artifacts: bool,
    pub run: RunFn,
}

/// All experiments, in paper order.
pub fn all_experiments() -> Vec<ExperimentDef> {
    vec![
        ExperimentDef {
            id: "table1",
            paper_ref: "Table I",
            title: "Testbed specification",
            needs_artifacts: false,
            run: harness::tables::table1,
        },
        ExperimentDef {
            id: "ecm-inputs",
            paper_ref: "Sect. 4 / Eqs. 1-3",
            title: "ECM inputs & predictions for every kernel x machine",
            needs_artifacts: false,
            run: harness::tables::ecm_inputs,
        },
        ExperimentDef {
            id: "fig1",
            paper_ref: "Fig. 1",
            title: "ECM multicore scaling schematic",
            needs_artifacts: false,
            run: harness::fig1::fig1,
        },
        ExperimentDef {
            id: "fig5a",
            paper_ref: "Fig. 5a",
            title: "Single-core sweep, HSW",
            needs_artifacts: false,
            run: harness::fig5::fig5a,
        },
        ExperimentDef {
            id: "fig5b",
            paper_ref: "Fig. 5b",
            title: "Single-core sweep, BDW",
            needs_artifacts: false,
            run: harness::fig5::fig5b,
        },
        ExperimentDef {
            id: "fig6",
            paper_ref: "Fig. 6",
            title: "Single-core sweep with per-level kernels, KNC",
            needs_artifacts: false,
            run: harness::fig6::fig6,
        },
        ExperimentDef {
            id: "fig7a",
            paper_ref: "Fig. 7a",
            title: "PWR8 SMT sweep (naive)",
            needs_artifacts: false,
            run: harness::fig7::fig7a,
        },
        ExperimentDef {
            id: "fig7b",
            paper_ref: "Fig. 7b",
            title: "PWR8 naive vs manual Kahan (SMT-8)",
            needs_artifacts: false,
            run: harness::fig7::fig7b,
        },
        ExperimentDef {
            id: "fig8a",
            paper_ref: "Fig. 8a",
            title: "In-memory scaling, HSW",
            needs_artifacts: false,
            run: harness::fig8::fig8a,
        },
        ExperimentDef {
            id: "fig8b",
            paper_ref: "Fig. 8b",
            title: "In-memory scaling, BDW",
            needs_artifacts: false,
            run: harness::fig8::fig8b,
        },
        ExperimentDef {
            id: "fig8c",
            paper_ref: "Fig. 8c",
            title: "In-memory scaling, KNC",
            needs_artifacts: false,
            run: harness::fig8::fig8c,
        },
        ExperimentDef {
            id: "fig8d",
            paper_ref: "Fig. 8d",
            title: "In-memory scaling, PWR8",
            needs_artifacts: false,
            run: harness::fig8::fig8d,
        },
        ExperimentDef {
            id: "fig9",
            paper_ref: "Fig. 9",
            title: "Compiler Kahan ddot scaling, all machines",
            needs_artifacts: false,
            run: harness::fig9::fig9,
        },
        ExperimentDef {
            id: "fig10a",
            paper_ref: "Fig. 10a",
            title: "Cycles per update per level, all machines",
            needs_artifacts: false,
            run: harness::fig10::fig10a,
        },
        ExperimentDef {
            id: "fig10b",
            paper_ref: "Fig. 10b",
            title: "In-memory chip comparison",
            needs_artifacts: false,
            run: harness::fig10::fig10b,
        },
        ExperimentDef {
            id: "acc",
            paper_ref: "Sect. 1 (motivation)",
            title: "Accuracy vs condition number (+ PJRT f32 kernels)",
            needs_artifacts: false, // degrades gracefully without artifacts
            run: harness::accstudy::acc,
        },
        ExperimentDef {
            id: "host",
            paper_ref: "Sect. 6 (blueprint)",
            title: "Host-CPU kernel-ladder sweep (native backend + optional PJRT)",
            needs_artifacts: false, // native backend runs anywhere
            run: harness::hostexp::host,
        },
        ExperimentDef {
            id: "scale",
            paper_ref: "Sect. 5.1 / Figs. 8-9 (live)",
            title: "Measured thread-scaling vs contention model on this host",
            needs_artifacts: false, // parallel native backend runs anywhere
            run: harness::scaleexp::scale,
        },
        ExperimentDef {
            id: "serve",
            paper_ref: "Sect. 5.1 (applied)",
            title: "Batching/sharding dot-product serving layer under live load",
            needs_artifacts: false, // serves the native kernels anywhere
            run: harness::serveexp::serve,
        },
    ]
}

/// Find experiments matching `sel` ("all", exact id, or prefix like "fig8").
pub fn find(sel: &str) -> Vec<ExperimentDef> {
    let all = all_experiments();
    if sel == "all" {
        return all;
    }
    let exact: Vec<ExperimentDef> = all.iter().filter(|e| e.id == sel).cloned().collect();
    if !exact.is_empty() {
        return exact;
    }
    all.into_iter().filter(|e| e.id.starts_with(sel)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let ids: Vec<&str> = all_experiments().iter().map(|e| e.id).collect();
        for want in [
            "table1", "ecm-inputs", "fig1", "fig5a", "fig5b", "fig6", "fig7a", "fig7b",
            "fig8a", "fig8b", "fig8c", "fig8d", "fig9", "fig10a", "fig10b", "acc", "host",
            "scale", "serve",
        ] {
            assert!(ids.contains(&want), "missing experiment {want}");
        }
    }

    #[test]
    fn find_selects() {
        assert_eq!(find("all").len(), all_experiments().len());
        assert_eq!(find("fig8").len(), 4);
        assert_eq!(find("fig5a").len(), 1);
        assert!(find("nope").is_empty());
    }

    #[test]
    fn ids_unique() {
        let mut ids: Vec<&str> = all_experiments().iter().map(|e| e.id).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }
}
