//! Assemble a run-level report (`out/REPORT.md`) from experiment outcomes.

use crate::util::table::Table;

use super::pool::RunOutcome;
use super::registry::ExperimentDef;

/// Build the top-level markdown report for a batch run.
pub fn assemble_report(defs: &[ExperimentDef], outcomes: &[RunOutcome]) -> String {
    let mut s = String::from("# kahan-ecm experiment run\n\n");
    let mut t = Table::new(["experiment", "paper ref", "status", "time (s)", "notes"]);
    for (def, o) in defs.iter().zip(outcomes) {
        let (status, notes) = match &o.result {
            Ok(out) => ("ok".to_string(), out.notes.join(" ")),
            Err(e) => (format!("FAILED: {e:#}"), String::new()),
        };
        t.row([
            def.id.to_string(),
            def.paper_ref.to_string(),
            status,
            format!("{:.1}", o.seconds),
            notes.chars().take(140).collect::<String>(),
        ]);
    }
    s.push_str(&t.to_markdown());
    s.push_str(
        "\nPer-experiment data: `out/<id>/*.csv`, plots in `out/<id>/*.txt`, details \
         in `out/<id>/summary.md`.\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::run_parallel;
    use crate::coordinator::registry::find;
    use crate::harness::Ctx;

    #[test]
    fn report_contains_status_rows() {
        let defs = find("fig1");
        let out = run_parallel(&defs, &Ctx::quick(), 1);
        let rep = assemble_report(&defs, &out);
        assert!(rep.contains("fig1"));
        assert!(rep.contains("ok"));
    }
}
