//! Derive ECM inputs from a (machine, kernel) pair — the analysis the paper
//! performs by hand in Sect. 4, automated:
//!
//! * **T_nOL** — cycles with L1↔register traffic. Intel: loads/stores over
//!   the load-port throughput. KNC: loads + unpairable software prefetches,
//!   one cycle each (single V-pipe). POWER8: zero (multi-ported L1).
//! * **T_OL** — the larger of the *resource* bound (port pressure, computed
//!   exactly by subset enumeration) and the *recurrence* bound (the longest
//!   loop-carried latency cycle — the Fig. 3 analysis).
//! * **T_data** — per-hop bandwidth cycles from the machine's documented
//!   cache bandwidths and the measured sustained memory bandwidth, plus
//!   latency penalties T_p (Sect. 2).
//!
//! `paper_row` additionally applies the documented overrides where the
//! paper's hand-scheduled kernels differ from the analytic optimum (one
//! case: the 4-way FMA Kahan on HSW/BDW, paper 8 cy/CL vs RecMII 7 cy/CL).

use crate::arch::{Machine, OverlapPolicy};
use crate::isa::variants::{build_sched, Sched, Variant};
use crate::isa::{KernelLoop, OpClass};
use crate::util::units::Precision;

use super::inputs::{DataTerm, EcmInputs};

/// Which hierarchy level a kernel is tuned for (KNC's per-level kernels,
/// Sect. 4.2.2; ignored elsewhere).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemLevel {
    L1,
    L2,
    Mem,
}

/// The paper's kernel configuration for a machine: SIMD width from the ISA,
/// unroll factors as published, software pipelining for in-order cores,
/// per-level prefetch decoration on KNC.
pub fn kernel_for(m: &Machine, v: Variant, prec: Precision, level: MemLevel) -> KernelLoop {
    let lanes = if v == Variant::KahanScalar {
        1
    } else {
        m.simd_lanes(prec.bytes())
    };
    let (unroll, sched) = match (m.shorthand, v) {
        // Intel Xeon: naive needs >= 2*ports*latency/..., 10 chains saturate
        // both FMA ports at 5-cy latency; Kahan variants as published.
        (_, Variant::KahanScalar) => (1, Sched::StageMajor),
        ("KNC", Variant::NaiveSimd) => (4, Sched::SoftwarePipelined),
        ("KNC", _) => (4, Sched::SoftwarePipelined),
        ("PWR8", Variant::NaiveSimd) => (16, Sched::StageMajor),
        ("PWR8", _) => (16, Sched::StageMajor),
        (_, Variant::NaiveSimd) => (10, Sched::StageMajor),
        (_, Variant::KahanSimd) => (4, Sched::StageMajor),
        (_, Variant::KahanSimdFma) => (4, Sched::StageMajor),
        (_, Variant::KahanSimdFma5) => (5, Sched::StageMajor),
    };
    // Only the hand-written KNC *Kahan* kernels carry explicit software
    // prefetch (Fig. 4); the naive kernel's ECM input has none
    // (Sect. 4.1.2's {1 ‖ 2 | 4 | 0.8 + 20}).
    let prefetches: Vec<(u8, u32)> = if m.shorthand == "KNC" && v.is_kahan() {
        // Fig. 4 / Sect. 4.2.2: L1 kernel no prefetch; L2 kernel 2x PF->L1;
        // memory kernel additionally 2x PF->L2. Counts are per cache line
        // of work; scale by body CLs.
        let cls = (lanes as u64 * unroll as u64 * prec.bytes()).div_euclid(m.cacheline) as u32;
        let per_cl = match level {
            MemLevel::L1 => vec![],
            MemLevel::L2 => vec![(1u8, 2u32)],
            MemLevel::Mem => vec![(1, 2), (2, 2)],
        };
        per_cl
            .into_iter()
            .map(|(l, c)| (l, c * cls.max(1)))
            .collect()
    } else {
        vec![]
    };
    build_sched(v, lanes, unroll, prec, &prefetches, sched)
}

/// Exact resource-bound initiation interval (cycles per body) for the
/// arithmetic ops: max over port subsets S of |ops issuable only on S| / |S|.
fn res_mii(m: &Machine, k: &KernelLoop, include: impl Fn(&OpClass) -> bool) -> f64 {
    let nports = m.ports.len();
    let op_cands: Vec<Vec<usize>> = k
        .body
        .iter()
        .filter(|i| include(&i.op))
        .map(|i| m.ports_for(&i.op))
        .collect();
    let mut worst: f64 = 0.0;
    for mask in 1u32..(1 << nports) {
        let members: Vec<usize> = (0..nports).filter(|p| mask & (1 << p) != 0).collect();
        let confined = op_cands
            .iter()
            .filter(|cands| !cands.is_empty() && cands.iter().all(|p| members.contains(p)))
            .count();
        worst = worst.max(confined as f64 / members.len() as f64);
    }
    worst
}

/// Recurrence-bound initiation interval: the longest loop-carried latency
/// cycle (sum of producer latencies around the cycle), considering cycles
/// that cross the loop edge exactly once (sufficient for these kernels).
fn rec_mii(m: &Machine, k: &KernelLoop) -> f64 {
    let n = k.body.len();
    // Intra-iteration adjacency: edge producer -> consumer.
    let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (ix, ins) in k.body.iter().enumerate() {
        for &src in &ins.srcs {
            if let Some(p) = k.body[..ix].iter().rposition(|q| q.dst == Some(src)) {
                succ[p].push(ix);
            }
        }
    }
    // Longest path (by producer latency) from each node, memoized (DAG).
    fn longest(
        node: usize,
        target: usize,
        succ: &[Vec<usize>],
        lat: &[f64],
        memo: &mut [Option<f64>],
    ) -> f64 {
        // longest latency sum from `node` (exclusive of node's own latency)
        // to `target` (returns -inf if unreachable).
        if node == target {
            return 0.0;
        }
        if let Some(v) = memo[node] {
            return v;
        }
        let mut best = f64::NEG_INFINITY;
        for &s in &succ[node] {
            let tail = longest(s, target, succ, lat, memo);
            if tail > f64::NEG_INFINITY {
                best = best.max(lat[s] + tail);
            }
        }
        memo[node] = Some(best);
        best
    }
    let lat: Vec<f64> = k.body.iter().map(|i| m.lat.of(&i.op) as f64).collect();

    let mut worst: f64 = 0.0;
    for (ix, ins) in k.body.iter().enumerate() {
        for &src in &ins.srcs {
            let intra = k.body[..ix].iter().rposition(|q| q.dst == Some(src));
            if intra.is_some() {
                continue;
            }
            // Carried edge from the last writer of `src` to `ix`.
            if let Some(w) = k.body.iter().rposition(|q| q.dst == Some(src)) {
                // Cycle: consumer ix ->(dag)-> writer w, then carried w -> ix.
                let mut memo = vec![None; k.body.len()];
                let path = longest(ix, w, &succ, &lat, &mut memo);
                if path > f64::NEG_INFINITY {
                    worst = worst.max(lat[ix] + path);
                }
            }
        }
    }
    worst
}

/// Derive ECM inputs for an arbitrary (machine, kernel) pair.
pub fn derive(m: &Machine, k: &KernelLoop) -> EcmInputs {
    let upcl = k.updates_per_cl(m.cacheline);
    let cls_per_body = k.cachelines_per_body(m.cacheline);
    let norm = 1.0 / cls_per_body; // body cycles -> cycles per CL of work

    let loads = k.count(|o| o.is_l1_transfer()) as f64;
    let prefetch = k.count(|o| matches!(o, OpClass::Prefetch(_))) as f64;

    // ---- in-core terms ----------------------------------------------------
    let (t_ol, t_nol) = match m.overlap {
        OverlapPolicy::IntelNonOverlapping => {
            let t_nol = loads / m.throughput(&OpClass::Load) * norm;
            let res = res_mii(m, k, |o| o.is_arith());
            let rec = rec_mii(m, k);
            ((res.max(rec)) * norm, t_nol)
        }
        OverlapPolicy::KncPaired => {
            // All loads + prefetches cost one V-pipe cycle each (single L1
            // port); arithmetic retires 1/cy on the U-pipe. Pairing lets
            // them overlap *each other* but loads remain non-overlapping
            // with L1<->L2 transfers (Sect. 4.2.2's T_nOL composition).
            // In-order: the loop-carried latency chain is NOT hidden by
            // hardware scheduling, so it bounds T_OL too (the unrolled SIMD
            // kernels hide it by construction; the compiler's scalar Kahan
            // does not — hence its need for SMT, Fig. 8c/9).
            let t_nol = (loads + prefetch) * norm;
            let arith = k.count(|o| o.is_arith()) as f64;
            let rec = rec_mii(m, k);
            (arith.max(rec) * norm, t_nol)
        }
        OverlapPolicy::FullOverlap => {
            // PWR8: loads overlap everything (multi-ported L1) but still
            // occupy LSU throughput; T_OL is the slowest unit.
            let lsu = loads / m.throughput(&OpClass::Load);
            let res = res_mii(m, k, |o| o.is_arith());
            let rec = rec_mii(m, k);
            ((lsu.max(res).max(rec)) * norm, 0.0)
        }
    };

    // ---- data terms --------------------------------------------------------
    let streams = k.streams as f64;
    let mut data = Vec::new();
    for (i, c) in m.caches.iter().enumerate().skip(1) {
        data.push(DataTerm {
            name: c.name.to_string(),
            cycles: streams * m.cache_cycles_per_cl(i),
            penalty: c.latency_penalty,
        });
    }
    // Memory hop. KNC latency penalty is prefetch-distance dependent: the
    // Kahan memory kernel prefetches 64 iterations ahead into L2 and gets
    // T_p = 17 cy; everything else pays the ring's 20 cy (Sect. 4.2.2).
    let mem_penalty = if m.shorthand == "KNC"
        && k.count(|o| matches!(o, OpClass::Prefetch(2))) > 0
    {
        17.0
    } else {
        m.mem.latency_penalty
    };
    // The paper carries the memory transfer time at one-decimal precision
    // per cache line (4.6, 4.2, 0.4, 5.0/5.1 cy/CL) before multiplying by
    // the stream count; match that so pinned tables agree digit-for-digit.
    let mem_cycles = streams * (m.mem_cycles_per_cl() * 10.0).round() / 10.0;
    data.push(DataTerm {
        name: "Mem".to_string(),
        cycles: mem_cycles,
        penalty: mem_penalty,
    });

    // PWR8 victim hierarchy: the memory-level data path is L2<-Mem direct
    // plus L2->L3 evictions; the upper bound counts evictions fully
    // (4 + 8 + 10 = 22 cy), the lower assumes half the eviction traffic
    // overlaps with reloads (18 cy) — the band of Sect. 5.3.
    let mem_bounds = if m.victim_llc && m.caches.len() >= 3 {
        let d_l1l2 = streams * m.cache_cycles_per_cl(1);
        let d_evict = streams * m.cache_cycles_per_cl(2);
        let upper = d_l1l2 + d_evict + mem_cycles;
        let lower = upper - 0.5 * d_evict;
        // Rewrite the memory data term so the cumulative sum lands on the
        // upper bound (evictions ride on the same hop accounting).
        Some((lower - d_l1l2 - d_evict, upper - d_l1l2 - d_evict))
    } else {
        None
    };

    let mut inputs = EcmInputs {
        machine: m.shorthand,
        kernel: k.name.clone(),
        t_ol,
        t_nol,
        data,
        updates_per_cl: upcl,
        overlap: m.overlap,
        mem_bounds: None,
    };
    if let Some((lo, up)) = mem_bounds {
        // For the victim hierarchy the L3 reload hop doubles as the
        // eviction hop on the memory level; total matches `up` by
        // construction. Keep the lower bound for reporting.
        let mem_term = inputs.data.last_mut().expect("mem term");
        mem_term.cycles = up;
        let pre: f64 = inputs.data[..inputs.data.len() - 1]
            .iter()
            .map(|d| d.cycles + d.penalty)
            .sum();
        inputs.mem_bounds = Some((pre + lo, pre + up));
    }
    inputs
}

/// A fully-specified "paper row": machine x variant x precision (x level on
/// KNC), with the documented hand-schedule overrides applied so the pinned
/// tables reproduce the published numbers exactly.
pub fn paper_row(m: &Machine, v: Variant, prec: Precision, level: MemLevel) -> EcmInputs {
    let k = kernel_for(m, v, prec, level);
    let mut inputs = derive(m, &k);
    // Documented override (DESIGN.md §6, EXPERIMENTS.md): the paper's 4-way
    // FMA Kahan hand schedule executes at 16 cy / 2 CL = 8 cy/CL; the pure
    // recurrence bound is 14 cy (7 cy/CL). We pin the published number.
    // Identical in DP (same chunk recurrence, half the updates per CL).
    if matches!(m.overlap, OverlapPolicy::IntelNonOverlapping) && v == Variant::KahanSimdFma {
        inputs.t_ol = inputs.t_ol.max(8.0);
    }
    inputs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;
    use crate::util::table::fnum;

    fn p(m: &Machine, v: Variant, level: MemLevel) -> (EcmInputs, Vec<f64>) {
        let i = paper_row(m, v, Precision::Sp, level);
        let pred = i.predict();
        let cys = pred.levels.iter().map(|(_, c)| c * 1.0).collect();
        (i, cys)
    }

    fn close(a: &[f64], b: &[f64], tol: f64) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= tol)
    }

    // ------------------------- Sect. 4.1: naive ----------------------------

    #[test]
    fn hsw_naive_matches_paper() {
        let (i, cys) = p(&haswell(), Variant::NaiveSimd, MemLevel::Mem);
        assert_eq!(i.t_nol, 2.0, "{}", i.shorthand());
        assert_eq!(i.t_ol, 1.0, "{}", i.shorthand());
        assert!(close(&cys, &[2.0, 4.0, 9.0, 19.2], 0.01), "{cys:?}");
    }

    #[test]
    fn bdw_naive_matches_paper() {
        let (_, cys) = p(&broadwell(), Variant::NaiveSimd, MemLevel::Mem);
        // Paper: {2 | 4 | 13 | 26.4} cy (8.4 cy memory at 32.3 GB/s).
        assert!(close(&cys, &[2.0, 4.0, 13.0, 26.4], 0.1), "{cys:?}");
    }

    #[test]
    fn knc_naive_matches_paper() {
        let (i, cys) = p(&knights_corner(), Variant::NaiveSimd, MemLevel::Mem);
        // {1 ‖ 2 | 4 | 0.8 + 20} -> {2 | 6 | 26.8}.
        assert_eq!(i.t_ol, 1.0, "{}", i.shorthand());
        assert!(close(&cys, &[2.0, 6.0, 26.8], 0.05), "{cys:?}");
    }

    #[test]
    fn pwr8_naive_matches_paper() {
        let (i, cys) = p(&power8(), Variant::NaiveSimd, MemLevel::Mem);
        // {8 | 0 | 4 | 8 | 10} -> {8 | 8 | 12 | 22}.
        assert_eq!(i.t_ol, 8.0, "{}", i.shorthand());
        assert_eq!(i.t_nol, 0.0);
        assert!(close(&cys, &[8.0, 8.0, 12.0, 22.2], 0.25), "{cys:?}");
        // Eviction-overlap band: 18 .. 22 cy.
        let (lo, up) = i.mem_bounds.unwrap();
        assert!((lo - 18.2).abs() < 0.3, "lower {lo}");
        assert!((up - 22.2).abs() < 0.3, "upper {up}");
    }

    // ------------------------- Sect. 4.2: Kahan ----------------------------

    #[test]
    fn hsw_kahan_avx_matches_paper() {
        let (i, cys) = p(&haswell(), Variant::KahanSimd, MemLevel::Mem);
        // {8 ‖ 2 | 2 | 4 + 1 | 9.2 + 1} -> {8 | 8 | 9 | 19.2}.
        assert_eq!(i.t_ol, 8.0, "{}", i.shorthand());
        assert_eq!(i.t_nol, 2.0);
        assert!(close(&cys, &[8.0, 8.0, 9.0, 19.2], 0.01), "{cys:?}");
    }

    #[test]
    fn hsw_kahan_fma_pinned_to_paper() {
        let (i, cys) = p(&haswell(), Variant::KahanSimdFma, MemLevel::Mem);
        assert_eq!(i.t_ol, 8.0, "paper override: {}", i.shorthand());
        assert!(close(&cys, &[8.0, 8.0, 9.0, 19.2], 0.01), "{cys:?}");
        // The un-overridden derivation finds the tighter recurrence bound.
        let k = kernel_for(&haswell(), Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem);
        let raw = derive(&haswell(), &k);
        assert_eq!(raw.t_ol, 7.0, "RecMII 14 cy / 2 CL");
    }

    #[test]
    fn hsw_kahan_fma5_matches_paper() {
        let (i, cys) = p(&haswell(), Variant::KahanSimdFma5, MemLevel::Mem);
        // {6.4 ‖ 2 | 2 | 4 + 1 | 9.2 + 1} -> {6.4 | 6.4 | 9 | 19.2}.
        assert!((i.t_ol - 6.4).abs() < 1e-9, "{}", i.shorthand());
        assert!(close(&cys, &[6.4, 6.4, 9.0, 19.2], 0.01), "{cys:?}");
    }

    #[test]
    fn bdw_kahan_fma5_matches_paper() {
        let (_, cys) = p(&broadwell(), Variant::KahanSimdFma5, MemLevel::Mem);
        // Paper: {6.4 | 6.4 | 13 | 26.8} (with their 8.8-cy memory figure;
        // from the measured 32.3 GB/s it is 8.4 -> 26.4).
        assert!(close(&cys, &[6.4, 6.4, 13.0, 26.4], 0.1), "{cys:?}");
    }

    #[test]
    fn knc_kahan_kernels_match_paper() {
        let m = knights_corner();
        // L1 kernel: {4 ‖ 2 | 4 | ...} -> L1 prediction 4.
        let (i1, cys1) = p(&m, Variant::KahanSimdFma, MemLevel::L1);
        assert_eq!(i1.t_ol, 4.0, "{}", i1.shorthand());
        assert_eq!(i1.t_nol, 2.0);
        assert_eq!(cys1[0], 4.0);
        // L2 kernel: T_nOL = 4 -> L2 prediction 8.
        let (i2, cys2) = p(&m, Variant::KahanSimdFma, MemLevel::L2);
        assert_eq!(i2.t_nol, 4.0, "{}", i2.shorthand());
        assert_eq!(cys2[1], 8.0);
        // Memory kernel: T_nOL = 6, T_p = 17 -> Mem = 6 + 4 + 0.8 + 17 = 27.8.
        let (i3, cys3) = p(&m, Variant::KahanSimdFma, MemLevel::Mem);
        assert_eq!(i3.t_nol, 6.0, "{}", i3.shorthand());
        assert!((cys3[2] - 27.8).abs() < 0.05, "{cys3:?}");
    }

    #[test]
    fn pwr8_kahan_matches_paper() {
        let (i, cys) = p(&power8(), Variant::KahanSimdFma, MemLevel::Mem);
        // {16 | 0 | 4 | 8 | 10} -> {16 | 16 | 16 | 22}.
        assert_eq!(i.t_ol, 16.0, "{}", i.shorthand());
        assert!(close(&cys, &[16.0, 16.0, 16.0, 22.2], 0.25), "{cys:?}");
    }

    #[test]
    fn scalar_kahan_latency_bound() {
        // Compiler Kahan on HSW: 4-op recurrence at 3-cy ADD latency ->
        // 12 cy/update -> 192 cy/CL SP.
        let (i, _) = p(&haswell(), Variant::KahanScalar, MemLevel::Mem);
        assert_eq!(i.t_ol, 192.0, "{}", i.shorthand());
        // DP: 8 updates/CL -> 96 cy/CL.
        let idp = paper_row(&haswell(), Variant::KahanScalar, Precision::Dp, MemLevel::Mem);
        assert_eq!(idp.t_ol, 96.0);
    }

    #[test]
    fn dp_predictions_same_cycles_half_work() {
        // Sect. 4: "The model prediction in terms of cycles per CL does not
        // change for the SIMD variants of Kahan when going from SP to DP".
        let sp = paper_row(&haswell(), Variant::KahanSimd, Precision::Sp, MemLevel::Mem);
        let dp = paper_row(&haswell(), Variant::KahanSimd, Precision::Dp, MemLevel::Mem);
        assert_eq!(sp.t_ol, dp.t_ol);
        assert_eq!(sp.updates_per_cl, 16);
        assert_eq!(dp.updates_per_cl, 8);
    }

    #[test]
    fn shorthand_examples() {
        let i = paper_row(&haswell(), Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        assert_eq!(i.shorthand(), "{1 ‖ 2 | 2 | 4 + 1 | 9.2 + 1} cy");
        let n = paper_row(&knights_corner(), Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        assert_eq!(fnum(n.t_nol, 1), "2");
    }

    #[test]
    fn res_mii_subset_bound() {
        // On HSW the Kahan AVX body (4 chunks) has 16 ADD-class ops on the
        // single ADD port: ResMII = 16.
        let m = haswell();
        let k = kernel_for(&m, Variant::KahanSimd, Precision::Sp, MemLevel::Mem);
        assert_eq!(res_mii(&m, &k, |o| o.is_arith()), 16.0);
    }

    #[test]
    fn rec_mii_chains() {
        let m = haswell();
        // kahan-simd: c -> y -> t -> tmp -> c = 3+3+3+3 = 12.
        let k = kernel_for(&m, Variant::KahanSimd, Precision::Sp, MemLevel::Mem);
        assert_eq!(rec_mii(&m, &k), 12.0);
        // kahan-fma: 5+3+3+3 = 14.
        let k = kernel_for(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem);
        assert_eq!(rec_mii(&m, &k), 14.0);
        // kahan-fma5: 5+5+3+3 = 16.
        let k = kernel_for(&m, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem);
        assert_eq!(rec_mii(&m, &k), 16.0);
        // naive: fma self-loop = 5.
        let k = kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        assert_eq!(rec_mii(&m, &k), 5.0);
    }
}
