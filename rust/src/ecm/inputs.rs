//! ECM input and prediction types + the paper's shorthand notation.
//!
//! All cycle counts are normalized to **one cache line of work**: the
//! number of scalar updates that fit one cache line (16 SP / 8 DP on 64-B
//! lines, 32 SP / 16 DP on POWER8's 128-B lines). One CL of work moves
//! `streams` cache lines through the hierarchy (2 for the dot product).

use crate::arch::{Machine, OverlapPolicy};
use crate::util::table::fnum;

/// One data-transfer term of the ECM input (a hierarchy hop).
#[derive(Clone, Debug, PartialEq)]
pub struct DataTerm {
    /// Name of the *source* level of this hop ("L2", "L3", "Mem"): data in
    /// that level must cross this hop (and all closer ones) to reach L1.
    pub name: String,
    /// Pure bandwidth cycles for the hop (per CL of work, all streams).
    pub cycles: f64,
    /// Latency penalty T_p added when this hop is on the data path.
    pub penalty: f64,
}

/// ECM model inputs for one (kernel, machine) pair.
#[derive(Clone, Debug)]
pub struct EcmInputs {
    pub machine: &'static str,
    pub kernel: String,
    /// Overlapping in-core cycles (arithmetic).
    pub t_ol: f64,
    /// Non-overlapping in-core cycles (L1<->register transfers; 0 on PWR8).
    pub t_nol: f64,
    /// Data-transfer terms, L1L2 outward.
    pub data: Vec<DataTerm>,
    /// Scalar updates per cache line of work.
    pub updates_per_cl: u64,
    /// Composition rule of the source machine.
    pub overlap: OverlapPolicy,
    /// PWR8 victim-cache memory bound pair (lower, upper) when applicable:
    /// Sect. 5.3's "18 cy if evicts fully overlap ... 22 cy if not".
    pub mem_bounds: Option<(f64, f64)>,
}

/// Per-level runtime prediction (cycles per CL of work).
#[derive(Clone, Debug)]
pub struct EcmPrediction {
    pub machine: &'static str,
    pub kernel: String,
    /// (level name, cycles per CL of work), L1 first, memory last.
    pub levels: Vec<(String, f64)>,
    pub updates_per_cl: u64,
    /// Optional optimistic memory bound (PWR8 eviction overlap).
    pub mem_lower: Option<f64>,
}

impl EcmInputs {
    /// The paper's input shorthand: `{T_OL ∥ T_nOL | T_L1L2 | ... + Tp}` cy.
    pub fn shorthand(&self) -> String {
        let mut s = format!("{{{} ‖ {}", fnum(self.t_ol, 1), fnum(self.t_nol, 1));
        for d in &self.data {
            s.push_str(" | ");
            s.push_str(&fnum(d.cycles, 1));
            if d.penalty > 0.0 {
                s.push_str(&format!(" + {}", fnum(d.penalty, 1)));
            }
        }
        s.push_str("} cy");
        s
    }

    /// Compose inputs into per-level predictions (Sect. 2):
    /// * Intel / KNC: `T_l = max(T_OL, T_nOL + Σ_{j<=l} (T_j + Tp_j))`
    /// * PWR8 (full overlap): `T_l = max(T_OL, Σ_{j<=l} (T_j + Tp_j))`
    pub fn predict(&self) -> EcmPrediction {
        let mut levels = Vec::with_capacity(self.data.len() + 1);
        // L1 level: in-core only.
        levels.push(("L1".to_string(), self.t_ol.max(self.t_nol)));
        let base = match self.overlap {
            OverlapPolicy::FullOverlap => 0.0,
            _ => self.t_nol,
        };
        let mut acc = base;
        for d in &self.data {
            acc += d.cycles + d.penalty;
            levels.push((d.name.clone(), self.t_ol.max(acc)));
        }
        let mem_lower = self.mem_bounds.map(|(lo, _)| {
            let pre: f64 = match self.overlap {
                OverlapPolicy::FullOverlap => 0.0,
                _ => self.t_nol,
            };
            self.t_ol.max(pre + lo)
        });
        EcmPrediction {
            machine: self.machine,
            kernel: self.kernel.clone(),
            levels,
            updates_per_cl: self.updates_per_cl,
            mem_lower,
        }
    }

    /// Memory-hop transfer time *without* latency penalty (denominator of
    /// the saturation formula σ_S = T_ECM^Mem / T_L3Mem).
    pub fn mem_transfer_cycles(&self) -> f64 {
        self.data.last().map(|d| d.cycles).unwrap_or(f64::NAN)
    }
}

impl EcmPrediction {
    /// The paper's prediction shorthand `{T^L1 | T^L2 | ... | T^Mem}` cy.
    pub fn shorthand(&self) -> String {
        let inner: Vec<String> = self.levels.iter().map(|(_, c)| fnum(*c, 1)).collect();
        format!("{{{}}} cy", inner.join(" | "))
    }

    /// Cycles for the given level index (0 = L1, last = memory).
    pub fn cycles(&self, level: usize) -> f64 {
        self.levels[level].1
    }

    pub fn mem_cycles(&self) -> f64 {
        self.levels.last().expect("no levels").1
    }

    /// Single-core performance per level in GUP/s at frequency `f` GHz
    /// (Eq. 1-3 of the paper).
    pub fn performance_gups(&self, freq_ghz: f64) -> Vec<(String, f64)> {
        self.levels
            .iter()
            .map(|(n, c)| {
                (
                    n.clone(),
                    crate::util::units::cycles_per_cl_to_gups(*c, freq_ghz, self.updates_per_cl),
                )
            })
            .collect()
    }
}

/// Convenience: derive + predict for (machine, kernel) via [`crate::ecm::derive`].
pub fn predict_for(m: &Machine, k: &crate::isa::KernelLoop) -> EcmPrediction {
    crate::ecm::derive::derive(m, k).predict()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hsw_naive_inputs() -> EcmInputs {
        // Hand-built Sect. 4.1.1 inputs: {1 ‖ 2 | 2 | 4 + 1 | 9.2 + 1}.
        EcmInputs {
            machine: "HSW",
            kernel: "naive".into(),
            t_ol: 1.0,
            t_nol: 2.0,
            data: vec![
                DataTerm { name: "L2".into(), cycles: 2.0, penalty: 0.0 },
                DataTerm { name: "L3".into(), cycles: 4.0, penalty: 1.0 },
                DataTerm { name: "Mem".into(), cycles: 9.2, penalty: 1.0 },
            ],
            updates_per_cl: 16,
            overlap: OverlapPolicy::IntelNonOverlapping,
            mem_bounds: None,
        }
    }

    #[test]
    fn hsw_naive_prediction_matches_eq1() {
        let p = hsw_naive_inputs().predict();
        let cys: Vec<f64> = p.levels.iter().map(|(_, c)| *c).collect();
        assert_eq!(cys, vec![2.0, 4.0, 9.0, 19.2]);
        let perf = p.performance_gups(2.3);
        let gups: Vec<f64> = perf.iter().map(|(_, g)| *g).collect();
        // Eq. (1): {18.40 | 9.20 | 4.09 | 1.92} GUP/s.
        assert!((gups[0] - 18.40).abs() < 0.01);
        assert!((gups[1] - 9.20).abs() < 0.01);
        assert!((gups[2] - 4.09).abs() < 0.01);
        assert!((gups[3] - 1.92).abs() < 0.01);
    }

    #[test]
    fn shorthand_formats() {
        let i = hsw_naive_inputs();
        assert_eq!(i.shorthand(), "{1 ‖ 2 | 2 | 4 + 1 | 9.2 + 1} cy");
        assert_eq!(i.predict().shorthand(), "{2 | 4 | 9 | 19.2} cy");
    }

    #[test]
    fn full_overlap_drops_tnol() {
        let mut i = hsw_naive_inputs();
        i.overlap = OverlapPolicy::FullOverlap;
        i.t_ol = 8.0;
        i.t_nol = 0.0;
        let p = i.predict();
        // L2: max(8, 2) = 8; L3: max(8, 2+5)=8; Mem: max(8, 2+5+10.2)=17.2
        assert_eq!(p.cycles(0), 8.0);
        assert_eq!(p.cycles(1), 8.0);
        assert_eq!(p.cycles(2), 8.0);
        assert!((p.mem_cycles() - 17.2).abs() < 1e-12);
    }
}
