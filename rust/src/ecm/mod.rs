//! The ECM (Execution–Cache–Memory) performance model — the paper's method
//! (Sect. 2), implemented end to end:
//!
//! 1. [`derive`] turns a (machine, kernel) pair into ECM *inputs*
//!    `{T_OL ∥ T_nOL | T_L1L2 | T_L2L3 + T_p | T_L3Mem + T_p}`;
//! 2. [`inputs`] holds the input/prediction types and the paper's shorthand
//!    notation formatting;
//! 3. [`scaling`] applies the multicore model: linear scaling until the
//!    memory bottleneck saturates (Fig. 1), σ_S, n_S, and saturated
//!    performance.
//!
//! Everything here is *analytic* — no simulation. The simulator ([`crate::sim`])
//! independently produces "measurements" to validate these predictions
//! against, exactly like the paper's Sect. 5.

pub mod derive;
pub mod inputs;
pub mod scaling;

pub use derive::{derive, kernel_for, paper_row, MemLevel};
pub use inputs::{DataTerm, EcmInputs, EcmPrediction};
pub use scaling::{saturation, scaling_curve, Saturation};
