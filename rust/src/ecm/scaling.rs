//! ECM multicore scaling (Sect. 2, Fig. 1): performance scales linearly
//! with cores until the shared memory bandwidth saturates.
//!
//! * σ_S = T_ECM^Mem / T_L3Mem — maximum speedup within one memory domain;
//! * n_S = ⌈σ_S⌉ — cores needed to saturate;
//! * P_S = f · W_CL / T_L3Mem — performance at saturation (per domain).
//!
//! Under cluster-on-die, cores are assigned to the chip's domains
//! round-robin (the paper's measurement protocol: "the two-core run was
//! done with one core per memory domain"), so the chip-level curve is the
//! per-domain curve stretched by the domain count.

use crate::arch::Machine;

use super::inputs::EcmInputs;

/// Saturation characteristics of a kernel on a machine.
#[derive(Clone, Debug)]
pub struct Saturation {
    /// Maximum in-domain speedup (T_ECM^Mem / T_L3Mem).
    pub sigma: f64,
    /// Cores per *memory domain* needed to saturate.
    pub n_s: u32,
    /// Cores per chip needed to saturate.
    pub n_s_chip: u32,
    /// Saturated performance per domain, GUP/s.
    pub p_sat_domain: f64,
    /// Saturated performance per chip, GUP/s.
    pub p_sat_chip: f64,
    /// Single-core in-memory performance, GUP/s.
    pub p_single: f64,
    /// True if the kernel cannot saturate the chip (n_s_chip > cores).
    pub scalable: bool,
}

/// Compute saturation characteristics from ECM inputs.
pub fn saturation(m: &Machine, inputs: &EcmInputs) -> Saturation {
    let pred = inputs.predict();
    let t_mem = pred.mem_cycles();
    let t_transfer = inputs.mem_transfer_cycles();
    let sigma = t_mem / t_transfer;
    let n_s = sigma.ceil() as u32;
    let w = inputs.updates_per_cl as f64;
    let p_sat_domain = m.freq_ghz * w / t_transfer;
    let p_single = m.freq_ghz * w / t_mem;
    let domains = m.mem.domains.max(1);
    Saturation {
        sigma,
        n_s,
        n_s_chip: n_s * domains,
        p_sat_domain,
        p_sat_chip: p_sat_domain * domains as f64,
        p_single,
        scalable: n_s * domains > m.cores,
    }
}

/// The ECM scaling *model* curve: P(n) for n = 1..=cores (chip level, GUP/s),
/// with cores spread round-robin over memory domains.
pub fn scaling_curve(m: &Machine, inputs: &EcmInputs) -> Vec<(u32, f64)> {
    let sat = saturation(m, inputs);
    let domains = m.mem.domains.max(1);
    (1..=m.cores)
        .map(|n| {
            // Cores per domain (round-robin assignment).
            let base = n / domains;
            let extra = n % domains;
            let mut p = 0.0;
            for d in 0..domains {
                let cores_here = base + u32::from(d < extra);
                let lin = cores_here as f64 * sat.p_single;
                p += lin.min(sat.p_sat_domain);
            }
            (n, p)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::*;
    use crate::ecm::derive::{paper_row, MemLevel};
    use crate::isa::Variant;
    use crate::util::units::Precision;

    #[test]
    fn hsw_naive_saturation_matches_paper() {
        // Sect. 4.1.1: n_S = ceil(19.2/9.2) = 3 per domain (6 per chip);
        // P_S = 4 GUP/s per domain, 8 per chip.
        let m = haswell();
        let i = paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let s = saturation(&m, &i);
        assert_eq!(s.n_s, 3);
        assert_eq!(s.n_s_chip, 6);
        assert!((s.p_sat_domain - 4.0).abs() < 0.01, "{}", s.p_sat_domain);
        assert!((s.p_sat_chip - 8.0).abs() < 0.02);
        assert!(!s.scalable);
    }

    #[test]
    fn bdw_naive_saturation_matches_paper() {
        // Sect. 4.1.1: n_S = ceil(26.4/8.4) = 4 per domain, 8 per chip.
        let m = broadwell();
        let i = paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let s = saturation(&m, &i);
        assert_eq!(s.n_s, 4);
        assert_eq!(s.n_s_chip, 8);
        // "prediction for the saturated performance is identical to HSW".
        assert!((s.p_sat_chip - 8.0).abs() < 0.1, "{}", s.p_sat_chip);
    }

    #[test]
    fn knc_naive_saturation_matches_paper() {
        // Sect. 4.1.2: n_S = ceil(26.8/0.8) = 34 cores, max 21.3 GUP/s.
        let m = knights_corner();
        let i = paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let s = saturation(&m, &i);
        assert_eq!(s.n_s, 34);
        assert!((s.p_sat_chip - 21.3).abs() < 0.6, "{}", s.p_sat_chip);
    }

    #[test]
    fn pwr8_naive_saturation_matches_paper() {
        // Sect. 4.1.3: n_S = ceil(22/10) = 3 cores.
        let m = power8();
        let i = paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let s = saturation(&m, &i);
        assert_eq!(s.n_s, 3);
        // Chip saturation: 73.6 GB/s over 32-update CLs of 128 B:
        // 2.926 * 32 / 10.18 = 9.2 GUP/s.
        assert!((s.p_sat_chip - 9.2).abs() < 0.1, "{}", s.p_sat_chip);
    }

    #[test]
    fn kahan_same_saturated_performance_as_naive_on_hsw() {
        // The paper's headline: Kahan comes for free in memory — same
        // saturated bandwidth-bound performance.
        let m = haswell();
        let n = paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let k = paper_row(&m, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem);
        let sn = saturation(&m, &n);
        let sk = saturation(&m, &k);
        assert_eq!(sn.p_sat_chip, sk.p_sat_chip);
        assert_eq!(saturation(&m, &k).n_s, 3);
    }

    #[test]
    fn compiler_kahan_misses_saturation_on_hsw() {
        // Sect. 5.1: "On HSW one would need more than twice the number of
        // available cores to reach saturation" (7 per domain available).
        let m = haswell();
        let i = paper_row(&m, Variant::KahanScalar, Precision::Sp, MemLevel::Mem);
        let s = saturation(&m, &i);
        assert!(s.scalable, "compiler Kahan must not saturate");
        assert!(
            s.sigma > 2.0 * 7.0,
            "sigma {} should exceed 2x cores/domain",
            s.sigma
        );
    }

    #[test]
    fn compiler_kahan_dp_just_saturates_on_bdw() {
        // Fig. 9: "the additional cores help BDW to just about saturate
        // whereas HSW misses this goal" (DP).
        // "Just about" = the full chip lands within a few percent of the
        // bandwidth ceiling on BDW, while HSW stays well below it.
        let bdw = broadwell();
        let i = paper_row(&bdw, Variant::KahanScalar, Precision::Dp, MemLevel::Mem);
        let s = saturation(&bdw, &i);
        let p_full = scaling_curve(&bdw, &i).last().unwrap().1;
        assert!(
            p_full >= 0.92 * s.p_sat_chip,
            "BDW DP compiler Kahan: {} of {} GUP/s",
            p_full,
            s.p_sat_chip
        );
        let hsw = haswell();
        let ih = paper_row(&hsw, Variant::KahanScalar, Precision::Dp, MemLevel::Mem);
        let sh = saturation(&hsw, &ih);
        let ph_full = scaling_curve(&hsw, &ih).last().unwrap().1;
        assert!(
            ph_full < 0.8 * sh.p_sat_chip,
            "HSW DP compiler Kahan: {} of {} GUP/s",
            ph_full,
            sh.p_sat_chip
        );
    }

    #[test]
    fn scaling_curve_shape() {
        let m = haswell();
        let i = paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
        let curve = scaling_curve(&m, &i);
        assert_eq!(curve.len(), m.cores as usize);
        // Monotone non-decreasing, saturating at p_sat_chip.
        let s = saturation(&m, &i);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9);
        }
        let last = curve.last().unwrap().1;
        assert!((last - s.p_sat_chip).abs() < 1e-9);
        // Two cores (one per domain) = 2x single-core performance.
        assert!((curve[1].1 - 2.0 * s.p_single).abs() < 1e-9);
    }
}
