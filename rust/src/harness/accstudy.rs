//! Accuracy study (`acc`): the paper's Sect. 1 motivation made quantitative.
//!
//! Part 1 (pure Rust, f64): error vs condition number for the algorithm zoo
//! (naive, Kahan, lane-Kahan, Neumaier, pairwise, dot2) on Ogita-Rump-Oishi
//! ill-conditioned dot products.
//!
//! Part 2 (execution backends, f64): the naive and Kahan SIMD kernels of
//! every available [`crate::runtime::backend::Backend`] evaluated on the
//! same ill-conditioned data — the native Rust backend always, the PJRT
//! artifacts when the `pjrt` feature and `make artifacts` provide them —
//! demonstrating that the *deployed* kernels inherit the compensation
//! property.

use anyhow::Result;

use crate::accuracy::{self, dots, generator, sums};
use crate::runtime::backend::{
    selected_backends, Backend, ImplStyle, KernelClass, KernelInput, KernelSpec,
};
use crate::util::plot::{render, Scale, Series};
use crate::util::rng::Rng;
use crate::util::table::Table;

use super::ctx::Ctx;
use super::output::ExperimentOutput;

fn rel_err(got: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        got.abs()
    } else {
        ((got - exact) / exact).abs().max(1e-18)
    }
}

pub fn acc(ctx: &Ctx) -> Result<ExperimentOutput> {
    let mut out = ExperimentOutput::new(
        "acc",
        "Accuracy vs condition number: naive / Kahan / lane-Kahan / Neumaier / pairwise / dot2",
    );
    let mut rng = Rng::new(ctx.seed ^ 0xACC);
    let n = if ctx.quick { 256 } else { 2048 };
    let cond_exps: Vec<f64> = if ctx.quick {
        vec![8.0, 24.0, 40.0, 56.0, 80.0]
    } else {
        (1..=14).map(|i| i as f64 * 7.0).collect()
    };

    let mut t = Table::new([
        "cond_exp2", "naive", "kahan", "kahan_lanes128", "neumaier", "pairwise", "dot2",
    ]);
    let mut series: Vec<Series> = ["naive", "kahan", "dot2"]
        .iter()
        .map(|n| Series::new(*n, vec![]))
        .collect();
    for &ce in &cond_exps {
        let (x, y, exact) = generator::ill_conditioned_dot(n, 2f64.powf(ce), &mut rng);
        let sum_xy: Vec<f64> = x.iter().zip(&y).map(|(a, b)| a * b).collect();
        let e = [
            rel_err(dots::naive_dot(&x, &y), exact),
            rel_err(dots::kahan_dot(&x, &y), exact),
            rel_err(dots::kahan_dot_lanes(&x, &y, 128), exact),
            rel_err(sums::neumaier_sum(&sum_xy), rel_err_base(&sum_xy, exact)),
            rel_err(sums::pairwise_sum(&sum_xy), rel_err_base(&sum_xy, exact)),
            rel_err(dots::dot2(&x, &y), exact),
        ];
        t.row(
            std::iter::once(format!("{ce}"))
                .chain(e.iter().map(|v| format!("{v:.3e}")))
                .collect::<Vec<_>>(),
        );
        series[0].points.push((ce, e[0].log10()));
        series[1].points.push((ce, e[1].log10()));
        series[2].points.push((ce, e[5].log10()));
    }
    out.table("errors", t);
    out.plot(
        "errors",
        render(
            &series,
            72,
            18,
            Scale::Linear,
            Scale::Linear,
            "log10(relative error) vs log2(condition number)",
        ),
    );
    out.note("Expected: naive error grows ~ eps*cond; Kahan/lane-Kahan stay ~n*eps^2*cond \
              (flat until cond ~ 1/eps); dot2 flat (doubled precision) until cond ~ 1/eps^2.");

    // ---- Part 2: the same study through the execution backends -----------
    let n2 = 4096; // matches the AOT artifact shapes so PJRT can join in
    let naive_spec = KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes);
    let kahan_spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
    let mut t2 = Table::new(["backend", "cond_exp2", "naive_simd", "kahan_simd", "ratio"]);
    let mut improved = 0;
    let mut total = 0;
    let backends = selected_backends(&ctx.artifacts_dir, |name| ctx.backend_enabled(name));
    if backends.is_empty() {
        out.note(format!(
            "Backend part skipped: selector '{}' matched no available backend.",
            ctx.backend
        ));
    }
    let had_backends = !backends.is_empty();
    // One dataset per conditioning, shared by every backend so rows at the
    // same cond_exp2 are comparable. Quantized through f32 so "exact"
    // refers to bits every backend actually sees (the PJRT dot artifacts
    // compute in f32; native f64 kernels only inherit the input rounding).
    let datasets: Vec<(f64, Vec<f64>, Vec<f64>, f64)> = [6.0, 12.0, 18.0, 24.0]
        .iter()
        .map(|&ce| {
            let (x, y, _) = generator::ill_conditioned_dot(n2, 2f64.powf(ce), &mut rng);
            let xq: Vec<f64> = x.iter().map(|&v| v as f32 as f64).collect();
            let yq: Vec<f64> = y.iter().map(|&v| v as f32 as f64).collect();
            let exact = accuracy::exact::exact_dot(&xq, &yq);
            (ce, xq, yq, exact)
        })
        .collect();
    for backend in backends {
        for (ce, xq, yq, exact) in &datasets {
            let exact = *exact;
            let input = KernelInput::Dot(xq, yq);
            let (Ok(nv), Ok(kv)) = (
                backend.run(naive_spec, &input),
                backend.run(kahan_spec, &input),
            ) else {
                continue; // backend lacks a matching kernel for this shape
            };
            let e_naive = rel_err(nv, exact);
            let e_kahan = rel_err(kv, exact);
            t2.row([
                backend.name().to_string(),
                format!("{ce}"),
                format!("{e_naive:.3e}"),
                format!("{e_kahan:.3e}"),
                format!("{:.1}", e_naive / e_kahan.max(1e-18)),
            ]);
            total += 1;
            if e_kahan <= e_naive {
                improved += 1;
            }
        }
    }
    if total > 0 {
        out.note(format!(
            "Backend SIMD kernels: Kahan matched or beat naive in {improved}/{total} cases."
        ));
        out.table("backends", t2);
    } else if had_backends {
        out.note("Backend part produced no rows: no selected backend could run the kernels.");
    }
    Ok(out)
}

/// Exact value of a plain sum used for the sum-algorithm rows (they sum the
/// rounded products, so their reference is the exact sum of those bits).
fn rel_err_base(xs: &[f64], _dot_exact: f64) -> f64 {
    accuracy::exact::exact_sum(xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acc_builds_and_shows_separation() {
        let o = acc(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        assert!(t.rows.len() >= 5);
        // At a *moderate* condition number (within Kahan's working range,
        // cond << 1/eps^2) naive error >> kahan error. At extreme cond both
        // are garbage, so sample the middle of the sweep.
        let mid = &t.rows[t.rows.len() / 2];
        let naive: f64 = mid[1].parse().unwrap();
        let kahan: f64 = mid[2].parse().unwrap();
        assert!(naive > kahan * 10.0, "naive {naive} vs kahan {kahan} (cond 2^{})", mid[0]);
    }
}
