//! Shared experiment context: parameters every experiment receives.

#[derive(Clone, Debug)]
pub struct Ctx {
    /// Artifact directory for PJRT-backed experiments.
    pub artifacts_dir: String,
    /// Base seed for the deterministic "measurement" noise.
    pub seed: u64,
    /// Reduced parameter grids (CI / smoke runs).
    pub quick: bool,
    /// Execution backend selector for host experiments:
    /// `"native"`, `"pjrt"`, or `"auto"` (= every available backend).
    pub backend: String,
}

impl Default for Ctx {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::runtime::DEFAULT_ARTIFACT_DIR.to_string(),
            seed: 1,
            quick: false,
            backend: "auto".to_string(),
        }
    }
}

impl Ctx {
    pub fn quick() -> Self {
        Self {
            quick: true,
            ..Self::default()
        }
    }

    /// Is the named backend selected by `--backend` (or by `auto`)?
    pub fn backend_enabled(&self, name: &str) -> bool {
        self.backend == "auto" || self.backend == name
    }

    /// Working-set sweep sizes honoring `quick`.
    pub fn sweep_sizes(&self, max_bytes: u64) -> Vec<u64> {
        let all = crate::sim::default_sweep_sizes(max_bytes);
        if self.quick {
            all.into_iter().step_by(6).collect()
        } else {
            all
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::GIB;

    #[test]
    fn backend_selection() {
        let mut c = Ctx::default();
        assert!(c.backend_enabled("native"));
        assert!(c.backend_enabled("pjrt"));
        c.backend = "native".into();
        assert!(c.backend_enabled("native"));
        assert!(!c.backend_enabled("pjrt"));
    }

    #[test]
    fn quick_thins_grid() {
        let full = Ctx::default().sweep_sizes(GIB);
        let quick = Ctx::quick().sweep_sizes(GIB);
        assert!(quick.len() * 4 < full.len());
        assert!(!quick.is_empty());
    }
}
