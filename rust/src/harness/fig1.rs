//! Fig. 1: the ECM multicore scaling schematic — per-core timelines showing
//! the memory-bottleneck (T_mem) and core-local (T_chip) contributions, and
//! the stall cycles that appear past the saturation point.

use anyhow::Result;

use crate::arch::haswell;
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::util::table::{fnum, Table};
use crate::util::units::Precision;

use super::ctx::Ctx;
use super::output::ExperimentOutput;

pub fn fig1(_ctx: &Ctx) -> Result<ExperimentOutput> {
    let m = haswell();
    let inputs = ecm::derive::paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
    let pred = inputs.predict();
    let t_mem = inputs.mem_transfer_cycles();
    let t_total = pred.mem_cycles();
    let t_chip = t_total - t_mem;
    let sat = ecm::scaling::saturation(&m, &inputs);

    let mut t = Table::new([
        "cores", "T_chip (cy)", "T_mem demand (cy)", "bus utilization", "stall per core (cy)",
    ]);
    let mut art = String::new();
    art.push_str(&format!(
        "ECM scaling schematic (HSW naive, per-domain): T_chip = {}, T_mem = {} cy \
         per {} updates\n\n",
        fnum(t_chip, 1),
        fnum(t_mem, 1),
        inputs.updates_per_cl
    ));
    let cores_max = 6u32;
    for n in 1..=cores_max {
        let demand = n as f64 * t_mem;
        let util = (demand / t_total).min(1.0);
        // Past saturation each core waits for its share of the bus.
        let stall = (n as f64 * t_mem - t_total).max(0.0) / n as f64;
        t.row([
            n.to_string(),
            fnum(t_chip, 1),
            fnum(demand, 1),
            format!("{:.0}%", util * 100.0),
            fnum(stall, 1),
        ]);
        // ASCII timeline: '=' chip work, 'M' memory transfer, '.' stall.
        let scale = 2.0; // chars per cycle
        let chip_chars = (t_chip / scale) as usize;
        let mem_chars = (t_mem / scale) as usize;
        let stall_chars = (stall / scale) as usize;
        art.push_str(&format!(
            "core x{n}: [{}{}{}]\n",
            "=".repeat(chip_chars),
            "M".repeat(mem_chars),
            ".".repeat(stall_chars)
        ));
    }
    art.push_str(&format!(
        "\nsaturation at ceil({} / {}) = {} cores per domain ({} per chip)\n",
        fnum(t_total, 1),
        fnum(t_mem, 1),
        sat.n_s,
        sat.n_s_chip
    ));

    let mut out = ExperimentOutput::new("fig1", "ECM multicore scaling schematic (paper Fig. 1)");
    out.table("scaling", t);
    out.plot("timeline", art);
    out.note(format!(
        "Saturation point n_s = {} per domain; hatched (.) stalls appear beyond it.",
        sat.n_s
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_with_saturation_at_3() {
        let o = fig1(&Ctx::quick()).unwrap();
        assert!(o.plots[0].1.contains("= 3 cores per domain"));
        assert_eq!(o.tables[0].1.rows.len(), 6);
    }
}
