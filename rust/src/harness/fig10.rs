//! Figs. 10a/10b: cross-architecture comparison of the manual SIMD Kahan
//! kernels — (a) single-core cycles *per update* in every hierarchy level
//! with the saturation point annotated; (b) single-core and full-chip
//! in-memory GUP/s.

use anyhow::Result;

use crate::arch::{all_machines, Machine};
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::runtime::backend::native::preferred_kahan_style;
use crate::runtime::backend::{KernelClass, KernelSpec};
use crate::runtime::hostbench::{bench_kernel, freq_ghz_with_source};
use crate::runtime::parallel::ParallelBackend;
use crate::sim::{self, MeasureOpts};
use crate::util::table::{fnum, Table};
use crate::util::units::{Precision, GIB, KIB, MIB};

use super::ctx::Ctx;
use super::output::ExperimentOutput;

/// The headline manual Kahan variant per machine.
pub fn manual_kahan(m: &Machine) -> (Variant, MemLevel) {
    match m.shorthand {
        "KNC" => (Variant::KahanSimdFma, MemLevel::Mem),
        "PWR8" => (Variant::KahanSimdFma, MemLevel::Mem),
        _ => (Variant::KahanSimdFma5, MemLevel::Mem),
    }
}

fn protocol(m: &Machine) -> MeasureOpts {
    match m.shorthand {
        "KNC" => MeasureOpts { smt: 2, untuned: false, seed: 1 },
        "PWR8" => MeasureOpts { smt: 8, untuned: false, seed: 1 },
        _ => MeasureOpts::default(),
    }
}

/// Representative working set per hierarchy level for a machine.
fn level_ws(m: &Machine) -> Vec<(String, u64)> {
    let mut v = Vec::new();
    for (i, c) in m.caches.iter().enumerate() {
        // Half the (effective) capacity: safely resident.
        let mut ws = c.capacity / 2;
        if i == m.caches.len() - 1 {
            if let Some(e) = m.calib.effective_llc_capacity {
                ws = ws.min(e / 2);
            }
        }
        v.push((c.name.to_string(), ws.max(8 * KIB)));
    }
    v.push(("Mem".to_string(), GIB.max(64 * MIB)));
    v
}

pub fn fig10a(ctx: &Ctx) -> Result<ExperimentOutput> {
    let machines = all_machines();
    let mut t = Table::new([
        "machine", "level", "cy/update (sim)", "cy/update (ECM)", "n_s (chip)",
    ]);
    let mut bars = String::from("cycles per update, manual SIMD Kahan (smaller is better)\n\n");
    for m in &machines {
        let (v, lvl) = manual_kahan(m);
        let k = ecm::derive::kernel_for(m, v, Precision::Sp, lvl);
        let inputs = ecm::derive::paper_row(m, v, Precision::Sp, lvl);
        let pred = inputs.predict();
        let sat = ecm::scaling::saturation(m, &inputs);
        let upcl = k.updates_per_cl(m.cacheline) as f64;
        let mut o = protocol(m);
        o.seed = ctx.seed;
        for (i, (name, ws)) in level_ws(m).iter().enumerate() {
            // On KNC use the level-matched kernel (the paper's protocol).
            let k_lvl = if m.shorthand == "KNC" {
                let lvl = match i {
                    0 => MemLevel::L1,
                    1 => MemLevel::L2,
                    _ => MemLevel::Mem,
                };
                ecm::derive::kernel_for(m, v, Precision::Sp, lvl)
            } else {
                k.clone()
            };
            let o_lvl = if m.shorthand == "KNC" && i >= 2 {
                MeasureOpts { smt: 4, ..o }
            } else {
                o
            };
            let pt = &sim::sweep(m, &k_lvl, &[*ws], &o_lvl)[0];
            let cy_up_sim = pt.cy_per_cl / upcl;
            let model_ix = i.min(pred.levels.len() - 1);
            let cy_up_model = pred.cycles(model_ix) / upcl;
            t.row([
                m.shorthand.to_string(),
                name.clone(),
                fnum(cy_up_sim, 3),
                fnum(cy_up_model, 3),
                if i == level_ws(m).len() - 1 {
                    sat.n_s_chip.to_string()
                } else {
                    String::new()
                },
            ]);
            bars.push_str(&format!(
                "{:<5} {:<4} {:<7} |{}\n",
                m.shorthand,
                name,
                fnum(cy_up_sim, 2),
                "#".repeat((cy_up_sim * 30.0) as usize)
            ));
        }
        bars.push('\n');
    }
    let mut out = ExperimentOutput::new(
        "fig10a",
        "Cycles per update per hierarchy level, all machines (paper Fig. 10a)",
    );
    out.table("per_level", t);
    out.plot("bars", bars);
    out.note("Expected shape: Intel chips near design specs in L1/L2 then significant drops \
              in L3/memory (worst on BDW with its large Uncore); PWR8 ~30% off its design \
              throughput in-core but flattest across levels (lock-free hierarchy).");
    Ok(out)
}

pub fn fig10b(ctx: &Ctx) -> Result<ExperimentOutput> {
    let machines = all_machines();
    let mut t = Table::new(["machine", "single-core GUP/s", "full-chip GUP/s", "chip/LLC note"]);
    let mut bars = String::from("in-memory performance, manual SIMD Kahan (bigger is better)\n\n");
    for m in &machines {
        let (v, lvl) = manual_kahan(m);
        let k = ecm::derive::kernel_for(m, v, Precision::Sp, lvl);
        let mut o = protocol(m);
        o.seed = ctx.seed;
        if m.shorthand == "KNC" {
            o.smt = 4;
        }
        let single = sim::sweep(m, &k, &[10 * GIB], &o)[0].gups;
        let scan_opts = if m.shorthand == "KNC" {
            MeasureOpts { smt: 1, untuned: false, seed: ctx.seed }
        } else {
            o
        };
        let chip = sim::corescan(m, &k, 10 * GIB, &scan_opts)
            .last()
            .unwrap()
            .1;
        t.row([
            m.shorthand.to_string(),
            fnum(single, 3),
            fnum(chip, 3),
            format!("{} cores", m.cores),
        ]);
        bars.push_str(&format!(
            "{:<5} 1-core {:>6} |{}\n",
            m.shorthand,
            fnum(single, 2),
            "#".repeat((single * 12.0) as usize)
        ));
        bars.push_str(&format!(
            "{:<5} chip   {:>6} |{}\n",
            m.shorthand,
            fnum(chip, 2),
            "#".repeat((chip * 3.0) as usize)
        ));
    }
    // The "fifth machine": the same single-thread vs full-chip comparison
    // measured live on this host with the thread-parallel native backend
    // (manual SIMD Kahan analog: the widest unrolled intrinsic rung the
    // host supports — 8×-unrolled AVX-512 or AVX2 — portable lanes
    // otherwise).
    if ctx.backend_enabled("native") {
        let (tmax, n, warm, reps) =
            super::scaleexp::live_protocol(ctx.quick, None, 1 << 18, 1 << 22);
        let (freq, src) = freq_ghz_with_source();
        let single_backend = ParallelBackend::new(1);
        let chip_backend = ParallelBackend::new(tmax);
        let style = preferred_kahan_style(single_backend.caps());
        let spec = KernelSpec::new(KernelClass::KahanDot, style);
        let single = bench_kernel(&single_backend, spec, n, warm, reps, Some(freq))?;
        let chip = bench_kernel(&chip_backend, spec, n, warm, reps, Some(freq))?;
        t.row([
            "HOST (measured)".to_string(),
            fnum(single.gups_median, 3),
            fnum(chip.gups_median, 3),
            format!("{tmax} threads, {} @ {freq:.2} GHz ({})", spec.id(), src.label()),
        ]);
    }

    let mut out = ExperimentOutput::new(
        "fig10b",
        "In-memory single-core and full-chip performance (paper Fig. 10b)",
    );
    out.table("chip", t);
    out.plot("bars", bars);
    out.note("Expected ranking: PWR8 best single-core AND best multicore chip; full-chip KNC \
              beats it by >2x on raw bandwidth.");
    out.note(
        "The HOST row is a live measurement (thread-parallel native backend), not a \
         simulation — the paper's cross-machine figure extended by the machine running \
         this reproduction.",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10b_ranking_matches_paper() {
        let o = fig10b(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        let get = |row: usize, col: usize| -> f64 { t.rows[row][col].parse().unwrap() };
        // rows: HSW, BDW, KNC, PWR8
        let (hsw_1, hsw_c) = (get(0, 1), get(0, 2));
        let (knc_c, p8_1, p8_c) = (get(2, 2), get(3, 1), get(3, 2));
        assert!(p8_1 > hsw_1, "PWR8 single-core {p8_1} > HSW {hsw_1}");
        assert!(p8_c > hsw_c, "PWR8 chip {p8_c} > HSW {hsw_c}");
        assert!(knc_c > 2.0 * p8_c, "KNC chip {knc_c} > 2x PWR8 {p8_c}");
    }

    #[test]
    fn fig10b_has_live_host_row() {
        let o = fig10b(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        let host = t
            .rows
            .iter()
            .find(|r| r[0] == "HOST (measured)")
            .expect("live host row");
        let single: f64 = host[1].parse().unwrap();
        let chip: f64 = host[2].parse().unwrap();
        assert!(single > 0.0 && chip > 0.0);
    }

    #[test]
    fn fig10a_pwr8_flattest() {
        let o = fig10a(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        // Ratio mem/L1 per machine; PWR8's must be the smallest.
        let mut ratios = std::collections::BTreeMap::new();
        let mut l1 = std::collections::BTreeMap::new();
        for r in &t.rows {
            let mach = r[0].clone();
            let v: f64 = r[2].parse().unwrap();
            l1.entry(mach.clone()).or_insert(v);
            ratios.insert(mach.clone(), v / l1[&mach]);
        }
        let p8 = ratios["PWR8"];
        for (m, r) in &ratios {
            if m != "PWR8" {
                assert!(p8 <= *r * 1.05, "PWR8 ratio {p8} vs {m} {r}");
            }
        }
    }
}
