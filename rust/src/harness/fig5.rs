//! Figs. 5a/5b: single-core cycles per CL vs. working-set size on HSW and
//! BDW, for the naive, AVX-Kahan, AVX/FMA-Kahan and compiler-Kahan kernels,
//! with the ECM predictions as horizontal reference lines.

use anyhow::Result;

use crate::arch::{broadwell, haswell, Machine};
use crate::ecm::{self, EcmPrediction, MemLevel};
use crate::isa::{KernelLoop, Variant};
use crate::sim::{self, MeasureOpts};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::{Precision, GIB};

use super::ctx::Ctx;
use super::output::ExperimentOutput;

/// One plotted series: label, kernel, protocol.
pub struct SweepSeries {
    pub label: String,
    pub kernel: KernelLoop,
    pub opts: MeasureOpts,
}

/// Shared builder for all single-core sweep figures (Figs. 5, 6, 7).
pub fn sweep_figure(
    id: &str,
    title: &str,
    m: &Machine,
    series: Vec<SweepSeries>,
    models: Vec<(String, EcmPrediction)>,
    ctx: &Ctx,
) -> Result<ExperimentOutput> {
    let sizes = ctx.sweep_sizes(GIB);
    let mut table = Table::new(
        std::iter::once("ws_bytes".to_string())
            .chain(series.iter().map(|s| s.label.clone()))
            .collect::<Vec<_>>(),
    );
    let mut results = Vec::new();
    for s in &series {
        let mut o = s.opts;
        o.seed = ctx.seed;
        results.push(sim::sweep(m, &s.kernel, &sizes, &o));
    }
    for (i, &ws) in sizes.iter().enumerate() {
        let mut row = vec![ws.to_string()];
        for r in &results {
            row.push(fnum(r[i].cy_per_cl, 3));
        }
        table.row(row);
    }

    let mut plot_series: Vec<Series> = series
        .iter()
        .zip(&results)
        .map(|(s, r)| {
            Series::new(
                s.label.clone(),
                r.iter().map(|p| (p.ws_bytes as f64, p.cy_per_cl)).collect(),
            )
        })
        .collect();
    // Model reference lines (flat per level; drawn as sparse marks).
    let mut model_table = Table::new(["model", "level", "cy_per_cl"]);
    for (label, pred) in &models {
        for (lname, cy) in &pred.levels {
            model_table.row([label.clone(), lname.clone(), fnum(*cy, 2)]);
        }
        let span: Vec<(f64, f64)> = sizes
            .iter()
            .map(|&ws| {
                // Draw the model staircase: prediction of the level the ws
                // falls into (by nominal capacities).
                let mut lvl = 0;
                for (i, c) in m.caches.iter().enumerate() {
                    if ws as f64 > 0.85 * c.capacity as f64 {
                        lvl = i + 1;
                    }
                }
                (ws as f64, pred.cycles(lvl.min(pred.levels.len() - 1)))
            })
            .collect();
        plot_series.push(Series::new(format!("ECM {label}"), span));
    }

    // Log y: the compiler-Kahan series sits ~24x above the SIMD kernels
    // (off-chart in the paper's linear plots).
    let art = render(
        &plot_series,
        72,
        24,
        Scale::Log10,
        Scale::Log2,
        &format!("{title} — cy/CL vs working set (log-log)"),
    );

    let mut out = ExperimentOutput::new(id, title);
    out.table("sweep", table);
    out.table("model", model_table);
    out.plot("sweep", art);
    Ok(out)
}

fn intel_fig(id: &str, title: &str, m: Machine, ctx: &Ctx) -> Result<ExperimentOutput> {
    let kf = |v| ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::Mem);
    let series = vec![
        SweepSeries {
            label: "naive (plain sdot)".into(),
            kernel: kf(Variant::NaiveSimd),
            opts: MeasureOpts::default(),
        },
        SweepSeries {
            label: "kahan AVX".into(),
            kernel: kf(Variant::KahanSimd),
            opts: MeasureOpts::default(),
        },
        SweepSeries {
            label: "kahan AVX/FMA".into(),
            kernel: kf(Variant::KahanSimdFma5),
            opts: MeasureOpts::default(),
        },
        SweepSeries {
            label: "kahan compiler".into(),
            kernel: kf(Variant::KahanScalar),
            opts: MeasureOpts::default(),
        },
    ];
    let models = vec![
        (
            "naive".to_string(),
            ecm::derive::paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem).predict(),
        ),
        (
            "kahan AVX".to_string(),
            ecm::derive::paper_row(&m, Variant::KahanSimd, Precision::Sp, MemLevel::Mem).predict(),
        ),
        (
            "kahan AVX/FMA".to_string(),
            ecm::derive::paper_row(&m, Variant::KahanSimdFma5, Precision::Sp, MemLevel::Mem)
                .predict(),
        ),
    ];
    let mut out = sweep_figure(id, title, &m, series, models, ctx)?;
    out.note("Expected shape (paper Sect. 5.1): AVX Kahan flat at 8 cy/CL through L1+L2, \
              identical to naive in L3/memory; naive & FMA-Kahan slightly above the L2 \
              prediction (hardware prefetcher friction); compiler Kahan flat and ~24x slower.");
    Ok(out)
}

pub fn fig5a(ctx: &Ctx) -> Result<ExperimentOutput> {
    intel_fig("fig5a", "Single-core sweep on HSW (paper Fig. 5a)", haswell(), ctx)
}

pub fn fig5b(ctx: &Ctx) -> Result<ExperimentOutput> {
    intel_fig("fig5b", "Single-core sweep on BDW (paper Fig. 5b)", broadwell(), ctx)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Row with ws nearest the requested size.
    pub(crate) fn row_near(t: &Table, ws: f64) -> Vec<String> {
        t.rows
            .iter()
            .min_by(|a, b| {
                let da = (a[0].parse::<f64>().unwrap().ln() - ws.ln()).abs();
                let db = (b[0].parse::<f64>().unwrap().ln() - ws.ln()).abs();
                da.partial_cmp(&db).unwrap()
            })
            .unwrap()
            .clone()
    }

    #[test]
    fn fig5a_shape() {
        let o = fig5a(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        assert!(t.rows.len() > 5);
        // naive column: mid-L1 point ~2 (+ small loop overhead), memory
        // point ~19-21.5 (Fig. 5a).
        let l1: f64 = row_near(t, 16.0 * 1024.0)[1].parse().unwrap();
        let last: f64 = t.rows.last().unwrap()[1].parse().unwrap();
        assert!((1.8..3.0).contains(&l1), "{l1}");
        assert!((18.0..23.0).contains(&last), "{last}");
        // kahan AVX == naive at the largest size (within 6%).
        let kn: f64 = t.rows.last().unwrap()[2].parse().unwrap();
        assert!((kn - last).abs() / last < 0.06, "kahan {kn} vs naive {last}");
    }

    #[test]
    fn fig5b_has_model_rows() {
        let o = fig5b(&Ctx::quick()).unwrap();
        let model = &o.tables[1].1;
        assert!(model.rows.iter().any(|r| r[2] == "26.4" || r[2] == "26.32"));
    }
}
