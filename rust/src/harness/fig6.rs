//! Fig. 6: KNC single-core sweep — per-level-optimized Kahan kernels
//! (Sect. 4.2.2's software-prefetch variants) plus the compiler naive code.

use anyhow::Result;

use crate::arch::knights_corner;
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::sim::MeasureOpts;
use crate::util::units::Precision;

use super::ctx::Ctx;
use super::fig5::{sweep_figure, SweepSeries};
use super::output::ExperimentOutput;

pub fn fig6(ctx: &Ctx) -> Result<ExperimentOutput> {
    let m = knights_corner();
    let kf = |v, lvl| ecm::derive::kernel_for(&m, v, Precision::Sp, lvl);
    // Paper protocol: all versions 2-SMT except the memory-optimized manual
    // kernel (4-SMT); compiler naive carries no software prefetch.
    let series = vec![
        SweepSeries {
            label: "kahan L1-kernel (2-SMT)".into(),
            kernel: kf(Variant::KahanSimdFma, MemLevel::L1),
            opts: MeasureOpts { smt: 2, untuned: false, seed: 1 },
        },
        SweepSeries {
            label: "kahan L2-kernel (2-SMT)".into(),
            kernel: kf(Variant::KahanSimdFma, MemLevel::L2),
            opts: MeasureOpts { smt: 2, untuned: false, seed: 1 },
        },
        SweepSeries {
            label: "kahan mem-kernel (4-SMT)".into(),
            kernel: kf(Variant::KahanSimdFma, MemLevel::Mem),
            opts: MeasureOpts { smt: 4, untuned: false, seed: 1 },
        },
        SweepSeries {
            label: "naive compiler (2-SMT)".into(),
            kernel: kf(Variant::NaiveSimd, MemLevel::L1),
            opts: MeasureOpts { smt: 2, untuned: true, seed: 1 },
        },
    ];
    let models = vec![
        (
            "kahan L1".to_string(),
            ecm::derive::paper_row(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::L1)
                .predict(),
        ),
        (
            "kahan L2".to_string(),
            ecm::derive::paper_row(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::L2)
                .predict(),
        ),
        (
            "kahan mem".to_string(),
            ecm::derive::paper_row(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem)
                .predict(),
        ),
    ];
    let mut out = sweep_figure(
        "fig6",
        "Single-core sweep on KNC with per-level kernels (paper Fig. 6)",
        &m,
        series,
        models,
        ctx,
    )?;
    out.note("Expected shape: the model fits only when the level-matched kernel is used \
              (L1 kernel 4 cy/CL in L1; L2 kernel 8 cy/CL in L2; mem kernel ~27.8 cy/CL \
              in memory); the unprefetched compiler code is latency-dominated in memory.");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_per_level_kernels_win_their_level() {
        let o = fig6(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        // In memory (last row): mem-kernel (col 3) beats L1-kernel (col 1)
        // and the untuned compiler code (col 4) is far worse.
        let last = t.rows.last().unwrap();
        let l1k: f64 = last[1].parse().unwrap();
        let memk: f64 = last[3].parse().unwrap();
        let compiler: f64 = last[4].parse().unwrap();
        assert!(memk < l1k, "mem kernel {memk} vs L1 kernel {l1k}");
        assert!(compiler > memk * 1.5, "compiler {compiler} vs mem kernel {memk}");
        // Mid-L1 (16 KiB): L1 kernel at ~4-5 cy/CL.
        let l1row = crate::harness::fig5::tests::row_near(t, 16.0 * 1024.0);
        let first: f64 = l1row[1].parse().unwrap();
        assert!((3.5..5.5).contains(&first), "L1 {first}");
    }
}
