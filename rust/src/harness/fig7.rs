//! Figs. 7a/7b: POWER8 single-core sweeps — SMT sensitivity of the naive
//! kernel (7a) and compiler-naive vs manual SIMD Kahan at SMT-8 (7b),
//! including the 18/22-cy eviction-overlap band of Sect. 5.3.

use anyhow::Result;

use crate::arch::power8;
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::sim::MeasureOpts;
use crate::util::table::fnum;
use crate::util::units::Precision;

use super::ctx::Ctx;
use super::fig5::{sweep_figure, SweepSeries};
use super::output::ExperimentOutput;

pub fn fig7a(ctx: &Ctx) -> Result<ExperimentOutput> {
    let m = power8();
    let k = ecm::derive::kernel_for(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
    let series = [1u32, 2, 4, 8]
        .into_iter()
        .map(|smt| SweepSeries {
            label: format!("naive SMT-{smt}"),
            kernel: k.clone(),
            opts: MeasureOpts { smt, untuned: false, seed: 1 },
        })
        .collect();
    let models = vec![(
        "naive".to_string(),
        ecm::derive::paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem).predict(),
    )];
    let mut out = sweep_figure(
        "fig7a",
        "PWR8 naive sdot under SMT-1/2/4/8 (paper Fig. 7a)",
        &m,
        series,
        models,
        ctx,
    )?;
    out.note("Expected shape: SMT-1 best in L1 (short loops penalize many threads); any \
              SMT > 1 reaches wirespeed in L2; L3 latency compensated only by SMT-8; in \
              memory SMT-4 is best and is the only setting beating the 22-cy no-overlap \
              bound; fluctuations in the 2-64 MB window.");
    Ok(out)
}

pub fn fig7b(ctx: &Ctx) -> Result<ExperimentOutput> {
    let m = power8();
    let kf = |v| ecm::derive::kernel_for(&m, v, Precision::Sp, MemLevel::Mem);
    let opts = MeasureOpts { smt: 8, untuned: false, seed: 1 };
    let series = vec![
        SweepSeries {
            label: "naive compiler (SMT-8)".into(),
            kernel: kf(Variant::NaiveSimd), // XL C generates optimal code (Sect. 4.1)
            opts,
        },
        SweepSeries {
            label: "kahan VSX manual (SMT-8)".into(),
            kernel: kf(Variant::KahanSimdFma),
            opts,
        },
    ];
    let inputs = ecm::derive::paper_row(&m, Variant::NaiveSimd, Precision::Sp, MemLevel::Mem);
    let (lo, up) = inputs.mem_bounds.unwrap_or((18.0, 22.0));
    let models = vec![
        ("naive".to_string(), inputs.predict()),
        (
            "kahan".to_string(),
            ecm::derive::paper_row(&m, Variant::KahanSimdFma, Precision::Sp, MemLevel::Mem)
                .predict(),
        ),
    ];
    let mut out = sweep_figure(
        "fig7b",
        "PWR8 naive vs manual SIMD Kahan, SMT-8 (paper Fig. 7b)",
        &m,
        series,
        models,
        ctx,
    )?;
    out.note(format!(
        "Memory-level eviction-overlap band: {} cy (full overlap) .. {} cy (none); \
         Sect. 5.3 reports only SMT-4 beats the upper bound.",
        fnum(lo, 1),
        fnum(up, 1)
    ));
    out.note("Expected shape: naive and Kahan identical in L1/L2 per the model (8 vs 16 cy \
              only in-core; both load-bound at SMT-8), Kahan for free only in memory; \
              erratic 2-64 MB window; L4 not visible.");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7a_smt_ordering() {
        let o = fig7a(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        // First row ~ L1: SMT-1 (col 1) beats SMT-8 (col 4).
        let first = &t.rows[0];
        let s1: f64 = first[1].parse().unwrap();
        let s8: f64 = first[4].parse().unwrap();
        assert!(s1 < s8, "L1 cy/CL: SMT-1 {s1} < SMT-8 {s8}");
        // Last row ~ memory: SMT-4 (col 3) is the best.
        let last = t.rows.last().unwrap();
        let m1: f64 = last[1].parse().unwrap();
        let m4: f64 = last[3].parse().unwrap();
        let m8: f64 = last[4].parse().unwrap();
        assert!(m4 < m1 && m4 <= m8, "mem: SMT-4 {m4} vs SMT-1 {m1}, SMT-8 {m8}");
    }

    #[test]
    fn fig7b_kahan_free_only_in_memory() {
        let o = fig7b(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        // Mid-L1 (32 KiB on the 64-KiB L1): past the SMT-8 short-loop
        // breakdown region so the in-core difference is visible.
        let first = crate::harness::fig5::tests::row_near(t, 32.0 * 1024.0);
        let naive_l1: f64 = first[1].parse().unwrap();
        let kahan_l1: f64 = first[2].parse().unwrap();
        assert!(
            kahan_l1 > naive_l1 * 1.5,
            "L1: kahan {kahan_l1} should cost ~2x naive {naive_l1}"
        );
        let last = t.rows.last().unwrap();
        let naive_mem: f64 = last[1].parse().unwrap();
        let kahan_mem: f64 = last[2].parse().unwrap();
        assert!(
            (kahan_mem - naive_mem).abs() / naive_mem < 0.1,
            "mem: kahan {kahan_mem} ~ naive {naive_mem}"
        );
    }
}
