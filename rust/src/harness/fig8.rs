//! Figs. 8a–8d: in-memory multicore scaling (10 GB working set) of the
//! Kahan scalar product on all four machines: compiler naive, manual SIMD
//! Kahan, compiler Kahan — "measured" (simulated) curves plus the ECM
//! scaling model.

use anyhow::Result;

use crate::arch::{broadwell, haswell, knights_corner, power8, Machine};
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::sim::{self, MeasureOpts};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::{Precision, GIB};

use super::ctx::Ctx;
use super::output::ExperimentOutput;

struct ScanSeries {
    label: String,
    variant: Variant,
    level: MemLevel,
    opts: MeasureOpts,
}

fn scaling_fig(
    id: &str,
    title: &str,
    m: &Machine,
    series: Vec<ScanSeries>,
    ctx: &Ctx,
) -> Result<ExperimentOutput> {
    let ws = 10 * GIB;
    let mut table = Table::new(
        std::iter::once("cores".to_string())
            .chain(series.iter().map(|s| s.label.clone()))
            .chain(std::iter::once("ECM model (manual kahan)".to_string()))
            .collect::<Vec<_>>(),
    );
    let mut curves = Vec::new();
    for s in &series {
        let k = ecm::derive::kernel_for(m, s.variant, Precision::Sp, s.level);
        let mut o = s.opts;
        o.seed = ctx.seed;
        curves.push(sim::corescan(m, &k, ws, &o));
    }
    // ECM model curve for the headline manual-Kahan kernel.
    let manual = series
        .iter()
        .position(|s| s.variant != Variant::KahanScalar && s.variant.is_kahan())
        .unwrap_or(0);
    let inputs =
        ecm::derive::paper_row(m, series[manual].variant, Precision::Sp, series[manual].level);
    let model = ecm::scaling::scaling_curve(m, &inputs);

    for i in 0..m.cores as usize {
        let mut row = vec![(i + 1).to_string()];
        for c in &curves {
            row.push(fnum(c[i].1, 3));
        }
        row.push(fnum(model[i].1, 3));
        table.row(row);
    }

    let mut plot_series: Vec<Series> = series
        .iter()
        .zip(&curves)
        .map(|(s, c)| {
            Series::new(
                s.label.clone(),
                c.iter().map(|&(n, p)| (n as f64, p)).collect(),
            )
        })
        .collect();
    plot_series.push(Series::new(
        "ECM model",
        model.iter().map(|&(n, p)| (n as f64, p)).collect(),
    ));
    let art = render(
        &plot_series,
        72,
        20,
        Scale::Linear,
        Scale::Linear,
        &format!("{title} — GUP/s vs cores (10 GB working set)"),
    );

    let sat = ecm::scaling::saturation(m, &inputs);
    let mut out = ExperimentOutput::new(id, title);
    out.table("scaling", table);
    out.plot("scaling", art);
    out.note(format!(
        "ECM saturation: n_s = {} per domain ({} per chip), P_sat = {} GUP/s per chip.",
        sat.n_s,
        sat.n_s_chip,
        fnum(sat.p_sat_chip, 2)
    ));
    Ok(out)
}

fn intel_series() -> Vec<ScanSeries> {
    vec![
        ScanSeries {
            label: "naive compiler".into(),
            variant: Variant::NaiveSimd,
            level: MemLevel::Mem,
            opts: MeasureOpts::default(),
        },
        ScanSeries {
            label: "kahan manual (AVX/FMA)".into(),
            variant: Variant::KahanSimdFma5,
            level: MemLevel::Mem,
            opts: MeasureOpts::default(),
        },
        ScanSeries {
            label: "kahan compiler".into(),
            variant: Variant::KahanScalar,
            level: MemLevel::Mem,
            opts: MeasureOpts::default(),
        },
    ]
}

pub fn fig8a(ctx: &Ctx) -> Result<ExperimentOutput> {
    let title = "In-memory scaling on HSW (paper Fig. 8a)";
    scaling_fig("fig8a", title, &haswell(), intel_series(), ctx)
}

pub fn fig8b(ctx: &Ctx) -> Result<ExperimentOutput> {
    let title = "In-memory scaling on BDW (paper Fig. 8b)";
    scaling_fig("fig8b", title, &broadwell(), intel_series(), ctx)
}

pub fn fig8c(ctx: &Ctx) -> Result<ExperimentOutput> {
    // Paper protocol: 1-SMT for in-memory scaling on KNC.
    scaling_fig(
        "fig8c",
        "In-memory scaling on KNC (paper Fig. 8c)",
        &knights_corner(),
        vec![
            ScanSeries {
                label: "naive compiler (no SW prefetch)".into(),
                variant: Variant::NaiveSimd,
                level: MemLevel::Mem,
                opts: MeasureOpts { smt: 1, untuned: true, seed: 1 },
            },
            ScanSeries {
                label: "kahan manual (mem kernel)".into(),
                variant: Variant::KahanSimdFma,
                level: MemLevel::Mem,
                opts: MeasureOpts { smt: 1, untuned: false, seed: 1 },
            },
            ScanSeries {
                label: "naive manual".into(),
                variant: Variant::NaiveSimd,
                level: MemLevel::Mem,
                opts: MeasureOpts { smt: 1, untuned: false, seed: 1 },
            },
        ],
        ctx,
    )
}

pub fn fig8d(ctx: &Ctx) -> Result<ExperimentOutput> {
    let opts = MeasureOpts { smt: 8, untuned: false, seed: 1 };
    scaling_fig(
        "fig8d",
        "In-memory scaling on PWR8 (paper Fig. 8d)",
        &power8(),
        vec![
            ScanSeries {
                label: "naive (SMT-8)".into(),
                variant: Variant::NaiveSimd,
                level: MemLevel::Mem,
                opts,
            },
            ScanSeries {
                label: "kahan manual VSX (SMT-8)".into(),
                variant: Variant::KahanSimdFma,
                level: MemLevel::Mem,
                opts,
            },
            ScanSeries {
                label: "kahan compiler (SMT-8)".into(),
                variant: Variant::KahanScalar,
                level: MemLevel::Mem,
                opts,
            },
        ],
        ctx,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn last_val(o: &ExperimentOutput, col: usize) -> f64 {
        o.tables[0].1.rows.last().unwrap()[col].parse().unwrap()
    }

    #[test]
    fn fig8a_kahan_free_compiler_slow() {
        let o = fig8a(&Ctx::quick()).unwrap();
        let naive = last_val(&o, 1);
        let kahan = last_val(&o, 2);
        let compiler = last_val(&o, 3);
        assert!((naive - kahan).abs() / naive < 0.05, "naive {naive} vs kahan {kahan}");
        assert!((6.8..8.3).contains(&naive), "HSW saturates ~8: {naive}");
        assert!(compiler < 0.6 * naive, "compiler kahan {compiler} must miss");
    }

    #[test]
    fn fig8c_knc_story() {
        let o = fig8c(&Ctx::quick()).unwrap();
        let compiler_naive = last_val(&o, 1);
        let kahan_manual = last_val(&o, 2);
        let naive_manual = last_val(&o, 3);
        assert!((17.0..22.5).contains(&kahan_manual), "KNC kahan {kahan_manual}");
        assert!((kahan_manual - naive_manual).abs() / naive_manual < 0.12);
        assert!(compiler_naive < 0.65 * kahan_manual, "compiler naive {compiler_naive}");
    }

    #[test]
    fn fig8d_pwr8_all_saturate() {
        let o = fig8d(&Ctx::quick()).unwrap();
        let naive = last_val(&o, 1);
        let kahan = last_val(&o, 2);
        let compiler = last_val(&o, 3);
        assert!((8.0..9.6).contains(&naive), "PWR8 ~9.2: {naive}");
        assert!((naive - kahan).abs() / naive < 0.06);
        // Sect. 5.3: the compiler Kahan (SMT-8) almost saturates.
        assert!(compiler > 0.8 * naive, "compiler {compiler} vs naive {naive}");
    }
}
