//! Fig. 9: on-chip scaling of the *compiler-generated* Kahan ddot (DP) on
//! all four machines — the "what you get without hand-tuning" picture.
//! Paper: saturates at ~4 GUP/s (HSW/BDW — BDW just about, HSW misses),
//! 10.6 GUP/s (KNC, 4-SMT), 4.5 GUP/s (PWR8, SMT-8, 5 cores).

use anyhow::Result;

use crate::arch::{all_machines, Machine};
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::sim::{self, MeasureOpts};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::{Precision, GIB};

use super::ctx::Ctx;
use super::output::ExperimentOutput;

fn protocol(m: &Machine) -> MeasureOpts {
    match m.shorthand {
        // The compiler code benefits from SMT latency hiding; the paper ran
        // KNC with 4 threads/core and PWR8 with 8 for these scans.
        "KNC" => MeasureOpts { smt: 4, untuned: true, seed: 1 },
        "PWR8" => MeasureOpts { smt: 8, untuned: false, seed: 1 },
        _ => MeasureOpts::default(),
    }
}

pub fn fig9(ctx: &Ctx) -> Result<ExperimentOutput> {
    let machines = all_machines();
    let ws = 10 * GIB;
    let max_cores = machines.iter().map(|m| m.cores).max().unwrap();

    let mut table = Table::new(
        std::iter::once("cores".to_string())
            .chain(machines.iter().map(|m| m.shorthand.to_string()))
            .collect::<Vec<_>>(),
    );
    let mut curves = Vec::new();
    for m in &machines {
        let k = ecm::derive::kernel_for(m, Variant::KahanScalar, Precision::Dp, MemLevel::Mem);
        let mut o = protocol(m);
        o.seed = ctx.seed;
        curves.push(sim::corescan(m, &k, ws, &o));
    }
    for n in 1..=max_cores as usize {
        let mut row = vec![n.to_string()];
        for c in &curves {
            row.push(c.get(n - 1).map(|p| fnum(p.1, 3)).unwrap_or_default());
        }
        table.row(row);
    }

    let plot_series: Vec<Series> = machines
        .iter()
        .zip(&curves)
        .map(|(m, c)| {
            Series::new(
                m.shorthand,
                c.iter().map(|&(n, p)| (n as f64, p)).collect(),
            )
        })
        .collect();
    let art = render(
        &plot_series,
        72,
        20,
        Scale::Linear,
        Scale::Linear,
        "Compiler-generated Kahan ddot scaling (paper Fig. 9) — GUP/s vs cores",
    );

    let mut out = ExperimentOutput::new(
        "fig9",
        "Compiler Kahan ddot (DP) on-chip scaling, all machines (paper Fig. 9)",
    );
    out.table("scaling", table);
    out.plot("scaling", art);
    out.note("Paper saturation targets: 4 GUP/s HSW/BDW (BDW just reaches it, HSW misses), \
              10.6 GUP/s KNC, 4.5 GUP/s PWR8 (at ~5 cores).");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_saturation_story() {
        let o = fig9(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        let at = |cores: usize, col: usize| -> f64 {
            t.rows[cores - 1][col].parse().unwrap_or(f64::NAN)
        };
        // DP ceiling ~4.0-4.3 GUP/s on HSW/BDW (2x32 GB/s / 16 B).
        let hsw_full = at(14, 1);
        let bdw_full = at(22, 2);
        assert!(hsw_full < 3.6, "HSW misses DP saturation: {hsw_full}");
        assert!(bdw_full > 3.4, "BDW just about saturates: {bdw_full}");
        // KNC ~10.9 GUP/s DP ceiling; the paper's compiler code saturates
        // (10.6) with 4-SMT. Our in-order core model charges more
        // round-robin issue stalls than the real chip, landing at 60-90% of
        // the ceiling — still far above every other chip's compiler result,
        // which is the figure's comparative point.
        let knc_full = at(60, 3);
        assert!((6.0..11.5).contains(&knc_full), "KNC {knc_full}");
        assert!(knc_full > 1.5 * bdw_full, "KNC must dominate Intel: {knc_full}");
        // PWR8 saturates ~4.6 by ~5 cores.
        let p8_5 = at(5, 4);
        let p8_full = at(10, 4);
        assert!(p8_5 > 0.85 * p8_full, "PWR8 saturates early: {p8_5} vs {p8_full}");
        assert!((3.8..4.8).contains(&p8_full), "PWR8 {p8_full}");
    }
}
