//! Fig. 9: on-chip scaling of the *compiler-generated* Kahan ddot (DP) on
//! all four machines — the "what you get without hand-tuning" picture.
//! Paper: saturates at ~4 GUP/s (HSW/BDW — BDW just about, HSW misses),
//! 10.6 GUP/s (KNC, 4-SMT), 4.5 GUP/s (PWR8, SMT-8, 5 cores).

use anyhow::Result;

use crate::arch::{all_machines, Machine};
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::runtime::backend::{ImplStyle, KernelClass, KernelSpec};
use crate::runtime::hostbench::{bench_scaling, freq_ghz_with_source};
use crate::sim::{self, MeasureOpts};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::{Precision, GIB};

use super::ctx::Ctx;
use super::output::ExperimentOutput;
use super::scaleexp;

fn protocol(m: &Machine) -> MeasureOpts {
    match m.shorthand {
        // The compiler code benefits from SMT latency hiding; the paper ran
        // KNC with 4 threads/core and PWR8 with 8 for these scans.
        "KNC" => MeasureOpts { smt: 4, untuned: true, seed: 1 },
        "PWR8" => MeasureOpts { smt: 8, untuned: false, seed: 1 },
        _ => MeasureOpts::default(),
    }
}

pub fn fig9(ctx: &Ctx) -> Result<ExperimentOutput> {
    let machines = all_machines();
    let ws = 10 * GIB;
    let max_cores = machines.iter().map(|m| m.cores).max().unwrap();

    let mut table = Table::new(
        std::iter::once("cores".to_string())
            .chain(machines.iter().map(|m| m.shorthand.to_string()))
            .collect::<Vec<_>>(),
    );
    let mut curves = Vec::new();
    for m in &machines {
        let k = ecm::derive::kernel_for(m, Variant::KahanScalar, Precision::Dp, MemLevel::Mem);
        let mut o = protocol(m);
        o.seed = ctx.seed;
        curves.push(sim::corescan(m, &k, ws, &o));
    }
    for n in 1..=max_cores as usize {
        let mut row = vec![n.to_string()];
        for c in &curves {
            row.push(c.get(n - 1).map(|p| fnum(p.1, 3)).unwrap_or_default());
        }
        table.row(row);
    }

    let plot_series: Vec<Series> = machines
        .iter()
        .zip(&curves)
        .map(|(m, c)| {
            Series::new(
                m.shorthand,
                c.iter().map(|&(n, p)| (n as f64, p)).collect(),
            )
        })
        .collect();
    let art = render(
        &plot_series,
        72,
        20,
        Scale::Linear,
        Scale::Linear,
        "Compiler-generated Kahan ddot scaling (paper Fig. 9) — GUP/s vs cores",
    );

    let mut out = ExperimentOutput::new(
        "fig9",
        "Compiler Kahan ddot (DP) on-chip scaling, all machines (paper Fig. 9)",
    );
    out.table("scaling", table);
    out.plot("scaling", art);
    out.note("Paper saturation targets: 4 GUP/s HSW/BDW (BDW just reaches it, HSW misses), \
              10.6 GUP/s KNC, 4.5 GUP/s PWR8 (at ~5 cores).");

    // Live counterpart: the same figure's protocol — a compiler-style
    // (scalar) Kahan ddot scaled across cores — measured on *this* host
    // via the thread-parallel native backend, with the contention model
    // anchored on the single-thread measurement.
    if ctx.backend_enabled("native") {
        // Short vector: the scalar compiler analog is ~8x slower per update
        // than the SIMD rungs; 8 threads bound the table height.
        let (tmax, n, warm, reps) = scaleexp::live_protocol(ctx.quick, Some(8), 1 << 16, 1 << 21);
        let (freq, src) = freq_ghz_with_source();
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::Scalar);
        let curve = bench_scaling(spec, n, tmax, warm, reps, Some(freq))?;
        let hm = scaleexp::host_model(freq, tmax as u32);
        let model =
            scaleexp::model_scaling_gups(&hm, spec, curve[0].1.gups_median).unwrap_or_default();
        let mut ht = Table::new(["threads", "measured GUP/s", "model GUP/s"]);
        for (t, r) in &curve {
            ht.row([
                t.to_string(),
                fnum(r.gups_median, 3),
                model
                    .get(*t - 1)
                    .map(|&(_, g)| fnum(g, 3))
                    .unwrap_or_default(),
            ]);
        }
        out.table("host_scaling", ht);
        out.note(format!(
            "Live measurement on this host ({tmax} threads, clock {freq:.2} GHz via {}): \
             kahan_dot.scalar — the compiler-variant analog — on the thread-parallel native \
             backend. Like the figure's compiler curves, a slow single-thread kernel scales \
             near-linearly because it sits far from the bandwidth ceiling.",
            src.label()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_includes_host_measurement() {
        let o = fig9(&Ctx::quick()).unwrap();
        let ht = o
            .tables
            .iter()
            .find(|(n, _)| n == "host_scaling")
            .expect("live host scaling table");
        assert!(!ht.1.rows.is_empty());
        let gups: f64 = ht.1.rows[0][1].parse().unwrap();
        assert!(gups > 0.0);
        let mut ctx = Ctx::quick();
        ctx.backend = "pjrt".into();
        let o = fig9(&ctx).unwrap();
        assert!(o.tables.iter().all(|(n, _)| n != "host_scaling"));
    }

    #[test]
    fn fig9_saturation_story() {
        let o = fig9(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        let at = |cores: usize, col: usize| -> f64 {
            t.rows[cores - 1][col].parse().unwrap_or(f64::NAN)
        };
        // DP ceiling ~4.0-4.3 GUP/s on HSW/BDW (2x32 GB/s / 16 B).
        let hsw_full = at(14, 1);
        let bdw_full = at(22, 2);
        assert!(hsw_full < 3.6, "HSW misses DP saturation: {hsw_full}");
        assert!(bdw_full > 3.4, "BDW just about saturates: {bdw_full}");
        // KNC ~10.9 GUP/s DP ceiling; the paper's compiler code saturates
        // (10.6) with 4-SMT. Our in-order core model charges more
        // round-robin issue stalls than the real chip, landing at 60-90% of
        // the ceiling — still far above every other chip's compiler result,
        // which is the figure's comparative point.
        let knc_full = at(60, 3);
        assert!((6.0..11.5).contains(&knc_full), "KNC {knc_full}");
        assert!(knc_full > 1.5 * bdw_full, "KNC must dominate Intel: {knc_full}");
        // PWR8 saturates ~4.6 by ~5 cores.
        let p8_5 = at(5, 4);
        let p8_full = at(10, 4);
        assert!(p8_5 > 0.85 * p8_full, "PWR8 saturates early: {p8_5} vs {p8_full}");
        assert!((3.8..4.8).contains(&p8_full), "PWR8 {p8_full}");
    }
}
