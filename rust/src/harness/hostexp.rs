//! `host`: the Sect. 6 "blueprint" claim exercised on a real machine — the
//! AOT-compiled Pallas kernels swept over working-set sizes on the host CPU
//! via PJRT, likwid-bench style. This is the repo's end-to-end driver: it
//! proves L1 (Pallas kernel) -> L2 (JAX graph) -> AOT -> L3 (Rust/PJRT)
//! compose on real data.

use anyhow::{Context, Result};

use crate::runtime::{bench_artifact, Executor, Manifest};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::fmt_bytes;

use super::ctx::Ctx;
use super::output::ExperimentOutput;

pub fn host(ctx: &Ctx) -> Result<ExperimentOutput> {
    let manifest = Manifest::load(&ctx.artifacts_dir)
        .with_context(|| format!("loading {}/manifest.json (run `make artifacts`)", ctx.artifacts_dir))?;
    let mut ex = Executor::new(manifest)?;
    let (warm, reps) = if ctx.quick { (1, 3) } else { (3, 9) };

    let mut out = ExperimentOutput::new(
        "host",
        "Host-CPU working-set sweep of the AOT kernels via PJRT (blueprint demo)",
    );
    let mut t = Table::new([
        "artifact", "ws", "updates", "ns (min)", "ns (median)", "GUP/s", "GB/s",
    ]);
    let mut series: Vec<Series> = Vec::new();
    let variants = [
        ("naive_opt", "f32"),
        ("naive", "f32"),
        ("kahan", "f32"),
        ("kahan_scalar", "f32"),
        ("naive_opt", "f64"),
        ("kahan", "f64"),
    ];
    for (variant, dtype) in variants {
        let names: Vec<String> = ex
            .manifest()
            .by_variant(variant, dtype)
            .iter()
            .map(|a| a.name.clone())
            .collect();
        let mut pts = Vec::new();
        for name in names {
            // Quick mode: keep the sweep small (the sequential-scan variant
            // is O(n) slow by design; large pallas grids take seconds).
            let a = ex.manifest().get(&name)?.clone();
            if ctx.quick {
                let cap = if variant == "kahan_scalar" { 5_000 } else { 300_000 };
                if a.n > cap {
                    continue;
                }
            }
            // Scale repetitions down for multi-second executions: the
            // big-artifact numbers are bandwidth-dominated and stable.
            let (warm, reps) = if a.n > 8_000_000 {
                (1, 3.min(reps))
            } else if a.n > 1_000_000 {
                (1, 5.min(reps))
            } else {
                (warm, reps)
            };
            let r = bench_artifact(&mut ex, &name, warm, reps)?;
            t.row([
                r.name.clone(),
                fmt_bytes(r.ws_bytes),
                r.updates.to_string(),
                fnum(r.ns.min, 0),
                fnum(r.ns.median, 0),
                fnum(r.gups_best, 3),
                fnum(r.gbs_best, 2),
            ]);
            pts.push((r.ws_bytes as f64, r.gups_best));
        }
        if !pts.is_empty() {
            series.push(Series::new(format!("{variant}/{dtype}"), pts));
        }
    }
    out.table("hostbench", t);
    out.plot(
        "hostbench",
        render(
            &series,
            72,
            18,
            Scale::Log10,
            Scale::Log10,
            "Host PJRT throughput (GUP/s) vs working set",
        ),
    );
    out.note(format!("PJRT platform: {}", ex.platform()));
    out.note("Interpretation: naive_opt is XLA's native dot (the compiler-optimal baseline); \
              naive/kahan are the lane-parallel Pallas kernels (interpret-mode lowering adds \
              grid-loop overhead, so compare kahan against naive, not against naive_opt); \
              kahan_scalar is the loop-carried scan — the 'compiler variant' analog, slow by \
              design exactly as in the paper.");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_runs_if_artifacts_present() {
        if Manifest::load("artifacts").is_err() {
            return;
        }
        let mut ctx = Ctx::quick();
        ctx.artifacts_dir = "artifacts".into();
        let o = host(&ctx).unwrap();
        assert!(!o.tables[0].1.rows.is_empty());
    }
}
