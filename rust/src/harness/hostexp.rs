//! `host`: the Sect. 6 "blueprint" claim exercised on a real machine — the
//! kernel ladder swept over working-set sizes on the host CPU, likwid-bench
//! style.
//!
//! The sweep runs on the native Rust backend by default (scalar → unrolled
//! → SIMD → AVX2, selected per `--backend`), so the experiment works on any
//! machine with no artifacts installed. With the `pjrt` feature enabled and
//! `make artifacts` run, the AOT-compiled Pallas kernels are swept as well,
//! proving L1 (Pallas kernel) -> L2 (JAX graph) -> AOT -> L3 (Rust/PJRT)
//! compose on real data.

use anyhow::Result;

use crate::runtime::backend::{Backend, ImplStyle, KernelClass, KernelSpec, NativeBackend};
use crate::runtime::hostbench::{bench_kernel, bench_scaling, freq_ghz_with_source};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::fmt_bytes;

use super::ctx::Ctx;
use super::output::ExperimentOutput;

/// Vector lengths for the native ladder sweep (elements, not bytes).
fn native_sizes(quick: bool) -> Vec<usize> {
    if quick {
        vec![1 << 10, 1 << 14, 1 << 18]
    } else {
        vec![1 << 10, 1 << 13, 1 << 16, 1 << 19, 1 << 22]
    }
}

fn native_part(ctx: &Ctx, out: &mut ExperimentOutput) -> Result<()> {
    let backend = NativeBackend::new();
    let (freq_val, freq_src) = freq_ghz_with_source();
    let freq = Some(freq_val);
    let (warm, reps) = if ctx.quick { (1, 3) } else { (3, 9) };

    let mut t = Table::new([
        "kernel", "n", "ws", "ns (min)", "ns (median)", "MFlop/s", "GUP/s", "GB/s", "cy/up",
    ]);
    let mut series: Vec<Series> = Vec::new();
    for spec in backend.kernels() {
        // Keep the table focused on the paper's dot ladder plus the SIMD
        // sum; the full ladder stays reachable via `bench-native`.
        if spec.class == KernelClass::KahanSum && spec.style != ImplStyle::SimdLanes {
            continue;
        }
        let mut pts = Vec::new();
        for &n in &native_sizes(ctx.quick) {
            let r = bench_kernel(&backend, spec, n, warm, reps, freq)?;
            t.row([
                r.kernel.clone(),
                r.n.to_string(),
                fmt_bytes(r.ws_bytes),
                fnum(r.ns.min, 0),
                fnum(r.ns.median, 0),
                fnum(r.mflops_best, 0),
                fnum(r.gups_best, 3),
                fnum(r.gbs_best, 2),
                r.cycles_per_update
                    .map(|c| fnum(c, 2))
                    .unwrap_or_else(|| "-".to_string()),
            ]);
            pts.push((r.ws_bytes as f64, r.gups_best));
        }
        series.push(Series::new(spec.id(), pts));
    }
    out.table("native", t);
    out.plot(
        "native",
        render(
            &series,
            72,
            18,
            Scale::Log10,
            Scale::Log10,
            "Native backend throughput (GUP/s) vs working set",
        ),
    );
    out.note(format!(
        "Native backend: avx2 = {}, avx512 = {}, clock estimate = {freq_val:.2} GHz (via {}).",
        backend.has_avx2(),
        backend.has_avx512(),
        freq_src.label()
    ));
    out.note(
        "Interpretation: in cache the Kahan ladder costs up to ~4x the naive dot \
         (extra compensation arithmetic); as the working set moves to memory the \
         unrolled+SIMD Kahan variants converge to the naive throughput — the \
         paper's 'Kahan for free' claim, now measured natively on this host. The \
         avx2u2/u4/u8 (and avx512*) rungs carry 2/4/8 independent vector \
         accumulator chains: compare them against the single-accumulator avx2 \
         rung to see the latency→throughput transition of the paper's Fig. 1 \
         ladder in cache-resident working sets.",
    );
    Ok(())
}

/// Thread-scaling teaser: the SIMD naive/Kahan pair across worker counts on
/// the parallel native backend. The full model-vs-measurement overlay lives
/// in the `scale` experiment and the `bench-scale` subcommand; this table
/// makes the host experiment self-contained on the multicore claim.
fn scaling_part(ctx: &Ctx, out: &mut ExperimentOutput) -> Result<()> {
    let (tmax, n, warm, reps) =
        super::scaleexp::live_protocol(ctx.quick, Some(8), 1 << 16, 1 << 21);
    let (freq, _) = freq_ghz_with_source();
    let mut t = Table::new(["kernel", "threads", "MFlop/s", "GUP/s", "speedup vs T=1"]);
    for spec in [
        KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes),
        KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes),
    ] {
        let curve = bench_scaling(spec, n, tmax, warm, reps, Some(freq))?;
        let p1 = curve[0].1.gups_median;
        for (tc, r) in &curve {
            t.row([
                r.kernel.clone(),
                tc.to_string(),
                fnum(r.mflops_median, 0),
                fnum(r.gups_median, 3),
                fnum(r.gups_median / p1, 2),
            ]);
        }
    }
    out.table("threads", t);
    out.note(
        "Thread scaling: per-thread slices are cache-line aligned and partial sums combine \
         through a deterministic compensated tree (same result every run at a fixed thread \
         count). See the `scale` experiment / `bench-scale` for the model overlay.",
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn pjrt_part(ctx: &Ctx, out: &mut ExperimentOutput) -> Result<()> {
    use crate::runtime::{bench_artifact, Executor, Manifest};

    let manifest = match Manifest::load(&ctx.artifacts_dir) {
        Ok(m) => m,
        Err(e) => {
            out.note(format!(
                "PJRT sweep skipped: {e} (run `make artifacts` to build the AOT kernels)."
            ));
            return Ok(());
        }
    };
    let mut ex = match Executor::new(manifest) {
        Ok(ex) => ex,
        Err(e) => {
            out.note(format!("PJRT sweep skipped: {e:#}."));
            return Ok(());
        }
    };
    let (warm, reps) = if ctx.quick { (1, 3) } else { (3, 9) };

    let mut t = Table::new([
        "artifact", "ws", "updates", "ns (min)", "ns (median)", "GUP/s", "GB/s",
    ]);
    let mut series: Vec<Series> = Vec::new();
    let variants = [
        ("naive_opt", "f32"),
        ("naive", "f32"),
        ("kahan", "f32"),
        ("kahan_scalar", "f32"),
        ("naive_opt", "f64"),
        ("kahan", "f64"),
    ];
    for (variant, dtype) in variants {
        let names: Vec<String> = ex
            .manifest()
            .by_variant(variant, dtype)
            .iter()
            .map(|a| a.name.clone())
            .collect();
        let mut pts = Vec::new();
        for name in names {
            // Quick mode: keep the sweep small (the sequential-scan variant
            // is O(n) slow by design; large pallas grids take seconds).
            let a = ex.manifest().get(&name)?.clone();
            if ctx.quick {
                let cap = if variant == "kahan_scalar" { 5_000 } else { 300_000 };
                if a.n > cap {
                    continue;
                }
            }
            // Scale repetitions down for multi-second executions: the
            // big-artifact numbers are bandwidth-dominated and stable.
            let (warm, reps) = if a.n > 8_000_000 {
                (1, 3.min(reps))
            } else if a.n > 1_000_000 {
                (1, 5.min(reps))
            } else {
                (warm, reps)
            };
            let r = bench_artifact(&mut ex, &name, warm, reps)?;
            t.row([
                r.name.clone(),
                fmt_bytes(r.ws_bytes),
                r.updates.to_string(),
                fnum(r.ns.min, 0),
                fnum(r.ns.median, 0),
                fnum(r.gups_best, 3),
                fnum(r.gbs_best, 2),
            ]);
            pts.push((r.ws_bytes as f64, r.gups_best));
        }
        if !pts.is_empty() {
            series.push(Series::new(format!("{variant}/{dtype}"), pts));
        }
    }
    out.table("hostbench", t);
    out.plot(
        "hostbench",
        render(
            &series,
            72,
            18,
            Scale::Log10,
            Scale::Log10,
            "Host PJRT throughput (GUP/s) vs working set",
        ),
    );
    out.note(format!("PJRT platform: {}", ex.platform()));
    out.note(
        "Interpretation: naive_opt is XLA's native dot (the compiler-optimal baseline); \
         naive/kahan are the lane-parallel Pallas kernels (interpret-mode lowering adds \
         grid-loop overhead, so compare kahan against naive, not against naive_opt); \
         kahan_scalar is the loop-carried scan — the 'compiler variant' analog, slow by \
         design exactly as in the paper.",
    );
    Ok(())
}

pub fn host(ctx: &Ctx) -> Result<ExperimentOutput> {
    let mut out = ExperimentOutput::new(
        "host",
        "Host-CPU kernel-ladder sweep (native backend; PJRT artifacts when enabled)",
    );
    if ctx.backend_enabled("native") {
        native_part(ctx, &mut out)?;
        scaling_part(ctx, &mut out)?;
    }
    #[cfg(feature = "pjrt")]
    if ctx.backend_enabled("pjrt") {
        // A broken artifact must not discard the native sweep already in
        // `out`; every PJRT failure mode degrades to a skip note.
        if let Err(e) = pjrt_part(ctx, &mut out) {
            out.note(format!("PJRT sweep aborted: {e:#}."));
        }
    }
    #[cfg(not(feature = "pjrt"))]
    if ctx.backend == "pjrt" {
        out.note("PJRT backend requested but this build lacks the `pjrt` feature.");
    }
    if out.tables.is_empty() && out.notes.is_empty() {
        out.note(format!(
            "backend selector '{}' matched no available backend (expected native|pjrt|auto).",
            ctx.backend
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_runs_without_artifacts() {
        let o = host(&Ctx::quick()).unwrap();
        assert!(!o.tables.is_empty());
        let (name, t) = &o.tables[0];
        assert_eq!(name, "native");
        assert!(!t.rows.is_empty());
        // Naive and Kahan ladders both appear.
        assert!(t.rows.iter().any(|r| r[0].starts_with("naive_dot")));
        assert!(t.rows.iter().any(|r| r[0].starts_with("kahan_dot")));
    }

    #[test]
    fn host_native_only_backend_selector() {
        let mut ctx = Ctx::quick();
        ctx.backend = "native".into();
        let o = host(&ctx).unwrap();
        assert!(!o.tables.is_empty());
        // Native backend yields the ladder sweep plus the thread-scaling
        // table, and nothing PJRT-flavored.
        assert!(o.tables.iter().all(|(n, _)| n == "native" || n == "threads"));
        assert!(o.tables.iter().any(|(n, _)| n == "threads"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn host_pjrt_only_without_runtime_yields_notes_not_tables() {
        let mut ctx = Ctx::quick();
        ctx.backend = "pjrt".into();
        let o = host(&ctx).unwrap();
        assert!(o.tables.is_empty(), "native sweep ran despite --backend pjrt");
        assert!(!o.notes.is_empty());
    }
}
