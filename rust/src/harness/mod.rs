//! Figure/table regeneration harness: one module per paper artifact
//! (DESIGN.md §5's experiment index). Each experiment produces CSV tables,
//! an ASCII plot preview and markdown notes into `out/<id>/`.

pub mod accstudy;
pub mod ctx;
pub mod fig1;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod hostexp;
pub mod output;
pub mod scaleexp;
pub mod serveexp;
pub mod tables;

pub use ctx::Ctx;
pub use output::ExperimentOutput;
