//! Experiment output container and the `out/<id>/` writer.

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::table::Table;

#[derive(Clone, Debug, Default)]
pub struct ExperimentOutput {
    pub id: String,
    pub title: String,
    /// Named data tables (written as `<name>.csv`).
    pub tables: Vec<(String, Table)>,
    /// Named ASCII plots (written as `<name>.txt`, echoed to terminal).
    pub plots: Vec<(String, String)>,
    /// Free-form findings, written into `summary.md`.
    pub notes: Vec<String>,
}

impl ExperimentOutput {
    pub fn new(id: &str, title: &str) -> Self {
        Self {
            id: id.to_string(),
            title: title.to_string(),
            ..Self::default()
        }
    }

    pub fn table(&mut self, name: &str, t: Table) -> &mut Self {
        self.tables.push((name.to_string(), t));
        self
    }

    pub fn plot(&mut self, name: &str, p: String) -> &mut Self {
        self.plots.push((name.to_string(), p));
        self
    }

    pub fn note(&mut self, n: impl Into<String>) -> &mut Self {
        self.notes.push(n.into());
        self
    }

    /// Write everything under `<out_dir>/<id>/`.
    pub fn write(&self, out_dir: &str) -> Result<()> {
        let dir = Path::new(out_dir).join(&self.id);
        fs::create_dir_all(&dir).with_context(|| format!("mkdir {}", dir.display()))?;
        let mut summary = format!("# {} — {}\n\n", self.id, self.title);
        for (name, t) in &self.tables {
            fs::write(dir.join(format!("{name}.csv")), t.to_csv())?;
            summary.push_str(&format!("## {name}\n\n{}\n", t.to_markdown()));
        }
        for (name, p) in &self.plots {
            fs::write(dir.join(format!("{name}.txt")), p)?;
            summary.push_str(&format!("## {name}\n\n```\n{p}```\n\n"));
        }
        if !self.notes.is_empty() {
            summary.push_str("## Notes\n\n");
            for n in &self.notes {
                summary.push_str(&format!("- {n}\n"));
            }
        }
        fs::write(dir.join("summary.md"), summary)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_files() {
        let tmp = std::env::temp_dir().join(format!("kahan-ecm-test-{}", std::process::id()));
        let mut t = Table::new(["x", "y"]);
        t.row(["1", "2"]);
        let mut o = ExperimentOutput::new("t1", "test experiment");
        o.table("data", t).plot("p", "ascii\n".into()).note("a note");
        o.write(tmp.to_str().unwrap()).unwrap();
        let base = tmp.join("t1");
        assert!(base.join("data.csv").exists());
        assert!(base.join("p.txt").exists());
        let md = std::fs::read_to_string(base.join("summary.md")).unwrap();
        assert!(md.contains("test experiment"));
        assert!(md.contains("a note"));
        std::fs::remove_dir_all(&tmp).ok();
    }
}
