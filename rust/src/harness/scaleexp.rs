//! `scale`: thread-parallel saturation measured on the real host and
//! overlaid with the model prediction — the live analog of the paper's
//! Figs. 8/9 (and the validation loop for `sim::multicore`).
//!
//! Protocol: the SIMD naive and Kahan dot kernels run on the
//! [`ParallelBackend`](crate::runtime::parallel::ParallelBackend) for
//! T = 1..=T_max threads at an in-memory working set. The single-thread
//! measurement anchors the contention model
//! ([`sim::multicore::scaling_curve`]), exactly the paper's method: the
//! model predicts *where* the shared bandwidth saturates, measurement
//! supplies the starting point. The paper's claim reproduces live when the
//! Kahan curve saturates at the same thread count as the naive curve.
//!
//! The model-mapping helpers ([`variant_for`], [`host_model`],
//! [`model_scaling_gups`], [`model_sweep`]) are shared with the
//! `bench-scale` CLI subcommand, which emits the same comparison as
//! machine-readable JSON (`BENCH_scaling.json`).

use anyhow::Result;

use crate::arch::{self, Machine};
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::runtime::backend::{ImplStyle, KernelClass, KernelSpec};
use crate::runtime::hostbench::{bench_scaling, freq_ghz_with_source};
use crate::runtime::parallel::ThreadPool;
use crate::sim::{self, MeasureOpts, MeasuredPoint};
use crate::util::plot::{render, Scale, Series};
use crate::util::table::{fnum, Table};
use crate::util::units::Precision;

use super::ctx::Ctx;
use super::output::ExperimentOutput;

/// The ISA-model variant corresponding to a native kernel spec, for the
/// model overlay (`None` when the model has no analog — the sum kernels).
/// The native kernels are f64, so pair with [`Precision::Dp`]. Every
/// explicit-intrinsic tier (AVX2 and AVX-512, single- or multi-
/// accumulator) maps to the fused-product model variant; the in-memory
/// model curves are transfer-bound, so unroll width does not change the
/// analog.
pub fn variant_for(spec: KernelSpec) -> Option<Variant> {
    match (spec.class, spec.style) {
        (KernelClass::NaiveDot, _) => Some(Variant::NaiveSimd),
        (KernelClass::KahanDot, ImplStyle::Scalar) => Some(Variant::KahanScalar),
        (KernelClass::KahanDot, s) if s.uses_fma() => Some(Variant::KahanSimdFma),
        (KernelClass::KahanDot, _) => Some(Variant::KahanSimd),
        (KernelClass::KahanSum, _) => None,
    }
}

/// The generic HOST machine model pinned to the measured clock and the
/// thread count under test (so model curves span the same T axis as the
/// measurement).
pub fn host_model(freq_ghz: f64, cores: u32) -> Machine {
    let mut m = arch::presets::host();
    m.freq_ghz = freq_ghz;
    m.cores = cores.max(1);
    m
}

/// Model-predicted chip-scaling curve in GUP/s for `spec`, anchored on the
/// measured single-thread in-memory performance `p1_gups` (the paper's
/// Fig. 8 protocol). `None` when the model has no analog for the kernel.
pub fn model_scaling_gups(m: &Machine, spec: KernelSpec, p1_gups: f64) -> Option<Vec<(u32, f64)>> {
    let v = variant_for(spec)?;
    let k = ecm::derive::kernel_for(m, v, Precision::Dp, MemLevel::Mem);
    Some(sim::multicore::scaling_curve(m, &k, p1_gups, &MeasureOpts::default()))
}

/// Model-predicted single-core working-set sweep for `spec`: per size, the
/// fully composed prediction (core ∥ data, via [`sim::sweep`]) plus the raw
/// data-transfer term from [`sim::data_cycles`] in cy/CL — the two ECM
/// quantities a measured sweep point decomposes into.
pub fn model_sweep(
    m: &Machine,
    spec: KernelSpec,
    sizes: &[u64],
) -> Option<Vec<(MeasuredPoint, f64)>> {
    let v = variant_for(spec)?;
    let k = ecm::derive::kernel_for(m, v, Precision::Dp, MemLevel::Mem);
    let opts = MeasureOpts::default();
    let pts = sim::sweep(m, &k, sizes, &opts);
    Some(
        pts.into_iter()
            .zip(sizes)
            .map(|(p, &ws)| {
                let d = sim::data_cycles(m, &k, ws, &opts);
                (p, d.cycles)
            })
            .collect(),
    )
}

/// GUP/s -> MFlop/s for a kernel class.
pub fn gups_to_mflops(class: KernelClass, gups: f64) -> f64 {
    gups * class.flops_per_update() as f64 * 1000.0
}

/// The two headline kernels of the saturation story.
fn scaling_specs() -> [KernelSpec; 2] {
    [
        KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes),
        KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes),
    ]
}

/// The shared live-measurement protocol: `(threads_max, n, warmup, reps)`
/// for quick vs full mode. One definition for every harness site so the
/// tuples cannot drift apart; only the vector length and full-mode thread
/// cap vary per site (`n` scales with how slow the kernel under test is —
/// the scalar compiler analog needs a shorter vector, `cap_full` bounds
/// table height for tables printed per thread count).
pub fn live_protocol(
    quick: bool,
    cap_full: Option<usize>,
    n_quick: usize,
    n_full: usize,
) -> (usize, usize, usize, usize) {
    let avail = ThreadPool::available();
    if quick {
        (avail.min(2), n_quick, 1, 3)
    } else {
        (cap_full.map_or(avail, |c| avail.min(c)), n_full, 2, 5)
    }
}

pub fn scale(ctx: &Ctx) -> Result<ExperimentOutput> {
    if !ctx.backend_enabled("native") {
        let mut out = ExperimentOutput::new(
            "scale",
            "Measured thread-scaling of the native kernels vs the contention model (live Fig. 8)",
        );
        out.note(format!(
            "skipped: thread-scaling measures the native backend, but --backend is '{}'.",
            ctx.backend
        ));
        return Ok(out);
    }
    let (tmax, n, warm, reps) = live_protocol(ctx.quick, None, 1 << 18, 1 << 22);
    let (freq, freq_src) = freq_ghz_with_source();
    let m = host_model(freq, tmax as u32);

    let mut out = ExperimentOutput::new(
        "scale",
        "Measured thread-scaling of the native kernels vs the contention model (live Fig. 8)",
    );
    let mut t = Table::new([
        "threads",
        "naive MFlop/s",
        "naive model",
        "kahan MFlop/s",
        "kahan model",
    ]);
    let mut series: Vec<Series> = Vec::new();
    let mut columns: Vec<(Vec<f64>, Vec<f64>)> = Vec::new(); // (measured, model) per kernel

    for spec in scaling_specs() {
        let curve = bench_scaling(spec, n, tmax, warm, reps, Some(freq))?;
        let p1 = curve[0].1.gups_median;
        let model = model_scaling_gups(&m, spec, p1)
            .expect("dot kernels always have a model analog");
        let measured: Vec<f64> = curve
            .iter()
            .map(|(_, r)| gups_to_mflops(spec.class, r.gups_median))
            .collect();
        let modeled: Vec<f64> = model
            .iter()
            .take(tmax)
            .map(|&(_, g)| gups_to_mflops(spec.class, g))
            .collect();
        series.push(Series::new(
            format!("{} meas", spec.id()),
            measured
                .iter()
                .enumerate()
                .map(|(i, &v)| ((i + 1) as f64, v))
                .collect(),
        ));
        series.push(Series::new(
            format!("{} model", spec.id()),
            modeled
                .iter()
                .enumerate()
                .map(|(i, &v)| ((i + 1) as f64, v))
                .collect(),
        ));
        columns.push((measured, modeled));
    }
    for i in 0..tmax {
        t.row([
            (i + 1).to_string(),
            fnum(columns[0].0[i], 0),
            fnum(columns[0].1[i], 0),
            fnum(columns[1].0[i], 0),
            fnum(columns[1].1[i], 0),
        ]);
    }
    out.table("scaling", t);
    out.plot(
        "scaling",
        render(
            &series,
            72,
            18,
            Scale::Linear,
            Scale::Linear,
            "Measured vs modeled thread scaling (MFlop/s)",
        ),
    );
    out.note(format!(
        "Host model: {} threads, clock {freq:.2} GHz ({}); model bandwidth ceiling \
         {} GB/s (generic HOST preset — retune `arch::presets::host` for your machine).",
        tmax,
        freq_src.label(),
        m.mem.sustained_bw_gbs
    ));
    out.note(
        "Reading the overlay: the model curve is linear in T until the memory-bandwidth \
         ceiling (the ECM T_L3Mem term) truncates it; the paper's claim is that the SIMD \
         Kahan curve saturates at the same T as the naive curve — compensation arithmetic \
         hides behind the same data transfers. Each measured point runs the kernel on \
         cache-line-aligned per-thread slices with a deterministic compensated reduction.",
    );
    out.note(
        "Measurement hygiene: under `run all` the experiment pool runs jobs concurrently, \
         so other experiments contend for the same cores and distort these timings. For \
         publishable numbers run `kahan-ecm run scale` standalone (or `--jobs 1`), or use \
         `bench-scale`, which always runs exclusively.",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_mapping() {
        assert_eq!(
            variant_for(KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes)),
            Some(Variant::NaiveSimd)
        );
        assert_eq!(
            variant_for(KernelSpec::new(KernelClass::KahanDot, ImplStyle::Scalar)),
            Some(Variant::KahanScalar)
        );
        assert_eq!(
            variant_for(KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdAvx2)),
            Some(Variant::KahanSimdFma)
        );
        // The whole unrolled/AVX-512 tier shares the fused-product analog.
        for style in [ImplStyle::Avx2U2, ImplStyle::Avx2U8, ImplStyle::Avx512U8] {
            assert_eq!(
                variant_for(KernelSpec::new(KernelClass::KahanDot, style)),
                Some(Variant::KahanSimdFma),
                "{style:?}"
            );
        }
        assert_eq!(
            variant_for(KernelSpec::new(KernelClass::KahanSum, ImplStyle::SimdLanes)),
            None
        );
    }

    #[test]
    fn model_curve_spans_thread_axis_and_is_monotone() {
        let m = host_model(3.0, 6);
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let curve = model_scaling_gups(&m, spec, 0.5).unwrap();
        assert_eq!(curve.len(), 6);
        assert_eq!(curve[0].0, 1);
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "{curve:?}");
        }
    }

    #[test]
    fn model_sweep_terms_are_consistent() {
        let m = host_model(3.0, 4);
        let spec = KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes);
        let sizes = [16 * 1024u64, 1 << 30];
        let pts = model_sweep(&m, spec, &sizes).unwrap();
        assert_eq!(pts.len(), 2);
        // The data term can never exceed the composed total, and deep in
        // memory it dominates.
        for (p, data_cy) in &pts {
            assert!(*data_cy <= p.cy_per_cl + 1e-9);
        }
        assert!(pts[1].1 > pts[0].1, "memory data term must dominate L1's");
    }

    #[test]
    fn scale_respects_backend_selector() {
        let mut ctx = Ctx::quick();
        ctx.backend = "pjrt".into();
        let o = scale(&ctx).unwrap();
        assert!(o.tables.is_empty(), "no native-mt run under --backend pjrt");
        assert!(o.notes.iter().any(|n| n.contains("skipped")));
    }

    #[test]
    fn live_protocol_shapes() {
        let (t_q, n_q, w_q, r_q) = live_protocol(true, Some(8), 1 << 16, 1 << 21);
        assert!(t_q <= 2 && n_q == 1 << 16 && w_q == 1 && r_q == 3);
        let (t_f, n_f, w_f, r_f) = live_protocol(false, Some(8), 1 << 16, 1 << 21);
        assert!(t_f <= 8 && n_f == 1 << 21 && w_f == 2 && r_f == 5);
        let (t_uncapped, ..) = live_protocol(false, None, 1, 1);
        assert_eq!(t_uncapped, ThreadPool::available());
    }

    #[test]
    fn scale_experiment_runs_quick() {
        let o = scale(&Ctx::quick()).unwrap();
        assert_eq!(o.tables.len(), 1);
        let t = &o.tables[0].1;
        assert!(!t.rows.is_empty());
        // Measured and modeled columns are positive numbers.
        for row in &t.rows {
            for cell in &row[1..] {
                let v: f64 = cell.parse().unwrap();
                assert!(v > 0.0, "{row:?}");
            }
        }
        // The model column is anchored on the T=1 measurement, but the
        // generic HOST preset's bandwidth ceiling may clip it well below a
        // cache-resident quick-mode measurement — only pin a loose band.
        let meas: f64 = t.rows[0][3].parse().unwrap();
        let model: f64 = t.rows[0][4].parse().unwrap();
        assert!(model > 0.02 * meas && model < 50.0 * meas, "{meas} vs {model}");
    }
}
