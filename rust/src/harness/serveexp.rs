//! `serve`: the serving layer exercised live on this host — a closed-loop
//! run, a derived virtual-clock open-loop run, and a real-time run through
//! the asynchronous submission queue, all over the default request
//! mixture, plus an inline bit-parity audit of the scheduling contract
//! (sync and async).
//!
//! This is the "millions of users" counterpart to `scale`: where `scale`
//! measures how one request saturates the chip, `serve` measures how the
//! [`crate::serve::DotService`] turns the same kernels and pool into
//! request throughput — fused small requests, sharded large ones, with the
//! batching-vs-sharding crossover taken from the saturation model. The
//! parity audit re-derives the contract the property tests pin: batched
//! execution must be bit-identical to submitting each request alone.

use anyhow::{ensure, Result};

use crate::runtime::backend::native::{preferred_kahan_style, SimdCaps};
use crate::runtime::backend::KernelInput;
use crate::runtime::hostbench::freq_ghz_with_source;
use crate::runtime::parallel::ThreadPool;
use crate::serve::{
    default_mix, run_load_async, run_load_with, AsyncDotService, AsyncOptions, DotService,
    LoadMode, LoadReport, OperandPool, ServeConfig, SharedInput, ThresholdMode,
};
use crate::util::rng::Rng;
use crate::util::table::{fnum, Table};

use super::ctx::Ctx;
use super::output::ExperimentOutput;

/// Bit-parity audit: a fixed batch straddling an explicit threshold must
/// serve identically batched, one-by-one, *and* through the asynchronous
/// submission queue (the scheduling layer — synchronous or pipelined —
/// may not fork the numerics).
fn parity_audit(threads: usize, seed: u64) -> Result<()> {
    let cfg = ServeConfig {
        threads,
        style: preferred_kahan_style(SimdCaps::detect()),
        compensated: true,
        shard_threshold: ThresholdMode::Fixed(4096),
        freq_ghz: 3.0,
        verify_hit_rate: 0.0,
    };
    let service = DotService::new(cfg.clone())?;
    let mut rng = Rng::new(seed);
    let data: Vec<(Vec<f64>, Vec<f64>)> = [63usize, 1024, 4095, 4096, 9000]
        .iter()
        .map(|&n| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        })
        .collect();
    let inputs: Vec<KernelInput<'_>> = data.iter().map(|(x, y)| KernelInput::Dot(x, y)).collect();
    let batched = service.submit_batch(&inputs)?;
    for (input, b) in inputs.iter().zip(&batched) {
        let alone = service.submit(input)?;
        ensure!(
            alone.value.to_bits() == b.value.to_bits(),
            "serving parity violated at n = {}: batched {} vs unbatched {}",
            b.n,
            b.value,
            alone.value
        );
    }
    let pipeline = AsyncDotService::new(cfg, AsyncOptions::default())?;
    let shared: Vec<SharedInput> = data.iter().map(|(x, y)| SharedInput::dot(x, y)).collect();
    let queued = pipeline.submit_wait(&shared)?;
    for (b, q) in batched.iter().zip(&queued) {
        ensure!(
            b.value.to_bits() == q.value.to_bits(),
            "async serving parity violated at n = {}: sync {} vs queued {}",
            b.n,
            b.value,
            q.value
        );
    }
    Ok(())
}

fn report_row(t: &mut Table, mode: &str, r: &LoadReport) {
    t.row([
        mode.to_string(),
        r.requests.to_string(),
        r.fused.to_string(),
        r.sharded.to_string(),
        fnum(r.latency_p50_ns / 1e3, 1),
        fnum(r.latency_p99_ns / 1e3, 1),
        fnum(r.mflops, 0),
        fnum(r.reqs_per_s, 0),
    ]);
}

pub fn serve(ctx: &Ctx) -> Result<ExperimentOutput> {
    let title = "Batching/sharding dot-product serving layer under live load";
    let mut out = ExperimentOutput::new("serve", title);
    if !ctx.backend_enabled("native") {
        out.note(format!(
            "skipped: the serving layer runs on the native backend, but --backend is '{}'.",
            ctx.backend
        ));
        return Ok(out);
    }
    let avail = ThreadPool::available();
    let (threads, requests, batch) = if ctx.quick {
        (avail.min(2), 128, 16)
    } else {
        (avail, 2048, 64)
    };
    parity_audit(threads, ctx.seed)?;

    let (freq, freq_src) = freq_ghz_with_source();
    let cfg = ServeConfig {
        threads,
        style: preferred_kahan_style(SimdCaps::detect()),
        compensated: true,
        shard_threshold: ThresholdMode::Model,
        freq_ghz: freq,
        verify_hit_rate: 0.0,
    };
    let service = DotService::new(cfg.clone())?;
    let mix = default_mix(ctx.quick);
    // One operand pool for both runs: first-touched once by the service's
    // own workers, reused by the closed- and open-loop passes.
    let operands = OperandPool::generate(&mix, ctx.seed, service.pool());
    let closed = run_load_with(
        &service,
        &mix,
        &operands,
        requests,
        batch,
        LoadMode::Closed,
        ctx.seed,
    )?;
    // Open loop at ~70% of the closed-loop service rate: loaded but not
    // saturated, so the latency tail shows queueing without blowing up.
    let rate = (closed.reqs_per_s * 0.7).max(1.0);
    let open_mode = LoadMode::Open { rate_rps: rate };
    let open = run_load_with(
        &service,
        &mix,
        &operands,
        requests,
        batch,
        open_mode,
        ctx.seed,
    )?;
    // The same request stream through the asynchronous pipeline, at the
    // same offered load, measured on the real clock (queueing included).
    let pipeline = AsyncDotService::new(cfg, AsyncOptions::default())?;
    let pipeline_ops = OperandPool::generate(&mix, ctx.seed, pipeline.service().pool());
    let queued = run_load_async(&pipeline, &mix, &pipeline_ops, requests, rate, ctx.seed)?;
    ensure!(
        queued.load.checksum.to_bits() == closed.checksum.to_bits(),
        "async pipeline checksum diverged from the synchronous path"
    );

    let mut t = Table::new([
        "mode", "requests", "fused", "sharded", "p50 us", "p99 us", "MFlop/s", "req/s",
    ]);
    report_row(&mut t, "closed", &closed);
    report_row(&mut t, "open", &open);
    report_row(&mut t, "open-queued", &queued.load);
    out.table("serving", t);

    let mut mt = Table::new(["n", "weight", "path"]);
    for e in &mix {
        let path = if e.n >= service.shard_threshold() {
            "sharded"
        } else {
            "fused"
        };
        mt.row([e.n.to_string(), fnum(e.weight, 2), path.to_string()]);
    }
    out.table("mixture", mt);

    out.note(format!(
        "Service: {} worker(s), rung {}, compensated dot; shard crossover at n >= {} \
         ({}, clock {freq:.2} GHz via {}). Open-loop arrival rate: {} req/s.",
        service.threads(),
        service.dot_spec(),
        service.shard_threshold(),
        service.threshold_source().label(),
        freq_src.label(),
        fnum(rate, 0)
    ));
    out.note(format!(
        "Async pipeline (open-queued row): bounded submission queue (depth {}), {}-us \
         batching window, arrival batches overlap in-flight sharded tails; queue high-water \
         {} / {} and pool utilization {} over the run. Latency here is measured from each \
         request's scheduled arrival to ticket completion on the real clock.",
        queued.queue_depth,
        fnum(queued.batch_window_us, 0),
        queued.max_queue_depth,
        queued.queue_depth,
        fnum(queued.pool_utilization, 2)
    ));
    out.note(
        "Scheduling contract audited inline: every request returns bit-identical results \
         batched, unbatched and through the async submission queue at this thread count \
         (fused = serial kernel on one worker, sharded = the measurement path's partition + \
         compensated tree reduction). The crossover comes from the multicore saturation \
         model: once the chip's bandwidth saturates, extra workers buy more as request \
         parallelism than as shard parallelism, so only requests past the model's pay-off \
         length are split.",
    );
    out.note(
        "Measurement hygiene: under `run all` other experiments contend for the same \
         cores; for publishable serving numbers use `kahan-ecm serve-bench`, which runs \
         exclusively and writes BENCH_serving.json.",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_experiment_runs_quick() {
        let o = serve(&Ctx::quick()).unwrap();
        assert_eq!(o.tables.len(), 2);
        let (name, t) = &o.tables[0];
        assert_eq!(name, "serving");
        assert_eq!(t.rows.len(), 3, "closed + open + open-queued rows");
        for row in &t.rows {
            let requests: f64 = row[1].parse().unwrap();
            let fused: f64 = row[2].parse().unwrap();
            let sharded: f64 = row[3].parse().unwrap();
            assert_eq!(fused + sharded, requests, "{row:?}");
            let mflops: f64 = row[6].parse().unwrap();
            assert!(mflops > 0.0, "{row:?}");
        }
    }

    #[test]
    fn serve_respects_backend_selector() {
        let mut ctx = Ctx::quick();
        ctx.backend = "pjrt".into();
        let o = serve(&ctx).unwrap();
        assert!(o.tables.is_empty());
        assert!(o.notes.iter().any(|n| n.contains("skipped")));
    }

    #[test]
    fn parity_audit_passes_here() {
        parity_audit(3, 123).unwrap();
    }
}
