//! `table1` (testbed specification, Table I) and `ecm-inputs` (the Sect. 4
//! model inputs and predictions for every kernel x machine, incl. Eqs. 1–3).

use anyhow::Result;

use crate::arch::{all_machines, Machine};
use crate::ecm::{self, MemLevel};
use crate::isa::Variant;
use crate::util::table::{fnum, Table};
use crate::util::units::{fmt_bytes, Precision};

use super::ctx::Ctx;
use super::output::ExperimentOutput;

pub fn table1(_ctx: &Ctx) -> Result<ExperimentOutput> {
    let machines = all_machines();
    let mut t = Table::new(
        ["Microarchitecture", "HSW", "BDW", "KNC", "PWR8"],
    );
    let cell = |f: &dyn Fn(&Machine) -> String| -> Vec<String> {
        machines.iter().map(|m| f(m)).collect()
    };
    let mut row = |label: &str, f: &dyn Fn(&Machine) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(cell(f));
        t.row(cells);
    };
    row("Chip model", &|m| m.name.to_string());
    row("Nominal CPU clock", &|m| format!("{} GHz", m.freq_ghz));
    row("Cores/threads", &|m| format!("{}/{}", m.cores, m.cores * m.smt_ways));
    row("Max. SIMD width", &|m| format!("{} B", m.simd_bytes));
    row("# of SIMD registers", &|m| m.simd_regs.to_string());
    row("Cache line", &|m| format!("{} B", m.cacheline));
    row("LOAD/STORE per cy", &|m| {
        format!(
            "{}/{}",
            m.throughput(&crate::isa::OpClass::Load),
            m.throughput(&crate::isa::OpClass::Store)
        )
    });
    row("ADD/MUL/FMA per cy", &|m| {
        format!(
            "{}/{}/{}",
            m.throughput(&crate::isa::OpClass::Add),
            m.throughput(&crate::isa::OpClass::Mul),
            m.throughput(&crate::isa::OpClass::Fma)
        )
    });
    row("Caches", &|m| {
        m.caches
            .iter()
            .map(|c| format!("{} {}", fmt_bytes(c.capacity), c.name))
            .collect::<Vec<_>>()
            .join(", ")
    });
    row("L2-L1 bandwidth", &|m| format!("{} B/cy", m.caches[1].bw_bytes_per_cy));
    row("Meas. load BW (domain)", &|m| {
        format!("{} GB/s x{}", m.mem.sustained_bw_gbs, m.mem.domains)
    });
    row("Mem cycles per CL", &|m| fnum(m.mem_cycles_per_cl(), 2));
    row("Latency penalty T_p", &|m| fnum(m.mem.latency_penalty, 1));
    row("Overlap policy", &|m| format!("{:?}", m.overlap));

    let mut out = ExperimentOutput::new("table1", "Testbed specification (paper Table I)");
    out.note(
        "All quantities are model inputs; derived columns (mem cycles/CL) cross-check \
         Sect. 4 arithmetic.",
    );
    out.table("table1", t);
    Ok(out)
}

/// Variants tabulated per machine (paper Sect. 4 kernels).
pub fn variants_for(m: &Machine) -> Vec<(Variant, MemLevel, &'static str)> {
    match m.shorthand {
        "KNC" => vec![
            (Variant::NaiveSimd, MemLevel::Mem, "naive"),
            (Variant::KahanSimdFma, MemLevel::L1, "kahan (L1 kernel)"),
            (Variant::KahanSimdFma, MemLevel::L2, "kahan (L2 kernel)"),
            (Variant::KahanSimdFma, MemLevel::Mem, "kahan (mem kernel)"),
            (Variant::KahanScalar, MemLevel::Mem, "kahan compiler"),
        ],
        "PWR8" => vec![
            (Variant::NaiveSimd, MemLevel::Mem, "naive"),
            (Variant::KahanSimdFma, MemLevel::Mem, "kahan VSX"),
            (Variant::KahanScalar, MemLevel::Mem, "kahan compiler"),
        ],
        _ => vec![
            (Variant::NaiveSimd, MemLevel::Mem, "naive"),
            (Variant::KahanSimd, MemLevel::Mem, "kahan AVX"),
            (Variant::KahanSimdFma, MemLevel::Mem, "kahan AVX/FMA (4-way)"),
            (Variant::KahanSimdFma5, MemLevel::Mem, "kahan AVX/FMA (5-way)"),
            (Variant::KahanScalar, MemLevel::Mem, "kahan compiler"),
        ],
    }
}

pub fn ecm_inputs(_ctx: &Ctx) -> Result<ExperimentOutput> {
    let mut t = Table::new([
        "machine", "kernel", "prec", "ECM input", "prediction", "GUP/s per level",
        "sigma", "n_s (domain)", "n_s (chip)", "P_sat chip",
    ]);
    for m in all_machines() {
        for prec in [Precision::Sp, Precision::Dp] {
            for (v, lvl, label) in variants_for(&m) {
                let inputs = ecm::derive::paper_row(&m, v, prec, lvl);
                let pred = inputs.predict();
                let sat = ecm::scaling::saturation(&m, &inputs);
                let gups: Vec<String> = pred
                    .performance_gups(m.freq_ghz)
                    .into_iter()
                    .map(|(_, g)| fnum(g, 2))
                    .collect();
                t.row([
                    m.shorthand.to_string(),
                    label.to_string(),
                    prec.label().to_string(),
                    inputs.shorthand(),
                    pred.shorthand(),
                    gups.join(" / "),
                    fnum(sat.sigma, 2),
                    sat.n_s.to_string(),
                    sat.n_s_chip.to_string(),
                    fnum(sat.p_sat_chip, 2),
                ]);
            }
        }
    }
    let mut out = ExperimentOutput::new(
        "ecm-inputs",
        "ECM model inputs & predictions for every kernel x machine (Sect. 4, Eqs. 1-3)",
    );
    out.note(
        "Pinned against the paper: HSW naive {1 ‖ 2 | 2 | 4 + 1 | 9.2 + 1} -> \
         {2 | 4 | 9 | 19.2}; Kahan AVX {8 | 8 | 9 | 19.2}; KNC naive {2 | 6 | 26.8}; \
         PWR8 naive {8 | 8 | 12 | 22}; the 4-way FMA Kahan T_OL is the paper's \
         hand-schedule value 8 (RecMII alone gives 7).",
    );
    out.table("ecm_inputs", t);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_builds() {
        let o = table1(&Ctx::quick()).unwrap();
        assert_eq!(o.tables.len(), 1);
        let t = &o.tables[0].1;
        assert_eq!(t.header.len(), 5);
        assert!(t.rows.len() >= 10);
    }

    #[test]
    fn ecm_inputs_covers_all_machines() {
        let o = ecm_inputs(&Ctx::quick()).unwrap();
        let t = &o.tables[0].1;
        // 4 machines x 2 precisions x (3..5 variants).
        assert!(t.rows.len() >= 4 * 2 * 3);
        let text = t.to_csv();
        assert!(text.contains("{1 ‖ 2 | 2 | 4 + 1 | 9.2 + 1} cy"));
        assert!(text.contains("{2 | 4 | 9 | 19.2} cy"));
    }
}
