//! Instructions and operand registers of the abstract kernel IR.

/// Virtual register id. The IR is in SSA-like form *except* for explicitly
/// carried accumulators (sum/compensation registers), which are deliberately
/// rewritten each iteration to express the loop-carried recurrence.
pub type Reg = u32;

/// Functional class of an instruction — what execution resource it needs.
/// SUB shares the Add class (same pipeline on every covered chip); FMS
/// (fused multiply-subtract) shares Fma.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpClass {
    /// L1 -> register vector load.
    Load,
    /// Register -> L1 vector store (unused by dot, present for generality).
    Store,
    /// Vector add/subtract.
    Add,
    /// Vector multiply.
    Mul,
    /// Fused multiply-add/subtract.
    Fma,
    /// Register move (eliminated by renaming on OoO cores; occupies an issue
    /// slot on in-order cores).
    Mov,
    /// Software prefetch targeting the given cache level (1 = into L1,
    /// 2 = into L2, ...). Occupies an issue/retire slot but no data port.
    Prefetch(u8),
    /// Scalar ALU helper (loop counter, address increment) — modeled only
    /// for in-order cores where it competes for issue slots.
    Scalar,
}

impl OpClass {
    /// Is this an arithmetic (floating-point) operation for ECM's T_OL?
    pub fn is_arith(&self) -> bool {
        matches!(self, OpClass::Add | OpClass::Mul | OpClass::Fma)
    }

    /// Does this op move data between L1 and registers (ECM's T_nOL class
    /// on architectures with non-overlapping L1 transfers)?
    pub fn is_l1_transfer(&self) -> bool {
        matches!(self, OpClass::Load | OpClass::Store)
    }

    pub fn label(&self) -> String {
        match self {
            OpClass::Load => "LOAD".into(),
            OpClass::Store => "STORE".into(),
            OpClass::Add => "ADD".into(),
            OpClass::Mul => "MUL".into(),
            OpClass::Fma => "FMA".into(),
            OpClass::Mov => "MOV".into(),
            OpClass::Prefetch(l) => format!("PF.L{l}"),
            OpClass::Scalar => "SCALAR".into(),
        }
    }
}

/// One instruction of a kernel loop body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Instr {
    pub op: OpClass,
    /// Destination register (None for stores/prefetches).
    pub dst: Option<Reg>,
    /// Source registers. Loads have no register sources (address arithmetic
    /// is implicit / strength-reduced, as in the paper's asm kernels).
    pub srcs: Vec<Reg>,
}

impl Instr {
    pub fn new(op: OpClass, dst: Option<Reg>, srcs: Vec<Reg>) -> Self {
        Self { op, dst, srcs }
    }

    pub fn load(dst: Reg) -> Self {
        Self::new(OpClass::Load, Some(dst), vec![])
    }

    pub fn add(dst: Reg, a: Reg, b: Reg) -> Self {
        Self::new(OpClass::Add, Some(dst), vec![a, b])
    }

    pub fn mul(dst: Reg, a: Reg, b: Reg) -> Self {
        Self::new(OpClass::Mul, Some(dst), vec![a, b])
    }

    /// dst = a * b (+/-) c — all fused forms share the class.
    pub fn fma(dst: Reg, a: Reg, b: Reg, c: Reg) -> Self {
        Self::new(OpClass::Fma, Some(dst), vec![a, b, c])
    }

    pub fn prefetch(level: u8) -> Self {
        Self::new(OpClass::Prefetch(level), None, vec![])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_predicates() {
        assert!(OpClass::Add.is_arith());
        assert!(OpClass::Fma.is_arith());
        assert!(!OpClass::Load.is_arith());
        assert!(OpClass::Load.is_l1_transfer());
        assert!(OpClass::Store.is_l1_transfer());
        assert!(!OpClass::Prefetch(2).is_l1_transfer());
    }

    #[test]
    fn constructors() {
        let i = Instr::fma(3, 0, 1, 2);
        assert_eq!(i.op, OpClass::Fma);
        assert_eq!(i.dst, Some(3));
        assert_eq!(i.srcs, vec![0, 1, 2]);
        assert_eq!(Instr::prefetch(2).op, OpClass::Prefetch(2));
    }
}
