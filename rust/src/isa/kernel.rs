//! `KernelLoop`: one steady-state loop body plus the work/traffic metadata
//! the ECM model and the simulator need.

use super::instr::{Instr, OpClass, Reg};
use crate::util::units::Precision;

/// A kernel loop body in steady state.
#[derive(Clone, Debug)]
pub struct KernelLoop {
    pub name: String,
    /// Instructions of one loop body, in program order.
    pub body: Vec<Instr>,
    /// Scalar loop iterations ("updates") one body performs.
    pub updates_per_body: u64,
    /// Number of distinct load streams (2 for dot: a[] and b[]).
    pub streams: u32,
    /// Element precision.
    pub prec: Precision,
    /// Useful flops per scalar update (2 naive, 5 Kahan).
    pub flops_per_update: u64,
    /// True if the body is SIMD-vectorized (affects in-order issue modeling
    /// and the "compiler variant" bookkeeping only).
    pub simd: bool,
}

impl KernelLoop {
    /// Bytes loaded from L1 per scalar update (all streams).
    pub fn bytes_per_update(&self) -> u64 {
        self.streams as u64 * self.prec.bytes()
    }

    /// Scalar updates per cache line of a machine with the given line size
    /// (one "CL of work" touches one line of *each* stream).
    pub fn updates_per_cl(&self, cacheline: u64) -> u64 {
        cacheline / self.prec.bytes()
    }

    /// Cache lines (per stream) touched by one loop body.
    pub fn cachelines_per_body(&self, cacheline: u64) -> f64 {
        self.updates_per_body as f64 * self.prec.bytes() as f64 / cacheline as f64
    }

    /// Count instructions of one class in the body.
    pub fn count(&self, pred: impl Fn(&OpClass) -> bool) -> usize {
        self.body.iter().filter(|i| pred(&i.op)).count()
    }

    /// Registers that carry a loop-level recurrence: read at some position
    /// before their (first) write in the same body. Reading such a register
    /// at the start of iteration *i+1* depends on its last write in
    /// iteration *i*.
    pub fn carried_regs(&self) -> Vec<Reg> {
        let mut carried = Vec::new();
        let mut written: Vec<Reg> = Vec::new();
        for ins in &self.body {
            for &s in &ins.srcs {
                if !written.contains(&s) && !carried.contains(&s) {
                    carried.push(s);
                }
            }
            if let Some(d) = ins.dst {
                if !written.contains(&d) {
                    written.push(d);
                }
            }
        }
        // Only registers that are also written in the body actually carry a
        // recurrence; read-only registers (constants like the FMA-trick's
        // vector of 1.0s) are invariant.
        carried
            .into_iter()
            .filter(|r| self.body.iter().any(|i| i.dst == Some(*r)))
            .collect()
    }

    /// Position of the last write to `reg` in the body, if any.
    pub fn last_write(&self, reg: Reg) -> Option<usize> {
        self.body.iter().rposition(|i| i.dst == Some(reg))
    }

    /// Basic well-formedness: every arithmetic source is either written in
    /// the body, carried, or a declared constant (never-written register).
    pub fn validate(&self) -> Result<(), String> {
        if self.body.is_empty() {
            return Err(format!("kernel '{}' has an empty body", self.name));
        }
        if self.updates_per_body == 0 {
            return Err(format!("kernel '{}' does no work", self.name));
        }
        for (pos, ins) in self.body.iter().enumerate() {
            match ins.op {
                OpClass::Load => {
                    if ins.dst.is_none() {
                        return Err(format!("{}[{}]: load without dst", self.name, pos));
                    }
                }
                OpClass::Add | OpClass::Mul => {
                    if ins.srcs.len() != 2 || ins.dst.is_none() {
                        return Err(format!("{}[{}]: malformed 2-op arith", self.name, pos));
                    }
                }
                OpClass::Fma => {
                    if ins.srcs.len() != 3 || ins.dst.is_none() {
                        return Err(format!("{}[{}]: malformed fma", self.name, pos));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::instr::Instr;

    /// Tiny kahan-like body: regs 0=a,1=b loaded; 2=c carried; 3=s carried.
    fn toy() -> KernelLoop {
        KernelLoop {
            name: "toy".into(),
            body: vec![
                Instr::load(0),
                Instr::load(1),
                Instr::mul(4, 0, 1),     // p = a*b
                Instr::add(5, 4, 2),     // y = p - c   (c carried)
                Instr::add(3, 3, 5),     // s = s + y   (s carried)
                Instr::add(6, 3, 3),     // tmp = t - s (structure only)
                Instr::add(2, 6, 5),     // c = tmp - y
            ],
            updates_per_body: 8,
            streams: 2,
            prec: Precision::Sp,
            flops_per_update: 5,
            simd: true,
        }
    }

    #[test]
    fn carried_registers_found() {
        let k = toy();
        let carried = k.carried_regs();
        assert!(carried.contains(&2), "c is carried: {carried:?}");
        assert!(carried.contains(&3), "s is carried: {carried:?}");
        assert!(!carried.contains(&0), "loads are not carried");
        assert!(!carried.contains(&4), "intra-body temp is not carried");
    }

    #[test]
    fn traffic_metadata() {
        let k = toy();
        assert_eq!(k.bytes_per_update(), 8); // 2 streams x 4 B
        assert_eq!(k.updates_per_cl(64), 16);
        assert_eq!(k.updates_per_cl(128), 32);
        assert_eq!(k.cachelines_per_body(64), 0.5);
    }

    #[test]
    fn counts() {
        let k = toy();
        assert_eq!(k.count(|o| o.is_arith()), 5);
        assert_eq!(k.count(|o| *o == OpClass::Load), 2);
    }

    #[test]
    fn last_write_position() {
        let k = toy();
        assert_eq!(k.last_write(2), Some(6));
        assert_eq!(k.last_write(0), Some(0));
        assert_eq!(k.last_write(99), None);
    }

    #[test]
    fn validate_ok_and_errors() {
        assert!(toy().validate().is_ok());
        let mut bad = toy();
        bad.body.clear();
        assert!(bad.validate().is_err());
        let mut bad2 = toy();
        bad2.body[2] = Instr::new(OpClass::Mul, Some(4), vec![0]);
        assert!(bad2.validate().is_err());
    }

    #[test]
    fn constant_register_not_carried() {
        // FMA-trick: register 7 holds 1.0 and is read but never written.
        let mut k = toy();
        k.body.push(Instr::fma(8, 3, 7, 5));
        assert!(!k.carried_regs().contains(&7));
    }
}
