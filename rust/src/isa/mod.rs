//! Abstract kernel IR: the paper's hand-written assembly kernels (Figs. 2–4)
//! expressed as machine-independent instruction sequences with explicit
//! dependencies, so both the ECM analyzer and the core simulator can reason
//! about throughput *and* latency chains.
//!
//! The IR models one *loop body*; loop-carried dependencies arise from
//! registers that are read before they are (re)written within the body
//! (e.g. the Kahan compensation term `c` and partial sum `s`).

pub mod instr;
pub mod kernel;
pub mod variants;

pub use instr::{Instr, OpClass, Reg};
pub use kernel::KernelLoop;
pub use variants::{build, Variant};
