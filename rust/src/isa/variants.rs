//! The paper's kernel zoo (Sect. 4) as IR builders.
//!
//! | Variant          | paper's kernel                                        |
//! |------------------|-------------------------------------------------------|
//! | `NaiveSimd`      | Fig. 2a, unrolled + SIMD (compiler -O3 gets this)     |
//! | `KahanScalar`    | Fig. 2b as a compiler must emit it (no reassociation) |
//! | `KahanSimd`      | AVX/VSX Kahan without FMA: 1 MUL + 4 ADD per chunk    |
//! | `KahanSimdFma`   | Fig. 3 left: FMS for `y`, 4-way unrolled              |
//! | `KahanSimdFma5`  | Fig. 3 right: 5-way + FMA-as-ADD trick (T_OL = 6.4)   |
//!
//! KNC's per-level kernels (Fig. 4) are `KahanSimd` / `NaiveSimd` bodies
//! decorated with software-prefetch instructions via `prefetches`.

use super::instr::{Instr, OpClass, Reg};
use super::kernel::KernelLoop;
use crate::util::units::Precision;

/// Kernel variant selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    NaiveSimd,
    KahanScalar,
    KahanSimd,
    KahanSimdFma,
    KahanSimdFma5,
}

impl Variant {
    pub fn label(&self) -> &'static str {
        match self {
            Variant::NaiveSimd => "naive",
            Variant::KahanScalar => "kahan-scalar",
            Variant::KahanSimd => "kahan-simd",
            Variant::KahanSimdFma => "kahan-fma",
            Variant::KahanSimdFma5 => "kahan-fma5",
        }
    }

    pub fn is_kahan(&self) -> bool {
        !matches!(self, Variant::NaiveSimd)
    }

    pub fn flops_per_update(&self) -> u64 {
        match self {
            Variant::NaiveSimd => 2,
            // 1 MUL + 4 ADD/SUB — the paper's "one update = five flops".
            _ => 5,
        }
    }
}

/// Instruction-ordering discipline of the emitted body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sched {
    /// Stage-major interleave (all chains' stage s before stage s+1): the
    /// hand-scheduled order of Fig. 3; sufficient for out-of-order cores.
    StageMajor,
    /// Fig. 4's software-pipelined order for in-order cores: loads are
    /// hoisted across the loop edge (they feed the *next* iteration's
    /// arithmetic) and interleaved between arithmetic ops so each (U, V)
    /// issue pair carries one arith + one load/prefetch.
    SoftwarePipelined,
}

/// Build a kernel loop body (stage-major schedule).
///
/// * `simd_elems` — vector lanes per instruction (8 for AVX SP, 16 for IMCI
///   SP, 4 for VSX SP, 1 for the scalar/compiler variant).
/// * `unroll` — number of independent accumulator chains (SIMD chunks) per
///   body; the paper's "n-way unrolling".
/// * `prefetches` — software-prefetch decoration: (target level, count per
///   body), for the KNC per-level kernels.
pub fn build(
    v: Variant,
    simd_elems: u32,
    unroll: u32,
    prec: Precision,
    prefetches: &[(u8, u32)],
) -> KernelLoop {
    build_sched(v, simd_elems, unroll, prec, prefetches, Sched::StageMajor)
}

/// [`build`] with an explicit ordering discipline.
pub fn build_sched(
    v: Variant,
    simd_elems: u32,
    unroll: u32,
    prec: Precision,
    prefetches: &[(u8, u32)],
    sched: Sched,
) -> KernelLoop {
    assert!(simd_elems >= 1 && unroll >= 1);
    let mut next: Reg = 0;
    let mut fresh = || {
        let r = next;
        next += 1;
        r
    };

    // Constant register of 1.0s for the FMA-as-ADD trick (never written).
    let one = fresh();

    // Build per-chain instruction sequences, then emit them STAGE-MAJOR
    // (all loads of every chain, then stage 1 of every chain, ...). This is
    // the software-pipelined order of the paper's hand-written assembly
    // (Figs. 3 and 4): on out-of-order cores the order is irrelevant (the
    // scheduler sees the whole window), but on the in-order KNC the
    // stage-interleaved order is exactly what keeps the U-pipe busy.
    let mut chains: Vec<Vec<Instr>> = Vec::with_capacity(unroll as usize);
    match v {
        Variant::NaiveSimd => {
            for _ in 0..unroll {
                // Independent partial-sum chain: acc = fma(a, b, acc).
                let acc = fresh();
                let a = fresh();
                let b = fresh();
                chains.push(vec![
                    Instr::load(a),
                    Instr::load(b),
                    Instr::fma(acc, a, b, acc),
                ]);
            }
        }
        Variant::KahanScalar | Variant::KahanSimd | Variant::KahanSimdFma
        | Variant::KahanSimdFma5 => {
            for _ in 0..unroll {
                // One (s, c) Kahan chain.
                let s = fresh();
                let c = fresh();
                let a = fresh();
                let b = fresh();
                let mut ops = vec![Instr::load(a), Instr::load(b)];
                let y = fresh();
                match v {
                    Variant::KahanScalar | Variant::KahanSimd => {
                        // p = a*b ; y = p - c
                        let p = fresh();
                        ops.push(Instr::mul(p, a, b));
                        ops.push(Instr::add(y, p, c));
                    }
                    _ => {
                        // y = a*b - c (vfmsub231)
                        ops.push(Instr::fma(y, a, b, c));
                    }
                }
                // t = s + y (plain ADD, or FMA(s,1,y) in the 5-way trick)
                let t = fresh();
                match v {
                    Variant::KahanSimdFma5 => ops.push(Instr::fma(t, s, one, y)),
                    _ => ops.push(Instr::add(t, s, y)),
                }
                // tmp = t - s ; c = tmp - y ; s = t
                let tmp = fresh();
                ops.push(Instr::add(tmp, t, s));
                ops.push(Instr::add(c, tmp, y));
                ops.push(Instr::new(OpClass::Mov, Some(s), vec![t]));
                chains.push(ops);
            }
        }
    }

    let stages = chains.iter().map(|c| c.len()).max().unwrap();
    let mut body = Vec::new();
    match sched {
        Sched::StageMajor => {
            for stage in 0..stages {
                for chain in &chains {
                    if let Some(ins) = chain.get(stage) {
                        body.push(ins.clone());
                    }
                }
            }
            for &(level, count) in prefetches {
                for _ in 0..count {
                    body.push(Instr::prefetch(level));
                }
            }
        }
        Sched::SoftwarePipelined => {
            // Split each chain into loads and non-loads; emit arithmetic
            // stage-major with one load/prefetch spliced after each arith
            // op (KNC's (U, V) pairing). Loads come *after* their consumers
            // in program order, i.e. they produce for the next iteration —
            // the dependency extractor classifies them as carried, exactly
            // modeling Fig. 4's `vmovaps zmm0, [rsi+rax*8+64]  # next iter`.
            let mut fills: Vec<Instr> = Vec::new();
            let mut arith: Vec<Vec<Instr>> = vec![Vec::new(); chains.len()];
            for (k, chain) in chains.iter().enumerate() {
                for ins in chain {
                    if ins.op == OpClass::Load {
                        fills.push(ins.clone());
                    } else {
                        arith[k].push(ins.clone());
                    }
                }
            }
            for &(level, count) in prefetches {
                for _ in 0..count {
                    fills.push(Instr::prefetch(level));
                }
            }
            let astages = arith.iter().map(|c| c.len()).max().unwrap();
            let mut fill_iter = fills.into_iter();
            for stage in 0..astages {
                for chain in &arith {
                    if let Some(ins) = chain.get(stage) {
                        body.push(ins.clone());
                        if let Some(f) = fill_iter.next() {
                            body.push(f);
                        }
                    }
                }
            }
            body.extend(fill_iter);
        }
    }

    // Unambiguous name: precision, prefetch decoration and schedule
    // discipline are part of the identity (the core-sim memo keys on it).
    let mut name = format!(
        "{}x{}u{}{}",
        v.label(),
        simd_elems,
        unroll,
        if prec == Precision::Dp { "-dp" } else { "" }
    );
    for &(level, count) in prefetches {
        name.push_str(&format!("+pf{level}x{count}"));
    }
    if sched == Sched::SoftwarePipelined {
        name.push_str("-swp");
    }
    KernelLoop {
        name,
        body,
        updates_per_body: simd_elems as u64 * unroll as u64,
        streams: 2,
        prec,
        flops_per_update: v.flops_per_update(),
        simd: simd_elems > 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_counts_match_paper_hsw() {
        // HSW Sect. 4.1.1: per CL (16 SP iters = 2 AVX chunks): 4 loads,
        // 2 FMAs. Build 2 chunks (1 CL) and check.
        let k = build(Variant::NaiveSimd, 8, 2, Precision::Sp, &[]);
        k.validate().unwrap();
        assert_eq!(k.updates_per_body, 16);
        assert_eq!(k.count(|o| *o == OpClass::Load), 4);
        assert_eq!(k.count(|o| *o == OpClass::Fma), 2);
        assert_eq!(k.cachelines_per_body(64), 1.0);
    }

    #[test]
    fn kahan_avx_counts_match_paper() {
        // Sect. 4.2.1: per unit of work (8 scalar iters = 1 AVX chunk):
        // 1 MUL (of 2 per CL) and 4 ADD/SUB (of 8 per CL).
        let k = build(Variant::KahanSimd, 8, 2, Precision::Sp, &[]);
        k.validate().unwrap();
        assert_eq!(k.count(|o| *o == OpClass::Mul), 2);
        // per chunk: y, t, tmp, c -> 4 ADD-class ops; 2 chunks per CL.
        let adds = k.count(|o| *o == OpClass::Add);
        assert_eq!(adds, 8, "8 AVX additions/subtractions per CL");
        assert_eq!(k.count(|o| *o == OpClass::Load), 4);
    }

    #[test]
    fn kahan_fma_counts_match_paper() {
        // FMA variant: 1 FMS + 3 ADD/SUB per chunk.
        let k = build(Variant::KahanSimdFma, 8, 4, Precision::Sp, &[]);
        k.validate().unwrap();
        assert_eq!(k.count(|o| *o == OpClass::Fma), 4);
        assert_eq!(k.count(|o| *o == OpClass::Add), 12);
        assert_eq!(k.updates_per_body, 32); // 2 CLs at 4-way
    }

    #[test]
    fn kahan_fma5_counts_match_paper() {
        // 5-way trick: 2 FMA-class + 2 ADD-class per chunk.
        let k = build(Variant::KahanSimdFma5, 8, 5, Precision::Sp, &[]);
        k.validate().unwrap();
        assert_eq!(k.count(|o| *o == OpClass::Fma), 10);
        assert_eq!(k.count(|o| *o == OpClass::Add), 10);
        assert_eq!(k.cachelines_per_body(64), 2.5);
    }

    #[test]
    fn knc_kahan_counts_match_paper() {
        // Sect. 4.2.2: per 16 SP iters (one 512-b chunk): 1 FMA + 3 ADD/SUB,
        // 2 loads; L2 kernel adds 2 prefetches, mem kernel 4.
        let k = build(Variant::KahanSimdFma, 16, 1, Precision::Sp, &[(1, 2)]);
        assert_eq!(k.count(|o| *o == OpClass::Fma), 1);
        assert_eq!(k.count(|o| *o == OpClass::Add), 3);
        assert_eq!(k.count(|o| matches!(o, OpClass::Prefetch(_))), 2);
    }

    #[test]
    fn carried_chains_are_s_and_c() {
        let k = build(Variant::KahanSimdFma, 8, 4, Precision::Sp, &[]);
        let carried = k.carried_regs();
        // 4 chains x (s, c) = 8 carried registers.
        assert_eq!(carried.len(), 8, "{carried:?}");
    }

    #[test]
    fn naive_carried_chains_are_accs() {
        let k = build(Variant::NaiveSimd, 8, 7, Precision::Sp, &[]);
        assert_eq!(k.carried_regs().len(), 7);
    }

    #[test]
    fn scalar_variant_is_not_simd() {
        let k = build(Variant::KahanScalar, 1, 1, Precision::Dp, &[]);
        assert!(!k.simd);
        assert_eq!(k.updates_per_body, 1);
        assert_eq!(k.flops_per_update, 5);
    }

    #[test]
    fn pwr8_kahan_counts_match_paper() {
        // Sect. 4.2.3: per 128-B CL (32 SP iters = 8 VSX chunks): 16 loads,
        // 8 FMA + 24 ADD/SUB. Build 8 chunks (1 CL of work).
        let k = build(Variant::KahanSimdFma, 4, 8, Precision::Sp, &[]);
        assert_eq!(k.count(|o| *o == OpClass::Load), 16);
        assert_eq!(k.count(|o| *o == OpClass::Fma), 8);
        assert_eq!(k.count(|o| *o == OpClass::Add), 24);
        assert_eq!(k.updates_per_body, 32);
    }
}
