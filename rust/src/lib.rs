//! # kahan-ecm
//!
//! Reproduction of *"Performance analysis of the Kahan-enhanced scalar
//! product on current multi- and manycore processors"* (Hofmann, Fey,
//! Riedmann, Eitzinger, Hager, Wellein — Concurrency Computat.: Pract.
//! Exper. 2016, DOI 10.1002/cpe.3921).
//!
//! The library has three pillars (see DESIGN.md):
//!
//! * **The ECM performance model** ([`ecm`]) — the paper's analysis method:
//!   derive `{T_OL ∥ T_nOL | T_L1L2 | T_L2L3 | T_L3Mem}` inputs from an
//!   abstract kernel description ([`isa`]) and a machine model ([`arch`]),
//!   compose them with per-architecture overlap rules, and predict
//!   single-core performance per memory level plus multicore scaling.
//! * **A virtual testbed** ([`sim`]) — a microarchitecture simulator standing
//!   in for the paper's Haswell-EP, Broadwell-EP, Knights Corner and POWER8
//!   machines (which we do not have): a scoreboard core model, a cache
//!   hierarchy walker and a multicore memory-contention model that produce
//!   the "measured" curves of Figs. 5–10.
//! * **Real numerics on real hardware** ([`runtime`], [`accuracy`]) — a
//!   pluggable execution-backend subsystem running the paper's full kernel
//!   ladder. The default [`runtime::backend::NativeBackend`] implements
//!   naive dot, Kahan dot and Kahan sum in scalar, 2×/4×/8×-unrolled,
//!   portable-SIMD, runtime-detected AVX2 (single- *and* 2×/4×/8×
//!   multi-vector-accumulator — the register unrolling that breaks the
//!   FMA/ADD latency chain, the paper's headline transformation) and,
//!   behind the `avx512` cargo feature, 8-lane AVX-512 form — pure Rust,
//!   so the "blueprint" claim (Sect. 6) executes on *any* host with zero
//!   exotic dependencies. Benchmark operands come from the 64-byte-aligned
//!   [`runtime::arena`], so the intrinsic kernels take their aligned-load
//!   fast path and NUMA pages are first-touched by the worker that later
//!   streams them. [`runtime::parallel::ParallelBackend`] lifts every rung
//!   onto a *persistent parked-worker pool* (spawned once per backend —
//!   timed samples contain kernel execution, not thread creation):
//!   operand streams are split into cache-line-aligned per-thread slices
//!   (each thread keeps its own Kahan compensation) and the partials
//!   combine through a deterministic compensated tree reduction —
//!   bit-stable at a fixed thread count, and still within the serial
//!   compensated error bound. This is what lets the paper's *multicore
//!   saturation* claim (Figs. 8–10) be measured live (`bench-scale`, the
//!   `scale` experiment) and overlaid with the [`sim::multicore`]
//!   contention model and the ECM memory terms. The optional `pjrt` cargo
//!   feature adds a second backend that runs the AOT-compiled JAX/Pallas
//!   artifacts through PJRT, and [`accuracy`] provides the exact ground
//!   truth all of them are validated against.
//!
//! On top of the runtime sits the [`serve`] subsystem — the "serve heavy
//! traffic" layer. [`serve::DotService`] accepts batches of independent
//! dot/sum requests and schedules them over the persistent worker pool:
//! small requests are *fused* (workers pull whole requests back-to-back
//! from a shared queue), large requests are *sharded* through the exact
//! partition + compensated tree reduction of the measurement path, and
//! the crossover between the two is derived from the [`sim::multicore`]
//! saturation model — past bandwidth saturation, extra workers are worth
//! more as request parallelism than as shard parallelism — or *measured*
//! on the host (`serve-bench --calibrate`: single-thread p1 +
//! per-dispatch overhead, recorded model-vs-measured in the artifact).
//! [`serve::AsyncDotService`] pipelines submission: a bounded MPSC queue
//! with blocking backpressure feeds a dispatcher thread that drains
//! arrival batches inside a time/count-bounded window and posts fused
//! groups and shard partitions through *non-blocking* pool primitives
//! (`run_tasks_async`/`run_chunks_async` latch handles over a detached
//! pool), so arrival batches overlap in-flight sharded tails; callers
//! hold per-request `ResponseHandle` tickets (`wait`/`try_wait`).
//! Scheduling never forks the numerics — batched, unbatched, sharded and
//! async-queued results are bit-identical at a fixed thread count, only
//! completion order may differ (`serve-bench` drives both paths with
//! open/closed-loop load generators, emits sync-vs-async rows plus queue
//! and pool-utilization stats in `BENCH_serving.json`, and CI gates the
//! perf trajectory run-over-run via `tools/compare_bench.py`).
//! [`serve::NetServer`] (`serve-net` in the CLI) puts the same pipeline on
//! a TCP socket: a dependency-free length-prefixed binary protocol
//! ([`serve::codec`]; normative spec in `docs/PROTOCOL.md`) carries
//! operands and results as IEEE-754 bit patterns, per-connection
//! reader/writer halves stream responses in completion order correlated by
//! request id, and queue backpressure surfaces as a typed BUSY frame — so
//! the bit-parity contract extends across the socket (`serve-bench` adds a
//! loopback `wire` row to `BENCH_serving.json` and hard-fails on checksum
//! divergence; the dataflow narrative is `docs/ARCHITECTURE.md`).
//!
//! The [`harness`] module regenerates every table and figure of the paper;
//! [`coordinator`] wires it all into the `kahan-ecm` CLI.

// Style lints that conflict with this crate's numeric-kernel idioms
// (index-heavy lane loops, builder-free constructors, precise float
// literals). `manual_div_ceil` is allowed because `usize::div_ceil` needs
// Rust 1.73 and the crate's MSRV is 1.70. Correctness lints stay enabled;
// CI runs `clippy -D warnings` (enforced).
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::excessive_precision,
    clippy::manual_range_contains,
    clippy::manual_div_ceil,
    clippy::comparison_chain,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::len_without_is_empty,
    clippy::many_single_char_names
)]

pub mod accuracy;
pub mod arch;
pub mod bench_kit;
pub mod coordinator;
pub mod ecm;
pub mod harness;
pub mod isa;
pub mod ptest;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

pub use arch::Machine;
pub use ecm::{EcmInputs, EcmPrediction};
pub use isa::KernelLoop;
pub use runtime::backend::{Backend, KernelExec, KernelSpec, NativeBackend};
