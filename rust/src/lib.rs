//! # kahan-ecm
//!
//! Reproduction of *"Performance analysis of the Kahan-enhanced scalar
//! product on current multi- and manycore processors"* (Hofmann, Fey,
//! Riedmann, Eitzinger, Hager, Wellein — Concurrency Computat.: Pract.
//! Exper. 2016, DOI 10.1002/cpe.3921).
//!
//! The library has three pillars (see DESIGN.md):
//!
//! * **The ECM performance model** ([`ecm`]) — the paper's analysis method:
//!   derive `{T_OL ∥ T_nOL | T_L1L2 | T_L2L3 | T_L3Mem}` inputs from an
//!   abstract kernel description ([`isa`]) and a machine model ([`arch`]),
//!   compose them with per-architecture overlap rules, and predict
//!   single-core performance per memory level plus multicore scaling.
//! * **A virtual testbed** ([`sim`]) — a microarchitecture simulator standing
//!   in for the paper's Haswell-EP, Broadwell-EP, Knights Corner and POWER8
//!   machines (which we do not have): a scoreboard core model, a cache
//!   hierarchy walker and a multicore memory-contention model that produce
//!   the "measured" curves of Figs. 5–10.
//! * **Real numerics + a real fifth machine** ([`runtime`], [`accuracy`]) —
//!   the Kahan/naive kernels AOT-compiled from JAX/Pallas run on the host
//!   CPU via PJRT, providing genuine accuracy data and a live demonstration
//!   of the paper's "blueprint" claim.
//!
//! The [`harness`] module regenerates every table and figure of the paper;
//! [`coordinator`] wires it all into the `kahan-ecm` CLI.

pub mod accuracy;
pub mod arch;
pub mod bench_kit;
pub mod coordinator;
pub mod ecm;
pub mod harness;
pub mod isa;
pub mod ptest;
pub mod runtime;
pub mod sim;
pub mod util;

pub use arch::Machine;
pub use ecm::{EcmInputs, EcmPrediction};
pub use isa::KernelLoop;
