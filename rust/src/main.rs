//! `kahan-ecm` — CLI for the Kahan/ECM reproduction.
//!
//! Subcommands:
//!   list                       list all experiments (paper tables/figures)
//!   run <id|prefix|all>        regenerate experiments into --out-dir
//!   bench-native               benchmark the native kernel ladder -> JSON
//!   bench-scale                thread-scaling (and optional working-set)
//!                              measurement vs model -> JSON
//!   serve-bench                batching/sharding serving layer under an
//!                              open/closed-loop request load -> JSON
//!   serve-net                  TCP wire front-end over the async serving
//!                              pipeline (protocol: docs/PROTOCOL.md)
//!   ecm                        print ECM inputs/predictions for one config
//!   sweep                      print a single-core sweep for one config
//!   custom --config FILE       run the ECM analysis on a user machine
//!   info                       build/runtime information

// Same style-lint posture as lib.rs (see the rationale there).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

use std::collections::BTreeMap;
use std::process::ExitCode;

use kahan_ecm::arch::{self, loader};
use kahan_ecm::coordinator::{all_experiments, assemble_report, find, run_parallel};
use kahan_ecm::ecm::{self, MemLevel};
use kahan_ecm::harness::{scaleexp, Ctx};
use kahan_ecm::isa::Variant;
use kahan_ecm::runtime::backend::native::{preferred_kahan_style, SimdCaps};
use kahan_ecm::runtime::backend::{Backend, ImplStyle, KernelClass, KernelSpec, NativeBackend};
use kahan_ecm::runtime::hostbench::{
    bench_kernel, bench_scaling, bench_ws_sweep, detect_freq_ghz, freq_ghz_with_source,
    FreqSource,
};
use kahan_ecm::runtime::parallel::ThreadPool;
use kahan_ecm::serve::{
    calibrate, codec, default_mix, parse_mix, run_interleaving_checksum, run_load,
    run_load_async, run_load_chaos, run_load_integrity, run_load_tenants, run_load_wire,
    run_load_zipf, AsyncDotService, AsyncLoadReport, AsyncOptions, Calibration, ChaosReport,
    DotService, FaultInjector, FaultPlan, FaultSite, IntegrityReport, InterleavingReport,
    LoadMode, LoadReport, NetOptions, NetServer, OperandPool, QosPolicy, ServeConfig,
    TenantLoadReport, ThresholdMode, WireLoadReport, ZipfReport,
};
use kahan_ecm::sim::{self, MeasureOpts};
use kahan_ecm::util::cli::Spec;
use kahan_ecm::util::json::Json;
use kahan_ecm::util::table::{fnum, Table};
use kahan_ecm::util::units::{fmt_bytes, Precision, GIB};

fn usage() -> String {
    let mut s = String::from(
        "kahan-ecm — reproduction of 'Performance analysis of the Kahan-enhanced scalar \
         product on current multi- and manycore processors' (Hofmann et al., 2016)\n\n\
         USAGE: kahan-ecm <command> [options]\n\nCOMMANDS:\n\
         \x20 list                      list experiments\n\
         \x20 run <id|prefix|all>       regenerate paper tables/figures\n\
         \x20 bench-native              benchmark the native kernel ladder -> JSON\n\
         \x20 bench-scale               measured thread-scaling vs ECM model -> JSON\n\
         \x20 serve-bench               serving layer under request load -> JSON\n\
         \x20 serve-net                 TCP wire front-end (docs/PROTOCOL.md)\n\
         \x20 ecm                       ECM analysis for one machine x kernel\n\
         \x20 sweep                     simulated single-core working-set sweep\n\
         \x20 custom                    ECM analysis on a machine config file\n\
         \x20 info                      version / environment info\n\nOPTIONS (run):\n",
    );
    s.push_str(&run_spec().help_text());
    s.push_str("\nOPTIONS (bench-native):\n");
    s.push_str(&bench_native_spec().help_text());
    s.push_str("\nOPTIONS (bench-scale):\n");
    s.push_str(&bench_scale_spec().help_text());
    s.push_str("\nOPTIONS (serve-bench):\n");
    s.push_str(&serve_bench_spec().help_text());
    s.push_str("\nOPTIONS (serve-net):\n");
    s.push_str(&serve_net_spec().help_text());
    s.push_str("\nOPTIONS (ecm/sweep):\n");
    s.push_str(&ecm_spec().help_text());
    s
}

fn run_spec() -> Spec {
    Spec::new()
        .opt("out-dir", "output directory (default: out)")
        .opt("seed", "measurement-noise seed (default: 1)")
        .opt("jobs", "worker threads (default: available cores)")
        .opt("artifacts", "artifact directory (default: artifacts)")
        .opt("backend", "host-kernel backend: native|pjrt|auto (default: auto)")
        .flag("quick", "reduced grids for smoke runs")
}

fn bench_native_spec() -> Spec {
    Spec::new()
        .opt("out", "write JSON results to FILE (default: BENCH_native.json)")
        .opt("sizes", "comma-separated vector lengths (default: 1024,16384,262144,1048576)")
        .opt("warmup", "warmup executions per kernel (default: 2)")
        .opt("reps", "timed executions per kernel (default: 7)")
        .opt("freq-ghz", "core clock for cycle metrics (default: /proc/cpuinfo)")
        .flag("quick", "tiny sweep for CI smoke runs")
}

fn bench_scale_spec() -> Spec {
    Spec::new()
        .opt("out", "write JSON results to FILE (default: BENCH_scaling.json)")
        .opt("threads", "max worker threads; the curve covers T = 1..=T (default: all cores)")
        .opt("n", "vector length for the scaling curve (default: 4194304)")
        .flag("sweep", "also run a single-core working-set sweep spanning L1..MEM")
        .opt("warmup", "warmup executions per point (default: 2)")
        .opt("reps", "timed executions per point (default: 5)")
        .opt("freq-ghz", "core clock for cycle metrics (default: detected, nominal fallback)")
        .flag("quick", "tiny grids for CI smoke runs")
}

fn serve_bench_spec() -> Spec {
    Spec::new()
        .opt("out", "write JSON results to FILE (default: BENCH_serving.json)")
        .opt("threads", "service worker count (default: all cores)")
        .opt("requests", "total requests in the run (default: 4096)")
        .opt("batch", "requests per arrival batch / queue batching cap (default: 64)")
        .opt("mix", "request mixture n:weight,... (default: small-heavy serving mix)")
        .opt("mode", "closed|open arrival loop for the primary run (default: closed)")
        .opt(
            "rate",
            "arrival rate, requests/s: --mode open's primary run (default 50000) and the \
             queue-mode rows (default: 70% of the measured closed-loop rate)",
        )
        .opt("threshold", "shard requests with n >= N (default: model-derived crossover)")
        .opt("queue-depth", "async submission-queue depth (default: 256)")
        .opt("batch-window-us", "async batching window in microseconds (default: 100)")
        .flag("calibrate", "measure p1 + dispatch overhead, record model vs measured crossover")
        .opt("seed", "request-stream seed (default: 1)")
        .flag("naive", "serve the naive dot instead of the compensated default")
        .opt("freq-ghz", "core clock for the model crossover (default: detected)")
        .opt(
            "wire-connections",
            "wire loadgen client connections, 0 skips the wire run (default: 4, quick: 2)",
        )
        .opt(
            "wire-addr",
            "drive an already-running serve-net server instead of a private loopback one",
        )
        .flag(
            "chaos",
            "run a seeded fault-injection scenario and record a `chaos` block (hard-fails \
             on any hung request or failed recovery), plus the corruption-detection \
             `integrity` block (hard-fails unless every injected corruption is detected, \
             zero corrupt payloads are delivered, and a clean control pass raises no \
             false positives)",
        )
        .opt("chaos-seed", "fault-plan seed for --chaos (default: the request seed)")
        .flag(
            "zipf",
            "run the skewed-popularity operand-store scenario and record a `zipf` block \
             (hard-fails unless the cached pass is bit-identical to the baseline)",
        )
        .opt("zipf-s", "popularity exponent for --zipf (default: 1.2; 0 = uniform)")
        .opt(
            "tenants",
            "tenant QoS spec name:weight[:quota],... (bare weights like 3:1 also work); \
             enables weighted-fair scheduling with per-tenant quotas and records the \
             tenant mixture, noisy-neighbor and scheduling-interleaving scenarios",
        )
        .flag("quick", "tiny run for CI smoke")
}

fn serve_net_spec() -> Spec {
    Spec::new()
        .opt("addr", "listen address (default: 127.0.0.1:4990; port 0 picks a free port)")
        .opt("threads", "service worker count (default: all cores)")
        .opt("threshold", "shard requests with n >= N (default: model-derived crossover)")
        .opt("queue-depth", "async submission-queue depth (default: 256)")
        .opt("batch-window-us", "async batching window in microseconds (default: 100)")
        .opt("batch", "queue batching cap per dispatch (default: 64)")
        .flag("naive", "serve the naive dot instead of the compensated default")
        .opt("freq-ghz", "core clock for the model crossover (default: detected)")
        .opt(
            "read-timeout-ms",
            "per-read socket timeout; a mid-frame stall past it drops the connection \
             (default: none)",
        )
        .opt(
            "idle-timeout-ms",
            "reap connections idle between frames for this long (default: none)",
        )
        .opt(
            "write-timeout-ms",
            "per-write socket timeout; a slow client past it is evicted (default: none)",
        )
        .opt(
            "tenants",
            "tenant QoS spec name:weight[:quota],... (bare weights like 3:1 also work); \
             unset quotas default to a weight-proportional share of the queue depth",
        )
        .opt(
            "verify-hit-rate",
            "fraction of result-cache hits to recompute and bit-verify before serving \
             (0..=1; default: 0 — rate 0 is bit-identical to the unverified pipeline)",
        )
}

fn ecm_spec() -> Spec {
    Spec::new()
        .opt("machine", "HSW|BDW|KNC|PWR8|HOST (default: HSW)")
        .opt("variant", "naive|kahan-simd|kahan-fma|kahan-fma5|kahan-scalar (default: kahan-fma5)")
        .opt("prec", "sp|dp (default: sp)")
        .opt("level", "l1|l2|mem kernel tuning, KNC only (default: mem)")
        .opt("smt", "threads per core for sweep (default: 1)")
        .opt("config", "machine config file (custom command)")
}

/// `--freq-ghz` handling shared by the bench subcommands: an explicit value
/// must be positive; otherwise fall back to detection with a recorded
/// source (never absent).
fn parse_freq_arg(args: &kahan_ecm::util::cli::Args) -> Result<(f64, FreqSource), String> {
    match args.opt("freq-ghz") {
        Some(v) => match v.parse::<f64>() {
            Ok(f) if f > 0.0 => Ok((f, FreqSource::UserProvided)),
            _ => Err("--freq-ghz expects a positive number".to_string()),
        },
        None => Ok(freq_ghz_with_source()),
    }
}

fn parse_variant(s: &str) -> Option<Variant> {
    Some(match s {
        "naive" => Variant::NaiveSimd,
        "kahan-simd" | "kahan-avx" => Variant::KahanSimd,
        "kahan-fma" => Variant::KahanSimdFma,
        "kahan-fma5" => Variant::KahanSimdFma5,
        "kahan-scalar" | "kahan-compiler" => Variant::KahanScalar,
        _ => return None,
    })
}

fn cmd_list() -> ExitCode {
    let mut t = Table::new(["id", "paper ref", "title", "needs artifacts"]);
    for e in all_experiments() {
        t.row([
            e.id.to_string(),
            e.paper_ref.to_string(),
            e.title.to_string(),
            if e.needs_artifacts { "yes" } else { "" }.to_string(),
        ]);
    }
    print!("{}", t.to_text());
    ExitCode::SUCCESS
}

fn cmd_run(raw: Vec<String>) -> ExitCode {
    let args = match run_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let sel = args.positionals.first().map(String::as_str).unwrap_or("all");
    let defs = find(sel);
    if defs.is_empty() {
        eprintln!("no experiment matches '{sel}' (try `kahan-ecm list`)");
        return ExitCode::FAILURE;
    }
    let out_dir = args.opt_or("out-dir", "out").to_string();
    let backend = args.opt_or("backend", "auto").to_string();
    if !matches!(backend.as_str(), "native" | "pjrt" | "auto") {
        eprintln!("error: --backend must be native, pjrt or auto (got '{backend}')");
        return ExitCode::FAILURE;
    }
    let ctx = Ctx {
        artifacts_dir: args.opt_or("artifacts", "artifacts").to_string(),
        seed: args.opt_parse("seed", 1u64).unwrap_or(1),
        quick: args.flag("quick"),
        backend,
    };
    let jobs = args
        .opt_parse(
            "jobs",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
        .unwrap_or(1);

    eprintln!("running {} experiment(s) with {jobs} worker(s) ...", defs.len());
    let outcomes = run_parallel(&defs, &ctx, jobs);
    let mut failed = 0;
    for o in &outcomes {
        match &o.result {
            Ok(out) => {
                if let Err(e) = out.write(&out_dir) {
                    eprintln!("[{}] write failed: {e:#}", o.id);
                    failed += 1;
                    continue;
                }
                println!("[{}] ok ({:.1}s) -> {}/{}/", o.id, o.seconds, out_dir, o.id);
                for p in &out.plots {
                    println!("{}", p.1);
                }
            }
            Err(e) => {
                eprintln!("[{}] FAILED: {e:#}", o.id);
                failed += 1;
            }
        }
    }
    let report = assemble_report(&defs, &outcomes);
    if let Err(e) = std::fs::create_dir_all(&out_dir)
        .and_then(|_| std::fs::write(format!("{out_dir}/REPORT.md"), &report))
    {
        eprintln!("report write failed: {e}");
    }
    if failed == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_bench_native(raw: Vec<String>) -> ExitCode {
    let args = match bench_native_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let quick = args.flag("quick");
    let sizes: Vec<usize> = match args.opt("sizes") {
        Some(s) => {
            let parsed: Result<Vec<usize>, _> = s.split(',').map(|t| t.trim().parse()).collect();
            match parsed {
                Ok(v) if !v.is_empty() => v,
                _ => {
                    eprintln!("error: --sizes expects comma-separated integers");
                    return ExitCode::FAILURE;
                }
            }
        }
        None if quick => vec![1024, 16384],
        None => vec![1024, 16384, 262144, 1048576],
    };
    let warmup = match args.opt_parse("warmup", if quick { 1usize } else { 2 }) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let reps = match args.opt_parse("reps", if quick { 3usize } else { 7 }) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (freq_val, freq_src) = match parse_freq_arg(&args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let freq = Some(freq_val);
    let out_path = args.opt_or("out", "BENCH_native.json").to_string();

    let backend = NativeBackend::new();
    let mut t = Table::new([
        "kernel", "n", "ns (min)", "MFlop/s", "GUP/s", "GB/s", "cy/flop", "cy/up",
    ]);
    let fmt_cy = |c: Option<f64>| c.map(|v| fnum(v, 3)).unwrap_or_else(|| "-".to_string());
    let mut results = Vec::new();
    for spec in backend.kernels() {
        for &n in &sizes {
            let r = match bench_kernel(&backend, spec, n, warmup, reps, freq) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("[{spec}] FAILED: {e:#}");
                    return ExitCode::FAILURE;
                }
            };
            t.row([
                r.kernel.clone(),
                r.n.to_string(),
                fnum(r.ns.min, 0),
                fnum(r.mflops_best, 0),
                fnum(r.gups_best, 3),
                fnum(r.gbs_best, 2),
                fmt_cy(r.cycles_per_flop),
                fmt_cy(r.cycles_per_update),
            ]);
            let mut obj = BTreeMap::new();
            obj.insert("kernel".to_string(), Json::Str(r.kernel.clone()));
            obj.insert("n".to_string(), Json::Num(r.n as f64));
            obj.insert("ws_bytes".to_string(), Json::Num(r.ws_bytes as f64));
            obj.insert("flops".to_string(), Json::Num(r.flops as f64));
            obj.insert("ns_min".to_string(), Json::Num(r.ns.min));
            obj.insert("ns_median".to_string(), Json::Num(r.ns.median));
            obj.insert("mflops".to_string(), Json::Num(r.mflops_best));
            obj.insert("gups".to_string(), Json::Num(r.gups_best));
            obj.insert("gbs".to_string(), Json::Num(r.gbs_best));
            obj.insert(
                "cycles_per_flop".to_string(),
                r.cycles_per_flop.map(Json::Num).unwrap_or(Json::Null),
            );
            obj.insert(
                "cycles_per_update".to_string(),
                r.cycles_per_update.map(Json::Num).unwrap_or(Json::Null),
            );
            results.push(Json::Obj(obj));
        }
    }
    print!("{}", t.to_text());

    let n_results = results.len();
    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str("native".to_string()));
    root.insert("avx2".to_string(), Json::Bool(backend.has_avx2()));
    root.insert("avx512".to_string(), Json::Bool(backend.has_avx512()));
    root.insert("freq_ghz".to_string(), Json::Num(freq_val));
    root.insert(
        "freq_source".to_string(),
        Json::Str(freq_src.label().to_string()),
    );
    root.insert("warmup".to_string(), Json::Num(warmup as f64));
    root.insert("reps".to_string(), Json::Num(reps as f64));
    root.insert("results".to_string(), Json::Arr(results));
    let doc = Json::Obj(root);
    if let Err(e) = std::fs::write(&out_path, format!("{doc}\n")) {
        eprintln!("error: cannot write {out_path}: {e}");
        return ExitCode::FAILURE;
    }
    println!("\nwrote {n_results} kernel results to {out_path}");
    ExitCode::SUCCESS
}

/// Kernels on the bench-scale curves: the paper's naive-vs-Kahan SIMD pair,
/// plus — per available host tier — the single-accumulator AVX2 rungs (the
/// latency-bound baseline), the 8×-unrolled AVX2 rungs (the paper's
/// throughput-saturating layout) and the 8×-unrolled AVX-512 rungs.
fn scale_kernels(caps: SimdCaps) -> Vec<KernelSpec> {
    let mut v = vec![
        KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes),
        KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes),
    ];
    let mut pair = |style| {
        v.push(KernelSpec::new(KernelClass::NaiveDot, style));
        v.push(KernelSpec::new(KernelClass::KahanDot, style));
    };
    if caps.avx2 {
        pair(ImplStyle::SimdAvx2);
        pair(ImplStyle::Avx2U8);
    }
    if caps.avx512 {
        pair(ImplStyle::Avx512U8);
    }
    v
}

fn cmd_bench_scale(raw: Vec<String>) -> ExitCode {
    let args = match bench_scale_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let quick = args.flag("quick");
    let avail = ThreadPool::available();
    let threads = match args.opt_parse("threads", if quick { avail.min(2) } else { avail }) {
        Ok(t) if t >= 1 => t,
        Ok(_) => {
            eprintln!("error: --threads must be >= 1");
            return ExitCode::FAILURE;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let n = match args.opt_parse("n", if quick { 1usize << 18 } else { 1usize << 22 }) {
        Ok(v) if v >= 1 => v,
        Ok(_) => {
            eprintln!("error: --n must be >= 1");
            return ExitCode::FAILURE;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let warmup = match args.opt_parse("warmup", if quick { 1usize } else { 2 }) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let reps = match args.opt_parse("reps", if quick { 3usize } else { 5 }) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (freq, freq_src) = match parse_freq_arg(&args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let out_path = args.opt_or("out", "BENCH_scaling.json").to_string();

    let caps = SimdCaps::detect();
    let m = scaleexp::host_model(freq, threads as u32);
    eprintln!(
        "bench-scale: T = 1..={threads}, n = {n}, clock = {freq:.2} GHz ({}) ...",
        freq_src.label()
    );

    let mut t = Table::new([
        "kernel", "T", "ns (median)", "MFlop/s", "model MFlop/s", "GUP/s", "model GUP/s",
    ]);
    let mut scaling_json = Vec::new();
    for spec in scale_kernels(caps) {
        let curve = match bench_scaling(spec, n, threads, warmup, reps, Some(freq)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("[{spec}] FAILED: {e:#}");
                return ExitCode::FAILURE;
            }
        };
        let p1 = curve[0].1.gups_median;
        let model = scaleexp::model_scaling_gups(&m, spec, p1).unwrap_or_default();
        let mut points = Vec::new();
        for (tcount, r) in &curve {
            let mg = model.get(*tcount - 1).map(|&(_, g)| g);
            t.row([
                r.kernel.clone(),
                tcount.to_string(),
                fnum(r.ns.median, 0),
                fnum(r.mflops_median, 0),
                mg.map(|g| fnum(scaleexp::gups_to_mflops(spec.class, g), 0))
                    .unwrap_or_else(|| "-".to_string()),
                fnum(r.gups_median, 3),
                mg.map(|g| fnum(g, 3)).unwrap_or_else(|| "-".to_string()),
            ]);
            let mut obj = BTreeMap::new();
            obj.insert("threads".to_string(), Json::Num(*tcount as f64));
            obj.insert("ns_min".to_string(), Json::Num(r.ns.min));
            obj.insert("ns_median".to_string(), Json::Num(r.ns.median));
            obj.insert("mflops".to_string(), Json::Num(r.mflops_median));
            obj.insert("mflops_best".to_string(), Json::Num(r.mflops_best));
            obj.insert("gups".to_string(), Json::Num(r.gups_median));
            obj.insert("gbs".to_string(), Json::Num(r.gbs_median));
            obj.insert(
                "model_gups".to_string(),
                mg.map(Json::Num).unwrap_or(Json::Null),
            );
            obj.insert(
                "model_mflops".to_string(),
                mg.map(|g| Json::Num(scaleexp::gups_to_mflops(spec.class, g)))
                    .unwrap_or(Json::Null),
            );
            points.push(Json::Obj(obj));
        }
        let mut kobj = BTreeMap::new();
        kobj.insert("kernel".to_string(), Json::Str(spec.id()));
        kobj.insert("n".to_string(), Json::Num(n as f64));
        kobj.insert("points".to_string(), Json::Arr(points));
        scaling_json.push(Json::Obj(kobj));
    }
    print!("{}", t.to_text());

    let mut sweep_json = Vec::new();
    if args.flag("sweep") {
        let max_bytes: u64 = if quick { 16 << 20 } else { 256 << 20 };
        let step = if quick { 8 } else { 4 };
        let sizes: Vec<u64> = sim::default_sweep_sizes(max_bytes)
            .into_iter()
            .step_by(step)
            .collect();
        let backend = NativeBackend::new();
        let mut st = Table::new([
            "kernel", "ws", "MFlop/s", "GUP/s", "model GUP/s", "model cy/CL", "model data cy/CL",
        ]);
        for spec in scale_kernels(caps) {
            let pts = match bench_ws_sweep(&backend, spec, &sizes, warmup, reps, Some(freq)) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("[{spec}] sweep FAILED: {e:#}");
                    return ExitCode::FAILURE;
                }
            };
            let model = scaleexp::model_sweep(&m, spec, &sizes).unwrap_or_default();
            let mut points = Vec::new();
            for ((r, (mp, data_cy)), &ws) in pts.iter().zip(&model).zip(&sizes) {
                st.row([
                    r.kernel.clone(),
                    fmt_bytes(ws),
                    fnum(r.mflops_median, 0),
                    fnum(r.gups_median, 3),
                    fnum(mp.gups, 3),
                    fnum(mp.cy_per_cl, 2),
                    fnum(*data_cy, 2),
                ]);
                let mut obj = BTreeMap::new();
                obj.insert("ws_bytes".to_string(), Json::Num(ws as f64));
                obj.insert("n".to_string(), Json::Num(r.n as f64));
                obj.insert("mflops".to_string(), Json::Num(r.mflops_median));
                obj.insert("gups".to_string(), Json::Num(r.gups_median));
                obj.insert(
                    "cy_per_update".to_string(),
                    r.cycles_per_update_median.map(Json::Num).unwrap_or(Json::Null),
                );
                obj.insert("model_gups".to_string(), Json::Num(mp.gups));
                obj.insert("model_cy_per_cl".to_string(), Json::Num(mp.cy_per_cl));
                obj.insert("model_data_cy_per_cl".to_string(), Json::Num(*data_cy));
                points.push(Json::Obj(obj));
            }
            let mut kobj = BTreeMap::new();
            kobj.insert("kernel".to_string(), Json::Str(spec.id()));
            kobj.insert("points".to_string(), Json::Arr(points));
            sweep_json.push(Json::Obj(kobj));
        }
        print!("{}", st.to_text());
    }

    let n_curves = scaling_json.len();
    let mut root = BTreeMap::new();
    root.insert("backend".to_string(), Json::Str("native-mt".to_string()));
    root.insert("avx2".to_string(), Json::Bool(caps.avx2));
    root.insert("avx512".to_string(), Json::Bool(caps.avx512));
    root.insert("threads_max".to_string(), Json::Num(threads as f64));
    root.insert("n".to_string(), Json::Num(n as f64));
    root.insert("freq_ghz".to_string(), Json::Num(freq));
    root.insert(
        "freq_source".to_string(),
        Json::Str(freq_src.label().to_string()),
    );
    root.insert("warmup".to_string(), Json::Num(warmup as f64));
    root.insert("reps".to_string(), Json::Num(reps as f64));
    root.insert("machine_model".to_string(), Json::Str("HOST".to_string()));
    root.insert("model_bw_gbs".to_string(), Json::Num(m.mem.sustained_bw_gbs));
    root.insert("scaling".to_string(), Json::Arr(scaling_json));
    root.insert("sweep".to_string(), Json::Arr(sweep_json));
    let doc = Json::Obj(root);
    if let Err(e) = std::fs::write(&out_path, format!("{doc}\n")) {
        eprintln!("error: cannot write {out_path}: {e}");
        return ExitCode::FAILURE;
    }
    println!("\nwrote {n_curves} scaling curve(s) to {out_path}");
    ExitCode::SUCCESS
}

/// `--tenants` handling shared by serve-bench and serve-net: parse the
/// spec and fill unset quotas with a weight-proportional share of the
/// queue depth (the documented default).
fn parse_tenants_arg(
    args: &kahan_ecm::util::cli::Args,
    queue_depth: usize,
) -> Result<Option<QosPolicy>, String> {
    match args.opt("tenants") {
        None => Ok(None),
        Some(spec) => QosPolicy::parse(spec)
            .map(|p| Some(p.with_default_quotas(queue_depth)))
            .map_err(|e| format!("--tenants: {e}")),
    }
}

/// Human label for a shard crossover (`usize::MAX` = "never shard").
fn crossover_label(n: usize) -> String {
    if n == usize::MAX {
        "never".to_string()
    } else {
        n.to_string()
    }
}

/// JSON value for a shard crossover (`usize::MAX` -> null).
fn crossover_json(n: usize) -> Json {
    if n == usize::MAX {
        Json::Null
    } else {
        Json::Num(n as f64)
    }
}

/// The open-loop row fields shared by the `sync`/`async` queue rows and the
/// `wire` row in `BENCH_serving.json` (the wire row adds a few of its own
/// on top — see [`wire_row_json`]).
fn load_row_obj(
    load: &LoadReport,
    max_queue_depth: usize,
    dispatches: u64,
    arrival_batches: u64,
    pool_utilization: f64,
) -> BTreeMap<String, Json> {
    let mut lat = BTreeMap::new();
    lat.insert("p50".to_string(), Json::Num(load.latency_p50_ns));
    lat.insert("p90".to_string(), Json::Num(load.latency_p90_ns));
    lat.insert("p99".to_string(), Json::Num(load.latency_p99_ns));
    lat.insert("max".to_string(), Json::Num(load.latency_max_ns));
    let mut obj = BTreeMap::new();
    obj.insert("requests".to_string(), Json::Num(load.requests as f64));
    obj.insert("fused".to_string(), Json::Num(load.fused as f64));
    obj.insert("sharded".to_string(), Json::Num(load.sharded as f64));
    obj.insert("latency_ns".to_string(), Json::Obj(lat));
    obj.insert("busy_ns".to_string(), Json::Num(load.busy_ns));
    obj.insert("elapsed_ns".to_string(), Json::Num(load.elapsed_ns));
    obj.insert("mflops".to_string(), Json::Num(load.mflops));
    obj.insert("gups".to_string(), Json::Num(load.gups));
    obj.insert("reqs_per_s".to_string(), Json::Num(load.reqs_per_s));
    obj.insert("checksum".to_string(), Json::Num(load.checksum));
    obj.insert("max_queue_depth".to_string(), Json::Num(max_queue_depth as f64));
    obj.insert("dispatches".to_string(), Json::Num(dispatches as f64));
    obj.insert(
        "arrival_batches".to_string(),
        Json::Num(arrival_batches as f64),
    );
    obj.insert("pool_utilization".to_string(), Json::Num(pool_utilization));
    obj.insert(
        "non_finite_latencies".to_string(),
        Json::Num(load.non_finite_latencies as f64),
    );
    obj
}

/// One queue-mode open-loop row (the `sync` and `async` sides of the
/// side-by-side comparison in `BENCH_serving.json`).
fn queue_row_json(r: &AsyncLoadReport) -> Json {
    Json::Obj(load_row_obj(
        &r.load,
        r.max_queue_depth,
        r.dispatches,
        r.arrival_batches,
        r.pool_utilization,
    ))
}

/// The `wire` row: the same open-loop schema measured through the TCP
/// front-end, plus the wire-only fields (`connections`, `busy_retries`,
/// `rate_rps`).
fn wire_row_json(r: &WireLoadReport) -> Json {
    let mut obj = load_row_obj(
        &r.load,
        r.max_queue_depth,
        r.dispatches,
        r.arrival_batches,
        r.pool_utilization,
    );
    obj.insert("connections".to_string(), Json::Num(r.connections as f64));
    obj.insert("busy_retries".to_string(), Json::Num(r.busy_retries as f64));
    obj.insert("rate_rps".to_string(), Json::Num(r.rate_rps));
    Json::Obj(obj)
}

/// Finite number or JSON null (percentiles of an empty sample set are
/// NaN, which is not valid JSON).
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// One tenant scenario in `BENCH_serving.json`: offered rate plus one
/// accounting + latency row per tenant class.
fn tenant_scenario_json(rep: &TenantLoadReport, rate_rps: f64) -> Json {
    let mut rows = Vec::new();
    for r in &rep.rows {
        let mut lat = BTreeMap::new();
        lat.insert("p50".to_string(), num_or_null(r.latency_p50_ns));
        lat.insert("p99".to_string(), num_or_null(r.latency_p99_ns));
        lat.insert("max".to_string(), num_or_null(r.latency_max_ns));
        let mut obj = BTreeMap::new();
        obj.insert("tenant".to_string(), Json::Num(r.tenant as f64));
        obj.insert("name".to_string(), Json::Str(r.name.clone()));
        obj.insert("weight".to_string(), Json::Num(r.weight as f64));
        obj.insert(
            "quota".to_string(),
            r.quota.map(|q| Json::Num(q as f64)).unwrap_or(Json::Null),
        );
        obj.insert("offered".to_string(), Json::Num(r.offered as f64));
        obj.insert("admitted".to_string(), Json::Num(r.admitted as f64));
        obj.insert("completed_ok".to_string(), Json::Num(r.completed_ok as f64));
        obj.insert("quota_shed".to_string(), Json::Num(r.quota_shed as f64));
        obj.insert("busy_shed".to_string(), Json::Num(r.busy_shed as f64));
        obj.insert(
            "deadline_shed".to_string(),
            Json::Num(r.deadline_shed as f64),
        );
        obj.insert("latency_ns".to_string(), Json::Obj(lat));
        rows.push(Json::Obj(obj));
    }
    let mut obj = BTreeMap::new();
    obj.insert("requests".to_string(), Json::Num(rep.requests as f64));
    obj.insert("rate_rps".to_string(), Json::Num(rate_rps));
    obj.insert("elapsed_ns".to_string(), Json::Num(rep.elapsed_ns));
    obj.insert("rows".to_string(), Json::Arr(rows));
    Json::Obj(obj)
}

/// Everything the `--tenants` scenarios measured, staged for the table
/// and JSON emitters.
struct TenantBench {
    weighted: TenantLoadReport,
    noisy: TenantLoadReport,
    noisy_rate: f64,
    interleave_requests: usize,
    fifo: InterleavingReport,
    fair: InterleavingReport,
    reversed: InterleavingReport,
}

fn cmd_serve_bench(raw: Vec<String>) -> ExitCode {
    let args = match serve_bench_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let quick = args.flag("quick");
    let avail = ThreadPool::available();
    let threads = match args.opt_parse("threads", if quick { avail.min(2) } else { avail }) {
        Ok(t) if t >= 1 => t,
        Ok(_) => {
            eprintln!("error: --threads must be >= 1");
            return ExitCode::FAILURE;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let requests = match args.opt_parse("requests", if quick { 256usize } else { 4096 }) {
        Ok(v) if v >= 1 => v,
        _ => {
            eprintln!("error: --requests must be a positive integer");
            return ExitCode::FAILURE;
        }
    };
    let batch = match args.opt_parse("batch", if quick { 32usize } else { 64 }) {
        Ok(v) if v >= 1 => v,
        _ => {
            eprintln!("error: --batch must be a positive integer");
            return ExitCode::FAILURE;
        }
    };
    let seed = match args.opt_parse("seed", 1u64) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let mix = match args.opt("mix") {
        Some(s) => match parse_mix(s) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("error: --mix: {e}");
                return ExitCode::FAILURE;
            }
        },
        None => default_mix(quick),
    };
    let mode = match args.opt_or("mode", "closed") {
        "closed" => LoadMode::Closed,
        "open" => {
            let rate = match args.opt_parse("rate", 50_000.0f64) {
                Ok(r) if r > 0.0 => r,
                _ => {
                    eprintln!("error: --rate must be a positive number");
                    return ExitCode::FAILURE;
                }
            };
            LoadMode::Open { rate_rps: rate }
        }
        other => {
            eprintln!("error: --mode must be closed or open (got '{other}')");
            return ExitCode::FAILURE;
        }
    };
    let threshold = match args.opt("threshold") {
        Some(v) => match v.parse::<usize>() {
            Ok(t) => Some(t),
            Err(_) => {
                eprintln!("error: --threshold expects a non-negative integer");
                return ExitCode::FAILURE;
            }
        },
        None => None,
    };
    let queue_depth = match args.opt_parse("queue-depth", 256usize) {
        Ok(v) if v >= 1 => v,
        _ => {
            eprintln!("error: --queue-depth must be a positive integer");
            return ExitCode::FAILURE;
        }
    };
    let batch_window_us = match args.opt_parse("batch-window-us", 100u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let qos = match parse_tenants_arg(&args, queue_depth) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (freq, freq_src) = match parse_freq_arg(&args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let out_path = args.opt_or("out", "BENCH_serving.json").to_string();

    let mut cfg = ServeConfig {
        threads,
        style: preferred_kahan_style(SimdCaps::detect()),
        compensated: !args.flag("naive"),
        shard_threshold: match threshold {
            Some(t) => ThresholdMode::Fixed(t),
            None => ThresholdMode::Model,
        },
        freq_ghz: freq,
        // The bench measures the unverified fast path; the integrity
        // scenario below arms its own service at rate 1.0.
        verify_hit_rate: 0.0,
    };
    // Calibration: measure p1 + dispatch overhead on a probe service, and
    // (unless the threshold was pinned) serve with the measured crossover.
    let calibration: Option<Calibration> = if args.flag("calibrate") {
        let probe = match DotService::new(cfg.clone()) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: cannot build the calibration service: {e}");
                return ExitCode::FAILURE;
            }
        };
        let c = calibrate(&probe, freq, quick);
        eprintln!(
            "calibrate: p1 = {} MFlop/s ({} GUP/s), dispatch overhead = {} ns, \
             measured crossover = {}, model crossover = {}",
            fnum(c.p1_mflops, 0),
            fnum(c.p1_gups, 3),
            fnum(c.dispatch_overhead_ns, 0),
            crossover_label(c.measured_crossover),
            crossover_label(c.model_crossover)
        );
        if threshold.is_none() {
            cfg.shard_threshold = ThresholdMode::Calibrated(c.measured_crossover);
        }
        Some(c)
    } else {
        None
    };
    let service = match DotService::new(cfg.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: cannot build the service: {e}");
            return ExitCode::FAILURE;
        }
    };
    let threshold_label = crossover_label(service.shard_threshold());
    eprintln!(
        "serve-bench: T = {threads}, {requests} requests in batches of {batch}, {} loop, \
         rung {}, shard at n >= {threshold_label} ({}) ...",
        mode.label(),
        service.dot_spec(),
        service.threshold_source().label()
    );
    let report = match run_load(&service, &mix, requests, batch, mode, seed) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: load run failed: {e}");
            return ExitCode::FAILURE;
        }
    };

    // Queue-mode open-loop pair at the same offered load: `sync` retires
    // every dispatch before draining the next arrival batch (pipelined but
    // serialized), `async` overlaps arrival batches with in-flight tails.
    let rate = match (mode, args.opt("rate")) {
        (LoadMode::Open { rate_rps }, _) => rate_rps,
        (LoadMode::Closed, Some(v)) => match v.parse::<f64>() {
            Ok(r) if r > 0.0 => r,
            _ => {
                eprintln!("error: --rate must be a positive number");
                return ExitCode::FAILURE;
            }
        },
        (LoadMode::Closed, None) => (report.reqs_per_s * 0.7).max(1.0),
    };
    let queue_pair = |overlap: bool| -> Result<AsyncLoadReport, String> {
        let opts = AsyncOptions {
            queue_depth,
            batch_window: std::time::Duration::from_micros(batch_window_us),
            batch_max: batch,
            overlap,
            deadline: None,
        };
        let asy = AsyncDotService::new(cfg.clone(), opts)
            .map_err(|e| format!("cannot build the async service: {e}"))?;
        let operands = OperandPool::generate(&mix, seed, asy.service().pool());
        run_load_async(&asy, &mix, &operands, requests, rate, seed)
            .map_err(|e| format!("async load run failed: {e}"))
    };
    eprintln!(
        "serve-bench: queue mode at {} req/s (depth {queue_depth}, window {batch_window_us} us) ...",
        fnum(rate, 0)
    );
    let (qsync, qasync) = match (queue_pair(false), queue_pair(true)) {
        (Ok(s), Ok(a)) => (s, a),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    // Determinism contract (hard): at fixed T all three paths must serve
    // bit-identical results, so the submission-order checksums agree.
    if qsync.load.checksum.to_bits() != report.checksum.to_bits()
        || qasync.load.checksum.to_bits() != report.checksum.to_bits()
    {
        eprintln!(
            "error: checksum parity violated: batch {} / queue-sync {} / queue-async {}",
            report.checksum, qsync.load.checksum, qasync.load.checksum
        );
        return ExitCode::FAILURE;
    }
    let async_p99_ok = qasync.load.latency_p99_ns <= qsync.load.latency_p99_ns;
    if !async_p99_ok {
        eprintln!(
            "warning: async p99 ({} us) exceeds sync p99 ({} us) at the same offered load — \
             expected on idle tails or noisy hosts, worth a look under real load",
            fnum(qasync.load.latency_p99_ns / 1e3, 1),
            fnum(qsync.load.latency_p99_ns / 1e3, 1)
        );
    }

    // Wire row: the same open-loop offered load driven through the TCP
    // front-end (docs/PROTOCOL.md). Unless --wire-addr points at an
    // external server, a private loopback serve-net instance with the
    // exact service config is bound on an ephemeral port — in that case
    // checksum parity with the in-process rows is a hard failure.
    let wire_connections =
        match args.opt_parse("wire-connections", if quick { 2usize } else { 4 }) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("error: {e}");
                return ExitCode::FAILURE;
            }
        };
    let wire_report: Option<WireLoadReport> = if wire_connections == 0 {
        None
    } else {
        let opts = AsyncOptions {
            queue_depth,
            batch_window: std::time::Duration::from_micros(batch_window_us),
            batch_max: batch,
            overlap: true,
            deadline: None,
        };
        let (loopback, wire_addr) = match args.opt("wire-addr") {
            Some(a) => (None, a.to_string()),
            None => match NetServer::bind("127.0.0.1:0", cfg.clone(), opts) {
                Ok(srv) => {
                    let a = srv.local_addr().to_string();
                    (Some(srv), a)
                }
                Err(e) => {
                    eprintln!("error: cannot bind the loopback wire server: {e}");
                    return ExitCode::FAILURE;
                }
            },
        };
        eprintln!(
            "serve-bench: wire row at {} req/s over {wire_connections} connection(s) to \
             {wire_addr}{} ...",
            fnum(rate, 0),
            if loopback.is_some() { " (loopback)" } else { "" }
        );
        // Operand bytes are a function of the seed alone (pool placement
        // only affects NUMA locality), so the wire payloads carry exactly
        // the bytes the in-process rows submitted.
        let operands = OperandPool::generate(&mix, seed, service.pool());
        let fpu = service.dot_spec().class.flops_per_update();
        let w = match run_load_wire(
            &wire_addr,
            &mix,
            &operands,
            requests,
            rate,
            wire_connections,
            fpu,
            seed,
        ) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("error: wire load run failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        if w.load.checksum.to_bits() != report.checksum.to_bits() {
            if loopback.is_some() {
                eprintln!(
                    "error: wire checksum parity violated: wire {} / batch {}",
                    w.load.checksum, report.checksum
                );
                return ExitCode::FAILURE;
            }
            eprintln!(
                "warning: wire checksum ({}) differs from the local runs ({}) — the external \
                 server's kernel config, threads or threshold differ from this bench's",
                w.load.checksum, report.checksum
            );
        }
        drop(loopback);
        Some(w)
    };

    // Tenant QoS scenarios (--tenants): a weight-proportional mixture, an
    // adversarial noisy neighbor, and the scheduling-independence
    // checksums. The mixture and noisy rows are *accounting* (sheds are
    // the point), so they never join the perf gates above; the
    // interleaving block is a hard gate — FIFO, weighted-fair and
    // reversed-priority services must serve bit-identical checksums over
    // the same request set, or scheduling has forked the numerics.
    let tenant_bench: Option<TenantBench> = match &qos {
        None => None,
        Some(policy) => {
            let opts = AsyncOptions {
                queue_depth,
                batch_window: std::time::Duration::from_micros(batch_window_us),
                batch_max: batch,
                overlap: true,
                deadline: None,
            };
            let mk = |p: Option<QosPolicy>| -> Result<AsyncDotService, String> {
                AsyncDotService::new_with_qos(cfg.clone(), opts, p, None)
                    .map_err(|e| format!("cannot build the tenant service: {e}"))
            };
            let operands = OperandPool::generate(&mix, seed, service.pool());
            let watchdog = kahan_ecm::serve::loadgen::default_watchdog(requests, rate);
            let run = |svc: &AsyncDotService, offered: &[usize], r: f64| {
                run_load_tenants(svc, &mix, &operands, offered, r, None, seed, watchdog)
                    .map_err(|e| format!("tenant load run failed: {e}"))
            };
            let total_w: u64 = policy
                .classes()
                .iter()
                .map(|c| u64::from(c.weight.max(1)))
                .sum::<u64>()
                .max(1);
            let weighted_offered: Vec<usize> = policy
                .classes()
                .iter()
                .map(|c| {
                    let share = requests as u64 * u64::from(c.weight.max(1)) / total_w;
                    (share as usize).max(1)
                })
                .collect();
            eprintln!(
                "serve-bench: tenant scenarios over {} class(es) at {} req/s ...",
                policy.classes().len(),
                fnum(rate, 0)
            );
            let weighted =
                match mk(Some(policy.clone())).and_then(|s| run(&s, &weighted_offered, rate)) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("error: {e}");
                        return ExitCode::FAILURE;
                    }
                };
            // Noisy neighbor: the first tenant fires the full request
            // budget at 4x the configured rate; every other tenant rides
            // along with a light stream. The quota must shed the heavy
            // tenant while the light one keeps its weighted share.
            let light = (requests / 8).max(16);
            let mut noisy_offered = vec![light; policy.classes().len()];
            noisy_offered[0] = requests;
            let noisy_rate = rate * 4.0;
            let noisy =
                match mk(Some(policy.clone())).and_then(|s| run(&s, &noisy_offered, noisy_rate)) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("error: {e}");
                        return ExitCode::FAILURE;
                    }
                };
            // Scheduling-independence checksums: the same stream through a
            // FIFO service, this policy, and this policy with the weights
            // reversed (priorities inverted).
            let mut rev_classes = policy.classes().to_vec();
            let rev_weights: Vec<u32> = rev_classes.iter().rev().map(|c| c.weight).collect();
            for (c, w) in rev_classes.iter_mut().zip(rev_weights) {
                c.weight = w;
            }
            let interleave_requests = requests;
            let tenants_n = policy.classes().len() as u32;
            let inter = |p: Option<QosPolicy>| -> Result<InterleavingReport, String> {
                let svc = mk(p)?;
                run_interleaving_checksum(
                    &svc,
                    &mix,
                    &operands,
                    interleave_requests,
                    tenants_n,
                    seed,
                )
                .map_err(|e| format!("interleaving run failed: {e}"))
            };
            let (fifo, fair, reversed) = match (
                inter(None),
                inter(Some(policy.clone())),
                inter(Some(QosPolicy::new(rev_classes))),
            ) {
                (Ok(a), Ok(b), Ok(c)) => (a, b, c),
                (Err(e), _, _) | (_, Err(e), _) | (_, _, Err(e)) => {
                    eprintln!("error: {e}");
                    return ExitCode::FAILURE;
                }
            };
            // Hard gate: any divergence means scheduling touched the math.
            // The FIFO interleaving run also folds the same request stream
            // as the primary batch run, so it must match that checksum too.
            if fifo.checksum.to_bits() != fair.checksum.to_bits()
                || fifo.checksum.to_bits() != reversed.checksum.to_bits()
                || fifo.checksum.to_bits() != report.checksum.to_bits()
            {
                eprintln!(
                    "error: interleaving checksum parity violated: batch {} / fifo {} / \
                     weighted {} / reversed {}",
                    report.checksum, fifo.checksum, fair.checksum, reversed.checksum
                );
                return ExitCode::FAILURE;
            }
            let shed: u64 = noisy.rows.iter().map(|r| r.quota_shed as u64).sum();
            eprintln!(
                "tenants: weighted {} ok of {}, noisy {} quota-shed of {}, interleaving \
                 checksums bit-identical across 3 schedules",
                weighted.rows.iter().map(|r| r.completed_ok).sum::<usize>(),
                weighted.requests,
                shed,
                noisy.requests
            );
            Some(TenantBench {
                weighted,
                noisy,
                noisy_rate,
                interleave_requests,
                fifo,
                fair,
                reversed,
            })
        }
    };

    // Chaos scenario: replay a seeded in-process fault plan against a
    // dedicated service instance and account for every request. The two
    // hard gates are structural, not numeric: no request may hang, and
    // the pipeline must serve bit-identical results again after the
    // faults — so a chaos row never participates in the checksum-parity
    // or perf gates above.
    let chaos: Option<(u64, ChaosReport)> = if args.flag("chaos") {
        let chaos_seed = match args.opt_parse("chaos-seed", seed) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: {e}");
                return ExitCode::FAILURE;
            }
        };
        let opts = AsyncOptions {
            queue_depth,
            batch_window: std::time::Duration::from_micros(batch_window_us),
            batch_max: batch,
            overlap: true,
            deadline: None,
        };
        // Triggers land in 1..=8: early enough that every armed site fires
        // even in a --quick run's handful of dispatches.
        let plan = FaultPlan::seeded(chaos_seed, &FaultSite::IN_PROCESS, 8);
        let injector = FaultInjector::new(plan);
        // The chaos service always runs with a tenant policy (--tenants
        // when given, a 3:1 default otherwise): the starvation-stall site
        // only arms inside the weighted-fair drain, and the quota-reject
        // site needs tenants to account its sheds against.
        let chaos_qos = qos.clone().unwrap_or_else(|| {
            QosPolicy::parse("a:3,b:1")
                .expect("static default tenant policy")
                .with_default_quotas(queue_depth)
        });
        let asy = match AsyncDotService::new_with_qos(
            cfg.clone(),
            opts,
            Some(chaos_qos),
            Some(injector.clone()),
        ) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("error: cannot build the chaos service: {e}");
                return ExitCode::FAILURE;
            }
        };
        eprintln!(
            "serve-bench: chaos scenario at {} req/s, fault seed {chaos_seed} ({} in-process \
             sites) ...",
            fnum(rate, 0),
            FaultSite::IN_PROCESS.len()
        );
        // First-touch operand placement runs jobs through the given pool;
        // use the clean sync service's pool so a seeded low trigger cannot
        // fire while preparing inputs instead of during the measured run.
        let operands = OperandPool::generate(&mix, seed, service.pool());
        let watchdog = kahan_ecm::serve::loadgen::default_watchdog(requests, rate);
        let r = match run_load_chaos(
            &asy,
            &injector,
            &mix,
            &operands,
            requests,
            rate,
            Some(std::time::Duration::from_millis(20)),
            seed,
            watchdog,
        ) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: chaos run failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        eprintln!(
            "chaos: {} ok / {} deadline-shed / {} quota-shed / {} panicked / {} other / {} \
             hung of {} ({} faults injected; recovery {} in {} us)",
            r.completed_ok,
            r.deadline_shed,
            r.quota_shed,
            r.worker_panics,
            r.other_errors,
            r.hung,
            r.requests,
            r.total_injected,
            if r.recovery_verified { "bit-exact" } else { "FAILED" },
            fnum(r.recovery_latency_ns / 1e3, 1)
        );
        if r.hung > 0 {
            eprintln!(
                "error: chaos gate: {} request(s) never resolved — the pipeline wedged",
                r.hung
            );
            return ExitCode::FAILURE;
        }
        if !r.recovery_verified {
            eprintln!("error: chaos gate: post-chaos probe was not bit-identical to the sync path");
            return ExitCode::FAILURE;
        }
        Some((chaos_seed, r))
    } else {
        None
    };

    // Integrity scenario (rides --chaos): the end-to-end corruption
    // detection story. A loopback serve-net instance runs with every
    // verification tier armed — CRC-sealed frames, scrub-on-lookup,
    // verify-on-hit at rate 1.0 — while the three corruption fault sites
    // fire; a fault-free control pass with the same posture follows. The
    // hard gates are detection completeness (every injection caught),
    // delivery purity (zero corrupt payloads reach the client) and
    // specificity (zero false positives on the clean pass).
    let integrity: Option<IntegrityReport> = if args.flag("chaos") {
        let opts = AsyncOptions {
            queue_depth,
            batch_window: std::time::Duration::from_micros(batch_window_us),
            batch_max: batch,
            overlap: true,
            deadline: None,
        };
        let (int_n, int_catalog, int_requests) =
            if quick { (4096, 4, 32) } else { (16384, 8, 96) };
        eprintln!(
            "serve-bench: integrity scenario (catalog {int_catalog} x n={int_n}, \
             {int_requests} draws + clean control, {} corruption sites) ...",
            FaultSite::INTEGRITY.len()
        );
        let r = match run_load_integrity(&cfg, opts, int_n, int_catalog, int_requests, seed) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: integrity run failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        eprintln!(
            "integrity: {} injected / {} detected ({} frame, {} operand, {} cache), {} corrupt \
             delivered, {} re-registered; clean pass: {} detections, parity {}",
            r.total_injected,
            r.detected,
            r.corrupt_frames_detected,
            r.corrupt_operands_detected,
            r.cache_poisoned_evicted,
            r.delivered_corrupt,
            r.reregisters,
            r.clean_detections,
            if r.clean_bit_parity { "bit-exact" } else { "FAILED" }
        );
        if r.detected != r.total_injected {
            eprintln!(
                "error: integrity gate: {} of {} injected corruptions went undetected",
                r.total_injected - r.detected.min(r.total_injected),
                r.total_injected
            );
            return ExitCode::FAILURE;
        }
        if r.delivered_corrupt > 0 {
            eprintln!(
                "error: integrity gate: {} corrupt payload(s) were delivered as results",
                r.delivered_corrupt
            );
            return ExitCode::FAILURE;
        }
        if r.bound_missing > 0 {
            eprintln!(
                "error: integrity gate: {} response(s) lacked the requested certified error bound",
                r.bound_missing
            );
            return ExitCode::FAILURE;
        }
        if r.clean_detections > 0 || !r.clean_bit_parity {
            eprintln!(
                "error: integrity gate: clean pass raised {} false positive(s) (parity {})",
                r.clean_detections,
                if r.clean_bit_parity { "ok" } else { "broken" }
            );
            return ExitCode::FAILURE;
        }
        Some(r)
    } else {
        None
    };

    // Zipf scenario (--zipf): the resident-operand-store story end to end.
    // A dedicated loopback serve-net instance takes a skewed-popularity
    // stream twice — once re-shipping payloads, once submitting 16-byte
    // handle frames against the registered catalog — and the run hard-fails
    // unless every cached value is bit-identical to its recomputed twin.
    let zipf: Option<ZipfReport> = if args.flag("zipf") {
        let zipf_s = match args.opt_parse("zipf-s", 1.2f64) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: {e}");
                return ExitCode::FAILURE;
            }
        };
        let (zipf_n, zipf_catalog, zipf_requests) =
            if quick { (16384, 24, 400) } else { (65536, 48, 1500) };
        let opts = AsyncOptions {
            queue_depth,
            batch_window: std::time::Duration::from_micros(batch_window_us),
            batch_max: batch,
            overlap: true,
            deadline: None,
        };
        let srv = match NetServer::bind("127.0.0.1:0", cfg.clone(), opts) {
            Ok(srv) => srv,
            Err(e) => {
                eprintln!("error: cannot bind the zipf loopback server: {e}");
                return ExitCode::FAILURE;
            }
        };
        let zipf_addr = srv.local_addr().to_string();
        eprintln!(
            "serve-bench: zipf scenario (s={}, catalog {zipf_catalog} x n={zipf_n}, \
             {zipf_requests} draws/pass) at {zipf_addr} (loopback) ...",
            fnum(zipf_s, 2)
        );
        let r = match run_load_zipf(&zipf_addr, zipf_n, zipf_catalog, zipf_requests, zipf_s, seed)
        {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: zipf run failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        drop(srv);
        eprintln!(
            "zipf: baseline {} req/s ({} B/req) vs handles {} req/s ({} B/req) — {}x, \
             {} hit / {} miss of {} lookups, parity {}",
            fnum(r.baseline.reqs_per_s, 0),
            fnum(r.baseline.bytes_per_request, 0),
            fnum(r.handles.reqs_per_s, 0),
            fnum(r.handles.bytes_per_request, 0),
            fnum(r.speedup, 2),
            r.cache.cache_hits,
            r.cache.cache_misses,
            r.cache.cache_lookups,
            if r.bit_parity { "bit-exact" } else { "FAILED" }
        );
        // Hard gate: the cache may change *when* a value is computed,
        // never *what* it is (docs/ARCHITECTURE.md).
        if !r.bit_parity {
            eprintln!(
                "error: zipf gate: cached pass diverged from the baseline ({} of {} values; \
                 checksums {} / {})",
                r.value_mismatches, r.requests, r.baseline.checksum, r.handles.checksum
            );
            return ExitCode::FAILURE;
        }
        // Structural sanity, not perf: every lookup is a hit or a miss,
        // and a skewed draw over a small catalog must repeat itself.
        if r.cache.cache_hits + r.cache.cache_misses != r.cache.cache_lookups
            || r.cache.cache_hits == 0
        {
            eprintln!(
                "error: zipf gate: cache counters inconsistent ({} hits + {} misses vs {} \
                 lookups)",
                r.cache.cache_hits, r.cache.cache_misses, r.cache.cache_lookups
            );
            return ExitCode::FAILURE;
        }
        Some(r)
    } else {
        None
    };

    let mut t = Table::new(["metric", "value"]);
    t.row(["kernel".to_string(), service.dot_spec().id()]);
    t.row(["threads".to_string(), threads.to_string()]);
    t.row(["shard threshold".to_string(), threshold_label.clone()]);
    t.row(["requests".to_string(), report.requests.to_string()]);
    t.row(["batches".to_string(), report.batches.to_string()]);
    t.row(["fused".to_string(), report.fused.to_string()]);
    t.row(["sharded".to_string(), report.sharded.to_string()]);
    let us = |ns: f64| fnum(ns / 1e3, 1);
    t.row(["p50 us".to_string(), us(report.latency_p50_ns)]);
    t.row(["p90 us".to_string(), us(report.latency_p90_ns)]);
    t.row(["p99 us".to_string(), us(report.latency_p99_ns)]);
    t.row(["max us".to_string(), us(report.latency_max_ns)]);
    t.row(["MFlop/s".to_string(), fnum(report.mflops, 0)]);
    t.row(["GUP/s".to_string(), fnum(report.gups, 3)]);
    t.row(["req/s".to_string(), fnum(report.reqs_per_s, 0)]);
    print!("{}", t.to_text());

    let mut qt = Table::new([
        "queue row", "p50 us", "p99 us", "max us", "MFlop/s", "req/s", "util", "max depth",
    ]);
    for (name, r) in [("sync", &qsync), ("async", &qasync)] {
        qt.row([
            name.to_string(),
            us(r.load.latency_p50_ns),
            us(r.load.latency_p99_ns),
            us(r.load.latency_max_ns),
            fnum(r.load.mflops, 0),
            fnum(r.load.reqs_per_s, 0),
            fnum(r.pool_utilization, 2),
            r.max_queue_depth.to_string(),
        ]);
    }
    if let Some(w) = &wire_report {
        qt.row([
            "wire".to_string(),
            us(w.load.latency_p50_ns),
            us(w.load.latency_p99_ns),
            us(w.load.latency_max_ns),
            fnum(w.load.mflops, 0),
            fnum(w.load.reqs_per_s, 0),
            fnum(w.pool_utilization, 2),
            w.max_queue_depth.to_string(),
        ]);
    }
    print!("{}", qt.to_text());

    if let Some(tb) = &tenant_bench {
        let mut tt = Table::new([
            "scenario", "tenant", "w", "quota", "offered", "admitted", "ok", "quota shed",
            "p50 us", "p99 us",
        ]);
        for (scenario, rep) in [("weighted", &tb.weighted), ("noisy", &tb.noisy)] {
            for r in &rep.rows {
                tt.row([
                    scenario.to_string(),
                    r.name.clone(),
                    r.weight.to_string(),
                    r.quota.map(|q| q.to_string()).unwrap_or_else(|| "-".to_string()),
                    r.offered.to_string(),
                    r.admitted.to_string(),
                    r.completed_ok.to_string(),
                    r.quota_shed.to_string(),
                    us(r.latency_p50_ns),
                    us(r.latency_p99_ns),
                ]);
            }
        }
        print!("{}", tt.to_text());
    }

    let mut mix_json = Vec::new();
    for e in &mix {
        let mut obj = BTreeMap::new();
        obj.insert("n".to_string(), Json::Num(e.n as f64));
        obj.insert("weight".to_string(), Json::Num(e.weight));
        mix_json.push(Json::Obj(obj));
    }
    let mut lat = BTreeMap::new();
    lat.insert("p50".to_string(), Json::Num(report.latency_p50_ns));
    lat.insert("p90".to_string(), Json::Num(report.latency_p90_ns));
    lat.insert("p99".to_string(), Json::Num(report.latency_p99_ns));
    lat.insert("max".to_string(), Json::Num(report.latency_max_ns));
    let mut root = BTreeMap::new();
    root.insert("subsystem".to_string(), Json::Str("serve".to_string()));
    root.insert("backend".to_string(), Json::Str("native-mt".to_string()));
    root.insert("kernel".to_string(), Json::Str(service.dot_spec().id()));
    root.insert("threads".to_string(), Json::Num(threads as f64));
    root.insert("compensated".to_string(), Json::Bool(service.compensated()));
    root.insert(
        "shard_threshold".to_string(),
        crossover_json(service.shard_threshold()),
    );
    root.insert(
        "threshold_source".to_string(),
        Json::Str(service.threshold_source().label().to_string()),
    );
    root.insert("mode".to_string(), Json::Str(mode.label().to_string()));
    root.insert(
        "rate_rps".to_string(),
        match mode {
            LoadMode::Open { rate_rps } => Json::Num(rate_rps),
            LoadMode::Closed => Json::Null,
        },
    );
    root.insert("requests".to_string(), Json::Num(report.requests as f64));
    root.insert("batch".to_string(), Json::Num(batch as f64));
    root.insert("batches".to_string(), Json::Num(report.batches as f64));
    root.insert("seed".to_string(), Json::Num(seed as f64));
    root.insert("freq_ghz".to_string(), Json::Num(freq));
    root.insert(
        "freq_source".to_string(),
        Json::Str(freq_src.label().to_string()),
    );
    root.insert("mix".to_string(), Json::Arr(mix_json));
    root.insert("fused".to_string(), Json::Num(report.fused as f64));
    root.insert("sharded".to_string(), Json::Num(report.sharded as f64));
    root.insert("latency_ns".to_string(), Json::Obj(lat));
    root.insert("busy_ns".to_string(), Json::Num(report.busy_ns));
    root.insert("elapsed_ns".to_string(), Json::Num(report.elapsed_ns));
    root.insert("updates".to_string(), Json::Num(report.updates as f64));
    root.insert("flops".to_string(), Json::Num(report.flops as f64));
    root.insert("mflops".to_string(), Json::Num(report.mflops));
    root.insert("gups".to_string(), Json::Num(report.gups));
    root.insert("reqs_per_s".to_string(), Json::Num(report.reqs_per_s));
    root.insert("checksum".to_string(), Json::Num(report.checksum));

    let mut queue_obj = BTreeMap::new();
    queue_obj.insert("depth".to_string(), Json::Num(queue_depth as f64));
    queue_obj.insert(
        "batch_window_us".to_string(),
        Json::Num(batch_window_us as f64),
    );
    queue_obj.insert("batch_max".to_string(), Json::Num(batch as f64));
    root.insert("queue".to_string(), Json::Obj(queue_obj));
    let mut open_loop = BTreeMap::new();
    open_loop.insert("rate_rps".to_string(), Json::Num(rate));
    open_loop.insert("sync".to_string(), queue_row_json(&qsync));
    open_loop.insert("async".to_string(), queue_row_json(&qasync));
    root.insert("open_loop".to_string(), Json::Obj(open_loop));
    if let Some(w) = &wire_report {
        root.insert("wire".to_string(), wire_row_json(w));
    }
    root.insert("async_p99_ok".to_string(), Json::Bool(async_p99_ok));
    if let Some(tb) = &tenant_bench {
        let policy = qos.as_ref().expect("tenant bench implies a policy");
        let mut pol_rows = Vec::new();
        for (i, c) in policy.classes().iter().enumerate() {
            let mut obj = BTreeMap::new();
            obj.insert("tenant".to_string(), Json::Num(i as f64));
            obj.insert("name".to_string(), Json::Str(c.name.clone()));
            obj.insert("weight".to_string(), Json::Num(f64::from(c.weight)));
            obj.insert(
                "quota".to_string(),
                c.quota.map(|q| Json::Num(q as f64)).unwrap_or(Json::Null),
            );
            pol_rows.push(Json::Obj(obj));
        }
        let mut scenarios = BTreeMap::new();
        scenarios.insert(
            "weighted".to_string(),
            tenant_scenario_json(&tb.weighted, rate),
        );
        scenarios.insert(
            "noisy".to_string(),
            tenant_scenario_json(&tb.noisy, tb.noisy_rate),
        );
        let mut inter = BTreeMap::new();
        inter.insert(
            "requests".to_string(),
            Json::Num(tb.interleave_requests as f64),
        );
        inter.insert("fifo".to_string(), Json::Num(tb.fifo.checksum));
        inter.insert("weighted".to_string(), Json::Num(tb.fair.checksum));
        inter.insert("reversed".to_string(), Json::Num(tb.reversed.checksum));
        // Hard-gated above: the artifact only exists when the three agree.
        inter.insert(
            "match".to_string(),
            Json::Bool(
                tb.fifo.checksum.to_bits() == tb.fair.checksum.to_bits()
                    && tb.fifo.checksum.to_bits() == tb.reversed.checksum.to_bits(),
            ),
        );
        let mut obj = BTreeMap::new();
        obj.insert("policy".to_string(), Json::Arr(pol_rows));
        obj.insert("scenarios".to_string(), Json::Obj(scenarios));
        obj.insert("interleaving".to_string(), Json::Obj(inter));
        root.insert("tenants".to_string(), Json::Obj(obj));
    }
    if let Some((chaos_seed, r)) = &chaos {
        let mut injected = BTreeMap::new();
        for (label, count) in &r.injected {
            injected.insert((*label).to_string(), Json::Num(*count as f64));
        }
        let mut recovery = BTreeMap::new();
        recovery.insert("verified".to_string(), Json::Bool(r.recovery_verified));
        recovery.insert("latency_ns".to_string(), Json::Num(r.recovery_latency_ns));
        let mut obj = BTreeMap::new();
        obj.insert("seed".to_string(), Json::Num(*chaos_seed as f64));
        obj.insert("requests".to_string(), Json::Num(r.requests as f64));
        obj.insert("completed_ok".to_string(), Json::Num(r.completed_ok as f64));
        obj.insert("deadline_shed".to_string(), Json::Num(r.deadline_shed as f64));
        obj.insert("quota_shed".to_string(), Json::Num(r.quota_shed as f64));
        obj.insert("worker_panics".to_string(), Json::Num(r.worker_panics as f64));
        obj.insert("other_errors".to_string(), Json::Num(r.other_errors as f64));
        obj.insert("hung_requests".to_string(), Json::Num(r.hung as f64));
        obj.insert("injected".to_string(), Json::Obj(injected));
        obj.insert(
            "total_injected".to_string(),
            Json::Num(r.total_injected as f64),
        );
        obj.insert("recovery".to_string(), Json::Obj(recovery));
        root.insert("chaos".to_string(), Json::Obj(obj));
    }
    if let Some(r) = &integrity {
        let mut injected = BTreeMap::new();
        for (label, count) in &r.injected {
            injected.insert((*label).to_string(), Json::Num(*count as f64));
        }
        let mut detected = BTreeMap::new();
        detected.insert(
            "corrupt_frames".to_string(),
            Json::Num(r.corrupt_frames_detected as f64),
        );
        detected.insert(
            "corrupt_operands".to_string(),
            Json::Num(r.corrupt_operands_detected as f64),
        );
        detected.insert(
            "cache_poisoned".to_string(),
            Json::Num(r.cache_poisoned_evicted as f64),
        );
        let mut scrub = BTreeMap::new();
        scrub.insert(
            "scrub_verified".to_string(),
            Json::Num(r.scrub.scrub_verified as f64),
        );
        scrub.insert(
            "scrub_quarantined".to_string(),
            Json::Num(r.scrub.scrub_quarantined as f64),
        );
        scrub.insert(
            "scrub_passes".to_string(),
            Json::Num(r.scrub.scrub_passes as f64),
        );
        scrub.insert(
            "cache_verified".to_string(),
            Json::Num(r.scrub.cache_verified as f64),
        );
        scrub.insert(
            "cache_poisoned".to_string(),
            Json::Num(r.scrub.cache_poisoned as f64),
        );
        let mut clean = BTreeMap::new();
        clean.insert("requests".to_string(), Json::Num(r.clean_requests as f64));
        clean.insert(
            "detections".to_string(),
            Json::Num(r.clean_detections as f64),
        );
        clean.insert("bit_parity".to_string(), Json::Bool(r.clean_bit_parity));
        let mut obj = BTreeMap::new();
        obj.insert("requests".to_string(), Json::Num(r.requests as f64));
        obj.insert("catalog".to_string(), Json::Num(r.catalog as f64));
        obj.insert("n".to_string(), Json::Num(r.n as f64));
        obj.insert("injected".to_string(), Json::Obj(injected));
        obj.insert(
            "total_injected".to_string(),
            Json::Num(r.total_injected as f64),
        );
        obj.insert("total_detected".to_string(), Json::Num(r.detected as f64));
        obj.insert("detected".to_string(), Json::Obj(detected));
        obj.insert(
            "delivered_corrupt".to_string(),
            Json::Num(r.delivered_corrupt as f64),
        );
        obj.insert("completed_ok".to_string(), Json::Num(r.completed_ok as f64));
        obj.insert("reregisters".to_string(), Json::Num(r.reregisters as f64));
        obj.insert("retries".to_string(), Json::Num(r.retries as f64));
        obj.insert(
            "bound_missing".to_string(),
            Json::Num(r.bound_missing as f64),
        );
        obj.insert("scrub".to_string(), Json::Obj(scrub));
        obj.insert("clean".to_string(), Json::Obj(clean));
        root.insert("integrity".to_string(), Json::Obj(obj));
    }
    if let Some(r) = &zipf {
        let pass = |p: &kahan_ecm::serve::ZipfPassReport| {
            let mut obj = BTreeMap::new();
            obj.insert("elapsed_ns".to_string(), Json::Num(p.elapsed_ns));
            obj.insert("reqs_per_s".to_string(), Json::Num(p.reqs_per_s));
            obj.insert("bytes_sent".to_string(), Json::Num(p.bytes_sent as f64));
            obj.insert(
                "bytes_per_request".to_string(),
                Json::Num(p.bytes_per_request),
            );
            obj.insert("latency_p50_ns".to_string(), Json::Num(p.latency_p50_ns));
            obj.insert("latency_p99_ns".to_string(), Json::Num(p.latency_p99_ns));
            obj.insert("checksum".to_string(), Json::Num(p.checksum));
            Json::Obj(obj)
        };
        let mut cache = BTreeMap::new();
        cache.insert(
            "store_entries".to_string(),
            Json::Num(r.cache.store_entries as f64),
        );
        cache.insert(
            "store_resident_bytes".to_string(),
            Json::Num(r.cache.store_resident_bytes as f64),
        );
        cache.insert(
            "store_registered".to_string(),
            Json::Num(r.cache.store_registered as f64),
        );
        cache.insert(
            "store_evictions".to_string(),
            Json::Num(r.cache.store_evictions as f64),
        );
        cache.insert("lookups".to_string(), Json::Num(r.cache.cache_lookups as f64));
        cache.insert("hits".to_string(), Json::Num(r.cache.cache_hits as f64));
        cache.insert("misses".to_string(), Json::Num(r.cache.cache_misses as f64));
        cache.insert(
            "evictions".to_string(),
            Json::Num(r.cache.cache_evictions as f64),
        );
        let mut obj = BTreeMap::new();
        obj.insert("s".to_string(), Json::Num(r.zipf_s));
        obj.insert("n".to_string(), Json::Num(r.n as f64));
        obj.insert("catalog".to_string(), Json::Num(r.catalog as f64));
        obj.insert("requests".to_string(), Json::Num(r.requests as f64));
        obj.insert(
            "unique_pairs_drawn".to_string(),
            Json::Num(r.unique_pairs_drawn as f64),
        );
        obj.insert("baseline".to_string(), pass(&r.baseline));
        obj.insert("handles".to_string(), pass(&r.handles));
        obj.insert("speedup".to_string(), Json::Num(r.speedup));
        obj.insert("register_ns".to_string(), Json::Num(r.register_ns));
        obj.insert(
            "register_bytes".to_string(),
            Json::Num(r.register_bytes as f64),
        );
        obj.insert(
            "value_mismatches".to_string(),
            Json::Num(r.value_mismatches as f64),
        );
        // Hard-gated above: the artifact only exists when parity holds.
        obj.insert("bit_parity".to_string(), Json::Bool(r.bit_parity));
        obj.insert("cache".to_string(), Json::Obj(cache));
        root.insert("zipf".to_string(), Json::Obj(obj));
    }
    if let Some(c) = calibration {
        let mut measured = BTreeMap::new();
        measured.insert("p1_gups".to_string(), Json::Num(c.p1_gups));
        measured.insert("p1_mflops".to_string(), Json::Num(c.p1_mflops));
        measured.insert("p1_n".to_string(), Json::Num(c.p1_n as f64));
        measured.insert(
            "dispatch_overhead_ns".to_string(),
            Json::Num(c.dispatch_overhead_ns),
        );
        measured.insert("crossover".to_string(), crossover_json(c.measured_crossover));
        let mut model = BTreeMap::new();
        model.insert(
            "p1_gups".to_string(),
            c.model_p1_gups.map(Json::Num).unwrap_or(Json::Null),
        );
        model.insert(
            "dispatch_overhead_ns".to_string(),
            Json::Num(kahan_ecm::serve::crossover::DEFAULT_DISPATCH_OVERHEAD_NS),
        );
        model.insert("crossover".to_string(), crossover_json(c.model_crossover));
        let mut cal = BTreeMap::new();
        cal.insert("measured".to_string(), Json::Obj(measured));
        cal.insert("model".to_string(), Json::Obj(model));
        root.insert("calibration".to_string(), Json::Obj(cal));
    }
    let doc = Json::Obj(root);
    if let Err(e) = std::fs::write(&out_path, format!("{doc}\n")) {
        eprintln!("error: cannot write {out_path}: {e}");
        return ExitCode::FAILURE;
    }
    println!(
        "\nserved {} requests ({} fused, {} sharded; queue-mode async p99 {} us vs sync {} us) \
         -> {out_path}",
        report.requests,
        report.fused,
        report.sharded,
        fnum(qasync.load.latency_p99_ns / 1e3, 1),
        fnum(qsync.load.latency_p99_ns / 1e3, 1)
    );
    if let Some(w) = &wire_report {
        println!(
            "wire: {} connection(s), p99 {} us, {} req/s, {} BUSY retries",
            w.connections,
            fnum(w.load.latency_p99_ns / 1e3, 1),
            fnum(w.load.reqs_per_s, 0),
            w.busy_retries
        );
    }
    if let Some(r) = &zipf {
        println!(
            "zipf: handle submits {}x the payload baseline ({} vs {} req/s, {} vs {} B/req), \
             cached pass bit-exact",
            fnum(r.speedup, 2),
            fnum(r.handles.reqs_per_s, 0),
            fnum(r.baseline.reqs_per_s, 0),
            fnum(r.handles.bytes_per_request, 0),
            fnum(r.baseline.bytes_per_request, 0)
        );
    }
    if let Some(tb) = &tenant_bench {
        let heavy = &tb.noisy.rows[0];
        let light = tb.noisy.rows.last().expect("noisy scenario has rows");
        println!(
            "tenants: noisy neighbor '{}' quota-shed {} of {}; light tenant '{}' p99 {} us; \
             interleaving checksums bit-identical across fifo/weighted/reversed",
            heavy.name,
            heavy.quota_shed,
            heavy.offered,
            light.name,
            fnum(light.latency_p99_ns / 1e3, 1)
        );
    }
    ExitCode::SUCCESS
}

fn cmd_serve_net(raw: Vec<String>) -> ExitCode {
    let args = match serve_net_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let avail = ThreadPool::available();
    let threads = match args.opt_parse("threads", avail) {
        Ok(t) if t >= 1 => t,
        Ok(_) => {
            eprintln!("error: --threads must be >= 1");
            return ExitCode::FAILURE;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let threshold = match args.opt("threshold") {
        Some(v) => match v.parse::<usize>() {
            Ok(t) => Some(t),
            Err(_) => {
                eprintln!("error: --threshold expects a non-negative integer");
                return ExitCode::FAILURE;
            }
        },
        None => None,
    };
    let queue_depth = match args.opt_parse("queue-depth", 256usize) {
        Ok(v) if v >= 1 => v,
        _ => {
            eprintln!("error: --queue-depth must be a positive integer");
            return ExitCode::FAILURE;
        }
    };
    let batch_window_us = match args.opt_parse("batch-window-us", 100u64) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let batch = match args.opt_parse("batch", 64usize) {
        Ok(v) if v >= 1 => v,
        _ => {
            eprintln!("error: --batch must be a positive integer");
            return ExitCode::FAILURE;
        }
    };
    let (freq, freq_src) = match parse_freq_arg(&args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let addr = args.opt_or("addr", "127.0.0.1:4990").to_string();
    let parse_ms = |name: &str| -> Result<Option<u64>, String> {
        match args.opt(name) {
            None => Ok(None),
            Some(v) => match v.parse::<u64>() {
                Ok(ms) if ms >= 1 => Ok(Some(ms)),
                _ => Err(format!("--{name} expects a positive millisecond count")),
            },
        }
    };
    let (read_timeout_ms, idle_timeout_ms, write_timeout_ms) = match (
        parse_ms("read-timeout-ms"),
        parse_ms("idle-timeout-ms"),
        parse_ms("write-timeout-ms"),
    ) {
        (Ok(r), Ok(i), Ok(w)) => (r, i, w),
        (Err(e), _, _) | (_, Err(e), _) | (_, _, Err(e)) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };

    let verify_hit_rate = match args.opt_parse("verify-hit-rate", 0.0f64) {
        Ok(r) if (0.0..=1.0).contains(&r) => r,
        Ok(_) => {
            eprintln!("error: --verify-hit-rate must lie in 0..=1");
            return ExitCode::FAILURE;
        }
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };

    let cfg = ServeConfig {
        threads,
        style: preferred_kahan_style(SimdCaps::detect()),
        compensated: !args.flag("naive"),
        shard_threshold: match threshold {
            Some(t) => ThresholdMode::Fixed(t),
            None => ThresholdMode::Model,
        },
        freq_ghz: freq,
        verify_hit_rate,
    };
    let opts = AsyncOptions {
        queue_depth,
        batch_window: std::time::Duration::from_micros(batch_window_us),
        batch_max: batch,
        overlap: true,
        deadline: None,
    };
    let qos = match parse_tenants_arg(&args, queue_depth) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let qos_label = qos.as_ref().map(|p| {
        p.classes()
            .iter()
            .map(|c| format!("{}:{}", c.name, c.weight))
            .collect::<Vec<_>>()
            .join(",")
    });
    let net = NetOptions {
        read_timeout: read_timeout_ms.map(std::time::Duration::from_millis),
        idle_timeout: idle_timeout_ms.map(std::time::Duration::from_millis),
        write_timeout: write_timeout_ms.map(std::time::Duration::from_millis),
        qos,
        ..NetOptions::default()
    };
    let server = match NetServer::bind_with(&addr, cfg, opts, net) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: cannot bind {addr}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let svc = server.service().service();
    eprintln!(
        "serve-net: T = {threads}, rung {}, shard at n >= {} ({}), queue depth {queue_depth}, \
         window {batch_window_us} us, clock {freq:.2} GHz ({}){}{}",
        svc.dot_spec(),
        crossover_label(svc.shard_threshold()),
        svc.threshold_source().label(),
        freq_src.label(),
        qos_label
            .map(|l| format!(", tenants {l}"))
            .unwrap_or_default(),
        if verify_hit_rate > 0.0 {
            format!(", verify-hit rate {verify_hit_rate}")
        } else {
            String::new()
        }
    );
    // Parseable by scripts (tools/bench-smoke): the actual bound address,
    // which differs from --addr when port 0 asked for an ephemeral port.
    println!(
        "serve-net: listening on {} (wire protocol v{}, docs/PROTOCOL.md)",
        server.local_addr(),
        codec::VERSION
    );
    // Serve until killed: the acceptor and per-connection threads own all
    // the work; this thread only keeps `server` (and the listener) alive.
    loop {
        std::thread::park();
    }
}

fn machine_and_kernel(
    args: &kahan_ecm::util::cli::Args,
) -> Result<(arch::Machine, Variant, Precision, MemLevel), String> {
    let m = arch::presets::by_shorthand(args.opt_or("machine", "HSW"))
        .ok_or_else(|| format!("unknown machine '{}'", args.opt_or("machine", "HSW")))?;
    let v = parse_variant(args.opt_or("variant", "kahan-fma5"))
        .ok_or_else(|| format!("unknown variant '{}'", args.opt_or("variant", "kahan-fma5")))?;
    let prec = match args.opt_or("prec", "sp") {
        "sp" => Precision::Sp,
        "dp" => Precision::Dp,
        p => return Err(format!("unknown precision '{p}'")),
    };
    let level = match args.opt_or("level", "mem") {
        "l1" => MemLevel::L1,
        "l2" => MemLevel::L2,
        "mem" => MemLevel::Mem,
        l => return Err(format!("unknown level '{l}'")),
    };
    Ok((m, v, prec, level))
}

fn print_ecm(m: &arch::Machine, v: Variant, prec: Precision, level: MemLevel) {
    let inputs = ecm::derive::paper_row(m, v, prec, level);
    let pred = inputs.predict();
    let sat = ecm::scaling::saturation(m, &inputs);
    println!("machine   : {} ({})", m.shorthand, m.name);
    println!("kernel    : {} [{}]", inputs.kernel, prec.label());
    println!("ECM input : {}", inputs.shorthand());
    println!("prediction: {}", pred.shorthand());
    if let Some(lo) = pred.mem_lower {
        println!(
            "mem band  : {} .. {} cy (eviction overlap)",
            fnum(lo, 1),
            fnum(pred.mem_cycles(), 1)
        );
    }
    let gups: Vec<String> = pred
        .performance_gups(m.freq_ghz)
        .into_iter()
        .map(|(n, g)| format!("{n}: {}", fnum(g, 2)))
        .collect();
    println!("GUP/s     : {}", gups.join(" | "));
    println!(
        "saturation: sigma = {}, n_s = {}/domain = {}/chip, P_sat = {} GUP/s/chip",
        fnum(sat.sigma, 2),
        sat.n_s,
        sat.n_s_chip,
        fnum(sat.p_sat_chip, 2)
    );
}

fn cmd_ecm(raw: Vec<String>) -> ExitCode {
    let args = match ecm_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    match machine_and_kernel(&args) {
        Ok((m, v, prec, level)) => {
            print_ecm(&m, v, prec, level);
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_sweep(raw: Vec<String>) -> ExitCode {
    let args = match ecm_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let (m, v, prec, level) = match machine_and_kernel(&args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let smt = args.opt_parse("smt", 1u32).unwrap_or(1);
    let k = ecm::derive::kernel_for(&m, v, prec, level);
    let sizes = sim::default_sweep_sizes(GIB);
    let pts = sim::sweep(&m, &k, &sizes, &MeasureOpts { smt, untuned: false, seed: 1 });
    let mut t = Table::new(["ws_bytes", "cy/CL", "GUP/s"]);
    for p in pts.iter().step_by(4) {
        t.row([
            p.ws_bytes.to_string(),
            fnum(p.cy_per_cl, 2),
            fnum(p.gups, 3),
        ]);
    }
    print!("{}", t.to_text());
    ExitCode::SUCCESS
}

fn cmd_custom(raw: Vec<String>) -> ExitCode {
    let args = match ecm_spec().parse(raw) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let Some(path) = args.opt("config") else {
        eprintln!("error: --config FILE is required (see configs/example_machine.toml)");
        return ExitCode::FAILURE;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: cannot read {path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let m = match loader::machine_from_config(&text) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!("loaded machine '{}' from {path}\n", m.name);
    for v in [
        Variant::NaiveSimd,
        Variant::KahanSimd,
        Variant::KahanSimdFma5,
        Variant::KahanScalar,
    ] {
        let prec = match args.opt_or("prec", "sp") {
            "dp" => Precision::Dp,
            _ => Precision::Sp,
        };
        print_ecm(&m, v, prec, MemLevel::Mem);
        println!();
    }
    ExitCode::SUCCESS
}

fn cmd_info() -> ExitCode {
    println!("kahan-ecm {} — Kahan/ECM reproduction", env!("CARGO_PKG_VERSION"));
    println!("paper: DOI 10.1002/cpe.3921 (Hofmann, Fey, Riedmann, Eitzinger, Hager, Wellein)");
    println!("machines: HSW, BDW, KNC, PWR8 (+HOST, +custom configs)");
    let native = NativeBackend::new();
    println!(
        "backend: native ({} kernels, avx2 = {}, avx512 = {}, clock = {})",
        native.kernels().len(),
        native.has_avx2(),
        native.has_avx512(),
        detect_freq_ghz()
            .map(|f| format!("{f:.2} GHz"))
            .unwrap_or_else(|| "unknown".to_string())
    );
    println!(
        "backend: pjrt {}",
        if cfg!(feature = "pjrt") {
            "(feature enabled; needs artifacts + a real xla crate)"
        } else {
            "(disabled; build with --features pjrt)"
        }
    );
    match kahan_ecm::runtime::Manifest::load("artifacts") {
        Ok(m) => println!(
            "artifacts: {} kernels (jax {}) in ./artifacts",
            m.artifacts.len(),
            m.jax_version
        ),
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", usage());
        return ExitCode::SUCCESS;
    }
    let cmd = argv.remove(0);
    match cmd.as_str() {
        "list" => cmd_list(),
        "run" => cmd_run(argv),
        "bench-native" => cmd_bench_native(argv),
        "bench-scale" => cmd_bench_scale(argv),
        "serve-bench" => cmd_serve_bench(argv),
        "serve-net" => cmd_serve_net(argv),
        "ecm" => cmd_ecm(argv),
        "sweep" => cmd_sweep(argv),
        "custom" => cmd_custom(argv),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            ExitCode::FAILURE
        }
    }
}
