//! Minimal property-testing framework (proptest is not in the offline crate
//! cache; DESIGN.md §2).
//!
//! Usage (`no_run`: doctest binaries do not inherit the xla_extension
//! rpath in this environment; the same code runs in unit tests):
//! ```no_run
//! use kahan_ecm::ptest::{property, Gen};
//! property("abs is non-negative", 200, |g| {
//!     let x = g.f64_range(-1e9, 1e9);
//!     assert!(x.abs() >= 0.0, "x = {x}");
//! });
//! ```
//!
//! Each case draws from a deterministic per-case RNG; on failure the case
//! seed is reported so the exact inputs can be replayed with
//! [`replay`]. A lightweight "shrink" pass retries the failing predicate
//! with earlier case indices' seeds scaled toward simpler magnitudes — we
//! don't implement structural shrinking, but failures are always
//! reproducible, which is the property that matters for CI.

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::util::rng::Rng;

/// Value generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn new(seed: u64, case: usize) -> Self {
        Self {
            rng: Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            case,
        }
    }

    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Float with exponent spread uniformly over [2^lo_exp, 2^hi_exp],
    /// random sign — the distribution that actually exercises floating-point
    /// edge cases (uniform floats almost all share one exponent).
    pub fn f64_log(&mut self, lo_exp: i32, hi_exp: i32) -> f64 {
        let e = self.rng.range_f64(lo_exp as f64, hi_exp as f64);
        let m = 1.0 + self.rng.f64();
        let s = if self.rng.bool() { 1.0 } else { -1.0 };
        s * m * 2f64.powf(e)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool()
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_range(lo, hi)).collect()
    }

    pub fn vec_f64_log(&mut self, len: usize, lo_exp: i32, hi_exp: i32) -> Vec<f64> {
        (0..len).map(|_| self.f64_log(lo_exp, hi_exp)).collect()
    }
}

/// Environment knobs: `PTEST_SEED` overrides the base seed,
/// `PTEST_CASES` overrides the per-property case count.
fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

pub const DEFAULT_SEED: u64 = 0xECA1_2016;

/// Run `cases` randomized cases of `f`; panics (with seed/case info) on the
/// first failing case.
pub fn property<F: Fn(&mut Gen)>(name: &str, cases: usize, f: F) {
    let seed = env_u64("PTEST_SEED").unwrap_or(DEFAULT_SEED);
    let cases = env_u64("PTEST_CASES").map(|c| c as usize).unwrap_or(cases);
    for case in 0..cases {
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, case);
            f(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: PTEST_SEED={seed} case {case}): {msg}"
            );
        }
    }
}

/// Re-run a single case (for debugging a reported failure).
pub fn replay<F: Fn(&mut Gen)>(seed: u64, case: usize, f: F) {
    let mut g = Gen::new(seed, case);
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("sum symmetric", 50, |g| {
            let a = g.f64_range(-1e6, 1e6);
            let b = g.f64_range(-1e6, 1e6);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_case() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            property("always fails", 3, |_| panic!("boom"));
        }));
        let msg = match r {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("should have failed"),
        };
        assert!(msg.contains("always fails"), "{msg}");
        assert!(msg.contains("replay"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn deterministic_cases() {
        let mut a = Gen::new(1, 5);
        let mut b = Gen::new(1, 5);
        for _ in 0..20 {
            assert_eq!(a.u64(0, 1000), b.u64(0, 1000));
        }
    }

    #[test]
    fn log_floats_span_exponents() {
        let mut g = Gen::new(3, 0);
        let xs: Vec<f64> = (0..200).map(|_| g.f64_log(-20, 20).abs()).collect();
        let min = xs.iter().cloned().fold(f64::MAX, f64::min);
        let max = xs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max / min > 1e6, "span {min}..{max}");
    }

    #[test]
    fn replay_matches_property_case() {
        let seen = std::cell::RefCell::new(Vec::new());
        property("record", 3, |g| {
            let v = g.u64(0, u64::MAX - 1);
            if g.case == 2 {
                seen.borrow_mut().push(v);
            }
        });
        let seen = seen.into_inner();
        replay(DEFAULT_SEED, 2, |g| {
            let v = g.u64(0, u64::MAX - 1);
            assert_eq!(v, seen[0]);
        });
    }
}
