//! 64-byte-aligned operand arena for the measured kernel paths.
//!
//! `Vec<f64>` guarantees only element alignment (8 bytes), so explicit-SIMD
//! kernels must use unaligned loads and thread-parallel chunk boundaries
//! can straddle cache lines. [`AlignedVec`] allocates through a manual
//! [`std::alloc::Layout`] with [`ALIGN`]-byte (cache-line / AVX-512 vector)
//! alignment instead, which buys the whole measured path three properties:
//!
//! * every `_mm256`/`_mm512` load in the kernel hot loops takes the
//!   aligned fast path (`loadu` becomes `load` — the kernels probe the
//!   base pointer once per call, see `runtime::backend::native`);
//! * the cache-line-aligned chunk partition of
//!   [`ThreadPool`](crate::runtime::parallel::ThreadPool) is exact: no two
//!   workers ever share a straddling line;
//! * with [`AlignedVec::first_touch_copy`], pages are first *written* by
//!   the worker that will later stream them, so on a NUMA system
//!   first-touch placement puts each chunk's pages on the reading socket.
//!   (std has no explicit NUMA API; first-touch via the owning worker is
//!   the portable idiom, and it rides the deterministic chunk→worker
//!   assignment of the persistent pool.)
//!
//! The type derefs to `[f64]`, so every backend/kernel API that takes
//! slices accepts arena buffers unchanged.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

use super::parallel::{CACHELINE_F64, ThreadPool};

/// Arena alignment in bytes: one cache line, which is also the widest
/// vector register (AVX-512) — so one constant serves both purposes.
pub const ALIGN: usize = 64;

/// A fixed-length, 64-byte-aligned `f64` buffer (see the module docs).
pub struct AlignedVec {
    ptr: NonNull<f64>,
    len: usize,
}

// SAFETY: AlignedVec uniquely owns its allocation (no aliasing handles),
// and f64 is Send + Sync; moving the buffer or sharing &AlignedVec across
// threads is therefore sound.
unsafe impl Send for AlignedVec {}
unsafe impl Sync for AlignedVec {}

impl AlignedVec {
    fn layout(len: usize) -> Layout {
        // `Layout::array` checks the byte-size multiplication, so an
        // absurd `len` panics here instead of wrapping into a too-small
        // allocation that `Deref` would then overrun.
        Layout::array::<f64>(len)
            .and_then(|l| l.align_to(ALIGN))
            .expect("arena layout overflow")
    }

    /// An empty buffer (no allocation; pointer is a well-aligned dangling
    /// sentinel so alignment invariants hold even for `len == 0`).
    pub fn empty() -> Self {
        Self {
            ptr: NonNull::new(ALIGN as *mut f64).expect("non-null sentinel"),
            len: 0,
        }
    }

    /// A zero-initialized buffer of `len` elements. Uses `alloc_zeroed`,
    /// which on Linux typically maps copy-on-write zero pages — physical
    /// placement is then decided by whoever *writes* first (the property
    /// [`Self::first_touch_copy`] exploits).
    pub fn zeroed(len: usize) -> Self {
        if len == 0 {
            return Self::empty();
        }
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0).
        let raw = unsafe { alloc_zeroed(layout) } as *mut f64;
        let Some(ptr) = NonNull::new(raw) else {
            handle_alloc_error(layout);
        };
        Self { ptr, len }
    }

    /// A buffer initialized by `f(i)` per index, written serially by the
    /// calling thread.
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> f64) -> Self {
        let mut v = Self::zeroed(len);
        for (i, slot) in v.iter_mut().enumerate() {
            *slot = f(i);
        }
        v
    }

    /// An aligned copy of `src`, written serially by the calling thread.
    pub fn copy_from(src: &[f64]) -> Self {
        let mut v = Self::zeroed(src.len());
        v.copy_from_slice(src);
        v
    }

    /// An aligned copy of `src` whose pages are first-touched by the
    /// workers of `pool`, chunk by chunk, using the *same* cache-line-
    /// aligned partition and chunk→worker assignment the pool later
    /// dispatches kernels with — so each worker's operand pages land
    /// NUMA-local to it. The contents are bit-identical to `src`
    /// regardless of the worker count.
    pub fn first_touch_copy(src: &[f64], pool: &ThreadPool) -> Self {
        let v = Self::zeroed(src.len());
        let base = v.ptr.as_ptr() as usize;
        pool.run_chunks(src.len(), CACHELINE_F64, |_, r| {
            let dst = base as *mut f64;
            // SAFETY: chunks are disjoint in-bounds ranges of an allocation
            // this function owns; `src` and the arena never overlap.
            unsafe {
                std::ptr::copy_nonoverlapping(src[r.clone()].as_ptr(), dst.add(r.start), r.len());
            }
        });
        v
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_ptr(&self) -> *const f64 {
        self.ptr.as_ptr()
    }
}

impl Deref for AlignedVec {
    type Target = [f64];

    fn deref(&self) -> &[f64] {
        // SAFETY: ptr/len describe a live allocation (or the aligned
        // dangling sentinel with len 0, which from_raw_parts permits).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl DerefMut for AlignedVec {
    fn deref_mut(&mut self) -> &mut [f64] {
        // SAFETY: as above, plus &mut self guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for AlignedVec {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated in `zeroed` with the identical layout.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len)) };
        }
    }
}

impl Clone for AlignedVec {
    fn clone(&self) -> Self {
        Self::copy_from(self)
    }
}

impl fmt::Debug for AlignedVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("AlignedVec")
            .field("len", &self.len)
            .field("align", &ALIGN)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_invariant_holds_for_all_sizes() {
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000, 4096] {
            let v = AlignedVec::zeroed(len);
            assert_eq!(v.as_ptr() as usize % ALIGN, 0, "len={len}");
            assert_eq!(v.len(), len);
            assert!(v.iter().all(|&x| x == 0.0), "zeroed len={len}");
        }
    }

    #[test]
    fn from_fn_and_copy_roundtrip() {
        let v = AlignedVec::from_fn(100, |i| i as f64 * 0.5);
        assert_eq!(v[7], 3.5);
        let w = AlignedVec::copy_from(&v);
        assert_eq!(&v[..], &w[..]);
        let c = v.clone();
        assert_eq!(&v[..], &c[..]);
        assert_eq!(c.as_ptr() as usize % ALIGN, 0);
    }

    #[test]
    fn deref_mut_writes_stick() {
        let mut v = AlignedVec::zeroed(16);
        v[3] = 2.25;
        v[15] = -1.0;
        assert_eq!(v[3], 2.25);
        assert_eq!(v.iter().sum::<f64>(), 1.25);
    }

    #[test]
    fn first_touch_copy_is_bit_identical_for_any_worker_count() {
        let src: Vec<f64> = (0..1003).map(|i| (i as f64).sin()).collect();
        for threads in [1usize, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let v = AlignedVec::first_touch_copy(&src, &pool);
            assert_eq!(v.as_ptr() as usize % ALIGN, 0);
            assert_eq!(v.len(), src.len());
            for (i, (a, b)) in v.iter().zip(&src).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "T={threads} i={i}");
            }
        }
    }

    #[test]
    fn empty_buffers_are_safe() {
        let pool = ThreadPool::new(4);
        let v = AlignedVec::first_touch_copy(&[], &pool);
        assert!(v.is_empty());
        assert_eq!(&v[..], &[] as &[f64]);
        let e = AlignedVec::empty();
        assert_eq!(e.as_ptr() as usize % ALIGN, 0);
    }
}
