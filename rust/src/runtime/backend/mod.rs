//! Pluggable execution backends for the paper's kernel ladder.
//!
//! The paper's optimization story (Sect. 3) is a *ladder*: scalar loop →
//! modulo-unrolled loop → SIMD-vectorized loop, applied to the naive dot,
//! the Kahan dot, and the Kahan sum. This module abstracts *where* those
//! kernels execute:
//!
//! * [`native`] — real Rust implementations of every rung, runnable on any
//!   host (portable lane code plus an AVX2 `std::arch` path selected at
//!   runtime). This is the default backend and needs nothing installed.
//! * [`pjrt`] (feature `pjrt`) — the AOT-compiled JAX/Pallas artifacts
//!   executed through a PJRT client, the repo's original "fifth machine"
//!   path.
//!
//! A [`Backend`] enumerates the [`KernelSpec`]s it supports and resolves
//! each to a ready-to-run [`KernelExec`]; the harness, accuracy studies and
//! host benchmarks are written against these traits so every experiment can
//! run against either backend (`--backend native|pjrt|auto` on the CLI).

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::fmt;

pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

/// What a kernel computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelClass {
    /// `sum += x[i] * y[i]` (paper Fig. 2a).
    NaiveDot,
    /// Kahan-compensated dot product (paper Fig. 2b).
    KahanDot,
    /// Kahan-compensated summation (Fig. 2b without the product).
    KahanSum,
}

impl KernelClass {
    pub const ALL: [KernelClass; 3] = [
        KernelClass::NaiveDot,
        KernelClass::KahanDot,
        KernelClass::KahanSum,
    ];

    pub fn label(self) -> &'static str {
        match self {
            KernelClass::NaiveDot => "naive_dot",
            KernelClass::KahanDot => "kahan_dot",
            KernelClass::KahanSum => "kahan_sum",
        }
    }

    /// Arithmetic operations per loop update (the paper's flop accounting:
    /// naive dot 1 mul + 1 add; Kahan dot adds the 3-op compensation).
    pub fn flops_per_update(self) -> u64 {
        match self {
            KernelClass::NaiveDot => 2,
            KernelClass::KahanDot => 5,
            KernelClass::KahanSum => 4,
        }
    }

    /// Bytes streamed per update (f64 operands).
    pub fn bytes_per_update(self) -> u64 {
        match self {
            KernelClass::NaiveDot | KernelClass::KahanDot => 16,
            KernelClass::KahanSum => 8,
        }
    }

    pub fn is_dot(self) -> bool {
        !matches!(self, KernelClass::KahanSum)
    }
}

/// How the kernel loop is laid out — one rung of the paper's ladder.
///
/// The explicit-SIMD tiers carry a *vector register count* on top of the
/// lane width: `Avx2U4` means four independent 4-lane AVX2 accumulator
/// chains (16 scalar chains total). Multi-register unrolling is what breaks
/// the loop-carried add/FMA dependency (paper Sect. 3.2) — one vector
/// accumulator serializes on the instruction latency no matter how wide the
/// lanes are.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ImplStyle {
    /// Straight loop, one accumulator chain.
    Scalar,
    /// 2-way modulo unrolling (2 independent chains).
    Unroll2,
    /// 4-way modulo unrolling.
    Unroll4,
    /// 8-way modulo unrolling.
    Unroll8,
    /// Portable 4-lane vector code (auto-vectorizable chunked arrays).
    SimdLanes,
    /// Explicit AVX2+FMA `std::arch` intrinsics, one vector accumulator
    /// (runtime-detected; the latency-bound baseline of the AVX2 tier).
    SimdAvx2,
    /// AVX2+FMA with 2 independent vector accumulators (8 chains).
    Avx2U2,
    /// AVX2+FMA with 4 independent vector accumulators (16 chains).
    Avx2U4,
    /// AVX2+FMA with 8 independent vector accumulators (32 chains).
    Avx2U8,
    /// AVX-512F `_mm512` intrinsics, one 8-lane vector accumulator
    /// (compile-gated behind the `avx512` cargo feature + runtime-detected).
    SimdAvx512,
    /// AVX-512F with 4 independent vector accumulators (32 chains).
    Avx512U4,
    /// AVX-512F with 8 independent vector accumulators (64 chains).
    Avx512U8,
}

impl ImplStyle {
    pub const ALL: [ImplStyle; 12] = [
        ImplStyle::Scalar,
        ImplStyle::Unroll2,
        ImplStyle::Unroll4,
        ImplStyle::Unroll8,
        ImplStyle::SimdLanes,
        ImplStyle::SimdAvx2,
        ImplStyle::Avx2U2,
        ImplStyle::Avx2U4,
        ImplStyle::Avx2U8,
        ImplStyle::SimdAvx512,
        ImplStyle::Avx512U4,
        ImplStyle::Avx512U8,
    ];

    pub fn label(self) -> &'static str {
        match self {
            ImplStyle::Scalar => "scalar",
            ImplStyle::Unroll2 => "unroll2",
            ImplStyle::Unroll4 => "unroll4",
            ImplStyle::Unroll8 => "unroll8",
            ImplStyle::SimdLanes => "simd",
            ImplStyle::SimdAvx2 => "avx2",
            ImplStyle::Avx2U2 => "avx2u2",
            ImplStyle::Avx2U4 => "avx2u4",
            ImplStyle::Avx2U8 => "avx2u8",
            ImplStyle::SimdAvx512 => "avx512",
            ImplStyle::Avx512U4 => "avx512u4",
            ImplStyle::Avx512U8 => "avx512u8",
        }
    }

    /// Number of independent accumulator chains the layout carries
    /// (lane width × vector register count for the explicit-SIMD tiers).
    pub fn chains(self) -> usize {
        match self {
            ImplStyle::Scalar => 1,
            ImplStyle::Unroll2 => 2,
            ImplStyle::Unroll4 | ImplStyle::SimdLanes | ImplStyle::SimdAvx2 => 4,
            ImplStyle::Unroll8 | ImplStyle::SimdAvx512 => 8,
            ImplStyle::Avx2U2 => 8,
            ImplStyle::Avx2U4 => 16,
            ImplStyle::Avx2U8 | ImplStyle::Avx512U4 => 32,
            ImplStyle::Avx512U8 => 64,
        }
    }

    /// Styles implemented with AVX2+FMA intrinsics (need the host feature).
    pub fn needs_avx2(self) -> bool {
        matches!(
            self,
            ImplStyle::SimdAvx2 | ImplStyle::Avx2U2 | ImplStyle::Avx2U4 | ImplStyle::Avx2U8
        )
    }

    /// Styles implemented with AVX-512F intrinsics (need the `avx512` cargo
    /// feature at build time *and* the host feature at run time).
    pub fn needs_avx512(self) -> bool {
        matches!(
            self,
            ImplStyle::SimdAvx512 | ImplStyle::Avx512U4 | ImplStyle::Avx512U8
        )
    }

    /// Explicit-intrinsic styles whose products are fused (`fmadd`/`fmsub`
    /// contraction — the paper's KahanSimdFma shape). Their bit-exact
    /// portable references use `f64::mul_add`, not separate mul+add.
    pub fn uses_fma(self) -> bool {
        self.needs_avx2() || self.needs_avx512()
    }
}

/// One concrete kernel: what it computes and how the loop is laid out.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct KernelSpec {
    pub class: KernelClass,
    pub style: ImplStyle,
}

impl KernelSpec {
    pub fn new(class: KernelClass, style: ImplStyle) -> Self {
        Self { class, style }
    }

    /// Stable identifier, e.g. `kahan_dot.avx2`.
    pub fn id(self) -> String {
        format!("{}.{}", self.class.label(), self.style.label())
    }

    /// The full ladder: every class × every style.
    pub fn all() -> Vec<KernelSpec> {
        let mut v = Vec::with_capacity(KernelClass::ALL.len() * ImplStyle::ALL.len());
        for class in KernelClass::ALL {
            for style in ImplStyle::ALL {
                v.push(KernelSpec::new(class, style));
            }
        }
        v
    }
}

impl fmt::Display for KernelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// Input to one kernel execution.
#[derive(Clone, Copy, Debug)]
pub enum KernelInput<'a> {
    /// Two equal-length operand streams for the dot kernels.
    Dot(&'a [f64], &'a [f64]),
    /// One operand stream for the sum kernels.
    Sum(&'a [f64]),
}

impl KernelInput<'_> {
    /// Loop iterations this input drives.
    pub fn updates(&self) -> usize {
        match self {
            KernelInput::Dot(x, _) => x.len(),
            KernelInput::Sum(x) => x.len(),
        }
    }

    /// Validate this input against a kernel spec: the input kind must match
    /// the kernel class and dot operands must have equal length. Shared by
    /// the serial and thread-parallel execution paths so rejection semantics
    /// cannot drift between them.
    pub fn check(&self, spec: KernelSpec) -> Result<(), BackendError> {
        match (self, spec.class.is_dot()) {
            (KernelInput::Dot(x, y), true) => {
                if x.len() == y.len() {
                    Ok(())
                } else {
                    Err(BackendError::ShapeMismatch {
                        lhs: x.len(),
                        rhs: y.len(),
                    })
                }
            }
            (KernelInput::Sum(_), false) => Ok(()),
            _ => Err(BackendError::InputMismatch { spec }),
        }
    }
}

/// Backend failure modes.
#[derive(Clone, Debug)]
pub enum BackendError {
    /// The backend has no implementation for the requested spec.
    Unsupported { backend: String, spec: KernelSpec },
    /// Input kind does not match the kernel class (dot vs sum).
    InputMismatch { spec: KernelSpec },
    /// Dot operands of different lengths.
    ShapeMismatch { lhs: usize, rhs: usize },
    /// Backend-specific execution failure (e.g. PJRT compile error).
    Runtime(String),
    /// The request's deadline expired before execution began; it was shed
    /// in-queue without any compute (`budget_us` is the deadline it carried).
    DeadlineExceeded { budget_us: u64 },
    /// The request's tenant was at its per-tenant queue quota; it was shed
    /// at admission without entering the queue (distinct from whole-queue
    /// backpressure, which blocks or reports busy instead).
    QuotaExceeded { tenant: u32 },
    /// A handle-submit named an operand handle that is not resident in the
    /// store (never registered, released, or evicted). The client
    /// re-registers the operand — content addressing returns the same
    /// handle — and retries.
    UnknownHandle { handle: u64 },
    /// A register payload alone exceeds the operand store's byte capacity,
    /// so no eviction can make it resident.
    StoreFull { requested: usize, capacity: usize },
    /// A handle-submit resolved an operand whose resident bytes no longer
    /// hash to the registration digest (detected by the store scrubber).
    /// The entry was quarantined — evicted, never served — and the client
    /// recovers by re-registering the clean contents, which yields the
    /// same handle.
    CorruptOperand { handle: u64 },
}

impl fmt::Display for BackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BackendError::Unsupported { backend, spec } => {
                write!(f, "backend '{backend}' does not support kernel {spec}")
            }
            BackendError::InputMismatch { spec } => {
                write!(f, "input kind does not match kernel {spec}")
            }
            BackendError::ShapeMismatch { lhs, rhs } => {
                write!(f, "dot operands differ in length: {lhs} vs {rhs}")
            }
            BackendError::Runtime(msg) => write!(f, "backend execution failed: {msg}"),
            BackendError::DeadlineExceeded { budget_us } => {
                write!(f, "deadline exceeded: request shed after {budget_us} us budget")
            }
            BackendError::QuotaExceeded { tenant } => {
                write!(f, "quota exceeded: tenant {tenant} is at its queue quota")
            }
            BackendError::UnknownHandle { handle } => {
                write!(f, "unknown operand handle {handle:#018x}: not resident in the store")
            }
            BackendError::StoreFull { requested, capacity } => {
                write!(
                    f,
                    "operand store full: {requested} bytes exceeds capacity {capacity}"
                )
            }
            BackendError::CorruptOperand { handle } => {
                write!(
                    f,
                    "corrupt operand {handle:#018x}: resident bytes failed digest verification and were quarantined"
                )
            }
        }
    }
}

impl std::error::Error for BackendError {}

/// A kernel resolved by a backend, ready to execute many times.
pub trait KernelExec {
    fn spec(&self) -> KernelSpec;

    /// Execute once, returning the scalar result.
    fn run(&self, input: &KernelInput<'_>) -> Result<f64, BackendError>;
}

/// An execution engine for the kernel ladder.
pub trait Backend {
    /// Short stable name ("native", "pjrt").
    fn name(&self) -> &str;

    /// Every spec this backend can resolve on this machine.
    fn kernels(&self) -> Vec<KernelSpec>;

    /// Resolve a spec to an executable kernel (may compile/cache).
    fn resolve(&self, spec: KernelSpec) -> Result<Box<dyn KernelExec + '_>, BackendError>;

    fn supports(&self, spec: KernelSpec) -> bool {
        self.kernels().contains(&spec)
    }

    /// Convenience: resolve and execute once.
    fn run(&self, spec: KernelSpec, input: &KernelInput<'_>) -> Result<f64, BackendError> {
        self.resolve(spec)?.run(input)
    }
}

/// Backends usable in this build whose name passes `enabled`: native (when
/// selected) always works; PJRT additionally needs the feature and a
/// loadable artifact directory. Deselected backends are never constructed,
/// so a native-only run pays no PJRT client startup.
pub fn selected_backends(
    artifacts_dir: &str,
    enabled: impl Fn(&str) -> bool,
) -> Vec<Box<dyn Backend>> {
    let mut v: Vec<Box<dyn Backend>> = Vec::new();
    if enabled("native") {
        v.push(Box::new(NativeBackend::new()));
    }
    #[cfg(feature = "pjrt")]
    if enabled("pjrt") {
        if let Ok(b) = PjrtBackend::from_dir(artifacts_dir) {
            v.push(Box::new(b));
        }
    }
    #[cfg(not(feature = "pjrt"))]
    let _ = artifacts_dir;
    v
}

/// Every backend usable in this build.
pub fn available_backends(artifacts_dir: &str) -> Vec<Box<dyn Backend>> {
    selected_backends(artifacts_dir, |_| true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_ids_unique_and_stable() {
        let all = KernelSpec::all();
        assert_eq!(all.len(), 36);
        let mut ids: Vec<String> = all.iter().map(|s| s.id()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 36);
        assert_eq!(
            KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdAvx2).id(),
            "kahan_dot.avx2"
        );
        assert_eq!(
            KernelSpec::new(KernelClass::KahanDot, ImplStyle::Avx2U8).id(),
            "kahan_dot.avx2u8"
        );
        assert_eq!(
            KernelSpec::new(KernelClass::NaiveDot, ImplStyle::Avx512U4).id(),
            "naive_dot.avx512u4"
        );
    }

    #[test]
    fn style_tier_helpers_are_consistent() {
        for style in ImplStyle::ALL {
            // A style belongs to at most one intrinsic tier.
            assert!(!(style.needs_avx2() && style.needs_avx512()), "{style:?}");
            assert_eq!(
                style.uses_fma(),
                style.needs_avx2() || style.needs_avx512(),
                "{style:?}"
            );
            assert!(style.chains() >= 1);
            assert!(!style.label().is_empty());
        }
        // The unrolled tiers multiply the lane width by the register count.
        assert_eq!(ImplStyle::Avx2U8.chains(), 8 * ImplStyle::SimdAvx2.chains());
        assert_eq!(
            ImplStyle::Avx512U8.chains(),
            8 * ImplStyle::SimdAvx512.chains()
        );
    }

    #[test]
    fn input_updates() {
        let x = [1.0, 2.0];
        assert_eq!(KernelInput::Dot(&x, &x).updates(), 2);
        assert_eq!(KernelInput::Sum(&x).updates(), 2);
    }

    #[test]
    fn input_check_matrix() {
        let x = [1.0, 2.0];
        let y = [3.0];
        let dot = KernelSpec::new(KernelClass::KahanDot, ImplStyle::Scalar);
        let sum = KernelSpec::new(KernelClass::KahanSum, ImplStyle::Scalar);
        assert!(KernelInput::Dot(&x, &x).check(dot).is_ok());
        assert!(KernelInput::Sum(&x).check(sum).is_ok());
        assert!(matches!(
            KernelInput::Dot(&x, &y).check(dot),
            Err(BackendError::ShapeMismatch { lhs: 2, rhs: 1 })
        ));
        assert!(matches!(
            KernelInput::Sum(&x).check(dot),
            Err(BackendError::InputMismatch { .. })
        ));
        assert!(matches!(
            KernelInput::Dot(&x, &x).check(sum),
            Err(BackendError::InputMismatch { .. })
        ));
    }

    #[test]
    fn flop_and_byte_accounting() {
        assert_eq!(KernelClass::NaiveDot.flops_per_update(), 2);
        assert_eq!(KernelClass::KahanDot.flops_per_update(), 5);
        assert_eq!(KernelClass::KahanSum.flops_per_update(), 4);
        assert_eq!(KernelClass::KahanDot.bytes_per_update(), 16);
        assert_eq!(KernelClass::KahanSum.bytes_per_update(), 8);
    }

    #[test]
    fn available_backends_always_has_native() {
        let backends = available_backends("artifacts");
        assert!(!backends.is_empty());
        assert_eq!(backends[0].name(), "native");
    }

    #[test]
    fn errors_render() {
        let e = BackendError::Unsupported {
            backend: "native".into(),
            spec: KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdAvx2),
        };
        assert!(e.to_string().contains("kahan_dot.avx2"));
        let e = BackendError::ShapeMismatch { lhs: 3, rhs: 4 };
        assert!(e.to_string().contains("3 vs 4"));
    }
}
