//! Native Rust backend: the paper's full kernel ladder in real, host-runnable
//! code.
//!
//! Every [`KernelClass`] is provided in every [`ImplStyle`]:
//!
//! * `Scalar` — the literal Fig. 2 loops (delegating to [`crate::accuracy`]
//!   so the backend and the accuracy substrate share one definition);
//! * `Unroll2/4/8` — modulo unrolling with N independent accumulator
//!   chains, the transformation that breaks the loop-carried dependency
//!   (paper Sect. 3.2);
//! * `SimdLanes` — portable 4-lane vector code over chunked arrays, the
//!   shape LLVM auto-vectorizes (and bit-identical to `Unroll4` by
//!   construction — pinned by tests);
//! * `SimdAvx2` — explicit AVX2+FMA `std::arch` intrinsics, runtime-detected
//!   via `is_x86_feature_detected!`; the compensated product uses `fmsub`
//!   (the paper's KahanSimdFma variant).
//!
//! All compensated variants finish with the same compensated lane fold as
//! [`crate::accuracy::dots::kahan_dot_lanes`], so the n-independent error
//! bound of Kahan's algorithm survives the parallelization (validated
//! against the exact ground truth in `tests/properties.rs`).
#![allow(clippy::needless_range_loop)]

use super::{Backend, BackendError, ImplStyle, KernelClass, KernelExec, KernelInput, KernelSpec};
use crate::accuracy::{dots, sums};

// One shared `_finalize`: the reference lane algorithm and every native
// kernel combine their chains through the same compensated fold.
pub use crate::accuracy::dots::fold_kahan_lanes;

/// Lane count of the portable vector layout (f64x4 — one AVX2 register).
pub const LANES: usize = 4;

// ---------------------------------------------------------------------------
// Naive dot ladder
// ---------------------------------------------------------------------------

/// Naive dot, straight loop (Fig. 2a).
pub fn naive_dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    dots::naive_dot(x, y)
}

/// Naive dot with `CHAINS` independent accumulators (modulo unrolling).
pub fn naive_dot_unrolled<const CHAINS: usize>(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; CHAINS];
    for (xc, yc) in x.chunks_exact(CHAINS).zip(y.chunks_exact(CHAINS)) {
        for l in 0..CHAINS {
            acc[l] += xc[l] * yc[l];
        }
    }
    let done = x.len() - x.len() % CHAINS;
    for i in done..x.len() {
        acc[0] += x[i] * y[i];
    }
    acc.iter().sum()
}

/// Naive dot, portable 4-lane vector layout (bit-identical to
/// `naive_dot_unrolled::<4>`).
pub fn naive_dot_simd(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; LANES];
    let mut xi = x.chunks_exact(LANES);
    let mut yi = y.chunks_exact(LANES);
    for (xc, yc) in (&mut xi).zip(&mut yi) {
        let mut prod = [0.0f64; LANES];
        for l in 0..LANES {
            prod[l] = xc[l] * yc[l];
        }
        for l in 0..LANES {
            acc[l] += prod[l];
        }
    }
    for (a, b) in xi.remainder().iter().zip(yi.remainder()) {
        acc[0] += a * b;
    }
    acc.iter().sum()
}

/// Naive dot via AVX2 FMA when available; portable lanes otherwise. The FMA
/// contraction makes this the compiler's `-O3` baseline, not bit-identical
/// to the portable path.
pub fn naive_dot_avx2(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: guarded by runtime feature detection; lengths checked
        // above (the unsafe body reads x.len() elements from both slices).
        return unsafe { x86::naive_dot_avx2(x, y) };
    }
    naive_dot_simd(x, y)
}

// ---------------------------------------------------------------------------
// Kahan dot ladder
// ---------------------------------------------------------------------------

/// Kahan dot, straight loop (Fig. 2b).
pub fn kahan_dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    dots::kahan_dot(x, y)
}

/// Kahan dot with `CHAINS` independent (sum, compensation) chains and a
/// compensated fold.
pub fn kahan_dot_unrolled<const CHAINS: usize>(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = [0.0f64; CHAINS];
    let mut c = [0.0f64; CHAINS];
    for (xc, yc) in x.chunks_exact(CHAINS).zip(y.chunks_exact(CHAINS)) {
        for l in 0..CHAINS {
            let yv = xc[l] * yc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    let done = x.len() - x.len() % CHAINS;
    for i in done..x.len() {
        let yv = x[i] * y[i] - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Kahan dot, portable 4-lane vector layout (bit-identical to
/// `kahan_dot_unrolled::<4>`).
pub fn kahan_dot_simd(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = [0.0f64; LANES];
    let mut c = [0.0f64; LANES];
    let mut xi = x.chunks_exact(LANES);
    let mut yi = y.chunks_exact(LANES);
    for (xc, yc) in (&mut xi).zip(&mut yi) {
        for l in 0..LANES {
            let yv = xc[l] * yc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    for (a, b) in xi.remainder().iter().zip(yi.remainder()) {
        let yv = a * b - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Kahan dot via AVX2, `fmsub`-fused product (the paper's KahanSimdFma).
pub fn kahan_dot_avx2(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: guarded by runtime feature detection; lengths checked
        // above (the unsafe body reads x.len() elements from both slices).
        return unsafe { x86::kahan_dot_avx2(x, y) };
    }
    kahan_dot_simd(x, y)
}

// ---------------------------------------------------------------------------
// Kahan sum ladder
// ---------------------------------------------------------------------------

/// Kahan sum, straight loop.
pub fn kahan_sum_scalar(x: &[f64]) -> f64 {
    sums::kahan_sum(x)
}

/// Kahan sum with `CHAINS` independent chains and a compensated fold.
pub fn kahan_sum_unrolled<const CHAINS: usize>(x: &[f64]) -> f64 {
    let mut s = [0.0f64; CHAINS];
    let mut c = [0.0f64; CHAINS];
    for xc in x.chunks_exact(CHAINS) {
        for l in 0..CHAINS {
            let yv = xc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    let done = x.len() - x.len() % CHAINS;
    for &v in &x[done..] {
        let yv = v - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Kahan sum, portable 4-lane vector layout (bit-identical to
/// `kahan_sum_unrolled::<4>`, as an independent implementation).
pub fn kahan_sum_simd(x: &[f64]) -> f64 {
    let mut s = [0.0f64; LANES];
    let mut c = [0.0f64; LANES];
    let mut xi = x.chunks_exact(LANES);
    for xc in &mut xi {
        for l in 0..LANES {
            let yv = xc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    for &v in xi.remainder() {
        let yv = v - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Kahan sum via AVX2 when available.
pub fn kahan_sum_avx2(x: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: guarded by runtime feature detection.
        return unsafe { x86::kahan_sum_avx2(x) };
    }
    kahan_sum_simd(x)
}

// ---------------------------------------------------------------------------
// AVX2 paths
// ---------------------------------------------------------------------------

/// Does this host support the `SimdAvx2` style?
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Does this host support the `SimdAvx2` style?
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_fmadd_pd, _mm256_fmsub_pd, _mm256_loadu_pd, _mm256_setzero_pd,
        _mm256_storeu_pd, _mm256_sub_pd,
    };

    /// # Safety
    /// Caller must verify AVX2 + FMA via `avx2_available()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn naive_dot_avx2(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len();
        let chunks = n / 4;
        let mut acc = _mm256_setzero_pd();
        for i in 0..chunks {
            let a = _mm256_loadu_pd(x.as_ptr().add(4 * i));
            let b = _mm256_loadu_pd(y.as_ptr().add(4 * i));
            acc = _mm256_fmadd_pd(a, b, acc);
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        for i in 4 * chunks..n {
            lanes[0] = x[i].mul_add(y[i], lanes[0]);
        }
        lanes.iter().sum()
    }

    /// # Safety
    /// Caller must verify AVX2 + FMA via `avx2_available()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn kahan_dot_avx2(x: &[f64], y: &[f64]) -> f64 {
        let n = x.len();
        let chunks = n / 4;
        let mut s = _mm256_setzero_pd();
        let mut c = _mm256_setzero_pd();
        for i in 0..chunks {
            let a = _mm256_loadu_pd(x.as_ptr().add(4 * i));
            let b = _mm256_loadu_pd(y.as_ptr().add(4 * i));
            let yv = _mm256_fmsub_pd(a, b, c);
            let t = _mm256_add_pd(s, yv);
            c = _mm256_sub_pd(_mm256_sub_pd(t, s), yv);
            s = t;
        }
        let mut sl = [0.0f64; 4];
        let mut cl = [0.0f64; 4];
        _mm256_storeu_pd(sl.as_mut_ptr(), s);
        _mm256_storeu_pd(cl.as_mut_ptr(), c);
        for i in 4 * chunks..n {
            let yv = x[i].mul_add(y[i], -cl[0]);
            let t = sl[0] + yv;
            cl[0] = (t - sl[0]) - yv;
            sl[0] = t;
        }
        super::fold_kahan_lanes(&sl, &cl)
    }

    /// # Safety
    /// Caller must verify AVX2 + FMA via `avx2_available()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn kahan_sum_avx2(x: &[f64]) -> f64 {
        let n = x.len();
        let chunks = n / 4;
        let mut s = _mm256_setzero_pd();
        let mut c = _mm256_setzero_pd();
        for i in 0..chunks {
            let v = _mm256_loadu_pd(x.as_ptr().add(4 * i));
            let yv = _mm256_sub_pd(v, c);
            let t = _mm256_add_pd(s, yv);
            c = _mm256_sub_pd(_mm256_sub_pd(t, s), yv);
            s = t;
        }
        let mut sl = [0.0f64; 4];
        let mut cl = [0.0f64; 4];
        _mm256_storeu_pd(sl.as_mut_ptr(), s);
        _mm256_storeu_pd(cl.as_mut_ptr(), c);
        for &v in &x[4 * chunks..] {
            let yv = v - cl[0];
            let t = sl[0] + yv;
            cl[0] = (t - sl[0]) - yv;
            sl[0] = t;
        }
        super::fold_kahan_lanes(&sl, &cl)
    }
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// A native kernel entry point: a plain function pointer, zero overhead.
/// Public so the thread-parallel layer ([`crate::runtime::parallel`]) can
/// run the same entry points over per-thread slices.
#[derive(Clone, Copy)]
pub enum NativeFn {
    Dot(fn(&[f64], &[f64]) -> f64),
    Sum(fn(&[f64]) -> f64),
}

/// One rung of the ladder: every kernel class at one loop layout. The
/// scalar/unroll/simd/avx2 × dot/kahan-dot/kahan-sum matrix is registered
/// exactly once here; [`NativeBackend`] and the thread-parallel layer both
/// resolve through this table, so a new style is added in one row.
struct LadderRow {
    style: ImplStyle,
    naive_dot: fn(&[f64], &[f64]) -> f64,
    kahan_dot: fn(&[f64], &[f64]) -> f64,
    kahan_sum: fn(&[f64]) -> f64,
}

const LADDER: [LadderRow; 6] = [
    LadderRow {
        style: ImplStyle::Scalar,
        naive_dot: naive_dot_scalar,
        kahan_dot: kahan_dot_scalar,
        kahan_sum: kahan_sum_scalar,
    },
    LadderRow {
        style: ImplStyle::Unroll2,
        naive_dot: naive_dot_unrolled::<2>,
        kahan_dot: kahan_dot_unrolled::<2>,
        kahan_sum: kahan_sum_unrolled::<2>,
    },
    LadderRow {
        style: ImplStyle::Unroll4,
        naive_dot: naive_dot_unrolled::<4>,
        kahan_dot: kahan_dot_unrolled::<4>,
        kahan_sum: kahan_sum_unrolled::<4>,
    },
    LadderRow {
        style: ImplStyle::Unroll8,
        naive_dot: naive_dot_unrolled::<8>,
        kahan_dot: kahan_dot_unrolled::<8>,
        kahan_sum: kahan_sum_unrolled::<8>,
    },
    LadderRow {
        style: ImplStyle::SimdLanes,
        naive_dot: naive_dot_simd,
        kahan_dot: kahan_dot_simd,
        kahan_sum: kahan_sum_simd,
    },
    LadderRow {
        style: ImplStyle::SimdAvx2,
        naive_dot: naive_dot_avx2,
        kahan_dot: kahan_dot_avx2,
        kahan_sum: kahan_sum_avx2,
    },
];

/// Resolve a spec to its native entry point. `avx2` gates the `SimdAvx2`
/// row (runtime feature detection is the caller's — usually the backend's —
/// responsibility).
pub fn native_fn(spec: KernelSpec, avx2: bool) -> Option<NativeFn> {
    if spec.style == ImplStyle::SimdAvx2 && !avx2 {
        return None;
    }
    let row = LADDER.iter().find(|r| r.style == spec.style)?;
    Some(match spec.class {
        KernelClass::NaiveDot => NativeFn::Dot(row.naive_dot),
        KernelClass::KahanDot => NativeFn::Dot(row.kahan_dot),
        KernelClass::KahanSum => NativeFn::Sum(row.kahan_sum),
    })
}

/// A resolved native kernel (a plain function pointer — zero overhead).
pub struct NativeKernel {
    spec: KernelSpec,
    f: NativeFn,
}

impl KernelExec for NativeKernel {
    fn spec(&self) -> KernelSpec {
        self.spec
    }

    fn run(&self, input: &KernelInput<'_>) -> Result<f64, BackendError> {
        input.check(self.spec)?;
        Ok(match (self.f, *input) {
            (NativeFn::Dot(f), KernelInput::Dot(x, y)) => f(x, y),
            (NativeFn::Sum(f), KernelInput::Sum(x)) => f(x),
            _ => unreachable!("check() verified the input kind"),
        })
    }
}

/// The host-CPU backend: pure Rust kernels, AVX2 when the CPU has it.
pub struct NativeBackend {
    avx2: bool,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self {
            avx2: avx2_available(),
        }
    }

    /// Is the AVX2 style usable on this host?
    pub fn has_avx2(&self) -> bool {
        self.avx2
    }

    fn lookup(&self, spec: KernelSpec) -> Option<NativeFn> {
        native_fn(spec, self.avx2)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn kernels(&self) -> Vec<KernelSpec> {
        KernelSpec::all()
            .into_iter()
            .filter(|s| self.avx2 || s.style != ImplStyle::SimdAvx2)
            .collect()
    }

    fn resolve(&self, spec: KernelSpec) -> Result<Box<dyn KernelExec + '_>, BackendError> {
        match self.lookup(spec) {
            Some(f) => Ok(Box::new(NativeKernel { spec, f })),
            None => Err(BackendError::Unsupported {
                backend: self.name().to_string(),
                spec,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::{exact_dot, exact_sum};
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn ladder_agrees_on_benign_data() {
        let x = randvec(1003, 1); // deliberately not a multiple of 8
        let y = randvec(1003, 2);
        let want = exact_dot(&x, &y);
        let backend = NativeBackend::new();
        for spec in backend.kernels() {
            if !spec.class.is_dot() {
                continue;
            }
            let got = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
            let tol = 1e-11 * want.abs().max(1.0);
            assert!((got - want).abs() <= tol, "{spec}: {got} vs {want}");
        }
    }

    #[test]
    fn sum_ladder_agrees() {
        let x = randvec(777, 3);
        let want = exact_sum(&x);
        let backend = NativeBackend::new();
        for spec in backend.kernels() {
            if spec.class != KernelClass::KahanSum {
                continue;
            }
            let got = backend.run(spec, &KernelInput::Sum(&x)).unwrap();
            assert!(
                (got - want).abs() <= 1e-11 * want.abs().max(1.0),
                "{spec}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn simd_is_bit_identical_to_unroll4() {
        for n in [0usize, 1, 3, 4, 5, 63, 64, 1000] {
            let x = randvec(n, 10 + n as u64);
            let y = randvec(n, 20 + n as u64);
            assert_eq!(naive_dot_simd(&x, &y), naive_dot_unrolled::<4>(&x, &y));
            assert_eq!(kahan_dot_simd(&x, &y), kahan_dot_unrolled::<4>(&x, &y));
            assert_eq!(kahan_sum_simd(&x), kahan_sum_unrolled::<4>(&x));
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let backend = NativeBackend::new();
        for spec in backend.kernels() {
            let got = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[], &[])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[])).unwrap()
            };
            assert_eq!(got, 0.0, "{spec} on empty input");
            let one = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[3.0], &[2.0])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[6.0])).unwrap()
            };
            assert_eq!(one, 6.0, "{spec} on length-1 input");
        }
    }

    #[test]
    fn shape_and_kind_mismatches_rejected() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let err = backend
            .run(spec, &KernelInput::Dot(&[1.0], &[1.0, 2.0]))
            .unwrap_err();
        assert!(matches!(err, BackendError::ShapeMismatch { .. }));
        let err = backend.run(spec, &KernelInput::Sum(&[1.0])).unwrap_err();
        assert!(matches!(err, BackendError::InputMismatch { .. }));
    }

    #[test]
    fn avx2_matches_portable_within_kahan_bound() {
        if !avx2_available() {
            return;
        }
        let x = randvec(4097, 5);
        let y = randvec(4097, 6);
        let want = exact_dot(&x, &y);
        let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        for f in [kahan_dot_avx2, kahan_dot_simd] {
            let got = f(&x, &y);
            assert!((got - want).abs() <= 8.0 * f64::EPSILON * cond);
        }
        let s_avx = kahan_sum_avx2(&x);
        let s_port = kahan_sum_simd(&x);
        let abs: f64 = x.iter().map(|v| v.abs()).sum();
        assert!((s_avx - s_port).abs() <= 8.0 * f64::EPSILON * abs);
    }

    #[test]
    fn kahan_beats_naive_on_cancellation() {
        // Adversarial cancellation: +M enters lane 0 first and -M leaves it
        // last, so every O(100) addend in between is rounded against an
        // accumulator of magnitude M (ulp(M) = 16). The naive kernel loses
        // a random walk of those roundings; Kahan carries them in `c` and
        // the compensated fold, recovering the sum decisively (the exact
        // construction is ill-conditioned in Σ|x| / |Σx| ≈ 1e13).
        let mut rng = Rng::new(2016);
        let n = 4096;
        let mut x: Vec<f64> = (0..n).map(|_| 100.0 * rng.normal()).collect();
        let y = vec![1.0; n];
        const M: f64 = 1e17; // ulp(M) = 16 in f64
        x[0] = M;
        x[n - 4] = -M; // lane 0 of the final chunk: same chain as x[0]
        let exact = exact_dot(&x, &y);
        let e_naive = (naive_dot_simd(&x, &y) - exact).abs();
        let e_kahan = (kahan_dot_simd(&x, &y) - exact).abs();
        assert!(
            e_kahan <= 0.2 * e_naive,
            "kahan {e_kahan:.3e} must beat naive {e_naive:.3e} decisively"
        );
    }

    #[test]
    fn ladder_table_covers_every_spec() {
        for spec in KernelSpec::all() {
            let f = native_fn(spec, true).expect("every spec has a table row");
            match f {
                NativeFn::Dot(_) => assert!(spec.class.is_dot(), "{spec}"),
                NativeFn::Sum(_) => assert!(!spec.class.is_dot(), "{spec}"),
            }
            assert_eq!(
                native_fn(spec, false).is_none(),
                spec.style == ImplStyle::SimdAvx2,
                "{spec}"
            );
        }
    }

    #[test]
    fn resolve_reports_unsupported_avx2_when_absent() {
        let backend = NativeBackend { avx2: false };
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdAvx2);
        assert!(!backend.supports(spec));
        assert!(matches!(
            backend.resolve(spec),
            Err(BackendError::Unsupported { .. })
        ));
    }
}
