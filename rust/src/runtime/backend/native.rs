//! Native Rust backend: the paper's full kernel ladder in real, host-runnable
//! code.
//!
//! Every [`KernelClass`] is provided in every [`ImplStyle`]:
//!
//! * `Scalar` — the literal Fig. 2 loops (delegating to [`crate::accuracy`]
//!   so the backend and the accuracy substrate share one definition);
//! * `Unroll2/4/8` — modulo unrolling with N independent accumulator
//!   chains, the transformation that breaks the loop-carried dependency
//!   (paper Sect. 3.2);
//! * `SimdLanes` — portable 4-lane vector code over chunked arrays, the
//!   shape LLVM auto-vectorizes (and bit-identical to `Unroll4` by
//!   construction — pinned by tests);
//! * `SimdAvx2` — explicit AVX2+FMA `std::arch` intrinsics, runtime-detected
//!   via `is_x86_feature_detected!`; the compensated product uses `fmsub`
//!   (the paper's KahanSimdFma variant). One vector accumulator — the
//!   latency-bound baseline the paper's Fig. 1 ladder starts from;
//! * `Avx2U2/U4/U8` — the same AVX2 kernels with 2/4/8 *independent vector
//!   accumulator chains* (independent (s, c) register pairs for the Kahan
//!   kernels), folded once at the end. This is the paper's headline
//!   transformation: SIMD alone leaves the loop serialized on the FMA/ADD
//!   latency; multi-register unrolling fills the pipeline and is what lets
//!   the Kahan dot reach naive-dot throughput;
//! * `SimdAvx512/Avx512U4/Avx512U8` — 8-lane `_mm512` equivalents, gated
//!   behind the `avx512` cargo feature at compile time (so default and
//!   non-x86 builds are unaffected) and `avx512f` runtime detection.
//!
//! Every explicit-SIMD rung has an aligned-load fast path: when both
//! operand pointers are vector-aligned (the [`crate::runtime::arena`]
//! allocator guarantees 64 bytes), `loadu` becomes `load`. Alignment is
//! probed once per call, never per iteration.
//!
//! All compensated variants finish with the same compensated lane fold as
//! [`crate::accuracy::dots::kahan_dot_lanes`], so the n-independent error
//! bound of Kahan's algorithm survives the parallelization (validated
//! against the exact ground truth in `tests/properties.rs`). Each intrinsic
//! rung is bit-identical to a portable `mul_add`-based reference
//! ([`naive_dot_fma_ref`], [`kahan_dot_fma_ref`], [`kahan_sum_wide_ref`]) —
//! property-pinned on aligned and misaligned slices across all remainder
//! lengths.
#![allow(clippy::needless_range_loop)]

use super::{Backend, BackendError, ImplStyle, KernelClass, KernelExec, KernelInput, KernelSpec};
use crate::accuracy::{dots, sums};

/// One shared `_finalize`: the reference lane algorithm and every native
/// kernel combine their chains through the same compensated fold.
///
/// **Tail-ordering contract** (every explicit-SIMD rung and its portable
/// reference obey this, and the bit-parity property tests pin it): the
/// vector loop consumes the longest prefix whose length is a multiple of
/// `lanes × ways`; the remainder is accumulated by a *dedicated* scalar
/// `(s, c)` pair — the spilled vector state is never mutated after the
/// vector loop ends. The final fold then runs over `lanes × ways + 1`
/// chains in way-major, lane-minor spill order with the scalar tail pair
/// appended last. Folding the tail as its own chain (instead of threading
/// it through lane 0) keeps every chain's compensation history intact and
/// makes the fold order independent of the remainder length.
pub use crate::accuracy::dots::fold_kahan_lanes;

/// Lane count of the portable vector layout (f64x4 — one AVX2 register).
pub const LANES: usize = 4;

/// Lane count of one AVX-512 register (f64x8).
pub const LANES_512: usize = 8;

// ---------------------------------------------------------------------------
// Host SIMD capabilities
// ---------------------------------------------------------------------------

/// Does this host support the AVX2+FMA styles? Cached in a
/// [`std::sync::OnceLock`] so feature detection runs once per process, not
/// once per kernel call.
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    static AVX2: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"))
}

/// Does this host support the AVX2+FMA styles?
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

/// Does this build+host support the AVX-512 styles? Requires the `avx512`
/// cargo feature (the `_mm512` intrinsics are only compiled then) *and*
/// runtime `avx512f`. Cached like [`avx2_available`].
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
pub fn avx512_available() -> bool {
    static AVX512: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVX512.get_or_init(|| is_x86_feature_detected!("avx512f"))
}

/// Does this build+host support the AVX-512 styles?
#[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
pub fn avx512_available() -> bool {
    false
}

/// The explicit-SIMD instruction tiers usable on a host. Resolved once
/// (per backend construction or via [`SimdCaps::detect`], which reads the
/// `OnceLock`-cached probes) and passed through [`native_fn`], so feature
/// detection never sits on a kernel hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdCaps {
    pub avx2: bool,
    pub avx512: bool,
}

impl SimdCaps {
    /// Probe the running host (cached; cheap to call repeatedly).
    pub fn detect() -> Self {
        Self {
            avx2: avx2_available(),
            avx512: avx512_available(),
        }
    }

    /// Every tier enabled — for table-coverage tests.
    pub fn all() -> Self {
        Self {
            avx2: true,
            avx512: true,
        }
    }

    /// No explicit-SIMD tier (portable rungs only).
    pub fn none() -> Self {
        Self {
            avx2: false,
            avx512: false,
        }
    }

    /// Can `style` run on a host with these capabilities?
    pub fn supports(self, style: ImplStyle) -> bool {
        (!style.needs_avx2() || self.avx2) && (!style.needs_avx512() || self.avx512)
    }
}

/// The widest explicit-SIMD Kahan rung available on a host with `caps` —
/// the paper's "manual SIMD Kahan" analog for live measurements (fig10b's
/// HOST row, benchmarks that want the headline kernel).
pub fn preferred_kahan_style(caps: SimdCaps) -> ImplStyle {
    if caps.avx512 {
        ImplStyle::Avx512U8
    } else if caps.avx2 {
        ImplStyle::Avx2U8
    } else {
        ImplStyle::SimdLanes
    }
}

// ---------------------------------------------------------------------------
// Naive dot ladder (portable rungs)
// ---------------------------------------------------------------------------

/// Naive dot, straight loop (Fig. 2a).
pub fn naive_dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    dots::naive_dot(x, y)
}

/// Naive dot with `CHAINS` independent accumulators (modulo unrolling).
pub fn naive_dot_unrolled<const CHAINS: usize>(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; CHAINS];
    for (xc, yc) in x.chunks_exact(CHAINS).zip(y.chunks_exact(CHAINS)) {
        for l in 0..CHAINS {
            acc[l] += xc[l] * yc[l];
        }
    }
    let done = x.len() - x.len() % CHAINS;
    for i in done..x.len() {
        acc[0] += x[i] * y[i];
    }
    acc.iter().sum()
}

/// Naive dot, portable 4-lane vector layout (bit-identical to
/// `naive_dot_unrolled::<4>`).
pub fn naive_dot_simd(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f64; LANES];
    let mut xi = x.chunks_exact(LANES);
    let mut yi = y.chunks_exact(LANES);
    for (xc, yc) in (&mut xi).zip(&mut yi) {
        let mut prod = [0.0f64; LANES];
        for l in 0..LANES {
            prod[l] = xc[l] * yc[l];
        }
        for l in 0..LANES {
            acc[l] += prod[l];
        }
    }
    for (a, b) in xi.remainder().iter().zip(yi.remainder()) {
        acc[0] += a * b;
    }
    acc.iter().sum()
}

// ---------------------------------------------------------------------------
// Kahan dot ladder (portable rungs)
// ---------------------------------------------------------------------------

/// Kahan dot, straight loop (Fig. 2b).
pub fn kahan_dot_scalar(x: &[f64], y: &[f64]) -> f64 {
    dots::kahan_dot(x, y)
}

/// Kahan dot with `CHAINS` independent (sum, compensation) chains and a
/// compensated fold.
pub fn kahan_dot_unrolled<const CHAINS: usize>(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = [0.0f64; CHAINS];
    let mut c = [0.0f64; CHAINS];
    for (xc, yc) in x.chunks_exact(CHAINS).zip(y.chunks_exact(CHAINS)) {
        for l in 0..CHAINS {
            let yv = xc[l] * yc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    let done = x.len() - x.len() % CHAINS;
    for i in done..x.len() {
        let yv = x[i] * y[i] - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Kahan dot, portable 4-lane vector layout (bit-identical to
/// `kahan_dot_unrolled::<4>`).
pub fn kahan_dot_simd(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mut s = [0.0f64; LANES];
    let mut c = [0.0f64; LANES];
    let mut xi = x.chunks_exact(LANES);
    let mut yi = y.chunks_exact(LANES);
    for (xc, yc) in (&mut xi).zip(&mut yi) {
        for l in 0..LANES {
            let yv = xc[l] * yc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    for (a, b) in xi.remainder().iter().zip(yi.remainder()) {
        let yv = a * b - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

// ---------------------------------------------------------------------------
// Kahan sum ladder (portable rungs)
// ---------------------------------------------------------------------------

/// Kahan sum, straight loop.
pub fn kahan_sum_scalar(x: &[f64]) -> f64 {
    sums::kahan_sum(x)
}

/// Kahan sum with `CHAINS` independent chains and a compensated fold.
pub fn kahan_sum_unrolled<const CHAINS: usize>(x: &[f64]) -> f64 {
    let mut s = [0.0f64; CHAINS];
    let mut c = [0.0f64; CHAINS];
    for xc in x.chunks_exact(CHAINS) {
        for l in 0..CHAINS {
            let yv = xc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    let done = x.len() - x.len() % CHAINS;
    for &v in &x[done..] {
        let yv = v - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

/// Kahan sum, portable 4-lane vector layout (bit-identical to
/// `kahan_sum_unrolled::<4>`, as an independent implementation).
pub fn kahan_sum_simd(x: &[f64]) -> f64 {
    let mut s = [0.0f64; LANES];
    let mut c = [0.0f64; LANES];
    let mut xi = x.chunks_exact(LANES);
    for xc in &mut xi {
        for l in 0..LANES {
            let yv = xc[l] - c[l];
            let t = s[l] + yv;
            c[l] = (t - s[l]) - yv;
            s[l] = t;
        }
    }
    for &v in xi.remainder() {
        let yv = v - c[0];
        let t = s[0] + yv;
        c[0] = (t - s[0]) - yv;
        s[0] = t;
    }
    fold_kahan_lanes(&s, &c)
}

// ---------------------------------------------------------------------------
// Portable references for the explicit-SIMD tiers
// ---------------------------------------------------------------------------
//
// Bit-exact stand-ins for the intrinsic kernels: `WAYS` groups of `LANES`
// accumulator chains, fused products via `f64::mul_add` (IEEE-identical to
// the hardware `fmadd`/`fmsub`), the dedicated-scalar-tail contract of
// `fold_kahan_lanes`, and the shared fold. They serve two roles: the
// fallback on hosts without the instruction set, and the reference side of
// the bit-parity property tests. Maximum fold width is 8 lanes × 8 ways
// plus the tail chain.

const MAX_FOLD: usize = LANES_512 * 8 + 1;

/// Portable reference / fallback for the W-way AVX2/AVX-512 naive dot.
pub fn naive_dot_fma_ref<const L: usize, const W: usize>(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let step = L * W;
    let blocks = n / step;
    let mut acc = [[0.0f64; L]; W];
    for i in 0..blocks {
        let base = i * step;
        for w in 0..W {
            for l in 0..L {
                let j = base + w * L + l;
                acc[w][l] = x[j].mul_add(y[j], acc[w][l]);
            }
        }
    }
    let mut tail = 0.0f64;
    for j in blocks * step..n {
        tail = x[j].mul_add(y[j], tail);
    }
    let mut total = 0.0f64;
    for w in 0..W {
        for l in 0..L {
            total += acc[w][l];
        }
    }
    total + tail
}

/// Portable reference / fallback for the W-way AVX2/AVX-512 Kahan dot
/// (fused `a*b - c` products, per-way (s, c) chains, dedicated scalar tail).
pub fn kahan_dot_fma_ref<const L: usize, const W: usize>(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    let step = L * W;
    let blocks = n / step;
    let mut s = [[0.0f64; L]; W];
    let mut c = [[0.0f64; L]; W];
    for i in 0..blocks {
        let base = i * step;
        for w in 0..W {
            for l in 0..L {
                let j = base + w * L + l;
                let yv = x[j].mul_add(y[j], -c[w][l]);
                let t = s[w][l] + yv;
                c[w][l] = (t - s[w][l]) - yv;
                s[w][l] = t;
            }
        }
    }
    let (mut st, mut ct) = (0.0f64, 0.0f64);
    for j in blocks * step..n {
        let yv = x[j].mul_add(y[j], -ct);
        let t = st + yv;
        ct = (t - st) - yv;
        st = t;
    }
    let mut sl = [0.0f64; MAX_FOLD];
    let mut cl = [0.0f64; MAX_FOLD];
    for w in 0..W {
        for l in 0..L {
            sl[w * L + l] = s[w][l];
            cl[w * L + l] = c[w][l];
        }
    }
    sl[step] = st;
    cl[step] = ct;
    fold_kahan_lanes(&sl[..step + 1], &cl[..step + 1])
}

/// Portable reference / fallback for the W-way AVX2/AVX-512 Kahan sum
/// (no products, so this one is pure add/sub — identical math to the
/// intrinsics with or without FMA support).
pub fn kahan_sum_wide_ref<const L: usize, const W: usize>(x: &[f64]) -> f64 {
    let n = x.len();
    let step = L * W;
    let blocks = n / step;
    let mut s = [[0.0f64; L]; W];
    let mut c = [[0.0f64; L]; W];
    for i in 0..blocks {
        let base = i * step;
        for w in 0..W {
            for l in 0..L {
                let v = x[base + w * L + l];
                let yv = v - c[w][l];
                let t = s[w][l] + yv;
                c[w][l] = (t - s[w][l]) - yv;
                s[w][l] = t;
            }
        }
    }
    let (mut st, mut ct) = (0.0f64, 0.0f64);
    for &v in &x[blocks * step..] {
        let yv = v - ct;
        let t = st + yv;
        ct = (t - st) - yv;
        st = t;
    }
    let mut sl = [0.0f64; MAX_FOLD];
    let mut cl = [0.0f64; MAX_FOLD];
    for w in 0..W {
        for l in 0..L {
            sl[w * L + l] = s[w][l];
            cl[w * L + l] = c[w][l];
        }
    }
    sl[step] = st;
    cl[step] = ct;
    fold_kahan_lanes(&sl[..step + 1], &cl[..step + 1])
}

// ---------------------------------------------------------------------------
// AVX2 tier (runtime-detected)
// ---------------------------------------------------------------------------

macro_rules! avx2_dot_wrapper {
    ($name:ident, $inner:ident, $fallback:ident, $w:literal, $doc:expr) => {
        #[doc = $doc]
        pub fn $name(x: &[f64], y: &[f64]) -> f64 {
            assert_eq!(x.len(), y.len());
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                // SAFETY: AVX2+FMA verified by runtime detection; equal
                // lengths checked above (the unsafe body reads x.len()
                // elements from both slices).
                return unsafe { x86::$inner(x, y) };
            }
            $fallback::<LANES, $w>(x, y)
        }
    };
}

macro_rules! avx2_sum_wrapper {
    ($name:ident, $inner:ident, $w:literal, $doc:expr) => {
        #[doc = $doc]
        pub fn $name(x: &[f64]) -> f64 {
            #[cfg(target_arch = "x86_64")]
            if avx2_available() {
                // SAFETY: AVX2+FMA verified by runtime detection.
                return unsafe { x86::$inner(x) };
            }
            kahan_sum_wide_ref::<LANES, $w>(x)
        }
    };
}

avx2_dot_wrapper!(
    naive_dot_avx2,
    naive_dot_w1,
    naive_dot_fma_ref,
    1,
    "Naive dot via AVX2 FMA, one vector accumulator; portable `mul_add` \
     reference otherwise (bit-identical)."
);
avx2_dot_wrapper!(
    naive_dot_avx2_u2,
    naive_dot_w2,
    naive_dot_fma_ref,
    2,
    "Naive dot via AVX2 FMA with 2 independent vector accumulators."
);
avx2_dot_wrapper!(
    naive_dot_avx2_u4,
    naive_dot_w4,
    naive_dot_fma_ref,
    4,
    "Naive dot via AVX2 FMA with 4 independent vector accumulators."
);
avx2_dot_wrapper!(
    naive_dot_avx2_u8,
    naive_dot_w8,
    naive_dot_fma_ref,
    8,
    "Naive dot via AVX2 FMA with 8 independent vector accumulators — the \
     paper's throughput-saturating layout."
);
avx2_dot_wrapper!(
    kahan_dot_avx2,
    kahan_dot_w1,
    kahan_dot_fma_ref,
    1,
    "Kahan dot via AVX2, `fmsub`-fused product (the paper's KahanSimdFma), \
     one vector (s, c) pair."
);
avx2_dot_wrapper!(
    kahan_dot_avx2_u2,
    kahan_dot_w2,
    kahan_dot_fma_ref,
    2,
    "Kahan dot via AVX2 with 2 independent vector (s, c) register pairs."
);
avx2_dot_wrapper!(
    kahan_dot_avx2_u4,
    kahan_dot_w4,
    kahan_dot_fma_ref,
    4,
    "Kahan dot via AVX2 with 4 independent vector (s, c) register pairs."
);
avx2_dot_wrapper!(
    kahan_dot_avx2_u8,
    kahan_dot_w8,
    kahan_dot_fma_ref,
    8,
    "Kahan dot via AVX2 with 8 independent vector (s, c) register pairs — \
     the rung the paper shows matching naive-dot throughput."
);
avx2_sum_wrapper!(
    kahan_sum_avx2,
    kahan_sum_w1,
    1,
    "Kahan sum via AVX2, one vector (s, c) pair."
);
avx2_sum_wrapper!(
    kahan_sum_avx2_u2,
    kahan_sum_w2,
    2,
    "Kahan sum via AVX2 with 2 independent vector (s, c) register pairs."
);
avx2_sum_wrapper!(
    kahan_sum_avx2_u4,
    kahan_sum_w4,
    4,
    "Kahan sum via AVX2 with 4 independent vector (s, c) register pairs."
);
avx2_sum_wrapper!(
    kahan_sum_avx2_u8,
    kahan_sum_w8,
    8,
    "Kahan sum via AVX2 with 8 independent vector (s, c) register pairs."
);

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_fmadd_pd, _mm256_fmsub_pd, _mm256_load_pd, _mm256_loadu_pd,
        _mm256_setzero_pd, _mm256_storeu_pd, _mm256_sub_pd,
    };

    use super::{fold_kahan_lanes, LANES};

    /// 32-byte alignment gate for `_mm256_load_pd` (checked once per call;
    /// every in-loop address is then `base + k·32` bytes, so base alignment
    /// implies alignment of all loads).
    #[inline(always)]
    fn aligned(p: *const f64) -> bool {
        (p as usize) % 32 == 0
    }

    macro_rules! naive_loop {
        ($load:ident, $xp:ident, $yp:ident, $acc:ident, $blocks:ident, $step:ident, $w:tt) => {
            for i in 0..$blocks {
                let base = i * $step;
                for k in 0..$w {
                    let a = $load($xp.add(base + LANES * k));
                    let b = $load($yp.add(base + LANES * k));
                    $acc[k] = _mm256_fmadd_pd(a, b, $acc[k]);
                }
            }
        };
    }

    macro_rules! kahan_dot_loop {
        ($load:ident, $xp:ident, $yp:ident, $s:ident, $c:ident, $blocks:ident, $step:ident,
         $w:tt) => {
            for i in 0..$blocks {
                let base = i * $step;
                for k in 0..$w {
                    let a = $load($xp.add(base + LANES * k));
                    let b = $load($yp.add(base + LANES * k));
                    let yv = _mm256_fmsub_pd(a, b, $c[k]);
                    let t = _mm256_add_pd($s[k], yv);
                    $c[k] = _mm256_sub_pd(_mm256_sub_pd(t, $s[k]), yv);
                    $s[k] = t;
                }
            }
        };
    }

    macro_rules! kahan_sum_loop {
        ($load:ident, $xp:ident, $s:ident, $c:ident, $blocks:ident, $step:ident, $w:tt) => {
            for i in 0..$blocks {
                let base = i * $step;
                for k in 0..$w {
                    let v = $load($xp.add(base + LANES * k));
                    let yv = _mm256_sub_pd(v, $c[k]);
                    let t = _mm256_add_pd($s[k], yv);
                    $c[k] = _mm256_sub_pd(_mm256_sub_pd(t, $s[k]), yv);
                    $s[k] = t;
                }
            }
        };
    }

    macro_rules! avx2_rungs {
        ($naive:ident, $kahan:ident, $ksum:ident, $w:tt) => {
            /// # Safety
            /// Caller must verify AVX2 + FMA via `avx2_available()`.
            #[target_feature(enable = "avx2", enable = "fma")]
            pub unsafe fn $naive(x: &[f64], y: &[f64]) -> f64 {
                let n = x.len();
                let step = LANES * $w;
                let blocks = n / step;
                let xp = x.as_ptr();
                let yp = y.as_ptr();
                let mut acc = [_mm256_setzero_pd(); $w];
                if aligned(xp) && aligned(yp) {
                    naive_loop!(_mm256_load_pd, xp, yp, acc, blocks, step, $w);
                } else {
                    naive_loop!(_mm256_loadu_pd, xp, yp, acc, blocks, step, $w);
                }
                let mut lanes = [0.0f64; LANES * $w];
                for k in 0..$w {
                    _mm256_storeu_pd(lanes.as_mut_ptr().add(LANES * k), acc[k]);
                }
                let mut tail = 0.0f64;
                for j in blocks * step..n {
                    tail = x[j].mul_add(y[j], tail);
                }
                let mut total = 0.0f64;
                for v in lanes {
                    total += v;
                }
                total + tail
            }

            /// # Safety
            /// Caller must verify AVX2 + FMA via `avx2_available()`.
            #[target_feature(enable = "avx2", enable = "fma")]
            pub unsafe fn $kahan(x: &[f64], y: &[f64]) -> f64 {
                let n = x.len();
                let step = LANES * $w;
                let blocks = n / step;
                let xp = x.as_ptr();
                let yp = y.as_ptr();
                let mut s = [_mm256_setzero_pd(); $w];
                let mut c = [_mm256_setzero_pd(); $w];
                if aligned(xp) && aligned(yp) {
                    kahan_dot_loop!(_mm256_load_pd, xp, yp, s, c, blocks, step, $w);
                } else {
                    kahan_dot_loop!(_mm256_loadu_pd, xp, yp, s, c, blocks, step, $w);
                }
                let mut sl = [0.0f64; LANES * $w + 1];
                let mut cl = [0.0f64; LANES * $w + 1];
                for k in 0..$w {
                    _mm256_storeu_pd(sl.as_mut_ptr().add(LANES * k), s[k]);
                    _mm256_storeu_pd(cl.as_mut_ptr().add(LANES * k), c[k]);
                }
                let (mut st, mut ct) = (0.0f64, 0.0f64);
                for j in blocks * step..n {
                    let yv = x[j].mul_add(y[j], -ct);
                    let t = st + yv;
                    ct = (t - st) - yv;
                    st = t;
                }
                sl[LANES * $w] = st;
                cl[LANES * $w] = ct;
                fold_kahan_lanes(&sl, &cl)
            }

            /// # Safety
            /// Caller must verify AVX2 + FMA via `avx2_available()`.
            #[target_feature(enable = "avx2", enable = "fma")]
            pub unsafe fn $ksum(x: &[f64]) -> f64 {
                let n = x.len();
                let step = LANES * $w;
                let blocks = n / step;
                let xp = x.as_ptr();
                let mut s = [_mm256_setzero_pd(); $w];
                let mut c = [_mm256_setzero_pd(); $w];
                if aligned(xp) {
                    kahan_sum_loop!(_mm256_load_pd, xp, s, c, blocks, step, $w);
                } else {
                    kahan_sum_loop!(_mm256_loadu_pd, xp, s, c, blocks, step, $w);
                }
                let mut sl = [0.0f64; LANES * $w + 1];
                let mut cl = [0.0f64; LANES * $w + 1];
                for k in 0..$w {
                    _mm256_storeu_pd(sl.as_mut_ptr().add(LANES * k), s[k]);
                    _mm256_storeu_pd(cl.as_mut_ptr().add(LANES * k), c[k]);
                }
                let (mut st, mut ct) = (0.0f64, 0.0f64);
                for &v in &x[blocks * step..] {
                    let yv = v - ct;
                    let t = st + yv;
                    ct = (t - st) - yv;
                    st = t;
                }
                sl[LANES * $w] = st;
                cl[LANES * $w] = ct;
                fold_kahan_lanes(&sl, &cl)
            }
        };
    }

    avx2_rungs!(naive_dot_w1, kahan_dot_w1, kahan_sum_w1, 1);
    avx2_rungs!(naive_dot_w2, kahan_dot_w2, kahan_sum_w2, 2);
    avx2_rungs!(naive_dot_w4, kahan_dot_w4, kahan_sum_w4, 4);
    avx2_rungs!(naive_dot_w8, kahan_dot_w8, kahan_sum_w8, 8);
}

// ---------------------------------------------------------------------------
// AVX-512 tier (compile-gated behind the `avx512` cargo feature)
// ---------------------------------------------------------------------------

macro_rules! avx512_dot_wrapper {
    ($name:ident, $inner:ident, $fallback:ident, $w:literal, $doc:expr) => {
        #[doc = $doc]
        pub fn $name(x: &[f64], y: &[f64]) -> f64 {
            assert_eq!(x.len(), y.len());
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            if avx512_available() {
                // SAFETY: AVX-512F verified by runtime detection; equal
                // lengths checked above.
                return unsafe { x86_512::$inner(x, y) };
            }
            $fallback::<LANES_512, $w>(x, y)
        }
    };
}

macro_rules! avx512_sum_wrapper {
    ($name:ident, $inner:ident, $w:literal, $doc:expr) => {
        #[doc = $doc]
        pub fn $name(x: &[f64]) -> f64 {
            #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
            if avx512_available() {
                // SAFETY: AVX-512F verified by runtime detection.
                return unsafe { x86_512::$inner(x) };
            }
            kahan_sum_wide_ref::<LANES_512, $w>(x)
        }
    };
}

avx512_dot_wrapper!(
    naive_dot_avx512,
    naive_dot_w1,
    naive_dot_fma_ref,
    1,
    "Naive dot via AVX-512F, one 8-lane vector accumulator; portable \
     `mul_add` reference otherwise (bit-identical)."
);
avx512_dot_wrapper!(
    naive_dot_avx512_u4,
    naive_dot_w4,
    naive_dot_fma_ref,
    4,
    "Naive dot via AVX-512F with 4 independent vector accumulators."
);
avx512_dot_wrapper!(
    naive_dot_avx512_u8,
    naive_dot_w8,
    naive_dot_fma_ref,
    8,
    "Naive dot via AVX-512F with 8 independent vector accumulators."
);
avx512_dot_wrapper!(
    kahan_dot_avx512,
    kahan_dot_w1,
    kahan_dot_fma_ref,
    1,
    "Kahan dot via AVX-512F, `fmsub`-fused product, one vector (s, c) pair."
);
avx512_dot_wrapper!(
    kahan_dot_avx512_u4,
    kahan_dot_w4,
    kahan_dot_fma_ref,
    4,
    "Kahan dot via AVX-512F with 4 independent vector (s, c) register pairs."
);
avx512_dot_wrapper!(
    kahan_dot_avx512_u8,
    kahan_dot_w8,
    kahan_dot_fma_ref,
    8,
    "Kahan dot via AVX-512F with 8 independent vector (s, c) register pairs."
);
avx512_sum_wrapper!(
    kahan_sum_avx512,
    kahan_sum_w1,
    1,
    "Kahan sum via AVX-512F, one vector (s, c) pair."
);
avx512_sum_wrapper!(
    kahan_sum_avx512_u4,
    kahan_sum_w4,
    4,
    "Kahan sum via AVX-512F with 4 independent vector (s, c) register pairs."
);
avx512_sum_wrapper!(
    kahan_sum_avx512_u8,
    kahan_sum_w8,
    8,
    "Kahan sum via AVX-512F with 8 independent vector (s, c) register pairs."
);

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod x86_512 {
    use std::arch::x86_64::{
        _mm512_add_pd, _mm512_fmadd_pd, _mm512_fmsub_pd, _mm512_load_pd, _mm512_loadu_pd,
        _mm512_setzero_pd, _mm512_storeu_pd, _mm512_sub_pd,
    };

    use super::{fold_kahan_lanes, LANES_512 as LANES};

    /// 64-byte alignment gate for `_mm512_load_pd`.
    #[inline(always)]
    fn aligned(p: *const f64) -> bool {
        (p as usize) % 64 == 0
    }

    macro_rules! naive_loop {
        ($load:ident, $xp:ident, $yp:ident, $acc:ident, $blocks:ident, $step:ident, $w:tt) => {
            for i in 0..$blocks {
                let base = i * $step;
                for k in 0..$w {
                    let a = $load($xp.add(base + LANES * k));
                    let b = $load($yp.add(base + LANES * k));
                    $acc[k] = _mm512_fmadd_pd(a, b, $acc[k]);
                }
            }
        };
    }

    macro_rules! kahan_dot_loop {
        ($load:ident, $xp:ident, $yp:ident, $s:ident, $c:ident, $blocks:ident, $step:ident,
         $w:tt) => {
            for i in 0..$blocks {
                let base = i * $step;
                for k in 0..$w {
                    let a = $load($xp.add(base + LANES * k));
                    let b = $load($yp.add(base + LANES * k));
                    let yv = _mm512_fmsub_pd(a, b, $c[k]);
                    let t = _mm512_add_pd($s[k], yv);
                    $c[k] = _mm512_sub_pd(_mm512_sub_pd(t, $s[k]), yv);
                    $s[k] = t;
                }
            }
        };
    }

    macro_rules! kahan_sum_loop {
        ($load:ident, $xp:ident, $s:ident, $c:ident, $blocks:ident, $step:ident, $w:tt) => {
            for i in 0..$blocks {
                let base = i * $step;
                for k in 0..$w {
                    let v = $load($xp.add(base + LANES * k));
                    let yv = _mm512_sub_pd(v, $c[k]);
                    let t = _mm512_add_pd($s[k], yv);
                    $c[k] = _mm512_sub_pd(_mm512_sub_pd(t, $s[k]), yv);
                    $s[k] = t;
                }
            }
        };
    }

    macro_rules! avx512_rungs {
        ($naive:ident, $kahan:ident, $ksum:ident, $w:tt) => {
            /// # Safety
            /// Caller must verify AVX-512F via `avx512_available()`.
            #[target_feature(enable = "avx512f")]
            pub unsafe fn $naive(x: &[f64], y: &[f64]) -> f64 {
                let n = x.len();
                let step = LANES * $w;
                let blocks = n / step;
                let xp = x.as_ptr();
                let yp = y.as_ptr();
                let mut acc = [_mm512_setzero_pd(); $w];
                if aligned(xp) && aligned(yp) {
                    naive_loop!(_mm512_load_pd, xp, yp, acc, blocks, step, $w);
                } else {
                    naive_loop!(_mm512_loadu_pd, xp, yp, acc, blocks, step, $w);
                }
                let mut lanes = [0.0f64; LANES * $w];
                for k in 0..$w {
                    _mm512_storeu_pd(lanes.as_mut_ptr().add(LANES * k), acc[k]);
                }
                let mut tail = 0.0f64;
                for j in blocks * step..n {
                    tail = x[j].mul_add(y[j], tail);
                }
                let mut total = 0.0f64;
                for v in lanes {
                    total += v;
                }
                total + tail
            }

            /// # Safety
            /// Caller must verify AVX-512F via `avx512_available()`.
            #[target_feature(enable = "avx512f")]
            pub unsafe fn $kahan(x: &[f64], y: &[f64]) -> f64 {
                let n = x.len();
                let step = LANES * $w;
                let blocks = n / step;
                let xp = x.as_ptr();
                let yp = y.as_ptr();
                let mut s = [_mm512_setzero_pd(); $w];
                let mut c = [_mm512_setzero_pd(); $w];
                if aligned(xp) && aligned(yp) {
                    kahan_dot_loop!(_mm512_load_pd, xp, yp, s, c, blocks, step, $w);
                } else {
                    kahan_dot_loop!(_mm512_loadu_pd, xp, yp, s, c, blocks, step, $w);
                }
                let mut sl = [0.0f64; LANES * $w + 1];
                let mut cl = [0.0f64; LANES * $w + 1];
                for k in 0..$w {
                    _mm512_storeu_pd(sl.as_mut_ptr().add(LANES * k), s[k]);
                    _mm512_storeu_pd(cl.as_mut_ptr().add(LANES * k), c[k]);
                }
                let (mut st, mut ct) = (0.0f64, 0.0f64);
                for j in blocks * step..n {
                    let yv = x[j].mul_add(y[j], -ct);
                    let t = st + yv;
                    ct = (t - st) - yv;
                    st = t;
                }
                sl[LANES * $w] = st;
                cl[LANES * $w] = ct;
                fold_kahan_lanes(&sl, &cl)
            }

            /// # Safety
            /// Caller must verify AVX-512F via `avx512_available()`.
            #[target_feature(enable = "avx512f")]
            pub unsafe fn $ksum(x: &[f64]) -> f64 {
                let n = x.len();
                let step = LANES * $w;
                let blocks = n / step;
                let xp = x.as_ptr();
                let mut s = [_mm512_setzero_pd(); $w];
                let mut c = [_mm512_setzero_pd(); $w];
                if aligned(xp) {
                    kahan_sum_loop!(_mm512_load_pd, xp, s, c, blocks, step, $w);
                } else {
                    kahan_sum_loop!(_mm512_loadu_pd, xp, s, c, blocks, step, $w);
                }
                let mut sl = [0.0f64; LANES * $w + 1];
                let mut cl = [0.0f64; LANES * $w + 1];
                for k in 0..$w {
                    _mm512_storeu_pd(sl.as_mut_ptr().add(LANES * k), s[k]);
                    _mm512_storeu_pd(cl.as_mut_ptr().add(LANES * k), c[k]);
                }
                let (mut st, mut ct) = (0.0f64, 0.0f64);
                for &v in &x[blocks * step..] {
                    let yv = v - ct;
                    let t = st + yv;
                    ct = (t - st) - yv;
                    st = t;
                }
                sl[LANES * $w] = st;
                cl[LANES * $w] = ct;
                fold_kahan_lanes(&sl, &cl)
            }
        };
    }

    avx512_rungs!(naive_dot_w1, kahan_dot_w1, kahan_sum_w1, 1);
    avx512_rungs!(naive_dot_w4, kahan_dot_w4, kahan_sum_w4, 4);
    avx512_rungs!(naive_dot_w8, kahan_dot_w8, kahan_sum_w8, 8);
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// A native kernel entry point: a plain function pointer, zero overhead.
/// Public so the thread-parallel layer ([`crate::runtime::parallel`]) can
/// run the same entry points over per-thread slices.
#[derive(Clone, Copy)]
pub enum NativeFn {
    Dot(fn(&[f64], &[f64]) -> f64),
    Sum(fn(&[f64]) -> f64),
}

/// One rung of the ladder: every kernel class at one loop layout. The
/// scalar/unroll/simd/avx2/avx2-unrolled/avx512 × dot/kahan-dot/kahan-sum
/// matrix is registered exactly once here; [`NativeBackend`] and the
/// thread-parallel layer both resolve through this table, so a new style is
/// added in one row and flows to the registry, the harness experiments and
/// the bench subcommands with no special cases.
struct LadderRow {
    style: ImplStyle,
    naive_dot: fn(&[f64], &[f64]) -> f64,
    kahan_dot: fn(&[f64], &[f64]) -> f64,
    kahan_sum: fn(&[f64]) -> f64,
}

const LADDER: [LadderRow; 12] = [
    LadderRow {
        style: ImplStyle::Scalar,
        naive_dot: naive_dot_scalar,
        kahan_dot: kahan_dot_scalar,
        kahan_sum: kahan_sum_scalar,
    },
    LadderRow {
        style: ImplStyle::Unroll2,
        naive_dot: naive_dot_unrolled::<2>,
        kahan_dot: kahan_dot_unrolled::<2>,
        kahan_sum: kahan_sum_unrolled::<2>,
    },
    LadderRow {
        style: ImplStyle::Unroll4,
        naive_dot: naive_dot_unrolled::<4>,
        kahan_dot: kahan_dot_unrolled::<4>,
        kahan_sum: kahan_sum_unrolled::<4>,
    },
    LadderRow {
        style: ImplStyle::Unroll8,
        naive_dot: naive_dot_unrolled::<8>,
        kahan_dot: kahan_dot_unrolled::<8>,
        kahan_sum: kahan_sum_unrolled::<8>,
    },
    LadderRow {
        style: ImplStyle::SimdLanes,
        naive_dot: naive_dot_simd,
        kahan_dot: kahan_dot_simd,
        kahan_sum: kahan_sum_simd,
    },
    LadderRow {
        style: ImplStyle::SimdAvx2,
        naive_dot: naive_dot_avx2,
        kahan_dot: kahan_dot_avx2,
        kahan_sum: kahan_sum_avx2,
    },
    LadderRow {
        style: ImplStyle::Avx2U2,
        naive_dot: naive_dot_avx2_u2,
        kahan_dot: kahan_dot_avx2_u2,
        kahan_sum: kahan_sum_avx2_u2,
    },
    LadderRow {
        style: ImplStyle::Avx2U4,
        naive_dot: naive_dot_avx2_u4,
        kahan_dot: kahan_dot_avx2_u4,
        kahan_sum: kahan_sum_avx2_u4,
    },
    LadderRow {
        style: ImplStyle::Avx2U8,
        naive_dot: naive_dot_avx2_u8,
        kahan_dot: kahan_dot_avx2_u8,
        kahan_sum: kahan_sum_avx2_u8,
    },
    LadderRow {
        style: ImplStyle::SimdAvx512,
        naive_dot: naive_dot_avx512,
        kahan_dot: kahan_dot_avx512,
        kahan_sum: kahan_sum_avx512,
    },
    LadderRow {
        style: ImplStyle::Avx512U4,
        naive_dot: naive_dot_avx512_u4,
        kahan_dot: kahan_dot_avx512_u4,
        kahan_sum: kahan_sum_avx512_u4,
    },
    LadderRow {
        style: ImplStyle::Avx512U8,
        naive_dot: naive_dot_avx512_u8,
        kahan_dot: kahan_dot_avx512_u8,
        kahan_sum: kahan_sum_avx512_u8,
    },
];

/// Resolve a spec to its native entry point. `caps` gates the explicit-SIMD
/// tiers (runtime feature detection is the caller's — usually the
/// backend's — responsibility, resolved once per backend, never per call).
pub fn native_fn(spec: KernelSpec, caps: SimdCaps) -> Option<NativeFn> {
    if !caps.supports(spec.style) {
        return None;
    }
    let row = LADDER.iter().find(|r| r.style == spec.style)?;
    Some(match spec.class {
        KernelClass::NaiveDot => NativeFn::Dot(row.naive_dot),
        KernelClass::KahanDot => NativeFn::Dot(row.kahan_dot),
        KernelClass::KahanSum => NativeFn::Sum(row.kahan_sum),
    })
}

/// A resolved native kernel (a plain function pointer — zero overhead).
pub struct NativeKernel {
    spec: KernelSpec,
    f: NativeFn,
}

impl KernelExec for NativeKernel {
    fn spec(&self) -> KernelSpec {
        self.spec
    }

    fn run(&self, input: &KernelInput<'_>) -> Result<f64, BackendError> {
        input.check(self.spec)?;
        Ok(match (self.f, *input) {
            (NativeFn::Dot(f), KernelInput::Dot(x, y)) => f(x, y),
            (NativeFn::Sum(f), KernelInput::Sum(x)) => f(x),
            _ => unreachable!("check() verified the input kind"),
        })
    }
}

/// The host-CPU backend: pure Rust kernels, AVX2/AVX-512 when the CPU (and
/// build) has them. Capabilities are probed once at construction.
pub struct NativeBackend {
    caps: SimdCaps,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self {
            caps: SimdCaps::detect(),
        }
    }

    /// Is the AVX2 tier usable on this host?
    pub fn has_avx2(&self) -> bool {
        self.caps.avx2
    }

    /// Is the AVX-512 tier usable in this build on this host?
    pub fn has_avx512(&self) -> bool {
        self.caps.avx512
    }

    /// The SIMD tiers this backend resolved at construction.
    pub fn caps(&self) -> SimdCaps {
        self.caps
    }

    fn lookup(&self, spec: KernelSpec) -> Option<NativeFn> {
        native_fn(spec, self.caps)
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn kernels(&self) -> Vec<KernelSpec> {
        KernelSpec::all()
            .into_iter()
            .filter(|s| self.caps.supports(s.style))
            .collect()
    }

    fn resolve(&self, spec: KernelSpec) -> Result<Box<dyn KernelExec + '_>, BackendError> {
        match self.lookup(spec) {
            Some(f) => Ok(Box::new(NativeKernel { spec, f })),
            None => Err(BackendError::Unsupported {
                backend: self.name().to_string(),
                spec,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::{exact_dot, exact_sum};
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn ladder_agrees_on_benign_data() {
        let x = randvec(1003, 1); // deliberately not a multiple of 8
        let y = randvec(1003, 2);
        let want = exact_dot(&x, &y);
        let backend = NativeBackend::new();
        for spec in backend.kernels() {
            if !spec.class.is_dot() {
                continue;
            }
            let got = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
            let tol = 1e-11 * want.abs().max(1.0);
            assert!((got - want).abs() <= tol, "{spec}: {got} vs {want}");
        }
    }

    #[test]
    fn sum_ladder_agrees() {
        let x = randvec(777, 3);
        let want = exact_sum(&x);
        let backend = NativeBackend::new();
        for spec in backend.kernels() {
            if spec.class != KernelClass::KahanSum {
                continue;
            }
            let got = backend.run(spec, &KernelInput::Sum(&x)).unwrap();
            assert!(
                (got - want).abs() <= 1e-11 * want.abs().max(1.0),
                "{spec}: {got} vs {want}"
            );
        }
    }

    #[test]
    fn simd_is_bit_identical_to_unroll4() {
        for n in [0usize, 1, 3, 4, 5, 63, 64, 1000] {
            let x = randvec(n, 10 + n as u64);
            let y = randvec(n, 20 + n as u64);
            assert_eq!(naive_dot_simd(&x, &y), naive_dot_unrolled::<4>(&x, &y));
            assert_eq!(kahan_dot_simd(&x, &y), kahan_dot_unrolled::<4>(&x, &y));
            assert_eq!(kahan_sum_simd(&x), kahan_sum_unrolled::<4>(&x));
        }
    }

    /// Every explicit-SIMD rung (intrinsic path when the host has it,
    /// fallback otherwise) is bit-identical to its portable `mul_add`
    /// reference — the contract the `tests/properties.rs` corpus pins over
    /// aligned/misaligned slices and every remainder length.
    #[test]
    fn explicit_simd_rungs_bit_match_references() {
        type DotPair = (fn(&[f64], &[f64]) -> f64, fn(&[f64], &[f64]) -> f64);
        type SumPair = (fn(&[f64]) -> f64, fn(&[f64]) -> f64);
        let dots: [DotPair; 10] = [
            (naive_dot_avx2, naive_dot_fma_ref::<4, 1>),
            (naive_dot_avx2_u2, naive_dot_fma_ref::<4, 2>),
            (naive_dot_avx2_u4, naive_dot_fma_ref::<4, 4>),
            (naive_dot_avx2_u8, naive_dot_fma_ref::<4, 8>),
            (kahan_dot_avx2, kahan_dot_fma_ref::<4, 1>),
            (kahan_dot_avx2_u2, kahan_dot_fma_ref::<4, 2>),
            (kahan_dot_avx2_u4, kahan_dot_fma_ref::<4, 4>),
            (kahan_dot_avx2_u8, kahan_dot_fma_ref::<4, 8>),
            (kahan_dot_avx512, kahan_dot_fma_ref::<8, 1>),
            (kahan_dot_avx512_u8, kahan_dot_fma_ref::<8, 8>),
        ];
        let sums: [SumPair; 4] = [
            (kahan_sum_avx2, kahan_sum_wide_ref::<4, 1>),
            (kahan_sum_avx2_u8, kahan_sum_wide_ref::<4, 8>),
            (kahan_sum_avx512, kahan_sum_wide_ref::<8, 1>),
            (kahan_sum_avx512_u8, kahan_sum_wide_ref::<8, 8>),
        ];
        for n in [0usize, 1, 5, 31, 32, 33, 63, 64, 65, 127, 128, 1003] {
            let x = randvec(n, 100 + n as u64);
            let y = randvec(n, 200 + n as u64);
            for (i, (f, r)) in dots.iter().enumerate() {
                assert_eq!(f(&x, &y).to_bits(), r(&x, &y).to_bits(), "dot #{i} n={n}");
            }
            for (i, (f, r)) in sums.iter().enumerate() {
                assert_eq!(f(&x).to_bits(), r(&x).to_bits(), "sum #{i} n={n}");
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let backend = NativeBackend::new();
        for spec in backend.kernels() {
            let got = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[], &[])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[])).unwrap()
            };
            assert_eq!(got, 0.0, "{spec} on empty input");
            let one = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[3.0], &[2.0])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[6.0])).unwrap()
            };
            assert_eq!(one, 6.0, "{spec} on length-1 input");
        }
    }

    #[test]
    fn shape_and_kind_mismatches_rejected() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let err = backend
            .run(spec, &KernelInput::Dot(&[1.0], &[1.0, 2.0]))
            .unwrap_err();
        assert!(matches!(err, BackendError::ShapeMismatch { .. }));
        let err = backend.run(spec, &KernelInput::Sum(&[1.0])).unwrap_err();
        assert!(matches!(err, BackendError::InputMismatch { .. }));
    }

    #[test]
    fn avx2_matches_portable_within_kahan_bound() {
        if !avx2_available() {
            return;
        }
        let x = randvec(4097, 5);
        let y = randvec(4097, 6);
        let want = exact_dot(&x, &y);
        let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        for f in [kahan_dot_avx2, kahan_dot_avx2_u4, kahan_dot_avx2_u8, kahan_dot_simd] {
            let got = f(&x, &y);
            assert!((got - want).abs() <= 8.0 * f64::EPSILON * cond);
        }
        let abs: f64 = x.iter().map(|v| v.abs()).sum();
        for f in [kahan_sum_avx2, kahan_sum_avx2_u8] {
            let got = f(&x);
            let port = kahan_sum_simd(&x);
            assert!((got - port).abs() <= 8.0 * f64::EPSILON * abs);
        }
    }

    #[test]
    fn kahan_beats_naive_on_cancellation() {
        // Adversarial cancellation: +M enters lane 0 first and -M leaves it
        // last, so every O(100) addend in between is rounded against an
        // accumulator of magnitude M (ulp(M) = 16). The naive kernel loses
        // a random walk of those roundings; Kahan carries them in `c` and
        // the compensated fold, recovering the sum decisively (the exact
        // construction is ill-conditioned in Σ|x| / |Σx| ≈ 1e13).
        let mut rng = Rng::new(2016);
        let n = 4096;
        let mut x: Vec<f64> = (0..n).map(|_| 100.0 * rng.normal()).collect();
        let y = vec![1.0; n];
        const M: f64 = 1e17; // ulp(M) = 16 in f64
        x[0] = M;
        x[n - 4] = -M; // lane 0 of the final chunk: same chain as x[0]
        let exact = exact_dot(&x, &y);
        let e_naive = (naive_dot_simd(&x, &y) - exact).abs();
        let e_kahan = (kahan_dot_simd(&x, &y) - exact).abs();
        assert!(
            e_kahan <= 0.2 * e_naive,
            "kahan {e_kahan:.3e} must beat naive {e_naive:.3e} decisively"
        );
    }

    #[test]
    fn ladder_table_covers_every_spec() {
        for spec in KernelSpec::all() {
            let f = native_fn(spec, SimdCaps::all()).expect("every spec has a table row");
            match f {
                NativeFn::Dot(_) => assert!(spec.class.is_dot(), "{spec}"),
                NativeFn::Sum(_) => assert!(!spec.class.is_dot(), "{spec}"),
            }
            assert_eq!(
                native_fn(spec, SimdCaps::none()).is_none(),
                spec.style.uses_fma(),
                "{spec}"
            );
        }
    }

    #[test]
    fn caps_gate_each_tier_independently() {
        let avx2_only = SimdCaps {
            avx2: true,
            avx512: false,
        };
        for spec in KernelSpec::all() {
            let resolved = native_fn(spec, avx2_only).is_some();
            assert_eq!(resolved, !spec.style.needs_avx512(), "{spec}");
        }
        assert_eq!(preferred_kahan_style(SimdCaps::all()), ImplStyle::Avx512U8);
        assert_eq!(preferred_kahan_style(avx2_only), ImplStyle::Avx2U8);
        assert_eq!(preferred_kahan_style(SimdCaps::none()), ImplStyle::SimdLanes);
    }

    #[test]
    fn probes_are_stable_across_calls() {
        // OnceLock-cached probes must agree with themselves and with a
        // freshly constructed backend.
        assert_eq!(avx2_available(), avx2_available());
        assert_eq!(avx512_available(), avx512_available());
        let b = NativeBackend::new();
        assert_eq!(b.has_avx2(), avx2_available());
        assert_eq!(b.has_avx512(), avx512_available());
        assert_eq!(b.caps(), SimdCaps::detect());
    }

    #[test]
    fn resolve_reports_unsupported_avx2_when_absent() {
        let backend = NativeBackend {
            caps: SimdCaps::none(),
        };
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdAvx2);
        assert!(!backend.supports(spec));
        assert!(matches!(
            backend.resolve(spec),
            Err(BackendError::Unsupported { .. })
        ));
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::Avx512U8);
        assert!(matches!(
            backend.resolve(spec),
            Err(BackendError::Unsupported { .. })
        ));
    }
}
