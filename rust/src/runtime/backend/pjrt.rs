//! PJRT-backed [`Backend`]: executes the AOT-compiled JAX/Pallas artifacts
//! through [`crate::runtime::Executor`] (feature `pjrt`).
//!
//! The Pallas kernels are lane-parallel, so they surface as the `SimdLanes`
//! style of the naive and Kahan dot classes. Artifacts are fixed-shape: a
//! dot of length `n` resolves to the artifact compiled for exactly `n`
//! (f64 preferred, f32 accepted), and inputs of other lengths fail with a
//! [`BackendError::Runtime`].

use std::sync::Mutex;

use super::{Backend, BackendError, ImplStyle, KernelClass, KernelExec, KernelInput, KernelSpec};
use crate::runtime::executor::Executor;
use crate::runtime::manifest::Manifest;

/// Backend running the AOT artifacts on the host via PJRT.
pub struct PjrtBackend {
    ex: Mutex<Executor>,
}

impl PjrtBackend {
    /// Load the manifest from `dir` and construct a PJRT client.
    pub fn from_dir(dir: &str) -> anyhow::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let ex = Executor::new(manifest)?;
        Ok(Self { ex: Mutex::new(ex) })
    }

    pub fn from_executor(ex: Executor) -> Self {
        Self { ex: Mutex::new(ex) }
    }

    fn variant(class: KernelClass) -> Option<&'static str> {
        match class {
            KernelClass::NaiveDot => Some("naive"),
            KernelClass::KahanDot => Some("kahan"),
            KernelClass::KahanSum => None,
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn kernels(&self) -> Vec<KernelSpec> {
        [KernelClass::NaiveDot, KernelClass::KahanDot]
            .into_iter()
            .map(|class| KernelSpec::new(class, ImplStyle::SimdLanes))
            .collect()
    }

    fn resolve(&self, spec: KernelSpec) -> Result<Box<dyn KernelExec + '_>, BackendError> {
        if !self.supports(spec) {
            return Err(BackendError::Unsupported {
                backend: self.name().to_string(),
                spec,
            });
        }
        Ok(Box::new(PjrtKernel { backend: self, spec }))
    }
}

struct PjrtKernel<'a> {
    backend: &'a PjrtBackend,
    spec: KernelSpec,
}

impl KernelExec for PjrtKernel<'_> {
    fn spec(&self) -> KernelSpec {
        self.spec
    }

    fn run(&self, input: &KernelInput<'_>) -> Result<f64, BackendError> {
        let KernelInput::Dot(x, y) = *input else {
            return Err(BackendError::InputMismatch { spec: self.spec });
        };
        if x.len() != y.len() {
            return Err(BackendError::ShapeMismatch {
                lhs: x.len(),
                rhs: y.len(),
            });
        }
        let variant = PjrtBackend::variant(self.spec.class)
            .ok_or(BackendError::InputMismatch { spec: self.spec })?;
        let mut ex = self.backend.ex.lock().expect("executor lock poisoned");
        let name = {
            let m = ex.manifest();
            let n = x.len() as u64;
            m.by_variant(variant, "f64")
                .into_iter()
                .chain(m.by_variant(variant, "f32"))
                .find(|a| a.n == n && a.batch == 1)
                .map(|a| a.name.clone())
                .ok_or_else(|| {
                    BackendError::Runtime(format!(
                        "no {variant} artifact compiled for n = {n}"
                    ))
                })?
        };
        let out = ex
            .run(&name, &[x, y])
            .map_err(|e| BackendError::Runtime(format!("{e:#}")))?;
        Ok(out.scalar())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pjrt_backend_reports_dot_kernels() {
        // Without artifacts (or with the stub xla) construction fails
        // cleanly; when it succeeds, the kernel list is the Pallas pair.
        match PjrtBackend::from_dir("artifacts") {
            Ok(b) => {
                let specs = b.kernels();
                assert_eq!(specs.len(), 2);
                assert!(specs.iter().all(|s| s.style == ImplStyle::SimdLanes));
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(!msg.is_empty());
            }
        }
    }
}
