//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 emits 64-bit instruction ids in
//! serialized protos, which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::manifest::{Artifact, Manifest};

/// Output of one artifact execution.
#[derive(Clone, Debug)]
pub struct RunOutput {
    /// Flattened outputs, one vector per tuple element, converted to f64.
    pub outputs: Vec<Vec<f64>>,
}

impl RunOutput {
    /// First element of the first output — the scalar result of the dot
    /// artifacts.
    pub fn scalar(&self) -> f64 {
        self.outputs[0][0]
    }
}

/// Compiles and caches PJRT executables for manifest artifacts.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for `name`.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let art = self.manifest.get(name)?.clone();
            let path = self.manifest.hlo_path(&art);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Build input literals for an artifact from f64 data (converted to the
    /// artifact dtype). `data` must contain one slice per input parameter.
    pub fn literals(&self, art: &Artifact, data: &[&[f64]]) -> Result<Vec<xla::Literal>> {
        if data.len() != art.input_shapes.len() {
            bail!(
                "{} expects {} inputs, got {}",
                art.name,
                art.input_shapes.len(),
                data.len()
            );
        }
        let mut lits = Vec::with_capacity(data.len());
        for (d, shape) in data.iter().zip(&art.input_shapes) {
            let want: u64 = shape.iter().product();
            if d.len() as u64 != want {
                bail!("{}: input needs {} elems, got {}", art.name, want, d.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            let lit = if art.dtype == "f64" {
                xla::Literal::vec1(d).reshape(&dims)?
            } else {
                let f32s: Vec<f32> = d.iter().map(|&x| x as f32).collect();
                xla::Literal::vec1(&f32s).reshape(&dims)?
            };
            lits.push(lit);
        }
        Ok(lits)
    }

    /// Execute an artifact on the given inputs.
    pub fn run(&mut self, name: &str, data: &[&[f64]]) -> Result<RunOutput> {
        let art = self.manifest.get(name)?.clone();
        let lits = self.literals(&art, data)?;
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let elems = result.to_tuple()?;
        let mut outputs = Vec::with_capacity(elems.len());
        for e in elems {
            let v: Vec<f64> = if art.dtype == "f64" {
                e.to_vec::<f64>()?
            } else {
                e.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect()
            };
            outputs.push(v);
        }
        Ok(RunOutput { outputs })
    }

    /// Execute with pre-built literals (hot path for benchmarking; no
    /// conversion or validation).
    pub fn run_prepared(
        &mut self,
        name: &str,
        lits: &[xla::Literal],
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.load(name)?;
        let mut r = exe.execute::<xla::Literal>(lits)?;
        Ok(r.remove(0).remove(0))
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run; they are skipped
    //! (cleanly) when the artifact directory is absent so `cargo test`
    //! works in a fresh checkout.
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::rng::Rng;

    fn executor() -> Option<Executor> {
        let m = Manifest::load("artifacts").ok()?;
        Executor::new(m).ok()
    }

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn kahan_artifact_accuracy() {
        let Some(mut ex) = executor() else { return };
        let n = 4096;
        let x = randvec(n, 1);
        let y = randvec(n, 2);
        let out = ex.run("kahan_f32_n4096", &[&x, &y]).unwrap();
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        let exact = exact_dot_f32(&xf, &yf);
        let scale: f64 = xf.iter().zip(&yf).map(|(a, b)| (a * b).abs() as f64).sum();
        assert!(
            (out.scalar() - exact).abs() <= 8.0 * f32::EPSILON as f64 * scale,
            "kahan={} exact={exact}",
            out.scalar()
        );
    }

    #[test]
    fn pair_artifact_naive_vs_kahan() {
        let Some(mut ex) = executor() else { return };
        let n = 4096;
        let x = randvec(n, 3);
        let y = randvec(n, 4);
        let out = ex.run("pair_f32_n4096", &[&x, &y]).unwrap();
        assert_eq!(out.outputs.len(), 2);
        let (naive, kahan) = (out.outputs[0][0], out.outputs[1][0]);
        assert!(naive.is_finite() && kahan.is_finite());
        let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        assert!((naive - kahan).abs() <= 64.0 * f32::EPSILON as f64 * scale);
    }

    #[test]
    fn f64_artifact_runs() {
        let Some(mut ex) = executor() else { return };
        let n = 4096;
        let x = randvec(n, 5);
        let y = randvec(n, 6);
        let out = ex.run("kahan_f64_n4096", &[&x, &y]).unwrap();
        let direct: f64 = crate::accuracy::dots::kahan_dot(&x, &y);
        // f64 lane-kahan vs scalar kahan: close to f64 roundoff of the sum.
        let scale: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        assert!((out.scalar() - direct).abs() <= 16.0 * f64::EPSILON * scale);
    }

    #[test]
    fn executor_caches_compilations() {
        let Some(mut ex) = executor() else { return };
        let x = randvec(4096, 7);
        let y = randvec(4096, 8);
        ex.run("naive_f32_n4096", &[&x, &y]).unwrap();
        assert!(ex.cache.contains_key("naive_f32_n4096"));
        ex.run("naive_f32_n4096", &[&x, &y]).unwrap();
        assert_eq!(ex.cache.len(), 1);
    }

    #[test]
    fn wrong_input_count_rejected() {
        let Some(mut ex) = executor() else { return };
        let x = randvec(16, 9);
        assert!(ex.run("kahan_f32_n4096", &[&x]).is_err());
    }

    #[test]
    fn wrong_length_rejected() {
        let Some(mut ex) = executor() else { return };
        let x = randvec(16, 10);
        let y = randvec(16, 11);
        assert!(ex.run("kahan_f32_n4096", &[&x, &y]).is_err());
    }
}
