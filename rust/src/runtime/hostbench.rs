//! Mini-likwid: steady-state benchmarking of kernels on the host CPU.
//!
//! Methodology follows the paper's likwid-bench protocol: inputs prepared
//! once in the 64-byte-aligned operand arena (no allocation on the timed
//! path; explicit-SIMD kernels take their aligned-load fast path), the
//! kernel resolved once per bench run (a `NativeFn` function pointer —
//! feature detection and table lookup never sit inside the rep loop),
//! warmup until caches are primed (and, for PJRT, the executable
//! compiled), then timed runs; the *best* run is the headline number
//! (cycle-deterministic kernel, interference only adds time). Small
//! kernels are batched so every timed sample spans at least a few tens of
//! microseconds of work.
//!
//! Entry points:
//! * [`bench_kernel`] — any [`Backend`] kernel (native by default) at one
//!   size; reports best-run *and* median-of-reps metrics;
//! * [`bench_ws_sweep`] — one kernel across a working-set size grid
//!   (the measured analog of the simulator's Fig. 5–7 sweeps);
//! * [`bench_scaling`] — one kernel across thread counts on the parallel
//!   native backend (the measured analog of the Fig. 8/9 core scans);
//! * [`bench_artifact`] (feature `pjrt`) — a named AOT artifact.

use std::time::Instant;

use anyhow::Result;

use super::arena::AlignedVec;
use super::backend::{Backend, KernelInput, KernelSpec};
use super::parallel::ParallelBackend;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Result of benchmarking one backend kernel at one size.
#[derive(Clone, Debug)]
pub struct KernelBenchResult {
    /// Kernel spec id, e.g. `kahan_dot.avx2`.
    pub kernel: String,
    /// Backend name the kernel ran on.
    pub backend: String,
    /// Vector length (updates per execution).
    pub n: usize,
    /// Working set in bytes (all operand streams).
    pub ws_bytes: u64,
    /// Arithmetic operations per execution.
    pub flops: u64,
    /// Wall time per execution, ns.
    pub ns: Summary,
    /// Updates/s (GUP/s) from the best run.
    pub gups_best: f64,
    /// Streamed bandwidth GB/s from the best run.
    pub gbs_best: f64,
    /// Arithmetic throughput MFlop/s from the best run.
    pub mflops_best: f64,
    /// Updates/s (GUP/s) from the median run — robust against one-off
    /// interference, the headline for scaling/model comparisons.
    pub gups_median: f64,
    /// Arithmetic throughput MFlop/s from the median run.
    pub mflops_median: f64,
    /// Streamed bandwidth GB/s from the median run (consistent with the
    /// other median metrics: gbs_median / gups_median = bytes per update).
    pub gbs_median: f64,
    /// Cycles per flop (needs a clock estimate).
    pub cycles_per_flop: Option<f64>,
    /// Cycles per loop update (the paper's cy/up metric), best run.
    pub cycles_per_update: Option<f64>,
    /// Cycles per loop update from the median run.
    pub cycles_per_update_median: Option<f64>,
}

/// Deterministic benchmark operands for one (kernel, n): normal-distributed
/// vectors seeded by the length only, so every thread count / backend
/// benches the identical data. Allocated from the 64-byte-aligned operand
/// arena, so the explicit-SIMD kernels take their aligned-load fast path
/// and thread-parallel chunk boundaries never straddle a cache line.
pub fn bench_inputs(spec: KernelSpec, n: usize) -> (AlignedVec, AlignedVec) {
    let mut rng = Rng::new(0xBE7C4 ^ n as u64);
    let x = AlignedVec::from_fn(n, |_| rng.normal());
    let y = if spec.class.is_dot() {
        AlignedVec::from_fn(n, |_| rng.normal())
    } else {
        AlignedVec::empty()
    };
    (x, y)
}

/// Benchmark one kernel of `backend` on prepared operands (no allocation or
/// generation on the timed path — callers reusing inputs across thread
/// counts go through this). `reps` timed samples after `warmup` executions;
/// pass the core clock in `freq_ghz` for cycle metrics.
pub fn bench_prepared(
    backend: &dyn Backend,
    spec: KernelSpec,
    input: &KernelInput<'_>,
    warmup: usize,
    reps: usize,
    freq_ghz: Option<f64>,
) -> Result<KernelBenchResult> {
    let n = input.updates();
    let exec = backend.resolve(spec)?;

    // Batch so one timed sample covers >= ~50k updates (timer resolution).
    let batch = (50_000 / n.max(1)).max(1);
    for _ in 0..warmup.max(1) {
        std::hint::black_box(exec.run(input)?);
    }
    let mut samples = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(exec.run(input)?);
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    let ns = Summary::of(&samples);
    let flops = n as u64 * spec.class.flops_per_update();
    let ws_bytes = n as u64 * spec.class.bytes_per_update();
    Ok(KernelBenchResult {
        kernel: spec.id(),
        backend: backend.name().to_string(),
        n,
        ws_bytes,
        flops,
        gups_best: n as f64 / ns.min,
        gbs_best: ws_bytes as f64 / ns.min,
        mflops_best: flops as f64 / ns.min * 1000.0,
        gups_median: n as f64 / ns.median,
        mflops_median: flops as f64 / ns.median * 1000.0,
        gbs_median: ws_bytes as f64 / ns.median,
        cycles_per_flop: freq_ghz.map(|f| ns.min * f / flops.max(1) as f64),
        cycles_per_update: freq_ghz.map(|f| ns.min * f / n.max(1) as f64),
        cycles_per_update_median: freq_ghz.map(|f| ns.median * f / n.max(1) as f64),
        ns,
    })
}

/// Benchmark one kernel of `backend` on fresh normal-distributed inputs of
/// length `n` (see [`bench_inputs`] / [`bench_prepared`]).
pub fn bench_kernel(
    backend: &dyn Backend,
    spec: KernelSpec,
    n: usize,
    warmup: usize,
    reps: usize,
    freq_ghz: Option<f64>,
) -> Result<KernelBenchResult> {
    let (x, y) = bench_inputs(spec, n);
    let input = if spec.class.is_dot() {
        KernelInput::Dot(&x[..], &y[..])
    } else {
        KernelInput::Sum(&x[..])
    };
    bench_prepared(backend, spec, &input, warmup, reps, freq_ghz)
}

/// Working-set sweep: benchmark `spec` at each working-set size (bytes over
/// all operand streams), likwid-bench style. Sizes are converted to vector
/// lengths via the kernel's bytes-per-update, so the same byte grid is
/// comparable across dot (16 B/update) and sum (8 B/update) kernels and
/// against the simulator's [`crate::sim::default_sweep_sizes`] grid.
pub fn bench_ws_sweep(
    backend: &dyn Backend,
    spec: KernelSpec,
    sizes_bytes: &[u64],
    warmup: usize,
    reps: usize,
    freq_ghz: Option<f64>,
) -> Result<Vec<KernelBenchResult>> {
    sizes_bytes
        .iter()
        .map(|&ws| {
            let n = (ws / spec.class.bytes_per_update()).max(1) as usize;
            bench_kernel(backend, spec, n, warmup, reps, freq_ghz)
        })
        .collect()
}

/// Core-scaling sweep: benchmark `spec` on the thread-parallel native
/// backend for every thread count `1..=max_threads` at a fixed vector
/// length (pick one deep in memory to probe bandwidth saturation). The
/// operand *values* are generated once (identical data at every thread
/// count), but each thread count gets its own first-touch arena copy: the
/// persistent pool of the backend under test writes each chunk's pages
/// from the worker that will later stream them, so NUMA placement matches
/// the dispatch. Each `ParallelBackend` spawns its worker pool once and
/// reuses it across warmup + reps — the timed samples contain kernel
/// execution, not thread creation. Returns `(threads, result)` in thread
/// order.
pub fn bench_scaling(
    spec: KernelSpec,
    n: usize,
    max_threads: usize,
    warmup: usize,
    reps: usize,
    freq_ghz: Option<f64>,
) -> Result<Vec<(usize, KernelBenchResult)>> {
    let (src_x, src_y) = bench_inputs(spec, n);
    (1..=max_threads.max(1))
        .map(|t| {
            let backend = ParallelBackend::new(t);
            let x = AlignedVec::first_touch_copy(&src_x, backend.pool());
            let y = if spec.class.is_dot() {
                AlignedVec::first_touch_copy(&src_y, backend.pool())
            } else {
                AlignedVec::empty()
            };
            let input = if spec.class.is_dot() {
                KernelInput::Dot(&x[..], &y[..])
            } else {
                KernelInput::Sum(&x[..])
            };
            bench_prepared(&backend, spec, &input, warmup, reps, freq_ghz).map(|r| (t, r))
        })
        .collect()
}

/// Where a clock estimate came from — recorded next to every cycle metric
/// so a nominal fallback is never mistaken for a measured clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FreqSource {
    /// `/sys/.../cpufreq/cpuinfo_max_freq` (stable across runs).
    Cpufreq,
    /// `/proc/cpuinfo` `cpu MHz` (instantaneous, governor-scaled).
    CpuInfo,
    /// Neither interface available: the documented nominal fallback
    /// [`NOMINAL_FREQ_GHZ`]. Cycle metrics are then order-of-magnitude
    /// estimates, not measurements.
    Nominal,
    /// `--freq-ghz` on the command line.
    UserProvided,
}

impl FreqSource {
    pub fn label(self) -> &'static str {
        match self {
            FreqSource::Cpufreq => "cpufreq",
            FreqSource::CpuInfo => "cpuinfo",
            FreqSource::Nominal => "nominal-fallback",
            FreqSource::UserProvided => "cli",
        }
    }
}

/// Nominal clock assumed when no platform interface reports one — a
/// middle-of-the-road server-core value so cycles/flop is never silently
/// absent (the source is reported alongside, see [`FreqSource::Nominal`]).
pub const NOMINAL_FREQ_GHZ: f64 = 2.5;

/// Core clock estimate in GHz that always succeeds: cpufreq maximum
/// frequency, then `/proc/cpuinfo`, then the documented
/// [`NOMINAL_FREQ_GHZ`] fallback — with the source attached.
pub fn freq_ghz_with_source() -> (f64, FreqSource) {
    match detect_freq_ghz_sourced() {
        Some((f, src)) => (f, src),
        None => (NOMINAL_FREQ_GHZ, FreqSource::Nominal),
    }
}

/// Platform clock estimate in GHz, `None` when no interface reports one
/// (use [`freq_ghz_with_source`] for the never-`None` path).
pub fn detect_freq_ghz() -> Option<f64> {
    detect_freq_ghz_sourced().map(|(f, _)| f)
}

fn detect_freq_ghz_sourced() -> Option<(f64, FreqSource)> {
    let max_khz = std::fs::read_to_string("/sys/devices/system/cpu/cpu0/cpufreq/cpuinfo_max_freq")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok());
    if let Some(khz) = max_khz {
        if khz > 0.0 {
            return Some((khz / 1e6, FreqSource::Cpufreq));
        }
    }
    let text = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("cpu MHz") {
            if let Some(v) = rest.split(':').nth(1) {
                if let Ok(mhz) = v.trim().parse::<f64>() {
                    if mhz > 0.0 {
                        return Some((mhz / 1000.0, FreqSource::CpuInfo));
                    }
                }
            }
        }
    }
    None
}

#[cfg(feature = "pjrt")]
pub use pjrt_bench::{bench_artifact, HostBenchResult};

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use super::*;
    use crate::runtime::executor::Executor;

    /// Result of benchmarking one AOT artifact.
    #[derive(Clone, Debug)]
    pub struct HostBenchResult {
        pub name: String,
        /// Working set in bytes (both streams).
        pub ws_bytes: u64,
        /// Updates per execution.
        pub updates: u64,
        /// Wall time per execution, ns.
        pub ns: Summary,
        /// Throughput in GUP/s from the best run.
        pub gups_best: f64,
        /// Effective streamed bandwidth GB/s from the best run.
        pub gbs_best: f64,
    }

    /// Benchmark one artifact by name. `reps` timed executions after
    /// `warmup`.
    pub fn bench_artifact(
        ex: &mut Executor,
        name: &str,
        warmup: usize,
        reps: usize,
    ) -> Result<HostBenchResult> {
        let art = ex.manifest().get(name)?.clone();
        let elems: u64 = art.elems();
        let mut rng = Rng::new(0xBE7C4 ^ elems);
        let data: Vec<Vec<f64>> = art
            .input_shapes
            .iter()
            .map(|s| {
                let n: u64 = s.iter().product();
                (0..n).map(|_| rng.normal()).collect()
            })
            .collect();
        let refs: Vec<&[f64]> = data.iter().map(|d| d.as_slice()).collect();
        let lits = ex.literals(&art, &refs)?;

        for _ in 0..warmup.max(1) {
            let _ = ex.run_prepared(name, &lits)?;
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            let buf = ex.run_prepared(name, &lits)?;
            // PJRT CPU executes synchronously-ish, but fence via a host copy
            // of the (tiny) result to be strict about completion.
            let _ = buf.to_literal_sync()?;
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let ns = Summary::of(&samples);
        let updates = art.updates();
        let gups_best = updates as f64 / ns.min;
        let gbs_best = art.ws_bytes() as f64 / ns.min;
        Ok(HostBenchResult {
            name: name.to_string(),
            ws_bytes: art.ws_bytes(),
            updates,
            ns,
            gups_best,
            gbs_best,
        })
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::runtime::manifest::Manifest;

        #[test]
        fn bench_small_artifact_if_present() {
            let Ok(m) = Manifest::load("artifacts") else {
                return;
            };
            let Ok(mut ex) = Executor::new(m) else {
                return; // stub xla: no PJRT client available
            };
            let r = bench_artifact(&mut ex, "naive_opt_f32_n4096", 2, 3).unwrap();
            assert!(r.ns.min > 0.0);
            assert!(r.gups_best > 0.0);
            assert_eq!(r.updates, 4096);
            assert_eq!(r.ws_bytes, 2 * 4096 * 4);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{ImplStyle, KernelClass, NativeBackend};

    #[test]
    fn native_kernel_bench_produces_throughput() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let r = bench_kernel(&backend, spec, 2048, 1, 3, Some(2.0)).unwrap();
        assert_eq!(r.kernel, "kahan_dot.simd");
        assert_eq!(r.backend, "native");
        assert_eq!(r.n, 2048);
        assert_eq!(r.ws_bytes, 2 * 2048 * 8);
        assert_eq!(r.flops, 5 * 2048);
        assert!(r.ns.min > 0.0);
        assert!(r.gups_best > 0.0 && r.mflops_best > 0.0 && r.gbs_best > 0.0);
        let cpf = r.cycles_per_flop.unwrap();
        let cpu = r.cycles_per_update.unwrap();
        assert!(cpf > 0.0 && cpu > 0.0);
        // 5 flops per update ties the two cycle metrics together.
        assert!((cpu / cpf - 5.0).abs() < 1e-9);
        // Median metrics are populated and consistent with the summary.
        assert!(r.gups_median > 0.0 && r.mflops_median > 0.0);
        assert!(r.gups_median <= r.gups_best * (1.0 + 1e-12));
        let cpm = r.cycles_per_update_median.unwrap();
        assert!(cpm >= cpu * (1.0 - 1e-12));
        // Per-record consistency: gbs_median / gups_median = bytes/update.
        assert!((r.gbs_median / r.gups_median - 16.0).abs() < 1e-9);
    }

    #[test]
    fn sum_kernels_bench_too() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanSum, ImplStyle::Unroll4);
        let r = bench_kernel(&backend, spec, 1000, 1, 2, None).unwrap();
        assert_eq!(r.ws_bytes, 8 * 1000);
        assert!(r.cycles_per_flop.is_none());
        assert!(r.ns.min > 0.0);
    }

    #[test]
    fn freq_detection_is_sane_if_present() {
        if let Some(f) = detect_freq_ghz() {
            assert!(f > 0.1 && f < 10.0, "implausible clock {f} GHz");
        }
    }

    #[test]
    fn freq_with_source_never_fails() {
        let (f, src) = freq_ghz_with_source();
        assert!(f > 0.1 && f < 10.0, "implausible clock {f} GHz");
        if detect_freq_ghz().is_none() {
            assert_eq!(src, FreqSource::Nominal);
            assert_eq!(f, NOMINAL_FREQ_GHZ);
        } else {
            assert_ne!(src, FreqSource::Nominal);
        }
        assert!(!src.label().is_empty());
    }

    #[test]
    fn ws_sweep_converts_bytes_to_lengths() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let sizes = [4 * 1024u64, 64 * 1024];
        let pts = bench_ws_sweep(&backend, spec, &sizes, 1, 2, None).unwrap();
        assert_eq!(pts.len(), 2);
        for (p, &ws) in pts.iter().zip(&sizes) {
            assert_eq!(p.n as u64, ws / 16, "dot streams 16 B per update");
            assert!(p.gups_median > 0.0);
        }
        let sum = KernelSpec::new(KernelClass::KahanSum, ImplStyle::Scalar);
        let pts = bench_ws_sweep(&backend, sum, &sizes[..1], 1, 2, None).unwrap();
        assert_eq!(pts[0].n as u64, sizes[0] / 8, "sum streams 8 B per update");
    }

    #[test]
    fn scaling_sweep_covers_every_thread_count() {
        let spec = KernelSpec::new(KernelClass::NaiveDot, ImplStyle::SimdLanes);
        let curve = bench_scaling(spec, 1 << 14, 3, 1, 2, Some(2.0)).unwrap();
        assert_eq!(curve.len(), 3);
        for (i, (t, r)) in curve.iter().enumerate() {
            assert_eq!(*t, i + 1);
            assert_eq!(r.backend, "native-mt");
            assert!(r.mflops_median > 0.0);
        }
    }
}
