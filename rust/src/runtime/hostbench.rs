//! Mini-likwid: steady-state benchmarking of kernels on the host CPU.
//!
//! Methodology follows the paper's likwid-bench protocol: inputs prepared
//! once (no allocation on the timed path), warmup until caches are primed
//! (and, for PJRT, the executable compiled), then timed runs; the *best*
//! run is the headline number (cycle-deterministic kernel, interference
//! only adds time). Small kernels are batched so every timed sample spans
//! at least a few tens of microseconds of work.
//!
//! Two entry points:
//! * [`bench_kernel`] — any [`Backend`] kernel (native by default);
//! * [`bench_artifact`] (feature `pjrt`) — a named AOT artifact.

use std::time::Instant;

use anyhow::Result;

use super::backend::{Backend, KernelInput, KernelSpec};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Result of benchmarking one backend kernel at one size.
#[derive(Clone, Debug)]
pub struct KernelBenchResult {
    /// Kernel spec id, e.g. `kahan_dot.avx2`.
    pub kernel: String,
    /// Backend name the kernel ran on.
    pub backend: String,
    /// Vector length (updates per execution).
    pub n: usize,
    /// Working set in bytes (all operand streams).
    pub ws_bytes: u64,
    /// Arithmetic operations per execution.
    pub flops: u64,
    /// Wall time per execution, ns.
    pub ns: Summary,
    /// Updates/s (GUP/s) from the best run.
    pub gups_best: f64,
    /// Streamed bandwidth GB/s from the best run.
    pub gbs_best: f64,
    /// Arithmetic throughput MFlop/s from the best run.
    pub mflops_best: f64,
    /// Cycles per flop (needs a clock estimate).
    pub cycles_per_flop: Option<f64>,
    /// Cycles per loop update (the paper's cy/up metric).
    pub cycles_per_update: Option<f64>,
}

/// Benchmark one kernel of `backend` on fresh normal-distributed inputs of
/// length `n`. `reps` timed samples after `warmup` executions; pass the
/// core clock in `freq_ghz` (see [`detect_freq_ghz`]) to get cycle metrics.
pub fn bench_kernel(
    backend: &dyn Backend,
    spec: KernelSpec,
    n: usize,
    warmup: usize,
    reps: usize,
    freq_ghz: Option<f64>,
) -> Result<KernelBenchResult> {
    let mut rng = Rng::new(0xBE7C4 ^ n as u64);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let y: Vec<f64> = if spec.class.is_dot() {
        (0..n).map(|_| rng.normal()).collect()
    } else {
        Vec::new()
    };
    let input = if spec.class.is_dot() {
        KernelInput::Dot(&x, &y)
    } else {
        KernelInput::Sum(&x)
    };
    let exec = backend.resolve(spec)?;

    // Batch so one timed sample covers >= ~50k updates (timer resolution).
    let batch = (50_000 / n.max(1)).max(1);
    for _ in 0..warmup.max(1) {
        std::hint::black_box(exec.run(&input)?);
    }
    let mut samples = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(exec.run(&input)?);
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    let ns = Summary::of(&samples);
    let flops = n as u64 * spec.class.flops_per_update();
    let ws_bytes = n as u64 * spec.class.bytes_per_update();
    Ok(KernelBenchResult {
        kernel: spec.id(),
        backend: backend.name().to_string(),
        n,
        ws_bytes,
        flops,
        gups_best: n as f64 / ns.min,
        gbs_best: ws_bytes as f64 / ns.min,
        mflops_best: flops as f64 / ns.min * 1000.0,
        cycles_per_flop: freq_ghz.map(|f| ns.min * f / flops.max(1) as f64),
        cycles_per_update: freq_ghz.map(|f| ns.min * f / n.max(1) as f64),
        ns,
    })
}

/// Best-effort core clock estimate in GHz (Linux). Prefers the cpufreq
/// *maximum* frequency — stable across runs, unlike the instantaneous
/// governor-scaled `cpu MHz` value, which is only the fallback. Returns
/// `None` when unavailable — cycle metrics are then omitted.
pub fn detect_freq_ghz() -> Option<f64> {
    let max_khz = std::fs::read_to_string("/sys/devices/system/cpu/cpu0/cpufreq/cpuinfo_max_freq")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok());
    if let Some(khz) = max_khz {
        if khz > 0.0 {
            return Some(khz / 1e6);
        }
    }
    let text = std::fs::read_to_string("/proc/cpuinfo").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("cpu MHz") {
            if let Some(v) = rest.split(':').nth(1) {
                if let Ok(mhz) = v.trim().parse::<f64>() {
                    if mhz > 0.0 {
                        return Some(mhz / 1000.0);
                    }
                }
            }
        }
    }
    None
}

#[cfg(feature = "pjrt")]
pub use pjrt_bench::{bench_artifact, HostBenchResult};

#[cfg(feature = "pjrt")]
mod pjrt_bench {
    use super::*;
    use crate::runtime::executor::Executor;

    /// Result of benchmarking one AOT artifact.
    #[derive(Clone, Debug)]
    pub struct HostBenchResult {
        pub name: String,
        /// Working set in bytes (both streams).
        pub ws_bytes: u64,
        /// Updates per execution.
        pub updates: u64,
        /// Wall time per execution, ns.
        pub ns: Summary,
        /// Throughput in GUP/s from the best run.
        pub gups_best: f64,
        /// Effective streamed bandwidth GB/s from the best run.
        pub gbs_best: f64,
    }

    /// Benchmark one artifact by name. `reps` timed executions after
    /// `warmup`.
    pub fn bench_artifact(
        ex: &mut Executor,
        name: &str,
        warmup: usize,
        reps: usize,
    ) -> Result<HostBenchResult> {
        let art = ex.manifest().get(name)?.clone();
        let elems: u64 = art.elems();
        let mut rng = Rng::new(0xBE7C4 ^ elems);
        let data: Vec<Vec<f64>> = art
            .input_shapes
            .iter()
            .map(|s| {
                let n: u64 = s.iter().product();
                (0..n).map(|_| rng.normal()).collect()
            })
            .collect();
        let refs: Vec<&[f64]> = data.iter().map(|d| d.as_slice()).collect();
        let lits = ex.literals(&art, &refs)?;

        for _ in 0..warmup.max(1) {
            let _ = ex.run_prepared(name, &lits)?;
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            let buf = ex.run_prepared(name, &lits)?;
            // PJRT CPU executes synchronously-ish, but fence via a host copy
            // of the (tiny) result to be strict about completion.
            let _ = buf.to_literal_sync()?;
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let ns = Summary::of(&samples);
        let updates = art.updates();
        let gups_best = updates as f64 / ns.min;
        let gbs_best = art.ws_bytes() as f64 / ns.min;
        Ok(HostBenchResult {
            name: name.to_string(),
            ws_bytes: art.ws_bytes(),
            updates,
            ns,
            gups_best,
            gbs_best,
        })
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::runtime::manifest::Manifest;

        #[test]
        fn bench_small_artifact_if_present() {
            let Ok(m) = Manifest::load("artifacts") else {
                return;
            };
            let Ok(mut ex) = Executor::new(m) else {
                return; // stub xla: no PJRT client available
            };
            let r = bench_artifact(&mut ex, "naive_opt_f32_n4096", 2, 3).unwrap();
            assert!(r.ns.min > 0.0);
            assert!(r.gups_best > 0.0);
            assert_eq!(r.updates, 4096);
            assert_eq!(r.ws_bytes, 2 * 4096 * 4);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{ImplStyle, KernelClass, NativeBackend};

    #[test]
    fn native_kernel_bench_produces_throughput() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let r = bench_kernel(&backend, spec, 2048, 1, 3, Some(2.0)).unwrap();
        assert_eq!(r.kernel, "kahan_dot.simd");
        assert_eq!(r.backend, "native");
        assert_eq!(r.n, 2048);
        assert_eq!(r.ws_bytes, 2 * 2048 * 8);
        assert_eq!(r.flops, 5 * 2048);
        assert!(r.ns.min > 0.0);
        assert!(r.gups_best > 0.0 && r.mflops_best > 0.0 && r.gbs_best > 0.0);
        let cpf = r.cycles_per_flop.unwrap();
        let cpu = r.cycles_per_update.unwrap();
        assert!(cpf > 0.0 && cpu > 0.0);
        // 5 flops per update ties the two cycle metrics together.
        assert!((cpu / cpf - 5.0).abs() < 1e-9);
    }

    #[test]
    fn sum_kernels_bench_too() {
        let backend = NativeBackend::new();
        let spec = KernelSpec::new(KernelClass::KahanSum, ImplStyle::Unroll4);
        let r = bench_kernel(&backend, spec, 1000, 1, 2, None).unwrap();
        assert_eq!(r.ws_bytes, 8 * 1000);
        assert!(r.cycles_per_flop.is_none());
        assert!(r.ns.min > 0.0);
    }

    #[test]
    fn freq_detection_is_sane_if_present() {
        if let Some(f) = detect_freq_ghz() {
            assert!(f > 0.1 && f < 10.0, "implausible clock {f} GHz");
        }
    }
}
