//! Mini-likwid: steady-state benchmarking of AOT artifacts on the host CPU.
//!
//! Methodology follows the paper's likwid-bench protocol: inputs prepared
//! once (no allocation on the timed path), warmup until the executable is
//! compiled and caches are primed, then `reps` timed runs; the *best* run
//! is the headline number (cycle-deterministic kernel, interference only
//! adds time).

use std::time::Instant;

use anyhow::Result;

use super::executor::Executor;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

/// Result of benchmarking one artifact.
#[derive(Clone, Debug)]
pub struct HostBenchResult {
    pub name: String,
    /// Working set in bytes (both streams).
    pub ws_bytes: u64,
    /// Updates per execution.
    pub updates: u64,
    /// Wall time per execution, ns.
    pub ns: Summary,
    /// Throughput in GUP/s from the best run.
    pub gups_best: f64,
    /// Effective streamed bandwidth GB/s from the best run.
    pub gbs_best: f64,
}

/// Benchmark one artifact by name. `reps` timed executions after `warmup`.
pub fn bench_artifact(
    ex: &mut Executor,
    name: &str,
    warmup: usize,
    reps: usize,
) -> Result<HostBenchResult> {
    let art = ex.manifest().get(name)?.clone();
    let elems: u64 = art.elems();
    let mut rng = Rng::new(0xBE7C4 ^ elems);
    let data: Vec<Vec<f64>> = art
        .input_shapes
        .iter()
        .map(|s| {
            let n: u64 = s.iter().product();
            (0..n).map(|_| rng.normal()).collect()
        })
        .collect();
    let refs: Vec<&[f64]> = data.iter().map(|d| d.as_slice()).collect();
    let lits = ex.literals(&art, &refs)?;

    for _ in 0..warmup.max(1) {
        let _ = ex.run_prepared(name, &lits)?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let buf = ex.run_prepared(name, &lits)?;
        // PJRT CPU executes synchronously-ish, but fence via a host copy of
        // the (tiny) result to be strict about completion.
        let _ = buf.to_literal_sync()?;
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let ns = Summary::of(&samples);
    let updates = art.updates();
    let gups_best = updates as f64 / ns.min;
    let gbs_best = art.ws_bytes() as f64 / ns.min;
    Ok(HostBenchResult {
        name: name.to_string(),
        ws_bytes: art.ws_bytes(),
        updates,
        ns,
        gups_best,
        gbs_best,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn bench_small_artifact_if_present() {
        let Ok(m) = Manifest::load("artifacts") else { return };
        let mut ex = Executor::new(m).unwrap();
        let r = bench_artifact(&mut ex, "naive_opt_f32_n4096", 2, 3).unwrap();
        assert!(r.ns.min > 0.0);
        assert!(r.gups_best > 0.0);
        assert_eq!(r.updates, 4096);
        assert_eq!(r.ws_bytes, 2 * 4096 * 4);
    }
}
