//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`).

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::{Json, JsonError};

#[derive(Debug)]
pub enum ManifestError {
    Io(PathBuf, std::io::Error),
    Json(JsonError),
    Format(String),
    Unknown(String),
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Io(path, e) => write!(f, "cannot read {}: {e}", path.display()),
            ManifestError::Json(e) => write!(f, "manifest parse error: {e}"),
            ManifestError::Format(msg) => write!(f, "manifest format error: {msg}"),
            ManifestError::Unknown(name) => write!(f, "unknown artifact '{name}'"),
        }
    }
}

impl std::error::Error for ManifestError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ManifestError::Io(_, e) => Some(e),
            ManifestError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<JsonError> for ManifestError {
    fn from(e: JsonError) -> Self {
        ManifestError::Json(e)
    }
}

/// One AOT-compiled executable.
#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub variant: String,
    /// "f32" | "f64".
    pub dtype: String,
    /// Vector length per input row.
    pub n: u64,
    /// Batch rows (1 for plain dots).
    pub batch: u64,
    /// Number of outputs in the result tuple.
    pub outputs: u32,
    /// Input shapes (one per parameter).
    pub input_shapes: Vec<Vec<u64>>,
    pub sha256: String,
}

impl Artifact {
    /// Total elements per input parameter.
    pub fn elems(&self) -> u64 {
        self.input_shapes
            .first()
            .map(|s| s.iter().product())
            .unwrap_or(0)
    }

    /// Working-set bytes (all inputs).
    pub fn ws_bytes(&self) -> u64 {
        let b = if self.dtype == "f64" { 8 } else { 4 };
        self.input_shapes
            .iter()
            .map(|s| s.iter().product::<u64>() * b)
            .sum()
    }

    /// Updates (scalar loop iterations) per execution.
    pub fn updates(&self) -> u64 {
        self.n * self.batch
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
    pub jax_version: String,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text =
            std::fs::read_to_string(&path).map_err(|e| ManifestError::Io(path.clone(), e))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, ManifestError> {
        let j = Json::parse(text)?;
        if j.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            return Err(ManifestError::Format(
                "expected interchange = hlo-text".into(),
            ));
        }
        let jax_version = j
            .get("jax")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| ManifestError::Format("missing artifacts array".into()))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let get_str = |k: &str| -> Result<String, ManifestError> {
                a.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| ManifestError::Format(format!("artifact missing '{k}'")))
            };
            let input_shapes = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| ManifestError::Format("artifact missing inputs".into()))?
                .iter()
                .map(|inp| {
                    inp.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| dims.iter().filter_map(Json::as_u64).collect::<Vec<u64>>())
                        .ok_or_else(|| ManifestError::Format("input missing shape".into()))
                })
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.push(Artifact {
                name: get_str("name")?,
                file: get_str("file")?,
                variant: get_str("variant")?,
                dtype: get_str("dtype")?,
                n: a.get("n").and_then(Json::as_u64).unwrap_or(0),
                batch: a.get("batch").and_then(Json::as_u64).unwrap_or(1),
                outputs: a.get("outputs").and_then(Json::as_u64).unwrap_or(1) as u32,
                input_shapes,
                sha256: get_str("sha256").unwrap_or_default(),
            });
        }
        Ok(Self {
            dir,
            artifacts,
            jax_version,
        })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact, ManifestError> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| ManifestError::Unknown(name.to_string()))
    }

    /// Artifacts of one variant, sorted by n.
    pub fn by_variant(&self, variant: &str, dtype: &str) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| a.variant == variant && a.dtype == dtype)
            .collect();
        v.sort_by_key(|a| a.n);
        v
    }

    pub fn hlo_path(&self, a: &Artifact) -> PathBuf {
        self.dir.join(&a.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1, "interchange": "hlo-text", "jax": "0.8.2",
      "artifacts": [
        {"name": "kahan_f32_n4096", "file": "kahan_f32_n4096.hlo.txt",
         "variant": "kahan", "dtype": "f32", "n": 4096, "outputs": 1,
         "sha256": "ab", "inputs": [{"shape": [4096], "dtype": "f32"},
                      {"shape": [4096], "dtype": "f32"}]},
        {"name": "kahan_batched_f32_b64_n16384", "file": "b.hlo.txt",
         "variant": "kahan_batched", "dtype": "f32", "n": 16384, "batch": 64,
         "outputs": 1, "sha256": "cd",
         "inputs": [{"shape": [64, 16384], "dtype": "f32"},
                    {"shape": [64, 16384], "dtype": "f32"}]}
      ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("kahan_f32_n4096").unwrap();
        assert_eq!(a.n, 4096);
        assert_eq!(a.batch, 1);
        assert_eq!(a.elems(), 4096);
        assert_eq!(a.ws_bytes(), 2 * 4096 * 4);
        let b = m.get("kahan_batched_f32_b64_n16384").unwrap();
        assert_eq!(b.updates(), 64 * 16384);
        assert_eq!(b.ws_bytes(), 2 * 64 * 16384 * 4);
    }

    #[test]
    fn by_variant_sorted() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let v = m.by_variant("kahan", "f32");
        assert_eq!(v.len(), 1);
        assert!(m.by_variant("kahan", "f64").is_empty());
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(matches!(m.get("nope"), Err(ManifestError::Unknown(_))));
    }

    #[test]
    fn wrong_interchange_rejected() {
        let bad = SAMPLE.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration sanity when `make artifacts` has run.
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.len() >= 20);
            assert!(!m.by_variant("kahan", "f32").is_empty());
            assert!(!m.by_variant("naive_opt", "f64").is_empty());
            for a in &m.artifacts {
                assert!(m.hlo_path(a).exists(), "{} missing", a.file);
            }
        }
    }
}
