//! Kernel execution runtime: pluggable [`backend`]s (native Rust SIMD by
//! default, PJRT behind the `pjrt` feature), the thread-[`parallel`]
//! execution layer (persistent parked-worker pool, cache-line-aligned slice
//! partitioning + deterministic compensated reduction), the 64-byte-aligned
//! operand [`arena`] the measured paths allocate from, and the host
//! benchmarking harness.
//!
//! The default build is hermetic: the [`backend::NativeBackend`] implements
//! the paper's full kernel ladder in plain Rust (with runtime-detected
//! AVX2 and — behind the `avx512` cargo feature — AVX-512 tiers, including
//! the multi-accumulator unrolled rungs), so every host experiment runs on
//! any machine with no artifacts installed. Enabling the `pjrt` cargo
//! feature additionally compiles the [`executor`] that loads the
//! AOT-compiled HLO-text artifacts produced by `python/compile/aot.py` and
//! executes them through the PJRT C API — the paper's "blueprint on a
//! fifth, real machine" path (DESIGN.md §2). Python never runs here: the
//! artifacts are self-contained HLO text and the manifest is plain JSON.

pub mod arena;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod executor;
pub mod hostbench;
pub mod manifest;
pub mod parallel;

pub use arena::AlignedVec;
pub use backend::{
    available_backends, Backend, BackendError, ImplStyle, KernelClass, KernelExec, KernelInput,
    KernelSpec, NativeBackend,
};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use executor::{Executor, RunOutput};
#[cfg(feature = "pjrt")]
pub use hostbench::{bench_artifact, HostBenchResult};
pub use hostbench::{
    bench_inputs, bench_kernel, bench_prepared, bench_scaling, bench_ws_sweep, detect_freq_ghz,
    freq_ghz_with_source, FreqSource, KernelBenchResult, NOMINAL_FREQ_GHZ,
};
pub use manifest::{Artifact, Manifest};
pub use parallel::{compensated_tree_reduce, ParallelBackend, ThreadPool};

/// Default artifact directory (relative to the repo root / cwd).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
