//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the host CPU — the paper's
//! "blueprint on a fifth, real machine" path (DESIGN.md §2).
//!
//! Python never runs here: the artifacts are self-contained HLO text, the
//! manifest is plain JSON, and the `xla` crate drives the PJRT C API.

pub mod executor;
pub mod hostbench;
pub mod manifest;

pub use executor::{Executor, RunOutput};
pub use hostbench::{bench_artifact, HostBenchResult};
pub use manifest::{Artifact, Manifest};

/// Default artifact directory (relative to the repo root / cwd).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
