//! Thread-parallel execution of the native kernel ladder — the layer that
//! turns the paper's *multicore saturation* claim (Sect. 5.1, Figs. 8/9)
//! into something this repo can measure instead of only simulate.
//!
//! Design:
//!
//! * [`ThreadPool`] partitions the iteration space into at most `T`
//!   contiguous chunks whose boundaries are aligned to cache-line
//!   granularity ([`CACHELINE_F64`] elements). With a 64-byte-aligned
//!   allocation (the [`crate::runtime::arena`] allocator guarantees it) no
//!   two workers touch the same line of the operand streams; a plain
//!   `Vec<f64>` only guarantees element alignment, so in the worst case
//!   each chunk *boundary* shares one straddling line with its neighbor —
//!   O(T) lines against millions streamed, so per-worker traffic is whole
//!   cache lines to ECM accuracy, and read-only sharing causes no
//!   invalidation traffic anyway.
//! * Workers are *persistent*: [`ThreadPool::new`] spawns `T - 1` parked
//!   OS threads once (chunk 0 always runs inline on the dispatching
//!   thread), and every dispatch hands chunk `i` to worker `i - 1` over a
//!   per-worker `std::sync::mpsc` channel, then blocks on a
//!   mutex+condvar completion latch. The earlier design spawned scoped
//!   threads per dispatch; at benchmark rep rates that put tens of
//!   microseconds of `clone(2)`/teardown inside every timed sample, which
//!   is exactly the overhead the `bench-scale` curves must *not* contain —
//!   a thread-scaling measurement should observe kernel saturation, not
//!   thread-creation cost. The chunk→worker assignment is fixed by index,
//!   so repeated dispatches reuse both the workers and (via first-touch
//!   allocation) their NUMA-local pages. Thread→core *pinning* is not
//!   available in std; we rely on the OS scheduler, which on an otherwise
//!   idle machine behaves pinned-ish — documented, not guaranteed.
//! * Every worker runs an unmodified [`NativeFn`] rung on its slice, so
//!   each thread carries its own Kahan compensation (the per-chunk kernels
//!   already end in the compensated lane fold). The `T` partial results are
//!   then combined by [`compensated_tree_reduce`] — a pairwise `two_sum`
//!   tree that is *deterministic for a fixed thread count* (the combination
//!   order depends only on the partition, never on thread finish order)
//!   and keeps the total error within the serial compensated bound: each
//!   chunk contributes its own Kahan-bounded error over Σ_chunk|x·y|, and
//!   the tree adds only the exactly-tracked `two_sum` residues
//!   (property-tested against the exact ground truth in
//!   `tests/properties.rs`). The persistent pool preserves this bit-for-
//!   bit: results land in partition order regardless of finish order, so a
//!   fixed `T` still implies a bit-identical result across dispatches.
//!
//! [`ParallelBackend`] exposes all of this through the ordinary
//! [`Backend`]/[`KernelExec`] traits, so `hostbench`, the harness and the
//! CLI (`bench-scale`) drive threaded kernels exactly like serial ones.
//! The backend owns one pool for its lifetime ("spawn once per backend"),
//! and every kernel it resolves shares that pool.

use std::any::Any;
use std::fmt;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

use super::backend::native::{self, NativeFn, SimdCaps};
use super::backend::{Backend, BackendError, KernelExec, KernelInput, KernelSpec, NativeBackend};
use crate::accuracy::eft::two_sum;
use crate::serve::faults::{FaultInjector, FaultSite};

/// f64 elements per 64-byte cache line — the chunk-boundary alignment.
pub const CACHELINE_F64: usize = 8;

/// Poison-tolerant lock: a thread that panicked while holding a pool or
/// latch mutex must never wedge the threads still using it — the protected
/// state (counters, sender lists) stays structurally valid across an unwind,
/// so we keep serving rather than propagate the poison.
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Completion latch for one dispatch: the dispatcher blocks until every
/// posted chunk has been executed (successfully or by unwinding), so the
/// borrowed task closure and output slots never outlive a running worker.
struct Latch {
    remaining: Mutex<usize>,
    all_done: Condvar,
    /// First worker panic payload, re-raised on the dispatching thread so
    /// callers see the original assertion/message, exactly as the previous
    /// scoped-thread design propagated it.
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
    /// When the last job arrived — the dispatch's true completion instant,
    /// which an asynchronous retirer may observe only later.
    finished: Mutex<Option<std::time::Instant>>,
}

impl Latch {
    fn new(count: usize) -> Self {
        Self {
            remaining: Mutex::new(count),
            all_done: Condvar::new(),
            panic_payload: Mutex::new(None),
            finished: Mutex::new(None),
        }
    }

    fn record_panic(&self, payload: Box<dyn Any + Send>) {
        let mut slot = lock_ok(&self.panic_payload);
        if slot.is_none() {
            *slot = Some(payload);
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        lock_ok(&self.panic_payload).take()
    }

    fn arrive(&self) {
        let mut r = lock_ok(&self.remaining);
        *r -= 1;
        if *r == 0 {
            *lock_ok(&self.finished) = Some(std::time::Instant::now());
            self.all_done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = lock_ok(&self.remaining);
        while *r > 0 {
            r = self
                .all_done
                .wait(r)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }

    fn is_done(&self) -> bool {
        *lock_ok(&self.remaining) == 0
    }
}

/// A borrowed, type-erased chunk task (what one [`Job`] points at).
type Task<'a> = &'a (dyn Fn(usize) + Sync);

/// What a [`Job`] executes: either a type-erased borrow of a blocking
/// dispatcher's stack-held closure, or a shared ownership stake in an
/// asynchronous dispatch's closure (the job itself keeps it alive).
enum TaskRef {
    /// Raw (fat) pointer to the dispatcher's stack-held closure. Valid for
    /// the whole dispatch: `run_chunks` blocks on the latch before the
    /// referent can be dropped.
    Borrowed(*const (dyn Fn(usize) + Sync)),
    /// Owned closure of a non-blocking dispatch (`run_chunks_async` /
    /// `run_tasks_async`): dropped when the last job referencing it
    /// finishes, so the dispatcher never has to stick around.
    Owned(Arc<dyn Fn(usize) + Send + Sync>),
}

/// One unit of dispatched work: the task to run plus the chunk (or lane)
/// index to run it on.
struct Job {
    task: TaskRef,
    index: usize,
    done: Arc<Latch>,
    /// Set once this job has been counted in at the latch. The `Drop`
    /// backstop fails-and-arrives any job that never was — e.g. a job still
    /// queued on a worker whose thread died — so a lost job degrades into a
    /// failed dispatch, never a hung latch.
    counted: bool,
}

impl Job {
    fn new(task: TaskRef, index: usize, done: Arc<Latch>) -> Self {
        Job {
            task,
            index,
            done,
            counted: false,
        }
    }

    /// Count this job in at the latch (exactly once; disarms the backstop).
    fn finish(mut self) {
        self.counted = true;
        self.done.arrive();
    }
}

impl Drop for Job {
    fn drop(&mut self) {
        if !self.counted {
            self.done
                .record_panic(Box::new("job dropped unexecuted: worker thread died"));
            self.done.arrive();
        }
    }
}

// SAFETY: the borrowed raw task pointer crosses threads, but the referent
// is `Sync` and the dispatcher keeps it alive (and does not return) until
// the latch has counted every job in — see `ThreadPool::run_chunks`. The
// owned variant is `Send + Sync` by construction.
unsafe impl Send for Job {}

fn worker_loop(jobs: Receiver<Job>, faults: Option<Arc<FaultInjector>>) {
    // A closed channel (pool dropped) is the shutdown signal.
    loop {
        let job = match jobs.recv() {
            Ok(job) => job,
            Err(_) => break,
        };
        let index = job.index;
        // Injected worker panic: fail this job's dispatch with a panic
        // payload, count the job in (so the dispatcher wakes), then let the
        // worker thread die. The receiver is closed *before* the latch
        // arrival: jobs already queued behind this one fail via the `Job`
        // drop backstop, and by the time a caller observes this dispatch
        // fail, a new send to this slot fails fast and triggers a respawn —
        // the pool heals before the next dispatch lands here.
        let killed = match &faults {
            Some(inj) => inj.fire(FaultSite::WorkerPanic),
            None => false,
        };
        if killed {
            job.done.record_panic(Box::new("injected worker panic"));
            drop(jobs);
            job.finish();
            return;
        }
        let run = || {
            let task: &(dyn Fn(usize) + Sync) = match &job.task {
                // SAFETY: the dispatcher guarantees the pointee outlives
                // this job (it blocks on the latch before releasing the
                // closure).
                TaskRef::Borrowed(p) => unsafe { &**p },
                TaskRef::Owned(f) => f.as_ref(),
            };
            task(index)
        };
        // Panics must not leak past the latch or the dispatcher deadlocks;
        // the payload is re-raised on the waiting thread instead.
        if let Err(payload) = catch_unwind(AssertUnwindSafe(run)) {
            job.done.record_panic(payload);
        }
        // Injected latch-wake delay: a "lost wakeup" is modeled as a late
        // one — callers observe latency, never a missing arrival.
        if let Some(inj) = &faults {
            if let Some(delay) = inj.stall(FaultSite::LatchWakeDelay) {
                std::thread::sleep(delay);
            }
        }
        job.finish();
    }
}

/// Shared writable result slots: workers write disjoint indices of the
/// dispatcher's output vector through a raw pointer.
struct SlotWriter<R> {
    ptr: *mut Option<R>,
}

// SAFETY: each chunk index is dispatched to exactly one executor (worker or
// the inline caller), so writes target disjoint slots; the vector itself is
// neither read nor resized until every writer has arrived at the latch.
unsafe impl<R: Send> Sync for SlotWriter<R> {}

impl<R> SlotWriter<R> {
    /// # Safety
    /// `i` must be in bounds and written by at most one thread per dispatch.
    unsafe fn write(&self, i: usize, r: R) {
        self.ptr.add(i).write(Some(r));
    }
}

/// Heap-owned result slots for a *non-blocking* dispatch: the slots live in
/// an `Arc` shared between the dispatched closure (writer) and the
/// [`PendingDispatch`] handle (reader), so neither side has to outlive the
/// other on a particular stack frame.
struct AsyncSlots<R> {
    cells: Vec<std::cell::UnsafeCell<Option<R>>>,
}

// SAFETY: each slot index is written by exactly one executor per dispatch
// (chunk index → one job; task ticket → one claiming lane), and the reader
// only touches the cells after the completion latch has counted every job
// in (the latch mutex provides the happens-before edge).
unsafe impl<R: Send> Sync for AsyncSlots<R> {}
unsafe impl<R: Send> Send for AsyncSlots<R> {}

impl<R> AsyncSlots<R> {
    fn new(count: usize) -> Self {
        Self {
            cells: (0..count).map(|_| std::cell::UnsafeCell::new(None)).collect(),
        }
    }

    /// # Safety
    /// `i` must be in bounds and written by at most one thread per dispatch.
    unsafe fn write(&self, i: usize, r: R) {
        *self.cells[i].get() = Some(r);
    }
}

/// Completion handle for a non-blocking dispatch ([`ThreadPool::run_chunks_async`]
/// / [`ThreadPool::run_tasks_async`]): the dispatching thread gets it back
/// immediately and can keep forming, posting and completing other work
/// while the pool executes. All state is `Arc`-owned, so dropping the
/// handle without waiting is safe and leaks nothing — the in-flight jobs
/// keep their closure and slots alive and simply finish unobserved (a
/// recorded worker panic is then dropped with them).
pub struct PendingDispatch<R> {
    latch: Arc<Latch>,
    slots: Arc<AsyncSlots<R>>,
}

impl<R> PendingDispatch<R> {
    /// A dispatch that already completed (empty or executed inline).
    fn completed(slots: Arc<AsyncSlots<R>>) -> Self {
        let latch = Latch::new(0);
        *lock_ok(&latch.finished) = Some(std::time::Instant::now());
        Self {
            latch: Arc::new(latch),
            slots,
        }
    }

    /// Has every dispatched job finished (successfully or by unwinding)?
    /// Non-blocking; `wait` is then immediate.
    pub fn is_done(&self) -> bool {
        self.latch.is_done()
    }

    /// Block until the dispatch completes and return the results in
    /// chunk/task order — exactly what the blocking `run_chunks` /
    /// `run_tasks` would have returned for the same dispatch. Re-raises
    /// the first worker panic, like the blocking paths.
    pub fn wait(self) -> Vec<R> {
        self.wait_finished().0
    }

    /// [`Self::wait`], also returning the instant the last job actually
    /// finished — which can be earlier than the `wait` call returns when
    /// the dispatch completed while the caller was off doing other work.
    /// Lets an asynchronous retirer account pool busy time by real
    /// completion, not by when it got around to looking.
    pub fn wait_finished(self) -> (Vec<R>, std::time::Instant) {
        self.latch.wait();
        if let Some(p) = self.latch.take_panic() {
            resume_unwind(p);
        }
        let finished = lock_ok(&self.latch.finished).unwrap_or_else(std::time::Instant::now);
        let results = self
            .slots
            .cells
            .iter()
            .map(|c| {
                // SAFETY: the latch counted every writer in, so no thread
                // writes these cells anymore and reads are exclusive.
                unsafe { &mut *c.get() }
                    .take()
                    .expect("async dispatch produced no result")
            })
            .collect();
        (results, finished)
    }
}

/// One spawned worker: its job channel plus its thread handle, kept
/// together so a dead worker can be detected (`handle.is_finished()`) and
/// replaced in place without disturbing the slot order.
struct WorkerSlot {
    tx: Sender<Job>,
    handle: JoinHandle<()>,
}

/// A persistent parked-worker pool for slice-parallel kernels: `T - 1`
/// worker threads spawned once at construction, plus the dispatching
/// thread, execute the deterministic cache-line-aligned partition of each
/// dispatch. Dropping the pool shuts the workers down.
///
/// The pool is *self-healing*: a worker whose thread died (today only an
/// injected worker panic kills one — ordinary task panics are caught and
/// the worker survives) fails the dispatch that was on it and is respawned
/// in the same slot before the next dispatch posts there. Slot index `i`
/// always serves the same chunk/lane indices, so the logical `T`-wide
/// partition — and with it every reduction order and every bit of every
/// result — is unchanged across a respawn.
pub struct ThreadPool {
    threads: usize,
    /// Spawned OS worker threads: `threads - 1` for a standard pool (the
    /// dispatching thread is lane 0), `threads` for a detached pool (the
    /// dispatcher only orchestrates — see [`Self::new_detached`]).
    workers: usize,
    /// Per-worker slots, locked as one unit: a blocking dispatch owns
    /// every worker for its full duration, so concurrent `run_chunks`
    /// calls on a shared pool serialize instead of interleaving jobs.
    /// Non-blocking dispatches only hold the lock while posting, so their
    /// jobs pipeline through the per-worker FIFOs.
    slots: Mutex<Vec<WorkerSlot>>,
    /// Deterministic fault injection (chaos tests / `serve-bench --chaos`);
    /// `None` in production — the sites reduce to one null check each.
    faults: Option<Arc<FaultInjector>>,
}

impl ThreadPool {
    /// A pool targeting `threads` workers (clamped to >= 1). Spawns the
    /// `threads - 1` persistent worker threads immediately; chunk 0 of
    /// every dispatch runs inline on the dispatching thread.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        Self::spawn(threads, threads - 1, None)
    }

    /// A pool whose `threads`-wide partition is executed *entirely* by
    /// dedicated workers: `threads` OS threads are spawned and no chunk
    /// ever runs inline on a dispatching thread. This is what a pipelined
    /// dispatcher needs — it posts work with the `*_async` variants and
    /// stays free to drain its submission queue while the pool executes.
    /// The partition (and therefore every reduction order and every bit of
    /// every result) is identical to a standard `new(threads)` pool.
    pub fn new_detached(threads: usize) -> Self {
        let threads = threads.max(1);
        Self::spawn(threads, threads, None)
    }

    /// [`Self::new`] with a fault injector threaded into every worker.
    pub fn new_with_faults(threads: usize, faults: Option<Arc<FaultInjector>>) -> Self {
        let threads = threads.max(1);
        Self::spawn(threads, threads - 1, faults)
    }

    /// [`Self::new_detached`] with a fault injector threaded into every
    /// worker.
    pub fn new_detached_with_faults(threads: usize, faults: Option<Arc<FaultInjector>>) -> Self {
        let threads = threads.max(1);
        Self::spawn(threads, threads, faults)
    }

    fn spawn(threads: usize, workers: usize, faults: Option<Arc<FaultInjector>>) -> Self {
        let slots = (0..workers)
            .map(|i| Self::spawn_worker(i, faults.clone()))
            .collect();
        Self {
            threads,
            workers,
            slots: Mutex::new(slots),
            faults,
        }
    }

    fn spawn_worker(index: usize, faults: Option<Arc<FaultInjector>>) -> WorkerSlot {
        let (tx, rx) = channel::<Job>();
        let handle = std::thread::Builder::new()
            .name(format!("kahan-mt-{index}"))
            .spawn(move || worker_loop(rx, faults))
            .expect("spawn persistent worker");
        WorkerSlot { tx, handle }
    }

    /// Replace the worker in slot `i` with a freshly spawned one (same
    /// name, same channel discipline). The dead thread is joined so its
    /// resources are reclaimed before new work lands on the slot.
    fn respawn(&self, slots: &mut [WorkerSlot], i: usize) {
        let fresh = Self::spawn_worker(i, self.faults.clone());
        let dead = std::mem::replace(&mut slots[i], fresh);
        drop(dead.tx);
        let _ = dead.handle.join();
    }

    /// Post one job to worker slot `i`, healing the slot first if its
    /// thread has already exited. A worker can still die *between* the
    /// liveness check and the send; the failed send returns the job, which
    /// is reposted to a respawned worker. Jobs that were already queued on
    /// the dead worker fail their dispatches via the `Job` drop backstop —
    /// a dead worker is never a hang, and the slot is healthy again before
    /// this dispatch's job lands.
    fn post_job(&self, slots: &mut [WorkerSlot], i: usize, job: Job) {
        if slots[i].handle.is_finished() {
            self.respawn(slots, i);
        }
        if let Err(returned) = slots[i].tx.send(job) {
            self.respawn(slots, i);
            slots[i]
                .tx
                .send(returned.0)
                .expect("freshly spawned worker must accept work");
        }
    }

    /// Worker count this pool partitions for (including the dispatcher).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Spawned OS worker threads (`threads - 1`, or `threads` for a
    /// detached pool).
    pub fn spawned_workers(&self) -> usize {
        self.workers
    }

    /// Hardware thread count of this host (>= 1).
    pub fn available() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Deterministic partition of `0..n` into at most `threads` contiguous
    /// chunks whose start indices are multiples of `align`. Blocks are
    /// dealt as evenly as possible (front chunks get the remainder), and a
    /// chunk never degenerates to empty unless `n == 0` (then one empty
    /// chunk is returned so callers still get a partial to reduce).
    pub fn partition(&self, n: usize, align: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return vec![0..0];
        }
        let align = align.max(1);
        let blocks = (n + align - 1) / align;
        let t = self.threads.min(blocks);
        let per = blocks / t;
        let extra = blocks % t;
        let mut v = Vec::with_capacity(t);
        let mut block = 0;
        for i in 0..t {
            let nb = per + usize::from(i < extra);
            let start = block * align;
            let end = ((block + nb) * align).min(n);
            v.push(start..end);
            block += nb;
        }
        v
    }

    /// Run `f(worker_index, chunk_range)` over the partition of `0..n`,
    /// returning results in partition order (independent of thread finish
    /// order — this is what makes downstream reductions deterministic).
    /// Chunk `i > 0` goes to persistent worker `i - 1`; chunk 0 (and any
    /// single-chunk dispatch) runs inline on the caller's thread. The
    /// assignment is fixed by index, so repeated dispatches of the same
    /// shape land each chunk on the same OS thread every time.
    pub fn run_chunks<R, F>(&self, n: usize, align: usize, f: F) -> Vec<R>
    where
        F: Fn(usize, Range<usize>) -> R + Sync,
        R: Send,
    {
        let parts = self.partition(n, align);
        let k = parts.len();
        if k == 1 {
            let r = parts[0].clone();
            return vec![f(0, r)];
        }
        let mut out: Vec<Option<R>> = (0..k).map(|_| None).collect();
        {
            let slots = SlotWriter {
                ptr: out.as_mut_ptr(),
            };
            let parts_ref = &parts;
            let fref = &f;
            let task = move |i: usize| {
                let r = fref(i, parts_ref[i].clone());
                // SAFETY: chunk i is dispatched exactly once (to worker
                // i - 1, or inline for i = 0), and `out` is untouched
                // until the latch wait below returns.
                unsafe { slots.write(i, r) };
            };
            // SAFETY: pure lifetime erasure — `task` outlives every
            // dispatched job because this function blocks on the latch
            // (even when unwinding) before `task` can be dropped.
            let erased: *const (dyn Fn(usize) + Sync) =
                unsafe { std::mem::transmute::<Task<'_>, Task<'static>>(&task) };
            let latch = Arc::new(Latch::new(k - 1));
            let mut slots = lock_ok(&self.slots);
            for i in 1..k {
                self.post_job(
                    &mut slots,
                    i - 1,
                    Job::new(TaskRef::Borrowed(erased), i, latch.clone()),
                );
            }
            // Chunk 0 inline. An inline panic must still wait for the
            // posted jobs before unwinding (they borrow `task`/`out`).
            let inline = catch_unwind(AssertUnwindSafe(|| task(0)));
            latch.wait();
            drop(slots);
            if let Err(p) = inline {
                resume_unwind(p);
            }
            if let Some(p) = latch.take_panic() {
                resume_unwind(p);
            }
        }
        out.into_iter()
            .map(|o| o.expect("worker produced no result"))
            .collect()
    }

    /// Run `f(task_index)` for every index in `0..total` over a *shared
    /// dynamic queue*: up to `threads` execution lanes (the dispatching
    /// thread plus the persistent workers) repeatedly claim the next
    /// unclaimed index from an atomic ticket counter and execute whole
    /// tasks back-to-back until the queue drains. This is the serving
    /// layer's *fused small-request dispatch* — the dual of
    /// [`Self::run_chunks`]: instead of one task split across all workers,
    /// many independent tasks share the workers, so a skewed request
    /// mixture load-balances dynamically.
    ///
    /// Results land in **task order** (slot `i` is written only by the lane
    /// that claimed ticket `i`), so downstream consumers see a
    /// deterministic layout. The task→lane assignment itself is dynamic;
    /// `f` must therefore be deterministic per index (true for whole-kernel
    /// executions, which depend only on their operands) for results to be
    /// reproducible — which keeps the fused path bit-identical to running
    /// each task alone. Panics propagate to the dispatcher exactly like
    /// [`Self::run_chunks`], and the pool stays usable afterwards.
    pub fn run_tasks<R, F>(&self, total: usize, f: F) -> Vec<R>
    where
        F: Fn(usize) -> R + Sync,
        R: Send,
    {
        if total == 0 {
            return Vec::new();
        }
        let lanes = self.threads.min(total);
        if lanes == 1 {
            return (0..total).map(f).collect();
        }
        let mut out: Vec<Option<R>> = (0..total).map(|_| None).collect();
        {
            let slots = SlotWriter {
                ptr: out.as_mut_ptr(),
            };
            let next = AtomicUsize::new(0);
            let fref = &f;
            let next_ref = &next;
            let task = move |_lane: usize| loop {
                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let r = fref(i);
                // SAFETY: ticket `i` is claimed by exactly one lane (the
                // fetch_add is atomic), so writes target disjoint slots;
                // `out` is untouched until the latch wait below returns.
                unsafe { slots.write(i, r) };
            };
            // SAFETY: pure lifetime erasure — `task` outlives every
            // dispatched job because this function blocks on the latch
            // (even when unwinding) before `task` can be dropped.
            let erased: *const (dyn Fn(usize) + Sync) =
                unsafe { std::mem::transmute::<Task<'_>, Task<'static>>(&task) };
            let latch = Arc::new(Latch::new(lanes - 1));
            let mut slots = lock_ok(&self.slots);
            for lane in 1..lanes {
                self.post_job(
                    &mut slots,
                    lane - 1,
                    Job::new(TaskRef::Borrowed(erased), lane, latch.clone()),
                );
            }
            // Lane 0 drains the queue inline; a panic must still wait for
            // the posted jobs before unwinding (they borrow `task`/`out`).
            let inline = catch_unwind(AssertUnwindSafe(|| task(0)));
            latch.wait();
            drop(slots);
            if let Err(p) = inline {
                resume_unwind(p);
            }
            if let Some(p) = latch.take_panic() {
                resume_unwind(p);
            }
        }
        out.into_iter()
            .map(|o| o.expect("task produced no result"))
            .collect()
    }

    /// Non-blocking [`Self::run_chunks`]: post every chunk of the same
    /// deterministic partition to the persistent workers and return a
    /// [`PendingDispatch`] immediately, leaving the calling thread free to
    /// form and post more work while this dispatch executes. `wait()` on
    /// the handle returns exactly the `Vec` the blocking call would have
    /// (same partition, same chunk order, bit-identical results), so a
    /// downstream reduction is unchanged.
    ///
    /// The closure is owned (`'static`) because nothing blocks for it:
    /// jobs keep it alive via `Arc` until the last chunk finishes. Chunks
    /// are dealt round-robin over the spawned workers — on a detached pool
    /// (`new_detached`) that is one chunk per worker. On a pool with no
    /// spawned workers (`new(1)`) the dispatch degenerates to inline
    /// execution and the returned handle is already complete.
    pub fn run_chunks_async<R, F>(&self, n: usize, align: usize, f: F) -> PendingDispatch<R>
    where
        F: Fn(usize, Range<usize>) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let parts = self.partition(n, align);
        let k = parts.len();
        let slots = Arc::new(AsyncSlots::new(k));
        if self.workers == 0 {
            for (i, r) in parts.iter().enumerate() {
                let v = f(i, r.clone());
                // SAFETY: sole executor, in-bounds, one write per slot.
                unsafe { slots.write(i, v) };
            }
            return PendingDispatch::completed(slots);
        }
        let latch = Arc::new(Latch::new(k));
        let task: Arc<dyn Fn(usize) + Send + Sync> = {
            let slots = Arc::clone(&slots);
            Arc::new(move |i: usize| {
                let v = f(i, parts[i].clone());
                // SAFETY: chunk i is posted to exactly one worker, and the
                // reader only looks after the latch counts every job in.
                unsafe { slots.write(i, v) };
            })
        };
        let mut worker_slots = lock_ok(&self.slots);
        for i in 0..k {
            self.post_job(
                &mut worker_slots,
                i % self.workers,
                Job::new(TaskRef::Owned(Arc::clone(&task)), i, Arc::clone(&latch)),
            );
        }
        PendingDispatch { latch, slots }
    }

    /// Non-blocking [`Self::run_tasks`]: the shared-ticket-queue fused
    /// dispatch, posted to the persistent workers without the calling
    /// thread joining as a lane. Results land in task order exactly like
    /// the blocking variant (slot `i` is written by whichever lane claims
    /// ticket `i`; `f` must be deterministic per index for reproducibility,
    /// which whole-kernel executions are). On a pool with no spawned
    /// workers the tasks run inline and the handle is already complete.
    pub fn run_tasks_async<R, F>(&self, total: usize, f: F) -> PendingDispatch<R>
    where
        F: Fn(usize) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let slots = Arc::new(AsyncSlots::new(total));
        if total == 0 {
            return PendingDispatch::completed(slots);
        }
        if self.workers == 0 {
            for i in 0..total {
                let v = f(i);
                // SAFETY: sole executor, in-bounds, one write per slot.
                unsafe { slots.write(i, v) };
            }
            return PendingDispatch::completed(slots);
        }
        let lanes = self.workers.min(total);
        let latch = Arc::new(Latch::new(lanes));
        let task: Arc<dyn Fn(usize) + Send + Sync> = {
            let slots = Arc::clone(&slots);
            let next = AtomicUsize::new(0);
            Arc::new(move |_lane: usize| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let v = f(i);
                // SAFETY: ticket i is claimed by exactly one lane, and the
                // reader only looks after the latch counts every lane in.
                unsafe { slots.write(i, v) };
            })
        };
        let mut worker_slots = lock_ok(&self.slots);
        for lane in 0..lanes {
            self.post_job(
                &mut worker_slots,
                lane,
                Job::new(TaskRef::Owned(Arc::clone(&task)), lane, Arc::clone(&latch)),
            );
        }
        PendingDispatch { latch, slots }
    }
}

impl fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channels is the shutdown signal. A poisoned lock
        // (a dispatcher panicked mid-dispatch) must not leak the workers.
        let slots = std::mem::take(&mut *lock_ok(&self.slots));
        // Close every channel first so all workers wind down in parallel,
        // then join them.
        let mut handles = Vec::with_capacity(slots.len());
        for WorkerSlot { tx, handle } in slots {
            drop(tx);
            handles.push(handle);
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Combine per-thread partial sums by a pairwise compensated tree: each
/// pair is added with an exact [`two_sum`], the rounding residues ride
/// along and are folded in once at the end. The reduction order is a fixed
/// left-to-right pairing over the input slice, so the result is bit-stable
/// for a given partition. The residues themselves accumulate with plain
/// adds (which round at the residues' own tiny scale) plus the final
/// `value + residue` add, so the reduction is not exact in general — but
/// those second-order roundings are far inside the compensated Kahan bound
/// the property tests pin.
pub fn compensated_tree_reduce(parts: &[f64]) -> f64 {
    match parts {
        [] => 0.0,
        [one] => *one,
        _ => {
            let mut nodes: Vec<(f64, f64)> = parts.iter().map(|&p| (p, 0.0)).collect();
            while nodes.len() > 1 {
                let mut next = Vec::with_capacity((nodes.len() + 1) / 2);
                for pair in nodes.chunks(2) {
                    if let [a, b] = pair {
                        let (s, e) = two_sum(a.0, b.0);
                        next.push((s, e + a.1 + b.1));
                    } else {
                        next.push(pair[0]);
                    }
                }
                nodes = next;
            }
            let (s, e) = nodes[0];
            s + e
        }
    }
}

/// A native kernel dispatched over per-thread slices with a deterministic
/// compensated combination of the partials. Holds a handle to the owning
/// backend's persistent pool — resolving a kernel spawns nothing.
pub struct ParallelKernel {
    spec: KernelSpec,
    f: NativeFn,
    pool: Arc<ThreadPool>,
}

impl ParallelKernel {
    /// Worker count this kernel partitions for.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl KernelExec for ParallelKernel {
    fn spec(&self) -> KernelSpec {
        self.spec
    }

    fn run(&self, input: &KernelInput<'_>) -> Result<f64, BackendError> {
        input.check(self.spec)?;
        let partials = match (self.f, *input) {
            (NativeFn::Dot(f), KernelInput::Dot(x, y)) => self
                .pool
                .run_chunks(x.len(), CACHELINE_F64, |_, r| f(&x[r.clone()], &y[r])),
            (NativeFn::Sum(f), KernelInput::Sum(x)) => {
                self.pool.run_chunks(x.len(), CACHELINE_F64, |_, r| f(&x[r]))
            }
            _ => unreachable!("check() verified the input kind"),
        };
        Ok(compensated_tree_reduce(&partials))
    }
}

/// The thread-parallel native backend: the same kernel ladder as
/// [`NativeBackend`], each kernel executed on `threads` workers over
/// cache-line-aligned slices. The persistent worker pool is spawned once
/// here and shared by every kernel the backend resolves.
pub struct ParallelBackend {
    inner: NativeBackend,
    pool: Arc<ThreadPool>,
}

impl ParallelBackend {
    /// A backend running every kernel on `threads` workers (>= 1). Spawns
    /// the persistent pool immediately.
    pub fn new(threads: usize) -> Self {
        Self {
            inner: NativeBackend::new(),
            pool: Arc::new(ThreadPool::new(threads)),
        }
    }

    /// One worker per available hardware thread.
    pub fn all_cores() -> Self {
        Self::new(ThreadPool::available())
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Is the AVX2 tier usable on this host?
    pub fn has_avx2(&self) -> bool {
        self.inner.has_avx2()
    }

    /// The SIMD tiers the underlying native backend resolved.
    pub fn caps(&self) -> SimdCaps {
        self.inner.caps()
    }

    /// The backend's persistent worker pool — exposed so operand arenas can
    /// be first-touch initialized by the same workers (same chunk→worker
    /// assignment) that later stream them.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }
}

impl Backend for ParallelBackend {
    fn name(&self) -> &str {
        "native-mt"
    }

    fn kernels(&self) -> Vec<KernelSpec> {
        self.inner.kernels()
    }

    fn resolve(&self, spec: KernelSpec) -> Result<Box<dyn KernelExec + '_>, BackendError> {
        match native::native_fn(spec, self.inner.caps()) {
            Some(f) => Ok(Box::new(ParallelKernel {
                spec,
                f,
                pool: Arc::clone(&self.pool),
            })),
            None => Err(BackendError::Unsupported {
                backend: self.name().to_string(),
                spec,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::{exact_dot, exact_sum};
    use crate::runtime::backend::{ImplStyle, KernelClass};
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn partition_is_aligned_disjoint_and_covering() {
        for threads in [1usize, 2, 3, 7, 16] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 1, 7, 8, 9, 64, 100, 1003, 4096] {
                let parts = pool.partition(n, CACHELINE_F64);
                assert!(parts.len() <= threads, "n={n} T={threads}: {parts:?}");
                let mut cursor = 0;
                for r in &parts {
                    assert_eq!(r.start, cursor, "n={n} T={threads}: {parts:?}");
                    assert_eq!(r.start % CACHELINE_F64, 0, "unaligned start: {parts:?}");
                    assert!(r.end > r.start || n == 0, "empty chunk: {parts:?}");
                    cursor = r.end;
                }
                assert_eq!(cursor, n, "partition must cover 0..{n}: {parts:?}");
            }
        }
    }

    #[test]
    fn run_chunks_orders_results_by_partition() {
        let pool = ThreadPool::new(4);
        let got = pool.run_chunks(64, CACHELINE_F64, |i, r| (i, r.start, r.end));
        assert_eq!(got.len(), 4);
        for (i, &(wi, s, e)) in got.iter().enumerate() {
            assert_eq!(wi, i);
            assert_eq!((s, e), (i * 16, i * 16 + 16));
        }
    }

    #[test]
    fn run_tasks_returns_results_in_task_order() {
        let pool = ThreadPool::new(4);
        for total in [0usize, 1, 3, 4, 17, 100] {
            let got = pool.run_tasks(total, |i| i * i);
            let want: Vec<usize> = (0..total).map(|i| i * i).collect();
            assert_eq!(got, want, "total={total}");
        }
    }

    #[test]
    fn run_tasks_is_deterministic_under_skewed_load() {
        // Task runtimes differ wildly, so the dynamic task→lane assignment
        // varies across dispatches — the *values* must not.
        let pool = ThreadPool::new(3);
        let work = |i: usize| {
            let spin = if i % 7 == 0 { 5000 } else { 10 };
            let mut acc = i as f64;
            for k in 0..spin {
                acc = std::hint::black_box(acc + (k as f64).sin() * 1e-12);
            }
            acc
        };
        let first = pool.run_tasks(40, work);
        for _ in 0..5 {
            let again = pool.run_tasks(40, work);
            for (a, b) in first.iter().zip(&again) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn run_tasks_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks(32, |i| {
                if i == 13 {
                    panic!("task boom");
                }
                i
            })
        }));
        let payload = boom.expect_err("task panic must reach the dispatcher");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"task boom"));
        let ok = pool.run_tasks(8, |i| i + 1);
        assert_eq!(ok, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn persistent_pool_survives_many_dispatches() {
        // The same pool object serves repeated dispatches of varying shape
        // (the whole point of persistence) and stays deterministic.
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let n = 64 + (round % 7) * 8;
            let parts = pool.run_chunks(n, CACHELINE_F64, |_, r| r.end - r.start);
            assert_eq!(parts.iter().sum::<usize>(), n, "round {round}");
        }
    }

    #[test]
    fn pool_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(64, CACHELINE_F64, |i, _| {
                if i == 2 {
                    panic!("injected");
                }
                i
            })
        }));
        let payload = boom.expect_err("worker panic must reach the dispatcher");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"injected"),
            "original panic payload must be preserved"
        );
        // The pool remains usable after a panicked dispatch.
        let ok = pool.run_chunks(64, CACHELINE_F64, |i, _| i);
        assert_eq!(ok, vec![0, 1, 2, 3]);
    }

    #[test]
    fn tree_reduce_small_cases() {
        assert_eq!(compensated_tree_reduce(&[]), 0.0);
        assert_eq!(compensated_tree_reduce(&[-0.0]).to_bits(), (-0.0f64).to_bits());
        assert_eq!(compensated_tree_reduce(&[1.0, 2.0, 3.0]), 6.0);
        // Catastrophic cancellation across partials: the tree's two_sum
        // residues recover what a naive left fold loses.
        let parts = [1e16, 3.25, -1e16, 2.5];
        assert_eq!(compensated_tree_reduce(&parts), 5.75);
    }

    #[test]
    fn parallel_matches_serial_ground_truth() {
        let x = randvec(4099, 11); // ragged: not a multiple of 8
        let y = randvec(4099, 12);
        let want = exact_dot(&x, &y);
        let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        for threads in [1usize, 2, 3, 8] {
            let backend = ParallelBackend::new(threads);
            for spec in backend.kernels() {
                if spec.class != KernelClass::KahanDot {
                    continue;
                }
                let got = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
                assert!(
                    (got - want).abs() <= 8.0 * f64::EPSILON * cond,
                    "{spec} T={threads}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn parallel_sum_matches_exact() {
        let x = randvec(2049, 21);
        let want = exact_sum(&x);
        let abs: f64 = x.iter().map(|v| v.abs()).sum();
        let backend = ParallelBackend::new(3);
        let spec = KernelSpec::new(KernelClass::KahanSum, ImplStyle::SimdLanes);
        let got = backend.run(spec, &KernelInput::Sum(&x)).unwrap();
        assert!((got - want).abs() <= 8.0 * f64::EPSILON * abs);
    }

    #[test]
    fn single_thread_is_bit_identical_to_serial() {
        let x = randvec(1003, 31);
        let y = randvec(1003, 32);
        let serial = NativeBackend::new();
        let par = ParallelBackend::new(1);
        for spec in serial.kernels() {
            let input = if spec.class.is_dot() {
                KernelInput::Dot(&x, &y)
            } else {
                KernelInput::Sum(&x)
            };
            let a = serial.run(spec, &input).unwrap();
            let b = par.run(spec, &input).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "{spec}");
        }
    }

    #[test]
    fn fixed_thread_count_is_deterministic() {
        let x = randvec(8192, 41);
        let y = randvec(8192, 42);
        for threads in [2usize, 5, 8] {
            // One backend instance per T: repeated dispatches exercise the
            // persistent-pool reuse path, not pool construction.
            let backend = ParallelBackend::new(threads);
            let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
            let a = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
            for _ in 0..5 {
                let b = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
                assert_eq!(a.to_bits(), b.to_bits(), "T={threads}");
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs_parallel() {
        let backend = ParallelBackend::new(8);
        for spec in backend.kernels() {
            let got = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[], &[])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[])).unwrap()
            };
            assert_eq!(got, 0.0, "{spec} on empty input");
            let one = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[3.0], &[2.0])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[6.0])).unwrap()
            };
            assert_eq!(one, 6.0, "{spec} on length-1 input");
        }
    }

    #[test]
    fn async_chunks_match_blocking_bits_on_both_pool_kinds() {
        let x = randvec(4099, 51);
        let y = randvec(4099, 52);
        for threads in [1usize, 2, 3, 8] {
            let standard = ThreadPool::new(threads);
            let detached = ThreadPool::new_detached(threads);
            assert_eq!(standard.spawned_workers(), threads - 1);
            assert_eq!(detached.spawned_workers(), threads);
            let want = {
                let (x, y) = (x.clone(), y.clone());
                standard.run_chunks(x.len(), CACHELINE_F64, move |_, r| {
                    native::kahan_dot_simd(&x[r.clone()], &y[r])
                })
            };
            for pool in [&standard, &detached] {
                let (cx, cy) = (x.clone(), y.clone());
                let pending = pool.run_chunks_async(x.len(), CACHELINE_F64, move |_, r| {
                    native::kahan_dot_simd(&cx[r.clone()], &cy[r])
                });
                let got = pending.wait();
                assert_eq!(got.len(), want.len(), "T={threads}");
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "T={threads}");
                }
            }
        }
    }

    #[test]
    fn async_tasks_match_blocking_and_overlap() {
        let pool = ThreadPool::new_detached(3);
        for total in [0usize, 1, 5, 40] {
            let pending = pool.run_tasks_async(total, |i| i * 3 + 1);
            let want: Vec<usize> = (0..total).map(|i| i * 3 + 1).collect();
            assert_eq!(pending.wait(), want, "total={total}");
        }
        // Two dispatches in flight at once: posting the second must not
        // require the first to finish, and both complete with task-order
        // results — the latency-hiding property the serving dispatcher
        // relies on.
        let a = pool.run_tasks_async(16, |i| i);
        let b = pool.run_tasks_async(16, |i| i + 100);
        assert_eq!(b.wait(), (100..116).collect::<Vec<_>>());
        assert_eq!(a.wait(), (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn async_dispatch_panic_reraised_at_wait_and_pool_survives() {
        let pool = ThreadPool::new_detached(2);
        let pending = pool.run_tasks_async(8, |i| {
            if i == 5 {
                panic!("async boom");
            }
            i
        });
        let payload =
            catch_unwind(AssertUnwindSafe(|| pending.wait())).expect_err("panic must re-raise");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"async boom"));
        // Dropping an unwaited handle (panicked or not) leaks nothing and
        // the pool keeps serving.
        drop(pool.run_tasks_async(4, |i| i));
        assert_eq!(pool.run_tasks(4, |i| i + 1), vec![1, 2, 3, 4]);
        let ok = pool.run_chunks_async(64, CACHELINE_F64, |i, _| i).wait();
        assert_eq!(ok, vec![0, 1]);
    }

    #[test]
    fn detached_pool_blocking_paths_are_bit_compatible() {
        // A detached pool must be a drop-in for the standard one on the
        // blocking paths too (same partition, same results) — the async
        // service's sync wrapper depends on it.
        let x = randvec(2051, 61);
        let y = randvec(2051, 62);
        for threads in [1usize, 2, 4] {
            let standard = ParallelBackend::new(threads);
            let detached = ThreadPool::new_detached(threads);
            let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
            let want = standard.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
            let partials = detached.run_chunks(x.len(), CACHELINE_F64, |_, r| {
                native::kahan_dot_simd(&x[r.clone()], &y[r])
            });
            let got = compensated_tree_reduce(&partials);
            assert_eq!(got.to_bits(), want.to_bits(), "T={threads}");
        }
    }

    #[test]
    fn rejects_mismatched_inputs_like_serial() {
        let backend = ParallelBackend::new(2);
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let err = backend
            .run(spec, &KernelInput::Dot(&[1.0], &[1.0, 2.0]))
            .unwrap_err();
        assert!(matches!(err, BackendError::ShapeMismatch { .. }));
        let err = backend.run(spec, &KernelInput::Sum(&[1.0])).unwrap_err();
        assert!(matches!(err, BackendError::InputMismatch { .. }));
    }

    #[test]
    fn injected_worker_panic_fails_own_dispatch_and_pool_self_heals() {
        use crate::serve::faults::FaultPlan;
        let x = randvec(4099, 71);
        let y = randvec(4099, 72);
        let clean = ThreadPool::new_detached(3);
        let want = clean.run_chunks(x.len(), CACHELINE_F64, |_, r| {
            native::kahan_dot_simd(&x[r.clone()], &y[r])
        });

        let inj = FaultInjector::new(FaultPlan::none().with(FaultSite::WorkerPanic, 1));
        let pool = ThreadPool::new_detached_with_faults(3, Some(Arc::clone(&inj)));
        let boom = catch_unwind(AssertUnwindSafe(|| {
            pool.run_chunks(x.len(), CACHELINE_F64, |_, r| {
                native::kahan_dot_simd(&x[r.clone()], &y[r])
            })
        }));
        let payload = boom.expect_err("injected worker panic must fail its own dispatch");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"injected worker panic"));
        assert_eq!(inj.fired(FaultSite::WorkerPanic), 1);

        // The trigger has passed; the slot is respawned before the next
        // dispatch, the logical partition is unchanged, and the results are
        // bit-identical to an uninjected pool at the same T.
        let got = pool.run_chunks(x.len(), CACHELINE_F64, |_, r| {
            native::kahan_dot_simd(&x[r.clone()], &y[r])
        });
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn queued_jobs_on_a_dying_worker_resolve_instead_of_hanging() {
        use crate::serve::faults::FaultPlan;
        // Single detached worker: both dispatches land on the same slot, so
        // the second's job can sit behind the killing job. It must resolve
        // (success if it was reposted to a healed worker, a re-raised panic
        // if it was dropped with the dead one) — never hang.
        let inj = FaultInjector::new(FaultPlan::none().with(FaultSite::WorkerPanic, 1));
        let pool = ThreadPool::new_detached_with_faults(1, Some(inj));
        let a = pool.run_tasks_async(1, |i| i);
        let b = pool.run_tasks_async(1, |i| i + 10);
        let ra = catch_unwind(AssertUnwindSafe(move || a.wait()));
        let rb = catch_unwind(AssertUnwindSafe(move || b.wait()));
        assert!(ra.is_err(), "the killing dispatch must fail");
        if let Ok(v) = rb {
            assert_eq!(v, vec![10]);
        }
        // Whatever happened in between, the slot heals and serves again
        // (async, so the work actually lands on the respawned worker —
        // a T=1 blocking dispatch would run inline and prove nothing).
        assert_eq!(pool.run_tasks_async(3, |i| i * 2).wait(), vec![0, 2, 4]);
    }

    #[test]
    fn idle_injector_is_bit_identical_to_no_injector() {
        use crate::serve::faults::FaultPlan;
        let x = randvec(2051, 81);
        let y = randvec(2051, 82);
        let plain = ThreadPool::new_detached(3);
        let armed = ThreadPool::new_detached_with_faults(
            3,
            Some(FaultInjector::new(FaultPlan::none())),
        );
        let a = plain.run_chunks(x.len(), CACHELINE_F64, |_, r| {
            native::kahan_dot_simd(&x[r.clone()], &y[r])
        });
        let b = armed.run_chunks(x.len(), CACHELINE_F64, |_, r| {
            native::kahan_dot_simd(&x[r.clone()], &y[r])
        });
        assert_eq!(a.len(), b.len());
        for (va, vb) in a.iter().zip(&b) {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    #[test]
    fn latch_wake_delay_only_adds_latency() {
        use crate::serve::faults::FaultPlan;
        use std::time::Duration;
        let inj = FaultInjector::new(FaultPlan::none().with_stall(
            FaultSite::LatchWakeDelay,
            1,
            Duration::from_millis(5),
        ));
        let pool = ThreadPool::new_detached_with_faults(2, Some(Arc::clone(&inj)));
        let got = pool.run_tasks(4, |i| i + 1);
        assert_eq!(got, vec![1, 2, 3, 4]);
        assert_eq!(inj.fired(FaultSite::LatchWakeDelay), 1);
    }
}
