//! Thread-parallel execution of the native kernel ladder — the layer that
//! turns the paper's *multicore saturation* claim (Sect. 5.1, Figs. 8/9)
//! into something this repo can measure instead of only simulate.
//!
//! Design:
//!
//! * [`ThreadPool`] partitions the iteration space into at most `T`
//!   contiguous chunks whose boundaries are aligned to cache-line
//!   granularity ([`CACHELINE_F64`] elements). With a 64-byte-aligned
//!   allocation no two workers touch the same line of the operand streams;
//!   `Vec<f64>` only guarantees element alignment, so in the worst case
//!   each chunk *boundary* shares one straddling line with its neighbor —
//!   O(T) lines against millions streamed, so per-worker traffic is whole
//!   cache lines to ECM accuracy, and read-only sharing causes no
//!   invalidation traffic anyway.
//! * Workers are `std::thread::scope` threads: the offline crate cache has
//!   no crossbeam/rayon, and scoped threads are the only way in std to run
//!   borrowed slices on multiple threads without `unsafe` lifetime erasure.
//!   The pool object itself is reusable (it owns the partition policy and
//!   thread count); OS threads are spawned per dispatch, which for the
//!   paper's kernels (>= tens of microseconds of work per timed pass) is
//!   noise. Thread→core *pinning* is not available in std; we rely on the
//!   OS scheduler, which on an otherwise idle machine behaves pinned-ish —
//!   documented, not guaranteed.
//! * Every worker runs an unmodified [`NativeFn`] rung on its slice, so
//!   each thread carries its own Kahan compensation (the per-chunk kernels
//!   already end in the compensated lane fold). The `T` partial results are
//!   then combined by [`compensated_tree_reduce`] — a pairwise `two_sum`
//!   tree that is *deterministic for a fixed thread count* (the combination
//!   order depends only on the partition, never on thread finish order) and
//!   keeps the total error within the serial compensated bound: each chunk
//!   contributes its own Kahan-bounded error over Σ_chunk|x·y|, and the
//!   tree adds only the exactly-tracked `two_sum` residues
//!   (property-tested against the exact ground truth in
//!   `tests/properties.rs`).
//!
//! [`ParallelBackend`] exposes all of this through the ordinary
//! [`Backend`]/[`KernelExec`] traits, so `hostbench`, the harness and the
//! CLI (`bench-scale`) drive threaded kernels exactly like serial ones.

use std::ops::Range;

use super::backend::native::{self, NativeFn};
use super::backend::{
    Backend, BackendError, KernelExec, KernelInput, KernelSpec, NativeBackend,
};
use crate::accuracy::eft::two_sum;

/// f64 elements per 64-byte cache line — the chunk-boundary alignment.
pub const CACHELINE_F64: usize = 8;

/// A reusable partition-and-dispatch pool for slice-parallel kernels.
#[derive(Clone, Debug)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// A pool targeting `threads` workers (clamped to >= 1).
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
        }
    }

    /// Worker count this pool partitions for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Hardware thread count of this host (>= 1).
    pub fn available() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Deterministic partition of `0..n` into at most `threads` contiguous
    /// chunks whose start indices are multiples of `align`. Blocks are
    /// dealt as evenly as possible (front chunks get the remainder), and a
    /// chunk never degenerates to empty unless `n == 0` (then one empty
    /// chunk is returned so callers still get a partial to reduce).
    pub fn partition(&self, n: usize, align: usize) -> Vec<Range<usize>> {
        if n == 0 {
            return vec![0..0];
        }
        let align = align.max(1);
        let blocks = (n + align - 1) / align;
        let t = self.threads.min(blocks);
        let per = blocks / t;
        let extra = blocks % t;
        let mut v = Vec::with_capacity(t);
        let mut block = 0;
        for i in 0..t {
            let nb = per + usize::from(i < extra);
            let start = block * align;
            let end = ((block + nb) * align).min(n);
            v.push(start..end);
            block += nb;
        }
        v
    }

    /// Run `f(worker_index, chunk_range)` over the partition of `0..n`,
    /// returning results in partition order (independent of thread finish
    /// order — this is what makes downstream reductions deterministic).
    /// Single-chunk dispatches run inline on the caller's thread.
    pub fn run_chunks<R, F>(&self, n: usize, align: usize, f: F) -> Vec<R>
    where
        F: Fn(usize, Range<usize>) -> R + Sync,
        R: Send,
    {
        let parts = self.partition(n, align);
        if parts.len() == 1 {
            let r = parts[0].clone();
            return vec![f(0, r)];
        }
        let mut out: Vec<Option<R>> = (0..parts.len()).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (i, (slot, range)) in out.iter_mut().zip(parts.iter()).enumerate() {
                let fref = &f;
                let range = range.clone();
                scope.spawn(move || {
                    *slot = Some(fref(i, range));
                });
            }
        });
        out.into_iter()
            .map(|o| o.expect("worker produced no result"))
            .collect()
    }
}

/// Combine per-thread partial sums by a pairwise compensated tree: each
/// pair is added with an exact [`two_sum`], the rounding residues ride
/// along and are folded in once at the end. The reduction order is a fixed
/// left-to-right pairing over the input slice, so the result is bit-stable
/// for a given partition. The residues themselves accumulate with plain
/// adds (which round at the residues' own tiny scale) plus the final
/// `value + residue` add, so the reduction is not exact in general — but
/// those second-order roundings are far inside the compensated Kahan bound
/// the property tests pin.
pub fn compensated_tree_reduce(parts: &[f64]) -> f64 {
    match parts {
        [] => 0.0,
        [one] => *one,
        _ => {
            let mut nodes: Vec<(f64, f64)> = parts.iter().map(|&p| (p, 0.0)).collect();
            while nodes.len() > 1 {
                let mut next = Vec::with_capacity((nodes.len() + 1) / 2);
                for pair in nodes.chunks(2) {
                    if let [a, b] = pair {
                        let (s, e) = two_sum(a.0, b.0);
                        next.push((s, e + a.1 + b.1));
                    } else {
                        next.push(pair[0]);
                    }
                }
                nodes = next;
            }
            let (s, e) = nodes[0];
            s + e
        }
    }
}

/// A native kernel dispatched over per-thread slices with a deterministic
/// compensated combination of the partials.
pub struct ParallelKernel {
    spec: KernelSpec,
    f: NativeFn,
    pool: ThreadPool,
}

impl ParallelKernel {
    /// Worker count this kernel partitions for.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl KernelExec for ParallelKernel {
    fn spec(&self) -> KernelSpec {
        self.spec
    }

    fn run(&self, input: &KernelInput<'_>) -> Result<f64, BackendError> {
        input.check(self.spec)?;
        let partials = match (self.f, *input) {
            (NativeFn::Dot(f), KernelInput::Dot(x, y)) => self
                .pool
                .run_chunks(x.len(), CACHELINE_F64, |_, r| f(&x[r.clone()], &y[r])),
            (NativeFn::Sum(f), KernelInput::Sum(x)) => {
                self.pool.run_chunks(x.len(), CACHELINE_F64, |_, r| f(&x[r]))
            }
            _ => unreachable!("check() verified the input kind"),
        };
        Ok(compensated_tree_reduce(&partials))
    }
}

/// The thread-parallel native backend: the same kernel ladder as
/// [`NativeBackend`], each kernel executed on `threads` workers over
/// cache-line-aligned slices.
pub struct ParallelBackend {
    inner: NativeBackend,
    threads: usize,
}

impl ParallelBackend {
    /// A backend running every kernel on `threads` workers (>= 1).
    pub fn new(threads: usize) -> Self {
        Self {
            inner: NativeBackend::new(),
            threads: threads.max(1),
        }
    }

    /// One worker per available hardware thread.
    pub fn all_cores() -> Self {
        Self::new(ThreadPool::available())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Is the AVX2 style usable on this host?
    pub fn has_avx2(&self) -> bool {
        self.inner.has_avx2()
    }
}

impl Backend for ParallelBackend {
    fn name(&self) -> &str {
        "native-mt"
    }

    fn kernels(&self) -> Vec<KernelSpec> {
        self.inner.kernels()
    }

    fn resolve(&self, spec: KernelSpec) -> Result<Box<dyn KernelExec + '_>, BackendError> {
        match native::native_fn(spec, self.inner.has_avx2()) {
            Some(f) => Ok(Box::new(ParallelKernel {
                spec,
                f,
                pool: ThreadPool::new(self.threads),
            })),
            None => Err(BackendError::Unsupported {
                backend: self.name().to_string(),
                spec,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::{exact_dot, exact_sum};
    use crate::runtime::backend::{ImplStyle, KernelClass};
    use crate::util::rng::Rng;

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn partition_is_aligned_disjoint_and_covering() {
        for threads in [1usize, 2, 3, 7, 16] {
            let pool = ThreadPool::new(threads);
            for n in [0usize, 1, 7, 8, 9, 64, 100, 1003, 4096] {
                let parts = pool.partition(n, CACHELINE_F64);
                assert!(parts.len() <= threads, "n={n} T={threads}: {parts:?}");
                let mut cursor = 0;
                for r in &parts {
                    assert_eq!(r.start, cursor, "n={n} T={threads}: {parts:?}");
                    assert_eq!(r.start % CACHELINE_F64, 0, "unaligned start: {parts:?}");
                    assert!(r.end > r.start || n == 0, "empty chunk: {parts:?}");
                    cursor = r.end;
                }
                assert_eq!(cursor, n, "partition must cover 0..{n}: {parts:?}");
            }
        }
    }

    #[test]
    fn run_chunks_orders_results_by_partition() {
        let pool = ThreadPool::new(4);
        let got = pool.run_chunks(64, CACHELINE_F64, |i, r| (i, r.start, r.end));
        assert_eq!(got.len(), 4);
        for (i, &(wi, s, e)) in got.iter().enumerate() {
            assert_eq!(wi, i);
            assert_eq!((s, e), (i * 16, i * 16 + 16));
        }
    }

    #[test]
    fn tree_reduce_small_cases() {
        assert_eq!(compensated_tree_reduce(&[]), 0.0);
        assert_eq!(compensated_tree_reduce(&[-0.0]).to_bits(), (-0.0f64).to_bits());
        assert_eq!(compensated_tree_reduce(&[1.0, 2.0, 3.0]), 6.0);
        // Catastrophic cancellation across partials: the tree's two_sum
        // residues recover what a naive left fold loses.
        let parts = [1e16, 3.25, -1e16, 2.5];
        assert_eq!(compensated_tree_reduce(&parts), 5.75);
    }

    #[test]
    fn parallel_matches_serial_ground_truth() {
        let x = randvec(4099, 11); // ragged: not a multiple of 8
        let y = randvec(4099, 12);
        let want = exact_dot(&x, &y);
        let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        for threads in [1usize, 2, 3, 8] {
            let backend = ParallelBackend::new(threads);
            for spec in backend.kernels() {
                if spec.class != KernelClass::KahanDot {
                    continue;
                }
                let got = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
                assert!(
                    (got - want).abs() <= 8.0 * f64::EPSILON * cond,
                    "{spec} T={threads}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn parallel_sum_matches_exact() {
        let x = randvec(2049, 21);
        let want = exact_sum(&x);
        let abs: f64 = x.iter().map(|v| v.abs()).sum();
        let backend = ParallelBackend::new(3);
        let spec = KernelSpec::new(KernelClass::KahanSum, ImplStyle::SimdLanes);
        let got = backend.run(spec, &KernelInput::Sum(&x)).unwrap();
        assert!((got - want).abs() <= 8.0 * f64::EPSILON * abs);
    }

    #[test]
    fn single_thread_is_bit_identical_to_serial() {
        let x = randvec(1003, 31);
        let y = randvec(1003, 32);
        let serial = NativeBackend::new();
        let par = ParallelBackend::new(1);
        for spec in serial.kernels() {
            let input = if spec.class.is_dot() {
                KernelInput::Dot(&x, &y)
            } else {
                KernelInput::Sum(&x)
            };
            let a = serial.run(spec, &input).unwrap();
            let b = par.run(spec, &input).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "{spec}");
        }
    }

    #[test]
    fn fixed_thread_count_is_deterministic() {
        let x = randvec(8192, 41);
        let y = randvec(8192, 42);
        for threads in [2usize, 5, 8] {
            let backend = ParallelBackend::new(threads);
            let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
            let a = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
            for _ in 0..5 {
                let b = backend.run(spec, &KernelInput::Dot(&x, &y)).unwrap();
                assert_eq!(a.to_bits(), b.to_bits(), "T={threads}");
            }
        }
    }

    #[test]
    fn empty_and_tiny_inputs_parallel() {
        let backend = ParallelBackend::new(8);
        for spec in backend.kernels() {
            let got = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[], &[])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[])).unwrap()
            };
            assert_eq!(got, 0.0, "{spec} on empty input");
            let one = if spec.class.is_dot() {
                backend.run(spec, &KernelInput::Dot(&[3.0], &[2.0])).unwrap()
            } else {
                backend.run(spec, &KernelInput::Sum(&[6.0])).unwrap()
            };
            assert_eq!(one, 6.0, "{spec} on length-1 input");
        }
    }

    #[test]
    fn rejects_mismatched_inputs_like_serial() {
        let backend = ParallelBackend::new(2);
        let spec = KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes);
        let err = backend
            .run(spec, &KernelInput::Dot(&[1.0], &[1.0, 2.0]))
            .unwrap_err();
        assert!(matches!(err, BackendError::ShapeMismatch { .. }));
        let err = backend.run(spec, &KernelInput::Sum(&[1.0])).unwrap_err();
        assert!(matches!(err, BackendError::InputMismatch { .. }));
    }
}
