//! Binary wire codec for the `serve-net` front-end.
//!
//! This module is the *only* place that knows the byte layout of the wire
//! protocol. The normative specification lives in `docs/PROTOCOL.md`; every
//! frame field here cites the section of that document that defines it, and
//! the two are kept in lockstep — a change to either without the other is a
//! review error.
//!
//! Design constraints (PROTOCOL.md §1):
//!
//! * **Dependency-free.** Frames are encoded into `Vec<u8>` and decoded from
//!   byte slices with explicit little-endian accessors — no serde, no async
//!   runtime.
//! * **Bounded.** Every length field is validated against
//!   [`MAX_PAYLOAD`] before any allocation, so a hostile or corrupted peer
//!   cannot make the server allocate unbounded memory.
//! * **Panic-free on hostile input.** Decoding returns [`WireError`]; it
//!   never panics, truncates silently, or accepts trailing garbage.
//! * **Numerically transparent.** `f64` operands travel as their IEEE-754
//!   bit patterns (little-endian), so a value decoded on the server is
//!   bit-identical to the value encoded by the client. This is the wire leg
//!   of the repo-wide determinism contract (see `docs/ARCHITECTURE.md`).

use crate::runtime::arena::AlignedVec;
use crate::serve::scheduler::ExecPath;
use crate::serve::SharedInput;
use std::sync::Arc;

/// Frame magic, `b"KDOT"` (PROTOCOL.md §2.1). First four bytes of every
/// frame in either direction; anything else is a fatal framing error.
pub const MAGIC: [u8; 4] = *b"KDOT";

/// Protocol version carried in every frame header (PROTOCOL.md §6). The
/// server rejects any other value with [`ErrorCode::BadVersion`] and closes
/// the connection.
pub const VERSION: u8 = 1;

/// Fixed frame-header length in bytes (PROTOCOL.md §2.2): magic (4) +
/// version (1) + opcode (1) + flags (1) + reserved (1) + request id (8) +
/// payload length (4).
pub const HEADER_LEN: usize = 20;

/// Header flag bit: the payload begins with an 8-byte little-endian
/// deadline in microseconds, measured from server receipt
/// (PROTOCOL.md §2.4, protocol revision 1.1). Offset 6 carried a
/// mandatory-zero reserved byte in revision 1.0, so a 1.0 server rejects
/// this flag with a non-fatal [`ErrorCode::Malformed`] — the documented
/// downgrade signal.
pub const FLAG_DEADLINE: u8 = 0x01;

/// Header flag bit (revision 1.2): the payload carries a 4-byte
/// little-endian tenant id, placed *after* the deadline prefix when both
/// flags are set (prefixes appear in ascending flag-bit order,
/// PROTOCOL.md §2.4). On a STATS request the tenant prefix doubles as the
/// opt-in for the per-tenant stats extension (§3.7); on a STATS_RESULT
/// frame this bit announces that extension. Pre-1.2 servers reject the
/// bit with a non-fatal [`ErrorCode::Malformed`] — the downgrade signal.
pub const FLAG_TENANT: u8 = 0x02;

/// Header flag bit (revision 1.2), error frames only: the error payload
/// carries a 4-byte little-endian retry-after hint in microseconds
/// between the code byte and the message length (PROTOCOL.md §4). The
/// server sets it only on BUSY/QUOTA frames answering a request that
/// itself carried a revision-1.2 flag, so a pre-1.2 client never sees it.
pub const FLAG_RETRY: u8 = 0x04;

/// Header flag bit (revision 1.3): the sender understands the
/// operand-store/result-cache extension (PROTOCOL.md §2.4). It carries no
/// payload prefix. On a STATS request it opts into the cache-counter
/// stats extension (§3.7); on a STATS_RESULT frame it announces that
/// extension. Pre-1.3 servers reject the bit with a non-fatal
/// [`ErrorCode::Malformed`] — the downgrade signal, exactly as for the
/// 1.1/1.2 flags.
pub const FLAG_CACHE: u8 = 0x08;

/// Header flag bit (revision 1.4): the frame carries a 4-byte little-endian
/// CRC32C *trailer* as the last bytes of the payload, and the declared
/// payload length includes it (PROTOCOL.md §2.6). The checksum covers the
/// 20 header bytes exactly as sent (flag set, length grown) plus the
/// payload without the trailer. Receivers verify and strip the trailer
/// before any prefix or body decoding; a mismatch is the typed non-fatal
/// [`ErrorCode::CorruptFrame`] — the stream is still frame-aligned, so the
/// connection survives. Pre-1.4 servers reject the bit with a non-fatal
/// [`ErrorCode::Malformed`] — the downgrade signal, exactly as for the
/// 1.1/1.2/1.3 flags.
pub const FLAG_CRC: u8 = 0x10;

/// Header flag bit (revision 1.4): on a request, the client asks for the
/// certified error bound; on a [`Opcode::Result`] frame, the 17-byte
/// result body is followed by an 8-byte IEEE-754 error-bound field — the
/// Kahan compensation magnitude the kernels already track, certified
/// `|result - exact| <=` bound (PROTOCOL.md §3.5, revision 1.4). Servers
/// set it only on results answering a request that itself carried the
/// flag, so pre-1.4 clients never see the extension.
pub const FLAG_ERRBOUND: u8 = 0x20;

/// Header flag bit (revision 1.4): on a STATS request it opts into the
/// integrity-counter stats extension; on a STATS_RESULT frame it announces
/// that extension — five `u64` scrub/verification counters appended after
/// the cache counters (PROTOCOL.md §3.7). Always accompanied by
/// [`FLAG_CACHE`]: the integrity counters extend the cache block, and a
/// scrub extension without it is [`ErrorCode::Malformed`].
pub const FLAG_SCRUB: u8 = 0x40;

/// All flag bits assigned so far (PROTOCOL.md §2.4). Unknown bits are
/// rejected as [`ErrorCode::Malformed`] without closing the connection,
/// exactly as revision 1.0 treated any nonzero offset-6 byte.
pub const FLAGS_KNOWN: u8 =
    FLAG_DEADLINE | FLAG_TENANT | FLAG_RETRY | FLAG_CACHE | FLAG_CRC | FLAG_ERRBOUND | FLAG_SCRUB;

/// Maximum payload length the codec will accept, 128 MiB
/// (PROTOCOL.md §2.3). Large enough for a dot request over the full default
/// mixture's largest operand pair (`n = 4_194_304` → 4 + 16·n ≈ 64 MiB),
/// small enough to bound per-connection memory.
pub const MAX_PAYLOAD: usize = 1 << 27;

/// CRC32C (Castagnoli) lookup table, built at compile time from the
/// reflected polynomial `0x82F63B78` (PROTOCOL.md §2.6, revision 1.4).
/// Table-driven and dependency-free by design constraint (§1).
const CRC32C_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0x82F6_3B78 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// Fold `bytes` into a running CRC32C state (pre- and post-inversion are
/// the caller's job) — lets [`verify_crc`] checksum header and payload
/// without concatenating them.
fn crc32c_update(mut crc: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        crc = (crc >> 8) ^ CRC32C_TABLE[((crc ^ u32::from(b)) & 0xFF) as usize];
    }
    crc
}

/// CRC32C (Castagnoli) checksum of `bytes` — the checksum the revision-1.4
/// [`FLAG_CRC`] trailer carries (PROTOCOL.md §2.6). Standard reflected
/// CRC32C: initial value `!0`, final complement; the check value over
/// `b"123456789"` is `0xE3069283`.
pub fn crc32c(bytes: &[u8]) -> u32 {
    !crc32c_update(!0u32, bytes)
}

/// Byte length of the [`FLAG_CRC`] trailer (PROTOCOL.md §2.6).
pub const CRC_TRAILER_LEN: usize = 4;

/// Seal a complete frame with the revision-1.4 CRC trailer, in place
/// (PROTOCOL.md §2.6): sets [`FLAG_CRC`] in the header, grows the declared
/// payload length by the 4-byte trailer, then appends the little-endian
/// CRC32C computed over the *updated* header and the payload without the
/// trailer — so the checksum also covers the flags and length the peer
/// actually received. Panics if the grown payload would exceed
/// [`MAX_PAYLOAD`] (encoders build payloads far below the cap) or on a
/// headerless buffer; both are caller bugs, not wire conditions.
pub fn seal_crc(frame: &mut Vec<u8>) {
    assert!(frame.len() >= HEADER_LEN, "sealing a frame without a header");
    let payload_len = frame.len() - HEADER_LEN + CRC_TRAILER_LEN;
    assert!(
        payload_len <= MAX_PAYLOAD,
        "sealed payload {} exceeds protocol cap {}",
        payload_len,
        MAX_PAYLOAD
    );
    frame[6] |= FLAG_CRC;
    frame[16..20].copy_from_slice(&(payload_len as u32).to_le_bytes());
    let crc = crc32c(frame);
    frame.extend_from_slice(&crc.to_le_bytes());
}

/// Verify and strip the revision-1.4 CRC trailer from a received payload
/// (PROTOCOL.md §2.6). `head` is the raw 20-byte header exactly as
/// received; the checksum covers those bytes plus the payload without its
/// trailing [`CRC_TRAILER_LEN`] bytes. Returns the payload with the
/// trailer stripped, ready for prefix splitting and body decoding; a
/// flagless call passes the payload through untouched. A flagged payload
/// shorter than its trailer, or a checksum mismatch, is the typed
/// non-fatal [`ErrorCode::CorruptFrame`].
pub fn verify_crc<'a>(
    head: &[u8; HEADER_LEN],
    flags: u8,
    payload: &'a [u8],
) -> Result<&'a [u8], WireError> {
    if flags & FLAG_CRC == 0 {
        return Ok(payload);
    }
    if payload.len() < CRC_TRAILER_LEN {
        return Err(WireError::new(
            ErrorCode::CorruptFrame,
            "CRC flag set but payload shorter than its 4-byte trailer",
        ));
    }
    let body = &payload[..payload.len() - CRC_TRAILER_LEN];
    let trailer = &payload[payload.len() - CRC_TRAILER_LEN..];
    let want = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let got = !crc32c_update(crc32c_update(!0u32, head), body);
    if got != want {
        return Err(WireError::new(
            ErrorCode::CorruptFrame,
            format!("frame checksum mismatch: computed {got:#010x}, trailer {want:#010x}"),
        ));
    }
    Ok(body)
}

/// Request/response opcodes (PROTOCOL.md §3). The discriminant values are
/// the wire bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opcode {
    /// Inline dot-product request: two equal-length `f64` vectors
    /// (PROTOCOL.md §3.1).
    Dot,
    /// Inline sum request: one `f64` vector (PROTOCOL.md §3.2).
    Sum,
    /// Batched submission: a count followed by that many embedded dot/sum
    /// payloads, answered by one batch-result frame (PROTOCOL.md §3.3).
    Batch,
    /// Stats probe: empty payload, answered with a stats frame
    /// (PROTOCOL.md §3.4).
    Stats,
    /// Register an operand vector into the resident store, answered with a
    /// register-result frame carrying its content handle
    /// (PROTOCOL.md §3.8, revision 1.3).
    Register,
    /// Drop the store's reference to a resident handle, answered with a
    /// release-result frame (PROTOCOL.md §3.9, revision 1.3).
    Release,
    /// Dot-product request by resident-operand handle pair — 16 payload
    /// bytes instead of two inline vectors; answered with an ordinary
    /// result frame (PROTOCOL.md §3.10, revision 1.3).
    DotHandles,
    /// Server → client scalar result (PROTOCOL.md §3.5).
    Result,
    /// Server → client batch result (PROTOCOL.md §3.6).
    BatchResult,
    /// Server → client stats snapshot (PROTOCOL.md §3.7).
    StatsResult,
    /// Server → client register acknowledgement (PROTOCOL.md §3.8,
    /// revision 1.3).
    RegisterResult,
    /// Server → client release acknowledgement (PROTOCOL.md §3.9,
    /// revision 1.3).
    ReleaseResult,
    /// Server → client typed error frame (PROTOCOL.md §4).
    Error,
}

impl Opcode {
    /// The wire byte for this opcode (PROTOCOL.md §3, opcode table).
    pub fn byte(self) -> u8 {
        match self {
            Opcode::Dot => 0x01,
            Opcode::Sum => 0x02,
            Opcode::Batch => 0x03,
            Opcode::Stats => 0x04,
            Opcode::Register => 0x05,
            Opcode::Release => 0x06,
            Opcode::DotHandles => 0x07,
            Opcode::Result => 0x81,
            Opcode::BatchResult => 0x83,
            Opcode::StatsResult => 0x84,
            Opcode::RegisterResult => 0x85,
            Opcode::ReleaseResult => 0x86,
            Opcode::Error => 0xFF,
        }
    }

    /// Parse a wire byte back into an opcode; `None` for unassigned bytes,
    /// which the server answers with [`ErrorCode::BadOpcode`] without
    /// closing the connection (PROTOCOL.md §3).
    pub fn from_byte(b: u8) -> Option<Self> {
        Some(match b {
            0x01 => Opcode::Dot,
            0x02 => Opcode::Sum,
            0x03 => Opcode::Batch,
            0x04 => Opcode::Stats,
            0x05 => Opcode::Register,
            0x06 => Opcode::Release,
            0x07 => Opcode::DotHandles,
            0x81 => Opcode::Result,
            0x83 => Opcode::BatchResult,
            0x84 => Opcode::StatsResult,
            0x85 => Opcode::RegisterResult,
            0x86 => Opcode::ReleaseResult,
            0xFF => Opcode::Error,
            _ => return None,
        })
    }
}

/// Typed error codes carried by [`Opcode::Error`] frames
/// (PROTOCOL.md §4, error-code table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Frame did not start with [`MAGIC`]; fatal (PROTOCOL.md §4.1).
    BadMagic,
    /// Version byte differed from [`VERSION`]; fatal (PROTOCOL.md §4.2).
    BadVersion,
    /// Unassigned opcode byte; the offending frame is skipped and the
    /// connection stays usable (PROTOCOL.md §4.3).
    BadOpcode,
    /// Payload failed structural validation — truncated, trailing bytes,
    /// or an internal length that disagrees with the payload length
    /// (PROTOCOL.md §4.4).
    Malformed,
    /// Declared payload length exceeded [`MAX_PAYLOAD`]; fatal because the
    /// stream cannot be resynchronised without reading the oversized body
    /// (PROTOCOL.md §4.5).
    Oversized,
    /// The request decoded cleanly but the service rejected it (e.g. a dot
    /// with mismatched operand lengths) (PROTOCOL.md §4.6).
    Invalid,
    /// Admission queue full: the documented backpressure signal. The client
    /// may retry; nothing was enqueued (PROTOCOL.md §5).
    Busy,
    /// The service is shutting down; fatal (PROTOCOL.md §4.8).
    Shutdown,
    /// Unexpected server-side failure (PROTOCOL.md §4.9).
    Internal,
    /// The request's deadline expired before execution began; it was shed
    /// in-queue without any compute. Non-fatal: the client may resubmit
    /// with a larger budget (PROTOCOL.md §4.10, revision 1.1). A 1.0
    /// client decodes this byte as [`ErrorCode::Internal`] — still a
    /// per-request error, never a framing break.
    Deadline,
    /// The request's tenant is at its configured queue quota; the request
    /// was shed at admission without entering the queue. Distinct from
    /// [`ErrorCode::Busy`] (whole-queue backpressure): QUOTA means *this
    /// tenant* must back off while others are still admitted. Non-fatal
    /// (PROTOCOL.md §4.11, revision 1.2); pre-1.2 clients decode the byte
    /// as [`ErrorCode::Internal`].
    Quota,
    /// A handle-submit or RELEASE named a handle that is not resident —
    /// never registered, already released, or evicted under capacity
    /// pressure. Non-fatal: the client re-registers the operand (getting
    /// the same handle back, since handles are content hashes) and
    /// retries (PROTOCOL.md §4.12, revision 1.3). Pre-1.3 clients decode
    /// the byte as [`ErrorCode::Internal`].
    UnknownHandle,
    /// A REGISTER payload alone exceeds the operand store's byte capacity,
    /// so no eviction can make it resident. Non-fatal: the client falls
    /// back to inline payload submission (PROTOCOL.md §4.13, revision
    /// 1.3). Pre-1.3 clients decode the byte as [`ErrorCode::Internal`].
    StoreFull,
    /// A [`FLAG_CRC`]-sealed frame failed checksum verification — the
    /// bytes were damaged in flight or by a faulty peer. Non-fatal: the
    /// header parsed cleanly, so the stream is still frame-aligned and the
    /// sender may simply resend (PROTOCOL.md §4.14, revision 1.4).
    /// Pre-1.4 clients decode the byte as [`ErrorCode::Internal`].
    CorruptFrame,
    /// A resident operand failed its SHA-256 scrub — the stored bits no
    /// longer match the digest recorded at REGISTER. The entry is
    /// quarantined (evicted, never served) and the client re-registers the
    /// operand to restore it; non-fatal (PROTOCOL.md §4.15, revision 1.4).
    /// Pre-1.4 clients decode the byte as [`ErrorCode::Internal`].
    CorruptOperand,
}

impl ErrorCode {
    /// The wire byte for this error code (PROTOCOL.md §4).
    pub fn byte(self) -> u8 {
        match self {
            ErrorCode::BadMagic => 0x01,
            ErrorCode::BadVersion => 0x02,
            ErrorCode::BadOpcode => 0x03,
            ErrorCode::Malformed => 0x04,
            ErrorCode::Oversized => 0x05,
            ErrorCode::Invalid => 0x06,
            ErrorCode::Busy => 0x07,
            ErrorCode::Shutdown => 0x08,
            ErrorCode::Internal => 0x09,
            ErrorCode::Deadline => 0x0A,
            ErrorCode::Quota => 0x0B,
            ErrorCode::UnknownHandle => 0x0C,
            ErrorCode::StoreFull => 0x0D,
            ErrorCode::CorruptFrame => 0x0E,
            ErrorCode::CorruptOperand => 0x0F,
        }
    }

    /// Parse a wire byte back into an error code; unknown bytes map to
    /// [`ErrorCode::Internal`] so a newer server never crashes an older
    /// client (PROTOCOL.md §4).
    pub fn from_byte(b: u8) -> Self {
        match b {
            0x01 => ErrorCode::BadMagic,
            0x02 => ErrorCode::BadVersion,
            0x03 => ErrorCode::BadOpcode,
            0x04 => ErrorCode::Malformed,
            0x05 => ErrorCode::Oversized,
            0x06 => ErrorCode::Invalid,
            0x07 => ErrorCode::Busy,
            0x08 => ErrorCode::Shutdown,
            0x0A => ErrorCode::Deadline,
            0x0B => ErrorCode::Quota,
            0x0C => ErrorCode::UnknownHandle,
            0x0D => ErrorCode::StoreFull,
            0x0E => ErrorCode::CorruptFrame,
            0x0F => ErrorCode::CorruptOperand,
            _ => ErrorCode::Internal,
        }
    }

    /// Whether the server closes the connection after sending this error
    /// (PROTOCOL.md §4, fatality column). Fatal errors mean the byte
    /// stream can no longer be trusted to be frame-aligned.
    pub fn is_fatal(self) -> bool {
        matches!(
            self,
            ErrorCode::BadMagic | ErrorCode::BadVersion | ErrorCode::Oversized | ErrorCode::Shutdown
        )
    }

    /// Human-readable label, used in error frames and diagnostics.
    pub fn label(self) -> &'static str {
        match self {
            ErrorCode::BadMagic => "bad-magic",
            ErrorCode::BadVersion => "bad-version",
            ErrorCode::BadOpcode => "bad-opcode",
            ErrorCode::Malformed => "malformed",
            ErrorCode::Oversized => "oversized",
            ErrorCode::Invalid => "invalid",
            ErrorCode::Busy => "busy",
            ErrorCode::Shutdown => "shutdown",
            ErrorCode::Internal => "internal",
            ErrorCode::Deadline => "deadline",
            ErrorCode::Quota => "quota",
            ErrorCode::UnknownHandle => "unknown-handle",
            ErrorCode::StoreFull => "store-full",
            ErrorCode::CorruptFrame => "corrupt-frame",
            ErrorCode::CorruptOperand => "corrupt-operand",
        }
    }
}

/// A decode failure or a decoded server-side error frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireError {
    /// The typed error code (PROTOCOL.md §4).
    pub code: ErrorCode,
    /// Free-form diagnostic detail; informational only, never parsed.
    pub message: String,
    /// Optional retry-after hint in microseconds, carried structurally by
    /// [`FLAG_RETRY`]-flagged BUSY/QUOTA frames (PROTOCOL.md §4, revision
    /// 1.2) — receivers must never parse `message` for it.
    pub retry_after_us: Option<u32>,
}

impl WireError {
    /// Construct an error with a code and diagnostic message.
    pub fn new(code: ErrorCode, message: impl Into<String>) -> Self {
        Self {
            code,
            message: message.into(),
            retry_after_us: None,
        }
    }

    /// [`Self::new`] carrying a retry-after hint (BUSY/QUOTA overload
    /// signals, PROTOCOL.md §4, revision 1.2).
    pub fn with_retry(code: ErrorCode, message: impl Into<String>, retry_after_us: u32) -> Self {
        Self {
            code,
            message: message.into(),
            retry_after_us: Some(retry_after_us),
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.label(), self.message)
    }
}

/// A decoded frame header (PROTOCOL.md §2.2). Magic, version and the
/// reserved byte are validated during decode and not retained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    /// Raw opcode byte (PROTOCOL.md §2.2, offset 5). Kept as a byte, not an
    /// [`Opcode`], so the caller can answer unknown opcodes with
    /// [`ErrorCode::BadOpcode`] after skipping the declared payload.
    pub opcode: u8,
    /// Flags byte (PROTOCOL.md §2.4, offset 6); only bits in
    /// [`FLAGS_KNOWN`] survive decoding. Zero on every revision-1.0 frame.
    pub flags: u8,
    /// Client-chosen request id echoed verbatim in the response
    /// (PROTOCOL.md §2.2, offset 8). Correlates out-of-order responses.
    pub request_id: u64,
    /// Payload length in bytes, already validated `<=` [`MAX_PAYLOAD`]
    /// (PROTOCOL.md §2.2, offset 16).
    pub payload_len: u32,
}

/// Decode and validate a frame header from exactly [`HEADER_LEN`] bytes
/// (PROTOCOL.md §2.2). Checks run in stream-trust order: magic first (is
/// this even our protocol?), then version, then the payload-length cap,
/// then the reserved bytes.
pub fn decode_header(buf: &[u8; HEADER_LEN]) -> Result<FrameHeader, WireError> {
    if buf[0..4] != MAGIC {
        return Err(WireError::new(
            ErrorCode::BadMagic,
            format!("expected magic {:?}, got {:?}", MAGIC, &buf[0..4]),
        ));
    }
    if buf[4] != VERSION {
        return Err(WireError::new(
            ErrorCode::BadVersion,
            format!("protocol version {} unsupported (server speaks {})", buf[4], VERSION),
        ));
    }
    let payload_len = u32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]);
    if payload_len as usize > MAX_PAYLOAD {
        return Err(WireError::new(
            ErrorCode::Oversized,
            format!("payload length {} exceeds cap {}", payload_len, MAX_PAYLOAD),
        ));
    }
    if buf[6] & !FLAGS_KNOWN != 0 {
        return Err(WireError::new(
            ErrorCode::Malformed,
            format!("unknown header flag bits {:#04x}", buf[6] & !FLAGS_KNOWN),
        ));
    }
    if buf[7] != 0 {
        return Err(WireError::new(
            ErrorCode::Malformed,
            "reserved header byte must be zero",
        ));
    }
    let request_id = u64::from_le_bytes([
        buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
    ]);
    Ok(FrameHeader {
        opcode: buf[5],
        flags: buf[6],
        request_id,
        payload_len,
    })
}

/// Encode a frame header into `out` (PROTOCOL.md §2.2). `payload_len` must
/// already be within [`MAX_PAYLOAD`]; callers go through
/// [`encode_frame`], which enforces it.
fn encode_header(out: &mut Vec<u8>, opcode: Opcode, request_id: u64, payload_len: u32) {
    encode_header_flagged(out, opcode, 0, request_id, payload_len);
}

fn encode_header_flagged(
    out: &mut Vec<u8>,
    opcode: Opcode,
    flags: u8,
    request_id: u64,
    payload_len: u32,
) {
    debug_assert_eq!(flags & !FLAGS_KNOWN, 0, "encoding unknown flag bits");
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(opcode.byte());
    out.push(flags); // flags (PROTOCOL.md §2.4)
    out.push(0u8); // reserved (PROTOCOL.md §2.2)
    out.extend_from_slice(&request_id.to_le_bytes());
    out.extend_from_slice(&payload_len.to_le_bytes());
}

/// Assemble a complete frame: header + payload (PROTOCOL.md §2). Panics if
/// `payload` exceeds [`MAX_PAYLOAD`] — encoders construct payloads from
/// validated requests, so an oversized payload is a caller bug, not a wire
/// condition.
pub fn encode_frame(opcode: Opcode, request_id: u64, payload: &[u8]) -> Vec<u8> {
    assert!(
        payload.len() <= MAX_PAYLOAD,
        "payload {} exceeds protocol cap {}",
        payload.len(),
        MAX_PAYLOAD
    );
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_header(&mut out, opcode, request_id, payload.len() as u32);
    out.extend_from_slice(payload);
    out
}

/// Encode just a frame header (PROTOCOL.md §2.2) for a payload of
/// `payload_len` bytes — used by streaming writers that cache one payload
/// per request size and stamp a fresh request id per send, avoiding a
/// payload copy per frame. Panics on `payload_len > MAX_PAYLOAD`, like
/// [`encode_frame`].
pub fn encode_header_bytes(
    opcode: Opcode,
    request_id: u64,
    payload_len: usize,
) -> [u8; HEADER_LEN] {
    assert!(
        payload_len <= MAX_PAYLOAD,
        "payload {} exceeds protocol cap {}",
        payload_len,
        MAX_PAYLOAD
    );
    let mut out = Vec::with_capacity(HEADER_LEN);
    encode_header(&mut out, opcode, request_id, payload_len as u32);
    let mut buf = [0u8; HEADER_LEN];
    buf.copy_from_slice(&out);
    buf
}

/// Assemble a deadline-carrying request frame (PROTOCOL.md §2.4): the
/// header sets [`FLAG_DEADLINE`] and the payload is the 8-byte
/// little-endian deadline in microseconds followed by the ordinary
/// request payload. Panics on an oversized combined payload, like
/// [`encode_frame`].
pub fn encode_frame_with_deadline(
    opcode: Opcode,
    request_id: u64,
    deadline_us: u64,
    payload: &[u8],
) -> Vec<u8> {
    let total = payload.len() + 8;
    assert!(
        total <= MAX_PAYLOAD,
        "payload {} exceeds protocol cap {}",
        total,
        MAX_PAYLOAD
    );
    let mut out = Vec::with_capacity(HEADER_LEN + total);
    encode_header_flagged(&mut out, opcode, FLAG_DEADLINE, request_id, total as u32);
    out.extend_from_slice(&deadline_us.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Strip the optional deadline prefix that [`FLAG_DEADLINE`] announces
/// (PROTOCOL.md §2.4), returning the deadline (if any) and the remaining
/// request payload. A flagged payload shorter than 8 bytes is
/// [`ErrorCode::Malformed`].
pub fn split_deadline(flags: u8, payload: &[u8]) -> Result<(Option<u64>, &[u8]), WireError> {
    if flags & FLAG_DEADLINE == 0 {
        return Ok((None, payload));
    }
    if payload.len() < 8 {
        return Err(WireError::new(
            ErrorCode::Malformed,
            "deadline flag set but payload shorter than its 8-byte prefix",
        ));
    }
    let deadline_us = u64::from_le_bytes([
        payload[0], payload[1], payload[2], payload[3], payload[4], payload[5], payload[6],
        payload[7],
    ]);
    Ok((Some(deadline_us), &payload[8..]))
}

/// Per-request metadata announced by header flags and carried as payload
/// prefixes (PROTOCOL.md §2.4): the revision-1.1 deadline, the
/// revision-1.2 tenant id, and the prefix-free revision-1.3 cache bit.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestMeta {
    /// Shedding budget in microseconds from server receipt
    /// ([`FLAG_DEADLINE`]).
    pub deadline_us: Option<u64>,
    /// Tenant id for QoS admission and scheduling ([`FLAG_TENANT`]).
    /// Absent means the default tenant (id 0).
    pub tenant: Option<u32>,
    /// Revision-1.3 cache awareness ([`FLAG_CACHE`], no payload prefix).
    /// On a STATS request it opts into the cache-counter stats extension
    /// (PROTOCOL.md §3.7).
    pub cache: bool,
    /// Revision-1.4 certified-error-bound opt-in ([`FLAG_ERRBOUND`], no
    /// payload prefix): the result frame answering this request carries
    /// the 8-byte error-bound extension (PROTOCOL.md §3.5).
    pub errbound: bool,
    /// Revision-1.4 integrity-counter opt-in ([`FLAG_SCRUB`], no payload
    /// prefix). On a STATS request it asks for the scrub extension; it
    /// implies the cache extension (PROTOCOL.md §3.7).
    pub scrub: bool,
}

/// Strip every flagged payload prefix (PROTOCOL.md §2.4, revision 1.4):
/// the 8-byte deadline ([`FLAG_DEADLINE`]), then the 4-byte tenant id
/// ([`FLAG_TENANT`]) — prefixes appear in ascending flag-bit order.
/// [`FLAG_CACHE`], [`FLAG_ERRBOUND`] and [`FLAG_SCRUB`] carry no prefix
/// and are recorded as-is ([`FLAG_CRC`]'s trailer is verified and
/// stripped before this call, see [`verify_crc`]). Returns the decoded
/// metadata and the remaining request payload; a flagged payload shorter
/// than its prefixes is [`ErrorCode::Malformed`].
pub fn split_prefixes(flags: u8, payload: &[u8]) -> Result<(RequestMeta, &[u8]), WireError> {
    let (deadline_us, rest) = split_deadline(flags, payload)?;
    let mut meta = RequestMeta {
        deadline_us,
        tenant: None,
        cache: flags & FLAG_CACHE != 0,
        errbound: flags & FLAG_ERRBOUND != 0,
        scrub: flags & FLAG_SCRUB != 0,
    };
    if flags & FLAG_TENANT == 0 {
        return Ok((meta, rest));
    }
    if rest.len() < 4 {
        return Err(WireError::new(
            ErrorCode::Malformed,
            "tenant flag set but payload shorter than its 4-byte prefix",
        ));
    }
    meta.tenant = Some(u32::from_le_bytes([rest[0], rest[1], rest[2], rest[3]]));
    Ok((meta, &rest[4..]))
}

/// Assemble a request frame carrying any combination of the flagged
/// prefixes (PROTOCOL.md §2.4): the flags byte announces what
/// [`RequestMeta`] carries, and the payload is prefixed accordingly —
/// deadline first, then tenant, then the ordinary request payload. Panics
/// on an oversized combined payload, like [`encode_frame`].
pub fn encode_frame_with_meta(
    opcode: Opcode,
    request_id: u64,
    meta: RequestMeta,
    payload: &[u8],
) -> Vec<u8> {
    let mut flags = 0u8;
    let mut prefix_len = 0usize;
    if meta.deadline_us.is_some() {
        flags |= FLAG_DEADLINE;
        prefix_len += 8;
    }
    if meta.tenant.is_some() {
        flags |= FLAG_TENANT;
        prefix_len += 4;
    }
    if meta.cache {
        flags |= FLAG_CACHE; // prefix-free (PROTOCOL.md §2.4)
    }
    if meta.errbound {
        flags |= FLAG_ERRBOUND; // prefix-free (PROTOCOL.md §2.4, rev 1.4)
    }
    if meta.scrub {
        flags |= FLAG_SCRUB; // prefix-free (PROTOCOL.md §2.4, rev 1.4)
    }
    let total = payload.len() + prefix_len;
    assert!(
        total <= MAX_PAYLOAD,
        "payload {} exceeds protocol cap {}",
        total,
        MAX_PAYLOAD
    );
    let mut out = Vec::with_capacity(HEADER_LEN + total);
    encode_header_flagged(&mut out, opcode, flags, request_id, total as u32);
    if let Some(deadline_us) = meta.deadline_us {
        out.extend_from_slice(&deadline_us.to_le_bytes());
    }
    if let Some(tenant) = meta.tenant {
        out.extend_from_slice(&tenant.to_le_bytes());
    }
    out.extend_from_slice(payload);
    out
}

/// Bounds-checked little-endian cursor over a payload. Every accessor
/// returns [`ErrorCode::Malformed`] instead of panicking when the payload
/// is shorter than its fields claim.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            WireError::new(ErrorCode::Malformed, "payload offset overflow")
        })?;
        if end > self.buf.len() {
            return Err(WireError::new(
                ErrorCode::Malformed,
                format!(
                    "payload truncated: need {} bytes at offset {}, have {}",
                    n,
                    self.pos,
                    self.buf.len() - self.pos
                ),
            ));
        }
        let slice = &self.buf[self.pos..end];
        self.pos = end;
        Ok(slice)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Reject trailing bytes: a well-formed payload is consumed exactly
    /// (PROTOCOL.md §2.3).
    fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::new(
                ErrorCode::Malformed,
                format!("{} trailing bytes after payload", self.buf.len() - self.pos),
            ));
        }
        Ok(())
    }
}

/// Byte length of an inline dot payload for vectors of `n` elements
/// (PROTOCOL.md §3.1): count (4) + 2·n doubles.
pub fn dot_payload_len(n: usize) -> usize {
    4 + 16 * n
}

/// Byte length of an inline sum payload for a vector of `n` elements
/// (PROTOCOL.md §3.2): count (4) + n doubles.
pub fn sum_payload_len(n: usize) -> usize {
    4 + 8 * n
}

fn push_f64s(out: &mut Vec<u8>, values: &[f64]) {
    for v in values {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Encode an inline dot payload — element count then `x` then `y`, both as
/// IEEE-754 bit patterns (PROTOCOL.md §3.1). Exposed separately from
/// [`encode_dot`] so the wire load generator can cache one payload per
/// mixture size and re-frame it with fresh request ids.
pub fn encode_dot_payload(x: &[f64], y: &[f64]) -> Vec<u8> {
    assert_eq!(x.len(), y.len(), "dot operands must be equal length");
    let mut payload = Vec::with_capacity(dot_payload_len(x.len()));
    payload.extend_from_slice(&(x.len() as u32).to_le_bytes());
    push_f64s(&mut payload, x);
    push_f64s(&mut payload, y);
    payload
}

/// Encode a complete inline dot request frame (PROTOCOL.md §3.1).
pub fn encode_dot(request_id: u64, x: &[f64], y: &[f64]) -> Vec<u8> {
    encode_frame(Opcode::Dot, request_id, &encode_dot_payload(x, y))
}

/// Encode an inline sum payload — element count then the vector
/// (PROTOCOL.md §3.2).
pub fn encode_sum_payload(x: &[f64]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(sum_payload_len(x.len()));
    payload.extend_from_slice(&(x.len() as u32).to_le_bytes());
    push_f64s(&mut payload, x);
    payload
}

/// Encode a complete inline sum request frame (PROTOCOL.md §3.2).
pub fn encode_sum(request_id: u64, x: &[f64]) -> Vec<u8> {
    encode_frame(Opcode::Sum, request_id, &encode_sum_payload(x))
}

/// Encode a REGISTER payload — element count then the vector as IEEE-754
/// bit patterns, identical in shape to a sum payload (PROTOCOL.md §3.8).
/// These are exactly the bytes the server hashes (after the count) to
/// derive the operand's content handle.
pub fn encode_register_payload(x: &[f64]) -> Vec<u8> {
    encode_sum_payload(x)
}

/// Encode a complete REGISTER request frame (PROTOCOL.md §3.8, revision
/// 1.3).
pub fn encode_register(request_id: u64, x: &[f64]) -> Vec<u8> {
    encode_frame(Opcode::Register, request_id, &encode_register_payload(x))
}

/// Encode a complete RELEASE request frame — one little-endian `u64`
/// handle (PROTOCOL.md §3.9, revision 1.3).
pub fn encode_release(request_id: u64, handle: u64) -> Vec<u8> {
    encode_frame(Opcode::Release, request_id, &handle.to_le_bytes())
}

/// Encode a DOT_HANDLES payload — the two resident-operand handles,
/// little-endian, x first (PROTOCOL.md §3.10): 16 bytes regardless of
/// operand length, the entire point of the resident store.
pub fn encode_dot_handles_payload(a: u64, b: u64) -> Vec<u8> {
    let mut payload = Vec::with_capacity(16);
    payload.extend_from_slice(&a.to_le_bytes());
    payload.extend_from_slice(&b.to_le_bytes());
    payload
}

/// Encode a complete DOT_HANDLES request frame (PROTOCOL.md §3.10,
/// revision 1.3).
pub fn encode_dot_handles(request_id: u64, a: u64, b: u64) -> Vec<u8> {
    encode_frame(
        Opcode::DotHandles,
        request_id,
        &encode_dot_handles_payload(a, b),
    )
}

fn encode_request_payload(out: &mut Vec<u8>, input: &SharedInput) {
    match input {
        SharedInput::Dot(x, y) => {
            out.push(0x01); // kind byte: dot (PROTOCOL.md §3.3)
            out.extend_from_slice(&(x.len() as u32).to_le_bytes());
            push_f64s(out, x);
            push_f64s(out, y);
        }
        SharedInput::Sum(x) => {
            out.push(0x02); // kind byte: sum (PROTOCOL.md §3.3)
            out.extend_from_slice(&(x.len() as u32).to_le_bytes());
            push_f64s(out, x);
        }
    }
}

/// Encode a batched submission frame: request count, then per-request a
/// kind byte (dot/sum), element count and operands (PROTOCOL.md §3.3). The
/// server answers with one [`Opcode::BatchResult`] frame carrying results
/// in submission order.
pub fn encode_batch(request_id: u64, inputs: &[SharedInput]) -> Vec<u8> {
    let mut payload = Vec::new();
    payload.extend_from_slice(&(inputs.len() as u32).to_le_bytes());
    for input in inputs {
        encode_request_payload(&mut payload, input);
    }
    encode_frame(Opcode::Batch, request_id, &payload)
}

/// Encode a stats probe: empty payload (PROTOCOL.md §3.4).
pub fn encode_stats(request_id: u64) -> Vec<u8> {
    encode_frame(Opcode::Stats, request_id, &[])
}

/// Encode a stats probe that opts into the per-tenant extension
/// (PROTOCOL.md §3.7, revision 1.2): the tenant prefix identifies the
/// asking tenant and asks the server to answer with a
/// [`FLAG_TENANT`]-flagged stats result carrying per-tenant counters.
pub fn encode_stats_tenants(request_id: u64, tenant: u32) -> Vec<u8> {
    encode_frame_with_meta(
        Opcode::Stats,
        request_id,
        RequestMeta {
            tenant: Some(tenant),
            ..RequestMeta::default()
        },
        &[],
    )
}

/// Encode a stats probe that opts into the cache-counter extension
/// (PROTOCOL.md §3.7, revision 1.3): [`FLAG_CACHE`] asks the server to
/// answer with a [`FLAG_CACHE`]-flagged stats result carrying
/// operand-store and result-cache counters. Pass a tenant to opt into the
/// per-tenant extension as well; both extensions then appear in the
/// response in ascending flag-bit order.
pub fn encode_stats_cache(request_id: u64, tenant: Option<u32>) -> Vec<u8> {
    encode_frame_with_meta(
        Opcode::Stats,
        request_id,
        RequestMeta {
            tenant,
            cache: true,
            ..RequestMeta::default()
        },
        &[],
    )
}

/// Encode a stats probe that opts into the integrity-counter extension
/// (PROTOCOL.md §3.7, revision 1.4): [`FLAG_SCRUB`] asks the server for
/// the scrub/verification counters, and it always rides with
/// [`FLAG_CACHE`] (the scrub block extends the cache block). Pass a
/// tenant to opt into the per-tenant extension as well.
pub fn encode_stats_scrub(request_id: u64, tenant: Option<u32>) -> Vec<u8> {
    encode_frame_with_meta(
        Opcode::Stats,
        request_id,
        RequestMeta {
            tenant,
            cache: true,
            scrub: true,
            ..RequestMeta::default()
        },
        &[],
    )
}

/// A decoded client request, ready for service admission.
#[derive(Clone, Debug)]
pub enum Request {
    /// One inline dot or sum, submitted individually (PROTOCOL.md §3.1–2).
    Submit(SharedInput),
    /// A batched submission, answered with one batch-result frame
    /// (PROTOCOL.md §3.3).
    Batch(Vec<SharedInput>),
    /// A stats probe (PROTOCOL.md §3.4).
    Stats,
    /// Register an operand into the resident store (PROTOCOL.md §3.8,
    /// revision 1.3). Decoded straight into an aligned arena buffer, like
    /// inline operands.
    Register(Arc<AlignedVec>),
    /// Release a resident-operand handle (PROTOCOL.md §3.9, revision 1.3).
    Release(u64),
    /// A dot submitted by resident-operand handle pair (PROTOCOL.md §3.10,
    /// revision 1.3).
    SubmitHandles {
        /// Handle of the first operand (`x`).
        a: u64,
        /// Handle of the second operand (`y`).
        b: u64,
    },
}

/// Upper bound on elements implied by a payload of `len` bytes, used to cap
/// pre-allocation before the operand bytes are validated.
fn element_cap(len: usize, bytes_per_elem: usize) -> usize {
    len / bytes_per_elem.max(1)
}

fn decode_vec(r: &mut Reader<'_>, n: usize) -> Result<Arc<AlignedVec>, WireError> {
    let bytes = r.take(8 * n)?;
    // Decode straight into an aligned operand buffer so the kernels see the
    // same alignment guarantees as in-process operands.
    let v = AlignedVec::from_fn(n, |i| {
        let o = 8 * i;
        f64::from_bits(u64::from_le_bytes([
            bytes[o],
            bytes[o + 1],
            bytes[o + 2],
            bytes[o + 3],
            bytes[o + 4],
            bytes[o + 5],
            bytes[o + 6],
            bytes[o + 7],
        ]))
    });
    Ok(Arc::new(v))
}

fn decode_dot_body(r: &mut Reader<'_>, payload_len: usize) -> Result<SharedInput, WireError> {
    let n = r.u32()? as usize;
    if n > element_cap(payload_len, 16) {
        return Err(WireError::new(
            ErrorCode::Malformed,
            format!("dot count {} exceeds payload capacity", n),
        ));
    }
    let x = decode_vec(r, n)?;
    let y = decode_vec(r, n)?;
    Ok(SharedInput::Dot(x, y))
}

fn decode_sum_body(r: &mut Reader<'_>, payload_len: usize) -> Result<SharedInput, WireError> {
    let n = r.u32()? as usize;
    if n > element_cap(payload_len, 8) {
        return Err(WireError::new(
            ErrorCode::Malformed,
            format!("sum count {} exceeds payload capacity", n),
        ));
    }
    Ok(SharedInput::Sum(decode_vec(r, n)?))
}

/// Decode a request payload for a validated request opcode
/// (PROTOCOL.md §3). `opcode` must be one of the request opcodes; response
/// opcodes arriving at a server are answered with
/// [`ErrorCode::BadOpcode`] by the caller.
pub fn decode_request(opcode: Opcode, payload: &[u8]) -> Result<Request, WireError> {
    let mut r = Reader::new(payload);
    let req = match opcode {
        Opcode::Dot => Request::Submit(decode_dot_body(&mut r, payload.len())?),
        Opcode::Sum => Request::Submit(decode_sum_body(&mut r, payload.len())?),
        Opcode::Batch => {
            let count = r.u32()? as usize;
            // Each embedded request costs at least a kind byte + count.
            if count > element_cap(payload.len(), 5) {
                return Err(WireError::new(
                    ErrorCode::Malformed,
                    format!("batch count {} exceeds payload capacity", count),
                ));
            }
            let mut inputs = Vec::with_capacity(count);
            for _ in 0..count {
                let kind = r.u8()?;
                let input = match kind {
                    0x01 => decode_dot_body(&mut r, payload.len())?,
                    0x02 => decode_sum_body(&mut r, payload.len())?,
                    other => {
                        return Err(WireError::new(
                            ErrorCode::Malformed,
                            format!("unknown batch request kind byte {:#04x}", other),
                        ))
                    }
                };
                inputs.push(input);
            }
            Request::Batch(inputs)
        }
        Opcode::Stats => Request::Stats,
        Opcode::Register => {
            let n = r.u32()? as usize;
            if n > element_cap(payload.len(), 8) {
                return Err(WireError::new(
                    ErrorCode::Malformed,
                    format!("register count {} exceeds payload capacity", n),
                ));
            }
            Request::Register(decode_vec(&mut r, n)?)
        }
        Opcode::Release => Request::Release(r.u64()?),
        Opcode::DotHandles => Request::SubmitHandles {
            a: r.u64()?,
            b: r.u64()?,
        },
        other => {
            return Err(WireError::new(
                ErrorCode::BadOpcode,
                format!("{:?} is not a request opcode", other),
            ))
        }
    };
    r.finish()?;
    Ok(req)
}

/// One scalar result as carried by [`Opcode::Result`] and
/// [`Opcode::BatchResult`] frames (PROTOCOL.md §3.5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireResult {
    /// The dot/sum value, transported as its IEEE-754 bit pattern so it is
    /// bit-identical to the in-process result (PROTOCOL.md §3.5).
    pub value: f64,
    /// Number of updates (elements) in the request.
    pub n: u64,
    /// Which execution path served the request (fused or sharded).
    pub path: ExecPath,
    /// Certified absolute error bound carried by the revision-1.4
    /// [`FLAG_ERRBOUND`] extension (PROTOCOL.md §3.5): the compensated
    /// kernels certify `|value - exact| <=` this bound. `None` on frames
    /// without the extension — the byte layout is then exactly the
    /// 17-byte revision-1.0 body.
    pub err_bound: Option<f64>,
}

fn path_byte(path: ExecPath) -> u8 {
    match path {
        ExecPath::Fused => 0x00,
        ExecPath::Sharded => 0x01,
    }
}

fn path_from_byte(b: u8) -> Result<ExecPath, WireError> {
    match b {
        0x00 => Ok(ExecPath::Fused),
        0x01 => Ok(ExecPath::Sharded),
        other => Err(WireError::new(
            ErrorCode::Malformed,
            format!("unknown exec-path byte {:#04x}", other),
        )),
    }
}

fn push_result(out: &mut Vec<u8>, result: &WireResult) {
    out.extend_from_slice(&result.value.to_bits().to_le_bytes());
    out.extend_from_slice(&result.n.to_le_bytes());
    out.push(path_byte(result.path));
}

fn read_result(r: &mut Reader<'_>) -> Result<WireResult, WireError> {
    let value = r.f64()?;
    let n = r.u64()?;
    let path = path_from_byte(r.u8()?)?;
    Ok(WireResult {
        value,
        n,
        path,
        err_bound: None,
    })
}

/// Encode a scalar-result frame (PROTOCOL.md §3.5): value bits (8) +
/// update count (8) + path byte (1). When the result carries a certified
/// error bound, the header sets [`FLAG_ERRBOUND`] and the bound's IEEE-754
/// bits (8) follow the path byte (revision 1.4); a bound-free result is
/// byte-identical to the revision-1.0 frame.
pub fn encode_result(request_id: u64, result: &WireResult) -> Vec<u8> {
    let mut payload = Vec::with_capacity(25);
    push_result(&mut payload, result);
    let mut flags = 0u8;
    if let Some(bound) = result.err_bound {
        flags |= FLAG_ERRBOUND;
        payload.extend_from_slice(&bound.to_bits().to_le_bytes());
    }
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_header_flagged(&mut out, Opcode::Result, flags, request_id, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

/// Encode a batch-result frame (PROTOCOL.md §3.6): result count then that
/// many scalar results in submission order.
pub fn encode_batch_result(request_id: u64, results: &[WireResult]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(4 + 17 * results.len());
    payload.extend_from_slice(&(results.len() as u32).to_le_bytes());
    for result in results {
        push_result(&mut payload, result);
    }
    encode_frame(Opcode::BatchResult, request_id, &payload)
}

/// A server-state snapshot carried by [`Opcode::StatsResult`] frames
/// (PROTOCOL.md §3.7): eight little-endian `u64` fields in this order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Configured bounded-queue depth.
    pub queue_depth: u64,
    /// Worker-pool thread count T.
    pub threads: u64,
    /// Requests admitted to the queue since startup.
    pub enqueued: u64,
    /// Requests completed (tickets resolved) since startup.
    pub completed: u64,
    /// Arrival batches drained by the dispatcher.
    pub arrival_batches: u64,
    /// Kernel dispatches issued by the dispatcher.
    pub dispatches: u64,
    /// High-water mark of queue occupancy.
    pub max_queue_depth: u64,
    /// Cumulative worker busy time in nanoseconds.
    pub busy_ns: u64,
}

/// Per-tenant QoS counters carried by the [`FLAG_TENANT`] stats extension
/// (PROTOCOL.md §3.7, revision 1.2): tenant id (u32) then four `u64`
/// fields, all little-endian, in this order.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireTenantStats {
    /// The tenant id these counters belong to.
    pub tenant: u32,
    /// Requests admitted past quota and queue checks since startup.
    pub admitted: u64,
    /// Admitted requests whose tickets resolved (success or typed error).
    pub completed: u64,
    /// Requests shed at admission because the tenant was at quota.
    pub quota_shed: u64,
    /// Admitted requests shed in-queue on deadline expiry.
    pub deadline_shed: u64,
}

fn push_stats_fields(payload: &mut Vec<u8>, stats: &WireStats) {
    for field in [
        stats.queue_depth,
        stats.threads,
        stats.enqueued,
        stats.completed,
        stats.arrival_batches,
        stats.dispatches,
        stats.max_queue_depth,
        stats.busy_ns,
    ] {
        payload.extend_from_slice(&field.to_le_bytes());
    }
}

/// Encode a stats-result frame (PROTOCOL.md §3.7).
pub fn encode_stats_result(request_id: u64, stats: &WireStats) -> Vec<u8> {
    let mut payload = Vec::with_capacity(64);
    push_stats_fields(&mut payload, stats);
    encode_frame(Opcode::StatsResult, request_id, &payload)
}

/// Operand-store and result-cache counters carried by the [`FLAG_CACHE`]
/// stats extension (PROTOCOL.md §3.7, revision 1.3): eight little-endian
/// `u64` fields in this order, appended after the per-tenant extension
/// when both are present (extensions appear in ascending flag-bit order).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireCacheStats {
    /// Operands currently resident in the store.
    pub store_entries: u64,
    /// Bytes currently resident in the store.
    pub store_resident_bytes: u64,
    /// Fresh registrations since startup (upserts not counted).
    pub store_registered: u64,
    /// Store entries removed by capacity-pressure LRU eviction.
    pub store_evictions: u64,
    /// Result-cache probes since startup.
    pub cache_lookups: u64,
    /// Probes that found a memoized result
    /// (`cache_hits + cache_misses == cache_lookups`).
    pub cache_hits: u64,
    /// Probes that found nothing.
    pub cache_misses: u64,
    /// Cache entries removed by capacity-pressure LRU eviction.
    pub cache_evictions: u64,
}

fn push_cache_fields(payload: &mut Vec<u8>, cache: &WireCacheStats) {
    for field in [
        cache.store_entries,
        cache.store_resident_bytes,
        cache.store_registered,
        cache.store_evictions,
        cache.cache_lookups,
        cache.cache_hits,
        cache.cache_misses,
        cache.cache_evictions,
    ] {
        payload.extend_from_slice(&field.to_le_bytes());
    }
}

/// Integrity counters carried by the [`FLAG_SCRUB`] stats extension
/// (PROTOCOL.md §3.7, revision 1.4): five little-endian `u64` fields in
/// this order, appended after the cache counters (extensions appear in
/// ascending flag-bit order; the scrub extension always rides with
/// [`FLAG_CACHE`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireScrubStats {
    /// Resident-operand digest re-checks that matched (on-demand and
    /// background scrubs alike).
    pub scrub_verified: u64,
    /// Resident operands quarantined on digest mismatch — evicted, never
    /// served.
    pub scrub_quarantined: u64,
    /// Full background scrub sweeps completed.
    pub scrub_passes: u64,
    /// Sampled cache hits recomputed and bit-confirmed against the
    /// memoized value.
    pub cache_verified: u64,
    /// Sampled cache hits whose recomputation disagreed — the entry was
    /// evicted and the request fell through to a fresh compute.
    pub cache_poisoned: u64,
}

fn push_scrub_fields(payload: &mut Vec<u8>, scrub: &WireScrubStats) {
    for field in [
        scrub.scrub_verified,
        scrub.scrub_quarantined,
        scrub.scrub_passes,
        scrub.cache_verified,
        scrub.cache_poisoned,
    ] {
        payload.extend_from_slice(&field.to_le_bytes());
    }
}

/// Encode a stats-result frame carrying the per-tenant extension
/// (PROTOCOL.md §3.7, revision 1.2). Shorthand for
/// [`encode_stats_result_ext`] with no cache extension.
pub fn encode_stats_result_tenants(
    request_id: u64,
    stats: &WireStats,
    tenants: &[WireTenantStats],
) -> Vec<u8> {
    encode_stats_result_ext(request_id, stats, Some(tenants), None, None)
}

/// Encode a stats-result frame carrying any combination of the flagged
/// extensions (PROTOCOL.md §3.7): the fixed eight `u64` fields, then — in
/// ascending flag-bit order — the per-tenant rows ([`FLAG_TENANT`],
/// revision 1.2), the cache counters ([`FLAG_CACHE`], revision 1.3) and
/// the integrity counters ([`FLAG_SCRUB`], revision 1.4). The frame's
/// flag bits announce exactly the extensions present; servers send each
/// extension only to clients that opted in via the matching flag on their
/// STATS request. The scrub extension extends the cache block, so passing
/// it without the cache counters is a caller bug (panics in debug).
pub fn encode_stats_result_ext(
    request_id: u64,
    stats: &WireStats,
    tenants: Option<&[WireTenantStats]>,
    cache: Option<&WireCacheStats>,
    scrub: Option<&WireScrubStats>,
) -> Vec<u8> {
    debug_assert!(
        scrub.is_none() || cache.is_some(),
        "the scrub extension rides with the cache extension (PROTOCOL.md §3.7)"
    );
    let mut flags = 0u8;
    let mut payload = Vec::with_capacity(64 + 4 + 36 * tenants.map_or(0, <[_]>::len) + 64 + 40);
    push_stats_fields(&mut payload, stats);
    if let Some(rows) = tenants {
        flags |= FLAG_TENANT;
        payload.extend_from_slice(&(rows.len() as u32).to_le_bytes());
        for row in rows {
            payload.extend_from_slice(&row.tenant.to_le_bytes());
            for field in [row.admitted, row.completed, row.quota_shed, row.deadline_shed] {
                payload.extend_from_slice(&field.to_le_bytes());
            }
        }
    }
    if let Some(cache) = cache {
        flags |= FLAG_CACHE;
        push_cache_fields(&mut payload, cache);
    }
    if let Some(scrub) = scrub {
        flags |= FLAG_SCRUB;
        push_scrub_fields(&mut payload, scrub);
    }
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_header_flagged(
        &mut out,
        Opcode::StatsResult,
        flags,
        request_id,
        payload.len() as u32,
    );
    out.extend_from_slice(&payload);
    out
}

/// Encode a register-result frame (PROTOCOL.md §3.8): handle (8) + element
/// count (8) + fresh byte (1), where fresh is `0x01` iff the contents were
/// not resident before this REGISTER.
pub fn encode_register_result(request_id: u64, handle: u64, n: u64, fresh: bool) -> Vec<u8> {
    let mut payload = Vec::with_capacity(17);
    payload.extend_from_slice(&handle.to_le_bytes());
    payload.extend_from_slice(&n.to_le_bytes());
    payload.push(u8::from(fresh));
    encode_frame(Opcode::RegisterResult, request_id, &payload)
}

/// Encode a release-result frame (PROTOCOL.md §3.9): one found byte,
/// `0x01` iff the handle was resident and its store reference dropped.
pub fn encode_release_result(request_id: u64, found: bool) -> Vec<u8> {
    encode_frame(Opcode::ReleaseResult, request_id, &[u8::from(found)])
}

/// Encode a typed error frame (PROTOCOL.md §4): code byte (1) + message
/// length (4) + UTF-8 message bytes.
pub fn encode_error(request_id: u64, code: ErrorCode, message: &str) -> Vec<u8> {
    let bytes = message.as_bytes();
    // Clamp pathological messages rather than violating the payload cap.
    let take = bytes.len().min(4096);
    let mut payload = Vec::with_capacity(5 + take);
    payload.push(code.byte());
    payload.extend_from_slice(&(take as u32).to_le_bytes());
    payload.extend_from_slice(&bytes[..take]);
    encode_frame(Opcode::Error, request_id, &payload)
}

/// Encode a typed error frame carrying a structured retry-after hint
/// (PROTOCOL.md §4, revision 1.2): the header sets [`FLAG_RETRY`] and the
/// payload is code byte (1) + retry-after µs (4) + message length (4) +
/// UTF-8 message bytes. Only BUSY/QUOTA overload signals carry it, and
/// only toward clients that demonstrated revision-1.2 support.
pub fn encode_error_retry(
    request_id: u64,
    code: ErrorCode,
    retry_after_us: u32,
    message: &str,
) -> Vec<u8> {
    let bytes = message.as_bytes();
    let take = bytes.len().min(4096);
    let mut payload = Vec::with_capacity(9 + take);
    payload.push(code.byte());
    payload.extend_from_slice(&retry_after_us.to_le_bytes());
    payload.extend_from_slice(&(take as u32).to_le_bytes());
    payload.extend_from_slice(&bytes[..take]);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_header_flagged(
        &mut out,
        Opcode::Error,
        FLAG_RETRY,
        request_id,
        payload.len() as u32,
    );
    out.extend_from_slice(&payload);
    out
}

/// A decoded server → client response payload.
#[derive(Clone, Debug)]
pub enum Response {
    /// One scalar result (PROTOCOL.md §3.5).
    Result(WireResult),
    /// A batch of results in submission order (PROTOCOL.md §3.6).
    Batch(Vec<WireResult>),
    /// A stats snapshot (PROTOCOL.md §3.7).
    Stats(WireStats),
    /// A stats snapshot with the revision-1.2 per-tenant extension
    /// (PROTOCOL.md §3.7): the fixed fields plus one row per tenant the
    /// server has seen.
    TenantStats {
        /// The fixed eight-field snapshot every revision carries.
        stats: WireStats,
        /// Per-tenant QoS counter rows, ascending by tenant id.
        tenants: Vec<WireTenantStats>,
    },
    /// A stats snapshot with the revision-1.3 cache-counter extension
    /// (PROTOCOL.md §3.7), optionally combined with the per-tenant rows
    /// and the revision-1.4 integrity counters.
    CacheStats {
        /// The fixed eight-field snapshot every revision carries.
        stats: WireStats,
        /// Per-tenant QoS counter rows if [`FLAG_TENANT`] was also set;
        /// empty otherwise.
        tenants: Vec<WireTenantStats>,
        /// Operand-store and result-cache counters.
        cache: WireCacheStats,
        /// Scrub/verification integrity counters if [`FLAG_SCRUB`] was
        /// also set (revision 1.4); `None` otherwise.
        scrub: Option<WireScrubStats>,
    },
    /// A register acknowledgement (PROTOCOL.md §3.8, revision 1.3).
    Registered {
        /// The operand's content-derived handle.
        handle: u64,
        /// Element count of the registered operand.
        n: u64,
        /// Whether the contents were newly made resident.
        fresh: bool,
    },
    /// A release acknowledgement (PROTOCOL.md §3.9, revision 1.3).
    Released {
        /// Whether the handle was resident and removed.
        found: bool,
    },
    /// A typed error frame (PROTOCOL.md §4).
    Error(WireError),
}

/// Decode a response payload for a validated response opcode
/// (PROTOCOL.md §3.5–3.7, §4). Request opcodes arriving at a client are
/// protocol violations and decode to [`ErrorCode::BadOpcode`]. Flagless
/// shorthand for [`decode_response_flagged`].
pub fn decode_response(opcode: Opcode, payload: &[u8]) -> Result<Response, WireError> {
    decode_response_flagged(0, opcode, payload)
}

/// [`decode_response`] honoring the frame's flags byte (revision 1.2):
/// [`FLAG_TENANT`] on a stats result announces the per-tenant extension,
/// [`FLAG_RETRY`] on an error frame announces the structured retry-after
/// hint.
pub fn decode_response_flagged(
    flags: u8,
    opcode: Opcode,
    payload: &[u8],
) -> Result<Response, WireError> {
    let mut r = Reader::new(payload);
    let resp = match opcode {
        Opcode::Result => {
            let mut result = read_result(&mut r)?;
            if flags & FLAG_ERRBOUND != 0 {
                // Revision-1.4 certified error bound (PROTOCOL.md §3.5).
                result.err_bound = Some(r.f64()?);
            }
            Response::Result(result)
        }
        Opcode::BatchResult => {
            let count = r.u32()? as usize;
            if count > element_cap(payload.len(), 17) {
                return Err(WireError::new(
                    ErrorCode::Malformed,
                    format!("batch-result count {} exceeds payload capacity", count),
                ));
            }
            let mut results = Vec::with_capacity(count);
            for _ in 0..count {
                results.push(read_result(&mut r)?);
            }
            Response::Batch(results)
        }
        Opcode::StatsResult => {
            let stats = WireStats {
                queue_depth: r.u64()?,
                threads: r.u64()?,
                enqueued: r.u64()?,
                completed: r.u64()?,
                arrival_batches: r.u64()?,
                dispatches: r.u64()?,
                max_queue_depth: r.u64()?,
                busy_ns: r.u64()?,
            };
            let mut tenants = Vec::new();
            if flags & FLAG_TENANT != 0 {
                let count = r.u32()? as usize;
                // Each row costs 36 bytes (u32 + 4 × u64).
                if count > element_cap(payload.len(), 36) {
                    return Err(WireError::new(
                        ErrorCode::Malformed,
                        format!("tenant-stats count {} exceeds payload capacity", count),
                    ));
                }
                tenants.reserve(count);
                for _ in 0..count {
                    tenants.push(WireTenantStats {
                        tenant: r.u32()?,
                        admitted: r.u64()?,
                        completed: r.u64()?,
                        quota_shed: r.u64()?,
                        deadline_shed: r.u64()?,
                    });
                }
            }
            if flags & FLAG_SCRUB != 0 && flags & FLAG_CACHE == 0 {
                return Err(WireError::new(
                    ErrorCode::Malformed,
                    "scrub extension requires the cache extension (PROTOCOL.md §3.7)",
                ));
            }
            if flags & FLAG_CACHE != 0 {
                // Extensions appear in ascending flag-bit order, so the
                // cache counters follow the tenant rows (PROTOCOL.md §3.7).
                let cache = WireCacheStats {
                    store_entries: r.u64()?,
                    store_resident_bytes: r.u64()?,
                    store_registered: r.u64()?,
                    store_evictions: r.u64()?,
                    cache_lookups: r.u64()?,
                    cache_hits: r.u64()?,
                    cache_misses: r.u64()?,
                    cache_evictions: r.u64()?,
                };
                let scrub = if flags & FLAG_SCRUB != 0 {
                    Some(WireScrubStats {
                        scrub_verified: r.u64()?,
                        scrub_quarantined: r.u64()?,
                        scrub_passes: r.u64()?,
                        cache_verified: r.u64()?,
                        cache_poisoned: r.u64()?,
                    })
                } else {
                    None
                };
                Response::CacheStats {
                    stats,
                    tenants,
                    cache,
                    scrub,
                }
            } else if flags & FLAG_TENANT != 0 {
                Response::TenantStats { stats, tenants }
            } else {
                Response::Stats(stats)
            }
        }
        Opcode::RegisterResult => Response::Registered {
            handle: r.u64()?,
            n: r.u64()?,
            fresh: r.u8()? != 0,
        },
        Opcode::ReleaseResult => Response::Released { found: r.u8()? != 0 },
        Opcode::Error => {
            let code = ErrorCode::from_byte(r.u8()?);
            let retry_after_us = if flags & FLAG_RETRY != 0 {
                Some(r.u32()?)
            } else {
                None
            };
            let len = r.u32()? as usize;
            let bytes = r.take(len)?;
            let message = String::from_utf8_lossy(bytes).into_owned();
            Response::Error(WireError {
                code,
                message,
                retry_after_us,
            })
        }
        other => {
            return Err(WireError::new(
                ErrorCode::BadOpcode,
                format!("{:?} is not a response opcode", other),
            ))
        }
    };
    r.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared_dot(x: &[f64], y: &[f64]) -> SharedInput {
        SharedInput::Dot(
            Arc::new(AlignedVec::from_fn(x.len(), |i| x[i])),
            Arc::new(AlignedVec::from_fn(y.len(), |i| y[i])),
        )
    }

    fn shared_sum(x: &[f64]) -> SharedInput {
        SharedInput::Sum(Arc::new(AlignedVec::from_fn(x.len(), |i| x[i])))
    }

    fn split(frame: &[u8]) -> (FrameHeader, &[u8]) {
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&frame[..HEADER_LEN]);
        let header = decode_header(&head).expect("valid header");
        assert_eq!(frame.len(), HEADER_LEN + header.payload_len as usize);
        (header, &frame[HEADER_LEN..])
    }

    #[test]
    fn opcode_bytes_round_trip() {
        for op in [
            Opcode::Dot,
            Opcode::Sum,
            Opcode::Batch,
            Opcode::Stats,
            Opcode::Register,
            Opcode::Release,
            Opcode::DotHandles,
            Opcode::Result,
            Opcode::BatchResult,
            Opcode::StatsResult,
            Opcode::RegisterResult,
            Opcode::ReleaseResult,
            Opcode::Error,
        ] {
            assert_eq!(Opcode::from_byte(op.byte()), Some(op));
        }
        assert_eq!(Opcode::from_byte(0x00), None);
        assert_eq!(Opcode::from_byte(0x42), None);
    }

    #[test]
    fn error_codes_round_trip_and_fatality() {
        for code in [
            ErrorCode::BadMagic,
            ErrorCode::BadVersion,
            ErrorCode::BadOpcode,
            ErrorCode::Malformed,
            ErrorCode::Oversized,
            ErrorCode::Invalid,
            ErrorCode::Busy,
            ErrorCode::Shutdown,
            ErrorCode::Internal,
            ErrorCode::Deadline,
            ErrorCode::Quota,
            ErrorCode::UnknownHandle,
            ErrorCode::StoreFull,
            ErrorCode::CorruptFrame,
            ErrorCode::CorruptOperand,
        ] {
            assert_eq!(ErrorCode::from_byte(code.byte()), code);
        }
        assert!(ErrorCode::BadMagic.is_fatal());
        assert!(ErrorCode::BadVersion.is_fatal());
        assert!(ErrorCode::Oversized.is_fatal());
        assert!(ErrorCode::Shutdown.is_fatal());
        assert!(!ErrorCode::Busy.is_fatal());
        assert!(!ErrorCode::BadOpcode.is_fatal());
        assert!(!ErrorCode::Malformed.is_fatal());
        assert!(!ErrorCode::Invalid.is_fatal());
        assert!(!ErrorCode::Deadline.is_fatal());
        assert!(!ErrorCode::Quota.is_fatal());
        assert!(!ErrorCode::UnknownHandle.is_fatal());
        assert!(!ErrorCode::StoreFull.is_fatal());
        assert!(!ErrorCode::CorruptFrame.is_fatal(), "stream stays frame-aligned");
        assert!(!ErrorCode::CorruptOperand.is_fatal(), "re-register recovers");
    }

    #[test]
    fn dot_request_round_trip_bit_exact() {
        let x = [1.0, -2.5, 3.75, f64::MIN_POSITIVE];
        let y = [0.5, 1e300, -1e-300, 4.0];
        let frame = encode_dot(42, &x, &y);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::Dot.byte());
        assert_eq!(header.request_id, 42);
        match decode_request(Opcode::Dot, payload).expect("decodes") {
            Request::Submit(SharedInput::Dot(dx, dy)) => {
                assert_eq!(dx.len(), x.len());
                for i in 0..x.len() {
                    assert_eq!(dx[i].to_bits(), x[i].to_bits());
                    assert_eq!(dy[i].to_bits(), y[i].to_bits());
                }
            }
            other => panic!("unexpected request {:?}", other),
        }
    }

    #[test]
    fn sum_request_round_trip() {
        let x = [2.0, -0.125, 9.5];
        let frame = encode_sum(7, &x);
        let (header, payload) = split(&frame);
        assert_eq!(header.request_id, 7);
        match decode_request(Opcode::Sum, payload).expect("decodes") {
            Request::Submit(SharedInput::Sum(sx)) => {
                assert_eq!(sx.len(), 3);
                for i in 0..3 {
                    assert_eq!(sx[i].to_bits(), x[i].to_bits());
                }
            }
            other => panic!("unexpected request {:?}", other),
        }
    }

    #[test]
    fn batch_request_round_trip() {
        let inputs = vec![
            shared_dot(&[1.0, 2.0], &[3.0, 4.0]),
            shared_sum(&[5.0, 6.0, 7.0]),
            shared_dot(&[0.25], &[8.0]),
        ];
        let frame = encode_batch(9, &inputs);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::Batch.byte());
        match decode_request(Opcode::Batch, payload).expect("decodes") {
            Request::Batch(decoded) => {
                assert_eq!(decoded.len(), 3);
                match (&decoded[0], &inputs[0]) {
                    (SharedInput::Dot(a, b), SharedInput::Dot(c, d)) => {
                        assert_eq!(&a[..], &c[..]);
                        assert_eq!(&b[..], &d[..]);
                    }
                    _ => panic!("kind mismatch"),
                }
                match &decoded[1] {
                    SharedInput::Sum(s) => assert_eq!(&s[..], &[5.0, 6.0, 7.0][..]),
                    _ => panic!("kind mismatch"),
                }
            }
            other => panic!("unexpected request {:?}", other),
        }
    }

    #[test]
    fn stats_request_is_empty() {
        let frame = encode_stats(3);
        let (header, payload) = split(&frame);
        assert_eq!(header.payload_len, 0);
        assert!(matches!(
            decode_request(Opcode::Stats, payload),
            Ok(Request::Stats)
        ));
    }

    #[test]
    fn result_round_trip_bit_exact() {
        let result = WireResult {
            value: -1e-42,
            n: 262144,
            path: ExecPath::Sharded,
            err_bound: None,
        };
        let frame = encode_result(11, &result);
        let (header, payload) = split(&frame);
        assert_eq!(header.request_id, 11);
        match decode_response(Opcode::Result, payload).expect("decodes") {
            Response::Result(r) => {
                assert_eq!(r.value.to_bits(), result.value.to_bits());
                assert_eq!(r.n, 262144);
                assert_eq!(r.path, ExecPath::Sharded);
            }
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn batch_result_round_trip() {
        let results = vec![
            WireResult {
                value: 1.5,
                n: 8,
                path: ExecPath::Fused,
                err_bound: None,
            },
            WireResult {
                value: f64::NEG_INFINITY,
                n: 1 << 20,
                path: ExecPath::Sharded,
                err_bound: None,
            },
        ];
        let frame = encode_batch_result(13, &results);
        let (_, payload) = split(&frame);
        match decode_response(Opcode::BatchResult, payload).expect("decodes") {
            Response::Batch(decoded) => {
                assert_eq!(decoded.len(), 2);
                for (a, b) in decoded.iter().zip(&results) {
                    assert_eq!(a.value.to_bits(), b.value.to_bits());
                    assert_eq!(a.n, b.n);
                    assert_eq!(a.path, b.path);
                }
            }
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn stats_result_round_trip() {
        let stats = WireStats {
            queue_depth: 256,
            threads: 8,
            enqueued: 1000,
            completed: 998,
            arrival_batches: 120,
            dispatches: 140,
            max_queue_depth: 97,
            busy_ns: 123_456_789,
        };
        let frame = encode_stats_result(21, &stats);
        let (_, payload) = split(&frame);
        match decode_response(Opcode::StatsResult, payload).expect("decodes") {
            Response::Stats(s) => assert_eq!(s, stats),
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn error_frame_round_trip() {
        let frame = encode_error(5, ErrorCode::Busy, "queue full");
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::Error.byte());
        match decode_response(Opcode::Error, payload).expect("decodes") {
            Response::Error(e) => {
                assert_eq!(e.code, ErrorCode::Busy);
                assert_eq!(e.message, "queue full");
            }
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn header_bytes_match_encode_frame() {
        let payload = [1u8, 2, 3];
        let frame = encode_frame(Opcode::Sum, 99, &payload);
        let head = encode_header_bytes(Opcode::Sum, 99, payload.len());
        assert_eq!(&frame[..HEADER_LEN], &head[..]);
    }

    #[test]
    fn header_rejects_bad_magic() {
        let frame = encode_stats(1);
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&frame[..HEADER_LEN]);
        head[0] = b'X';
        assert_eq!(decode_header(&head).unwrap_err().code, ErrorCode::BadMagic);
    }

    #[test]
    fn header_rejects_bad_version() {
        let frame = encode_stats(1);
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&frame[..HEADER_LEN]);
        head[4] = VERSION + 1;
        assert_eq!(
            decode_header(&head).unwrap_err().code,
            ErrorCode::BadVersion
        );
    }

    #[test]
    fn header_rejects_nonzero_reserved() {
        let frame = encode_stats(1);
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&frame[..HEADER_LEN]);
        head[7] = 1;
        assert_eq!(
            decode_header(&head).unwrap_err().code,
            ErrorCode::Malformed
        );
    }

    #[test]
    fn header_rejects_unknown_flag_bits() {
        let frame = encode_stats(1);
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&frame[..HEADER_LEN]);
        head[6] = 0x80; // first unassigned flag bit (0x01 through 0x40 are taken)
        assert_eq!(
            decode_header(&head).unwrap_err().code,
            ErrorCode::Malformed
        );
        head[6] = FLAG_CACHE;
        assert_eq!(decode_header(&head).expect("known flag").flags, FLAG_CACHE);
        head[6] = FLAG_CRC;
        assert_eq!(decode_header(&head).expect("known flag").flags, FLAG_CRC);
        head[6] = FLAG_ERRBOUND | FLAG_SCRUB;
        assert_eq!(
            decode_header(&head).expect("known flags").flags,
            FLAG_ERRBOUND | FLAG_SCRUB
        );
        head[6] = FLAG_DEADLINE;
        assert_eq!(decode_header(&head).expect("known flag").flags, FLAG_DEADLINE);
        head[6] = FLAG_TENANT;
        assert_eq!(decode_header(&head).expect("known flag").flags, FLAG_TENANT);
        head[6] = FLAG_DEADLINE | FLAG_TENANT;
        assert_eq!(
            decode_header(&head).expect("known flags").flags,
            FLAG_DEADLINE | FLAG_TENANT
        );
    }

    #[test]
    fn deadline_frame_round_trips_and_strips_cleanly() {
        let x = [1.0, -2.5, 3.75];
        let y = [0.5, 1e300, -1e-300];
        let inner = encode_dot_payload(&x, &y);
        let frame = encode_frame_with_deadline(Opcode::Dot, 42, 1_500_000, &inner);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::Dot.byte());
        assert_eq!(header.flags, FLAG_DEADLINE);
        let (deadline, rest) = split_deadline(header.flags, payload).expect("well-formed");
        assert_eq!(deadline, Some(1_500_000));
        match decode_request(Opcode::Dot, rest).expect("decodes") {
            Request::Submit(SharedInput::Dot(dx, dy)) => {
                for i in 0..x.len() {
                    assert_eq!(dx[i].to_bits(), x[i].to_bits());
                    assert_eq!(dy[i].to_bits(), y[i].to_bits());
                }
            }
            other => panic!("unexpected request {:?}", other),
        }
        // Without the flag the same bytes pass through untouched.
        let (none, all) = split_deadline(0, payload).expect("flagless");
        assert_eq!(none, None);
        assert_eq!(all.len(), payload.len());
    }

    #[test]
    fn truncated_deadline_prefix_rejected() {
        for len in 0..8usize {
            let short = vec![0u8; len];
            assert_eq!(
                split_deadline(FLAG_DEADLINE, &short).unwrap_err().code,
                ErrorCode::Malformed,
                "len {}",
                len
            );
        }
    }

    #[test]
    fn tenant_and_deadline_prefixes_round_trip_in_flag_bit_order() {
        let x = [1.0, -2.5];
        let y = [0.5, 4.0];
        let inner = encode_dot_payload(&x, &y);
        let meta = RequestMeta {
            deadline_us: Some(2_000_000),
            tenant: Some(7),
            ..RequestMeta::default()
        };
        let frame = encode_frame_with_meta(Opcode::Dot, 5, meta, &inner);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_DEADLINE | FLAG_TENANT);
        let (got, rest) = split_prefixes(header.flags, payload).expect("well-formed");
        assert_eq!(got, meta);
        match decode_request(Opcode::Dot, rest).expect("decodes") {
            Request::Submit(SharedInput::Dot(dx, _)) => {
                assert_eq!(dx[0].to_bits(), x[0].to_bits());
            }
            other => panic!("unexpected request {:?}", other),
        }
        // Tenant-only frames carry just the 4-byte prefix.
        let t_only = RequestMeta {
            tenant: Some(3),
            ..RequestMeta::default()
        };
        let frame = encode_frame_with_meta(Opcode::Dot, 6, t_only, &inner);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_TENANT);
        let (got, rest) = split_prefixes(header.flags, payload).expect("well-formed");
        assert_eq!(got.tenant, Some(3));
        assert_eq!(got.deadline_us, None);
        assert_eq!(rest.len(), inner.len());
        // Flagless payloads pass through untouched.
        let (none, all) = split_prefixes(0, payload).expect("flagless");
        assert_eq!(none, RequestMeta::default());
        assert_eq!(all.len(), payload.len());
    }

    #[test]
    fn truncated_tenant_prefix_rejected() {
        for len in 0..4usize {
            let short = vec![0u8; len];
            assert_eq!(
                split_prefixes(FLAG_TENANT, &short).unwrap_err().code,
                ErrorCode::Malformed,
                "len {}",
                len
            );
        }
        // Deadline present but tenant prefix truncated.
        let mut buf = vec![0u8; 8];
        buf.extend_from_slice(&[1, 2]);
        assert_eq!(
            split_prefixes(FLAG_DEADLINE | FLAG_TENANT, &buf)
                .unwrap_err()
                .code,
            ErrorCode::Malformed
        );
    }

    #[test]
    fn tenant_stats_round_trip() {
        let stats = WireStats {
            queue_depth: 64,
            threads: 4,
            enqueued: 500,
            completed: 490,
            arrival_batches: 60,
            dispatches: 70,
            max_queue_depth: 33,
            busy_ns: 987_654,
        };
        let rows = vec![
            WireTenantStats {
                tenant: 0,
                admitted: 300,
                completed: 295,
                quota_shed: 12,
                deadline_shed: 5,
            },
            WireTenantStats {
                tenant: 1,
                admitted: 190,
                completed: 190,
                quota_shed: 0,
                deadline_shed: 0,
            },
        ];
        let frame = encode_stats_result_tenants(17, &stats, &rows);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_TENANT);
        match decode_response_flagged(header.flags, Opcode::StatsResult, payload)
            .expect("decodes")
        {
            Response::TenantStats {
                stats: s,
                tenants: t,
            } => {
                assert_eq!(s, stats);
                assert_eq!(t, rows);
            }
            other => panic!("unexpected response {:?}", other),
        }
        // A flagless decode of a plain stats frame still yields Stats.
        let plain = encode_stats_result(18, &stats);
        let (header, payload) = split(&plain);
        assert_eq!(header.flags, 0);
        assert!(matches!(
            decode_response_flagged(0, Opcode::StatsResult, payload),
            Ok(Response::Stats(_))
        ));
    }

    #[test]
    fn error_retry_hint_round_trips_structurally() {
        let frame = encode_error_retry(9, ErrorCode::Quota, 1500, "tenant 2 at quota");
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_RETRY);
        match decode_response_flagged(header.flags, Opcode::Error, payload).expect("decodes") {
            Response::Error(e) => {
                assert_eq!(e.code, ErrorCode::Quota);
                assert_eq!(e.retry_after_us, Some(1500));
                assert_eq!(e.message, "tenant 2 at quota");
            }
            other => panic!("unexpected response {:?}", other),
        }
        // Unflagged errors decode with no hint, bytes unchanged.
        let plain = encode_error(10, ErrorCode::Busy, "queue full");
        let (header, payload) = split(&plain);
        match decode_response_flagged(header.flags, Opcode::Error, payload).expect("decodes") {
            Response::Error(e) => {
                assert_eq!(e.code, ErrorCode::Busy);
                assert_eq!(e.retry_after_us, None);
            }
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn header_rejects_oversized_payload() {
        let frame = encode_stats(1);
        let mut head = [0u8; HEADER_LEN];
        head.copy_from_slice(&frame[..HEADER_LEN]);
        head[16..20].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_le_bytes());
        assert_eq!(
            decode_header(&head).unwrap_err().code,
            ErrorCode::Oversized
        );
    }

    #[test]
    fn truncated_payloads_never_panic() {
        let x = [1.0, 2.0, 3.0];
        let y = [4.0, 5.0, 6.0];
        let dot = encode_dot(1, &x, &y);
        let full = &dot[HEADER_LEN..];
        for cut in 0..full.len() {
            let err = decode_request(Opcode::Dot, &full[..cut]).unwrap_err();
            assert_eq!(err.code, ErrorCode::Malformed, "cut at {}", cut);
        }
        let result = encode_result(
            2,
            &WireResult {
                value: 1.0,
                n: 3,
                path: ExecPath::Fused,
                err_bound: None,
            },
        );
        let full = &result[HEADER_LEN..];
        for cut in 0..full.len() {
            assert!(decode_response(Opcode::Result, &full[..cut]).is_err());
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let frame = encode_sum(1, &[1.0, 2.0]);
        let mut payload = frame[HEADER_LEN..].to_vec();
        payload.push(0xAB);
        assert_eq!(
            decode_request(Opcode::Sum, &payload).unwrap_err().code,
            ErrorCode::Malformed
        );
    }

    #[test]
    fn counts_exceeding_capacity_rejected_before_allocation() {
        // Claim 2^31 elements in a 12-byte payload: must fail on the cap
        // check, not attempt an allocation.
        let mut payload = Vec::new();
        payload.extend_from_slice(&(1u32 << 31).to_le_bytes());
        payload.extend_from_slice(&[0u8; 8]);
        assert_eq!(
            decode_request(Opcode::Dot, &payload).unwrap_err().code,
            ErrorCode::Malformed
        );
        assert_eq!(
            decode_request(Opcode::Sum, &payload).unwrap_err().code,
            ErrorCode::Malformed
        );
        let mut batch = Vec::new();
        batch.extend_from_slice(&(1u32 << 31).to_le_bytes());
        assert_eq!(
            decode_request(Opcode::Batch, &batch).unwrap_err().code,
            ErrorCode::Malformed
        );
    }

    #[test]
    fn batch_with_unknown_kind_rejected() {
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.push(0x7F);
        payload.extend_from_slice(&0u32.to_le_bytes());
        assert_eq!(
            decode_request(Opcode::Batch, &payload).unwrap_err().code,
            ErrorCode::Malformed
        );
    }

    #[test]
    fn response_opcode_as_request_rejected() {
        assert_eq!(
            decode_request(Opcode::Result, &[]).unwrap_err().code,
            ErrorCode::BadOpcode
        );
        assert_eq!(
            decode_response(Opcode::Dot, &[]).unwrap_err().code,
            ErrorCode::BadOpcode
        );
    }

    #[test]
    fn unknown_error_code_maps_to_internal() {
        assert_eq!(ErrorCode::from_byte(0xEE), ErrorCode::Internal);
    }

    #[test]
    fn error_message_clamped() {
        let long = "x".repeat(10_000);
        let frame = encode_error(1, ErrorCode::Internal, &long);
        let (_, payload) = split(&frame);
        match decode_response(Opcode::Error, payload).expect("decodes") {
            Response::Error(e) => assert_eq!(e.message.len(), 4096),
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn register_request_round_trip_bit_exact() {
        let x = [1.0, -2.5, f64::MIN_POSITIVE, -0.0];
        let frame = encode_register(31, &x);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::Register.byte());
        match decode_request(Opcode::Register, payload).expect("decodes") {
            Request::Register(v) => {
                assert_eq!(v.len(), x.len());
                for i in 0..x.len() {
                    assert_eq!(v[i].to_bits(), x[i].to_bits());
                }
            }
            other => panic!("unexpected request {:?}", other),
        }
        // A register payload is byte-identical to a sum payload: the
        // content hash is defined over exactly these operand bytes.
        assert_eq!(encode_register_payload(&x), encode_sum_payload(&x));
    }

    #[test]
    fn release_and_dot_handles_round_trip() {
        let frame = encode_release(32, 0xDEAD_BEEF_CAFE_F00D);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::Release.byte());
        assert_eq!(header.payload_len, 8);
        match decode_request(Opcode::Release, payload).expect("decodes") {
            Request::Release(h) => assert_eq!(h, 0xDEAD_BEEF_CAFE_F00D),
            other => panic!("unexpected request {:?}", other),
        }
        let frame = encode_dot_handles(33, 11, u64::MAX);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::DotHandles.byte());
        assert_eq!(header.payload_len, 16);
        match decode_request(Opcode::DotHandles, payload).expect("decodes") {
            Request::SubmitHandles { a, b } => {
                assert_eq!(a, 11);
                assert_eq!(b, u64::MAX);
            }
            other => panic!("unexpected request {:?}", other),
        }
    }

    #[test]
    fn dot_handles_carries_prefixes_like_any_request() {
        let meta = RequestMeta {
            deadline_us: Some(5_000),
            tenant: Some(2),
            ..RequestMeta::default()
        };
        let inner = encode_dot_handles_payload(41, 42);
        let frame = encode_frame_with_meta(Opcode::DotHandles, 77, meta, &inner);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_DEADLINE | FLAG_TENANT);
        let (got, rest) = split_prefixes(header.flags, payload).expect("well-formed");
        assert_eq!(got, meta);
        match decode_request(Opcode::DotHandles, rest).expect("decodes") {
            Request::SubmitHandles { a, b } => {
                assert_eq!(a, 41);
                assert_eq!(b, 42);
            }
            other => panic!("unexpected request {:?}", other),
        }
    }

    #[test]
    fn cache_flag_is_prefix_free_and_round_trips_in_meta() {
        let meta = RequestMeta {
            cache: true,
            ..RequestMeta::default()
        };
        let frame = encode_frame_with_meta(Opcode::Stats, 8, meta, &[]);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_CACHE);
        assert_eq!(header.payload_len, 0, "cache flag adds no prefix bytes");
        let (got, rest) = split_prefixes(header.flags, payload).expect("well-formed");
        assert!(got.cache);
        assert!(rest.is_empty());
        // encode_stats_cache is the same frame.
        assert_eq!(encode_stats_cache(8, None), frame);
    }

    #[test]
    fn register_result_round_trip() {
        let frame = encode_register_result(51, 0x0123_4567_89AB_CDEF, 65536, true);
        let (header, payload) = split(&frame);
        assert_eq!(header.opcode, Opcode::RegisterResult.byte());
        assert_eq!(header.payload_len, 17);
        match decode_response(Opcode::RegisterResult, payload).expect("decodes") {
            Response::Registered { handle, n, fresh } => {
                assert_eq!(handle, 0x0123_4567_89AB_CDEF);
                assert_eq!(n, 65536);
                assert!(fresh);
            }
            other => panic!("unexpected response {:?}", other),
        }
        let frame = encode_register_result(52, 9, 4, false);
        let (_, payload) = split(&frame);
        match decode_response(Opcode::RegisterResult, payload).expect("decodes") {
            Response::Registered { fresh, .. } => assert!(!fresh),
            other => panic!("unexpected response {:?}", other),
        }
    }

    #[test]
    fn release_result_round_trip() {
        for found in [true, false] {
            let frame = encode_release_result(53, found);
            let (header, payload) = split(&frame);
            assert_eq!(header.opcode, Opcode::ReleaseResult.byte());
            assert_eq!(header.payload_len, 1);
            match decode_response(Opcode::ReleaseResult, payload).expect("decodes") {
                Response::Released { found: f } => assert_eq!(f, found),
                other => panic!("unexpected response {:?}", other),
            }
        }
    }

    #[test]
    fn cache_stats_extension_round_trips_alone_and_with_tenants() {
        let stats = WireStats {
            queue_depth: 128,
            threads: 4,
            enqueued: 900,
            completed: 1000,
            arrival_batches: 80,
            dispatches: 90,
            max_queue_depth: 40,
            busy_ns: 55_555,
        };
        let cache = WireCacheStats {
            store_entries: 24,
            store_resident_bytes: 24 << 17,
            store_registered: 30,
            store_evictions: 6,
            cache_lookups: 1000,
            cache_hits: 900,
            cache_misses: 100,
            cache_evictions: 2,
        };
        // Cache extension alone.
        let frame = encode_stats_result_ext(61, &stats, None, Some(&cache), None);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_CACHE);
        match decode_response_flagged(header.flags, Opcode::StatsResult, payload)
            .expect("decodes")
        {
            Response::CacheStats {
                stats: s,
                tenants: t,
                cache: c,
                scrub,
            } => {
                assert_eq!(s, stats);
                assert!(t.is_empty());
                assert_eq!(c, cache);
                assert_eq!(scrub, None);
            }
            other => panic!("unexpected response {:?}", other),
        }
        // Both extensions, ascending flag-bit order (tenants then cache).
        let rows = vec![WireTenantStats {
            tenant: 3,
            admitted: 10,
            completed: 10,
            quota_shed: 1,
            deadline_shed: 0,
        }];
        let frame = encode_stats_result_ext(62, &stats, Some(&rows), Some(&cache), None);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_TENANT | FLAG_CACHE);
        match decode_response_flagged(header.flags, Opcode::StatsResult, payload)
            .expect("decodes")
        {
            Response::CacheStats {
                stats: s,
                tenants: t,
                cache: c,
                scrub,
            } => {
                assert_eq!(s, stats);
                assert_eq!(t, rows);
                assert_eq!(c, cache);
                assert_eq!(scrub, None);
            }
            other => panic!("unexpected response {:?}", other),
        }
        // Tenants-only frames still decode to TenantStats: the wrapper
        // delegates without changing rev-1.2 bytes.
        let frame = encode_stats_result_tenants(63, &stats, &rows);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_TENANT);
        assert!(matches!(
            decode_response_flagged(header.flags, Opcode::StatsResult, payload),
            Ok(Response::TenantStats { .. })
        ));
    }

    #[test]
    fn truncated_handle_payloads_never_panic() {
        let frame = encode_dot_handles(1, 7, 8);
        let full = &frame[HEADER_LEN..];
        for cut in 0..full.len() {
            assert_eq!(
                decode_request(Opcode::DotHandles, &full[..cut])
                    .unwrap_err()
                    .code,
                ErrorCode::Malformed,
                "cut at {}",
                cut
            );
        }
        let frame = encode_register(2, &[1.0, 2.0]);
        let full = &frame[HEADER_LEN..];
        for cut in 0..full.len() {
            assert!(decode_request(Opcode::Register, &full[..cut]).is_err());
        }
        // Oversized register counts rejected before allocation.
        let mut payload = Vec::new();
        payload.extend_from_slice(&(1u32 << 31).to_le_bytes());
        payload.extend_from_slice(&[0u8; 8]);
        assert_eq!(
            decode_request(Opcode::Register, &payload).unwrap_err().code,
            ErrorCode::Malformed
        );
    }

    #[test]
    fn crc32c_matches_the_castagnoli_check_value() {
        // The universal CRC32C check value (PROTOCOL.md §2.6).
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(b""), 0);
        // Incremental folding matches one-shot computation.
        let bytes = b"kahan compensated dot product";
        let split_at = 11;
        let inc = !crc32c_update(crc32c_update(!0, &bytes[..split_at]), &bytes[split_at..]);
        assert_eq!(inc, crc32c(bytes));
    }

    #[test]
    fn crc_seal_and_verify_round_trip() {
        let x = [1.0, -2.5, 3.75];
        let y = [0.5, 1e300, -1e-300];
        let mut frame = encode_dot(42, &x, &y);
        let unsealed_len = frame.len();
        seal_crc(&mut frame);
        assert_eq!(frame.len(), unsealed_len + CRC_TRAILER_LEN);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags & FLAG_CRC, FLAG_CRC);
        // The declared payload length includes the trailer (PROTOCOL.md §2.6).
        assert_eq!(header.payload_len as usize, dot_payload_len(x.len()) + CRC_TRAILER_LEN);
        let head: [u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
        let body = verify_crc(&head, header.flags, payload).expect("intact frame verifies");
        assert_eq!(body.len(), dot_payload_len(x.len()));
        match decode_request(Opcode::Dot, body).expect("decodes after strip") {
            Request::Submit(SharedInput::Dot(dx, dy)) => {
                for i in 0..x.len() {
                    assert_eq!(dx[i].to_bits(), x[i].to_bits());
                    assert_eq!(dy[i].to_bits(), y[i].to_bits());
                }
            }
            other => panic!("unexpected request {:?}", other),
        }
        // A flagless call passes the payload through untouched.
        let plain = encode_dot(42, &x, &y);
        let phead: [u8; HEADER_LEN] = plain[..HEADER_LEN].try_into().unwrap();
        let through = verify_crc(&phead, 0, &plain[HEADER_LEN..]).unwrap();
        assert_eq!(through.len(), plain.len() - HEADER_LEN);
    }

    #[test]
    fn crc_trailer_truncation_and_bit_flips_detected() {
        let mut frame = encode_sum(7, &[1.0, 2.0, 4.0]);
        seal_crc(&mut frame);
        let head: [u8; HEADER_LEN] = frame[..HEADER_LEN].try_into().unwrap();
        let payload = &frame[HEADER_LEN..];
        // A payload shorter than its 4-byte trailer is the typed corrupt
        // frame, never a panic.
        for cut in 0..CRC_TRAILER_LEN {
            assert_eq!(
                verify_crc(&head, FLAG_CRC, &payload[..cut]).unwrap_err().code,
                ErrorCode::CorruptFrame,
                "trailer cut to {cut} bytes"
            );
        }
        // Every single-bit flip in the payload (operand bytes and trailer
        // alike) is detected — CRC32C has Hamming distance >= 2 at any
        // length this protocol allows.
        for byte in 0..payload.len() {
            for bit in 0..8 {
                let mut damaged = payload.to_vec();
                damaged[byte] ^= 1 << bit;
                assert_eq!(
                    verify_crc(&head, FLAG_CRC, &damaged).unwrap_err().code,
                    ErrorCode::CorruptFrame,
                    "flip at byte {byte} bit {bit} must not verify"
                );
            }
        }
        // Header damage is detected too: the checksum covers all 20
        // header bytes as sent (here, the request id).
        let mut bad_head = head;
        bad_head[8] ^= 0x01;
        assert_eq!(
            verify_crc(&bad_head, FLAG_CRC, payload).unwrap_err().code,
            ErrorCode::CorruptFrame
        );
    }

    #[test]
    fn errbound_result_round_trips_and_boundless_bytes_are_rev10() {
        let bounded = WireResult {
            value: 11.0,
            n: 2,
            path: ExecPath::Fused,
            err_bound: Some(3.5e-15),
        };
        let frame = encode_result(77, &bounded);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_ERRBOUND);
        assert_eq!(header.payload_len, 25, "17-byte body + 8-byte bound");
        match decode_response_flagged(header.flags, Opcode::Result, payload).expect("decodes") {
            Response::Result(r) => {
                assert_eq!(r.value.to_bits(), bounded.value.to_bits());
                assert_eq!(r.err_bound.unwrap().to_bits(), 3.5e-15f64.to_bits());
            }
            other => panic!("unexpected response {:?}", other),
        }
        // Without a bound the frame is byte-identical to revision 1.0.
        let plain = WireResult { err_bound: None, ..bounded };
        let frame = encode_result(77, &plain);
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, 0);
        assert_eq!(header.payload_len, 17);
        match decode_response(Opcode::Result, payload).expect("decodes") {
            Response::Result(r) => assert_eq!(r.err_bound, None),
            other => panic!("unexpected response {:?}", other),
        }
        // A flagless decode of a bounded payload trips the
        // exact-consumption rule instead of misreading the bound.
        let bounded_frame = encode_result(78, &bounded);
        assert!(decode_response(Opcode::Result, &bounded_frame[HEADER_LEN..]).is_err());
    }

    #[test]
    fn scrub_stats_extension_round_trips_and_requires_cache() {
        let stats = WireStats { queue_depth: 64, threads: 2, ..WireStats::default() };
        let cache = WireCacheStats { cache_lookups: 10, cache_hits: 4, cache_misses: 6, ..WireCacheStats::default() };
        let scrub = WireScrubStats {
            scrub_verified: 12,
            scrub_quarantined: 1,
            scrub_passes: 3,
            cache_verified: 4,
            cache_poisoned: 1,
        };
        let frame = encode_stats_result_ext(91, &stats, None, Some(&cache), Some(&scrub));
        let (header, payload) = split(&frame);
        assert_eq!(header.flags, FLAG_CACHE | FLAG_SCRUB);
        match decode_response_flagged(header.flags, Opcode::StatsResult, payload)
            .expect("decodes")
        {
            Response::CacheStats { cache: c, scrub: s, .. } => {
                assert_eq!(c, cache);
                assert_eq!(s, Some(scrub));
            }
            other => panic!("unexpected response {:?}", other),
        }
        // The scrub extension without the cache extension is malformed —
        // its fields extend the cache block (PROTOCOL.md §3.7).
        assert_eq!(
            decode_response_flagged(FLAG_SCRUB, Opcode::StatsResult, payload)
                .unwrap_err()
                .code,
            ErrorCode::Malformed
        );
        // The request-side helper sets both bits.
        let probe = encode_stats_scrub(92, None);
        let (header, _) = split(&probe);
        assert_eq!(header.flags, FLAG_CACHE | FLAG_SCRUB);
    }
}
