//! The batching-vs-sharding crossover, derived from the multicore
//! saturation model.
//!
//! Under heavy independent traffic the serving layer has two ways to use
//! `T` workers on one request:
//!
//! * **batch** it — run the whole request serially on one worker while the
//!   other workers run *other* requests (perfect parallelism, zero
//!   synchronization on the request's critical path);
//! * **shard** it — split it into the pool's cache-line-aligned partition
//!   and reduce the partials (lower latency for *this* request, but one
//!   dispatch+latch round trip and, per Hofmann et al.'s saturation
//!   analysis, a sub-linear speedup once the chip's memory bandwidth
//!   saturates).
//!
//! Sharding a request of `n` updates takes roughly `n / (s·p1) + o` where
//! `p1` is the single-core in-memory throughput (GUP/s = updates/ns), `s`
//! the model speedup at `T` workers ([`sim::multicore::scaling_curve`],
//! anchored on `p1`) and `o` the dispatch overhead; running it whole takes
//! `n / p1`. Sharding therefore wins only past
//!
//! ```text
//! n* = o · p1 · s / (s − 1)
//! ```
//!
//! and `n*` grows without bound as `s → 1` — exactly the paper's point
//! that past saturation more cores add nothing, so a saturated chip should
//! spend extra workers on *more requests*, not more shards. The service
//! uses [`service_crossover`] as its default threshold; callers can
//! override it per service ([`crate::serve::ServeConfig`]).

use std::time::Instant;

use crate::arch::Machine;
use crate::ecm::{self, MemLevel};
use crate::harness::scaleexp;
use crate::runtime::arena::AlignedVec;
use crate::runtime::backend::{KernelInput, KernelSpec};
use crate::runtime::parallel::CACHELINE_F64;
use crate::sim::{self, MeasureOpts};
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use crate::util::units::{Precision, MIB};

use super::DotService;

/// Default cost of one sharded dispatch (per-worker channel sends, the
/// completion latch, the tree reduction) in nanoseconds. Order of
/// magnitude, not a measurement — the crossover depends on it only
/// linearly, and services can override the derived threshold outright.
pub const DEFAULT_DISPATCH_OVERHEAD_NS: f64 = 10_000.0;

/// Working-set size used to anchor the model's single-core in-memory
/// throughput: far past any cache on the modeled machines.
const IN_MEMORY_WS: u64 = 256 * MIB;

/// Model-predicted single-core in-memory throughput in GUP/s for `spec` on
/// machine `m` — the anchor the saturation model scales from when no live
/// measurement is available. `None` when the kernel has no model analog
/// (the sum kernels).
pub fn model_p1_gups(m: &Machine, spec: KernelSpec) -> Option<f64> {
    let v = scaleexp::variant_for(spec)?;
    let k = ecm::derive::kernel_for(m, v, Precision::Dp, MemLevel::Mem);
    let pts = sim::sweep(m, &k, &[IN_MEMORY_WS], &MeasureOpts::default());
    pts.first().map(|p| p.gups)
}

/// The batch-vs-shard crossover length `n*` for `spec` on machine `m` with
/// `threads` workers, anchored on `p1_gups` (see the module docs).
/// Returns `usize::MAX` ("never shard") when sharding cannot pay: a single
/// worker, no model analog, or a saturation speedup of ≤ 1.
pub fn model_crossover(
    m: &Machine,
    spec: KernelSpec,
    threads: usize,
    p1_gups: f64,
    dispatch_overhead_ns: f64,
) -> usize {
    if threads <= 1 || p1_gups <= 0.0 {
        return usize::MAX;
    }
    let Some(v) = scaleexp::variant_for(spec) else {
        return usize::MAX;
    };
    let k = ecm::derive::kernel_for(m, v, Precision::Dp, MemLevel::Mem);
    let curve = sim::multicore::scaling_curve(m, &k, p1_gups, &MeasureOpts::default());
    let idx = threads.min(curve.len());
    if idx == 0 {
        return usize::MAX;
    }
    let speedup = curve[idx - 1].1 / p1_gups;
    if speedup <= 1.0 + 1e-9 {
        return usize::MAX;
    }
    let n_star = dispatch_overhead_ns * p1_gups * speedup / (speedup - 1.0);
    if !n_star.is_finite() || n_star >= usize::MAX as f64 / 2.0 {
        return usize::MAX;
    }
    // Round up to a cache-line multiple and floor at one line per worker,
    // so a sharded request always hands every worker at least one chunk.
    let n = (n_star.ceil() as usize).max(threads * CACHELINE_F64);
    (n + CACHELINE_F64 - 1) / CACHELINE_F64 * CACHELINE_F64
}

/// The service-default crossover: the generic HOST machine model pinned to
/// `threads` workers and the detected clock, anchored on the *model's own*
/// single-core in-memory prediction for `spec` — fully deterministic, no
/// measurement required at service construction.
pub fn service_crossover(spec: KernelSpec, threads: usize, freq_ghz: f64) -> usize {
    let m = scaleexp::host_model(freq_ghz, threads as u32);
    match model_p1_gups(&m, spec) {
        Some(p1) => model_crossover(&m, spec, threads, p1, DEFAULT_DISPATCH_OVERHEAD_NS),
        None => usize::MAX,
    }
}

/// A host calibration of the crossover inputs: the *measured*
/// single-thread throughput and per-dispatch overhead next to the model's
/// own anchors, and the crossover `n*` each pair implies. `serve-bench
/// --calibrate` records both sides in `BENCH_serving.json` so the model's
/// prediction can be audited against the host it claims to describe.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Measured single-thread in-memory throughput, GUP/s (updates/ns).
    pub p1_gups: f64,
    /// The same measurement as MFlop/s for the served kernel class.
    pub p1_mflops: f64,
    /// Measured cost of one sharded dispatch over the service's own pool
    /// (channel posts + latch round trip + tree reduction), ns.
    pub dispatch_overhead_ns: f64,
    /// Crossover implied by the measured pair (`usize::MAX` = never
    /// shard — e.g. a single worker, or no measured speedup).
    pub measured_crossover: usize,
    /// The model's p1 anchor for the same spec (`None` for kernels
    /// without a model analog).
    pub model_p1_gups: Option<f64>,
    /// Crossover implied by the model pair (what [`service_crossover`]
    /// would pick).
    pub model_crossover: usize,
    /// Operand length the p1 measurement streamed.
    pub p1_n: usize,
}

/// Time one execution of `f` in ns (monotonic clock).
fn time_ns<R>(f: impl FnOnce() -> R) -> f64 {
    let t0 = Instant::now();
    let r = f();
    std::hint::black_box(&r);
    t0.elapsed().as_nanos() as f64
}

fn median_of(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN timing"));
    percentile_sorted(&v, 50.0)
}

/// Measure the crossover inputs on this host, using the service's own
/// resolved dot kernel and pool (so the overhead includes exactly the
/// dispatch machinery a sharded request pays). Deterministic operands
/// (fixed seed); timing is the only nondeterminism, as in every bench.
///
/// * `p1`: serial dot over an in-memory-sized operand pair, best of a few
///   reps (minimum — the standard "least interference" estimator). Quick
///   mode streams a 32 MiB working set (vs 64 MiB in full mode) — past
///   the L3 of typical hosts, but on a large-cache machine a quick p1 can
///   still carry some cache residency; full mode is the authoritative
///   calibration, quick is the CI smoke.
/// * `dispatch overhead`: median sharded-path time minus median serial
///   time over a tiny cache-resident input, floored at 1 ns (on a noisy
///   host the difference can go negative; the crossover only needs a
///   scale, and callers see the raw value recorded in the artifact).
///
/// The measured crossover then reuses the *same* `n* = o·p1·s/(s−1)`
/// formula as the model path, swapping in measured `o` and `p1`.
pub fn calibrate(service: &DotService, freq_ghz: f64, quick: bool) -> Calibration {
    let threads = service.threads();
    let spec = service.dot_spec();
    let (p1_n, p1_reps, oh_reps) = if quick {
        (1usize << 21, 3usize, 33usize)
    } else {
        (1usize << 22, 5, 101)
    };
    let mut rng = Rng::new(0xCA11B);
    let x = AlignedVec::from_fn(p1_n, |_| rng.normal());
    let y = AlignedVec::from_fn(p1_n, |_| rng.normal());
    let serial = |x: &[f64], y: &[f64]| service.run_serial(&KernelInput::Dot(x, y));
    // Warm up (page faults, clock ramp), then take the fastest rep.
    serial(&x, &y);
    let mut best = f64::INFINITY;
    for _ in 0..p1_reps {
        best = best.min(time_ns(|| serial(&x, &y)));
    }
    let p1_gups = p1_n as f64 / best.max(1.0);
    let p1_mflops = p1_gups * spec.class.flops_per_update() as f64 * 1000.0;

    // Dispatch overhead: tiny input, so kernel time is negligible against
    // the posting + latch + reduce machinery the sharded path adds.
    let oh_n = (threads * CACHELINE_F64).max(CACHELINE_F64);
    let input = KernelInput::Dot(&x[..oh_n], &y[..oh_n]);
    service.run_sharded(&input);
    let sharded_ns =
        median_of((0..oh_reps).map(|_| time_ns(|| service.run_sharded(&input))).collect());
    let serial_ns =
        median_of((0..oh_reps).map(|_| time_ns(|| service.run_serial(&input))).collect());
    let dispatch_overhead_ns = (sharded_ns - serial_ns).max(1.0);

    let m = scaleexp::host_model(freq_ghz, threads as u32);
    let measured_crossover = model_crossover(&m, spec, threads, p1_gups, dispatch_overhead_ns);
    let model_p1 = model_p1_gups(&m, spec);
    let model_cross = match model_p1 {
        Some(p1) => model_crossover(&m, spec, threads, p1, DEFAULT_DISPATCH_OVERHEAD_NS),
        None => usize::MAX,
    };
    Calibration {
        p1_gups,
        p1_mflops,
        dispatch_overhead_ns,
        measured_crossover,
        model_p1_gups: model_p1,
        model_crossover: model_cross,
        p1_n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::{ImplStyle, KernelClass};

    fn kahan_simd() -> KernelSpec {
        KernelSpec::new(KernelClass::KahanDot, ImplStyle::SimdLanes)
    }

    #[test]
    fn single_worker_never_shards() {
        let m = scaleexp::host_model(3.0, 1);
        assert_eq!(model_crossover(&m, kahan_simd(), 1, 1.0, 1e4), usize::MAX);
        assert_eq!(service_crossover(kahan_simd(), 1, 3.0), usize::MAX);
    }

    #[test]
    fn sum_kernels_have_no_model_analog() {
        let spec = KernelSpec::new(KernelClass::KahanSum, ImplStyle::SimdLanes);
        let m = scaleexp::host_model(3.0, 4);
        assert_eq!(model_p1_gups(&m, spec), None);
        assert_eq!(service_crossover(spec, 4, 3.0), usize::MAX);
    }

    #[test]
    fn crossover_is_aligned_and_scales_with_overhead() {
        let m = scaleexp::host_model(3.0, 4);
        let p1 = model_p1_gups(&m, kahan_simd()).unwrap();
        assert!(p1 > 0.0);
        let lo = model_crossover(&m, kahan_simd(), 4, p1, 1_000.0);
        let hi = model_crossover(&m, kahan_simd(), 4, p1, 100_000.0);
        assert!(lo < usize::MAX && hi < usize::MAX);
        assert_eq!(lo % CACHELINE_F64, 0);
        assert_eq!(hi % CACHELINE_F64, 0);
        // 100x the dispatch overhead must push the crossover out ~100x.
        assert!(hi > 20 * lo, "lo={lo} hi={hi}");
        assert!(lo >= 4 * CACHELINE_F64);
    }

    #[test]
    fn calibration_measures_sane_values() {
        use crate::serve::{ServeConfig, ThresholdMode};
        let service = DotService::new(ServeConfig {
            threads: 2,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(1024),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        })
        .unwrap();
        let c = calibrate(&service, 3.0, true);
        assert!(c.p1_gups > 0.0 && c.p1_gups.is_finite(), "{c:?}");
        assert!(c.p1_mflops > c.p1_gups, "5 flops/update: {c:?}");
        assert!(c.dispatch_overhead_ns >= 1.0, "{c:?}");
        if c.measured_crossover != usize::MAX {
            assert_eq!(c.measured_crossover % CACHELINE_F64, 0, "{c:?}");
            assert!(c.measured_crossover >= 2 * CACHELINE_F64, "{c:?}");
        }
        // The model side mirrors what the service default would pick.
        assert_eq!(c.model_crossover, service_crossover(kahan_simd(), 2, 3.0));
    }

    #[test]
    fn service_default_is_plausible() {
        // On the generic HOST model the crossover sits in the tens of
        // thousands of elements: far above a cache-resident small request,
        // far below the deep-memory sizes the scaling benches use.
        let n = service_crossover(kahan_simd(), 4, 3.0);
        assert!(n > 1024 && n < 1 << 24, "crossover {n}");
    }
}
