//! Deterministic, seeded fault injection for the serving stack.
//!
//! Production failure handling is only trustworthy if every failure path can
//! be exercised on demand and *replayed exactly*. This module provides that
//! lever: a [`FaultPlan`] is a list of site-keyed, trigger-counted fault
//! points ("the 3rd job executed by the pool panics", "the 2nd socket write
//! on this server fails"), and a [`FaultInjector`] is the cheap runtime form
//! threaded through the pool, dispatcher, and wire server as an
//! `Option<Arc<FaultInjector>>`.
//!
//! Design rules:
//!
//! * **Zero cost when absent.** Every injection site is a single
//!   `if let Some(inj) = faults { ... }` null check; production builds pass
//!   `None` and take no other branch.
//! * **Deterministic.** Each site keeps an atomic arrival counter; a fault
//!   point fires when the site's arrival ordinal matches its `trigger`.
//!   Given the same plan and the same (single-consumer) arrival order, a
//!   chaos run replays exactly. [`FaultPlan::seeded`] derives a plan from a
//!   `u64` seed via the repo's own deterministic [`Rng`], so chaos tests and
//!   `serve-bench --chaos` are reproducible from one number.
//! * **Observable.** The injector counts every fault it actually fired, per
//!   site; [`FaultInjector::fired`] snapshots feed the `chaos` block of
//!   `BENCH_serving.json` and the chaos-matrix tests.
//!
//! The sites themselves live in the code they perturb:
//! `runtime/parallel.rs` (worker panic, latch-wake delay),
//! `serve/queue.rs` (dispatcher stall, quota-admission reject,
//! weighted-fair starvation stall, store bit-flip, cache poison), and
//! `serve/net.rs` (socket read/write errors, truncated frames,
//! connection drops, slow-client writer stalls, frame-CRC corruption).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::rng::Rng;

/// Where in the serving stack a fault point injects.
///
/// Each variant names one *instrumented site*; the matching production code
/// consults the injector at exactly that point. The doc comment of each
/// variant states the observable degradation the rest of the stack must
/// provide (and that the chaos tests pin).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// A pool worker panics while executing a job, and the worker thread
    /// exits. The owning dispatch fails with a typed worker-panic error;
    /// the pool respawns the worker (same slot index, same logical
    /// partition) before the next dispatch.
    WorkerPanic,
    /// The serve dispatcher stalls for [`FaultPoint::delay`] before draining
    /// the queue — models a descheduled dispatcher thread. Requests queue up
    /// behind backpressure; deadline-bearing requests may be shed.
    DispatcherStall,
    /// A worker sleeps for [`FaultPoint::delay`] after finishing its chunk
    /// but before arriving at the completion latch — models a lost/late
    /// wakeup. Callers see latency, never a hang.
    LatchWakeDelay,
    /// The server-side connection reader fails with an I/O error before the
    /// next frame. The connection closes; in-flight responses are still
    /// resolved server-side and discarded by the writer.
    SocketReadError,
    /// The server-side connection writer fails with an I/O error mid-stream.
    /// The connection closes; the client observes EOF or a torn stream.
    SocketWriteError,
    /// The server writes only a prefix of a response frame, then drops the
    /// connection — the client must detect the torn frame as an error, not
    /// hang.
    TruncatedFrame,
    /// The server drops the whole connection while a batch is in flight.
    /// Every admitted request still resolves server-side (tickets are
    /// drop-safe); the client sees EOF.
    ConnDropMidBatch,
    /// The connection writer stalls for [`FaultPoint::delay`] before writing
    /// — models a slow client that stops draining its socket. Bounded writer
    /// queues plus write timeouts must evict the connection instead of
    /// wedging the reader.
    SlowClientWriter,
    /// The QoS admission check rejects a request as if its tenant were at
    /// quota, even though it is not — models a mis-sized or racing quota.
    /// The submitter sees the typed quota error exactly as a real shed;
    /// nothing enters the queue and no compute runs.
    QuotaAdmissionReject,
    /// The weighted-fair dispatcher stalls for [`FaultPoint::delay`] before
    /// selecting the next deficit-round-robin batch — models a scheduling
    /// hiccup that delays every backlogged tenant equally. Requests queue
    /// behind backpressure; deadline-bearing requests may be shed, but no
    /// tenant is starved and nothing hangs.
    StarvationStall,
    /// A resident operand's buffer has one bit flipped in place (digest
    /// unchanged) at handle admission — models silent memory corruption of
    /// stored data. The store scrubber must detect the mismatch, quarantine
    /// the entry, and fail the request with the typed corrupt-operand
    /// error; the corrupted bytes are never served.
    StoreBitFlip,
    /// A response frame's CRC32C trailer has one bit flipped after sealing
    /// — models wire corruption between server and client. The client-side
    /// CRC check must reject the frame as corrupt instead of delivering
    /// the payload.
    FrameCrcCorrupt,
    /// A memoized result-cache entry has the low bit of its IEEE-754
    /// pattern flipped at insertion — models cache-memory rot. The
    /// verify-on-hit policy must catch the mismatch on the next sampled
    /// hit, evict the entry, and fall through to recompute; the poisoned
    /// bits are never delivered.
    CachePoison,
}

impl FaultSite {
    /// Every instrumented site, in a stable order (used by seeded plans and
    /// the bench chaos block).
    pub const ALL: [FaultSite; 13] = [
        FaultSite::WorkerPanic,
        FaultSite::DispatcherStall,
        FaultSite::LatchWakeDelay,
        FaultSite::SocketReadError,
        FaultSite::SocketWriteError,
        FaultSite::TruncatedFrame,
        FaultSite::ConnDropMidBatch,
        FaultSite::SlowClientWriter,
        FaultSite::QuotaAdmissionReject,
        FaultSite::StarvationStall,
        FaultSite::StoreBitFlip,
        FaultSite::FrameCrcCorrupt,
        FaultSite::CachePoison,
    ];

    /// Sites exercised by the in-process chaos scenario (no socket).
    /// Quota rejects arm at every admission check; starvation stalls arm
    /// only when a QoS policy puts the dispatcher in weighted-fair mode
    /// (the chaos bench therefore always runs with a tenant policy).
    pub const IN_PROCESS: [FaultSite; 5] = [
        FaultSite::WorkerPanic,
        FaultSite::DispatcherStall,
        FaultSite::LatchWakeDelay,
        FaultSite::QuotaAdmissionReject,
        FaultSite::StarvationStall,
    ];

    /// The corruption sites exercised by the integrity scenario — one per
    /// defense layer (store scrub, frame CRC, verify-on-hit). Kept out of
    /// [`FaultSite::IN_PROCESS`] deliberately: corruption is only a safe
    /// thing to inject where the matching detector is armed, and the
    /// integrity scenario is the run that arms all three.
    pub const INTEGRITY: [FaultSite; 3] = [
        FaultSite::StoreBitFlip,
        FaultSite::FrameCrcCorrupt,
        FaultSite::CachePoison,
    ];

    /// Stable snake_case label (JSON keys in the bench chaos block).
    pub fn label(self) -> &'static str {
        match self {
            FaultSite::WorkerPanic => "worker_panic",
            FaultSite::DispatcherStall => "dispatcher_stall",
            FaultSite::LatchWakeDelay => "latch_wake_delay",
            FaultSite::SocketReadError => "socket_read_error",
            FaultSite::SocketWriteError => "socket_write_error",
            FaultSite::TruncatedFrame => "truncated_frame",
            FaultSite::ConnDropMidBatch => "conn_drop_mid_batch",
            FaultSite::SlowClientWriter => "slow_client_writer",
            FaultSite::QuotaAdmissionReject => "quota_admission_reject",
            FaultSite::StarvationStall => "starvation_stall",
            FaultSite::StoreBitFlip => "store_bit_flip",
            FaultSite::FrameCrcCorrupt => "frame_crc_corrupt",
            FaultSite::CachePoison => "cache_poison",
        }
    }

    fn index(self) -> usize {
        FaultSite::ALL.iter().position(|s| *s == self).unwrap()
    }

    /// Whether this site's fault is a timed stall (carries a delay) rather
    /// than an induced failure.
    pub fn is_stall(self) -> bool {
        matches!(
            self,
            FaultSite::DispatcherStall
                | FaultSite::LatchWakeDelay
                | FaultSite::SlowClientWriter
                | FaultSite::StarvationStall
        )
    }
}

/// One scheduled fault: at the `trigger`-th arrival (1-based) at `site`,
/// inject; stall sites sleep for `delay`, failure sites fail.
#[derive(Clone, Copy, Debug)]
pub struct FaultPoint {
    /// The instrumented site this point arms.
    pub site: FaultSite,
    /// 1-based arrival ordinal at the site on which the fault fires.
    pub trigger: u64,
    /// Stall duration for stall sites; ignored by failure sites.
    pub delay: Duration,
}

/// A reproducible schedule of fault points.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    points: Vec<FaultPoint>,
}

impl FaultPlan {
    /// An empty plan: an injector built from it never fires. Used by the
    /// parity tests proving that a compiled-in-but-idle injector is
    /// bit-identical to no injector at all.
    pub fn none() -> Self {
        FaultPlan { points: Vec::new() }
    }

    /// Arm `site` to fail at its `trigger`-th arrival (1-based).
    pub fn with(mut self, site: FaultSite, trigger: u64) -> Self {
        self.points.push(FaultPoint {
            site,
            trigger,
            delay: Duration::from_millis(1),
        });
        self
    }

    /// Arm a stall of `delay` at the `trigger`-th arrival at `site`.
    pub fn with_stall(mut self, site: FaultSite, trigger: u64, delay: Duration) -> Self {
        self.points.push(FaultPoint {
            site,
            trigger,
            delay,
        });
        self
    }

    /// Derive a deterministic plan from a seed: every site in `sites` gets
    /// one fault point with a pseudo-random trigger in `1..=spread` (and a
    /// small pseudo-random stall delay for stall sites). Same seed, same
    /// plan — byte for byte.
    pub fn seeded(seed: u64, sites: &[FaultSite], spread: u64) -> Self {
        let spread = spread.max(1);
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let mut plan = FaultPlan::none();
        for &site in sites {
            let trigger = rng.next_u64() % spread + 1;
            let delay = Duration::from_micros(200 + rng.next_u64() % 800);
            plan.points.push(FaultPoint {
                site,
                trigger,
                delay,
            });
        }
        plan
    }

    /// The scheduled points, in insertion order.
    pub fn points(&self) -> &[FaultPoint] {
        &self.points
    }

    /// True if no site is armed.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Runtime form of a [`FaultPlan`]: per-site atomic arrival counters plus
/// per-site fired counters. Shared as `Option<Arc<FaultInjector>>`;
/// `None` is the production path.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    arrivals: [AtomicU64; 13],
    fired: [AtomicU64; 13],
}

impl FaultInjector {
    /// Build an injector for a plan.
    pub fn new(plan: FaultPlan) -> Arc<Self> {
        Arc::new(FaultInjector {
            plan,
            arrivals: Default::default(),
            fired: Default::default(),
        })
    }

    /// Record one arrival at `site`; returns `Some(point)` if a scheduled
    /// fault fires on this arrival. Failure sites use the returned point as
    /// a yes/no; stall sites read its `delay`.
    pub fn arm(&self, site: FaultSite) -> Option<FaultPoint> {
        let idx = site.index();
        let nth = self.arrivals[idx].fetch_add(1, Ordering::Relaxed) + 1;
        let hit = self
            .plan
            .points
            .iter()
            .find(|p| p.site == site && p.trigger == nth)
            .copied();
        if hit.is_some() {
            self.fired[idx].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Convenience for failure sites: did a fault fire on this arrival?
    pub fn fire(&self, site: FaultSite) -> bool {
        self.arm(site).is_some()
    }

    /// Convenience for stall sites: the stall to apply on this arrival, if
    /// one fired.
    pub fn stall(&self, site: FaultSite) -> Option<Duration> {
        self.arm(site).map(|p| p.delay)
    }

    /// How many faults actually fired at `site` so far.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.fired[site.index()].load(Ordering::Relaxed)
    }

    /// Total faults fired across all sites.
    pub fn total_fired(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.fired(s)).sum()
    }

    /// How many arrivals `site` has seen (fired or not).
    pub fn arrivals(&self, site: FaultSite) -> u64 {
        self.arrivals[site.index()].load(Ordering::Relaxed)
    }

    /// The plan this injector replays.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trigger_counting_fires_exactly_once_at_the_nth_arrival() {
        let inj = FaultInjector::new(FaultPlan::none().with(FaultSite::WorkerPanic, 3));
        assert!(!inj.fire(FaultSite::WorkerPanic));
        assert!(!inj.fire(FaultSite::WorkerPanic));
        assert!(inj.fire(FaultSite::WorkerPanic));
        assert!(!inj.fire(FaultSite::WorkerPanic));
        assert_eq!(inj.fired(FaultSite::WorkerPanic), 1);
        assert_eq!(inj.arrivals(FaultSite::WorkerPanic), 4);
        assert_eq!(inj.total_fired(), 1);
    }

    #[test]
    fn sites_count_independently() {
        let plan = FaultPlan::none()
            .with(FaultSite::SocketReadError, 1)
            .with(FaultSite::SocketWriteError, 2);
        let inj = FaultInjector::new(plan);
        assert!(inj.fire(FaultSite::SocketReadError));
        assert!(!inj.fire(FaultSite::SocketWriteError));
        assert!(inj.fire(FaultSite::SocketWriteError));
        assert_eq!(inj.fired(FaultSite::SocketReadError), 1);
        assert_eq!(inj.fired(FaultSite::SocketWriteError), 1);
    }

    #[test]
    fn stall_sites_return_their_delay() {
        let d = Duration::from_micros(1234);
        let inj = FaultInjector::new(FaultPlan::none().with_stall(
            FaultSite::DispatcherStall,
            1,
            d,
        ));
        assert_eq!(inj.stall(FaultSite::DispatcherStall), Some(d));
        assert_eq!(inj.stall(FaultSite::DispatcherStall), None);
    }

    #[test]
    fn seeded_plans_are_reproducible_and_cover_requested_sites() {
        let a = FaultPlan::seeded(42, &FaultSite::ALL, 16);
        let b = FaultPlan::seeded(42, &FaultSite::ALL, 16);
        assert_eq!(a.points().len(), FaultSite::ALL.len());
        for (pa, pb) in a.points().iter().zip(b.points()) {
            assert_eq!(pa.site, pb.site);
            assert_eq!(pa.trigger, pb.trigger);
            assert_eq!(pa.delay, pb.delay);
            assert!((1..=16).contains(&pa.trigger));
        }
        let c = FaultPlan::seeded(43, &FaultSite::ALL, 16);
        assert!(
            a.points()
                .iter()
                .zip(c.points())
                .any(|(pa, pc)| pa.trigger != pc.trigger || pa.delay != pc.delay),
            "different seeds should produce different plans"
        );
    }

    #[test]
    fn empty_plan_never_fires() {
        let inj = FaultInjector::new(FaultPlan::none());
        for &site in &FaultSite::ALL {
            assert!(!inj.fire(site));
            assert!(inj.stall(site).is_none());
        }
        assert_eq!(inj.total_fired(), 0);
    }

    #[test]
    fn labels_are_stable_and_unique() {
        let mut seen = std::collections::HashSet::new();
        for &site in &FaultSite::ALL {
            assert!(seen.insert(site.label()), "duplicate label {}", site.label());
        }
        assert!(!FaultSite::WorkerPanic.is_stall());
        assert!(FaultSite::DispatcherStall.is_stall());
    }

    #[test]
    fn integrity_sites_are_failures_outside_the_in_process_set() {
        // Corruption is only safe to inject where the matching detector is
        // armed; the plain chaos scenarios (IN_PROCESS) must never fire an
        // undetectable bit flip.
        for &site in &FaultSite::INTEGRITY {
            assert!(FaultSite::ALL.contains(&site));
            assert!(!FaultSite::IN_PROCESS.contains(&site));
            assert!(!site.is_stall(), "corruption sites are failure-typed");
        }
        assert_eq!(FaultSite::StoreBitFlip.label(), "store_bit_flip");
        assert_eq!(FaultSite::FrameCrcCorrupt.label(), "frame_crc_corrupt");
        assert_eq!(FaultSite::CachePoison.label(), "cache_poison");
    }
}
