//! Load generation for the serving layer: request-size mixtures, open- and
//! closed-loop arrival models, latency percentiles and aggregate
//! throughput — the measurement engine behind `serve-bench` and the
//! `serve` experiment.
//!
//! * **Closed loop**: a fixed number of outstanding requests — each
//!   arrival batch is submitted when the previous one completes, and every
//!   request's latency is its batch's service time. This measures the
//!   service at its own pace (no queueing term).
//! * **Open loop**: requests arrive on a virtual clock at a fixed rate,
//!   independent of service progress; a batch is dispatched once its last
//!   request has arrived, and latency runs from a request's *arrival* to
//!   its batch's completion — so an underprovisioned service shows the
//!   queueing blow-up a closed loop hides (the classical coordinated-
//!   omission argument).
//! * **Open loop, queued** ([`run_load_async`]): the same request stream
//!   driven through the [`AsyncDotService`] submission queue in *real*
//!   time — the generator paces arrivals on the wall clock and latency is
//!   measured from each request's scheduled arrival to its ticket's
//!   completion, so p50/p90/p99 are actual queueing + service latency
//!   (backpressure included), not a model. This is the measurement the
//!   virtual-clock open loop only approximates.
//!
//! All requests are dot products (the service's headline class); operand
//! buffers are allocated once per distinct mixture size from the 64-byte
//! arena and first-touched by the service's own workers, so the sharded
//! path streams NUMA-local pages exactly like the measurement stack.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::arena::AlignedVec;
use crate::runtime::backend::{BackendError, KernelInput};
use crate::runtime::parallel::ThreadPool;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;

use super::codec::{self, ErrorCode, Opcode, Response, WireCacheStats, WireScrubStats, HEADER_LEN};
use super::faults::{FaultInjector, FaultPlan, FaultSite};
use super::net::{is_timeout, NetOptions, NetServer, WireCallError, WireClient};
use super::queue::{AsyncDotService, AsyncOptions, TrySubmit};
use super::scheduler::ExecPath;
use super::{DotService, ServeConfig, SharedInput};

/// One component of a request-size mixture.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MixEntry {
    /// Request length (updates).
    pub n: usize,
    /// Relative sampling weight (> 0; weights need not sum to 1).
    pub weight: f64,
}

/// Parse a mixture spec: comma-separated `n:weight` entries (bare `n`
/// means weight 1), e.g. `1024:0.9,1048576:0.1`.
pub fn parse_mix(s: &str) -> Result<Vec<MixEntry>, String> {
    let mut v = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (n_str, w_str) = match part.split_once(':') {
            Some((n, w)) => (n, w),
            None => (part, "1"),
        };
        let n: usize = n_str
            .trim()
            .parse()
            .map_err(|_| format!("bad size '{n_str}' in mix entry '{part}'"))?;
        let weight: f64 = w_str
            .trim()
            .parse()
            .map_err(|_| format!("bad weight '{w_str}' in mix entry '{part}'"))?;
        if n == 0 {
            return Err(format!("mix size must be >= 1 in '{part}'"));
        }
        if weight <= 0.0 || !weight.is_finite() {
            return Err(format!("mix weight must be positive in '{part}'"));
        }
        v.push(MixEntry { n, weight });
    }
    if v.is_empty() {
        return Err("empty request mixture".to_string());
    }
    Ok(v)
}

/// The default serving mixture: mostly small cache-resident requests, a
/// tail of in-memory ones, and (full mode) an occasional huge request that
/// crosses the shard threshold.
pub fn default_mix(quick: bool) -> Vec<MixEntry> {
    if quick {
        vec![
            MixEntry { n: 1024, weight: 0.6 },
            MixEntry { n: 16384, weight: 0.3 },
            MixEntry { n: 262144, weight: 0.1 },
        ]
    } else {
        vec![
            MixEntry { n: 1024, weight: 0.35 },
            MixEntry { n: 16384, weight: 0.45 },
            MixEntry { n: 262144, weight: 0.15 },
            MixEntry { n: 4194304, weight: 0.05 },
        ]
    }
}

/// Deterministic weighted size sequence for `count` requests.
pub fn sample_sizes(mix: &[MixEntry], count: usize, seed: u64) -> Vec<usize> {
    assert!(!mix.is_empty(), "sample_sizes on an empty mixture");
    let total: f64 = mix.iter().map(|e| e.weight).sum();
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let mut t = rng.f64() * total;
        let mut pick = mix[mix.len() - 1].n;
        for e in mix {
            t -= e.weight;
            if t < 0.0 {
                pick = e.n;
                break;
            }
        }
        out.push(pick);
    }
    out
}

/// One aligned operand pair per distinct mixture size, generated
/// deterministically from the seed and first-touched by `pool`'s workers
/// (requests of the same size share operands — the load generator measures
/// scheduling and kernels, not allocator traffic). Buffers are
/// `Arc`-shared so the asynchronous path can carry them across the
/// submission queue without copying ([`Self::shared_dot`]).
pub struct OperandPool {
    bufs: Vec<(usize, Arc<AlignedVec>, Arc<AlignedVec>)>,
}

impl OperandPool {
    /// Generate one deterministic operand pair per distinct mixture size,
    /// first-touched by `pool`'s workers (see the type docs).
    pub fn generate(mix: &[MixEntry], seed: u64, pool: &ThreadPool) -> Self {
        let mut sizes: Vec<usize> = mix.iter().map(|e| e.n).collect();
        sizes.sort_unstable();
        sizes.dedup();
        let mut rng = Rng::new(seed ^ 0x5E57E);
        let mut bufs = Vec::with_capacity(sizes.len());
        for n in sizes {
            let src_x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let src_y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = Arc::new(AlignedVec::first_touch_copy(&src_x, pool));
            let y = Arc::new(AlignedVec::first_touch_copy(&src_y, pool));
            bufs.push((n, x, y));
        }
        Self { bufs }
    }

    fn pair(&self, n: usize) -> (&Arc<AlignedVec>, &Arc<AlignedVec>) {
        let (_, x, y) = self
            .bufs
            .iter()
            .find(|(m, _, _)| *m == n)
            .expect("request size not in the operand pool");
        (x, y)
    }

    /// A dot request over the shared operands of length `n` (must be a
    /// mixture size).
    pub fn dot_input(&self, n: usize) -> KernelInput<'_> {
        let (x, y) = self.pair(n);
        KernelInput::Dot(x, y)
    }

    /// The same request as an owned [`SharedInput`] for the asynchronous
    /// submission path — a pair of `Arc` clones, no data copy, so async
    /// and sync runs stream the *same bytes*.
    pub fn shared_dot(&self, n: usize) -> SharedInput {
        let (x, y) = self.pair(n);
        SharedInput::Dot(Arc::clone(x), Arc::clone(y))
    }
}

/// Arrival model for [`run_load`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LoadMode {
    /// Submit the next batch when the previous completes.
    Closed,
    /// Requests arrive at a fixed rate on a virtual clock (see module
    /// docs); latency includes queueing delay.
    Open { rate_rps: f64 },
}

impl LoadMode {
    /// The label bench artifacts record for this arrival model.
    pub fn label(self) -> &'static str {
        match self {
            LoadMode::Closed => "closed",
            LoadMode::Open { .. } => "open",
        }
    }
}

/// Aggregate results of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests completed.
    pub requests: usize,
    /// Arrival batches the run dispatched.
    pub batches: usize,
    /// Requests served on the fused path.
    pub fused: u64,
    /// Requests served on the sharded path.
    pub sharded: u64,
    /// Wall time the service spent executing batches, ns.
    pub busy_ns: f64,
    /// End-to-end span of the run (virtual clock for open loop), ns.
    pub elapsed_ns: f64,
    /// Median request latency, ns.
    pub latency_p50_ns: f64,
    /// 90th-percentile request latency, ns.
    pub latency_p90_ns: f64,
    /// 99th-percentile request latency, ns.
    pub latency_p99_ns: f64,
    /// Worst observed request latency, ns.
    pub latency_max_ns: f64,
    /// Total updates streamed across all requests.
    pub updates: u64,
    /// Total arithmetic operations (per the served dot class).
    pub flops: u64,
    /// Aggregate arithmetic throughput while busy, MFlop/s.
    pub mflops: f64,
    /// Aggregate update throughput while busy, GUP/s.
    pub gups: f64,
    /// Completed requests per second over the run span.
    pub reqs_per_s: f64,
    /// Sum of all response values — a determinism anchor (fixed seed +
    /// fixed threads ⇒ bit-identical checksum).
    pub checksum: f64,
    /// Latency samples dropped from the percentiles because they were not
    /// finite (a wedged clock source or an injected fault can produce
    /// them). Zero on every healthy run; reported instead of panicking
    /// mid-bench.
    pub non_finite_latencies: usize,
}

/// Sort latency samples for percentile extraction, dropping non-finite
/// values instead of panicking on an incomparable sort: returns the
/// finite samples in ascending order plus the number dropped.
fn finite_sorted(latencies: Vec<f64>) -> (Vec<f64>, usize) {
    let before = latencies.len();
    let mut finite: Vec<f64> = latencies.into_iter().filter(|v| v.is_finite()).collect();
    finite.sort_by(f64::total_cmp);
    (finite, before - finite.len())
}

/// [`percentile_sorted`] that degrades to NaN on an empty sample set
/// (every latency was non-finite) rather than asserting.
fn pct_or_nan(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        f64::NAN
    } else {
        percentile_sorted(sorted, p)
    }
}

/// Drive `service` with `requests` dot requests sampled from `mix` in
/// arrival batches of `batch`, under the given arrival model. Fully
/// deterministic request stream for a fixed seed. Generates a fresh
/// [`OperandPool`] — callers running several loads over the same mixture
/// should generate the pool once and use [`run_load_with`].
pub fn run_load(
    service: &DotService,
    mix: &[MixEntry],
    requests: usize,
    batch: usize,
    mode: LoadMode,
    seed: u64,
) -> Result<LoadReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    let operands = OperandPool::generate(mix, seed, service.pool());
    run_load_with(service, mix, &operands, requests, batch, mode, seed)
}

/// [`run_load`] over a pre-generated operand pool (which must cover every
/// mixture size).
pub fn run_load_with(
    service: &DotService,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    batch: usize,
    mode: LoadMode,
    seed: u64,
) -> Result<LoadReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    if requests == 0 {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    let gap_ns = match mode {
        LoadMode::Closed => 0.0,
        LoadMode::Open { rate_rps } => {
            if rate_rps <= 0.0 || !rate_rps.is_finite() {
                return Err(BackendError::Runtime("open-loop rate must be > 0".to_string()));
            }
            1e9 / rate_rps
        }
    };
    let batch = batch.max(1);
    let sizes = sample_sizes(mix, requests, seed);

    let mut latencies = Vec::with_capacity(requests);
    let mut busy_ns = 0.0;
    let mut server_free_ns = 0.0;
    let (mut fused, mut sharded) = (0u64, 0u64);
    let mut updates = 0u64;
    let mut batches = 0usize;
    let mut checksum = 0.0;
    let mut first = 0usize;
    for chunk in sizes.chunks(batch) {
        let inputs: Vec<KernelInput<'_>> = chunk.iter().map(|&n| operands.dot_input(n)).collect();
        let t0 = Instant::now();
        let responses = service.submit_batch(&inputs)?;
        let dt = t0.elapsed().as_nanos() as f64;
        busy_ns += dt;
        batches += 1;
        for r in &responses {
            checksum += r.value;
            updates += r.n as u64;
            match r.path {
                ExecPath::Fused => fused += 1,
                ExecPath::Sharded => sharded += 1,
            }
        }
        match mode {
            LoadMode::Closed => {
                for _ in 0..responses.len() {
                    latencies.push(dt);
                }
            }
            LoadMode::Open { .. } => {
                let last_arrival = (first + chunk.len() - 1) as f64 * gap_ns;
                let start = server_free_ns.max(last_arrival);
                let completion = start + dt;
                server_free_ns = completion;
                for k in 0..chunk.len() {
                    latencies.push(completion - (first + k) as f64 * gap_ns);
                }
            }
        }
        first += chunk.len();
    }
    let (latencies, non_finite) = finite_sorted(latencies);
    let flops = updates * service.dot_spec().class.flops_per_update();
    let elapsed_ns = match mode {
        LoadMode::Closed => busy_ns,
        LoadMode::Open { .. } => server_free_ns.max(busy_ns),
    };
    Ok(LoadReport {
        requests,
        batches,
        fused,
        sharded,
        busy_ns,
        elapsed_ns,
        latency_p50_ns: pct_or_nan(&latencies, 50.0),
        latency_p90_ns: pct_or_nan(&latencies, 90.0),
        latency_p99_ns: pct_or_nan(&latencies, 99.0),
        latency_max_ns: latencies.last().copied().unwrap_or(f64::NAN),
        updates,
        flops,
        mflops: flops as f64 / busy_ns * 1000.0,
        gups: updates as f64 / busy_ns,
        reqs_per_s: requests as f64 / elapsed_ns * 1e9,
        checksum,
        non_finite_latencies: non_finite,
    })
}

/// Pace an arrival to its scheduled instant: sleep for the bulk, spin the
/// last stretch (sleep granularity on a loaded host is tens of µs).
fn pace_until(target: Instant) {
    loop {
        let now = Instant::now();
        if now >= target {
            return;
        }
        let remaining = target - now;
        if remaining > Duration::from_micros(200) {
            std::thread::sleep(remaining - Duration::from_micros(100));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Results of one *real-time* open-loop run through the asynchronous
/// pipeline: the classic [`LoadReport`] aggregates plus the queue and
/// pool-utilization stats only the queued path can report.
#[derive(Clone, Debug)]
pub struct AsyncLoadReport {
    /// The classic load aggregates, measured through the queue.
    pub load: LoadReport,
    /// Configured submission-queue depth.
    pub queue_depth: usize,
    /// Observed queue high-water mark (≤ `queue_depth` by construction —
    /// the backpressure bound).
    pub max_queue_depth: usize,
    /// Configured batching window, µs.
    pub batch_window_us: f64,
    /// Pool dispatches the dispatcher posted.
    pub dispatches: u64,
    /// Arrival batches the dispatcher drained.
    pub arrival_batches: u64,
    /// Fraction of the run during which at least one dispatch was in
    /// flight (busy-interval union / elapsed).
    pub pool_utilization: f64,
}

/// Drive the asynchronous pipeline with `requests` dot requests sampled
/// from `mix` — the *same* deterministic stream as the synchronous
/// [`run_load`] for the same seed, over the same shared operands — at a
/// fixed real-time arrival rate. Unlike the synchronous path's virtual
/// clock, this measures *actual* queueing + service latency: each request
/// is submitted at its scheduled arrival instant (the generator sleeps /
/// spins between arrivals), latency runs from that instant to ticket
/// completion, and time spent blocked on queue backpressure counts as
/// queueing delay (no coordinated omission).
///
/// Determinism: the request stream, every response value and the checksum
/// are bit-identical to the synchronous run at the same `T` — only the
/// timing columns are measurements.
///
/// A wall-clock watchdog bounds the whole run at a generous multiple of
/// the offered-load duration (see [`default_watchdog`]): a wedged
/// pipeline fails with a diagnostic error instead of hanging CI forever.
pub fn run_load_async(
    service: &AsyncDotService,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    rate_rps: f64,
    seed: u64,
) -> Result<AsyncLoadReport, BackendError> {
    let watchdog = default_watchdog(requests, rate_rps);
    run_load_async_bounded(service, mix, operands, requests, rate_rps, seed, watchdog)
}

/// The watchdog budget [`run_load_async`] applies: 20× the offered-load
/// duration, floored at 10 s so tiny runs on loaded CI hosts don't trip,
/// capped at 10 min so nothing waits longer than that on a hung pipeline.
pub fn default_watchdog(requests: usize, rate_rps: f64) -> Duration {
    let offered_s = if rate_rps > 0.0 && rate_rps.is_finite() {
        requests as f64 / rate_rps
    } else {
        0.0
    };
    Duration::from_secs_f64((offered_s * 20.0).clamp(10.0, 600.0))
}

/// [`run_load_async`] with an explicit watchdog budget (tests use a small
/// one to pin the failure mode; the public entry point computes a
/// generous default).
pub fn run_load_async_bounded(
    service: &AsyncDotService,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    rate_rps: f64,
    seed: u64,
    watchdog: Duration,
) -> Result<AsyncLoadReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    if requests == 0 {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    if rate_rps <= 0.0 || !rate_rps.is_finite() {
        return Err(BackendError::Runtime("open-loop rate must be > 0".to_string()));
    }
    let gap_ns = 1e9 / rate_rps;
    let sizes = sample_sizes(mix, requests, seed);
    let stats_before = service.stats();

    let epoch = Instant::now();
    let hard_deadline = epoch + watchdog;
    let mut handles = Vec::with_capacity(requests);
    for (k, &n) in sizes.iter().enumerate() {
        let target = epoch + Duration::from_nanos((k as f64 * gap_ns) as u64);
        pace_until(target);
        let handle = service.submit_with_arrival(operands.shared_dot(n), target)?;
        handles.push(handle);
    }
    let mut latencies = Vec::with_capacity(requests);
    let (mut fused, mut sharded) = (0u64, 0u64);
    let mut updates = 0u64;
    let mut checksum = 0.0;
    for handle in handles {
        let remaining = hard_deadline.saturating_duration_since(Instant::now());
        let (r, latency_ns) = match handle.wait_timed_for(remaining) {
            Some(done) => done?,
            None => {
                return Err(BackendError::Runtime(format!(
                    "load-generator watchdog: request unresolved {watchdog:?} into the run \
                     — the pipeline is wedged (dispatcher or pool stuck)"
                )))
            }
        };
        latencies.push(latency_ns);
        checksum += r.value;
        updates += r.n as u64;
        match r.path {
            ExecPath::Fused => fused += 1,
            ExecPath::Sharded => sharded += 1,
        }
    }
    let elapsed_ns = epoch.elapsed().as_nanos() as f64;
    let stats = service.stats();
    let busy_ns = (stats.busy_ns - stats_before.busy_ns).max(1.0);
    let (latencies, non_finite) = finite_sorted(latencies);
    let spec = service.service().dot_spec();
    let flops = updates * spec.class.flops_per_update();
    let opts = service.options();
    Ok(AsyncLoadReport {
        load: LoadReport {
            requests,
            batches: (stats.arrival_batches - stats_before.arrival_batches) as usize,
            fused,
            sharded,
            busy_ns,
            elapsed_ns,
            latency_p50_ns: pct_or_nan(&latencies, 50.0),
            latency_p90_ns: pct_or_nan(&latencies, 90.0),
            latency_p99_ns: pct_or_nan(&latencies, 99.0),
            latency_max_ns: latencies.last().copied().unwrap_or(f64::NAN),
            updates,
            flops,
            mflops: flops as f64 / busy_ns * 1000.0,
            gups: updates as f64 / busy_ns,
            reqs_per_s: requests as f64 / elapsed_ns * 1e9,
            checksum,
            non_finite_latencies: non_finite,
        },
        queue_depth: opts.queue_depth,
        max_queue_depth: stats.max_queue_depth,
        batch_window_us: opts.batch_window.as_nanos() as f64 / 1e3,
        dispatches: stats.dispatches - stats_before.dispatches,
        arrival_batches: stats.arrival_batches - stats_before.arrival_batches,
        pool_utilization: (busy_ns / elapsed_ns).min(1.0),
    })
}

/// Results of one open-loop run against a `serve-net` server over real
/// sockets: the classic [`LoadReport`] aggregates measured end-to-end on
/// the wire, plus connection-level accounting and the pipeline counters
/// recovered from the server's STATS probe (`docs/PROTOCOL.md` §3.4).
#[derive(Clone, Debug)]
pub struct WireLoadReport {
    /// Wire-measured aggregates: latency runs from each request's
    /// *scheduled* arrival to its response frame's receipt (socket, codec,
    /// queueing, BUSY retries and service time all included — no
    /// coordinated omission).
    pub load: LoadReport,
    /// Client connections driven in parallel.
    pub connections: usize,
    /// Aggregate target arrival rate across all connections, req/s.
    pub rate_rps: f64,
    /// BUSY responses absorbed (each one re-sent its request with latency
    /// still measured from the original schedule).
    pub busy_retries: u64,
    /// Server-side submission-queue depth (from the stats probe).
    pub queue_depth: usize,
    /// Server-side queue high-water mark over the run.
    pub max_queue_depth: usize,
    /// Pool dispatches the server's dispatcher posted during the run.
    pub dispatches: u64,
    /// Arrival batches the server's dispatcher drained during the run.
    pub arrival_batches: u64,
    /// Server busy-interval union / client elapsed span.
    pub pool_utilization: f64,
}

/// What one connection's receiver records per completed request.
struct WireRecord {
    id: usize,
    value: f64,
    sharded: bool,
    latency_ns: f64,
}

/// The sender/receiver pair for one wire connection. The sender paces the
/// connection's share of the global arrival schedule (request `i` goes to
/// connection `i % connections` at instant `epoch + i·gap`) and writes
/// frames without waiting for responses; the receiver thread drains
/// response frames as they stream back (out of order) and feeds BUSY
/// rejects back to the sender for immediate re-send. This is the
/// pipelined, no-coordinated-omission client: a slow response never
/// delays later scheduled arrivals on the same connection.
struct WireWorker {
    writer: BufWriter<TcpStream>,
    retry_rx: Receiver<usize>,
    finished: Arc<AtomicBool>,
    payloads: Arc<HashMap<usize, Vec<u8>>>,
    sizes: Arc<Vec<usize>>,
}

impl WireWorker {
    fn send_request(&mut self, id: usize) -> Result<(), String> {
        let n = self.sizes[id];
        let payload = self.payloads.get(&n).expect("payload per mixture size");
        let head = codec::encode_header_bytes(Opcode::Dot, id as u64, payload.len());
        self.writer
            .write_all(&head)
            .and_then(|_| self.writer.write_all(payload))
            .and_then(|_| self.writer.flush())
            .map_err(|e| format!("wire send: {e}"))
    }

    /// Drive this connection's schedule, then service retries until the
    /// receiver confirms every assigned request completed.
    fn run(&mut self, assigned: &[usize], epoch: Instant, gap_ns: f64) -> Result<(), String> {
        for &id in assigned {
            // Re-send whatever bounced with BUSY before pacing onward.
            while let Ok(retry_id) = self.retry_rx.try_recv() {
                self.send_request(retry_id)?;
            }
            let target = epoch + Duration::from_nanos((id as f64 * gap_ns) as u64);
            pace_until(target);
            self.send_request(id)?;
        }
        while !self.finished.load(Ordering::Acquire) {
            match self.retry_rx.recv_timeout(Duration::from_micros(100)) {
                Ok(retry_id) => self.send_request(retry_id)?,
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Ok(())
    }
}

/// Read exactly `buf.len()` bytes under a wall-clock watchdog: socket
/// read timeouts below the deadline just keep waiting (partial progress
/// is preserved across them), while a timeout past the deadline turns
/// into a diagnostic error instead of a hung receiver. `Ok(false)` on
/// clean EOF before the first byte.
fn read_exact_deadline(
    r: &mut impl std::io::Read,
    buf: &mut [u8],
    deadline: Instant,
) -> Result<bool, String> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err("eof inside a frame".to_string());
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if Instant::now() >= deadline {
                    return Err(format!(
                        "wire watchdog: run exceeded its wall-clock budget with {} of {} \
                         frame bytes outstanding — server or socket wedged",
                        buf.len() - filled,
                        buf.len()
                    ));
                }
            }
            Err(e) => return Err(format!("wire read: {e}")),
        }
    }
    Ok(true)
}

/// One connection's receiver: read response frames until every assigned
/// request has a result, bouncing BUSY ids back to the sender. Bounded by
/// the run's watchdog `deadline` so a silent server fails the run with a
/// diagnostic instead of hanging it.
fn wire_receiver(
    stream: TcpStream,
    assigned: usize,
    epoch: Instant,
    gap_ns: f64,
    retry_tx: Sender<usize>,
    finished: Arc<AtomicBool>,
    deadline: Instant,
) -> Result<(Vec<WireRecord>, u64), String> {
    // Coarse per-read timeout: the watchdog's tick. Progress mid-frame is
    // carried across ticks by `read_exact_deadline`.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = BufReader::new(stream);
    let mut records = Vec::with_capacity(assigned);
    let mut busy_retries = 0u64;
    let fail = |msg: String, finished: &AtomicBool| {
        finished.store(true, Ordering::Release);
        Err(msg)
    };
    while records.len() < assigned {
        let mut head = [0u8; HEADER_LEN];
        match read_exact_deadline(&mut reader, &mut head, deadline) {
            Ok(true) => {}
            Ok(false) => return fail("server closed mid-run".to_string(), &finished),
            Err(msg) => return fail(msg, &finished),
        }
        let header = match codec::decode_header(&head) {
            Ok(h) => h,
            Err(e) => return fail(format!("wire header: {e}"), &finished),
        };
        let mut payload = vec![0u8; header.payload_len as usize];
        if header.payload_len > 0 {
            match read_exact_deadline(&mut reader, &mut payload, deadline) {
                Ok(true) => {}
                Ok(false) => return fail("server closed mid-frame".to_string(), &finished),
                Err(msg) => return fail(msg, &finished),
            }
        }
        let Some(opcode) = Opcode::from_byte(header.opcode) else {
            return fail(format!("unassigned opcode {:#04x}", header.opcode), &finished);
        };
        match codec::decode_response(opcode, &payload) {
            Ok(Response::Result(r)) => {
                let id = header.request_id as usize;
                let scheduled_ns = id as f64 * gap_ns;
                let now_ns = epoch.elapsed().as_nanos() as f64;
                records.push(WireRecord {
                    id,
                    value: r.value,
                    sharded: r.path == ExecPath::Sharded,
                    latency_ns: (now_ns - scheduled_ns).max(0.0),
                });
            }
            Ok(Response::Error(e)) if e.code == ErrorCode::Busy => {
                busy_retries += 1;
                if retry_tx.send(header.request_id as usize).is_err() {
                    return fail("sender hung up during retry".to_string(), &finished);
                }
            }
            Ok(Response::Error(e)) => {
                return fail(format!("server error for {}: {e}", header.request_id), &finished)
            }
            Ok(other) => return fail(format!("unexpected frame {other:?}"), &finished),
            Err(e) => return fail(format!("wire decode: {e}"), &finished),
        }
    }
    finished.store(true, Ordering::Release);
    Ok((records, busy_retries))
}

/// Drive a `serve-net` server at `addr` with the *same* deterministic
/// request stream as [`run_load_async`] (same mixture, seed and shared
/// operand bytes), split round-robin over `connections` pipelined wire
/// connections at an aggregate open-loop rate. Latency is measured from
/// each request's scheduled arrival to its response frame (socket and
/// codec included); BUSY rejects are re-sent with the original schedule
/// kept, so backpressure shows up as latency, not dropped samples.
///
/// `flops_per_update` is the served dot class's cost (the client cannot
/// see the server's kernel config over the wire).
///
/// Determinism: the checksum folds response values in request-id order —
/// at the same `T` and seed it is bit-identical to the in-process
/// [`run_load_async`] checksum (pinned in `tests/integration.rs`).
#[allow(clippy::too_many_arguments)]
pub fn run_load_wire(
    addr: &str,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    rate_rps: f64,
    connections: usize,
    flops_per_update: u64,
    seed: u64,
) -> Result<WireLoadReport, BackendError> {
    let watchdog = default_watchdog(requests, rate_rps);
    run_load_wire_bounded(
        addr,
        mix,
        operands,
        requests,
        rate_rps,
        connections,
        flops_per_update,
        seed,
        watchdog,
    )
}

/// [`run_load_wire`] with an explicit watchdog budget (the public entry
/// point computes a generous default; tests use a small one to pin the
/// no-hang failure mode against an unresponsive server).
#[allow(clippy::too_many_arguments)]
pub fn run_load_wire_bounded(
    addr: &str,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    rate_rps: f64,
    connections: usize,
    flops_per_update: u64,
    seed: u64,
    watchdog: Duration,
) -> Result<WireLoadReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    if requests == 0 {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    if rate_rps <= 0.0 || !rate_rps.is_finite() {
        return Err(BackendError::Runtime("open-loop rate must be > 0".to_string()));
    }
    let connections = connections.max(1);
    let gap_ns = 1e9 / rate_rps;
    let sizes = Arc::new(sample_sizes(mix, requests, seed));

    // One cached payload per distinct mixture size, encoded from the same
    // shared operand buffers the in-process paths submit — byte-for-byte
    // the operands of `run_load_async`.
    let mut payloads = HashMap::new();
    for entry in mix {
        payloads.entry(entry.n).or_insert_with(|| {
            let (x, y) = operands.pair(entry.n);
            codec::encode_dot_payload(x, y)
        });
    }
    let payloads = Arc::new(payloads);

    let wire_err = |e: super::net::WireCallError| BackendError::Runtime(e.to_string());
    let mut probe = WireClient::connect(addr)
        .map_err(|e| BackendError::Runtime(format!("connect {addr}: {e}")))?;
    let before = probe.stats().map_err(wire_err)?;

    let epoch = Instant::now();
    let hard_deadline = epoch + watchdog;
    let mut workers = Vec::with_capacity(connections);
    for c in 0..connections {
        let stream = TcpStream::connect(addr)
            .map_err(|e| BackendError::Runtime(format!("connect {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        let read_half = stream
            .try_clone()
            .map_err(|e| BackendError::Runtime(format!("clone stream: {e}")))?;
        let assigned: Vec<usize> = (c..requests).step_by(connections).collect();
        let (retry_tx, retry_rx) = std::sync::mpsc::channel();
        let finished = Arc::new(AtomicBool::new(false));
        let receiver = {
            let finished = Arc::clone(&finished);
            let count = assigned.len();
            std::thread::Builder::new()
                .name("kahan-wire-recv".to_string())
                .spawn(move || {
                    wire_receiver(
                        read_half,
                        count,
                        epoch,
                        gap_ns,
                        retry_tx,
                        finished,
                        hard_deadline,
                    )
                })
                .expect("spawn wire receiver")
        };
        let sender = {
            let mut worker = WireWorker {
                writer: BufWriter::new(stream),
                retry_rx,
                finished,
                payloads: Arc::clone(&payloads),
                sizes: Arc::clone(&sizes),
            };
            std::thread::Builder::new()
                .name("kahan-wire-send".to_string())
                .spawn(move || {
                    let r = worker.run(&assigned, epoch, gap_ns);
                    if r.is_err() {
                        // Unblock this connection's receiver: it would
                        // otherwise wait for responses that can't come.
                        worker.finished.store(true, Ordering::Release);
                        let _ = worker
                            .writer
                            .get_ref()
                            .shutdown(std::net::Shutdown::Both);
                    }
                    r
                })
                .expect("spawn wire sender")
        };
        workers.push((sender, receiver));
    }

    let mut values = vec![0.0f64; requests];
    let mut latencies = Vec::with_capacity(requests);
    let (mut fused, mut sharded) = (0u64, 0u64);
    let mut busy_retries = 0u64;
    let mut failure: Option<String> = None;
    for (sender, receiver) in workers {
        match receiver.join().expect("wire receiver panicked") {
            Ok((records, busy)) => {
                busy_retries += busy;
                for rec in records {
                    values[rec.id] = rec.value;
                    latencies.push(rec.latency_ns);
                    if rec.sharded {
                        sharded += 1;
                    } else {
                        fused += 1;
                    }
                }
            }
            Err(msg) => {
                failure.get_or_insert(msg);
            }
        }
        if let Err(msg) = sender.join().expect("wire sender panicked") {
            failure.get_or_insert(msg);
        }
    }
    let elapsed_ns = epoch.elapsed().as_nanos() as f64;
    if let Some(msg) = failure {
        return Err(BackendError::Runtime(msg));
    }
    let after = probe.stats().map_err(wire_err)?;

    // Checksum in request-id order — the exact fold order of the
    // in-process open-loop runs.
    let checksum = values.iter().sum::<f64>();
    let updates: u64 = sizes.iter().map(|&n| n as u64).sum();
    let flops = updates * flops_per_update;
    let busy_ns = (after.busy_ns.saturating_sub(before.busy_ns) as f64).max(1.0);
    let (latencies, non_finite) = finite_sorted(latencies);
    Ok(WireLoadReport {
        load: LoadReport {
            requests,
            batches: (after.arrival_batches - before.arrival_batches) as usize,
            fused,
            sharded,
            busy_ns,
            elapsed_ns,
            latency_p50_ns: pct_or_nan(&latencies, 50.0),
            latency_p90_ns: pct_or_nan(&latencies, 90.0),
            latency_p99_ns: pct_or_nan(&latencies, 99.0),
            latency_max_ns: latencies.last().copied().unwrap_or(f64::NAN),
            updates,
            flops,
            mflops: flops as f64 / busy_ns * 1000.0,
            gups: updates as f64 / busy_ns,
            reqs_per_s: requests as f64 / elapsed_ns * 1e9,
            checksum,
            non_finite_latencies: non_finite,
        },
        connections,
        rate_rps,
        busy_retries,
        queue_depth: after.queue_depth as usize,
        max_queue_depth: after.max_queue_depth as usize,
        dispatches: after.dispatches - before.dispatches,
        arrival_batches: after.arrival_batches - before.arrival_batches,
        pool_utilization: (busy_ns / elapsed_ns).min(1.0),
    })
}

/// One tenant's row in a [`TenantLoadReport`]: the policy attributes it
/// ran under, full shed accounting (every offered request lands in
/// exactly one of admitted / quota-shed / busy-shed, and every admitted
/// one in completed-ok / deadline-shed), and latency percentiles over its
/// *completed* requests only — sheds are accounted, not averaged in.
#[derive(Clone, Debug)]
pub struct TenantLoadRow {
    /// Tenant id (index into the policy's classes).
    pub tenant: u32,
    /// Display name from the policy.
    pub name: String,
    /// Weighted-fair share weight.
    pub weight: u32,
    /// Per-tenant queue quota (`None` = unbounded).
    pub quota: Option<usize>,
    /// Requests this tenant's stream offered.
    pub offered: usize,
    /// Requests admitted past quota + depth checks.
    pub admitted: usize,
    /// Admitted requests that completed with a result.
    pub completed_ok: usize,
    /// Requests refused at admission with the typed quota outcome.
    pub quota_shed: usize,
    /// Requests refused because the shared queue was at depth (global
    /// backpressure, not this tenant's quota).
    pub busy_shed: usize,
    /// Admitted requests shed in-queue on deadline expiry.
    pub deadline_shed: usize,
    /// Median completed-request latency, ns (NaN if none completed).
    pub latency_p50_ns: f64,
    /// 99th-percentile completed-request latency, ns.
    pub latency_p99_ns: f64,
    /// Worst completed-request latency, ns.
    pub latency_max_ns: f64,
}

/// Results of one multi-tenant open-loop run ([`run_load_tenants`]): one
/// accounting + latency row per tenant class, in class order.
#[derive(Clone, Debug)]
pub struct TenantLoadReport {
    /// Requests offered across all tenants.
    pub requests: usize,
    /// End-to-end span of the run, ns.
    pub elapsed_ns: f64,
    /// Sum of completed responses in submission order — only comparable
    /// across runs when nothing was shed.
    pub checksum: f64,
    /// One row per tenant class, in policy order.
    pub rows: Vec<TenantLoadRow>,
}

/// Drive a QoS-configured [`AsyncDotService`] with per-tenant open-loop
/// streams merged onto one arrival clock and account every outcome per
/// tenant. `offered[i]` is tenant `i`'s request count; the merged stream
/// interleaves tenants deterministically in proportion to their remaining
/// counts (a saturating tenant therefore dominates arrivals — the
/// noisy-neighbor shape — while a light one still arrives throughout the
/// run).
///
/// Admission is non-blocking: a quota refusal or queue-full BUSY sheds
/// that request on the spot (bucketed in its tenant's row) and the
/// generator paces on, so a heavy tenant's backpressure can never delay a
/// light tenant's arrivals — the measurement the noisy-neighbor gate
/// depends on.
#[allow(clippy::too_many_arguments)]
pub fn run_load_tenants(
    service: &AsyncDotService,
    mix: &[MixEntry],
    operands: &OperandPool,
    offered: &[usize],
    rate_rps: f64,
    deadline: Option<Duration>,
    seed: u64,
    watchdog: Duration,
) -> Result<TenantLoadReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    let requests: usize = offered.iter().sum();
    if requests == 0 || offered.is_empty() {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    if rate_rps <= 0.0 || !rate_rps.is_finite() {
        return Err(BackendError::Runtime("open-loop rate must be > 0".to_string()));
    }
    let policy = service.qos().cloned();
    let gap_ns = 1e9 / rate_rps;
    let sizes = sample_sizes(mix, requests, seed);

    // Deterministic proportional interleave: draw each arrival's tenant
    // weighted by its remaining request count.
    let mut remaining: Vec<usize> = offered.to_vec();
    let mut left = requests;
    let mut rng = Rng::new(seed ^ 0x7E4A47);
    let mut order = Vec::with_capacity(requests);
    while left > 0 {
        let mut t = (rng.f64() * left as f64) as usize;
        t = t.min(left - 1);
        let mut tenant = remaining.len() - 1;
        for (i, &r) in remaining.iter().enumerate() {
            if t < r {
                tenant = i;
                break;
            }
            t -= r;
        }
        remaining[tenant] -= 1;
        left -= 1;
        order.push(tenant as u32);
    }

    let mut rows: Vec<TenantLoadRow> = (0..offered.len())
        .map(|i| TenantLoadRow {
            tenant: i as u32,
            name: policy
                .as_ref()
                .map_or_else(|| format!("t{i}"), |p| p.name(i as u32)),
            weight: policy.as_ref().map_or(1, |p| p.weight(i as u32)),
            quota: policy.as_ref().and_then(|p| p.classes().get(i).and_then(|c| c.quota)),
            offered: offered[i],
            admitted: 0,
            completed_ok: 0,
            quota_shed: 0,
            busy_shed: 0,
            deadline_shed: 0,
            latency_p50_ns: f64::NAN,
            latency_p99_ns: f64::NAN,
            latency_max_ns: f64::NAN,
        })
        .collect();

    let epoch = Instant::now();
    let hard_deadline = epoch + watchdog;
    let mut handles = Vec::with_capacity(requests);
    for (k, (&n, &tenant)) in sizes.iter().zip(order.iter()).enumerate() {
        let target = epoch + Duration::from_nanos((k as f64 * gap_ns) as u64);
        pace_until(target);
        match service.try_submit_with_opts(operands.shared_dot(n), target, deadline, tenant, false)? {
            TrySubmit::Accepted(h) => {
                rows[tenant as usize].admitted += 1;
                handles.push((tenant, h));
            }
            TrySubmit::Quota => rows[tenant as usize].quota_shed += 1,
            TrySubmit::Busy => rows[tenant as usize].busy_shed += 1,
        }
    }

    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); offered.len()];
    let mut checksum = 0.0;
    for (tenant, handle) in handles {
        let remaining = hard_deadline.saturating_duration_since(Instant::now());
        match handle.wait_timed_for(remaining) {
            Some(Ok((r, latency_ns))) => {
                rows[tenant as usize].completed_ok += 1;
                latencies[tenant as usize].push(latency_ns);
                checksum += r.value;
            }
            Some(Err(BackendError::DeadlineExceeded { .. })) => {
                rows[tenant as usize].deadline_shed += 1;
            }
            Some(Err(e)) => return Err(e),
            None => {
                return Err(BackendError::Runtime(format!(
                    "tenant-load watchdog: request unresolved {watchdog:?} into the run \
                     — the pipeline is wedged"
                )))
            }
        }
    }
    let elapsed_ns = epoch.elapsed().as_nanos() as f64;
    for (row, lat) in rows.iter_mut().zip(latencies) {
        let (sorted, _) = finite_sorted(lat);
        row.latency_p50_ns = pct_or_nan(&sorted, 50.0);
        row.latency_p99_ns = pct_or_nan(&sorted, 99.0);
        row.latency_max_ns = sorted.last().copied().unwrap_or(f64::NAN);
    }
    Ok(TenantLoadReport { requests, elapsed_ns, checksum, rows })
}

/// Aggregates of one scheduling-interleaving run
/// ([`run_interleaving_checksum`]): the bit-parity anchors the gate
/// compares across FIFO, weighted-fair and reversed-priority services.
#[derive(Clone, Copy, Debug)]
pub struct InterleavingReport {
    /// Requests completed (always the full stream — nothing sheds).
    pub requests: usize,
    /// Requests served on the fused path.
    pub fused: u64,
    /// Requests served on the sharded path.
    pub sharded: u64,
    /// Sum of response values folded in submission order — bit-identical
    /// across any scheduling order at fixed `T` and seed.
    pub checksum: f64,
}

/// Run the deterministic request stream through `service` as fast as the
/// queue admits (blocking submission — nothing is shed) and fold the
/// responses in submission order. Requests cycle round-robin over
/// `tenants` tenant ids and every third one carries a far-future deadline
/// so it rides the urgent lane — together these exercise every scheduling
/// decision (FIFO vs weighted-fair drain order, urgent promotion, DRR
/// carryover) without ever forking the numerics.
///
/// The scheduling-independence gate: run this against a FIFO service, a
/// weighted-fair one, and one with the priorities reversed — same `T`,
/// seed and operands — and the three checksums (and fused/sharded splits)
/// must be bit-identical, because batch composition is a pure function of
/// request lengths and scheduling only permutes *when* requests dispatch.
pub fn run_interleaving_checksum(
    service: &AsyncDotService,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    tenants: u32,
    seed: u64,
) -> Result<InterleavingReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    if requests == 0 {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    let tenants = tenants.max(1);
    let sizes = sample_sizes(mix, requests, seed);
    // Far past any plausible run length: routes via the urgent lane
    // without ever actually shedding.
    let urgent = Some(Duration::from_secs(3600));
    let mut handles = Vec::with_capacity(requests);
    for (k, &n) in sizes.iter().enumerate() {
        let tenant = (k as u32) % tenants;
        let deadline = if k % 3 == 0 { urgent } else { None };
        let h =
            service.submit_with_opts(operands.shared_dot(n), Instant::now(), deadline, tenant, false)?;
        handles.push(h);
    }
    let (mut fused, mut sharded) = (0u64, 0u64);
    let mut checksum = 0.0;
    for handle in handles {
        match handle.wait_timed_for(Duration::from_secs(120)) {
            Some(done) => {
                let (r, _) = done?;
                checksum += r.value;
                match r.path {
                    ExecPath::Fused => fused += 1,
                    ExecPath::Sharded => sharded += 1,
                }
            }
            None => {
                return Err(BackendError::Runtime(
                    "interleaving run: request unresolved after 120s — pipeline wedged"
                        .to_string(),
                ))
            }
        }
    }
    Ok(InterleavingReport { requests, fused, sharded, checksum })
}

/// Outcome of one chaos run ([`run_load_chaos`]): every submitted request
/// classified into exactly one bucket, the injector's per-site accounting,
/// and the post-chaos recovery probe. The structural invariant the chaos
/// bench gates on is `hung == 0`: under any seeded fault plan, every
/// request resolves to a result or a typed error before the watchdog.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Requests submitted (and classified — the buckets sum to this).
    pub requests: usize,
    /// Requests that completed with a correct result.
    pub completed_ok: usize,
    /// Requests shed with the typed deadline error before any compute.
    pub deadline_shed: usize,
    /// Requests shed at admission with the typed quota outcome — a
    /// [`TrySubmit::Quota`] refusal (including injected
    /// quota-admission-reject faults) or a
    /// [`BackendError::QuotaExceeded`] resolution. Never entered the
    /// pipeline; disjoint from every other bucket.
    pub quota_shed: usize,
    /// Requests failed by an (injected) worker panic.
    pub worker_panics: usize,
    /// Requests that resolved to any other typed error.
    pub other_errors: usize,
    /// Requests still unresolved when the watchdog expired. Must be 0 —
    /// the resolve-exactly-once contract under faults.
    pub hung: usize,
    /// Fired fault count per site label, for every site (zeros included —
    /// a stable schema for the bench artifact).
    pub injected: Vec<(&'static str, u64)>,
    /// Total faults fired across all sites.
    pub total_injected: u64,
    /// Whether the post-chaos probe completed bit-identical to the
    /// synchronous path on the self-healed pool.
    pub recovery_verified: bool,
    /// Latency of the post-chaos probe through the full async pipeline,
    /// ns (the "how long until the service is useful again" number).
    pub recovery_latency_ns: f64,
}

/// Drive the async pipeline with the standard open-loop stream while
/// `injector` (already wired into the service via
/// [`AsyncDotService::new_with_faults`]) fires a seeded fault plan, and
/// classify every outcome. Faulted runs make no numeric claims — panicked
/// requests have no result — so unlike [`run_load_async`] this returns
/// accounting, not throughput: the properties it measures are
/// "no request hangs" and "the pipeline recovers".
///
/// On a QoS-configured service the stream cycles requests round-robin
/// across the policy's tenant classes, so the tenant-facing fault sites
/// (quota-admission reject, weighted-fair starvation stall) are
/// reachable; quota refusals land in the [`ChaosReport::quota_shed`]
/// bucket rather than failing the run.
#[allow(clippy::too_many_arguments)]
pub fn run_load_chaos(
    service: &AsyncDotService,
    injector: &FaultInjector,
    mix: &[MixEntry],
    operands: &OperandPool,
    requests: usize,
    rate_rps: f64,
    deadline: Option<Duration>,
    seed: u64,
    watchdog: Duration,
) -> Result<ChaosReport, BackendError> {
    if mix.is_empty() {
        return Err(BackendError::Runtime("empty request mixture".to_string()));
    }
    if requests == 0 {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    if rate_rps <= 0.0 || !rate_rps.is_finite() {
        return Err(BackendError::Runtime("open-loop rate must be > 0".to_string()));
    }
    let gap_ns = 1e9 / rate_rps;
    let sizes = sample_sizes(mix, requests, seed);
    let tenant_cycle = service.qos().map_or(1, |q| q.classes().len().max(1));

    let epoch = Instant::now();
    let hard_deadline = epoch + watchdog;
    let mut quota_shed = 0usize;
    let mut handles = Vec::with_capacity(requests);
    for (k, &n) in sizes.iter().enumerate() {
        let target = epoch + Duration::from_nanos((k as f64 * gap_ns) as u64);
        pace_until(target);
        let tenant = (k % tenant_cycle) as u32;
        // Non-blocking admission with a watchdog on the retry loop: a
        // wedged dispatcher turns queue-full into a diagnostic failure
        // instead of blocking the generator forever. A quota refusal is
        // terminal for the request (retrying immediately cannot help), so
        // it is bucketed and the generator paces on.
        let mut admitted = None;
        loop {
            match service.try_submit_with_opts(operands.shared_dot(n), target, deadline, tenant, false)? {
                TrySubmit::Accepted(h) => {
                    admitted = Some(h);
                    break;
                }
                TrySubmit::Quota => {
                    quota_shed += 1;
                    break;
                }
                TrySubmit::Busy => {
                    if Instant::now() >= hard_deadline {
                        return Err(BackendError::Runtime(format!(
                            "chaos watchdog: queue refused admission for {watchdog:?} \
                             — dispatcher not draining"
                        )));
                    }
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        }
        if let Some(h) = admitted {
            handles.push(h);
        }
    }

    let (mut completed_ok, mut deadline_shed) = (0usize, 0usize);
    let (mut worker_panics, mut other_errors, mut hung) = (0usize, 0usize, 0usize);
    for handle in handles {
        let remaining = hard_deadline.saturating_duration_since(Instant::now());
        match handle.wait_timed_for(remaining) {
            Some(Ok(_)) => completed_ok += 1,
            Some(Err(BackendError::DeadlineExceeded { .. })) => deadline_shed += 1,
            Some(Err(BackendError::QuotaExceeded { .. })) => quota_shed += 1,
            Some(Err(BackendError::Runtime(msg))) if msg.contains("panic") => worker_panics += 1,
            Some(Err(_)) => other_errors += 1,
            None => hung += 1,
        }
    }

    // Recovery probe: one clean request through the full async pipeline,
    // bit-compared against the synchronous path over the *same* (by now
    // self-healed) pool. Verifies both halves of the degradation
    // contract: the pool is usable again, and healing preserved the
    // partition (bit-identical results at fixed T).
    let probe = operands.shared_dot(sizes[0]);
    let want = service.service().submit(&probe.view())?;
    let t0 = Instant::now();
    let handle = service.submit(probe)?;
    let (recovery_verified, recovery_latency_ns) =
        match handle.wait_timed_for(Duration::from_secs(30)) {
            Some(Ok((got, _))) => (
                got.value.to_bits() == want.value.to_bits(),
                t0.elapsed().as_nanos() as f64,
            ),
            _ => (false, f64::NAN),
        };

    let injected: Vec<(&'static str, u64)> = FaultSite::ALL
        .iter()
        .map(|&site| (site.label(), injector.fired(site)))
        .collect();
    Ok(ChaosReport {
        requests,
        completed_ok,
        deadline_shed,
        quota_shed,
        worker_panics,
        other_errors,
        hung,
        total_injected: injector.total_fired(),
        injected,
        recovery_verified,
        recovery_latency_ns,
    })
}

/// One pass of the skewed-popularity wire scenario ([`run_load_zipf`]):
/// closed-loop aggregates for either the payload-resubmission baseline or
/// the register-once/submit-by-handle pass over the *same* draw sequence.
#[derive(Clone, Debug)]
pub struct ZipfPassReport {
    /// End-to-end span of the pass, ns.
    pub elapsed_ns: f64,
    /// Closed-loop throughput, requests per second.
    pub reqs_per_s: f64,
    /// Request bytes written to the socket over the pass (headers +
    /// payloads + BUSY re-sends; registration traffic is reported
    /// separately in [`ZipfReport::register_bytes`]).
    pub bytes_sent: u64,
    /// Steady-state request bytes per draw — the wire-traffic axis of the
    /// O(n) → O(1) claim.
    pub bytes_per_request: f64,
    /// Median round-trip latency, ns.
    pub latency_p50_ns: f64,
    /// 99th-percentile round-trip latency, ns.
    pub latency_p99_ns: f64,
    /// Response values folded in draw order — the cross-pass parity probe.
    pub checksum: f64,
}

/// Results of the `--zipf` skewed-popularity scenario ([`run_load_zipf`]):
/// the baseline and handle passes side by side, the measured speedup, the
/// server's cache-counter deltas over the handle pass, and the bit-parity
/// verdict between the two passes.
#[derive(Clone, Debug)]
pub struct ZipfReport {
    /// Draws per pass.
    pub requests: usize,
    /// Distinct operand pairs in the catalog.
    pub catalog: usize,
    /// Zipf exponent `s` of the popularity skew (0 = uniform).
    pub zipf_s: f64,
    /// Operand length (updates per request).
    pub n: usize,
    /// Distinct catalog entries the draw sequence actually touched — the
    /// number of results the cache must compute; everything else replays.
    pub unique_pairs_drawn: usize,
    /// The payload-resubmission pass (every draw ships both operands).
    pub baseline: ZipfPassReport,
    /// The handle pass (operands registered once, 16-byte submits).
    pub handles: ZipfPassReport,
    /// Baseline wall time / handle-pass wall time.
    pub speedup: f64,
    /// One-time registration cost for the whole catalog, ns.
    pub register_ns: f64,
    /// One-time registration traffic for the whole catalog, bytes.
    pub register_bytes: u64,
    /// Draws whose handle-pass value differed bitwise from the baseline
    /// pass (the hard parity gate requires 0).
    pub value_mismatches: usize,
    /// `true` iff every per-draw value and the folded checksum are
    /// bit-identical across the two passes — the cached-vs-recomputed
    /// parity contract measured across the socket.
    pub bit_parity: bool,
    /// Server store/cache counter deltas over the handle pass (probed via
    /// the rev-1.3 stats extension; `cache_hits + cache_misses ==
    /// cache_lookups` is hard-gated by `tools/validate_bench.py`).
    pub cache: WireCacheStats,
}

/// Sample `requests` catalog indices under a Zipf(`s`) popularity law
/// (rank `r`, 1-based, drawn with probability ∝ `1/r^s`; `s = 0` is
/// uniform). Deterministic in `rng`.
fn zipf_draws(rng: &mut Rng, catalog: usize, requests: usize, s: f64) -> Vec<usize> {
    let weights: Vec<f64> = (0..catalog).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
    let mut cum = Vec::with_capacity(catalog);
    let mut total = 0.0;
    for w in &weights {
        total += w;
        cum.push(total);
    }
    (0..requests)
        .map(|_| {
            let u = rng.f64() * total;
            cum.partition_point(|&c| c <= u).min(catalog - 1)
        })
        .collect()
}

/// The `--zipf` skewed-popularity scenario: drive a `serve-net` server at
/// `addr` with a catalog of `catalog` distinct operand pairs of length
/// `n`, drawn `requests` times under a Zipf(`zipf_s`) popularity law —
/// the repeat-heavy shape real retrieval traffic has — twice over the
/// same deterministic draw sequence:
///
/// 1. **Baseline**: every draw re-ships both operand payloads (a DOT
///    frame, `O(n)` wire bytes + a full recomputation per draw).
/// 2. **Handles**: each catalog vector is registered once (REGISTER),
///    then every draw submits a 16-byte DOT_HANDLES frame; repeat pairs
///    resolve from the server's result cache.
///
/// Both passes run closed-loop on one connection, so the measured ratio
/// is the per-request win (wire + compute), not a parallelism artifact.
/// The per-draw response values of the two passes are bit-compared —
/// [`ZipfReport::bit_parity`] is the cached-vs-recomputed parity contract
/// observed across the socket, and `serve-bench` hard-fails when it does
/// not hold.
pub fn run_load_zipf(
    addr: &str,
    n: usize,
    catalog: usize,
    requests: usize,
    zipf_s: f64,
    seed: u64,
) -> Result<ZipfReport, BackendError> {
    if n == 0 {
        return Err(BackendError::Runtime("operand length must be >= 1".to_string()));
    }
    if catalog == 0 {
        return Err(BackendError::Runtime("catalog must hold at least one pair".to_string()));
    }
    if requests == 0 {
        return Err(BackendError::Runtime("need at least one request".to_string()));
    }
    if zipf_s < 0.0 || !zipf_s.is_finite() {
        return Err(BackendError::Runtime("zipf exponent must be finite and >= 0".to_string()));
    }
    let mut rng = Rng::new(seed);
    let pairs: Vec<(Vec<f64>, Vec<f64>)> = (0..catalog)
        .map(|_| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        })
        .collect();
    let draws = zipf_draws(&mut rng, catalog, requests, zipf_s);
    let unique_pairs_drawn = {
        let mut seen = vec![false; catalog];
        draws.iter().for_each(|&k| seen[k] = true);
        seen.iter().filter(|&&s| s).count()
    };

    let wire_err = |e: super::net::WireCallError| BackendError::Runtime(e.to_string());
    let mut client = WireClient::connect(addr)
        .map_err(|e| BackendError::Runtime(format!("connect {addr}: {e}")))?;
    client
        .set_read_timeout(Some(Duration::from_secs(60)))
        .map_err(|e| BackendError::Runtime(format!("read timeout: {e}")))?;

    // Both passes re-send their frame on BUSY (inside the client), so the
    // byte accounting charges each pass its retries.
    let dot_frame_len = (HEADER_LEN + 4 + 16 * n) as u64;
    let handle_frame_len = (HEADER_LEN + 16) as u64;

    // Pass 1: payload resubmission — every draw ships 16n+4 payload bytes.
    let mut baseline_values = Vec::with_capacity(requests);
    let mut baseline_lat = Vec::with_capacity(requests);
    let retries_before = client.busy_retries();
    let baseline_started = Instant::now();
    for &k in &draws {
        let (x, y) = &pairs[k];
        let t0 = Instant::now();
        let r = client.dot(x, y).map_err(wire_err)?;
        baseline_lat.push(t0.elapsed().as_nanos() as f64);
        baseline_values.push(r.value);
    }
    let baseline_elapsed_ns = baseline_started.elapsed().as_nanos() as f64;
    let baseline_sends = requests as u64 + (client.busy_retries() - retries_before);

    // Register the catalog once: the amortized O(catalog·n) cost the
    // handle pass trades the per-draw O(n) for.
    let register_started = Instant::now();
    let mut handles = Vec::with_capacity(catalog);
    for (x, y) in &pairs {
        let (a, _, _) = client.register(x).map_err(wire_err)?;
        let (b, _, _) = client.register(y).map_err(wire_err)?;
        handles.push((a, b));
    }
    let register_ns = register_started.elapsed().as_nanos() as f64;
    let register_bytes = 2 * catalog as u64 * (HEADER_LEN + 4 + 8 * n) as u64;

    // Pass 2: handle submission over the identical draw sequence.
    let (before_stats, _, before_cache) = client.stats_cache(None).map_err(wire_err)?;
    let mut handle_values = Vec::with_capacity(requests);
    let mut handle_lat = Vec::with_capacity(requests);
    let retries_before = client.busy_retries();
    let handles_started = Instant::now();
    for &k in &draws {
        let (a, b) = handles[k];
        let t0 = Instant::now();
        let r = client.dot_handles(a, b).map_err(wire_err)?;
        handle_lat.push(t0.elapsed().as_nanos() as f64);
        handle_values.push(r.value);
    }
    let handles_elapsed_ns = handles_started.elapsed().as_nanos() as f64;
    let handle_sends = requests as u64 + (client.busy_retries() - retries_before);
    let (after_stats, _, after_cache) = client.stats_cache(None).map_err(wire_err)?;
    debug_assert!(after_stats.completed >= before_stats.completed);

    let value_mismatches = baseline_values
        .iter()
        .zip(&handle_values)
        .filter(|(b, h)| b.to_bits() != h.to_bits())
        .count();
    let baseline_checksum: f64 = baseline_values.iter().sum();
    let handle_checksum: f64 = handle_values.iter().sum();
    let bit_parity =
        value_mismatches == 0 && baseline_checksum.to_bits() == handle_checksum.to_bits();

    let cache = WireCacheStats {
        store_entries: after_cache.store_entries,
        store_resident_bytes: after_cache.store_resident_bytes,
        store_registered: after_cache.store_registered - before_cache.store_registered,
        store_evictions: after_cache.store_evictions - before_cache.store_evictions,
        cache_lookups: after_cache.cache_lookups - before_cache.cache_lookups,
        cache_hits: after_cache.cache_hits - before_cache.cache_hits,
        cache_misses: after_cache.cache_misses - before_cache.cache_misses,
        cache_evictions: after_cache.cache_evictions - before_cache.cache_evictions,
    };

    let pass = |elapsed_ns: f64, sends: u64, frame_len: u64, lat: Vec<f64>, checksum: f64| {
        let (lat, _) = finite_sorted(lat);
        ZipfPassReport {
            elapsed_ns,
            reqs_per_s: requests as f64 / elapsed_ns * 1e9,
            bytes_sent: sends * frame_len,
            bytes_per_request: (sends * frame_len) as f64 / requests as f64,
            latency_p50_ns: pct_or_nan(&lat, 50.0),
            latency_p99_ns: pct_or_nan(&lat, 99.0),
            checksum,
        }
    };
    Ok(ZipfReport {
        requests,
        catalog,
        zipf_s,
        n,
        unique_pairs_drawn,
        baseline: pass(
            baseline_elapsed_ns,
            baseline_sends,
            dot_frame_len,
            baseline_lat,
            baseline_checksum,
        ),
        handles: pass(
            handles_elapsed_ns,
            handle_sends,
            handle_frame_len,
            handle_lat,
            handle_checksum,
        ),
        speedup: baseline_elapsed_ns / handles_elapsed_ns.max(1.0),
        register_ns,
        register_bytes,
        value_mismatches,
        bit_parity,
        cache,
    })
}

/// Outcome of the end-to-end data-integrity scenario
/// ([`run_load_integrity`]): two passes over the same deterministic
/// handle-traffic stream — one with the three corruption fault sites
/// armed ([`FaultSite::INTEGRITY`]), one fault-free — with every
/// delivered value bit-compared against a local reference computation.
///
/// The hard gates `tools/validate_bench.py` applies:
///
/// * `detected == total_injected` — every injected corruption was caught
///   by some tier's detector (CRC trailer, store scrubber, verify-on-hit);
/// * `delivered_corrupt == 0` — no corrupt payload ever reached the
///   client as a result;
/// * `clean_detections == 0` and `clean_bit_parity` — the detectors
///   raise no false positives on a fault-free run with every
///   verification knob at maximum.
#[derive(Clone, Debug)]
pub struct IntegrityReport {
    /// Draws in the injected pass (each settles to a verified value).
    pub requests: usize,
    /// Distinct operand pairs in the catalog.
    pub catalog: usize,
    /// Operand length (updates per request).
    pub n: usize,
    /// Fired fault count per integrity site label
    /// ([`FaultSite::INTEGRITY`] order, zeros included).
    pub injected: Vec<(&'static str, u64)>,
    /// Total corruptions injected across the three sites.
    pub total_injected: u64,
    /// Total corruptions caught by any tier's detector — client CRC
    /// rejections + store quarantines + verify-on-hit evictions. The
    /// headline gate is `detected == total_injected`.
    pub detected: u64,
    /// Response frames the client's CRC verification rejected.
    pub corrupt_frames_detected: u64,
    /// Typed CORRUPT_OPERAND errors observed over the wire (one per
    /// store quarantine).
    pub corrupt_operands_detected: u64,
    /// Poisoned result-cache entries evicted by verify-on-hit sampling
    /// (the server heals these silently; the count is the evidence).
    pub cache_poisoned_evicted: u64,
    /// Delivered results whose bits differ from the local reference —
    /// corrupt payloads that escaped every detector. Hard-gated to 0.
    pub delivered_corrupt: usize,
    /// Draws that settled to a bit-correct value (after any retries).
    pub completed_ok: usize,
    /// Handle re-registrations performed to recover quarantined operands.
    pub reregisters: usize,
    /// Request retries absorbed while recovering from typed detections.
    pub retries: usize,
    /// Ok responses that were missing the requested certified error
    /// bound (every draw opts in via `FLAG_ERRBOUND`; must be 0).
    pub bound_missing: usize,
    /// Server scrub/verification counters after the injected pass.
    pub scrub: WireScrubStats,
    /// Draws in the fault-free control pass.
    pub clean_requests: usize,
    /// Detections raised during the control pass — typed corruption
    /// errors, quarantines, or poison evictions with no fault armed.
    /// Any value above 0 is a false positive; hard-gated to 0.
    pub clean_detections: u64,
    /// `true` iff every control-pass value was bit-identical to the
    /// local reference with CRC, scrub-on-lookup and verify-on-hit all
    /// enabled — the "verification changes no bits" parity contract.
    pub clean_bit_parity: bool,
}

/// Drive one catalog pass: register `pairs` over `client`, then submit
/// `requests` round-robin handle draws (each requesting the certified
/// error bound), recovering from typed corruption detections by
/// re-registering and retrying. Returns per-class detection counts.
#[allow(clippy::type_complexity)]
fn integrity_pass(
    client: &mut WireClient,
    pairs: &[(Vec<f64>, Vec<f64>)],
    expected: &[f64],
    requests: usize,
) -> Result<(usize, usize, u64, u64, usize, usize, usize), BackendError> {
    let wire_err = |e: WireCallError| BackendError::Runtime(e.to_string());
    let mut handles = Vec::with_capacity(pairs.len());
    for (x, y) in pairs {
        let (a, _, _) = client.register(x).map_err(wire_err)?;
        let (b, _, _) = client.register(y).map_err(wire_err)?;
        handles.push((a, b));
    }
    let mut completed_ok = 0usize;
    let mut delivered_corrupt = 0usize;
    let mut corrupt_frames = 0u64;
    let mut corrupt_operands = 0u64;
    let mut reregisters = 0usize;
    let mut retries = 0usize;
    let mut bound_missing = 0usize;
    for k in 0..requests {
        let idx = k % pairs.len();
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            if attempts > 8 {
                return Err(BackendError::Runtime(format!(
                    "integrity draw {k} did not settle after {attempts} attempts"
                )));
            }
            let (a, b) = handles[idx];
            match client.dot_handles_with_errbound(a, b) {
                Ok(r) => {
                    if r.value.to_bits() == expected[idx].to_bits() {
                        completed_ok += 1;
                    } else {
                        delivered_corrupt += 1;
                    }
                    match r.err_bound {
                        Some(bound) if bound.is_finite() && bound >= 0.0 => {}
                        _ => bound_missing += 1,
                    }
                    break;
                }
                // Response frame failed the client's CRC check: the
                // typed protocol rejection *is* the detection. Retry —
                // the stream stays aligned (the payload was consumed).
                Err(WireCallError::Protocol(e)) if e.code == ErrorCode::CorruptFrame => {
                    corrupt_frames += 1;
                    retries += 1;
                }
                // The store's scrubber quarantined a resident operand:
                // re-register (content-addressing restores the same
                // handle from clean bytes) and retry.
                Err(WireCallError::Server(e)) if e.code == ErrorCode::CorruptOperand => {
                    corrupt_operands += 1;
                    let (x, y) = &pairs[idx];
                    let (a2, _, _) = client.register(x).map_err(wire_err)?;
                    let (b2, _, _) = client.register(y).map_err(wire_err)?;
                    handles[idx] = (a2, b2);
                    reregisters += 1;
                    retries += 1;
                }
                // Aftermath of a quarantine eviction seen by a later
                // draw of the same pair — recover the same way, but it
                // is not a fresh detection.
                Err(WireCallError::Server(e)) if e.code == ErrorCode::UnknownHandle => {
                    let (x, y) = &pairs[idx];
                    let (a2, _, _) = client.register(x).map_err(wire_err)?;
                    let (b2, _, _) = client.register(y).map_err(wire_err)?;
                    handles[idx] = (a2, b2);
                    reregisters += 1;
                    retries += 1;
                }
                Err(e) => return Err(wire_err(e)),
            }
        }
    }
    Ok((
        completed_ok,
        delivered_corrupt,
        corrupt_frames,
        corrupt_operands,
        reregisters,
        retries,
        bound_missing,
    ))
}

/// The `--chaos` integrity scenario: end-to-end corruption detection
/// across every tier of the serving stack, measured over the socket.
///
/// **Injected pass.** A loopback `serve-net` server runs with all three
/// verification tiers armed — CRC-sealed frames (revision 1.4),
/// scrub-on-lookup in the operand store, verify-on-hit at rate 1.0 in
/// the result cache — and a deterministic fault plan over the three
/// corruption sites ([`FaultSite::INTEGRITY`]): a resident-operand bit
/// flip, an in-flight frame-CRC corruption, and a result-cache
/// poisoning. The client drives `requests` round-robin handle draws
/// over a `catalog`-pair corpus, bit-compares every delivered value
/// against a local reference, and recovers from typed detections by
/// re-registering and retrying.
///
/// **Clean pass.** The identical stream against a fault-free server
/// with the same verification posture: any detection is a false
/// positive, and every value must be bit-identical to the reference —
/// verification must change no bits (the rate-0/CRC-off parity contract
/// is pinned separately in `tests/properties.rs`).
pub fn run_load_integrity(
    cfg: &ServeConfig,
    opts: AsyncOptions,
    n: usize,
    catalog: usize,
    requests: usize,
    seed: u64,
) -> Result<IntegrityReport, BackendError> {
    if n == 0 {
        return Err(BackendError::Runtime("operand length must be >= 1".to_string()));
    }
    if catalog < 2 {
        return Err(BackendError::Runtime(
            "integrity catalog needs >= 2 pairs (the cache-poison site arms the \
             second insert)"
                .to_string(),
        ));
    }
    if requests < 2 * catalog {
        return Err(BackendError::Runtime(
            "need >= 2 draws per catalog pair so poisoned entries are re-hit".to_string(),
        ));
    }
    let mut cfg = cfg.clone();
    cfg.verify_hit_rate = 1.0;

    let mut rng = Rng::new(seed);
    let pairs: Vec<(Vec<f64>, Vec<f64>)> = (0..catalog)
        .map(|_| {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (x, y)
        })
        .collect();
    let reference = DotService::new(cfg.clone())?;
    let expected: Vec<f64> = pairs
        .iter()
        .map(|(x, y)| Ok(reference.submit(&KernelInput::Dot(x, y))?.value))
        .collect::<Result<_, BackendError>>()?;

    // Deterministic triggers, one corruption per site: the bit flip lands
    // mid-stream (arrival = one resolve per draw), the poison on the
    // second cache insert (the first catalog cycle), the CRC corruption
    // in the final quarter of sealed result frames.
    let plan = FaultPlan::none()
        .with(FaultSite::StoreBitFlip, (requests as u64 / 2).max(1))
        .with(FaultSite::CachePoison, 2)
        .with(FaultSite::FrameCrcCorrupt, (3 * requests as u64 / 4).max(1));
    let injector = FaultInjector::new(plan);
    let server = NetServer::bind_with(
        "127.0.0.1:0",
        cfg.clone(),
        opts,
        NetOptions {
            faults: Some(injector.clone()),
            ..NetOptions::default()
        },
    )?;
    server.service().store().set_verify_on_lookup(true);
    let wire_err = |e: WireCallError| BackendError::Runtime(e.to_string());
    let addr = server.local_addr().to_string();
    let mut client = WireClient::connect(&addr)
        .map_err(|e| BackendError::Runtime(format!("connect {addr}: {e}")))?;
    client
        .set_read_timeout(Some(Duration::from_secs(60)))
        .map_err(|e| BackendError::Runtime(format!("read timeout: {e}")))?;
    client.set_crc(true);

    let (
        completed_ok,
        delivered_corrupt,
        corrupt_frames_detected,
        corrupt_operands_detected,
        reregisters,
        retries,
        bound_missing,
    ) = integrity_pass(&mut client, &pairs, &expected, requests)?;
    let (_, _, _, scrub) = client.stats_scrub(None).map_err(wire_err)?;
    drop(client);
    drop(server);

    let injected: Vec<(&'static str, u64)> = FaultSite::INTEGRITY
        .iter()
        .map(|&site| (site.label(), injector.fired(site)))
        .collect();
    let total_injected: u64 = injected.iter().map(|&(_, c)| c).sum();
    let detected = corrupt_frames_detected + scrub.scrub_quarantined + scrub.cache_poisoned;

    // Clean control pass: identical stream and verification posture, no
    // injector. Every detection here is a false positive.
    let clean_server = NetServer::bind_with(
        "127.0.0.1:0",
        cfg,
        opts,
        NetOptions::default(),
    )?;
    clean_server.service().store().set_verify_on_lookup(true);
    let clean_addr = clean_server.local_addr().to_string();
    let mut clean_client = WireClient::connect(&clean_addr)
        .map_err(|e| BackendError::Runtime(format!("connect {clean_addr}: {e}")))?;
    clean_client
        .set_read_timeout(Some(Duration::from_secs(60)))
        .map_err(|e| BackendError::Runtime(format!("read timeout: {e}")))?;
    clean_client.set_crc(true);
    let (clean_ok, clean_mismatch, clean_frames, clean_operands, clean_rereg, _, clean_bound) =
        integrity_pass(&mut clean_client, &pairs, &expected, requests)?;
    let (_, _, _, clean_scrub) = clean_client.stats_scrub(None).map_err(wire_err)?;
    let clean_detections = clean_frames
        + clean_operands
        + clean_rereg as u64
        + clean_scrub.scrub_quarantined
        + clean_scrub.cache_poisoned;
    let clean_bit_parity =
        clean_mismatch == 0 && clean_bound == 0 && clean_ok == requests;

    Ok(IntegrityReport {
        requests,
        catalog,
        n,
        injected,
        total_injected,
        detected,
        corrupt_frames_detected,
        corrupt_operands_detected,
        cache_poisoned_evicted: scrub.cache_poisoned,
        delivered_corrupt,
        completed_ok,
        reregisters,
        retries,
        bound_missing,
        scrub,
        clean_requests: requests,
        clean_detections,
        clean_bit_parity,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ImplStyle;
    use crate::serve::ServeConfig;

    use crate::serve::{AsyncOptions, ThresholdMode};

    fn tiny_cfg(threads: usize, threshold: usize) -> ServeConfig {
        ServeConfig {
            threads,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(threshold),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        }
    }

    fn tiny_service(threads: usize, threshold: usize) -> DotService {
        DotService::new(tiny_cfg(threads, threshold)).unwrap()
    }

    #[test]
    fn parse_mix_accepts_weights_and_bare_sizes() {
        let m = parse_mix("1024:0.9, 65536:0.1").unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0], MixEntry { n: 1024, weight: 0.9 });
        let m = parse_mix("64,128").unwrap();
        assert_eq!(m[1], MixEntry { n: 128, weight: 1.0 });
    }

    #[test]
    fn parse_mix_rejects_garbage() {
        assert!(parse_mix("").is_err());
        assert!(parse_mix("abc:1").is_err());
        assert!(parse_mix("64:zzz").is_err());
        assert!(parse_mix("0:1").is_err());
        assert!(parse_mix("64:-1").is_err());
        assert!(parse_mix("64:0").is_err());
    }

    #[test]
    fn sampling_is_deterministic_and_covers_the_mix() {
        let mix = default_mix(true);
        let a = sample_sizes(&mix, 500, 42);
        let b = sample_sizes(&mix, 500, 42);
        assert_eq!(a, b);
        for e in &mix {
            assert!(a.contains(&e.n), "size {} never sampled", e.n);
        }
        let c = sample_sizes(&mix, 500, 43);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn closed_loop_report_is_consistent() {
        let service = tiny_service(2, 4096);
        let mix = vec![
            MixEntry { n: 256, weight: 0.8 },
            MixEntry { n: 8192, weight: 0.2 },
        ];
        let r = run_load(&service, &mix, 64, 8, LoadMode::Closed, 7).unwrap();
        assert_eq!(r.requests, 64);
        assert_eq!(r.batches, 8);
        assert_eq!(r.fused + r.sharded, 64);
        assert!(r.sharded > 0, "8192-update requests must shard at threshold 4096");
        assert!(r.fused > 0);
        assert!(r.busy_ns > 0.0 && r.mflops > 0.0 && r.gups > 0.0);
        assert!(r.latency_p50_ns <= r.latency_p99_ns);
        assert!(r.latency_p99_ns <= r.latency_max_ns);
        assert_eq!(r.flops, r.updates * 5, "kahan dot: 5 flops per update");
        // Same seed + same threads ⇒ identical request stream and results.
        let again = run_load(&service, &mix, 64, 8, LoadMode::Closed, 7).unwrap();
        assert_eq!(r.checksum.to_bits(), again.checksum.to_bits());
        assert_eq!((r.fused, r.sharded), (again.fused, again.sharded));
    }

    #[test]
    fn open_loop_latency_includes_queueing() {
        let service = tiny_service(1, usize::MAX);
        let mix = vec![MixEntry { n: 1024, weight: 1.0 }];
        // An absurdly fast arrival rate: every request is effectively
        // queued behind the previous batch, so tail latency must exceed
        // one batch's service time by a growing margin.
        let r = run_load(&service, &mix, 32, 4, LoadMode::Open { rate_rps: 1e12 }, 3).unwrap();
        assert!(r.latency_max_ns >= r.latency_p50_ns);
        assert!(r.elapsed_ns >= r.busy_ns * 0.99);
        // And the queue means later requests wait longer than earlier ones.
        assert!(r.latency_max_ns > r.latency_p50_ns, "{r:?}");
    }

    #[test]
    fn run_load_rejects_bad_parameters() {
        let service = tiny_service(1, 100);
        let mix = vec![MixEntry { n: 64, weight: 1.0 }];
        assert!(run_load(&service, &[], 10, 2, LoadMode::Closed, 1).is_err());
        assert!(run_load(&service, &mix, 0, 2, LoadMode::Closed, 1).is_err());
        let bad_rate = LoadMode::Open { rate_rps: 0.0 };
        assert!(run_load(&service, &mix, 10, 2, bad_rate, 1).is_err());
    }

    #[test]
    fn async_open_loop_matches_sync_checksum_and_reports_queue_stats() {
        let mix = vec![
            MixEntry { n: 256, weight: 0.8 },
            MixEntry { n: 8192, weight: 0.2 },
        ];
        let sync = tiny_service(2, 4096);
        let sync_ops = OperandPool::generate(&mix, 7, sync.pool());
        let sync_report =
            run_load_with(&sync, &mix, &sync_ops, 64, 8, LoadMode::Closed, 7).unwrap();
        let asy = AsyncDotService::new(tiny_cfg(2, 4096), AsyncOptions::default()).unwrap();
        let asy_ops = OperandPool::generate(&mix, 7, asy.service().pool());
        // A rate fast enough to finish quickly, slow enough to be sane.
        let r = run_load_async(&asy, &mix, &asy_ops, 64, 1e6, 7).unwrap();
        assert_eq!(r.load.requests, 64);
        assert_eq!(r.load.fused + r.load.sharded, 64);
        assert_eq!(
            r.load.checksum.to_bits(),
            sync_report.checksum.to_bits(),
            "async and sync must serve bit-identical results at fixed T"
        );
        assert_eq!((r.load.fused, r.load.sharded), (sync_report.fused, sync_report.sharded));
        assert!(r.load.latency_p50_ns > 0.0);
        assert!(r.load.latency_p50_ns <= r.load.latency_p99_ns);
        assert!(r.load.latency_p99_ns <= r.load.latency_max_ns);
        assert!(r.max_queue_depth <= r.queue_depth, "{r:?}");
        assert!(r.dispatches >= 1 && r.arrival_batches >= 1, "{r:?}");
        assert!(r.pool_utilization > 0.0 && r.pool_utilization <= 1.0, "{r:?}");
        assert!(r.load.mflops > 0.0 && r.load.reqs_per_s > 0.0);
    }

    #[test]
    fn run_load_async_rejects_bad_parameters() {
        let asy = AsyncDotService::new(tiny_cfg(1, 100), AsyncOptions::default()).unwrap();
        let mix = vec![MixEntry { n: 64, weight: 1.0 }];
        let ops = OperandPool::generate(&mix, 1, asy.service().pool());
        assert!(run_load_async(&asy, &[], &ops, 10, 1e5, 1).is_err());
        assert!(run_load_async(&asy, &mix, &ops, 0, 1e5, 1).is_err());
        assert!(run_load_async(&asy, &mix, &ops, 10, 0.0, 1).is_err());
    }

    #[test]
    fn chaos_run_resolves_every_request_and_recovers() {
        use crate::serve::faults::FaultPlan;
        // Explicit triggers (not seeded) so the panic is guaranteed to land
        // within this short run: the very first pool job dies, the second
        // arrival batch stalls long past the request deadline, and a latch
        // wake is delayed.
        let plan = FaultPlan::none()
            .with(FaultSite::WorkerPanic, 1)
            .with_stall(FaultSite::DispatcherStall, 2, Duration::from_millis(20))
            .with_stall(FaultSite::LatchWakeDelay, 3, Duration::from_millis(2));
        let injector = FaultInjector::new(plan);
        let asy = AsyncDotService::new_with_faults(
            tiny_cfg(2, 4096),
            AsyncOptions::default(),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        let mix = vec![MixEntry { n: 256, weight: 1.0 }];
        // Generate operands through a clean pool: first-touch runs pool jobs,
        // and the armed WorkerPanic trigger must fire during the chaos run
        // itself, not while preparing its inputs.
        let clean = DotService::new(tiny_cfg(2, 4096)).unwrap();
        let ops = OperandPool::generate(&mix, 7, clean.pool());
        let r = run_load_chaos(
            &asy,
            &injector,
            &mix,
            &ops,
            48,
            1e5,
            Some(Duration::from_millis(10)),
            7,
            Duration::from_secs(60),
        )
        .unwrap();
        assert_eq!(r.requests, 48);
        assert_eq!(
            r.completed_ok
                + r.deadline_shed
                + r.quota_shed
                + r.worker_panics
                + r.other_errors
                + r.hung,
            r.requests,
            "every request must land in exactly one bucket: {r:?}"
        );
        assert_eq!(r.hung, 0, "no request may hang under faults: {r:?}");
        assert!(r.worker_panics >= 1, "first-job panic must fail its dispatch: {r:?}");
        assert!(r.total_injected >= 2, "panic + stall must both fire: {r:?}");
        assert_eq!(r.injected.len(), FaultSite::ALL.len(), "stable per-site schema");
        let by_label: HashMap<&str, u64> = r.injected.iter().copied().collect();
        assert_eq!(by_label["worker_panic"], 1);
        assert_eq!(by_label["socket_read_error"], 0, "no socket sites in-process");
        assert!(r.recovery_verified, "post-chaos probe must be bit-identical: {r:?}");
        assert!(r.recovery_latency_ns.is_finite() && r.recovery_latency_ns > 0.0);
    }

    #[test]
    fn chaos_with_idle_injector_matches_uninjected_checksum_bits() {
        use crate::serve::faults::FaultPlan;
        // A compiled-in but empty injector must be invisible: same request
        // stream, bit-identical checksum to the plain async service.
        let mix = vec![MixEntry { n: 256, weight: 1.0 }];
        let plain = AsyncDotService::new(tiny_cfg(2, 4096), AsyncOptions::default()).unwrap();
        let plain_ops = OperandPool::generate(&mix, 7, plain.service().pool());
        let want = run_load_async(&plain, &mix, &plain_ops, 32, 1e6, 7).unwrap();
        let injector = FaultInjector::new(FaultPlan::none());
        let idle = AsyncDotService::new_with_faults(
            tiny_cfg(2, 4096),
            AsyncOptions::default(),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        let idle_ops = OperandPool::generate(&mix, 7, idle.service().pool());
        let got = run_load_async(&idle, &mix, &idle_ops, 32, 1e6, 7).unwrap();
        assert_eq!(got.load.checksum.to_bits(), want.load.checksum.to_bits());
        assert_eq!(injector.total_fired(), 0);
    }

    #[test]
    fn chaos_on_qos_service_buckets_quota_sheds_and_recovers() {
        use crate::serve::faults::FaultPlan;
        use crate::serve::QosPolicy;
        // Tenant-facing sites on a weighted-fair service: the 3rd and 5th
        // admissions are rejected as (injected) quota sheds, and the very
        // first weighted-fair drain hits a starvation stall. Every request
        // must still resolve exactly once.
        let plan = FaultPlan::none()
            .with(FaultSite::QuotaAdmissionReject, 3)
            .with(FaultSite::QuotaAdmissionReject, 5)
            .with_stall(FaultSite::StarvationStall, 1, Duration::from_millis(10));
        let injector = FaultInjector::new(plan);
        let qos = QosPolicy::parse("a:3,b:1").unwrap();
        let asy = AsyncDotService::new_with_qos(
            tiny_cfg(2, 4096),
            AsyncOptions::default(),
            Some(qos),
            Some(Arc::clone(&injector)),
        )
        .unwrap();
        let mix = vec![MixEntry { n: 256, weight: 1.0 }];
        let clean = DotService::new(tiny_cfg(2, 4096)).unwrap();
        let ops = OperandPool::generate(&mix, 11, clean.pool());
        let r = run_load_chaos(
            &asy,
            &injector,
            &mix,
            &ops,
            32,
            1e5,
            None,
            11,
            Duration::from_secs(60),
        )
        .unwrap();
        assert_eq!(
            r.completed_ok
                + r.deadline_shed
                + r.quota_shed
                + r.worker_panics
                + r.other_errors
                + r.hung,
            r.requests,
            "every request must land in exactly one bucket: {r:?}"
        );
        assert_eq!(r.hung, 0, "no request may hang under tenant faults: {r:?}");
        assert_eq!(r.quota_shed, 2, "both injected quota rejects must shed: {r:?}");
        assert_eq!(injector.fired(FaultSite::QuotaAdmissionReject), 2);
        assert_eq!(
            injector.fired(FaultSite::StarvationStall),
            1,
            "weighted-fair drain must arm the starvation-stall site: {r:?}"
        );
        assert!(r.recovery_verified, "post-chaos probe must be bit-identical: {r:?}");
        // The service's own per-tenant counters agree with the buckets.
        let shed: u64 = asy.tenant_stats().iter().map(|t| t.quota_shed).sum();
        assert_eq!(shed, 2);
    }

    #[test]
    fn tenant_load_reports_per_tenant_rows_and_quota_sheds() {
        use crate::serve::QosPolicy;
        // Tenant b has quota 0: every one of its requests must shed as
        // QUOTA (never BUSY), while tenant a's full stream completes.
        let qos = QosPolicy::parse("a:3:64,b:1:0").unwrap();
        let asy = AsyncDotService::new_with_qos(
            tiny_cfg(2, 4096),
            AsyncOptions::default(),
            Some(qos),
            None,
        )
        .unwrap();
        let mix = vec![MixEntry { n: 256, weight: 1.0 }];
        let ops = OperandPool::generate(&mix, 13, asy.service().pool());
        let r = run_load_tenants(
            &asy,
            &mix,
            &ops,
            &[24, 8],
            1e5,
            None,
            13,
            Duration::from_secs(60),
        )
        .unwrap();
        assert_eq!(r.requests, 32);
        assert_eq!(r.rows.len(), 2);
        let a = &r.rows[0];
        assert_eq!((a.name.as_str(), a.weight, a.quota), ("a", 3, Some(64)));
        assert_eq!(a.offered, 24);
        assert_eq!(a.admitted, 24, "{a:?}");
        assert_eq!(a.completed_ok, 24, "{a:?}");
        assert_eq!((a.quota_shed, a.busy_shed, a.deadline_shed), (0, 0, 0));
        assert!(a.latency_p50_ns > 0.0 && a.latency_p50_ns <= a.latency_p99_ns);
        assert!(a.latency_p99_ns <= a.latency_max_ns);
        let b = &r.rows[1];
        assert_eq!((b.name.as_str(), b.weight, b.quota), ("b", 1, Some(0)));
        assert_eq!(b.offered, 8);
        assert_eq!(b.quota_shed, 8, "quota-0 tenant sheds everything: {b:?}");
        assert_eq!((b.admitted, b.completed_ok, b.busy_shed), (0, 0, 0));
        assert!(b.latency_p50_ns.is_nan(), "no completions, no percentiles");
        // Per-request accounting on the service agrees with the rows.
        let stats = asy.tenant_stats();
        let sa = stats.iter().find(|t| t.tenant == 0).unwrap();
        let sb = stats.iter().find(|t| t.tenant == 1).unwrap();
        assert_eq!((sa.admitted, sa.quota_shed), (24, 0));
        assert_eq!((sb.admitted, sb.quota_shed), (0, 8));
    }

    #[test]
    fn interleaving_checksums_are_bit_identical_across_schedules() {
        use crate::serve::QosPolicy;
        let mix = vec![
            MixEntry { n: 256, weight: 0.8 },
            MixEntry { n: 8192, weight: 0.2 },
        ];
        let policies: Vec<Option<QosPolicy>> = vec![
            None,
            Some(QosPolicy::parse("a:3,b:1").unwrap()),
            Some(QosPolicy::parse("a:1,b:3").unwrap()),
        ];
        let mut reports = Vec::new();
        for qos in policies {
            let asy =
                AsyncDotService::new_with_qos(tiny_cfg(2, 4096), AsyncOptions::default(), qos, None)
                    .unwrap();
            let ops = OperandPool::generate(&mix, 7, asy.service().pool());
            reports.push(run_interleaving_checksum(&asy, &mix, &ops, 64, 2, 7).unwrap());
        }
        let fifo = &reports[0];
        assert_eq!(fifo.requests, 64);
        assert_eq!(fifo.fused + fifo.sharded, 64);
        assert!(fifo.sharded > 0 && fifo.fused > 0);
        for r in &reports[1..] {
            assert_eq!(
                r.checksum.to_bits(),
                fifo.checksum.to_bits(),
                "scheduling must never fork the numerics: {reports:?}"
            );
            assert_eq!((r.fused, r.sharded), (fifo.fused, fifo.sharded));
        }
    }

    #[test]
    fn finite_sorted_filters_and_counts_non_finite_latencies() {
        let (sorted, dropped) = finite_sorted(vec![3.0, f64::NAN, 1.0, f64::INFINITY, 2.0]);
        assert_eq!(sorted, vec![1.0, 2.0, 3.0]);
        assert_eq!(dropped, 2);
        assert_eq!(pct_or_nan(&sorted, 50.0), 2.0);
        let (empty, dropped) = finite_sorted(vec![f64::NAN]);
        assert!(empty.is_empty());
        assert_eq!(dropped, 1);
        assert!(pct_or_nan(&empty, 50.0).is_nan(), "empty percentile is NaN, not a panic");
    }

    #[test]
    fn wire_load_matches_async_checksum_bits() {
        use crate::serve::net::NetServer;
        let mix = vec![
            MixEntry { n: 256, weight: 0.8 },
            MixEntry { n: 8192, weight: 0.2 },
        ];
        let server =
            NetServer::bind("127.0.0.1:0", tiny_cfg(2, 4096), AsyncOptions::default()).unwrap();
        let ops = OperandPool::generate(&mix, 7, server.service().service().pool());
        let fpu = server
            .service()
            .service()
            .dot_spec()
            .class
            .flops_per_update();
        let wire = run_load_wire(
            &server.local_addr().to_string(),
            &mix,
            &ops,
            48,
            1e6,
            2,
            fpu,
            7,
        )
        .unwrap();
        assert_eq!(wire.load.requests, 48);
        assert_eq!(wire.load.fused + wire.load.sharded, 48);
        assert!(wire.load.latency_p50_ns > 0.0);
        assert!(wire.load.latency_p50_ns <= wire.load.latency_p99_ns);
        assert!(wire.max_queue_depth <= wire.queue_depth);
        // Bit-parity against the in-process open-loop run: same seed, same
        // operand bytes, same T and threshold ⇒ identical checksum.
        let asy = AsyncDotService::new(tiny_cfg(2, 4096), AsyncOptions::default()).unwrap();
        let asy_ops = OperandPool::generate(&mix, 7, asy.service().pool());
        let r = run_load_async(&asy, &mix, &asy_ops, 48, 1e6, 7).unwrap();
        assert_eq!(
            wire.load.checksum.to_bits(),
            r.load.checksum.to_bits(),
            "wire and in-process checksums must be bit-identical"
        );
        assert_eq!((wire.load.fused, wire.load.sharded), (r.load.fused, r.load.sharded));
    }

    #[test]
    fn run_load_wire_rejects_bad_parameters() {
        use crate::serve::net::NetServer;
        let server =
            NetServer::bind("127.0.0.1:0", tiny_cfg(1, 100), AsyncOptions::default()).unwrap();
        let addr = server.local_addr().to_string();
        let mix = vec![MixEntry { n: 64, weight: 1.0 }];
        let ops = OperandPool::generate(&mix, 1, server.service().service().pool());
        assert!(run_load_wire(&addr, &[], &ops, 10, 1e5, 1, 5, 1).is_err());
        assert!(run_load_wire(&addr, &mix, &ops, 0, 1e5, 1, 5, 1).is_err());
        assert!(run_load_wire(&addr, &mix, &ops, 10, 0.0, 1, 5, 1).is_err());
    }

    #[test]
    fn integrity_run_detects_every_injection_and_raises_no_false_positives() {
        let r = run_load_integrity(&tiny_cfg(2, 4096), AsyncOptions::default(), 256, 3, 12, 41)
            .unwrap();
        // All three corruption sites fired exactly once under the
        // deterministic plan, and every injection was caught by its tier.
        assert_eq!(r.total_injected, 3, "per-site: {:?}", r.injected);
        assert_eq!(r.detected, r.total_injected);
        assert_eq!(r.corrupt_frames_detected, 1);
        assert_eq!(r.corrupt_operands_detected, 1);
        assert_eq!(r.cache_poisoned_evicted, 1);
        assert_eq!(r.scrub.scrub_quarantined, 1);
        // The delivery contract: zero corrupt payloads reached the
        // client, every draw settled to a bit-correct value, and every
        // response carried its certified error bound.
        assert_eq!(r.delivered_corrupt, 0);
        assert_eq!(r.completed_ok, r.requests);
        assert_eq!(r.bound_missing, 0);
        assert!(r.reregisters >= 1, "quarantine recovery re-registers");
        // Fault-free control pass: no detector fired, bits unchanged.
        assert_eq!(r.clean_detections, 0);
        assert!(r.clean_bit_parity);
    }

    #[test]
    fn integrity_run_rejects_bad_parameters() {
        let cfg = tiny_cfg(1, 100);
        let opts = AsyncOptions::default();
        assert!(run_load_integrity(&cfg, opts, 0, 3, 12, 1).is_err());
        assert!(run_load_integrity(&cfg, opts, 64, 1, 12, 1).is_err());
        assert!(run_load_integrity(&cfg, opts, 64, 3, 5, 1).is_err());
    }

    #[test]
    fn operand_pool_shares_buffers_per_size() {
        let pool = ThreadPool::new(2);
        let mix = vec![
            MixEntry { n: 64, weight: 1.0 },
            MixEntry { n: 64, weight: 2.0 },
            MixEntry { n: 128, weight: 1.0 },
        ];
        let ops = OperandPool::generate(&mix, 9, &pool);
        assert_eq!(ops.bufs.len(), 2, "duplicate sizes share one buffer pair");
        match ops.dot_input(64) {
            KernelInput::Dot(x, y) => {
                assert_eq!(x.len(), 64);
                assert_eq!(y.len(), 64);
            }
            _ => unreachable!(),
        }
    }
}
