//! High-throughput dot-product serving: many independent requests over the
//! persistent worker pool.
//!
//! The paper's result is that a Kahan-compensated dot product costs
//! essentially nothing once SIMD + multi-accumulator unrolling hide the
//! compensation latency — so a *service* built on those kernels can offer
//! compensated accuracy at naive-dot throughput. This module is that
//! service: a synchronous-API, internally concurrent layer that accepts
//! batches of independent dot/sum requests and schedules them over the
//! [`ThreadPool`](crate::runtime::parallel::ThreadPool) the measurement
//! stack already owns.
//!
//! Two execution paths, one numerics:
//!
//! * **Fused** (small requests): the [`scheduler::BatchScheduler`] packs
//!   every small request of a batch into one dispatch; the pool's workers
//!   pull *whole requests* back-to-back from a shared atomic queue
//!   ([`ThreadPool::run_tasks`](crate::runtime::parallel::ThreadPool::run_tasks)),
//!   so a skewed mixture load-balances dynamically and the per-request
//!   critical path contains zero synchronization.
//! * **Sharded** (large requests): the request is split by the *same*
//!   cache-line-aligned partition and combined by the *same* deterministic
//!   compensated tree reduction as the measurement path
//!   ([`ParallelKernel`](crate::runtime::parallel::ParallelKernel)), so a
//!   lone huge request still uses the whole chip.
//!
//! The crossover between the two comes from the multicore saturation model
//! ([`crossover`]): once the chip's bandwidth saturates, extra workers are
//! worth more as *request* parallelism than as *shard* parallelism. It can
//! also be *measured*: [`calibrate`] times the single-thread kernel and the
//! per-dispatch overhead on this host and re-evaluates the same `n*`
//! formula with measured inputs ([`ThresholdMode::Calibrated`]).
//!
//! On top of the synchronous service sits the **asynchronous pipeline**
//! ([`queue`]): an [`AsyncDotService`] feeds a bounded submission queue
//! (blocking backpressure past the configured depth) into a dedicated
//! dispatcher thread that drains whatever has arrived inside a time/count-
//! bounded batching window, routes the drained batch through the same
//! [`scheduler::BatchScheduler`], and posts fused groups and shard
//! partitions to the pool *without blocking* — so new arrival batches
//! overlap in-flight sharded tails instead of serializing behind them.
//! Callers get a [`ResponseHandle`] per request (`wait()` /
//! `try_wait()`); at a fixed thread count every result is bit-identical
//! to the synchronous path, only completion *order* may differ.
//!
//! **Bit-parity contract.** Which path a request takes depends only on its
//! length and the service threshold — never on the rest of the batch — and
//! both paths run the service's single resolved kernel rung: fused = the
//! serial kernel over the whole input (identical to the sharded path at
//! `T = 1`), sharded = the fixed-`T` partition + tree reduce. A request
//! therefore returns bit-identical results whether submitted alone or
//! inside any batch, across repeated dispatches, at a fixed thread count —
//! serving is a scheduling layer, not a numerics fork (property-pinned in
//! `tests/properties.rs`). Keeping the compensated rung as the default
//! (`ServeConfig::compensated = true`) is the point of the exercise: under
//! load it costs the same as the naive rung, per the paper.
//!
//! Operand buffers should come from the 64-byte
//! [`AlignedVec`](crate::runtime::arena::AlignedVec) arena —
//! [`DotService::pool`] exposes the worker pool so callers can first-touch
//! buffers with the same chunk→worker assignment the sharded path streams
//! them with (the load generator in [`loadgen`] does exactly that).
//!
//! The **wire front-end** ([`net`], `serve-net` in the CLI) exposes the
//! same pipeline over TCP: a dependency-free length-prefixed binary
//! protocol ([`codec`]; normative spec in `docs/PROTOCOL.md`) with
//! per-connection reader/writer halves, so responses stream back in
//! completion order correlated by request id, and queue backpressure
//! reaches the socket as a typed BUSY frame ([`TrySubmit`]). Operands and
//! results travel as IEEE-754 bit patterns, extending the bit-parity
//! contract across the socket; the end-to-end dataflow narrative lives in
//! `docs/ARCHITECTURE.md`.
//!
//! The **resident operand store** ([`store`], protocol revision 1.3) lets
//! a client register an operand once and submit repeat requests by
//! content-addressed handle — 16 payload bytes instead of the full
//! vectors — while the **result cache** memoizes completed dot products
//! so a repeat `(handle, handle)` pair skips the pool entirely. Cached
//! results are bit-identical to recomputation (property-pinned, including
//! across the socket): the cache changes *when* a value is computed,
//! never *what* it is.

// The serving layer is the repo's public product surface: every public
// item must ship documented (CI builds with `-D warnings`, so a missing
// doc is a build failure, not a nit).
#![deny(missing_docs)]

pub mod codec;
pub mod crossover;
pub mod faults;
pub mod loadgen;
pub mod net;
pub mod queue;
pub mod scheduler;
pub mod store;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::arena::AlignedVec;
use crate::runtime::backend::native::{native_fn, preferred_kahan_style, NativeFn, SimdCaps};
use crate::runtime::backend::{BackendError, ImplStyle, KernelClass, KernelInput, KernelSpec};
use crate::runtime::hostbench::freq_ghz_with_source;
use crate::runtime::parallel::{compensated_tree_reduce, ThreadPool, CACHELINE_F64};

pub use codec::{
    ErrorCode, RequestMeta, WireCacheStats, WireError, WireResult, WireScrubStats, WireStats,
    WireTenantStats,
};
pub use crossover::{calibrate, model_crossover, model_p1_gups, service_crossover, Calibration};
pub use faults::{FaultInjector, FaultPlan, FaultPoint, FaultSite};
pub use loadgen::{
    default_mix, parse_mix, run_interleaving_checksum, run_load, run_load_async, run_load_chaos,
    run_load_integrity, run_load_tenants, run_load_wire, run_load_with, run_load_zipf,
    AsyncLoadReport, ChaosReport, IntegrityReport, InterleavingReport, LoadMode, LoadReport,
    MixEntry, OperandPool, TenantLoadReport, TenantLoadRow, WireLoadReport, ZipfPassReport,
    ZipfReport,
};
pub use net::{NetOptions, NetServer, WireCallError, WireClient};
pub use queue::{
    AsyncDotService, AsyncOptions, AsyncServeStats, QosPolicy, ResponseHandle, TenantClass,
    TenantStats, TrySubmit,
};
pub use scheduler::{BatchScheduler, DispatchPlan, ExecPath};
pub use store::{
    handle_of, operand_digest, sha256, CacheStats, CachedResult, OperandStore, RegisterOutcome,
    ResultCache, ScrubOutcome, StoreError, StoreStats, CACHE_DEFAULT_ENTRIES,
    STORE_DEFAULT_CAPACITY_BYTES,
};

/// How the service picks its batch-vs-shard crossover.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThresholdMode {
    /// Derive the crossover from the saturation model at construction
    /// ([`service_crossover`]) — fully deterministic, no measurement.
    Model,
    /// Pin the crossover to an explicit value.
    Fixed(usize),
    /// Pin the crossover to a value *measured on this host* by
    /// [`calibrate`] (single-thread p1 + per-dispatch overhead). Recorded
    /// distinctly in bench artifacts so model-derived, pinned and
    /// calibrated runs are never conflated.
    Calibrated(usize),
}

/// Service construction parameters. `Default`/[`ServeConfig::for_host`]
/// give the production posture: every core, the widest compensated rung
/// the host supports, and the model-derived crossover.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker count (the persistent pool's size), >= 1.
    pub threads: usize,
    /// The kernel rung every request runs (one rung per service — part of
    /// the bit-parity contract).
    pub style: ImplStyle,
    /// Serve the Kahan-compensated dot (the default — the paper says it is
    /// free under load) or the naive dot for A/B comparisons. Sum requests
    /// always use the compensated sum; there is no naive rung for them.
    pub compensated: bool,
    /// Where the shard crossover comes from: the saturation model, an
    /// explicit pin, or a host calibration measurement.
    pub shard_threshold: ThresholdMode,
    /// Core clock anchoring the model crossover (ignored with an explicit
    /// threshold).
    pub freq_ghz: f64,
    /// Fraction of result-cache hits to re-verify by recomputation
    /// (`0.0..=1.0`). A sampled hit recomputes the dot synchronously and
    /// bit-compares against the memoized value: a match counts
    /// (`cache.verified`), a mismatch evicts the poisoned entry
    /// (`cache.poisoned`) and falls through to an ordinary recompute — a
    /// corrupted cache degrades to slow-but-correct, never to wrong bits.
    /// `0.0` (the default) takes no new branches: the hit path is
    /// bit-identical to a service without the verifier.
    pub verify_hit_rate: f64,
}

impl ServeConfig {
    /// All cores, widest supported rung, compensated, model crossover.
    pub fn for_host() -> Self {
        Self {
            threads: ThreadPool::available(),
            style: preferred_kahan_style(SimdCaps::detect()),
            compensated: true,
            shard_threshold: ThresholdMode::Model,
            freq_ghz: freq_ghz_with_source().0,
            verify_hit_rate: 0.0,
        }
    }

    /// [`Self::for_host`] pinned to a worker count.
    pub fn with_threads(threads: usize) -> Self {
        Self {
            threads,
            ..Self::for_host()
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self::for_host()
    }
}

/// Where the service's shard threshold came from (recorded in bench
/// artifacts so a model-derived and a pinned run are never conflated).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ThresholdSource {
    /// Derived from the saturation model at construction.
    Model,
    /// Supplied by the caller ([`ThresholdMode::Fixed`]).
    Override,
    /// Measured on this host by [`calibrate`] ([`ThresholdMode::Calibrated`]).
    Calibrated,
}

impl ThresholdSource {
    /// The label bench artifacts record for this source.
    pub fn label(self) -> &'static str {
        match self {
            ThresholdSource::Model => "model",
            ThresholdSource::Override => "override",
            ThresholdSource::Calibrated => "calibrated",
        }
    }
}

/// An owned, shareable request payload for the asynchronous submission
/// path: operands live in `Arc`-shared 64-byte [`AlignedVec`] arenas, so a
/// request can cross the queue into the dispatcher thread (and be retained
/// by in-flight pool jobs) without copying and without borrowing from the
/// submitter's stack. [`SharedInput::view`] projects the borrowed
/// [`KernelInput`] every execution path consumes — the async pipeline
/// schedules the *same* inputs the synchronous API does.
#[derive(Clone, Debug)]
pub enum SharedInput {
    /// Two equal-length operand streams for the dot kernels.
    Dot(Arc<AlignedVec>, Arc<AlignedVec>),
    /// One operand stream for the sum kernels.
    Sum(Arc<AlignedVec>),
}

impl SharedInput {
    /// A dot request over freshly arena-copied operands.
    pub fn dot(x: &[f64], y: &[f64]) -> Self {
        SharedInput::Dot(
            Arc::new(AlignedVec::copy_from(x)),
            Arc::new(AlignedVec::copy_from(y)),
        )
    }

    /// A sum request over a freshly arena-copied operand.
    pub fn sum(x: &[f64]) -> Self {
        SharedInput::Sum(Arc::new(AlignedVec::copy_from(x)))
    }

    /// The borrowed kernel input this request executes.
    pub fn view(&self) -> KernelInput<'_> {
        match self {
            SharedInput::Dot(x, y) => KernelInput::Dot(x, y),
            SharedInput::Sum(x) => KernelInput::Sum(x),
        }
    }

    /// Loop iterations this request drives.
    pub fn updates(&self) -> usize {
        match self {
            SharedInput::Dot(x, _) => x.len(),
            SharedInput::Sum(x) => x.len(),
        }
    }
}

/// One served request's outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeResponse {
    /// The kernel result.
    pub value: f64,
    /// Updates the request carried.
    pub n: usize,
    /// Which execution path served it.
    pub path: ExecPath,
    /// The certified error bound ([`certified_err_bound`]), present only
    /// when the request asked for one (wire FLAG_ERRBOUND). `None` leaves
    /// the response byte-identical to a pre-rev-1.4 response.
    pub err_bound: Option<f64>,
}

/// Certified per-request error bound (wire FLAG_ERRBOUND, PROTOCOL.md
/// §3.5): a rigorous a-posteriori bound on `|served − exact|` derived
/// from the Kahan compensation term — the paper's central observation
/// read backwards: the compensation that makes the dot accurate is also
/// a free running estimate of the error it removed (PAPERS.md, Dukhan
/// et al.). One scalar compensated pass accumulates the condition sum
/// `cond = Σ|xᵢ·yᵢ|` (`Σ|xᵢ|` for sums) together with the final
/// compensation magnitude `|c|`; the certified bound is
/// `|c| + 3·eps·cond` for compensated services — within the
/// `8·eps·cond` envelope the accuracy tests already pin, since
/// `|c| ≤ eps·cond` up to second-order terms — and the classical
/// recursive-summation bound `(n+1)·eps·cond` for the naive rung. The
/// bound covers every execution path (fused, sharded, cached replay):
/// all are property-pinned bit-identical, so one bound certifies them
/// all.
pub fn certified_err_bound(input: &KernelInput<'_>, compensated: bool) -> f64 {
    fn kahan_scan(terms: impl Iterator<Item = f64>) -> (f64, f64, usize) {
        let (mut s, mut c, mut cond, mut n) = (0.0f64, 0.0f64, 0.0f64, 0usize);
        for p in terms {
            cond += p.abs();
            n += 1;
            let t = p - c;
            let u = s + t;
            c = (u - s) - t;
            s = u;
        }
        (c.abs(), cond, n)
    }
    let (c_mag, cond, n) = match *input {
        KernelInput::Dot(x, y) => kahan_scan(x.iter().zip(y.iter()).map(|(&a, &b)| a * b)),
        KernelInput::Sum(x) => kahan_scan(x.iter().copied()),
    };
    if compensated {
        c_mag + 3.0 * f64::EPSILON * cond
    } else {
        (n as f64 + 1.0) * f64::EPSILON * cond
    }
}

/// Monotonic service counters (snapshot via [`DotService::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests served (fused + sharded).
    pub requests: u64,
    /// Requests executed whole inside fused dispatches.
    pub fused: u64,
    /// Requests partitioned across the pool.
    pub sharded: u64,
    /// Total updates streamed across all requests.
    pub updates: u64,
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    fused: AtomicU64,
    sharded: AtomicU64,
    updates: AtomicU64,
}

/// The serving engine: one resolved kernel rung, one persistent worker
/// pool, synchronous batch submission (see the module docs).
pub struct DotService {
    pool: Arc<ThreadPool>,
    scheduler: BatchScheduler,
    threshold_source: ThresholdSource,
    style: ImplStyle,
    compensated: bool,
    dot_spec: KernelSpec,
    sum_spec: KernelSpec,
    dot_fn: fn(&[f64], &[f64]) -> f64,
    sum_fn: fn(&[f64]) -> f64,
    stats: Counters,
}

impl DotService {
    /// Build a service: spawns the persistent pool, resolves the dot and
    /// sum kernels for `cfg.style` once, and fixes the shard crossover.
    /// Fails with [`BackendError::Unsupported`] when the host cannot run
    /// the requested rung.
    pub fn new(cfg: ServeConfig) -> Result<Self, BackendError> {
        let pool = Arc::new(ThreadPool::new(cfg.threads.max(1)));
        Self::with_pool(cfg, pool)
    }

    /// [`Self::new`] over a caller-supplied pool of the same width. The
    /// async pipeline uses this with a *detached* pool
    /// ([`ThreadPool::new_detached`]) so its dispatcher thread never
    /// executes chunks inline; the partition — and therefore every result
    /// bit — is identical either way.
    pub(crate) fn with_pool(cfg: ServeConfig, pool: Arc<ThreadPool>) -> Result<Self, BackendError> {
        assert_eq!(
            pool.threads(),
            cfg.threads.max(1),
            "service pool must match the configured width"
        );
        let caps = SimdCaps::detect();
        let dot_class = if cfg.compensated {
            KernelClass::KahanDot
        } else {
            KernelClass::NaiveDot
        };
        let dot_spec = KernelSpec::new(dot_class, cfg.style);
        let sum_spec = KernelSpec::new(KernelClass::KahanSum, cfg.style);
        let unsupported = |spec| BackendError::Unsupported {
            backend: "serve".to_string(),
            spec,
        };
        let Some(NativeFn::Dot(dot_fn)) = native_fn(dot_spec, caps) else {
            return Err(unsupported(dot_spec));
        };
        let Some(NativeFn::Sum(sum_fn)) = native_fn(sum_spec, caps) else {
            return Err(unsupported(sum_spec));
        };
        let threads = cfg.threads.max(1);
        let (threshold, threshold_source) = match cfg.shard_threshold {
            ThresholdMode::Fixed(t) => (t, ThresholdSource::Override),
            ThresholdMode::Calibrated(t) => (t, ThresholdSource::Calibrated),
            ThresholdMode::Model => {
                (service_crossover(dot_spec, threads, cfg.freq_ghz), ThresholdSource::Model)
            }
        };
        Ok(Self {
            pool,
            scheduler: BatchScheduler::new(threshold),
            threshold_source,
            style: cfg.style,
            compensated: cfg.compensated,
            dot_spec,
            sum_spec,
            dot_fn,
            sum_fn,
            stats: Counters::default(),
        })
    }

    /// Worker count the service schedules over.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// The persistent worker pool — exposed so callers can first-touch
    /// operand arenas with the same chunk→worker assignment the sharded
    /// path uses.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Requests with at least this many updates are sharded.
    pub fn shard_threshold(&self) -> usize {
        self.scheduler.shard_threshold()
    }

    /// Where the shard threshold came from (model, override, calibrated).
    pub fn threshold_source(&self) -> ThresholdSource {
        self.threshold_source
    }

    /// The kernel rung every request runs.
    pub fn style(&self) -> ImplStyle {
        self.style
    }

    /// Whether dot requests run the Kahan-compensated kernel.
    pub fn compensated(&self) -> bool {
        self.compensated
    }

    /// The rung dot requests run on.
    pub fn dot_spec(&self) -> KernelSpec {
        self.dot_spec
    }

    /// The rung sum requests run on.
    pub fn sum_spec(&self) -> KernelSpec {
        self.sum_spec
    }

    /// The spec a given request resolves to.
    pub fn spec_for(&self, input: &KernelInput<'_>) -> KernelSpec {
        match input {
            KernelInput::Dot(..) => self.dot_spec,
            KernelInput::Sum(..) => self.sum_spec,
        }
    }

    /// The certified error bound this service attaches to a request when
    /// the client asks for one ([`certified_err_bound`], using the rung
    /// the request actually runs: the naive bound for an uncompensated
    /// dot service, the compensated bound otherwise — sums always run the
    /// compensated rung).
    pub fn err_bound_for(&self, input: &KernelInput<'_>) -> f64 {
        let compensated = match input {
            KernelInput::Dot(..) => self.compensated,
            KernelInput::Sum(..) => true,
        };
        certified_err_bound(input, compensated)
    }

    /// Snapshot of the monotonic service counters.
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            requests: self.stats.requests.load(Ordering::Relaxed),
            fused: self.stats.fused.load(Ordering::Relaxed),
            sharded: self.stats.sharded.load(Ordering::Relaxed),
            updates: self.stats.updates.load(Ordering::Relaxed),
        }
    }

    fn run_serial(&self, input: &KernelInput<'_>) -> f64 {
        match *input {
            KernelInput::Dot(x, y) => (self.dot_fn)(x, y),
            KernelInput::Sum(x) => (self.sum_fn)(x),
        }
    }

    fn run_sharded(&self, input: &KernelInput<'_>) -> f64 {
        let pool = &self.pool;
        let partials = match *input {
            KernelInput::Dot(x, y) => {
                let f = self.dot_fn;
                pool.run_chunks(x.len(), CACHELINE_F64, |_, r| f(&x[r.clone()], &y[r]))
            }
            KernelInput::Sum(x) => {
                let f = self.sum_fn;
                pool.run_chunks(x.len(), CACHELINE_F64, |_, r| f(&x[r]))
            }
        };
        compensated_tree_reduce(&partials)
    }

    fn record(&self, fused: u64, sharded: u64, updates: u64) {
        let s = &self.stats;
        s.requests.fetch_add(fused + sharded, Ordering::Relaxed);
        s.fused.fetch_add(fused, Ordering::Relaxed);
        s.sharded.fetch_add(sharded, Ordering::Relaxed);
        s.updates.fetch_add(updates, Ordering::Relaxed);
    }

    /// Serve one request. Small requests run serially on the calling
    /// thread (bit-identical to their fused-batch execution); large ones
    /// shard across the pool.
    pub fn submit(&self, input: &KernelInput<'_>) -> Result<ServeResponse, BackendError> {
        input.check(self.spec_for(input))?;
        let n = input.updates();
        let path = self.scheduler.path_for(n);
        let value = match path {
            ExecPath::Fused => self.run_serial(input),
            ExecPath::Sharded => self.run_sharded(input),
        };
        match path {
            ExecPath::Fused => self.record(1, 0, n as u64),
            ExecPath::Sharded => self.record(0, 1, n as u64),
        }
        Ok(ServeResponse {
            value,
            n,
            path,
            err_bound: None,
        })
    }

    /// Serve a batch of independent requests: every input is validated
    /// up front (one bad request fails the whole batch before anything
    /// executes), small requests go out as one fused dispatch, large ones
    /// shard across the full pool one after another. Responses come back
    /// in submission order.
    pub fn submit_batch(
        &self,
        inputs: &[KernelInput<'_>],
    ) -> Result<Vec<ServeResponse>, BackendError> {
        for input in inputs {
            input.check(self.spec_for(input))?;
        }
        let plan = self.scheduler.plan(inputs);
        let mut values = vec![0.0f64; inputs.len()];
        let run_one = |k: usize| self.run_serial(&inputs[plan.fused[k]]);
        let fused_vals = self.pool.run_tasks(plan.fused.len(), run_one);
        for (k, &idx) in plan.fused.iter().enumerate() {
            values[idx] = fused_vals[k];
        }
        for &idx in &plan.sharded {
            values[idx] = self.run_sharded(&inputs[idx]);
        }
        let updates: u64 = inputs.iter().map(|i| i.updates() as u64).sum();
        self.record(plan.fused.len() as u64, plan.sharded.len() as u64, updates);
        Ok(inputs
            .iter()
            .zip(values)
            .map(|(input, value)| {
                let n = input.updates();
                ServeResponse {
                    value,
                    n,
                    path: self.scheduler.path_for(n),
                    err_bound: None,
                }
            })
            .collect())
    }
}

impl std::fmt::Debug for DotService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DotService")
            .field("threads", &self.threads())
            .field("style", &self.style)
            .field("compensated", &self.compensated)
            .field("shard_threshold", &self.shard_threshold())
            .field("threshold_source", &self.threshold_source)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::Backend;
    use crate::runtime::parallel::ParallelBackend;
    use crate::util::rng::Rng;

    fn cfg(threads: usize, threshold: usize) -> ServeConfig {
        ServeConfig {
            threads,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(threshold),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        }
    }

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn batched_matches_unbatched_bit_for_bit() {
        let service = DotService::new(cfg(3, 1000)).unwrap();
        let sizes = [7usize, 64, 999, 1000, 1001, 4096, 100];
        let data: Vec<(Vec<f64>, Vec<f64>)> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| (randvec(n, 100 + i as u64), randvec(n, 200 + i as u64)))
            .collect();
        let inputs: Vec<KernelInput<'_>> =
            data.iter().map(|(x, y)| KernelInput::Dot(x, y)).collect();
        let batched = service.submit_batch(&inputs).unwrap();
        for (input, b) in inputs.iter().zip(&batched) {
            let alone = service.submit(input).unwrap();
            assert_eq!(alone.value.to_bits(), b.value.to_bits(), "n={}", b.n);
            assert_eq!(alone.path, b.path);
        }
        // Repeated batched dispatches are bit-stable too.
        let again = service.submit_batch(&inputs).unwrap();
        for (a, b) in batched.iter().zip(&again) {
            assert_eq!(a.value.to_bits(), b.value.to_bits());
        }
    }

    #[test]
    fn sharded_path_matches_parallel_backend_bits() {
        for threads in [2usize, 3] {
            let service = DotService::new(cfg(threads, 64)).unwrap();
            let backend = ParallelBackend::new(threads);
            let x = randvec(4099, 7);
            let y = randvec(4099, 8);
            let input = KernelInput::Dot(&x, &y);
            let served = service.submit(&input).unwrap();
            assert_eq!(served.path, ExecPath::Sharded);
            let reference = backend.run(service.dot_spec(), &input).unwrap();
            assert_eq!(served.value.to_bits(), reference.to_bits(), "T={threads}");
            // Sum requests shard identically.
            let s_in = KernelInput::Sum(&x);
            let served = service.submit(&s_in).unwrap();
            let reference = backend.run(service.sum_spec(), &s_in).unwrap();
            assert_eq!(served.value.to_bits(), reference.to_bits(), "T={threads}");
        }
    }

    #[test]
    fn crossover_boundary_is_respected() {
        let service = DotService::new(cfg(2, 256)).unwrap();
        let x = randvec(256, 1);
        let y = randvec(256, 2);
        let below = service.submit(&KernelInput::Dot(&x[..255], &y[..255])).unwrap();
        assert_eq!(below.path, ExecPath::Fused);
        let at = service.submit(&KernelInput::Dot(&x, &y)).unwrap();
        assert_eq!(at.path, ExecPath::Sharded);
        let stats = service.stats();
        assert_eq!(stats.requests, 2);
        assert_eq!(stats.fused, 1);
        assert_eq!(stats.sharded, 1);
        assert_eq!(stats.updates, 255 + 256);
    }

    #[test]
    fn fused_path_equals_serial_kernel_and_t1_shard() {
        // A fused request is the serial kernel over the whole input —
        // which is also exactly what the sharded path produces at T = 1.
        let big = 2048;
        let x = randvec(big, 3);
        let y = randvec(big, 4);
        let input = KernelInput::Dot(&x, &y);
        let fused_service = DotService::new(cfg(4, usize::MAX)).unwrap();
        let shard_service = DotService::new(cfg(1, 0)).unwrap();
        let a = fused_service.submit(&input).unwrap();
        let b = shard_service.submit(&input).unwrap();
        assert_eq!(a.path, ExecPath::Fused);
        assert_eq!(b.path, ExecPath::Sharded);
        assert_eq!(a.value.to_bits(), b.value.to_bits());
    }

    #[test]
    fn naive_service_uses_naive_dot() {
        let mut c = cfg(2, usize::MAX);
        c.compensated = false;
        let service = DotService::new(c).unwrap();
        assert_eq!(service.dot_spec().class, KernelClass::NaiveDot);
        assert_eq!(service.sum_spec().class, KernelClass::KahanSum);
        let x = [1.0, 2.0, 3.0];
        let y = [4.0, 5.0, 6.0];
        let r = service.submit(&KernelInput::Dot(&x, &y)).unwrap();
        assert_eq!(r.value, 32.0);
    }

    #[test]
    fn certified_error_bound_sits_inside_the_accuracy_envelope() {
        let x = randvec(4096, 21);
        let y = randvec(4096, 22);
        let input = KernelInput::Dot(&x, &y);
        let cond: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let bound = certified_err_bound(&input, true);
        assert!(bound > 0.0);
        assert!(
            bound <= 8.0 * f64::EPSILON * cond,
            "compensated bound {bound} escapes the 8·eps·cond envelope"
        );
        let naive = certified_err_bound(&input, false);
        assert!(naive > bound, "the naive bound must dominate");
        let s_in = KernelInput::Sum(&x);
        let s_cond: f64 = x.iter().map(|v| v.abs()).sum();
        assert!(certified_err_bound(&s_in, true) <= 8.0 * f64::EPSILON * s_cond);
        // The service attaches the rung-appropriate bound; plain submits
        // carry none (the off path is the pre-rev-1.4 response).
        let service = DotService::new(cfg(2, usize::MAX)).unwrap();
        assert_eq!(service.err_bound_for(&input), bound);
        assert_eq!(service.submit(&input).unwrap().err_bound, None);
    }

    #[test]
    fn invalid_requests_fail_the_whole_batch() {
        let service = DotService::new(cfg(2, 100)).unwrap();
        let x = [1.0, 2.0];
        let y = [1.0];
        let good = KernelInput::Sum(&x);
        let bad = KernelInput::Dot(&x, &y);
        let err = service.submit_batch(&[good, bad]).unwrap_err();
        assert!(matches!(err, BackendError::ShapeMismatch { .. }));
        // Nothing executed: counters untouched.
        assert_eq!(service.stats(), ServeStats::default());
    }

    #[test]
    fn unsupported_style_is_rejected_at_construction() {
        if SimdCaps::detect().avx512 {
            return; // host actually supports it; nothing to reject
        }
        let mut c = cfg(2, 100);
        c.style = ImplStyle::Avx512U8;
        let err = DotService::new(c).unwrap_err();
        assert!(matches!(err, BackendError::Unsupported { .. }));
    }

    #[test]
    fn empty_and_mixed_batches_serve() {
        let service = DotService::new(cfg(4, 128)).unwrap();
        assert!(service.submit_batch(&[]).unwrap().is_empty());
        let x = randvec(300, 9);
        let small = [1.0, 2.0, 3.0, 4.0];
        let inputs = [
            KernelInput::Sum(&small),
            KernelInput::Dot(&x, &x),
            KernelInput::Sum(&x),
            KernelInput::Dot(&small, &small),
        ];
        let rs = service.submit_batch(&inputs).unwrap();
        assert_eq!(rs.len(), 4);
        assert_eq!(rs[0].path, ExecPath::Fused);
        assert_eq!(rs[1].path, ExecPath::Sharded);
        assert_eq!(rs[2].path, ExecPath::Sharded);
        assert_eq!(rs[3].path, ExecPath::Fused);
        assert_eq!(rs[0].value, 10.0);
        assert_eq!(rs[3].value, 30.0);
        let stats = service.stats();
        assert_eq!(stats.fused, 2);
        assert_eq!(stats.sharded, 2);
    }
}
