//! The TCP front-end for the serving layer: a dependency-free
//! `std::net::TcpListener` server speaking the length-prefixed binary
//! protocol of [`codec`](super::codec) (normative spec:
//! `docs/PROTOCOL.md`), plus the blocking [`WireClient`] the tests and the
//! wire load generator drive it with.
//!
//! Dataflow (the full narrative lives in `docs/ARCHITECTURE.md`):
//!
//! ```text
//! socket ──► reader thread ──► AsyncDotService queue ──► dispatcher/pool
//!                │ (decode, admit)        │
//!                └─► writer thread ◄──────┘ (tickets resolve)
//!                     (responses stream out-of-order, by request id)
//! ```
//!
//! Each accepted connection gets a **reader half** (decodes frames,
//! admits requests) and a **writer half** (polls outstanding
//! [`ResponseHandle`]s and writes whichever response resolves first) — so
//! responses stream back in completion order, correlated by request id,
//! and one slow sharded request never convoys the small requests behind
//! it on the same connection.
//!
//! **Backpressure** (PROTOCOL.md §5): inline `DOT`/`SUM` requests are
//! admitted with the non-blocking [`AsyncDotService::try_submit`] — a full
//! queue becomes a `BUSY` error frame on the wire and nothing is enqueued.
//! `BATCH` submissions use the blocking path instead: a full queue stalls
//! the connection's reader, which stops draining the socket, which is TCP
//! backpressure to the client. With a QoS policy ([`NetOptions::qos`]), a
//! tenant at its per-tenant quota draws the typed `QUOTA` frame instead —
//! distinct from `BUSY` because retrying cannot help until that tenant's
//! own queued work drains (PROTOCOL.md §4.11); rev-1.2 clients get a
//! retry-after hint on both.
//!
//! **Determinism**: the codec transports operands and results as IEEE-754
//! bit patterns and the server feeds the *same* `AsyncDotService` pipeline
//! in-process callers use, so at a fixed thread count a wire response is
//! bit-identical to `submit_wait` on the same operands (pinned by
//! `tests/integration.rs`).

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::backend::BackendError;

use super::codec::{
    self, ErrorCode, Opcode, Request, RequestMeta, Response, WireCacheStats, WireError, WireResult,
    WireScrubStats, WireStats, WireTenantStats, FLAG_CRC, HEADER_LEN,
};
use super::faults::{FaultInjector, FaultSite};
use super::queue::{AsyncDotService, AsyncOptions, QosPolicy, ResponseHandle, TrySubmit};
use super::{ServeConfig, ServeResponse, SharedInput};

/// How often the writer half re-polls outstanding tickets while waiting
/// for new messages from the reader. Bounds response-streaming latency at
/// light load without spinning.
const WRITER_POLL: Duration = Duration::from_micros(50);

/// First pause of the [`WireClient`] BUSY backoff (PROTOCOL.md §5: BUSY
/// means "nothing enqueued, retry later"). Doubles per consecutive BUSY
/// up to [`BUSY_BACKOFF_CAP`]; a server-provided retry-after hint
/// overrides the schedule.
const BUSY_BACKOFF_BASE: Duration = Duration::from_micros(50);

/// Cap on a single BUSY backoff pause: even a long-saturated server is
/// re-probed a few hundred times per second, not hot-spun against.
const BUSY_BACKOFF_CAP: Duration = Duration::from_millis(5);

/// Default wall-clock budget for BUSY retries before the error surfaces
/// to the caller (override per client via
/// [`WireClient::set_busy_retry_budget`]). The old fixed-pause scheme
/// (100 µs × 2^20 retries ≈ 105 s of hot-spinning) is gone: the budget
/// bounds total waiting in wall time, independent of the retry count.
const BUSY_RETRY_BUDGET: Duration = Duration::from_secs(2);

fn io_runtime(context: &str, e: std::io::Error) -> BackendError {
    BackendError::Runtime(format!("{context}: {e}"))
}

/// Socket-level robustness knobs for [`NetServer::bind_with`]. The
/// defaults reproduce the pre-deadline server exactly: no timeouts, no
/// idle reaping, no fault injection — graceful degradation is opt-in so
/// the fault-free path stays bit-identical to earlier revisions.
#[derive(Clone, Debug, Default)]
pub struct NetOptions {
    /// Per-read socket timeout. A peer that stalls *mid-frame* for longer
    /// than this has torn the stream; the connection is closed. `None`
    /// (default) blocks forever, as revision 1.0 did.
    pub read_timeout: Option<Duration>,
    /// Idle-connection reaper: a connection with no traffic *between*
    /// frames for this long is closed and its threads reclaimed. `None`
    /// (default) keeps idle connections forever.
    pub idle_timeout: Option<Duration>,
    /// Per-write socket timeout: a client that stops draining its
    /// receive window for this long is evicted (the writer errors out and
    /// the connection closes) instead of pinning a writer thread and an
    /// unbounded response backlog. `None` (default) blocks forever.
    pub write_timeout: Option<Duration>,
    /// Bound on the reader → writer message queue. A full queue blocks
    /// the reader — backpressure toward the socket — instead of growing
    /// without limit while a slow client ignores its responses.
    pub writer_queue: usize,
    /// Deterministic fault injection for the socket-facing sites
    /// ([`FaultSite::SocketReadError`] and friends). `None` in
    /// production: the sites cost one branch on a null pointer.
    pub faults: Option<Arc<FaultInjector>>,
    /// Multi-tenant QoS policy for the inner pipeline: weighted-fair
    /// scheduling plus per-tenant quotas keyed by the wire tenant field
    /// (PROTOCOL.md §2.5). `None` (default) serves single-class FIFO,
    /// exactly as revisions 1.0/1.1 did.
    pub qos: Option<QosPolicy>,
}

/// Default reader → writer queue bound when [`NetOptions::writer_queue`]
/// is left at zero: deep enough that completion-order streaming never
/// stalls a healthy connection, finite so a stalled client cannot queue
/// unbounded frames.
const WRITER_QUEUE_DEFAULT: usize = 1024;

impl NetOptions {
    fn writer_queue_cap(&self) -> usize {
        if self.writer_queue == 0 {
            WRITER_QUEUE_DEFAULT
        } else {
            self.writer_queue
        }
    }

    fn fire(&self, site: FaultSite) -> bool {
        match &self.faults {
            Some(inj) => inj.fire(site),
            None => false,
        }
    }

    fn stall(&self, site: FaultSite) -> Option<Duration> {
        match &self.faults {
            Some(inj) => inj.stall(site),
            None => None,
        }
    }
}

/// One registered connection: the acceptor's stream clone (for shutdown)
/// and the reader thread's join handle. Entries accumulate until the
/// server drops — connection lifetimes are bounded by the server's, which
/// is the bench/test usage this front-end serves.
struct Connection {
    stream: TcpStream,
    reader: Option<JoinHandle<()>>,
}

/// The `serve-net` server: a listener plus one acceptor thread feeding
/// per-connection reader/writer thread pairs into an owned
/// [`AsyncDotService`] (see the module docs). Dropping the server shuts
/// down the listener, every connection and the service — a drain, not an
/// abort: admitted requests complete first.
pub struct NetServer {
    service: Arc<AsyncDotService>,
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    connections: Arc<Mutex<Vec<Connection>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:4990"`; port 0 picks a free port —
    /// read it back via [`Self::local_addr`]) and start serving: builds
    /// the async pipeline for `cfg`/`opts` and spawns the acceptor.
    pub fn bind(addr: &str, cfg: ServeConfig, opts: AsyncOptions) -> Result<Self, BackendError> {
        Self::bind_with(addr, cfg, opts, NetOptions::default())
    }

    /// [`Self::bind`] with explicit socket-robustness options: timeouts,
    /// idle reaping, writer-queue bound and fault injection (the
    /// [`NetOptions`] default reproduces `bind` exactly). The pool-facing
    /// injector, if any, is shared with the async pipeline so one seeded
    /// plan drives every tier.
    pub fn bind_with(
        addr: &str,
        cfg: ServeConfig,
        opts: AsyncOptions,
        net: NetOptions,
    ) -> Result<Self, BackendError> {
        let service = Arc::new(AsyncDotService::new_with_qos(
            cfg,
            opts,
            net.qos.clone(),
            net.faults.clone(),
        )?);
        let listener = TcpListener::bind(addr).map_err(|e| io_runtime(&format!("bind {addr}"), e))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| io_runtime("local_addr", e))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let service = Arc::clone(&service);
            let shutdown = Arc::clone(&shutdown);
            let connections = Arc::clone(&connections);
            let net = Arc::new(net);
            std::thread::Builder::new()
                .name("kahan-net-accept".to_string())
                .spawn(move || acceptor_main(listener, service, shutdown, connections, net))
                .expect("spawn net acceptor")
        };
        Ok(Self {
            service,
            local_addr,
            shutdown,
            acceptor: Some(acceptor),
            connections,
        })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The async pipeline behind the socket — same accessors in-process
    /// callers get (`stats()`, `options()`, `service()` …).
    pub fn service(&self) -> &Arc<AsyncDotService> {
        &self.service
    }
}

impl Drop for NetServer {
    /// Orderly shutdown: raise the flag, self-dial to unblock `accept`,
    /// join the acceptor, shut every connection's socket down (unblocking
    /// its reader) and join the connection threads. The inner service then
    /// drains in its own `Drop`.
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let mut conns = self.connections.lock().unwrap_or_else(|p| p.into_inner());
        for conn in conns.iter_mut() {
            let _ = conn.stream.shutdown(std::net::Shutdown::Both);
        }
        for conn in conns.iter_mut() {
            if let Some(h) = conn.reader.take() {
                let _ = h.join();
            }
        }
    }
}

impl std::fmt::Debug for NetServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NetServer")
            .field("local_addr", &self.local_addr)
            .field("service", &self.service)
            .finish()
    }
}

fn acceptor_main(
    listener: TcpListener,
    service: Arc<AsyncDotService>,
    shutdown: Arc<AtomicBool>,
    connections: Arc<Mutex<Vec<Connection>>>,
    net: Arc<NetOptions>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shutdown.load(Ordering::SeqCst) {
            return; // the self-dial (or a raced client) during shutdown
        }
        // Latency over throughput for small frames.
        let _ = stream.set_nodelay(true);
        let Ok(registered) = stream.try_clone() else {
            continue;
        };
        let reader = {
            let service = Arc::clone(&service);
            let net = Arc::clone(&net);
            std::thread::Builder::new()
                .name("kahan-net-read".to_string())
                .spawn(move || connection_main(stream, service, net))
                .expect("spawn net reader")
        };
        connections
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(Connection {
                stream: registered,
                reader: Some(reader),
            });
    }
}

/// Messages the reader half hands the writer half. Raw frames are written
/// as-is; pending entries resolve out of order as their tickets complete.
enum WriterMsg {
    /// An already-encoded frame (errors, stats).
    Raw(Vec<u8>),
    /// One admitted request awaiting its ticket. `crc` echoes the
    /// request's [`FLAG_CRC`]: the response frame is sealed with the
    /// revision-1.4 checksum trailer for peers that negotiated it.
    Pending {
        id: u64,
        handle: ResponseHandle,
        crc: bool,
    },
    /// One admitted batch: waited in submission order, answered with a
    /// single batch-result frame (PROTOCOL.md §3.3).
    Batch {
        id: u64,
        handles: Vec<ResponseHandle>,
        crc: bool,
    },
}

/// Read exactly `buf.len()` bytes; `Ok(false)` on clean EOF *before the
/// first byte* (the peer closed between frames), `Err` on mid-buffer EOF
/// (a truncated frame) or any other I/O failure.
pub(crate) fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside a frame",
                ));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Discard `n` bytes from the stream (resync after a malformed header
/// whose payload length was still parseable).
fn skip_bytes(r: &mut impl Read, mut n: usize) -> std::io::Result<()> {
    let mut scratch = [0u8; 4096];
    while n > 0 {
        let take = n.min(scratch.len());
        r.read_exact(&mut scratch[..take])?;
        n -= take;
    }
    Ok(())
}

fn send(tx: &SyncSender<WriterMsg>, msg: WriterMsg) -> bool {
    // A full (bounded) writer queue blocks here: reader-side
    // backpressure toward the socket while a slow client catches up.
    tx.send(msg).is_ok()
}

fn send_error(tx: &SyncSender<WriterMsg>, id: u64, code: ErrorCode, message: &str) -> bool {
    send(tx, WriterMsg::Raw(codec::encode_error(id, code, message)))
}

/// The wire error code for a pipeline failure: deadline shedding and the
/// resident-store failures get their typed codes (PROTOCOL.md §4.10,
/// §4.12, §4.13); everything else (dispatcher drain, worker panic) is
/// internal.
fn error_code_of(e: &BackendError) -> ErrorCode {
    match e {
        BackendError::DeadlineExceeded { .. } => ErrorCode::Deadline,
        BackendError::UnknownHandle { .. } => ErrorCode::UnknownHandle,
        BackendError::StoreFull { .. } => ErrorCode::StoreFull,
        BackendError::CorruptOperand { .. } => ErrorCode::CorruptOperand,
        _ => ErrorCode::Internal,
    }
}

/// Seal `frame` with the revision-1.4 CRC trailer when the request
/// negotiated it ([`FLAG_CRC`] on the request header); pass it through
/// untouched otherwise, keeping CRC-off traffic byte-identical to
/// revision 1.3.
fn sealed(mut frame: Vec<u8>, crc: bool) -> Vec<u8> {
    if crc {
        codec::seal_crc(&mut frame);
    }
    frame
}

pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Snapshot the pipeline counters into the wire stats payload
/// (PROTOCOL.md §3.7).
fn wire_stats(service: &AsyncDotService) -> WireStats {
    let s = service.stats();
    WireStats {
        queue_depth: service.options().queue_depth as u64,
        threads: service.threads() as u64,
        enqueued: s.enqueued,
        completed: s.completed,
        arrival_batches: s.arrival_batches,
        dispatches: s.dispatches,
        max_queue_depth: s.max_queue_depth as u64,
        busy_ns: s.busy_ns as u64,
    }
}

/// Snapshot the per-tenant accounting rows for the rev-1.2 tenant stats
/// extension (PROTOCOL.md §3.7).
fn wire_tenant_stats(service: &AsyncDotService) -> Vec<WireTenantStats> {
    service
        .tenant_stats()
        .iter()
        .map(|t| WireTenantStats {
            tenant: t.tenant,
            admitted: t.admitted,
            completed: t.completed,
            quota_shed: t.quota_shed,
            deadline_shed: t.deadline_shed,
        })
        .collect()
}

/// Snapshot the operand-store and result-cache counters for the rev-1.3
/// cache stats extension (PROTOCOL.md §3.7).
fn wire_cache_stats(service: &AsyncDotService) -> WireCacheStats {
    let store = service.store_stats();
    let cache = service.cache_stats();
    WireCacheStats {
        store_entries: store.entries,
        store_resident_bytes: store.resident_bytes,
        store_registered: store.registered,
        store_evictions: store.evictions,
        cache_lookups: cache.lookups,
        cache_hits: cache.hits,
        cache_misses: cache.misses,
        cache_evictions: cache.evictions,
    }
}

/// Snapshot the integrity counters — store scrub verdicts and cache
/// verify-on-hit outcomes — for the rev-1.4 scrub stats extension
/// (PROTOCOL.md §3.7).
fn wire_scrub_stats(service: &AsyncDotService) -> WireScrubStats {
    let store = service.store_stats();
    let cache = service.cache_stats();
    WireScrubStats {
        scrub_verified: store.scrub_verified,
        scrub_quarantined: store.scrub_quarantined,
        scrub_passes: store.scrub_passes,
        cache_verified: cache.verified,
        cache_poisoned: cache.poisoned,
    }
}

/// The retry-after hint the server attaches to BUSY/QUOTA frames for
/// rev-1.2 clients: one batching window — the soonest the dispatcher can
/// plausibly have drained capacity.
fn retry_hint_us(service: &AsyncDotService) -> u32 {
    (service.options().batch_window.as_micros() as u32).max(100)
}

/// Encode a BUSY/QUOTA shed frame. Clients that demonstrated rev-1.2
/// support (the request carried a 1.2 prefix) get the retry-after hint;
/// rev-1.0/1.1 clients get the plain error frame they already understand
/// (PROTOCOL.md §6, version negotiation by request).
fn shed_frame(
    service: &AsyncDotService,
    id: u64,
    code: ErrorCode,
    message: &str,
    rev12: bool,
) -> Vec<u8> {
    if rev12 {
        codec::encode_error_retry(id, code, retry_hint_us(service), message)
    } else {
        codec::encode_error(id, code, message)
    }
}

/// The reader half: frame decode loop feeding the service and the writer.
/// Exits on clean EOF, fatal protocol errors (PROTOCOL.md §4), I/O
/// failure, idle reaping, or service shutdown; joins its writer before
/// returning.
fn connection_main(stream: TcpStream, service: Arc<AsyncDotService>, net: Arc<NetOptions>) {
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = writer_stream.set_write_timeout(net.write_timeout);
    let (tx, rx) = std::sync::mpsc::sync_channel::<WriterMsg>(net.writer_queue_cap());
    let writer = {
        let net = Arc::clone(&net);
        std::thread::Builder::new()
            .name("kahan-net-write".to_string())
            .spawn(move || writer_main(writer_stream, rx, net))
            .expect("spawn net writer")
    };
    reader_loop(stream, &service, &tx, &net);
    drop(tx); // writer drains outstanding tickets, then exits
    let _ = writer.join();
}

/// Wait for the first header byte of the next frame, ticking the idle
/// clock on read timeouts. `Ok(true)` once a byte arrived, `Ok(false)` on
/// clean EOF or idle-limit expiry (reap), `Err` on stream failure.
fn await_first_byte(
    reader: &mut BufReader<TcpStream>,
    net: &NetOptions,
    byte: &mut [u8],
) -> std::io::Result<bool> {
    let idle_start = Instant::now();
    loop {
        match read_exact_or_eof(reader, byte) {
            Ok(got) => return Ok(got),
            Err(e) if is_timeout(&e) => match net.idle_timeout {
                // Idle reaping: no traffic between frames for the limit.
                Some(limit) if idle_start.elapsed() >= limit => return Ok(false),
                // Below the limit (or no limit, with only a mid-frame
                // read timeout configured): keep waiting for a frame.
                _ => {}
            },
            Err(e) => return Err(e),
        }
    }
}

fn reader_loop(
    stream: TcpStream,
    service: &AsyncDotService,
    tx: &SyncSender<WriterMsg>,
    net: &NetOptions,
) {
    // One socket timeout serves both bounds: mid-frame stalls surface as
    // hard timeouts below, while between-frame timeouts just tick the
    // idle clock in `await_first_byte`.
    let tick = match (net.read_timeout, net.idle_timeout) {
        (Some(r), Some(i)) => Some(r.min(i)),
        (r, i) => r.or(i),
    };
    let _ = stream.set_read_timeout(tick);
    let mut reader = BufReader::new(stream);
    loop {
        let mut head = [0u8; HEADER_LEN];
        match await_first_byte(&mut reader, net, &mut head[..1]) {
            Ok(true) => {}
            Ok(false) | Err(_) => return,
        }
        // Injected read failure: the stream dies exactly as if the OS
        // returned an error — admitted requests still resolve, the
        // writer still drains them (into a likely-dead socket), nothing
        // hangs.
        if net.fire(FaultSite::SocketReadError) {
            return;
        }
        match read_exact_or_eof(&mut reader, &mut head[1..]) {
            Ok(true) => {}
            Ok(false) | Err(_) => return, // mid-frame stall, EOF or error
        }
        let header = match codec::decode_header(&head) {
            Ok(h) => h,
            Err(e) if e.code == ErrorCode::Malformed => {
                // Magic, version and the length cap all passed (they are
                // checked first — PROTOCOL.md §2.2), so the length and id
                // fields are trustworthy: skip the payload to stay
                // frame-aligned and keep the connection.
                let len = u32::from_le_bytes([head[16], head[17], head[18], head[19]]) as usize;
                let id = u64::from_le_bytes([
                    head[8], head[9], head[10], head[11], head[12], head[13], head[14], head[15],
                ]);
                if skip_bytes(&mut reader, len).is_err() {
                    return;
                }
                if !send_error(tx, id, e.code, &e.message) {
                    return;
                }
                continue;
            }
            Err(e) => {
                // Bad magic/version/oversized: the stream is not
                // frame-aligned (or not ours) — the id field cannot be
                // trusted, so the error frame echoes id 0 and the
                // connection closes (PROTOCOL.md §4).
                let _ = send_error(tx, 0, e.code, &e.message);
                return;
            }
        };
        let mut payload = vec![0u8; header.payload_len as usize];
        if header.payload_len > 0 && reader.read_exact(&mut payload).is_err() {
            return;
        }
        // Revision-1.4 integrity: a CRC-flagged frame is verified before
        // anything in its payload is believed. A checksum mismatch is the
        // typed non-fatal CORRUPT_FRAME error (PROTOCOL.md §4.14) — the
        // stream is still frame-aligned (the length field is covered by
        // the header checks), so the connection keeps serving.
        let crc = header.flags & FLAG_CRC != 0;
        let verified = match codec::verify_crc(&head, header.flags, &payload) {
            Ok(body) => body,
            Err(e) => {
                let frame = sealed(codec::encode_error(header.request_id, e.code, &e.message), crc);
                if !send(tx, WriterMsg::Raw(frame)) {
                    return;
                }
                continue;
            }
        };
        let Some(opcode) = Opcode::from_byte(header.opcode) else {
            let frame = sealed(
                codec::encode_error(
                    header.request_id,
                    ErrorCode::BadOpcode,
                    &format!("unassigned opcode byte {:#04x}", header.opcode),
                ),
                crc,
            );
            if !send(tx, WriterMsg::Raw(frame)) {
                return;
            }
            continue;
        };
        // Strip the optional deadline and tenant prefixes (PROTOCOL.md
        // §2.4/§2.5) before the opcode-specific payload decodes.
        let (meta, body) = match codec::split_prefixes(header.flags, verified) {
            Ok(split) => split,
            Err(e) => {
                let frame = sealed(codec::encode_error(header.request_id, e.code, &e.message), crc);
                if !send(tx, WriterMsg::Raw(frame)) {
                    return;
                }
                continue;
            }
        };
        let request = match codec::decode_request(opcode, body) {
            Ok(r) => r,
            Err(e) => {
                let frame = sealed(codec::encode_error(header.request_id, e.code, &e.message), crc);
                if !send(tx, WriterMsg::Raw(frame)) {
                    return;
                }
                if e.code.is_fatal() {
                    return;
                }
                continue;
            }
        };
        if !handle_request(service, tx, header.request_id, request, meta, crc, net) {
            return;
        }
    }
}

/// Admit one decoded request; `false` ends the connection. The request's
/// prefixes decide the class of service: the deadline prefix arms
/// shedding, the tenant prefix routes quota/fair-share accounting
/// (absent → tenant 0), and carrying any revision-1.2+ marker (prefix,
/// cache/errbound/scrub flag, or the CRC trailer) unlocks retry-after
/// hints on shed frames. `crc` echoes the request's [`FLAG_CRC`]: every
/// frame answering this request is sealed with the checksum trailer.
fn handle_request(
    service: &AsyncDotService,
    tx: &SyncSender<WriterMsg>,
    id: u64,
    request: Request,
    meta: RequestMeta,
    crc: bool,
    net: &NetOptions,
) -> bool {
    let deadline = meta.deadline_us.map(Duration::from_micros);
    let tenant = meta.tenant.unwrap_or(0);
    let rev12 = meta.deadline_us.is_some()
        || meta.tenant.is_some()
        || meta.cache
        || meta.errbound
        || meta.scrub
        || crc;
    match request {
        Request::Stats => {
            // Extensions are negotiated per request (PROTOCOL.md §6): a
            // tenant-prefixed STATS asks for the rev-1.2 per-tenant rows,
            // the cache flag asks for the rev-1.3 store/cache counters
            // (composable with tenant rows), the scrub flag additionally
            // asks for the rev-1.4 integrity counters (implying the cache
            // block it extends), and a plain STATS gets the classic
            // frame, so older clients never see bytes they cannot parse.
            let tenants = if meta.tenant.is_some() {
                Some(wire_tenant_stats(service))
            } else {
                None
            };
            let frame = if meta.scrub {
                // A scrub probe also drives one background sweep before
                // the counters are read (PROTOCOL.md §3.7): the snapshot
                // then reflects a full digest re-check of every resident
                // operand, and `scrub_passes` ticks visibly on the wire.
                service.store().scrub_all();
                codec::encode_stats_result_ext(
                    id,
                    &wire_stats(service),
                    tenants.as_deref(),
                    Some(&wire_cache_stats(service)),
                    Some(&wire_scrub_stats(service)),
                )
            } else if meta.cache {
                codec::encode_stats_result_ext(
                    id,
                    &wire_stats(service),
                    tenants.as_deref(),
                    Some(&wire_cache_stats(service)),
                    None,
                )
            } else if let Some(rows) = &tenants {
                codec::encode_stats_result_tenants(id, &wire_stats(service), rows)
            } else {
                codec::encode_stats_result(id, &wire_stats(service))
            };
            send(tx, WriterMsg::Raw(sealed(frame, crc)))
        }
        Request::Register(data) => match service.register_operand(data) {
            Ok(out) => send(
                tx,
                WriterMsg::Raw(sealed(
                    codec::encode_register_result(id, out.handle, out.n as u64, out.fresh),
                    crc,
                )),
            ),
            // STORE_FULL is non-fatal (PROTOCOL.md §4.13): nothing was
            // evicted or registered, and the connection keeps serving.
            Err(e @ BackendError::StoreFull { .. }) => send(
                tx,
                WriterMsg::Raw(sealed(
                    codec::encode_error(id, ErrorCode::StoreFull, &e.to_string()),
                    crc,
                )),
            ),
            Err(e) => send(
                tx,
                WriterMsg::Raw(sealed(
                    codec::encode_error(id, ErrorCode::Internal, &e.to_string()),
                    crc,
                )),
            ),
        },
        Request::Release(handle) => {
            // Idempotent by design (PROTOCOL.md §3.9): releasing a handle
            // that is not resident acknowledges `found == false` rather
            // than erroring, so clients can release unconditionally.
            let found = service.release_operand(handle);
            send(
                tx,
                WriterMsg::Raw(sealed(codec::encode_release_result(id, found), crc)),
            )
        }
        Request::SubmitHandles { a, b } => {
            match service.try_submit_handles_with_opts(
                a,
                b,
                Instant::now(),
                deadline,
                tenant,
                meta.errbound,
            ) {
                Ok(TrySubmit::Accepted(handle)) => {
                    send(tx, WriterMsg::Pending { id, handle, crc })
                }
                Ok(TrySubmit::Busy) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        shed_frame(
                            service,
                            id,
                            ErrorCode::Busy,
                            "submission queue full; retry (PROTOCOL.md §5)",
                            rev12,
                        ),
                        crc,
                    )),
                ),
                Ok(TrySubmit::Quota) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        shed_frame(
                            service,
                            id,
                            ErrorCode::Quota,
                            &format!("tenant {tenant} is at its queue quota (PROTOCOL.md §4.11)"),
                            rev12,
                        ),
                        crc,
                    )),
                ),
                // UNKNOWN_HANDLE is non-fatal (PROTOCOL.md §4.12): the
                // client may have raced an eviction or a release and can
                // re-register on the same connection.
                Err(e @ BackendError::UnknownHandle { .. }) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        codec::encode_error(id, ErrorCode::UnknownHandle, &e.to_string()),
                        crc,
                    )),
                ),
                // CORRUPT_OPERAND is likewise non-fatal (PROTOCOL.md
                // §4.15): the scrubber quarantined the operand, and the
                // client recovers by re-registering the clean contents.
                Err(e @ BackendError::CorruptOperand { .. }) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        codec::encode_error(id, ErrorCode::CorruptOperand, &e.to_string()),
                        crc,
                    )),
                ),
                Err(BackendError::Runtime(msg)) => {
                    let _ = send_error(tx, id, ErrorCode::Shutdown, &msg);
                    false
                }
                Err(e) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        codec::encode_error(id, ErrorCode::Invalid, &e.to_string()),
                        crc,
                    )),
                ),
            }
        }
        Request::Submit(input) => {
            match service.try_submit_with_opts(input, Instant::now(), deadline, tenant, meta.errbound)
            {
                Ok(TrySubmit::Accepted(handle)) => {
                    send(tx, WriterMsg::Pending { id, handle, crc })
                }
                Ok(TrySubmit::Busy) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        shed_frame(
                            service,
                            id,
                            ErrorCode::Busy,
                            "submission queue full; retry (PROTOCOL.md §5)",
                            rev12,
                        ),
                        crc,
                    )),
                ),
                Ok(TrySubmit::Quota) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        shed_frame(
                            service,
                            id,
                            ErrorCode::Quota,
                            &format!("tenant {tenant} is at its queue quota (PROTOCOL.md §4.11)"),
                            rev12,
                        ),
                        crc,
                    )),
                ),
                Err(BackendError::Runtime(msg)) => {
                    let _ = send_error(tx, id, ErrorCode::Shutdown, &msg);
                    false
                }
                Err(e) => send(
                    tx,
                    WriterMsg::Raw(sealed(
                        codec::encode_error(id, ErrorCode::Invalid, &e.to_string()),
                        crc,
                    )),
                ),
            }
        }
        Request::Batch(inputs) => submit_batch(service, tx, id, inputs, meta, crc, net),
    }
}

/// Batched admission: validate everything first (one bad request fails the
/// whole batch before anything enqueues — same atomicity as the in-process
/// API), then submit through the *blocking* path: a full queue stalls this
/// reader, i.e. socket-level backpressure (PROTOCOL.md §5).
fn submit_batch(
    service: &AsyncDotService,
    tx: &SyncSender<WriterMsg>,
    id: u64,
    inputs: Vec<SharedInput>,
    meta: RequestMeta,
    crc: bool,
    net: &NetOptions,
) -> bool {
    let deadline = meta.deadline_us.map(Duration::from_micros);
    let tenant = meta.tenant.unwrap_or(0);
    let rev12 = meta.deadline_us.is_some()
        || meta.tenant.is_some()
        || meta.cache
        || meta.errbound
        || meta.scrub
        || crc;
    for input in &inputs {
        if let Err(e) = input.view().check(service.service().spec_for(&input.view())) {
            return send(
                tx,
                WriterMsg::Raw(sealed(
                    codec::encode_error(id, ErrorCode::Invalid, &e.to_string()),
                    crc,
                )),
            );
        }
    }
    let mut handles = Vec::with_capacity(inputs.len());
    let total = inputs.len();
    for (k, input) in inputs.into_iter().enumerate() {
        // Injected connection drop halfway through admission: the
        // already-admitted half still resolves inside the pipeline (the
        // dropped handles just discard the results) — an abandoned batch
        // must never wedge the dispatcher.
        if k == total / 2 && net.fire(FaultSite::ConnDropMidBatch) {
            return false;
        }
        match service.submit_with_opts(input, Instant::now(), deadline, tenant, meta.errbound) {
            Ok(handle) => handles.push(handle),
            Err(BackendError::QuotaExceeded { tenant }) => {
                // Quota struck mid-batch: the whole batch fails with the
                // typed QUOTA frame (non-fatal — the connection keeps
                // serving). Already-admitted requests still resolve
                // inside the pipeline; their handles are dropped here and
                // the results discarded.
                return send(
                    tx,
                    WriterMsg::Raw(sealed(
                        shed_frame(
                            service,
                            id,
                            ErrorCode::Quota,
                            &format!("tenant {tenant} is at its queue quota (PROTOCOL.md §4.11)"),
                            rev12,
                        ),
                        crc,
                    )),
                );
            }
            Err(e) => {
                let _ = send_error(tx, id, ErrorCode::Shutdown, &e.to_string());
                return false;
            }
        }
    }
    send(tx, WriterMsg::Batch { id, handles, crc })
}

fn result_of(response: ServeResponse) -> WireResult {
    WireResult {
        value: response.value,
        n: response.n as u64,
        path: response.path,
        err_bound: response.err_bound,
    }
}

/// Encode one resolved ticket: a result frame, or a typed error frame if
/// the request failed inside the pipeline (deadline shed, dispatcher
/// drain, worker panic). Sealed with the CRC trailer when the request
/// negotiated it.
fn resolve_frame(id: u64, handle: ResponseHandle, crc: bool) -> Vec<u8> {
    let frame = match handle.wait() {
        Ok(response) => codec::encode_result(id, &result_of(response)),
        Err(e) => codec::encode_error(id, error_code_of(&e), &e.to_string()),
    };
    sealed(frame, crc)
}

/// The writer half: owns the socket's write side. Raw frames go straight
/// out; pending tickets are polled with `try_wait` and written in
/// *completion* order (the out-of-order streaming the per-request ids
/// exist for); batches block until fully resolved and go out as one
/// frame. Exits once the reader hung up and every pending ticket is
/// written, or on any write failure.
fn writer_main(stream: TcpStream, rx: Receiver<WriterMsg>, net: Arc<NetOptions>) {
    let mut out = BufWriter::new(stream);
    let mut pending: Vec<(u64, ResponseHandle, bool)> = Vec::new();
    let mut open = true;
    loop {
        // Injected slow client: the writer is descheduled as if the
        // peer's receive window closed. Responses back up into the
        // bounded queue; the reader blocks; backpressure, not loss.
        if let Some(delay) = net.stall(FaultSite::SlowClientWriter) {
            std::thread::sleep(delay);
        }
        // Flush whatever has resolved since the last pass.
        let mut wrote = false;
        let mut i = 0;
        while i < pending.len() {
            if pending[i].1.try_wait().is_some() {
                let (id, handle, crc) = pending.swap_remove(i);
                let mut frame = resolve_frame(id, handle, crc);
                // Injected truncated frame: write half, then die — the
                // client must surface a framing error, never hang.
                if net.fire(FaultSite::TruncatedFrame) {
                    let _ = out.write_all(&frame[..frame.len() / 2]);
                    let _ = out.flush();
                    return;
                }
                if net.fire(FaultSite::SocketWriteError) {
                    return; // injected write failure: connection dies
                }
                // Injected frame corruption (revision 1.4): flip one bit
                // of the sealed frame's CRC trailer in flight, so the
                // client's checksum verification must reject the frame.
                // The fire gate sits behind the seal check — the site is
                // only armed against peers whose detector (the trailer)
                // is present, so every injection is detectable.
                if frame[6] & FLAG_CRC != 0 && net.fire(FaultSite::FrameCrcCorrupt) {
                    let last = frame.len() - 1;
                    frame[last] ^= 0x01;
                }
                if out.write_all(&frame).is_err() {
                    return;
                }
                wrote = true;
            } else {
                i += 1;
            }
        }
        if wrote && out.flush().is_err() {
            return;
        }
        if pending.is_empty() && !open {
            return;
        }
        let msg = if !open {
            std::thread::sleep(WRITER_POLL);
            None
        } else if pending.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => {
                    open = false;
                    None
                }
            }
        } else {
            match rx.recv_timeout(WRITER_POLL) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    open = false;
                    None
                }
            }
        };
        match msg {
            None => {}
            Some(WriterMsg::Raw(frame)) => {
                if out.write_all(&frame).is_err() || out.flush().is_err() {
                    return;
                }
            }
            Some(WriterMsg::Pending { id, handle, crc }) => pending.push((id, handle, crc)),
            Some(WriterMsg::Batch { id, handles, crc }) => {
                let mut results = Vec::with_capacity(handles.len());
                let mut failed: Option<BackendError> = None;
                for handle in handles {
                    match handle.wait() {
                        Ok(response) => results.push(result_of(response)),
                        Err(e) => {
                            failed.get_or_insert(e);
                        }
                    }
                }
                let frame = sealed(
                    match failed {
                        None => codec::encode_batch_result(id, &results),
                        Some(e) => codec::encode_error(id, error_code_of(&e), &e.to_string()),
                    },
                    crc,
                );
                if out.write_all(&frame).is_err() || out.flush().is_err() {
                    return;
                }
            }
        }
    }
}

/// A call failure as seen by [`WireClient`].
#[derive(Debug)]
pub enum WireCallError {
    /// The socket failed.
    Io(std::io::Error),
    /// The response could not be decoded, or violated the protocol (wrong
    /// id, wrong frame kind).
    Protocol(WireError),
    /// The server answered with a typed error frame (PROTOCOL.md §4).
    Server(WireError),
}

impl std::fmt::Display for WireCallError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireCallError::Io(e) => write!(f, "wire i/o: {e}"),
            WireCallError::Protocol(e) => write!(f, "wire protocol: {e}"),
            WireCallError::Server(e) => write!(f, "server error: {e}"),
        }
    }
}

impl From<std::io::Error> for WireCallError {
    fn from(e: std::io::Error) -> Self {
        WireCallError::Io(e)
    }
}

/// Deterministic jitter in `[0, span_ns)` derived from the request id and
/// retry ordinal (a splitmix64 finalizer): spreads concurrent retriers
/// without clocks or a global RNG, and replays exactly.
fn jitter_ns(id: u64, attempt: u32, span_ns: u64) -> u64 {
    if span_ns == 0 {
        return 0;
    }
    let mut z = id ^ (u64::from(attempt) << 32) ^ 0x9E37_79B9_7F4A_7C15;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    z % span_ns
}

/// Pause before the next BUSY retry: the server's retry-after hint when
/// present (rev 1.2; capped at 4× the backoff cap), else capped
/// exponential backoff. Either way the deterministic jitter places the
/// pause in `[target/2, target]` — a shared hint taken verbatim would
/// march every backed-off client back in lockstep, re-creating the very
/// arrival spike the shed was relieving.
fn busy_backoff(attempt: u32, id: u64, hint_us: Option<u32>) -> Duration {
    let target = match hint_us {
        Some(us) if us > 0 => Duration::from_micros(u64::from(us)).min(BUSY_BACKOFF_CAP * 4),
        _ => BUSY_BACKOFF_BASE
            .saturating_mul(1u32 << attempt.min(12))
            .min(BUSY_BACKOFF_CAP),
    };
    let half = target / 2;
    let span_ns = (target - half).as_nanos() as u64;
    half + Duration::from_nanos(jitter_ns(id, attempt, span_ns.saturating_add(1)))
}

/// A blocking, single-connection protocol client: one request in flight at
/// a time, BUSY responses retried transparently (counted in
/// [`Self::busy_retries`]) under capped exponential backoff with
/// deterministic jitter and a wall-clock budget. The multi-connection
/// pipelined load generator lives in [`loadgen`](super::loadgen); this
/// client is the simple building block the tests and CLI probes use.
pub struct WireClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
    busy_retries: u64,
    busy_budget: Duration,
    crc: bool,
}

impl WireClient {
    /// Connect to a `serve-net` server.
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone()?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer: BufWriter::new(write_half),
            next_id: 1,
            busy_retries: 0,
            busy_budget: BUSY_RETRY_BUDGET,
            crc: false,
        })
    }

    /// Opt into revision-1.4 frame checksums (PROTOCOL.md §2.6): every
    /// subsequent request is sealed with the CRC32C trailer, the server
    /// answers in kind, and [`Self::read_response`] verifies each reply's
    /// trailer before believing a byte of it — a corrupted frame surfaces
    /// as the typed [`ErrorCode::CorruptFrame`] protocol error instead of
    /// silently wrong data. Off (the default), requests and responses are
    /// byte-identical to revision 1.3.
    pub fn set_crc(&mut self, on: bool) {
        self.crc = on;
    }

    /// Whether revision-1.4 frame checksums are negotiated on this client.
    pub fn crc(&self) -> bool {
        self.crc
    }

    /// Seal an outgoing frame with the CRC trailer when negotiated.
    fn seal(&self, mut frame: Vec<u8>) -> Vec<u8> {
        if self.crc {
            codec::seal_crc(&mut frame);
        }
        frame
    }

    /// BUSY retries absorbed so far (PROTOCOL.md §5 round trips that
    /// re-sent a request).
    pub fn busy_retries(&self) -> u64 {
        self.busy_retries
    }

    /// Override the wall-clock budget for transparent BUSY retries (the
    /// default is [`BUSY_RETRY_BUDGET`]). Once a call has spent the
    /// budget, the BUSY error surfaces to the caller instead of retrying.
    pub fn set_busy_retry_budget(&mut self, budget: Duration) {
        self.busy_budget = budget;
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Read exactly one response frame addressed to `id`. A CRC-flagged
    /// response is checksum-verified before decoding (revision 1.4): a
    /// mismatch is the typed [`ErrorCode::CorruptFrame`] protocol error.
    fn read_response(&mut self, id: u64) -> Result<Response, WireCallError> {
        let mut head = [0u8; HEADER_LEN];
        self.reader.read_exact(&mut head)?;
        let header = codec::decode_header(&head).map_err(WireCallError::Protocol)?;
        let mut payload = vec![0u8; header.payload_len as usize];
        if header.payload_len > 0 {
            self.reader.read_exact(&mut payload)?;
        }
        let body = codec::verify_crc(&head, header.flags, &payload)
            .map_err(WireCallError::Protocol)?;
        let opcode = Opcode::from_byte(header.opcode).ok_or_else(|| {
            WireCallError::Protocol(WireError::new(
                ErrorCode::BadOpcode,
                format!("unassigned response opcode {:#04x}", header.opcode),
            ))
        })?;
        if header.request_id != id {
            return Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("response id {} for request {}", header.request_id, id),
            )));
        }
        codec::decode_response_flagged(header.flags, opcode, body).map_err(WireCallError::Protocol)
    }

    /// Send one frame and read its response, transparently retrying BUSY
    /// under the backoff schedule and wall-clock budget. A QUOTA error is
    /// *not* retried here: it is a typed per-tenant shed the caller must
    /// observe (any retry-after hint rides along in the returned error).
    /// With CRC negotiated ([`Self::set_crc`]) the frame is sealed here,
    /// so every code path — including BUSY re-sends — carries the trailer.
    fn call(&mut self, frame: Vec<u8>, id: u64) -> Result<Response, WireCallError> {
        let frame = self.seal(frame);
        let started = Instant::now();
        let mut attempt = 0u32;
        loop {
            self.writer.write_all(&frame)?;
            self.writer.flush()?;
            match self.read_response(id)? {
                Response::Error(e) if e.code == ErrorCode::Busy => {
                    let pause = busy_backoff(attempt, id, e.retry_after_us);
                    attempt = attempt.saturating_add(1);
                    if started.elapsed() + pause > self.busy_budget {
                        return Err(WireCallError::Server(e));
                    }
                    self.busy_retries += 1;
                    std::thread::sleep(pause);
                }
                Response::Error(e) => return Err(WireCallError::Server(e)),
                other => return Ok(other),
            }
        }
    }

    fn expect_result(resp: Response) -> Result<WireResult, WireCallError> {
        match resp {
            Response::Result(r) => Ok(r),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a result frame, got {other:?}"),
            ))),
        }
    }

    /// One dot product over the wire (PROTOCOL.md §3.1).
    pub fn dot(&mut self, x: &[f64], y: &[f64]) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_dot(id, x, y);
        Self::expect_result(self.call(frame, id)?)
    }

    /// One sum over the wire (PROTOCOL.md §3.2).
    pub fn sum(&mut self, x: &[f64]) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_sum(id, x);
        Self::expect_result(self.call(frame, id)?)
    }

    /// One batched submission over the wire (PROTOCOL.md §3.3); results
    /// come back in submission order.
    pub fn batch(&mut self, inputs: &[SharedInput]) -> Result<Vec<WireResult>, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_batch(id, inputs);
        match self.call(frame, id)? {
            Response::Batch(results) => Ok(results),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a batch-result frame, got {other:?}"),
            ))),
        }
    }

    /// Bound every subsequent socket read: a server that stops answering
    /// for this long turns into an [`WireCallError::Io`] timeout instead
    /// of a hung client. `None` restores indefinite blocking.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// One dot product carrying a deadline budget (PROTOCOL.md §2.4): the
    /// server sheds the request with [`ErrorCode::Deadline`] if the budget
    /// expires before execution begins.
    pub fn dot_with_deadline(
        &mut self,
        x: &[f64],
        y: &[f64],
        deadline: Duration,
    ) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_frame_with_deadline(
            Opcode::Dot,
            id,
            deadline.as_micros() as u64,
            &codec::encode_dot_payload(x, y),
        );
        Self::expect_result(self.call(frame, id)?)
    }

    /// One batched submission carrying a deadline budget shared by every
    /// request in the batch (PROTOCOL.md §2.4, §3.3).
    pub fn batch_with_deadline(
        &mut self,
        inputs: &[SharedInput],
        deadline: Duration,
    ) -> Result<Vec<WireResult>, WireCallError> {
        let id = self.fresh_id();
        let full = codec::encode_batch(id, inputs);
        let frame = codec::encode_frame_with_deadline(
            Opcode::Batch,
            id,
            deadline.as_micros() as u64,
            &full[HEADER_LEN..],
        );
        match self.call(frame, id)? {
            Response::Batch(results) => Ok(results),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a batch-result frame, got {other:?}"),
            ))),
        }
    }

    /// Probe the server's pipeline counters (PROTOCOL.md §3.4/§3.7).
    pub fn stats(&mut self) -> Result<WireStats, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_stats(id);
        match self.call(frame, id)? {
            Response::Stats(stats) => Ok(stats),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a stats frame, got {other:?}"),
            ))),
        }
    }

    /// One dot product tagged with request metadata — tenant id and/or
    /// deadline budget (PROTOCOL.md §2.4/§2.5). Tenant-tagged requests
    /// are quota-checked and weighted-fair scheduled under their tenant's
    /// class; a tenant at quota draws the typed QUOTA error frame.
    pub fn dot_with_meta(
        &mut self,
        x: &[f64],
        y: &[f64],
        meta: RequestMeta,
    ) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame =
            codec::encode_frame_with_meta(Opcode::Dot, id, meta, &codec::encode_dot_payload(x, y));
        Self::expect_result(self.call(frame, id)?)
    }

    /// One dot product on behalf of `tenant` (PROTOCOL.md §2.5).
    pub fn dot_with_tenant(
        &mut self,
        x: &[f64],
        y: &[f64],
        tenant: u32,
    ) -> Result<WireResult, WireCallError> {
        self.dot_with_meta(
            x,
            y,
            RequestMeta {
                tenant: Some(tenant),
                ..RequestMeta::default()
            },
        )
    }

    /// One handle-pair dot product that also requests the revision-1.4
    /// certified error bound (PROTOCOL.md §3.5): the returned
    /// [`WireResult::err_bound`] carries the server's a-posteriori
    /// round-off certificate for the delivered value.
    pub fn dot_handles_with_errbound(
        &mut self,
        a: u64,
        b: u64,
    ) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_frame_with_meta(
            Opcode::DotHandles,
            id,
            RequestMeta {
                errbound: true,
                ..RequestMeta::default()
            },
            &codec::encode_dot_handles_payload(a, b),
        );
        Self::expect_result(self.call(frame, id)?)
    }

    /// One dot product that also requests the revision-1.4 certified
    /// error bound (PROTOCOL.md §3.5).
    pub fn dot_with_errbound(
        &mut self,
        x: &[f64],
        y: &[f64],
    ) -> Result<WireResult, WireCallError> {
        self.dot_with_meta(
            x,
            y,
            RequestMeta {
                errbound: true,
                ..RequestMeta::default()
            },
        )
    }

    /// One batched submission tagged with request metadata shared by the
    /// whole batch (PROTOCOL.md §2.4/§2.5, §3.3).
    pub fn batch_with_meta(
        &mut self,
        inputs: &[SharedInput],
        meta: RequestMeta,
    ) -> Result<Vec<WireResult>, WireCallError> {
        let id = self.fresh_id();
        let full = codec::encode_batch(id, inputs);
        let frame = codec::encode_frame_with_meta(Opcode::Batch, id, meta, &full[HEADER_LEN..]);
        match self.call(frame, id)? {
            Response::Batch(results) => Ok(results),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a batch-result frame, got {other:?}"),
            ))),
        }
    }

    /// Probe the pipeline counters *plus* the per-tenant accounting rows
    /// (rev 1.2 tenant stats extension, PROTOCOL.md §3.7). `tenant` names
    /// the asking tenant (it marks the request rev-1.2 so the server
    /// answers with the extended frame).
    pub fn stats_tenants(
        &mut self,
        tenant: u32,
    ) -> Result<(WireStats, Vec<WireTenantStats>), WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_stats_tenants(id, tenant);
        match self.call(frame, id)? {
            Response::TenantStats { stats, tenants } => Ok((stats, tenants)),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a tenant stats frame, got {other:?}"),
            ))),
        }
    }

    /// Register an operand vector in the server's resident store
    /// (PROTOCOL.md §3.8, revision 1.3): the payload crosses the wire
    /// once, and the returned `(handle, n, fresh)` names it for every
    /// subsequent [`Self::dot_handles`]. Registering contents already
    /// resident returns the same handle with `fresh == false`.
    pub fn register(&mut self, x: &[f64]) -> Result<(u64, u64, bool), WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_register(id, x);
        match self.call(frame, id)? {
            Response::Registered { handle, n, fresh } => Ok((handle, n, fresh)),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a register-result frame, got {other:?}"),
            ))),
        }
    }

    /// Release a resident-operand handle (PROTOCOL.md §3.9, revision 1.3).
    /// Returns whether the handle was resident; releasing an unknown
    /// handle is acknowledged with `false`, never an error.
    pub fn release(&mut self, handle: u64) -> Result<bool, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_release(id, handle);
        match self.call(frame, id)? {
            Response::Released { found } => Ok(found),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a release-result frame, got {other:?}"),
            ))),
        }
    }

    /// One dot product submitted by resident-operand handle pair
    /// (PROTOCOL.md §3.10, revision 1.3): 16 payload bytes regardless of
    /// operand length. A handle that is not resident draws the typed
    /// non-fatal [`ErrorCode::UnknownHandle`] frame.
    pub fn dot_handles(&mut self, a: u64, b: u64) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_dot_handles(id, a, b);
        Self::expect_result(self.call(frame, id)?)
    }

    /// [`Self::dot_handles`] tagged with request metadata — tenant id
    /// and/or deadline budget (PROTOCOL.md §2.4/§2.5).
    pub fn dot_handles_with_meta(
        &mut self,
        a: u64,
        b: u64,
        meta: RequestMeta,
    ) -> Result<WireResult, WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_frame_with_meta(
            Opcode::DotHandles,
            id,
            meta,
            &codec::encode_dot_handles_payload(a, b),
        );
        Self::expect_result(self.call(frame, id)?)
    }

    /// Probe the pipeline counters plus the rev-1.3 operand-store and
    /// result-cache extension (PROTOCOL.md §3.7). Pass a tenant to also
    /// request the per-tenant rows (empty in the reply otherwise — the
    /// two extensions compose independently).
    pub fn stats_cache(
        &mut self,
        tenant: Option<u32>,
    ) -> Result<(WireStats, Vec<WireTenantStats>, WireCacheStats), WireCallError> {
        let id = self.fresh_id();
        let frame = codec::encode_stats_cache(id, tenant);
        match self.call(frame, id)? {
            Response::CacheStats {
                stats,
                tenants,
                cache,
                ..
            } => Ok((stats, tenants, cache)),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a cache stats frame, got {other:?}"),
            ))),
        }
    }

    /// Probe the pipeline counters plus the rev-1.4 integrity extension
    /// (PROTOCOL.md §3.7): the cache block and the scrub/verification
    /// counters it extends. Pass a tenant to also request the per-tenant
    /// rows.
    #[allow(clippy::type_complexity)]
    pub fn stats_scrub(
        &mut self,
        tenant: Option<u32>,
    ) -> Result<(WireStats, Vec<WireTenantStats>, WireCacheStats, WireScrubStats), WireCallError>
    {
        let id = self.fresh_id();
        let frame = codec::encode_stats_scrub(id, tenant);
        match self.call(frame, id)? {
            Response::CacheStats {
                stats,
                tenants,
                cache,
                scrub: Some(scrub),
            } => Ok((stats, tenants, cache, scrub)),
            other => Err(WireCallError::Protocol(WireError::new(
                ErrorCode::Malformed,
                format!("expected a scrub stats frame, got {other:?}"),
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::ImplStyle;
    use crate::serve::{DotService, ThresholdMode};
    use crate::util::rng::Rng;

    fn cfg(threads: usize, threshold: usize) -> ServeConfig {
        ServeConfig {
            threads,
            style: ImplStyle::SimdLanes,
            compensated: true,
            shard_threshold: ThresholdMode::Fixed(threshold),
            freq_ghz: 3.0,
            verify_hit_rate: 0.0,
        }
    }

    fn randvec(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn loopback_dot_matches_in_process_bits() {
        let server = NetServer::bind("127.0.0.1:0", cfg(2, 1000), AsyncOptions::default()).unwrap();
        let reference = DotService::new(cfg(2, 1000)).unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        for (i, n) in [8usize, 999, 1000, 4096].into_iter().enumerate() {
            let x = randvec(n, 50 + i as u64);
            let y = randvec(n, 150 + i as u64);
            let wire = client.dot(&x, &y).unwrap();
            let local = reference
                .submit(&crate::runtime::backend::KernelInput::Dot(&x, &y))
                .unwrap();
            assert_eq!(wire.value.to_bits(), local.value.to_bits(), "n={n}");
            assert_eq!(wire.path, local.path);
            assert_eq!(wire.n, n as u64);
        }
    }

    #[test]
    fn loopback_stats_and_garbage_handling() {
        let server = NetServer::bind("127.0.0.1:0", cfg(1, usize::MAX), AsyncOptions::default())
            .unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        let x = randvec(64, 3);
        client.dot(&x, &x).unwrap();
        let stats = client.stats().unwrap();
        assert_eq!(stats.threads, 1);
        assert!(stats.enqueued >= 1);
        assert!(stats.completed >= 1);
        // An unassigned opcode draws a typed BAD_OPCODE error frame and
        // the connection stays usable (PROTOCOL.md §4.3).
        let id = client.fresh_id();
        let mut frame = codec::encode_stats(id);
        frame[5] = 0x42; // clobber the opcode byte
        match client.call(frame, id) {
            Err(WireCallError::Server(e)) => assert_eq!(e.code, ErrorCode::BadOpcode),
            other => panic!("expected a BadOpcode error frame, got {other:?}"),
        }
        // Batches still round-trip on the same connection afterwards.
        let results = client.batch(&[SharedInput::sum(&x)]).unwrap();
        assert_eq!(results.len(), 1);
        client.sum(&x).unwrap();
    }

    #[test]
    fn zero_deadline_draws_typed_deadline_error_and_connection_survives() {
        let server = NetServer::bind("127.0.0.1:0", cfg(2, 1000), AsyncOptions::default()).unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        let x = randvec(256, 11);
        match client.dot_with_deadline(&x, &x, Duration::ZERO) {
            Err(WireCallError::Server(e)) => assert_eq!(e.code, ErrorCode::Deadline),
            other => panic!("expected a DEADLINE error frame, got {other:?}"),
        }
        // Non-fatal: the same connection keeps serving, and a generous
        // deadline completes normally with in-process-identical bits.
        let reference = DotService::new(cfg(2, 1000)).unwrap();
        let wire = client
            .dot_with_deadline(&x, &x, Duration::from_secs(60))
            .unwrap();
        let local = reference
            .submit(&crate::runtime::backend::KernelInput::Dot(&x, &x))
            .unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits());
    }

    #[test]
    fn idle_connections_are_reaped_and_active_ones_survive_timeouts() {
        let net = NetOptions {
            read_timeout: Some(Duration::from_millis(20)),
            idle_timeout: Some(Duration::from_millis(60)),
            write_timeout: Some(Duration::from_secs(5)),
            writer_queue: 16,
            faults: None,
            qos: None,
        };
        let server =
            NetServer::bind_with("127.0.0.1:0", cfg(1, 1000), AsyncOptions::default(), net)
                .unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        let x = randvec(64, 21);
        // Gaps shorter than the idle limit never trip the reaper, even
        // though each one spans several read-timeout ticks.
        client.dot(&x, &x).unwrap();
        std::thread::sleep(Duration::from_millis(35));
        client.dot(&x, &x).unwrap();
        // Past the idle limit the server closes the connection: the next
        // call fails with EOF/reset instead of hanging.
        std::thread::sleep(Duration::from_millis(150));
        client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        assert!(client.dot(&x, &x).is_err(), "reaped connection must not serve");
        // A fresh connection works: the server itself is healthy.
        let mut fresh = WireClient::connect(server.local_addr()).unwrap();
        fresh.dot(&x, &x).unwrap();
    }

    #[test]
    fn tenant_quota_draws_typed_quota_frame_with_retry_hint() {
        // Tenant 1 has quota 0: every tagged submission sheds with QUOTA
        // (not BUSY), carries the rev-1.2 retry hint, and the connection
        // keeps serving. Untagged (tenant-0) traffic is unaffected.
        let net = NetOptions {
            qos: Some(QosPolicy::parse("a:3:64,z:1:0").unwrap()),
            ..NetOptions::default()
        };
        let server =
            NetServer::bind_with("127.0.0.1:0", cfg(2, 1000), AsyncOptions::default(), net)
                .unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        let x = randvec(128, 31);
        match client.dot_with_tenant(&x, &x, 1) {
            Err(WireCallError::Server(e)) => {
                assert_eq!(e.code, ErrorCode::Quota);
                assert!(
                    e.retry_after_us.unwrap_or(0) > 0,
                    "rev-1.2 request must draw a retry-after hint"
                );
            }
            other => panic!("expected a QUOTA error frame, got {other:?}"),
        }
        // The same connection still serves tenant 0 (untagged) and tenant
        // 0-tagged requests, bit-identical to in-process execution.
        let reference = DotService::new(cfg(2, 1000)).unwrap();
        let wire = client.dot_with_tenant(&x, &x, 0).unwrap();
        let local = reference
            .submit(&crate::runtime::backend::KernelInput::Dot(&x, &x))
            .unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits());
        // The tenant stats extension reports the shed exactly once.
        let (stats, tenants) = client.stats_tenants(1).unwrap();
        assert!(stats.completed >= 1);
        let z = tenants.iter().find(|t| t.tenant == 1).unwrap();
        assert_eq!(z.quota_shed, 1);
        assert_eq!(z.admitted, 0);
        let a = tenants.iter().find(|t| t.tenant == 0).unwrap();
        assert_eq!(a.quota_shed, 0);
        assert!(a.admitted >= 1);
    }

    #[test]
    fn loopback_register_submit_release_round_trip() {
        let server = NetServer::bind("127.0.0.1:0", cfg(2, 1000), AsyncOptions::default()).unwrap();
        let reference = DotService::new(cfg(2, 1000)).unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        let x = randvec(512, 61);
        let y = randvec(512, 62);
        let (a, na, fresh_a) = client.register(&x).unwrap();
        assert!(fresh_a);
        assert_eq!(na, 512);
        let (b, _, _) = client.register(&y).unwrap();
        // Re-registration is an upsert: same handle, not fresh.
        let (a2, _, fresh_again) = client.register(&x).unwrap();
        assert_eq!(a2, a);
        assert!(!fresh_again);
        // First handle submit computes; the second replays the memoized
        // result — both bit-identical to in-process execution.
        let miss = client.dot_handles(a, b).unwrap();
        let hit = client.dot_handles(a, b).unwrap();
        let local = reference
            .submit(&crate::runtime::backend::KernelInput::Dot(&x, &y))
            .unwrap();
        assert_eq!(miss.value.to_bits(), local.value.to_bits());
        assert_eq!(hit.value.to_bits(), miss.value.to_bits());
        assert_eq!(hit.path, miss.path);
        let (stats, tenants, cache) = client.stats_cache(None).unwrap();
        assert!(tenants.is_empty(), "cache-only probe carries no tenant rows");
        assert_eq!(cache.store_entries, 2);
        assert_eq!(cache.cache_hits, 1);
        assert_eq!(cache.cache_lookups, cache.cache_hits + cache.cache_misses);
        assert_eq!(stats.completed, stats.enqueued + cache.cache_hits);
        // Release is idempotent; a released handle draws the typed
        // non-fatal UNKNOWN_HANDLE frame and the connection survives.
        assert!(client.release(a).unwrap());
        assert!(!client.release(a).unwrap());
        match client.dot_handles(a, b) {
            Err(WireCallError::Server(e)) => assert_eq!(e.code, ErrorCode::UnknownHandle),
            other => panic!("expected an UNKNOWN_HANDLE error frame, got {other:?}"),
        }
        client.dot(&x, &y).unwrap();
    }

    #[test]
    fn busy_backoff_is_deterministic_capped_and_hint_driven() {
        // Pure schedule checks — no socket involved.
        assert_eq!(busy_backoff(0, 7, None), busy_backoff(0, 7, None));
        for attempt in 0..20 {
            let p = busy_backoff(attempt, 42, None);
            assert!(p >= BUSY_BACKOFF_BASE / 2, "floor at half the base");
            assert!(p <= BUSY_BACKOFF_CAP, "cap respected at attempt {attempt}");
        }
        // Different ids de-synchronize (jitter): some pair must differ.
        let spread: Vec<Duration> = (0..8).map(|id| busy_backoff(4, id, None)).collect();
        assert!(
            spread.iter().any(|&p| p != spread[0]),
            "jitter must spread concurrent retriers"
        );
        // A server hint steers the schedule, jittered into the half-open
        // window [hint/2, hint] so backed-off clients never march back in
        // lockstep — but the draw itself is a pure function of (id,
        // attempt), so retry schedules stay reproducible.
        let hinted = busy_backoff(0, 1, Some(1500));
        assert_eq!(hinted, busy_backoff(0, 1, Some(1500)), "hint draw is deterministic");
        assert!(hinted >= Duration::from_micros(750), "hint floor at half");
        assert!(hinted <= Duration::from_micros(1500), "hint is an upper bound");
        assert_eq!(busy_backoff(9, 1, Some(0)), busy_backoff(9, 1, None));
    }

    #[test]
    fn crc_negotiation_round_trips_and_catches_injected_frame_corruption() {
        // With FLAG_CRC negotiated, every frame grows a CRC32C trailer and
        // results stay bit-identical to the unprotected path (rev-1.4
        // parity contract, PROTOCOL.md §2.6).
        let server = NetServer::bind("127.0.0.1:0", cfg(2, 1000), AsyncOptions::default()).unwrap();
        let reference = DotService::new(cfg(2, 1000)).unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        client.set_crc(true);
        assert!(client.crc());
        let x = randvec(512, 71);
        let y = randvec(512, 72);
        let wire = client.dot(&x, &y).unwrap();
        let local = reference
            .submit(&crate::runtime::backend::KernelInput::Dot(&x, &y))
            .unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits());
        // Handle traffic and the scrub stats extension ride the same
        // checked channel.
        let (a, _, _) = client.register(&x).unwrap();
        let (b, _, _) = client.register(&y).unwrap();
        let miss = client.dot_handles(a, b).unwrap();
        let hit = client.dot_handles(a, b).unwrap();
        assert_eq!(miss.value.to_bits(), local.value.to_bits());
        assert_eq!(hit.value.to_bits(), miss.value.to_bits());
        let (stats, _, cache, scrub) = client.stats_scrub(None).unwrap();
        assert!(stats.completed >= 1);
        assert_eq!(cache.cache_hits, 1);
        assert_eq!(scrub.scrub_quarantined, 0);
        assert_eq!(scrub.cache_poisoned, 0);
        // The probe drove one full sweep: both resident operands were
        // digest re-checked and the pass counter ticked.
        assert_eq!(scrub.scrub_passes, 1);
        assert!(scrub.scrub_verified >= 2);
        // A request frame whose trailer is flipped draws the typed
        // non-fatal CORRUPT_FRAME error and the connection keeps serving.
        let id = client.fresh_id();
        let mut frame = codec::encode_stats(id);
        codec::seal_crc(&mut frame);
        let last = frame.len() - 1;
        frame[last] ^= 0x01;
        use std::io::Write as _;
        client.writer.write_all(&frame).unwrap();
        client.writer.flush().unwrap();
        match client.read_response(id) {
            Ok(Response::Error(e)) => assert_eq!(e.code, ErrorCode::CorruptFrame),
            other => panic!("expected a CORRUPT_FRAME error frame, got {other:?}"),
        }
        client.dot(&x, &y).unwrap();
    }

    #[test]
    fn injected_response_corruption_is_detected_by_the_client() {
        // Arm the response-side CRC corruption fault: the first sealed
        // result frame leaves the writer with a flipped trailer bit, and
        // the client's verify pass must refuse to decode it.
        use crate::serve::faults::FaultPlan;
        let net = NetOptions {
            faults: Some(FaultInjector::new(
                FaultPlan::none().with(FaultSite::FrameCrcCorrupt, 1),
            )),
            ..NetOptions::default()
        };
        let server =
            NetServer::bind_with("127.0.0.1:0", cfg(1, 1000), AsyncOptions::default(), net)
                .unwrap();
        let mut client = WireClient::connect(server.local_addr()).unwrap();
        client.set_crc(true);
        let x = randvec(128, 81);
        match client.dot(&x, &x) {
            Err(WireCallError::Protocol(e)) => assert_eq!(e.code, ErrorCode::CorruptFrame),
            other => panic!("expected client-side CORRUPT_FRAME detection, got {other:?}"),
        }
        // One-shot fault: the same connection serves clean frames after.
        let wire = client.dot(&x, &x).unwrap();
        let reference = DotService::new(cfg(1, 1000)).unwrap();
        let local = reference
            .submit(&crate::runtime::backend::KernelInput::Dot(&x, &x))
            .unwrap();
        assert_eq!(wire.value.to_bits(), local.value.to_bits());
    }
}
